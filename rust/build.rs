fn main() {
    // `--cfg loom` is injected via RUSTFLAGS by `make loom`; declare it
    // so rustc's cfg checking doesn't warn on the shim's cfg gates.
    println!("cargo::rustc-check-cfg=cfg(loom)");
}
