//! PJRT runtime: load AOT artifacts and run the data plane from Rust.
//!
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → compile →
//! `execute_b`. Weights are uploaded **once** as device buffers
//! (`PjRtBuffer::read_npy`); per-step inputs (ids, positions, KV state,
//! temperatures, hot mask) are small. HLO *text* is the interchange format
//! (see `python/compile/aot.py` and /opt/xla-example/README.md).
//!
//! Python never runs here — this module plus `artifacts/` is the entire
//! data-plane dependency of the serving binary.

pub mod artifact;

pub use artifact::{default_artifacts_dir, Manifest, ModelArtifact};

use crate::decision::HotVocab;
use xla::{PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

/// Minimal .npy reader for little-endian f32 arrays (what `aot.py` writes).
///
/// We bypass the xla crate's `PjRtBuffer::read_npy`: its raw-bytes upload
/// passes `ElementType as i32` where the C API expects `PrimitiveType`
/// codes, silently uploading f32 data as F16 (off-by-one enum family). The
/// typed `buffer_from_host_buffer::<f32>` path converts correctly.
pub fn read_npy_f32(path: &std::path::Path) -> crate::Result<(Vec<f32>, Vec<usize>)> {
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    anyhow::ensure!(bytes.len() > 10 && &bytes[..6] == b"\x93NUMPY", "not an npy file");
    let header_len = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
    let header = std::str::from_utf8(&bytes[10..10 + header_len])
        .map_err(|_| anyhow::anyhow!("bad npy header"))?;
    anyhow::ensure!(
        header.contains("'descr': '<f4'"),
        "expected '<f4' npy, got header {header}"
    );
    anyhow::ensure!(
        header.contains("'fortran_order': False"),
        "fortran order unsupported"
    );
    let shape_part = header
        .split("'shape': (")
        .nth(1)
        .and_then(|s| s.split(')').next())
        .ok_or_else(|| anyhow::anyhow!("no shape in npy header"))?;
    let dims: Vec<usize> = shape_part
        .split(',')
        .filter_map(|t| t.trim().parse::<usize>().ok())
        .collect();
    let data = &bytes[10 + header_len..];
    let n: usize = dims.iter().product();
    anyhow::ensure!(data.len() == n * 4, "npy size mismatch: {} vs {}", data.len(), n * 4);
    let mut out = Vec::with_capacity(n);
    for chunk in data.chunks_exact(4) {
        out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
    }
    Ok((out, dims))
}

/// One decode step's outputs, host-side.
pub struct StepOutput {
    /// Row-major [B, V] logits.
    pub logits: Vec<f32>,
    /// Per-sequence SHVS stats [B][4]: z_max, s_hot, s_tail, tail_max_w.
    pub stats: Vec<[f32; 4]>,
}

/// A loaded model: compiled executable + resident weight buffers + KV state.
pub struct ModelRuntime {
    client: PjRtClient,
    exe: PjRtLoadedExecutable,
    weight_bufs: Vec<PjRtBuffer>,
    /// KV caches kept host-side between steps (CPU PJRT: device == host
    /// memory, so the per-step upload is a memcpy).
    kv_k: Vec<f32>,
    kv_v: Vec<f32>,
    hot_mask: Vec<f32>,
    pub spec: ModelArtifact,
}

impl ModelRuntime {
    /// Load a model by name from the artifacts directory.
    pub fn load(manifest: &Manifest, name: &str) -> crate::Result<ModelRuntime> {
        let spec = manifest.model(name)?.clone();
        let client = PjRtClient::cpu()?;
        let proto = xla::HloModuleProto::from_text_file(&spec.hlo_path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;

        let mut weight_bufs = Vec::with_capacity(spec.weights.len());
        for w in &spec.weights {
            let (data, dims) = read_npy_f32(&w.file)?;
            anyhow::ensure!(
                dims == w.shape,
                "{}: npy shape {dims:?} != manifest {:?}",
                w.name,
                w.shape
            );
            let buf = client
                .buffer_from_host_buffer(&data, &dims, None)
                .map_err(|e| anyhow::anyhow!("uploading {}: {e:?}", w.name))?;
            weight_bufs.push(buf);
        }

        let kv_elems = spec.kv_elems();
        Ok(ModelRuntime {
            client,
            exe,
            weight_bufs,
            kv_k: vec![0.0; kv_elems],
            kv_v: vec![0.0; kv_elems],
            hot_mask: vec![0.0; spec.vocab],
            spec,
        })
    }

    /// Convenience: load from the default artifacts dir.
    pub fn load_default(name: &str) -> crate::Result<ModelRuntime> {
        let manifest = Manifest::load(&default_artifacts_dir())?;
        Self::load(&manifest, name)
    }

    /// Install the hot-vocab mask fed to the L1 kernel's SHVS precompute.
    pub fn set_hot_vocab(&mut self, hot: &HotVocab) {
        assert_eq!(hot.vocab(), self.spec.vocab);
        self.hot_mask.iter_mut().for_each(|m| *m = 0.0);
        for &id in hot.ids() {
            self.hot_mask[id as usize] = 1.0;
        }
    }

    /// Zero the KV caches (fresh batch).
    pub fn reset_kv(&mut self) {
        self.kv_k.iter_mut().for_each(|x| *x = 0.0);
        self.kv_v.iter_mut().for_each(|x| *x = 0.0);
    }

    /// Zero one batch slot's KV rows (sequence retired, slot reused).
    /// KV layout: [L, B, T, KVH, Dh].
    pub fn reset_kv_slot(&mut self, slot: usize) {
        let spec = &self.spec;
        let (l, b, t, kvh, dh) = (
            spec.kv_shape[0],
            spec.kv_shape[1],
            spec.kv_shape[2],
            spec.kv_shape[3],
            spec.kv_shape[4],
        );
        assert!(slot < b);
        let row = t * kvh * dh;
        for li in 0..l {
            let base = (li * b + slot) * row;
            self.kv_k[base..base + row].iter_mut().for_each(|x| *x = 0.0);
            self.kv_v[base..base + row].iter_mut().for_each(|x| *x = 0.0);
        }
    }

    /// Execute one decode step for the whole microbatch.
    ///
    /// `ids[b]` is the token to feed for slot b, `positions[b]` its position
    /// (0-based) in the sequence, `tau[b]` the temperature for the SHVS
    /// precompute (send 1.0 for greedy slots).
    pub fn step(
        &mut self,
        ids: &[i32],
        positions: &[i32],
        tau: &[f32],
    ) -> crate::Result<StepOutput> {
        let b = self.spec.batch;
        assert_eq!(ids.len(), b);
        assert_eq!(positions.len(), b);
        assert_eq!(tau.len(), b);
        debug_assert!(positions.iter().all(|&p| (p as usize) < self.spec.max_seq));

        let kv_dims = self.spec.kv_shape.clone();
        let ids_buf = self.client.buffer_from_host_buffer(ids, &[b], None)?;
        let pos_buf = self.client.buffer_from_host_buffer(positions, &[b], None)?;
        let kvk_buf = self.client.buffer_from_host_buffer(&self.kv_k, &kv_dims, None)?;
        let kvv_buf = self.client.buffer_from_host_buffer(&self.kv_v, &kv_dims, None)?;
        let tau_buf = self.client.buffer_from_host_buffer(tau, &[b], None)?;
        let hot_buf =
            self.client
                .buffer_from_host_buffer(&self.hot_mask, &[self.spec.vocab], None)?;

        let mut args: Vec<&PjRtBuffer> = self.weight_bufs.iter().collect();
        args.extend([&ids_buf, &pos_buf, &kvk_buf, &kvv_buf, &tau_buf, &hot_buf]);

        let result = self.exe.execute_b(&args)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        anyhow::ensure!(parts.len() == 4, "expected 4 outputs, got {}", parts.len());

        let logits: Vec<f32> = parts[0].to_vec()?;
        let stats_flat: Vec<f32> = parts[1].to_vec()?;
        parts[2].copy_raw_to(&mut self.kv_k)?;
        parts[3].copy_raw_to(&mut self.kv_v)?;

        let stats = stats_flat
            .chunks_exact(4)
            .map(|c| [c[0], c[1], c[2], c[3]])
            .collect();
        Ok(StepOutput { logits, stats })
    }

    pub fn batch(&self) -> usize {
        self.spec.batch
    }
    pub fn vocab(&self) -> usize {
        self.spec.vocab
    }
    pub fn max_seq(&self) -> usize {
        self.spec.max_seq
    }
}
