//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. Parsed from `artifacts/manifest.json`.

use crate::util::json::{read_json_file, Json};
use std::path::{Path, PathBuf};

/// One weight tensor entry.
#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub file: PathBuf,
    pub shape: Vec<usize>,
}

/// One AOT-compiled model.
#[derive(Debug, Clone)]
pub struct ModelArtifact {
    pub name: String,
    pub hlo_path: PathBuf,
    pub batch: usize,
    pub vocab: usize,
    pub layers: usize,
    pub hidden: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    pub kv_shape: Vec<usize>,
    pub weights: Vec<WeightEntry>,
}

impl ModelArtifact {
    /// Total KV elements (one of K or V).
    pub fn kv_elems(&self) -> usize {
        self.kv_shape.iter().product()
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub fingerprint: String,
    pub models: Vec<ModelArtifact>,
    pub root: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> crate::Result<Manifest> {
        let j = read_json_file(&dir.join("manifest.json"))?;
        let models = j
            .get("models")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("manifest: missing models"))?
            .iter()
            .map(|m| parse_model(m, dir))
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(Manifest {
            fingerprint: j
                .get("fingerprint")
                .as_str()
                .unwrap_or_default()
                .to_string(),
            models,
            root: dir.to_path_buf(),
        })
    }

    pub fn model(&self, name: &str) -> crate::Result<&ModelArtifact> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "model {name} not in manifest (have: {})",
                    self.models
                        .iter()
                        .map(|m| m.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }
}

fn get_usize(j: &Json, key: &str) -> crate::Result<usize> {
    j.get(key)
        .as_usize()
        .ok_or_else(|| anyhow::anyhow!("manifest: missing/invalid {key}"))
}

fn parse_model(j: &Json, root: &Path) -> crate::Result<ModelArtifact> {
    let name = j
        .get("name")
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("manifest: model missing name"))?
        .to_string();
    let weights = j
        .get("weights")
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("manifest: missing weights"))?
        .iter()
        .map(|w| {
            Ok(WeightEntry {
                name: w
                    .get("name")
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("weight missing name"))?
                    .to_string(),
                file: root.join(
                    w.get("file")
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("weight missing file"))?,
                ),
                shape: w
                    .get("shape")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|d| d.as_usize())
                    .collect(),
            })
        })
        .collect::<crate::Result<Vec<_>>>()?;
    let kv_shape: Vec<usize> = j
        .get("kv_shape")
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .filter_map(|d| d.as_usize())
        .collect();
    Ok(ModelArtifact {
        hlo_path: root.join(
            j.get("hlo")
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("manifest: missing hlo"))?,
        ),
        batch: get_usize(j, "batch")?,
        vocab: get_usize(j, "vocab")?,
        layers: get_usize(j, "layers")?,
        hidden: get_usize(j, "hidden")?,
        heads: get_usize(j, "heads")?,
        kv_heads: get_usize(j, "kv_heads")?,
        head_dim: get_usize(j, "head_dim")?,
        max_seq: get_usize(j, "max_seq")?,
        kv_shape,
        weights,
        name,
    })
}

/// Default artifacts dir: `$SIMPLE_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("SIMPLE_ARTIFACTS") {
        return PathBuf::from(p);
    }
    // CARGO_MANIFEST_DIR at build time points at the repo root.
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        default_artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn manifest_parses_if_built() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&default_artifacts_dir()).unwrap();
        assert!(!m.models.is_empty());
        let micro = m.model("micro-test").unwrap();
        assert_eq!(micro.vocab, 1000);
        assert_eq!(micro.kv_shape.len(), 5);
        assert!(micro.hlo_path.exists());
        for w in &micro.weights {
            assert!(w.file.exists(), "missing {}", w.file.display());
        }
        assert!(m.model("nope").is_err());
    }
}
