//! Statistics primitives used by the harnesses: percentiles (the paper
//! reports P50/P95/P99 TPOT), ECDFs (Figures 4/5/7), total variation
//! distance (Figure 13's exactness metric), and least-squares affine fitting
//! (Figure 11's T_cpu(H) = cH + c0).

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile `q ∈ [0,100]` by linear interpolation on the sorted copy.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, q)
}

/// Percentile on an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let q = q.clamp(0.0, 100.0);
    let pos = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Empirical CDF evaluated at `points.len()` evenly spaced quantiles;
/// returns (value, cumulative_fraction) pairs — the series for the TPOT
/// ECDF figures.
pub fn ecdf(xs: &[f64], points: usize) -> Vec<(f64, f64)> {
    if xs.is_empty() || points == 0 {
        return Vec::new();
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (0..points)
        .map(|i| {
            let frac = (i + 1) as f64 / points as f64;
            let idx = ((frac * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
            (sorted[idx - 1], frac)
        })
        .collect()
}

/// Total variation distance between two distributions on the same support:
/// `TVD(p, q) = 0.5 * Σ |p_i − q_i|`. Inputs need not be normalized; they
/// are normalized first (empirical histograms are the common caller).
pub fn total_variation_distance(p: &[f64], q: &[f64]) -> f64 {
    assert_eq!(p.len(), q.len(), "support mismatch");
    let sp: f64 = p.iter().sum();
    let sq: f64 = q.iter().sum();
    if sp <= 0.0 || sq <= 0.0 {
        return if sp == sq { 0.0 } else { 1.0 };
    }
    0.5 * p
        .iter()
        .zip(q)
        .map(|(a, b)| (a / sp - b / sq).abs())
        .sum::<f64>()
}

/// Least-squares affine fit `y ≈ c*x + c0`; returns (c, c0, r²).
/// This is exactly the fit used in Figure 11(a) for T_cpu(H).
pub fn affine_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need at least two points");
    let n = xs.len() as f64;
    let mx = mean(xs);
    let my = mean(ys);
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx).powi(2)).sum();
    let c = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    let c0 = my - c * mx;
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| (y - (c * x + c0)).powi(2))
        .sum();
    let ss_tot: f64 = ys.iter().map(|y| (y - my).powi(2)).sum();
    let r2 = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    let _ = n;
    (c, c0, r2)
}

/// Monotone piecewise-linear interpolator (used for the ᾱ(H) hit-ratio
/// curve of §5.4, profiled at a few H points offline).
#[derive(Debug, Clone)]
pub struct Interp1 {
    xs: Vec<f64>,
    ys: Vec<f64>,
}

impl Interp1 {
    /// Points must be strictly increasing in x.
    pub fn new(xs: Vec<f64>, ys: Vec<f64>) -> Self {
        assert_eq!(xs.len(), ys.len());
        assert!(xs.len() >= 2, "need at least two knots");
        assert!(xs.windows(2).all(|w| w[1] > w[0]), "x must be increasing");
        Interp1 { xs, ys }
    }

    /// Evaluate with flat extrapolation outside the knot range.
    pub fn eval(&self, x: f64) -> f64 {
        if x <= self.xs[0] {
            return self.ys[0];
        }
        if x >= *self.xs.last().unwrap() {
            return *self.ys.last().unwrap();
        }
        let i = match self.xs.binary_search_by(|v| v.partial_cmp(&x).unwrap()) {
            Ok(i) => return self.ys[i],
            Err(i) => i,
        };
        let (x0, x1) = (self.xs[i - 1], self.xs[i]);
        let (y0, y1) = (self.ys[i - 1], self.ys[i]);
        y0 + (y1 - y0) * (x - x0) / (x1 - x0)
    }

    /// Finite-difference derivative at x (central where possible).
    pub fn derivative(&self, x: f64) -> f64 {
        let span = self.xs.last().unwrap() - self.xs[0];
        let h = (span * 1e-6).max(1e-9);
        (self.eval(x + h) - self.eval(x - h)) / (2.0 * h)
    }

    pub fn domain(&self) -> (f64, f64) {
        (self.xs[0], *self.xs.last().unwrap())
    }
}

/// Summary statistics for a set of samples.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                stddev: 0.0,
                min: 0.0,
                p50: 0.0,
                p95: 0.0,
                p99: 0.0,
                max: 0.0,
            };
        }
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n: xs.len(),
            mean: mean(xs),
            stddev: stddev(xs),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
            max: *sorted.last().unwrap(),
        }
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("n", Json::Num(self.n as f64)),
            ("mean", Json::Num(self.mean)),
            ("stddev", Json::Num(self.stddev)),
            ("min", Json::Num(self.min)),
            ("p50", Json::Num(self.p50)),
            ("p95", Json::Num(self.p95)),
            ("p99", Json::Num(self.p99)),
            ("max", Json::Num(self.max)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 95.0) - 95.05).abs() < 1e-9);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn ecdf_is_monotone_and_ends_at_max() {
        let xs = vec![3.0, 1.0, 2.0, 5.0, 4.0];
        let e = ecdf(&xs, 10);
        assert_eq!(e.len(), 10);
        for w in e.windows(2) {
            assert!(w[1].0 >= w[0].0);
            assert!(w[1].1 > w[0].1);
        }
        assert_eq!(e.last().unwrap(), &(5.0, 1.0));
    }

    #[test]
    fn tvd_properties() {
        let p = [0.5, 0.5, 0.0];
        let q = [0.0, 0.5, 0.5];
        assert!((total_variation_distance(&p, &p) - 0.0).abs() < 1e-12);
        assert!((total_variation_distance(&p, &q) - 0.5).abs() < 1e-12);
        // symmetric
        assert_eq!(
            total_variation_distance(&p, &q),
            total_variation_distance(&q, &p)
        );
        // disjoint supports => 1
        let a = [1.0, 0.0];
        let b = [0.0, 1.0];
        assert!((total_variation_distance(&a, &b) - 1.0).abs() < 1e-12);
        // unnormalized inputs are normalized
        let a2 = [2.0, 0.0];
        assert!((total_variation_distance(&a2, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn affine_fit_recovers_exact_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64 * 100.0).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 1.06e-8 * x + 8.55e-6).collect();
        let (c, c0, r2) = affine_fit(&xs, &ys);
        assert!((c - 1.06e-8).abs() < 1e-12);
        assert!((c0 - 8.55e-6).abs() < 1e-10);
        assert!(r2 > 0.999999);
    }

    #[test]
    fn affine_fit_noisy_r2_reasonable() {
        let mut rng = crate::rng::Philox::new(1);
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| 2.0 * x + 1.0 + (rng.next_f64() - 0.5) * 0.5)
            .collect();
        let (c, c0, r2) = affine_fit(&xs, &ys);
        assert!((c - 2.0).abs() < 0.02);
        assert!((c0 - 1.0).abs() < 0.5);
        assert!(r2 > 0.99);
    }

    #[test]
    fn interp_matches_knots_and_midpoints() {
        let it = Interp1::new(vec![0.0, 1.0, 3.0], vec![0.0, 10.0, 30.0]);
        assert_eq!(it.eval(0.0), 0.0);
        assert_eq!(it.eval(1.0), 10.0);
        assert_eq!(it.eval(0.5), 5.0);
        assert_eq!(it.eval(2.0), 20.0);
        // flat extrapolation
        assert_eq!(it.eval(-5.0), 0.0);
        assert_eq!(it.eval(99.0), 30.0);
        // derivative of the second segment is 10
        assert!((it.derivative(2.0) - 10.0).abs() < 1e-3);
    }

    #[test]
    fn summary_consistency() {
        let xs: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 1000);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 1000.0);
        assert!((s.mean - 500.5).abs() < 1e-9);
        assert!(s.p50 < s.p95 && s.p95 < s.p99);
        let empty = Summary::of(&[]);
        assert_eq!(empty.n, 0);
    }
}
