//! Measurement: percentiles/ECDF/TVD and the serving-metrics recorder.

pub mod histogram;
pub mod recorder;
pub mod stats;

pub use histogram::LatencyHistogram;
pub use recorder::{Recorder, ServingSummary};
pub use stats::{ecdf, mean, percentile, total_variation_distance, Summary};
