//! Measurement: percentiles/ECDF/TVD and the serving-metrics recorder —
//! every number behind Figures 3–9 and Table 3 flows through here.
//!
//! - [`stats`] — order statistics ([`percentile`], [`ecdf`], [`Summary`]),
//!   [`total_variation_distance`] for the SHVS exactness claims (Fig. 13),
//!   and an affine fitter for the §5.4 sizing model.
//! - [`recorder`] — per-request lifecycles (arrival → first token → finish)
//!   yielding TTFT/TPOT samples and token throughput, plus named
//!   resource-busy intervals (`"gpu"`, `"cpu"`) merged into utilization
//!   and interquartile utilization bands (Figs. 8/9). Time is a plain
//!   `f64` seconds value so the same recorder serves wall-clock engine
//!   runs and simulated-clock runs unchanged.
//! - [`histogram`] — fixed-bin latency histogram for streaming summaries
//!   where keeping every sample would be wasteful.
//!
//! Tail metrics are the product here: the paper's headline claims are P95
//! claims, and the preemption/chunked-prefill scheduler work is judged by
//! what it does to `tpot_summary().p95` under burst load.

pub mod histogram;
pub mod recorder;
pub mod stats;

pub use histogram::LatencyHistogram;
pub use recorder::{OverlapReport, Recorder, ServingSummary};
pub use stats::{ecdf, mean, percentile, total_variation_distance, Summary};
