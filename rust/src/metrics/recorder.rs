//! Serving-metrics recorder: per-request TTFT/TPOT, token throughput, and
//! utilization windows — the quantities behind Figures 3–9.
//!
//! Time is a plain `f64` seconds value so the recorder works identically for
//! wall-clock runs (the PJRT-backed engine) and simulated-clock runs (the
//! distributed timing simulator).

use super::stats::{percentile, Summary};
use crate::util::json::Json;
use std::collections::HashMap;

/// Per-request lifecycle record.
#[derive(Debug, Clone)]
struct RequestRecord {
    arrival: f64,
    first_token: Option<f64>,
    /// Completion time of every output token (including the first).
    token_times: Vec<f64>,
    finished: Option<f64>,
}

/// Records request lifecycles and resource-busy intervals.
#[derive(Debug, Default)]
pub struct Recorder {
    requests: HashMap<u64, RequestRecord>,
    /// (start, end) busy intervals per resource name (e.g. "gpu0", "cpu").
    busy: HashMap<String, Vec<(f64, f64)>>,
    /// Stage timeline (pipelined executor): (microbatch, start, end)
    /// GPU-busy intervals — forwards (and inline epilogues) per microbatch.
    ///
    /// Deliberately separate from the `busy` map even though `on_stage_*`
    /// feeds both: `busy["gpu"]`/`busy["cpu"]` are the generic named
    /// resources that *simulated* runs also write (utilization figures),
    /// while the stage vectors carry only real-engine intervals with
    /// microbatch attribution — overlap math over the busy map would
    /// silently mix simulator spans in. The duplication is a few dozen
    /// bytes per iteration.
    stage_gpu: Vec<(usize, f64, f64)>,
    /// (microbatch, start, end) decision-busy intervals, one per sampler
    /// batch, timestamped by the workers against the shared epoch.
    stage_decision: Vec<(usize, f64, f64)>,
    /// Engine-thread seconds spent blocked waiting on decisions (the
    /// exposed, non-overlapped part of the decision plane).
    exposed_wait_s: f64,
    /// Fault-recovery accounting (DESIGN.md §10): respawned sampler
    /// workers / failed-over replicas, and the wall seconds the recovery
    /// machinery spent rebuilding state — the latency a fault-free run
    /// would not have paid. TTFT/TPOT tails already absorb these pauses
    /// (requeued sequences keep their original arrival stamps); the
    /// explicit counters make the recovery cost itself visible.
    recoveries: u64,
    recovery_s: f64,
    /// Observation horizon for throughput/utilization.
    t_start: f64,
    t_end: f64,
    horizon_init: bool,
}

/// Measured overlap between decision-plane work and data-plane compute —
/// the quantity the paper's Fig. 3 gains rest on (decision latency hidden
/// under forwards instead of serializing the last stage).
#[derive(Debug, Clone, Default)]
pub struct OverlapReport {
    /// Total decision-plane busy seconds (summed across samplers).
    pub decision_busy_s: f64,
    /// Portion of `decision_busy_s` that ran while a GPU stage was busy.
    pub hidden_s: f64,
    /// `hidden_s / decision_busy_s` (0 when there were no decisions).
    pub overlap_fraction: f64,
    /// Engine-thread seconds stalled waiting for decisions.
    pub exposed_wait_s: f64,
    /// The measured last-stage bubble: stalled wait as a fraction of the
    /// engine's productive timeline (GPU busy + stalls).
    pub last_stage_bubble: f64,
    /// Merged GPU-busy seconds across microbatches.
    pub gpu_busy_s: f64,
    /// Microbatches observed in the stage timeline.
    pub microbatches: usize,
}

impl OverlapReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("decision_busy_s", Json::Num(self.decision_busy_s)),
            ("hidden_s", Json::Num(self.hidden_s)),
            ("overlap_fraction", Json::Num(self.overlap_fraction)),
            ("exposed_wait_s", Json::Num(self.exposed_wait_s)),
            ("last_stage_bubble", Json::Num(self.last_stage_bubble)),
            ("gpu_busy_s", Json::Num(self.gpu_busy_s)),
            ("microbatches", Json::Num(self.microbatches as f64)),
        ])
    }
}

/// Sort + merge possibly-overlapping intervals into disjoint spans.
fn merge_intervals(mut iv: Vec<(f64, f64)>) -> Vec<(f64, f64)> {
    iv.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut out: Vec<(f64, f64)> = Vec::with_capacity(iv.len());
    for (s, e) in iv {
        match out.last_mut() {
            Some(last) if s <= last.1 => last.1 = last.1.max(e),
            _ => out.push((s, e)),
        }
    }
    out
}

/// Length of `[s, e] ∩ ⋃ spans` for sorted disjoint `spans`.
fn intersect_len(s: f64, e: f64, spans: &[(f64, f64)]) -> f64 {
    // First span that could overlap: the one before the partition point.
    let start = spans.partition_point(|&(_, se)| se < s);
    let mut hidden = 0.0;
    for &(gs, ge) in &spans[start..] {
        if gs >= e {
            break;
        }
        hidden += (e.min(ge) - s.max(gs)).max(0.0);
    }
    hidden
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn on_arrival(&mut self, req: u64, t: f64) {
        self.requests.insert(
            req,
            RequestRecord { arrival: t, first_token: None, token_times: Vec::new(), finished: None },
        );
        self.extend_horizon(t);
    }

    pub fn on_token(&mut self, req: u64, t: f64) {
        if let Some(r) = self.requests.get_mut(&req) {
            if r.first_token.is_none() {
                r.first_token = Some(t);
            }
            r.token_times.push(t);
        }
        self.extend_horizon(t);
    }

    pub fn on_finish(&mut self, req: u64, t: f64) {
        if let Some(r) = self.requests.get_mut(&req) {
            r.finished = Some(t);
        }
        self.extend_horizon(t);
    }

    /// Record a busy interval for a named resource.
    pub fn on_busy(&mut self, resource: &str, start: f64, end: f64) {
        if end > start {
            self.busy.entry(resource.to_string()).or_default().push((start, end));
            self.extend_horizon(end);
        }
    }

    /// Record one microbatch's GPU stage interval (a forward pass, or the
    /// baseline's inline sampling epilogue). Also feeds the "gpu"
    /// utilization resource.
    pub fn on_stage_gpu(&mut self, mb: usize, start: f64, end: f64) {
        if end > start {
            self.stage_gpu.push((mb, start, end));
            self.on_busy("gpu", start, end);
        }
    }

    /// Record one sampler's decision-busy interval for a microbatch's
    /// task. Also feeds the "cpu" utilization resource.
    pub fn on_stage_decision(&mut self, mb: usize, start: f64, end: f64) {
        if end > start {
            self.stage_decision.push((mb, start, end));
            self.on_busy("cpu", start, end);
        }
    }

    /// Account engine-thread stall time spent blocked on decision reaping
    /// (the exposed decision latency — zero when overlap hides it all).
    pub fn on_decision_exposed(&mut self, dt: f64) {
        if dt > 0.0 {
            self.exposed_wait_s += dt;
        }
    }

    /// Account fault recoveries: `n` repaired failures (sampler respawns,
    /// replica failovers) taking `secs` of recovery work in total.
    pub fn on_recovery(&mut self, n: u64, secs: f64) {
        self.recoveries += n;
        if secs > 0.0 {
            self.recovery_s += secs;
        }
    }

    /// Repaired-failure count recorded so far.
    pub fn recoveries(&self) -> u64 {
        self.recoveries
    }

    /// Total recovery seconds recorded so far.
    pub fn recovery_s(&self) -> f64 {
        self.recovery_s
    }

    /// Measured overlap between decision work and GPU stages: how much of
    /// the decision plane's busy time ran under a forward, and how big the
    /// remaining last-stage bubble was.
    pub fn overlap_report(&self) -> OverlapReport {
        let gpu = merge_intervals(self.stage_gpu.iter().map(|&(_, s, e)| (s, e)).collect());
        let gpu_busy_s: f64 = gpu.iter().map(|&(s, e)| e - s).sum();
        let mut decision_busy_s = 0.0;
        let mut hidden_s = 0.0;
        for &(_, s, e) in &self.stage_decision {
            decision_busy_s += e - s;
            hidden_s += intersect_len(s, e, &gpu);
        }
        let microbatches = self
            .stage_gpu
            .iter()
            .chain(&self.stage_decision)
            .map(|&(mb, _, _)| mb + 1)
            .max()
            .unwrap_or(0);
        let overlap_fraction =
            if decision_busy_s > 0.0 { hidden_s / decision_busy_s } else { 0.0 };
        let denom = gpu_busy_s + self.exposed_wait_s;
        let last_stage_bubble =
            if denom > 0.0 { self.exposed_wait_s / denom } else { 0.0 };
        OverlapReport {
            decision_busy_s,
            hidden_s,
            overlap_fraction: overlap_fraction.clamp(0.0, 1.0),
            exposed_wait_s: self.exposed_wait_s,
            last_stage_bubble,
            gpu_busy_s,
            microbatches,
        }
    }

    /// Merge another recorder's streams into this one — the fleet-wide view
    /// of a cluster of data-parallel replicas (DESIGN.md §9). Requires both
    /// recorders to share a time origin (replicas adopt the cluster epoch).
    ///
    /// Per-request records union: a request that lived on two replicas (a
    /// prefill→decode handoff) merges into one lifecycle — earliest
    /// arrival/first-token, all token times interleaved in time order, and
    /// the *latest* finish (the prefill side's truncated "finish" is
    /// superseded by the decode side's real one). Busy intervals and stage
    /// timelines concatenate; `utilization`/`overlap_report` already union
    /// overlapping spans at query time, so fleet utilization reads "any
    /// replica busy". Percentiles over the merged recorder are therefore
    /// exact fleet-wide quantiles, not averages of per-replica quantiles.
    pub fn merge(&mut self, other: &Recorder) {
        for (&id, r) in &other.requests {
            match self.requests.entry(id) {
                std::collections::hash_map::Entry::Occupied(mut e) => {
                    let m = e.get_mut();
                    m.arrival = m.arrival.min(r.arrival);
                    m.first_token = match (m.first_token, r.first_token) {
                        (Some(a), Some(b)) => Some(a.min(b)),
                        (a, b) => a.or(b),
                    };
                    m.token_times.extend_from_slice(&r.token_times);
                    m.token_times.sort_by(|a, b| a.partial_cmp(b).unwrap());
                    m.finished = match (m.finished, r.finished) {
                        (Some(a), Some(b)) => Some(a.max(b)),
                        (a, b) => a.or(b),
                    };
                }
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert(r.clone());
                }
            }
        }
        for (name, iv) in &other.busy {
            self.busy.entry(name.clone()).or_default().extend_from_slice(iv);
        }
        self.stage_gpu.extend_from_slice(&other.stage_gpu);
        self.stage_decision.extend_from_slice(&other.stage_decision);
        self.exposed_wait_s += other.exposed_wait_s;
        self.recoveries += other.recoveries;
        self.recovery_s += other.recovery_s;
        if other.horizon_init {
            self.extend_horizon(other.t_start);
            self.extend_horizon(other.t_end);
        }
    }

    /// Finish time of a request, if it finished — the cluster simulator's
    /// prefill→decode handoff reads this to schedule the decode phase.
    pub fn finish_time(&self, req: u64) -> Option<f64> {
        self.requests.get(&req).and_then(|r| r.finished)
    }

    fn extend_horizon(&mut self, t: f64) {
        if !self.horizon_init {
            self.t_start = t;
            self.t_end = t;
            self.horizon_init = true;
        } else {
            self.t_start = self.t_start.min(t);
            self.t_end = self.t_end.max(t);
        }
    }

    /// Total completed output tokens.
    pub fn total_tokens(&self) -> usize {
        self.requests.values().map(|r| r.token_times.len()).sum()
    }

    pub fn finished_requests(&self) -> usize {
        self.requests.values().filter(|r| r.finished.is_some()).count()
    }

    /// Output tokens per second over the observation horizon.
    pub fn throughput(&self) -> f64 {
        let span = self.t_end - self.t_start;
        if span <= 0.0 {
            0.0
        } else {
            self.total_tokens() as f64 / span
        }
    }

    /// All TTFT samples (first token − arrival), seconds.
    pub fn ttfts(&self) -> Vec<f64> {
        self.requests
            .values()
            .filter_map(|r| r.first_token.map(|f| f - r.arrival))
            .collect()
    }

    /// All TPOT samples: per-request inter-token gaps, seconds. This matches
    /// the paper's Time-per-Output-Token tail metrics (P95/P99 over gaps).
    pub fn tpots(&self) -> Vec<f64> {
        let mut out = Vec::new();
        for r in self.requests.values() {
            for w in r.token_times.windows(2) {
                out.push(w[1] - w[0]);
            }
        }
        out
    }

    pub fn tpot_summary(&self) -> Summary {
        Summary::of(&self.tpots())
    }

    pub fn ttft_summary(&self) -> Summary {
        Summary::of(&self.ttfts())
    }

    /// Utilization of a resource over the horizon: busy-time / span, with
    /// overlapping intervals merged (a resource can't be >100% busy).
    pub fn utilization(&self, resource: &str) -> f64 {
        let span = self.t_end - self.t_start;
        if span <= 0.0 {
            return 0.0;
        }
        let Some(intervals) = self.busy.get(resource) else {
            return 0.0;
        };
        let mut iv = intervals.clone();
        iv.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut busy = 0.0;
        let mut cur: Option<(f64, f64)> = None;
        for (s, e) in iv {
            match cur {
                None => cur = Some((s, e)),
                Some((cs, ce)) => {
                    if s <= ce {
                        cur = Some((cs, ce.max(e)));
                    } else {
                        busy += ce - cs;
                        cur = Some((s, e));
                    }
                }
            }
        }
        if let Some((cs, ce)) = cur {
            busy += ce - cs;
        }
        (busy / span).min(1.0)
    }

    /// Mid-50% utilization samples (the paper's Figures 8/9 plot the
    /// interquartile band): utilization over fixed windows, then P25..P75.
    pub fn utilization_mid50(&self, resource: &str, window: f64) -> (f64, f64, f64) {
        let span = self.t_end - self.t_start;
        if span <= 0.0 || window <= 0.0 {
            return (0.0, 0.0, 0.0);
        }
        let Some(intervals) = self.busy.get(resource) else {
            return (0.0, 0.0, 0.0);
        };
        let nwin = (span / window).ceil() as usize;
        let mut busy_per_win = vec![0.0f64; nwin.max(1)];
        for &(s, e) in intervals {
            let mut s = s;
            while s < e {
                let w = (((s - self.t_start) / window).floor() as usize).min(nwin - 1);
                let wend = self.t_start + (w + 1) as f64 * window;
                let chunk = e.min(wend) - s;
                busy_per_win[w] += chunk;
                s += chunk.max(1e-12);
            }
        }
        let mut utils: Vec<f64> =
            busy_per_win.iter().map(|b| (b / window).min(1.0)).collect();
        utils.sort_by(|a, b| a.partial_cmp(b).unwrap());
        (
            percentile(&utils, 25.0),
            percentile(&utils, 50.0),
            percentile(&utils, 75.0),
        )
    }

    /// Export a serving summary.
    pub fn summary(&self) -> ServingSummary {
        ServingSummary {
            requests: self.requests.len(),
            finished: self.finished_requests(),
            tokens: self.total_tokens(),
            duration: self.t_end - self.t_start,
            throughput: self.throughput(),
            ttft: self.ttft_summary(),
            tpot: self.tpot_summary(),
            recoveries: self.recoveries,
            recovery_s: self.recovery_s,
        }
    }
}

/// Flattened end-of-run summary.
#[derive(Debug, Clone)]
pub struct ServingSummary {
    pub requests: usize,
    pub finished: usize,
    pub tokens: usize,
    pub duration: f64,
    pub throughput: f64,
    pub ttft: Summary,
    pub tpot: Summary,
    /// Repaired failures (sampler respawns + replica failovers).
    pub recoveries: u64,
    /// Wall seconds spent in recovery work.
    pub recovery_s: f64,
}

impl ServingSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("finished", Json::Num(self.finished as f64)),
            ("tokens", Json::Num(self.tokens as f64)),
            ("duration_s", Json::Num(self.duration)),
            ("throughput_tok_s", Json::Num(self.throughput)),
            ("ttft", self.ttft.to_json()),
            ("tpot", self.tpot.to_json()),
            ("recoveries", Json::Num(self.recoveries as f64)),
            ("recovery_s", Json::Num(self.recovery_s)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ttft_and_tpot_computed_per_request() {
        let mut r = Recorder::new();
        r.on_arrival(1, 0.0);
        r.on_token(1, 0.5); // TTFT 0.5
        r.on_token(1, 0.7); // gap 0.2
        r.on_token(1, 1.0); // gap 0.3
        r.on_finish(1, 1.0);
        let ttfts = r.ttfts();
        assert_eq!(ttfts, vec![0.5]);
        let mut tpots = r.tpots();
        tpots.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((tpots[0] - 0.2).abs() < 1e-12);
        assert!((tpots[1] - 0.3).abs() < 1e-12);
        assert_eq!(r.total_tokens(), 3);
        assert_eq!(r.finished_requests(), 1);
    }

    #[test]
    fn throughput_over_horizon() {
        let mut r = Recorder::new();
        r.on_arrival(1, 0.0);
        for i in 1..=10 {
            r.on_token(1, i as f64 * 0.1);
        }
        r.on_finish(1, 1.0);
        assert!((r.throughput() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn utilization_merges_overlaps() {
        let mut r = Recorder::new();
        r.on_arrival(1, 0.0);
        r.on_finish(1, 10.0);
        r.on_busy("gpu", 0.0, 4.0);
        r.on_busy("gpu", 3.0, 6.0); // overlap with previous
        r.on_busy("gpu", 8.0, 9.0);
        assert!((r.utilization("gpu") - 0.7).abs() < 1e-9);
        assert_eq!(r.utilization("cpu"), 0.0);
    }

    #[test]
    fn mid50_utilization_windows() {
        let mut r = Recorder::new();
        r.on_arrival(1, 0.0);
        r.on_finish(1, 4.0);
        // windows of 1s: busy fractions 1.0, 0.5, 0.0, 1.0
        r.on_busy("gpu", 0.0, 1.5);
        r.on_busy("gpu", 3.0, 4.0);
        let (p25, p50, p75) = r.utilization_mid50("gpu", 1.0);
        assert!(p25 <= p50 && p50 <= p75);
        assert!(p75 <= 1.0);
    }

    #[test]
    fn summary_roundtrips_to_json() {
        let mut r = Recorder::new();
        r.on_arrival(1, 0.0);
        r.on_token(1, 0.1);
        r.on_finish(1, 0.1);
        let s = r.summary();
        let j = s.to_json();
        assert_eq!(j.get("requests").as_usize(), Some(1));
        assert_eq!(j.get("tokens").as_usize(), Some(1));
    }

    #[test]
    fn overlap_report_separates_hidden_and_exposed() {
        let mut r = Recorder::new();
        r.on_arrival(1, 0.0);
        // mb0 forward [0,1], mb1 forward [1.5, 2.5]
        r.on_stage_gpu(0, 0.0, 1.0);
        r.on_stage_gpu(1, 1.5, 2.5);
        // decision A fully under mb0's forward; B half-exposed in the gap
        r.on_stage_decision(1, 0.2, 0.6); // 0.4 hidden
        r.on_stage_decision(0, 1.3, 1.7); // 0.2 of 0.4 hidden
        r.on_decision_exposed(0.2);
        let o = r.overlap_report();
        assert!((o.decision_busy_s - 0.8).abs() < 1e-9);
        assert!((o.hidden_s - 0.6).abs() < 1e-9, "hidden {}", o.hidden_s);
        assert!((o.overlap_fraction - 0.75).abs() < 1e-9);
        assert!((o.gpu_busy_s - 2.0).abs() < 1e-9);
        assert!((o.exposed_wait_s - 0.2).abs() < 1e-9);
        assert!((o.last_stage_bubble - 0.2 / 2.2).abs() < 1e-9);
        assert_eq!(o.microbatches, 2);
        // stage intervals also feed the legacy utilization resources
        assert!(r.utilization("gpu") > 0.0);
        assert!(r.utilization("cpu") > 0.0);
    }

    #[test]
    fn overlap_report_zero_without_stage_timeline() {
        let mut r = Recorder::new();
        r.on_arrival(1, 0.0);
        r.on_busy("gpu", 0.0, 1.0); // legacy busy only — no stage data
        let o = r.overlap_report();
        assert_eq!(o.overlap_fraction, 0.0);
        assert_eq!(o.microbatches, 0);
        assert_eq!(o.last_stage_bubble, 0.0);
        let j = o.to_json();
        assert_eq!(j.get("microbatches").as_usize(), Some(0));
    }

    #[test]
    fn merge_equals_single_recorder_over_the_same_events() {
        // Fleet-wide percentiles: events split across two recorders then
        // merged must reproduce the one-recorder quantities exactly.
        let mut whole = Recorder::new();
        let mut a = Recorder::new();
        let mut b = Recorder::new();
        for (rec, alt, id) in [(&mut a, false, 1u64), (&mut b, true, 2u64)] {
            let shift = if alt { 0.05 } else { 0.0 };
            rec.on_arrival(id, shift);
            whole.on_arrival(id, shift);
            for i in 1..=4 {
                let t = shift + i as f64 * 0.1;
                rec.on_token(id, t);
                whole.on_token(id, t);
            }
            rec.on_finish(id, shift + 0.4);
            whole.on_finish(id, shift + 0.4);
        }
        a.on_busy("gpu", 0.0, 0.3);
        whole.on_busy("gpu", 0.0, 0.3);
        b.on_busy("gpu", 0.2, 0.5); // overlaps a's interval across replicas
        whole.on_busy("gpu", 0.2, 0.5);
        a.merge(&b);
        assert_eq!(a.total_tokens(), whole.total_tokens());
        assert_eq!(a.finished_requests(), 2);
        let (ma, mw) = (a.tpot_summary(), whole.tpot_summary());
        assert_eq!(ma.n, mw.n);
        assert!((ma.p50 - mw.p50).abs() < 1e-12);
        assert!((ma.p95 - mw.p95).abs() < 1e-12);
        assert!((ma.p99 - mw.p99).abs() < 1e-12);
        assert!((a.throughput() - whole.throughput()).abs() < 1e-9);
        // busy-interval union, not sum: overlap across replicas merges
        assert!((a.utilization("gpu") - whole.utilization("gpu")).abs() < 1e-12);
    }

    #[test]
    fn merge_unions_a_handoff_request_into_one_lifecycle() {
        // The prefill replica records arrival + the first token + a
        // truncated "finish"; the decode replica records a later arrival
        // (transfer delay) + the remaining tokens + the real finish.
        let mut prefill = Recorder::new();
        prefill.on_arrival(7, 0.0);
        prefill.on_token(7, 0.2);
        prefill.on_finish(7, 0.2);
        let mut decode = Recorder::new();
        decode.on_arrival(7, 0.3); // handoff + transfer
        decode.on_token(7, 0.5);
        decode.on_token(7, 0.6);
        decode.on_finish(7, 0.6);
        prefill.merge(&decode);
        assert_eq!(prefill.total_tokens(), 3);
        assert_eq!(prefill.requests.len(), 1, "one lifecycle, not two");
        assert_eq!(prefill.ttfts(), vec![0.2], "TTFT from the prefill side");
        assert_eq!(prefill.finish_time(7), Some(0.6), "decode finish wins");
        let mut gaps = prefill.tpots();
        gaps.sort_by(|x, y| x.partial_cmp(y).unwrap());
        // 0.2→0.5 spans the handoff (0.3), 0.5→0.6 is a decode gap
        assert!((gaps[0] - 0.1).abs() < 1e-12 && (gaps[1] - 0.3).abs() < 1e-12);
    }

    #[test]
    fn tokens_for_unknown_request_ignored() {
        let mut r = Recorder::new();
        r.on_token(42, 1.0); // never arrived — ignored, no panic
        assert_eq!(r.total_tokens(), 0);
    }
}
