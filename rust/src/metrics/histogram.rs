//! Streaming log-bucketed latency histogram (HDR-histogram style).
//!
//! The [`super::Recorder`] stores raw samples (fine for bounded runs); for
//! long-running serving the paper's observability needs constant-memory
//! percentile tracking. Buckets are logarithmic with a configurable number
//! of sub-buckets per octave, giving a bounded relative quantile error of
//! `2^(1/sub_buckets) − 1` regardless of run length.

/// Constant-memory latency histogram over (0, ~584 years] at nanosecond
/// resolution floor.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// counts[octave * sub + s]
    counts: Vec<u64>,
    sub: usize,
    total: u64,
    sum_s: f64,
    min_s: f64,
    max_s: f64,
}

const OCTAVES: usize = 64; // ns-scale granule, u64 nanoseconds range

impl LatencyHistogram {
    /// `sub_buckets_per_octave` trades memory for accuracy: 16 gives
    /// ≤ 4.4% relative error at 1 KiB of counters.
    pub fn new(sub_buckets_per_octave: usize) -> Self {
        let sub = sub_buckets_per_octave.max(1);
        LatencyHistogram {
            counts: vec![0; OCTAVES * sub],
            sub,
            total: 0,
            sum_s: 0.0,
            min_s: f64::INFINITY,
            max_s: 0.0,
        }
    }

    fn bucket_of(&self, seconds: f64) -> usize {
        let ns = (seconds * 1e9).max(1.0) as u64;
        let octave = 63 - ns.leading_zeros() as usize; // floor(log2 ns)
        // sub-bucket: linear position within [2^octave, 2^(octave+1))
        let base = 1u64 << octave;
        let frac = (ns - base) as f64 / base as f64; // [0, 1)
        let s = ((frac * self.sub as f64) as usize).min(self.sub - 1);
        octave * self.sub + s
    }

    /// Midpoint (seconds) represented by a bucket index.
    fn value_of(&self, bucket: usize) -> f64 {
        let octave = bucket / self.sub;
        let s = bucket % self.sub;
        let base = (1u64 << octave) as f64;
        let lo = base * (1.0 + s as f64 / self.sub as f64);
        let hi = base * (1.0 + (s + 1) as f64 / self.sub as f64);
        (lo + hi) * 0.5 / 1e9
    }

    pub fn record(&mut self, seconds: f64) {
        if !(seconds.is_finite() && seconds >= 0.0) {
            return;
        }
        let b = self.bucket_of(seconds);
        self.counts[b] += 1;
        self.total += 1;
        self.sum_s += seconds;
        self.min_s = self.min_s.min(seconds);
        self.max_s = self.max_s.max(seconds);
    }

    pub fn count(&self) -> u64 {
        self.total
    }
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_s / self.total as f64
        }
    }
    pub fn min(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.min_s
        }
    }
    pub fn max(&self) -> f64 {
        self.max_s
    }

    /// Quantile `q ∈ [0, 1]` with bounded relative error.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = (q * self.total as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                // clamp to observed extremes for edge quantiles
                return self.value_of(b).clamp(self.min_s, self.max_s);
            }
        }
        self.max_s
    }

    /// Merge another histogram (same sub-bucket config).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        assert_eq!(self.sub, other.sub, "sub-bucket mismatch");
        for (a, &b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_s += other.sum_s;
        self.min_s = self.min_s.min(other.min_s);
        self.max_s = self.max_s.max(other.max_s);
    }

    /// Memory footprint of the counters (bytes).
    pub fn counter_bytes(&self) -> usize {
        self.counts.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Philox;

    #[test]
    fn quantiles_within_relative_error_bound() {
        let sub = 16;
        let bound = 2f64.powf(1.0 / sub as f64) - 1.0 + 1.0 / sub as f64; // coarse
        let mut h = LatencyHistogram::new(sub);
        let mut rng = Philox::new(3);
        let mut samples = Vec::new();
        for _ in 0..50_000 {
            // log-uniform latencies across µs..s
            let s = 10f64.powf(-6.0 + 5.0 * rng.next_f64());
            samples.push(s);
            h.record(s);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.5, 0.9, 0.95, 0.99] {
            let exact = samples[((q * samples.len() as f64) as usize).min(samples.len() - 1)];
            let est = h.quantile(q);
            let rel = (est - exact).abs() / exact;
            assert!(rel < bound * 2.0 + 0.02, "q={q}: est {est} exact {exact} rel {rel}");
        }
    }

    #[test]
    fn mean_min_max_exact() {
        let mut h = LatencyHistogram::new(8);
        for v in [0.001, 0.002, 0.003] {
            h.record(v);
        }
        assert!((h.mean() - 0.002).abs() < 1e-12);
        assert_eq!(h.min(), 0.001);
        assert_eq!(h.max(), 0.003);
        assert_eq!(h.count(), 3);
    }

    #[test]
    fn empty_histogram_is_zeroes() {
        let h = LatencyHistogram::new(8);
        assert_eq!(h.quantile(0.99), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn merge_equals_combined_recording() {
        let mut a = LatencyHistogram::new(16);
        let mut b = LatencyHistogram::new(16);
        let mut all = LatencyHistogram::new(16);
        let mut rng = Philox::new(9);
        for i in 0..10_000 {
            let v = 1e-5 + rng.next_f64() * 0.1;
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            all.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        for q in [0.5, 0.95, 0.99] {
            assert!((a.quantile(q) - all.quantile(q)).abs() < 1e-12);
        }
    }

    #[test]
    fn constant_memory() {
        let h = LatencyHistogram::new(16);
        assert!(h.counter_bytes() <= 16 * 1024);
    }

    #[test]
    fn degenerate_inputs_ignored() {
        let mut h = LatencyHistogram::new(8);
        h.record(f64::NAN);
        h.record(-1.0);
        h.record(f64::INFINITY);
        assert_eq!(h.count(), 0);
        h.record(0.0); // clamps to 1 ns bucket
        assert_eq!(h.count(), 1);
    }
}
