//! Trace exporters: Chrome-trace/Perfetto JSON, plus the trace-derived
//! overlap accounting that cross-checks [`crate::metrics::Recorder`].
//!
//! The JSON shape is the Chrome Trace Event Format object form —
//! `{"traceEvents": [...], "displayTimeUnit": "ms"}` — loadable in
//! <https://ui.perfetto.dev> and `chrome://tracing`. Timestamps are
//! microseconds since the shared [`super::epoch`]; `pid` is the replica
//! lane (0 = pool/router), `tid` the thread role, and per-lane metadata
//! (`ph: "M"`) names both. `python/trace_check.py` validates the schema,
//! timestamp monotonicity, and B/E balance in CI (`make trace-smoke`).

use super::{Kind, Phase, TraceEvent};
use crate::metrics::{OverlapReport, Recorder};
use crate::util::json::Json;
use std::collections::BTreeSet;

fn event_json(ev: &TraceEvent) -> Json {
    let ph = match ev.ph {
        Phase::Begin => "B",
        Phase::End => "E",
        Phase::Complete => "X",
        Phase::Instant => "i",
    };
    let mut args = vec![("a", Json::Num(ev.a as f64)), ("b", Json::Num(ev.b as f64))];
    if ev.kind == Kind::Log {
        if let Some(msg) = super::interned(ev.a) {
            args.push(("msg", Json::Str(msg)));
        }
    }
    if ev.kind == Kind::RouteDecision {
        // b carries the policy score as f64 bits — decode for readability
        args.push(("score", Json::Num(f64::from_bits(ev.b))));
    }
    let mut fields = vec![
        ("name", Json::Str(ev.kind.name().to_string())),
        ("cat", Json::Str(ev.kind.category().to_string())),
        ("ph", Json::Str(ph.to_string())),
        ("ts", Json::Num(ev.ts_ns as f64 / 1e3)),
        ("pid", Json::Num(ev.pid as f64)),
        ("tid", Json::Num(ev.tid as f64)),
        ("args", Json::obj(args)),
    ];
    if ev.ph == Phase::Complete {
        fields.push(("dur", Json::Num(ev.dur_ns as f64 / 1e3)));
    }
    if ev.ph == Phase::Instant {
        // thread-scoped instants render as small arrows in Perfetto
        fields.push(("s", Json::Str("t".to_string())));
    }
    Json::obj(fields)
}

fn metadata_json(events: &[TraceEvent]) -> Vec<Json> {
    let mut lanes: BTreeSet<(u32, u32)> = BTreeSet::new();
    let mut pids: BTreeSet<u32> = BTreeSet::new();
    for ev in events {
        lanes.insert((ev.pid, ev.tid));
        pids.insert(ev.pid);
    }
    let mut out = Vec::new();
    for pid in pids {
        let pname = if pid == 0 {
            "pool/router".to_string()
        } else {
            format!("replica-{}", pid - 1)
        };
        out.push(Json::obj(vec![
            ("name", Json::Str("process_name".to_string())),
            ("ph", Json::Str("M".to_string())),
            ("pid", Json::Num(pid as f64)),
            ("tid", Json::Num(0.0)),
            ("args", Json::obj(vec![("name", Json::Str(pname))])),
        ]));
    }
    for (pid, tid) in lanes {
        out.push(Json::obj(vec![
            ("name", Json::Str("thread_name".to_string())),
            ("ph", Json::Str("M".to_string())),
            ("pid", Json::Num(pid as f64)),
            ("tid", Json::Num(tid as f64)),
            ("args", Json::obj(vec![("name", Json::Str(super::lane_name(tid)))])),
        ]));
    }
    out
}

/// Render events as a Chrome-trace JSON object. Metadata first, then
/// events sorted by timestamp (the collector already sorts).
pub fn chrome_json(events: &[TraceEvent]) -> Json {
    let mut all = metadata_json(events);
    all.extend(events.iter().map(event_json));
    Json::obj(vec![
        ("traceEvents", Json::Arr(all)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
        (
            "otherData",
            Json::obj(vec![
                ("producer", Json::Str("simple-serve flight recorder".to_string())),
                ("dropped_events", Json::Num(super::dropped_events() as f64)),
            ]),
        ),
    ])
}

/// Snapshot every thread's events and write the capture to `path`.
pub fn write_chrome(path: &std::path::Path) -> crate::Result<()> {
    let events = super::snapshot_events();
    crate::util::json::write_json_file(path, &chrome_json(&events))?;
    Ok(())
}

/// Derive an [`OverlapReport`] from trace spans: forward spans become GPU
/// stage intervals, decide spans become decision intervals, collect-wait
/// spans become exposed waits — fed through the *same* `Recorder`
/// arithmetic, so the two accounting systems can be cross-checked
/// event-for-event (they share the epoch and the measurement sites).
pub fn overlap_report_from_trace(events: &[TraceEvent]) -> OverlapReport {
    let mut rec = Recorder::new();
    for ev in events {
        if ev.ph != Phase::Complete {
            continue;
        }
        match ev.kind {
            Kind::EngineForward => rec.on_stage_gpu(ev.a as usize, ev.ts_s(), ev.end_s()),
            Kind::SvcDecide => rec.on_stage_decision(ev.a as usize, ev.ts_s(), ev.end_s()),
            Kind::EngineCollectWait => rec.on_decision_exposed(ev.dur_ns as f64 / 1e9),
            _ => {}
        }
    }
    rec.overlap_report()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: Kind, ph: Phase, ts_ns: u64, dur_ns: u64, a: u64) -> TraceEvent {
        TraceEvent { kind, ph, pid: 1, tid: 1, ts_ns, dur_ns, a, b: 0 }
    }

    #[test]
    fn chrome_json_shape() {
        let events = vec![
            ev(Kind::EnginePlan, Phase::Begin, 1_000, 0, 0),
            ev(Kind::EnginePlan, Phase::End, 2_000, 0, 0),
            ev(Kind::EngineForward, Phase::Complete, 1_000, 500, 0),
            ev(Kind::SvcSteal, Phase::Instant, 1_500, 0, 3),
        ];
        let j = chrome_json(&events);
        let list = j.get("traceEvents").as_arr().unwrap();
        // 1 process + 1 thread metadata + 4 events
        assert_eq!(list.len(), 6);
        let x = &list[list.len() - 2];
        assert_eq!(x.get("ph").as_str(), Some("X"));
        assert_eq!(x.get("dur").as_f64(), Some(0.5)); // µs
        assert_eq!(x.get("ts").as_f64(), Some(1.0));
        let i = &list[list.len() - 1];
        assert_eq!(i.get("s").as_str(), Some("t"));
        // parses back — the file the exporter writes is valid JSON
        assert_eq!(Json::parse(&j.to_string_pretty()).unwrap(), j);
    }

    #[test]
    fn overlap_from_trace_matches_recorder_arithmetic() {
        // decision [1,2] fully inside forward [0,3] → hidden; second
        // decision [4,5] outside any forward → exposed
        let events = vec![
            ev(Kind::EngineForward, Phase::Complete, 0, 3_000_000_000, 0),
            ev(Kind::SvcDecide, Phase::Complete, 1_000_000_000, 1_000_000_000, 0),
            ev(Kind::SvcDecide, Phase::Complete, 4_000_000_000, 1_000_000_000, 0),
            ev(Kind::EngineCollectWait, Phase::Complete, 4_000_000_000, 1_000_000_000, 0),
        ];
        let report = overlap_report_from_trace(&events);
        let mut rec = Recorder::new();
        rec.on_stage_gpu(0, 0.0, 3.0);
        rec.on_stage_decision(0, 1.0, 2.0);
        rec.on_stage_decision(0, 4.0, 5.0);
        rec.on_decision_exposed(1.0);
        let expect = rec.overlap_report();
        assert!((report.decision_busy_s - expect.decision_busy_s).abs() < 1e-9);
        assert!((report.hidden_s - expect.hidden_s).abs() < 1e-9);
        assert!((report.exposed_wait_s - expect.exposed_wait_s).abs() < 1e-9);
        assert!((report.gpu_busy_s - expect.gpu_busy_s).abs() < 1e-9);
    }
}
