//! Always-on counters and histograms with a Prometheus-style text
//! exposition (DESIGN.md §14).
//!
//! Unlike trace *events* (gated, ring-buffered, timestamped), these are
//! plain relaxed atomics bumped at the same seams — cheap enough to leave
//! on unconditionally, so `serve`/`serve_e2e` can surface them in their
//! JSON summaries and experiments can assert the mechanisms they exercise
//! actually fired (steals, claim releases, respawns, COW forks, LRU
//! evictions, prefix hits/misses, router requeues). `--metrics-out <path>`
//! renders the exposition; counters are process-global and monotonic.

// host atomics: these counters are const-initialized process globals,
// deliberately outside the loom-modeled surface (util::sync docs). The
// whole file is allowlisted by the concurrency lint — monotonic relaxed
// counters carry no happens-before edges.
use crate::util::sync::host::{AtomicU64, Ordering};

/// Process-global decision-plane counters. Monotonic; read with
/// [`Counters::snapshot`].
#[derive(Debug, Default)]
pub struct Counters {
    /// Tasks a sampler worker popped from a sibling's shard ring.
    pub steals: AtomicU64,
    /// Claim words released from cells owned by dead worker incarnations.
    pub claim_releases: AtomicU64,
    /// Sampler workers respawned after a death.
    pub sampler_respawns: AtomicU64,
    /// KV blocks forked copy-on-write at shared admission.
    pub cow_forks: AtomicU64,
    /// KV blocks reclaimed by LRU eviction.
    pub lru_evictions: AtomicU64,
    /// Prefix-cache lookups that shared at least one cached block.
    pub prefix_hits: AtomicU64,
    /// Prefix-cache lookups that shared nothing.
    pub prefix_misses: AtomicU64,
    /// Sequences requeued onto surviving replicas after a failover.
    pub router_requeues: AtomicU64,
    /// Replica failovers handled by the router's failure sweep.
    pub failovers: AtomicU64,
    /// WARN+ log records.
    pub log_warnings: AtomicU64,
}

impl Counters {
    /// `(metric name, value)` pairs, exposition order.
    pub fn snapshot(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("steals", self.steals.load(Ordering::Relaxed)),
            ("claim_releases", self.claim_releases.load(Ordering::Relaxed)),
            ("sampler_respawns", self.sampler_respawns.load(Ordering::Relaxed)),
            ("cow_forks", self.cow_forks.load(Ordering::Relaxed)),
            ("lru_evictions", self.lru_evictions.load(Ordering::Relaxed)),
            ("prefix_hits", self.prefix_hits.load(Ordering::Relaxed)),
            ("prefix_misses", self.prefix_misses.load(Ordering::Relaxed)),
            ("router_requeues", self.router_requeues.load(Ordering::Relaxed)),
            ("failovers", self.failovers.load(Ordering::Relaxed)),
            ("log_warnings", self.log_warnings.load(Ordering::Relaxed)),
        ]
    }

    pub fn get(&self, name: &str) -> Option<u64> {
        self.snapshot().into_iter().find(|(n, _)| *n == name).map(|(_, v)| v)
    }
}

static COUNTERS: Counters = Counters {
    steals: AtomicU64::new(0),
    claim_releases: AtomicU64::new(0),
    sampler_respawns: AtomicU64::new(0),
    cow_forks: AtomicU64::new(0),
    lru_evictions: AtomicU64::new(0),
    prefix_hits: AtomicU64::new(0),
    prefix_misses: AtomicU64::new(0),
    router_requeues: AtomicU64::new(0),
    failovers: AtomicU64::new(0),
    log_warnings: AtomicU64::new(0),
};

/// The process-global counter set.
pub fn counters() -> &'static Counters {
    &COUNTERS
}

/// Bump a counter by 1 (relaxed).
#[inline]
pub fn inc(counter: &AtomicU64) {
    counter.fetch_add(1, Ordering::Relaxed);
}

/// Bump a counter by `n` (relaxed).
#[inline]
pub fn add(counter: &AtomicU64, n: u64) {
    counter.fetch_add(n, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Histograms
// ---------------------------------------------------------------------------

/// Lock-free log2-bucketed latency histogram (microsecond buckets:
/// `le 1µs, 2µs, 4µs, … , 2^(N-2) µs, +Inf`).
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; Self::NUM_BUCKETS],
    count: AtomicU64,
    sum_ns: AtomicU64,
}

impl Histogram {
    pub const NUM_BUCKETS: usize = 24;

    pub const fn new() -> Histogram {
        #[allow(clippy::declare_interior_mutable_const)]
        const Z: AtomicU64 = AtomicU64::new(0);
        Histogram {
            buckets: [Z; Self::NUM_BUCKETS],
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }

    /// Record one observation in nanoseconds.
    #[inline]
    pub fn observe_ns(&self, ns: u64) {
        // bucket i covers le 2^i µs (inclusive, Prometheus semantics), so
        // round the µs up and take ceil(log2): exactly 1µs lands in
        // le=1µs, exactly 2^i µs in le=2^i µs, and 2^i+ε in the next.
        let us = ns.div_ceil(1_000);
        let idx = if us <= 1 {
            0
        } else {
            (64 - ((us - 1).leading_zeros() as usize)).min(Self::NUM_BUCKETS - 1)
        };
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_s(&self) -> f64 {
        self.sum_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Cumulative bucket counts with their `le` bounds in seconds
    /// (`f64::INFINITY` for the last).
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut acc = 0u64;
        (0..Self::NUM_BUCKETS)
            .map(|i| {
                acc += self.buckets[i].load(Ordering::Relaxed);
                let le = if i == Self::NUM_BUCKETS - 1 {
                    f64::INFINITY
                } else {
                    (1u64 << i) as f64 * 1e-6
                };
                (le, acc)
            })
            .collect()
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Latency of one sampler `decide()` (per shard batch).
pub static DECIDE_LATENCY: Histogram = Histogram::new();
/// Engine wait exposed on the blocking collect path.
pub static COLLECT_WAIT: Histogram = Histogram::new();

// ---------------------------------------------------------------------------
// Exposition
// ---------------------------------------------------------------------------

fn fmt_le(le: f64) -> String {
    if le.is_infinite() {
        "+Inf".to_string()
    } else {
        format!("{le:.6}")
    }
}

/// Render the Prometheus text exposition: every counter as
/// `simple_<name>_total`, both histograms, and the trace subsystem's own
/// drop counter.
pub fn exposition() -> String {
    let mut out = String::new();
    for (name, value) in COUNTERS.snapshot() {
        out.push_str(&format!(
            "# TYPE simple_{name}_total counter\nsimple_{name}_total {value}\n"
        ));
    }
    out.push_str(&format!(
        "# TYPE simple_trace_dropped_events_total counter\n\
         simple_trace_dropped_events_total {}\n",
        super::dropped_events()
    ));
    for (hname, hist) in [
        ("decide_latency_seconds", &DECIDE_LATENCY),
        ("collect_wait_seconds", &COLLECT_WAIT),
    ] {
        out.push_str(&format!("# TYPE simple_{hname} histogram\n"));
        for (le, cum) in hist.cumulative() {
            out.push_str(&format!(
                "simple_{hname}_bucket{{le=\"{}\"}} {cum}\n",
                fmt_le(le)
            ));
        }
        out.push_str(&format!("simple_{hname}_sum {}\n", hist.sum_s()));
        out.push_str(&format!("simple_{hname}_count {}\n", hist.count()));
    }
    out
}

/// Write the exposition to a file (the `--metrics-out` plumbing).
pub fn write_exposition(path: &std::path::Path) -> crate::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, exposition())?;
    Ok(())
}

/// Counters as a JSON object for the serve summaries.
pub fn counters_json() -> crate::util::json::Json {
    use crate::util::json::Json;
    Json::Obj(
        COUNTERS
            .snapshot()
            .into_iter()
            .map(|(n, v)| (n.to_string(), Json::Num(v as f64)))
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_are_cumulative_and_bounded() {
        let h = Histogram::new();
        h.observe_ns(500); // <1µs → bucket 0
        h.observe_ns(1_500); // ~1.5µs → le 2µs
        h.observe_ns(3_000_000); // 3ms
        h.observe_ns(u64::MAX / 2); // lands in +Inf
        let cum = h.cumulative();
        assert_eq!(cum.last().unwrap().1, 4, "last bucket holds everything");
        assert!(cum.windows(2).all(|w| w[0].1 <= w[1].1), "cumulative monotone");
        assert_eq!(cum[0].1, 1, "sub-µs observation in the first bucket");
        assert_eq!(h.count(), 4);
    }

    #[test]
    fn histogram_power_of_two_boundaries_are_inclusive() {
        let h = Histogram::new();
        h.observe_ns(1_000); // exactly 1µs → le 1µs (bucket 0)
        h.observe_ns(2_000); // exactly 2µs → le 2µs
        h.observe_ns(2_001); // just over 2µs → le 4µs
        h.observe_ns(4_000); // exactly 4µs → le 4µs
        let cum = h.cumulative();
        assert_eq!(cum[0].1, 1, "1µs must count in le=1µs");
        assert_eq!(cum[1].1, 2, "2µs must count in le=2µs");
        assert_eq!(cum[2].1, 4, "(2µs, 4µs] must count in le=4µs");
        assert_eq!(h.count(), 4);
    }

    #[test]
    fn exposition_is_well_formed() {
        counters().steals.fetch_add(0, Ordering::Relaxed);
        let text = exposition();
        assert!(text.contains("simple_steals_total"));
        assert!(text.contains("simple_cow_forks_total"));
        assert!(text.contains("simple_decide_latency_seconds_bucket{le=\"+Inf\"}"));
        assert!(text.contains("simple_collect_wait_seconds_count"));
        // every sample line is `name{labels}? value`
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let mut parts = line.rsplitn(2, ' ');
            let value = parts.next().unwrap();
            assert!(value.parse::<f64>().is_ok(), "bad sample line: {line}");
        }
    }

    #[test]
    fn counter_get_by_name() {
        inc(&counters().router_requeues);
        assert!(counters().get("router_requeues").unwrap() >= 1);
        assert_eq!(counters().get("nope"), None);
    }
}
