//! Flight-recorder tracing for the decision plane (DESIGN.md §14).
//!
//! The paper's central claim is about *where time hides* — decision-plane
//! work overlapped behind data-plane compute, last-stage bubbles, recovery
//! pauses. [`crate::metrics::Recorder`] reports those as post-hoc
//! aggregates; this module records the *timeline*: every scheduler
//! admission, microbatch forward, sampler decide, work steal, claim
//! release, respawn, COW fork, LRU eviction, and route decision, as a
//! timestamped event in a per-thread lock-free ring
//! ([`crate::ringbuf::flight::FlightRing`], bounded, overwrite-oldest), so
//! a capture always holds the most recent window and recording can never
//! stall the hot path.
//!
//! **Gate.** Tracing is off by default and costs one relaxed atomic load
//! per call site (`trace::on()`); every emit helper is a no-op when off, so
//! token streams and timing are untouched — tracing is pure observation
//! (enforced by the on/off differential tests and the `trace/{off,on}`
//! bench floor). Enable with `--trace <path>` on the CLIs or the
//! `SIMPLE_TRACE=<path>` environment variable.
//!
//! **Epoch.** All timestamps are nanoseconds since one shared process
//! epoch ([`epoch()`]): the engine, sampler workers, replica threads, the
//! router, and the logger ([`crate::util::logging`]) all clock against it,
//! so spans from different threads line up in a capture and trace-derived
//! overlap accounting is directly comparable to the `Recorder`'s.
//!
//! **Export.** [`export::write_chrome`] writes Chrome-trace/Perfetto JSON
//! (`ph: B/E/X/i`, pid = replica, tid = thread role — open in
//! <https://ui.perfetto.dev> or `chrome://tracing`); [`metrics`] keeps the
//! always-on counters/histograms and renders a Prometheus-style text
//! exposition (`--metrics-out`).

pub mod export;
pub mod metrics;

use crate::ringbuf::flight::FlightRing;
// host atomics: the const-initialized statics below (ENABLED, the
// registry) live outside the loom-modeled surface — see util::sync docs.
use crate::util::sync::host::{AtomicBool, AtomicU32, Ordering};
use std::cell::Cell;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Words per event record in the per-thread flight ring.
const WORDS: usize = 5;

/// Default per-thread ring capacity (events). ~40 B/event → ~640 KiB per
/// traced thread; override with `SIMPLE_TRACE_CAP`. Rings are allocated
/// lazily (first emit with tracing on) and recycled when a thread exits,
/// so total memory is bounded by the peak number of concurrently tracing
/// threads — not by how many threads a run ever spawned.
pub const DEFAULT_RING_CAP: usize = 1 << 14;

// ---------------------------------------------------------------------------
// Event taxonomy
// ---------------------------------------------------------------------------

/// Every event type the system declares. One byte on the wire; the name is
/// the Chrome-trace event name (and what `python/trace_check.py` matches).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Kind {
    // scheduler (engine thread)
    SchedAdmit = 0,
    SchedResume = 1,
    SchedPreempt = 2,
    SchedChunk = 3,
    // engine iteration (per microbatch)
    EnginePlan = 4,
    EngineForward = 5,
    EngineCommit = 6,
    EngineCollectWait = 7,
    // decision service
    SvcSubmit = 8,
    SvcDecide = 9,
    SvcCollect = 10,
    SvcSteal = 11,
    SvcClaimRelease = 12,
    SvcRespawn = 13,
    // in-flight slot table, recovery path
    SlotRecover = 14,
    // kv cache
    KvHit = 15,
    KvMiss = 16,
    KvCowFork = 17,
    KvEvict = 18,
    // cluster router
    RouteDecision = 19,
    RouteRequeue = 20,
    // WARN+ log records (args.msg carries the interned text)
    Log = 21,
}

impl Kind {
    pub const ALL: [Kind; 22] = [
        Kind::SchedAdmit,
        Kind::SchedResume,
        Kind::SchedPreempt,
        Kind::SchedChunk,
        Kind::EnginePlan,
        Kind::EngineForward,
        Kind::EngineCommit,
        Kind::EngineCollectWait,
        Kind::SvcSubmit,
        Kind::SvcDecide,
        Kind::SvcCollect,
        Kind::SvcSteal,
        Kind::SvcClaimRelease,
        Kind::SvcRespawn,
        Kind::SlotRecover,
        Kind::KvHit,
        Kind::KvMiss,
        Kind::KvCowFork,
        Kind::KvEvict,
        Kind::RouteDecision,
        Kind::RouteRequeue,
        Kind::Log,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Kind::SchedAdmit => "sched.admit",
            Kind::SchedResume => "sched.resume",
            Kind::SchedPreempt => "sched.preempt",
            Kind::SchedChunk => "sched.chunk",
            Kind::EnginePlan => "engine.plan",
            Kind::EngineForward => "engine.forward",
            Kind::EngineCommit => "engine.commit",
            Kind::EngineCollectWait => "engine.collect_wait",
            Kind::SvcSubmit => "svc.submit",
            Kind::SvcDecide => "svc.decide",
            Kind::SvcCollect => "svc.collect",
            Kind::SvcSteal => "svc.steal",
            Kind::SvcClaimRelease => "svc.claim_release",
            Kind::SvcRespawn => "svc.respawn",
            Kind::SlotRecover => "slot.recover",
            Kind::KvHit => "kv.hit",
            Kind::KvMiss => "kv.miss",
            Kind::KvCowFork => "kv.cow_fork",
            Kind::KvEvict => "kv.evict",
            Kind::RouteDecision => "route.decision",
            Kind::RouteRequeue => "route.requeue",
            Kind::Log => "log",
        }
    }

    /// Chrome-trace category (one per subsystem).
    pub fn category(self) -> &'static str {
        match self {
            Kind::SchedAdmit | Kind::SchedResume | Kind::SchedPreempt | Kind::SchedChunk => {
                "sched"
            }
            Kind::EnginePlan
            | Kind::EngineForward
            | Kind::EngineCommit
            | Kind::EngineCollectWait => "engine",
            Kind::SvcSubmit
            | Kind::SvcDecide
            | Kind::SvcCollect
            | Kind::SvcSteal
            | Kind::SvcClaimRelease
            | Kind::SvcRespawn => "svc",
            Kind::SlotRecover => "slot",
            Kind::KvHit | Kind::KvMiss | Kind::KvCowFork | Kind::KvEvict => "kv",
            Kind::RouteDecision | Kind::RouteRequeue => "route",
            Kind::Log => "log",
        }
    }

    fn from_u8(v: u8) -> Option<Kind> {
        Kind::ALL.get(v as usize).copied()
    }
}

/// Chrome-trace phase of an event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Phase {
    /// Span begin (`ph: "B"`).
    Begin = 0,
    /// Span end (`ph: "E"`).
    End = 1,
    /// Complete span with duration (`ph: "X"`).
    Complete = 2,
    /// Instant (`ph: "i"`).
    Instant = 3,
}

impl Phase {
    fn from_u8(v: u8) -> Option<Phase> {
        match v {
            0 => Some(Phase::Begin),
            1 => Some(Phase::End),
            2 => Some(Phase::Complete),
            3 => Some(Phase::Instant),
            _ => None,
        }
    }
}

/// A decoded trace event.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub kind: Kind,
    pub ph: Phase,
    /// Process lane: 0 = the pool/router process, r+1 = replica r.
    pub pid: u32,
    /// Thread lane within the pid (see [`tid_engine`] etc.).
    pub tid: u32,
    /// Nanoseconds since [`epoch()`].
    pub ts_ns: u64,
    /// Duration (Complete events only).
    pub dur_ns: u64,
    /// Event args — meaning is per-kind (seq id, microbatch, worker, …).
    pub a: u64,
    pub b: u64,
}

impl TraceEvent {
    pub fn ts_s(&self) -> f64 {
        self.ts_ns as f64 / 1e9
    }
    pub fn end_s(&self) -> f64 {
        (self.ts_ns + self.dur_ns) as f64 / 1e9
    }
}

// word0 layout: kind(8) | ph(8) | pid(16) | tid(32)
fn pack0(kind: Kind, ph: Phase, pid: u32, tid: u32) -> u64 {
    (kind as u64) | ((ph as u64) << 8) | (((pid as u64) & 0xffff) << 16) | ((tid as u64) << 32)
}

fn decode(rec: &[u64; WORDS]) -> Option<TraceEvent> {
    let kind = Kind::from_u8((rec[0] & 0xff) as u8)?;
    let ph = Phase::from_u8(((rec[0] >> 8) & 0xff) as u8)?;
    Some(TraceEvent {
        kind,
        ph,
        pid: ((rec[0] >> 16) & 0xffff) as u32,
        tid: (rec[0] >> 32) as u32,
        ts_ns: rec[1],
        dur_ns: rec[2],
        a: rec[3],
        b: rec[4],
    })
}

// ---------------------------------------------------------------------------
// Thread lanes
// ---------------------------------------------------------------------------

/// tid of the main/router thread.
pub const TID_MAIN: u32 = 0;
/// tid of an engine/replica worker thread.
pub const TID_ENGINE: u32 = 1;
/// tid of sampler worker `k`.
pub fn tid_sampler(worker: usize) -> u32 {
    100 + worker as u32
}

/// Human name for a (pid, tid) lane, used by the exporter's metadata.
pub fn lane_name(tid: u32) -> String {
    match tid {
        TID_MAIN => "main/router".to_string(),
        TID_ENGINE => "engine".to_string(),
        t if t >= 100 => format!("sampler-{}", t - 100),
        t => format!("thread-{t}"),
    }
}

// ---------------------------------------------------------------------------
// Global state
// ---------------------------------------------------------------------------

struct ThreadBuf {
    pid: AtomicU32,
    tid: AtomicU32,
    /// Claimed by a live thread? Released by the TLS destructor at thread
    /// exit so the next spawned thread reuses the ring allocation instead
    /// of growing the registry without bound (records carry their own
    /// pid/tid, so a recycled ring keeps the dead lane's events in the
    /// capture until they age out of the window).
    in_use: AtomicBool,
    ring: FlightRing<WORDS>,
}

struct Registry {
    bufs: Mutex<Vec<Arc<ThreadBuf>>>,
    next_anon_tid: AtomicU32,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static REGISTRY: OnceLock<Registry> = OnceLock::new();
static STRINGS: Mutex<Vec<String>> = Mutex::new(Vec::new());

/// Sentinel tid meaning "lane not declared" — an anonymous tid is
/// assigned the first time the thread actually emits.
const ANON_TID: u32 = u32::MAX;

/// Per-thread trace state. The ring is *not* allocated here: a thread gets
/// a buffer only on its first emit — which is gated on [`on()`] — so
/// spawning replica/sampler threads with tracing off allocates nothing.
struct TlsSlot {
    /// Lane declared by [`register_thread`] (pid, tid).
    lane: Cell<(u32, u32)>,
    buf: Cell<Option<&'static ThreadBuf>>,
}

impl Drop for TlsSlot {
    fn drop(&mut self) {
        // Return the buffer to the registry's free pool at thread exit.
        if let Some(b) = self.buf.get() {
            b.in_use.store(false, Ordering::Release);
        }
    }
}

thread_local! {
    static TLS: TlsSlot = const {
        TlsSlot { lane: Cell::new((0, ANON_TID)), buf: Cell::new(None) }
    };
}

fn registry() -> &'static Registry {
    REGISTRY.get_or_init(|| Registry {
        bufs: Mutex::new(Vec::new()),
        next_anon_tid: AtomicU32::new(2),
    })
}

fn ring_cap() -> usize {
    std::env::var("SIMPLE_TRACE_CAP")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&c| c > 0)
        .unwrap_or(DEFAULT_RING_CAP)
}

/// The shared monotonic epoch every subsystem clocks against. First access
/// pins it; the engine, sampler service, cluster, and logger all use this,
/// so their timestamps are directly comparable.
pub fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since [`epoch()`].
#[inline]
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

/// Is tracing enabled? One relaxed load — THE gate every instrumentation
/// site checks first, so tracing-off costs a predictable branch.
#[inline(always)]
pub fn on() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn tracing on/off (the `--trace` / `SIMPLE_TRACE` plumbing).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::SeqCst);
}

/// CLI plumbing: resolve the capture path from `--trace <path>` (passed by
/// the caller) or the `SIMPLE_TRACE=<path>` environment variable, and — if
/// one is set — enable tracing. Returns the path to hand to
/// [`export::write_chrome`] at the end of the run, `None` when tracing
/// stays off.
pub fn init_capture(cli: Option<&str>) -> Option<std::path::PathBuf> {
    let path = cli
        .map(str::to_string)
        .or_else(|| std::env::var("SIMPLE_TRACE").ok())
        .filter(|p| !p.is_empty())?;
    set_enabled(true);
    Some(std::path::PathBuf::from(path))
}

/// Per-thread buffer, acquired on first emit: recycle a free buffer from
/// an exited thread if one exists, else allocate. Only reached from
/// [`emit`], i.e. only when tracing is on — threads that never emit never
/// allocate a ring.
fn buf() -> Option<&'static ThreadBuf> {
    // try_with: a log/span emitted while TLS is being torn down at thread
    // exit is dropped rather than panicking.
    TLS.try_with(|tls| match tls.buf.get() {
        Some(b) => b,
        None => {
            let (pid, mut tid) = tls.lane.get();
            if tid == ANON_TID {
                // ordering: Relaxed — a pure id allocator; uniqueness
                // comes from the RMW itself, no data is published.
                tid = registry().next_anon_tid.fetch_add(1, Ordering::Relaxed);
                tls.lane.set((pid, tid));
            }
            let b = acquire_buf(pid, tid);
            tls.buf.set(Some(b));
            b
        }
    })
    .ok()
}

fn acquire_buf(pid: u32, tid: u32) -> &'static ThreadBuf {
    let reg = registry();
    let mut bufs = reg.bufs.lock().unwrap();
    for b in bufs.iter() {
        // ordering: Acquire on success pairs with the TLS destructor's
        // Release of in_use; Relaxed on failure — a taken buffer is just
        // skipped, nothing is read through it.
        if b.in_use
            .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
        {
            // ordering: Relaxed — lane labels are advisory metadata read
            // by the exporter; records carry their own pid/tid words.
            b.pid.store(pid, Ordering::Relaxed);
            // ordering: as above — advisory lane label.
            b.tid.store(tid, Ordering::Relaxed);
            // SAFETY: every buffer's allocation is immortal — one
            // refcount was leaked at creation (Arc::into_raw below) and
            // the registry holds another forever, so the 'static
            // reference can never dangle.
            return unsafe { &*Arc::as_ptr(b) };
        }
    }
    let b = Arc::new(ThreadBuf {
        pid: AtomicU32::new(pid),
        tid: AtomicU32::new(tid),
        in_use: AtomicBool::new(true),
        ring: FlightRing::new(ring_cap()),
    });
    bufs.push(b.clone());
    // SAFETY: the registry keeps its Arc forever; leaking one refcount
    // here makes the 'static reference handed to the owning thread
    // sound (the allocation is immortal). Rings are recycled (in_use
    // flag), so the registry's size is bounded by the peak number of
    // *concurrently* tracing threads.
    unsafe { &*Arc::into_raw(b) }
}

/// Declare the calling thread's trace lane: `pid` 0 for the pool/router
/// process, `r + 1` for replica `r`; `tid` from [`TID_ENGINE`] /
/// [`tid_sampler`] / [`TID_MAIN`]. Call at thread start (idempotent:
/// re-registering re-labels). Cheap — no ring is allocated until the
/// thread first emits with tracing on.
pub fn register_thread(pid: u32, tid: u32) {
    let _ = TLS.try_with(|tls| {
        tls.lane.set((pid, tid));
        if let Some(b) = tls.buf.get() {
            // ordering: Relaxed — advisory lane re-label (see
            // acquire_buf); only this thread writes its own buffer's
            // labels.
            b.pid.store(pid, Ordering::Relaxed);
            // ordering: as above — advisory lane re-label.
            b.tid.store(tid, Ordering::Relaxed);
        }
    });
}

// ---------------------------------------------------------------------------
// Emission
// ---------------------------------------------------------------------------

#[inline]
fn emit(kind: Kind, ph: Phase, ts_ns: u64, dur_ns: u64, a: u64, b: u64) {
    let Some(buf) = buf() else { return };
    let w0 = pack0(
        kind,
        ph,
        buf.pid.load(Ordering::Relaxed),
        buf.tid.load(Ordering::Relaxed),
    );
    buf.ring.push(&[w0, ts_ns, dur_ns, a, b]);
}

/// Emit an instant event now. No-op when tracing is off.
#[inline]
pub fn instant(kind: Kind, a: u64, b: u64) {
    if on() {
        emit(kind, Phase::Instant, now_ns(), 0, a, b);
    }
}

/// Emit a complete (`X`) span from explicit start/end instants measured by
/// the caller. No-op when tracing is off.
#[inline]
pub fn complete(kind: Kind, start_ns: u64, end_ns: u64, a: u64, b: u64) {
    if on() {
        emit(kind, Phase::Complete, start_ns, end_ns.saturating_sub(start_ns), a, b);
    }
}

/// Emit a complete span from f64 seconds-since-epoch timestamps (the
/// `Recorder`'s native unit — same epoch, so the conversion is exact to
/// f64 precision).
#[inline]
pub fn complete_s(kind: Kind, start_s: f64, end_s: f64, a: u64, b: u64) {
    if on() {
        let start = (start_s.max(0.0) * 1e9) as u64;
        let end = (end_s.max(0.0) * 1e9) as u64;
        emit(kind, Phase::Complete, start, end.saturating_sub(start), a, b);
    }
}

/// RAII span: emits `B` at construction and `E` on drop (stack discipline
/// keeps per-thread spans well-nested). When tracing is off at
/// construction nothing is emitted — including the `E` — so pairs stay
/// balanced even across a mid-run gate flip.
pub struct SpanGuard {
    kind: Option<Kind>,
    a: u64,
    b: u64,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(kind) = self.kind {
            emit(kind, Phase::End, now_ns(), 0, self.a, self.b);
        }
    }
}

/// Open a `B`/`E` span for the current scope. No-op guard when off.
#[inline]
pub fn span(kind: Kind, a: u64, b: u64) -> SpanGuard {
    if on() {
        emit(kind, Phase::Begin, now_ns(), 0, a, b);
        SpanGuard { kind: Some(kind), a, b }
    } else {
        SpanGuard { kind: None, a: 0, b: 0 }
    }
}

// ---------------------------------------------------------------------------
// String interning (rare events only: WARN+ log records)
// ---------------------------------------------------------------------------

/// Intern a string for event args (used by WARN+ log records; takes a
/// mutex, so only for rare events). Returns an id for [`interned`].
pub fn intern(s: &str) -> u64 {
    let mut table = STRINGS.lock().unwrap();
    table.push(s.to_string());
    table.len() as u64 // ids are 1-based; 0 = "no string"
}

/// Look up an interned string by id.
pub fn interned(id: u64) -> Option<String> {
    if id == 0 {
        return None;
    }
    STRINGS.lock().unwrap().get(id as usize - 1).cloned()
}

// ---------------------------------------------------------------------------
// Collection
// ---------------------------------------------------------------------------

/// Snapshot every thread's retained events, merged and sorted by
/// timestamp (ties keep `B` before `E` via stable per-thread order).
pub fn snapshot_events() -> Vec<TraceEvent> {
    let bufs = registry().bufs.lock().unwrap().clone();
    let mut out = Vec::new();
    for b in bufs {
        for rec in b.ring.snapshot() {
            if let Some(ev) = decode(&rec) {
                out.push(ev);
            }
        }
    }
    out.sort_by_key(|e| e.ts_ns);
    out
}

/// Number of ring buffers ever allocated (diagnostics). Recycling keeps
/// this bounded by the peak number of *concurrently* tracing threads, not
/// by how many threads the process ever spawned.
pub fn allocated_rings() -> usize {
    registry().bufs.lock().unwrap().len()
}

/// Total events dropped to ring overwrite across all threads (what the
/// capture is missing; surfaced in the export and the exposition).
pub fn dropped_events() -> u64 {
    let bufs = registry().bufs.lock().unwrap().clone();
    bufs.iter()
        .map(|b| b.ring.pushed().saturating_sub(b.ring.capacity() as u64))
        .sum()
}

/// Reset every ring (tests / between experiment cases). Caller must
/// quiesce writers first.
pub fn clear() {
    let bufs = registry().bufs.lock().unwrap().clone();
    for b in bufs {
        b.ring.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrips_and_names_unique() {
        let mut names = std::collections::BTreeSet::new();
        for (i, k) in Kind::ALL.iter().enumerate() {
            assert_eq!(*k as usize, i, "ALL order must match discriminants");
            assert_eq!(Kind::from_u8(*k as u8), Some(*k));
            assert!(names.insert(k.name()), "duplicate name {}", k.name());
        }
    }

    #[test]
    fn pack_decode_roundtrip() {
        let rec = [
            pack0(Kind::SvcSteal, Phase::Instant, 3, tid_sampler(2)),
            123_456,
            789,
            42,
            u64::MAX,
        ];
        let ev = decode(&rec).unwrap();
        assert_eq!(ev.kind, Kind::SvcSteal);
        assert_eq!(ev.ph, Phase::Instant);
        assert_eq!(ev.pid, 3);
        assert_eq!(ev.tid, tid_sampler(2));
        assert_eq!(ev.ts_ns, 123_456);
        assert_eq!(ev.dur_ns, 789);
        assert_eq!((ev.a, ev.b), (42, u64::MAX));
    }

    #[test]
    fn intern_roundtrip() {
        let id = intern("hello trace");
        assert_eq!(interned(id).as_deref(), Some("hello trace"));
        assert_eq!(interned(0), None);
    }

    #[test]
    fn off_gate_emits_nothing() {
        // Note: tests in this binary that enable tracing must hold the
        // same serialization discipline; unit scope here only checks the
        // off path, which is the default state.
        if !on() {
            let before = snapshot_events().len();
            instant(Kind::KvHit, 1, 2);
            drop(span(Kind::EnginePlan, 0, 0));
            complete(Kind::SvcDecide, 1, 2, 0, 0);
            assert_eq!(snapshot_events().len(), before);
        }
    }
}
