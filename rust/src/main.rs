//! `simple-serve` CLI — leader entrypoint.
//!
//! Subcommands:
//! - `serve`      — serve a synthetic workload end-to-end on an AOT model
//!                  through PJRT with the chosen decision-plane variant.
//! - `figures`    — regenerate paper figures/tables into `results/`.
//! - `calibrate`  — measure decision-plane costs + fit the sizing model.
//! - `sim`        — run one distributed serving simulation and print it.

// Config structs are built by `default()` + field assignment (sweep-driver
// idiom); see the identical crate-level allow in lib.rs.
#![allow(clippy::field_reassign_with_default)]

use simple_serve::cluster::{Cluster, ClusterConfig};
use simple_serve::config::{DecisionVariant, EngineConfig};
use simple_serve::decision::HotVocab;
use simple_serve::engine::{PjrtEngine, Request};
use simple_serve::harness::{self, Effort};
use simple_serve::runtime::{default_artifacts_dir, Manifest, ModelRuntime};
use simple_serve::simulator::{simulate, DecisionMode, GpuModel, SimConfig};
use simple_serve::util::argparse::{render_help, Args, OptSpec};
use simple_serve::util::json::Json;
use simple_serve::{config, workload};

const SPECS: &[OptSpec] = &[
    OptSpec::value("model", "model name (AOT: micro-test|tiny-30m; sim: paper models)"),
    OptSpec::value("platform", "platform for sim: l40|h100|b200"),
    OptSpec::value("variant", "decision plane: gpu-epilogue|naive-cpu|parallel|offloading|shvs"),
    OptSpec::value("tp", "tensor parallel degree"),
    OptSpec::value("pp", "pipeline parallel depth"),
    OptSpec::value("samplers", "number of CPU samplers m"),
    OptSpec::value("hot_vocab", "hot-vocab size H (0 = sizing model)"),
    OptSpec::value("vocab", "vocabulary size (calibrate)"),
    OptSpec::value("requests", "number of requests"),
    OptSpec::value("seed", "engine seed"),
    OptSpec::value("batch_per_gpu", "microbatch per GPU (sim)"),
    OptSpec::value("max_seq_len", "max sequence length"),
    OptSpec::value("spec_k", "speculative draft window per iteration (serve; 0 = off)"),
    OptSpec::value("n_microbatches", "in-flight microbatches for the pipelined executor"),
    OptSpec::value("idle_poll_us", "idle poll quantum in µs (0 = busy-poll)"),
    OptSpec::flag("overlap", "overlap the decision plane with forwards (serve)"),
    OptSpec::value("replicas", "data-parallel engine replicas (serve; default 1)"),
    OptSpec::value(
        "route",
        "routing policy: rr|least-outstanding|kv-pressure|session-affinity|prefix-cache",
    ),
    OptSpec::flag("shared_samplers", "one shared sampler pool for the whole fleet (serve)"),
    OptSpec::value("prefill_replicas", "DistServe-style split: prefill-only replicas (serve)"),
    OptSpec::value("kv_transfer_us", "simulated KV-transfer µs per context token (handoff)"),
    OptSpec::value(
        "chaos",
        "fault plan: sampler:<id>@<iter>,replica:<id>@<n>,poison@<iter> (legacy; kills worker 0) (serve)",
    ),
    OptSpec::flag("no_failover", "fail the run on replica death instead of requeueing (serve)"),
    OptSpec::value(
        "traffic",
        "workload shape: closed|steady|burst|zipf|conv (conv = conversation trees) (serve)",
    ),
    OptSpec::value("rate", "mean arrival rate, req/s (serve --traffic; default 100)"),
    OptSpec::value("experiments", "comma-separated figure ids (figures)"),
    OptSpec::value("trace", "write a Chrome-trace/Perfetto capture here (or SIMPLE_TRACE=)"),
    OptSpec::value("metrics_out", "write the Prometheus-style metrics exposition here"),
    OptSpec::flag("full", "full effort (paper-scale sweeps)"),
    OptSpec::flag("help", "show help"),
];

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> simple_serve::Result<()> {
    let args = Args::parse_env(SPECS, true)?;
    if args.flag("help") || args.subcommand.is_none() {
        print!(
            "{}",
            render_help(
                "simple-serve",
                "SIMPLE decision-plane serving (paper reproduction)\n\
                 subcommands: serve | figures | calibrate | sim",
                SPECS
            )
        );
        return Ok(());
    }
    match args.subcommand.as_deref().unwrap() {
        "serve" => cmd_serve(&args),
        "figures" => cmd_figures(&args),
        "calibrate" => cmd_calibrate(&args),
        "sim" => cmd_sim(&args),
        other => anyhow::bail!("unknown subcommand {other} (try --help)"),
    }
}

fn cmd_serve(args: &Args) -> simple_serve::Result<()> {
    let trace_out = simple_serve::trace::init_capture(args.get("trace"));
    let model = args.get("model").unwrap_or("micro-test").to_string();
    let n: usize = args.get_or("requests", 16)?;
    let mut cfg = EngineConfig::default();
    cfg.apply_args(args)?;
    if args.flag("overlap") {
        cfg.overlap = true;
    }
    let mut ccfg = ClusterConfig::default();
    ccfg.apply_args(args)?;
    ccfg.idle_poll_us = cfg.idle_poll_us;
    if let Some(spec) = args.get("chaos") {
        // fail loudly on a plan that cannot fire (wrong sampler/replica
        // ids) — a silently no-op injection makes a chaos run vacuous
        simple_serve::fault::FaultPlan::parse(spec)?
            .validate(cfg.sampler.num_samplers, ccfg.replicas)?;
    }

    let manifest = Manifest::load(&default_artifacts_dir())?;
    if ccfg.replicas > 1 || ccfg.prefill_replicas > 0 {
        serve_cluster(args, &model, n, &cfg, &ccfg, &manifest)?;
        return finish_observability(args, trace_out);
    }
    let rt = ModelRuntime::load(&manifest, &model)?;
    let vocab = rt.vocab();
    let hot = serve_hot_set(&cfg, vocab);
    println!(
        "serving {n} requests on {model} (V={vocab}) via {} with {} samplers ...",
        cfg.sampler.variant.name(),
        cfg.sampler.num_samplers
    );
    let mut engine = PjrtEngine::new(rt, &cfg, hot);
    for r in serve_trace(args, n, vocab, cfg.max_seq_len.min(256))? {
        engine.submit(r);
    }
    let summary = engine.run_until_idle()?;
    println!("{}", with_counters(summary.to_json()).to_string_pretty());
    let ov = engine.overlap_report();
    if ov.decision_busy_s > 0.0 {
        println!(
            "decision overlap: {:.0}% hidden under forwards, {:.2} ms exposed, \
             last-stage bubble {:.1}% ({} microbatches)",
            ov.overlap_fraction * 100.0,
            ov.exposed_wait_s * 1e3,
            ov.last_stage_bubble * 100.0,
            ov.microbatches
        );
    }
    if engine.spec_windows > 0 {
        println!(
            "speculative decoding: {}/{} drafts accepted over {} windows",
            engine.spec_accepted, engine.spec_proposed, engine.spec_windows
        );
    }
    let (recorder, stats) = engine.shutdown();
    if recorder.recoveries() > 0 {
        println!(
            "fault recovery: {} sampler respawn(s), {:.2} ms recovery time \
             (streams bit-identical to the fault-free run)",
            recorder.recoveries(),
            recorder.recovery_s() * 1e3
        );
    }
    let decisions: u64 = stats.iter().map(|s| s.decisions).sum();
    let fast: u64 = stats.iter().map(|s| s.fast_path_hits).sum();
    if decisions > 0 {
        println!(
            "decision plane: {decisions} decisions, {:.1}% fast path",
            fast as f64 / decisions as f64 * 100.0
        );
    }
    finish_observability(args, trace_out)
}

/// Append the decision-plane counters to a serve summary object.
fn with_counters(mut j: Json) -> Json {
    if let Json::Obj(fields) = &mut j {
        fields.insert(
            "counters".to_string(),
            simple_serve::trace::metrics::counters_json(),
        );
    }
    j
}

/// Flush observability outputs at the end of a serve run: the Perfetto
/// capture (`--trace` / `SIMPLE_TRACE`) and the Prometheus-style text
/// exposition (`--metrics_out`).
fn finish_observability(
    args: &Args,
    trace_out: Option<std::path::PathBuf>,
) -> simple_serve::Result<()> {
    if let Some(path) = trace_out {
        simple_serve::trace::export::write_chrome(&path)?;
        println!("wrote trace capture {}", path.display());
    }
    if let Some(p) = args.get("metrics_out") {
        let path = std::path::PathBuf::from(p);
        simple_serve::trace::metrics::write_exposition(&path)?;
        println!("wrote metrics exposition {}", path.display());
    }
    Ok(())
}

/// Build the serve workload. `--traffic closed` (default) is the classic
/// closed-loop ShareGPT-like trace; `steady|burst|zipf` stamp open-loop
/// arrivals at `--rate`; `conv` generates conversation trees (`--requests`
/// counts conversations) whose turns share growing prefixes — the
/// workload `--route prefix-cache` and the engine's radix KV reuse
/// (DESIGN.md §13) are built for.
fn serve_trace(
    args: &Args,
    n: usize,
    vocab: usize,
    max_seq: usize,
) -> simple_serve::Result<Vec<Request>> {
    let rate: f64 = args.get_or("rate", 100.0)?;
    Ok(match args.get("traffic").unwrap_or("closed") {
        "conv" | "conversations" => {
            let mut cfg = workload::ConvConfig::sharegpt_like(n, vocab, max_seq);
            cfg.start_rate = rate;
            cfg.think_s = 0.2;
            workload::conversations(&cfg).requests
        }
        "closed" => {
            workload::generate(&workload::TraceConfig::sharegpt_like(n, vocab, max_seq))
                .requests
        }
        other => {
            let pattern = workload::TrafficPattern::parse(other)
                .ok_or_else(|| anyhow::anyhow!("unknown traffic shape {other}"))?;
            let mut trace = workload::generate(&workload::TraceConfig::sharegpt_like(
                n, vocab, max_seq,
            ));
            pattern.stamp(&mut trace, rate, 13);
            trace.requests
        }
    })
}

/// Offline-profiled hot set for the SHVS variant (AOT models put their
/// Zipf head on low ids — see python/compile/model.py lm_bias).
fn serve_hot_set(cfg: &EngineConfig, vocab: usize) -> Option<std::sync::Arc<HotVocab>> {
    (cfg.sampler.variant == DecisionVariant::Shvs).then(|| {
        let h = if cfg.sampler.hot_vocab > 0 {
            cfg.sampler.hot_vocab
        } else {
            (vocab / 5).clamp(64, 32_768)
        };
        HotVocab::new((0..h as u32).collect(), vocab).into_arc()
    })
}

/// `serve --replicas R [--route P] [--shared_samplers]`: the same workload
/// through a fleet of data-parallel PJRT replicas behind the router
/// (DESIGN.md §9). Each replica loads the model inside its own worker
/// thread; the fleet report merges every replica's recorder.
fn serve_cluster(
    args: &Args,
    model: &str,
    n: usize,
    cfg: &EngineConfig,
    ccfg: &ClusterConfig,
    manifest: &Manifest,
) -> simple_serve::Result<()> {
    anyhow::ensure!(
        !(ccfg.shared_samplers && cfg.sampler.variant == DecisionVariant::GpuEpilogue),
        "--shared_samplers needs a service-backed variant \
         (the GPU-epilogue baseline samples inline)"
    );
    let spec = manifest.model(model)?;
    let (vocab, max_seq) = (spec.vocab, spec.max_seq);
    let hot = serve_hot_set(cfg, vocab);
    println!(
        "serving {n} requests on {model} (V={vocab}) across {} replicas \
         [{}{}{}] with {} samplers/pool ...",
        ccfg.replicas,
        ccfg.policy.name(),
        if ccfg.shared_samplers { ", shared pool" } else { "" },
        if ccfg.prefill_replicas > 0 {
            format!(", {} prefill", ccfg.prefill_replicas)
        } else {
            String::new()
        },
        cfg.sampler.num_samplers
    );
    let artifacts = default_artifacts_dir();
    let model_name = model.to_string();
    let mut cluster = Cluster::start(
        cfg,
        ccfg,
        hot,
        max_seq,
        move |_id| {
            let manifest = Manifest::load(&artifacts)?;
            ModelRuntime::load(&manifest, &model_name)
        },
    );
    cluster.run(serve_trace(args, n, vocab, max_seq.min(256))?)?;
    let report = cluster.shutdown()?;
    println!("{}", with_counters(report.recorder.summary().to_json()).to_string_pretty());
    if report.prefill_skipped > 0 {
        println!(
            "prefix cache: {} prefill tokens skipped ({:.0}% reuse)",
            report.prefill_skipped,
            report.prefill_skipped as f64
                / (report.prefill_computed + report.prefill_skipped).max(1) as f64
                * 100.0
        );
    }
    for r in &report.per_replica {
        println!(
            "  replica {} [{}]: {:.0} tok/s, {} tokens, {} preemptions",
            r.id,
            r.role.name(),
            r.summary.throughput,
            r.summary.tokens,
            r.preemptions
        );
    }
    println!("fleet stream digest: {:016x}", report.stream_digest());
    if report.recorder.recoveries() > 0 {
        println!(
            "fault recovery: {} failover(s)/respawn(s), {} sequence(s) requeued, \
             {:.2} ms recovery time",
            report.recorder.recoveries(),
            report.requeued,
            report.recorder.recovery_s() * 1e3
        );
    }
    let decisions: u64 = report.sampler_stats.iter().map(|s| s.decisions).sum();
    if decisions > 0 {
        println!(
            "decision plane: {decisions} decisions over {} sampler(s)",
            report.sampler_stats.len()
        );
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> simple_serve::Result<()> {
    let effort = if args.flag("full") { Effort::Full } else { Effort::Quick };
    let ids: Vec<String> = match args.get("experiments") {
        Some(list) => list.split(',').map(|s| s.trim().to_string()).collect(),
        None => harness::ALL_EXPERIMENTS.iter().map(|s| s.to_string()).collect(),
    };
    let dir = harness::default_results_dir();
    for id in ids {
        let t0 = std::time::Instant::now();
        let report = harness::run_experiment(&id, effort)?;
        report.write(&dir)?;
        println!(
            "[{:>7.2?}] {} — {} -> results/{}.md",
            t0.elapsed(),
            report.id,
            report.title,
            report.id
        );
        println!("{}", report.markdown);
    }
    Ok(())
}

fn cmd_calibrate(args: &Args) -> simple_serve::Result<()> {
    let vocab: usize = args.get_or("vocab", 152_064)?;
    let effort = if args.flag("full") { Effort::Full } else { Effort::Quick };
    let iters = effort.scale(10, 50);
    println!("calibrating decision plane at V={vocab} ({iters} iters/variant) ...");
    let cal = harness::measure::calibrate(vocab, (vocab / 5).min(32_768), iters);
    for (variant, per_seq) in &cal.per_seq {
        println!(
            "  {:>12}: {:>10} per decision ({:.0} tok/s/sampler)",
            variant.name(),
            simple_serve::util::fmt_duration(std::time::Duration::from_secs_f64(*per_seq)),
            1.0 / per_seq
        );
    }
    let model = harness::measure::fit_sizing_model(vocab, 1.08, iters);
    println!(
        "sizing model: c={:.3e} c0={:.3e} (R²={:.4}) → H* = {}",
        model.c,
        model.c0,
        model.r2,
        model.h_star()
    );
    Ok(())
}

fn cmd_sim(args: &Args) -> simple_serve::Result<()> {
    let model = config::ModelSpec::by_name(args.get("model").unwrap_or("qwen3-235b-a22b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let platform = config::PlatformSpec::by_name(args.get("platform").unwrap_or("h100"))
        .ok_or_else(|| anyhow::anyhow!("unknown platform"))?;
    let tp: usize = args.get_or("tp", 4)?;
    let pp: usize = args.get_or("pp", 2)?;
    let n: usize = args.get_or("requests", 200)?;
    let samplers: usize = args.get_or("samplers", 64)?;
    let parallel = config::ParallelConfig::new(tp, pp);
    let variant = args.get("variant").unwrap_or("shvs");

    let gpu = GpuModel::new(model.clone(), platform.clone(), parallel);
    let mode = match variant {
        "gpu-epilogue" | "baseline" => DecisionMode::GpuEpilogue,
        "naive-cpu" => DecisionMode::CpuSerial {
            per_seq_s: harness::e2e::measured_shvs_per_seq(model.vocab, Effort::Quick) * 20.0,
            samplers,
        },
        _ => DecisionMode::SimpleOverlapped {
            per_seq_s: harness::e2e::measured_shvs_per_seq(model.vocab, Effort::Quick),
            samplers,
        },
    };
    let cfg = SimConfig::new(
        gpu,
        mode,
        32 * parallel.world_size(),
        platform.cpu_cores,
        samplers,
    );
    let trace_w = workload::generate(&workload::TraceConfig::sharegpt_like(
        n,
        model.vocab,
        4096,
    ));
    let trace = simple_serve::simulator::serving::to_sim_requests(&trace_w);
    let res = simulate(&cfg, &trace);
    println!(
        "{} on {} {tp}x{pp} [{variant}]: {:.0} tok/s, P95 TPOT {:.1} ms, \
         bubbles {:.1}%, sampling share {:.1}%",
        model.name,
        platform.name,
        res.throughput(),
        res.recorder.tpot_summary().p95 * 1e3,
        res.mean_bubble_fraction * 100.0,
        res.mean_sampling_fraction * 100.0
    );
    Ok(())
}
