//! Zipf / Zipf–Mandelbrot distributions.
//!
//! Two roles in this reproduction:
//! 1. **Token-distribution substrate** — §5.3's premise is that next-token
//!    probabilities are Zipf-like ("top 32k often covers > 95%"); the
//!    synthetic logits generator shapes heads with [`ZipfMandelbrot`] so the
//!    SHVS hit-ratio curve ᾱ(H) reproduces the paper's saturating shape.
//! 2. **Workload substrate** — prompt popularity in the ShareGPT-like trace.

/// Zipf–Mandelbrot over ranks `0..n`: p(r) ∝ 1 / (r + 1 + q)^s.
///
/// `q = 0` gives classic Zipf. Sampling is inverse-CDF over the precomputed
/// cumulative table (O(log n) per draw); mass queries are O(1) from the same
/// table.
#[derive(Debug, Clone)]
pub struct ZipfMandelbrot {
    /// Cumulative probabilities, cdf[r] = P(rank <= r); cdf[n-1] == 1.
    cdf: Vec<f64>,
    s: f64,
    q: f64,
}

impl ZipfMandelbrot {
    pub fn new(n: usize, s: f64, q: f64) -> Self {
        assert!(n > 0, "zipf over empty support");
        assert!(s > 0.0, "zipf exponent must be positive");
        assert!(q >= 0.0, "zipf shift must be non-negative");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for r in 0..n {
            acc += 1.0 / (r as f64 + 1.0 + q).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        ZipfMandelbrot { cdf, s, q }
    }

    /// Classic Zipf (q = 0).
    pub fn zipf(n: usize, s: f64) -> Self {
        Self::new(n, s, 0.0)
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }
    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
    pub fn exponent(&self) -> f64 {
        self.s
    }
    pub fn shift(&self) -> f64 {
        self.q
    }

    /// Probability of rank `r`.
    pub fn pmf(&self, r: usize) -> f64 {
        if r == 0 {
            self.cdf[0]
        } else {
            self.cdf[r] - self.cdf[r - 1]
        }
    }

    /// P(rank < h): the mass covered by the top-`h` ranks — the paper's
    /// hot-vocab mass ᾱ(H) for a Zipf-shaped head.
    pub fn head_mass(&self, h: usize) -> f64 {
        if h == 0 {
            0.0
        } else {
            self.cdf[(h - 1).min(self.cdf.len() - 1)]
        }
    }

    /// Draw a rank by inverse CDF.
    pub fn sample(&self, rng: &mut super::Philox) -> usize {
        let u = rng.next_f64();
        // first index with cdf[i] >= u
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    /// Smallest `h` such that head_mass(h) >= target (e.g. 0.95).
    pub fn rank_covering(&self, target: f64) -> usize {
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&target).unwrap())
        {
            Ok(i) => i + 1,
            Err(i) => (i + 1).min(self.cdf.len()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Philox;

    #[test]
    fn cdf_is_monotone_and_normalized() {
        let z = ZipfMandelbrot::zipf(1000, 1.1);
        for w in z.cdf.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert!((z.cdf.last().unwrap() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pmf_sums_to_one_and_is_decreasing() {
        let z = ZipfMandelbrot::new(500, 1.2, 2.0);
        let total: f64 = (0..z.len()).map(|r| z.pmf(r)).sum();
        assert!((total - 1.0).abs() < 1e-9);
        for r in 1..z.len() {
            assert!(z.pmf(r) <= z.pmf(r - 1) + 1e-15);
        }
    }

    #[test]
    fn head_mass_matches_pmf_sum() {
        let z = ZipfMandelbrot::zipf(200, 1.0);
        let direct: f64 = (0..50).map(|r| z.pmf(r)).sum();
        assert!((z.head_mass(50) - direct).abs() < 1e-12);
        assert_eq!(z.head_mass(0), 0.0);
        assert!((z.head_mass(200) - 1.0).abs() < 1e-12);
        assert!((z.head_mass(10_000) - 1.0).abs() < 1e-12); // clamps
    }

    #[test]
    fn zipf_heads_concentrate_like_the_paper_claims() {
        // §5.3: "top 32k often covers > 95%" of a ~152k vocab. With s≈1.1
        // (typical for token frequencies) the head mass is indeed that large.
        let z = ZipfMandelbrot::zipf(152_000, 1.1);
        assert!(z.head_mass(32_000) > 0.90, "mass {}", z.head_mass(32_000));
        let needed = z.rank_covering(0.95);
        assert!(needed < 152_000 / 2, "needed {needed}");
    }

    #[test]
    fn sampling_matches_pmf() {
        let z = ZipfMandelbrot::zipf(50, 1.3);
        let mut rng = Philox::new(99);
        let n = 100_000;
        let mut counts = vec![0usize; 50];
        for _ in 0..n {
            counts[z.sample(&mut rng)] += 1;
        }
        // Check the head ranks' empirical frequency against the pmf.
        for r in 0..5 {
            let emp = counts[r] as f64 / n as f64;
            let p = z.pmf(r);
            assert!((emp - p).abs() < 0.01, "rank {r}: emp {emp} pmf {p}");
        }
    }

    #[test]
    fn rank_covering_is_minimal() {
        let z = ZipfMandelbrot::zipf(1000, 1.1);
        let h = z.rank_covering(0.5);
        assert!(z.head_mass(h) >= 0.5);
        assert!(h == 1 || z.head_mass(h - 1) < 0.5);
    }

    #[test]
    #[should_panic]
    fn rejects_empty_support() {
        ZipfMandelbrot::zipf(0, 1.0);
    }
}
