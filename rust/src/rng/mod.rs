//! Deterministic random-number generation.
//!
//! The paper (§5.1 "Deterministic random number generation") pre-generates
//! random variates on the GPUs under a fixed seed and lets each CPU sampler
//! consume its slice, so that sequence-parallel sampling reproduces the
//! single-worker token stream exactly. A *counter-based* RNG is the natural
//! realization: any (seed, counter) cell can be evaluated independently by
//! any worker with no shared state. We implement **Philox 4x32-10**
//! (Salmon et al., SC'11) — the same family JAX's `threefry`/`rbg` and
//! cuRAND use — plus SplitMix64 for cheap non-reproducible utility streams.

pub mod zipf;

/// Philox 4x32-10 counter-based RNG.
///
/// `key` is the 64-bit seed; the 128-bit counter advances by one block per
/// four 32-bit outputs. Workers can `at(counter)` directly to consume
/// disjoint slices deterministically (the paper's pre-generated randoms).
#[derive(Debug, Clone)]
pub struct Philox {
    key: [u32; 2],
    counter: u128,
    buf: [u32; 4],
    buf_pos: usize,
}

const PHILOX_M0: u64 = 0xD251_1F53;
const PHILOX_M1: u64 = 0xCD9E_8D57;
const PHILOX_W0: u32 = 0x9E37_79B9;
const PHILOX_W1: u32 = 0xBB67_AE85;

impl Philox {
    /// New stream for `seed`, starting at counter 0.
    pub fn new(seed: u64) -> Self {
        Self::at(seed, 0)
    }

    /// New stream for `seed` positioned at block `counter` — random access,
    /// used by samplers to jump to their slice of the pre-generated stream.
    pub fn at(seed: u64, counter: u128) -> Self {
        Philox {
            key: [seed as u32, (seed >> 32) as u32],
            counter,
            buf: [0; 4],
            buf_pos: 4, // force refill on first draw
        }
    }

    /// Derive an independent stream for (seed, stream_id) — e.g. one stream
    /// per sequence id, so decisions are independent of batch composition.
    pub fn substream(seed: u64, stream_id: u64) -> Self {
        // Mix the stream id into the upper counter half: blocks never collide
        // with other substreams of the same seed.
        Self::at(seed, (stream_id as u128) << 64)
    }

    /// The 10-round Philox block function.
    fn block(key: [u32; 2], ctr: u128) -> [u32; 4] {
        let mut c = [
            ctr as u32,
            (ctr >> 32) as u32,
            (ctr >> 64) as u32,
            (ctr >> 96) as u32,
        ];
        let mut k = key;
        for _ in 0..10 {
            let p0 = PHILOX_M0 * c[0] as u64;
            let p1 = PHILOX_M1 * c[2] as u64;
            c = [
                ((p1 >> 32) as u32) ^ c[1] ^ k[0],
                p1 as u32,
                ((p0 >> 32) as u32) ^ c[3] ^ k[1],
                p0 as u32,
            ];
            k[0] = k[0].wrapping_add(PHILOX_W0);
            k[1] = k[1].wrapping_add(PHILOX_W1);
        }
        c
    }

    /// Next raw 32-bit word.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        if self.buf_pos == 4 {
            self.buf = Self::block(self.key, self.counter);
            self.counter = self.counter.wrapping_add(1);
            self.buf_pos = 0;
        }
        let v = self.buf[self.buf_pos];
        self.buf_pos += 1;
        v
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        (self.next_u32() as u64) << 32 | self.next_u32() as u64
    }

    /// Uniform in [0, 1) with 24 bits of mantissa (f32-grade, like cuRAND).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with 53 bits of mantissa.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo < n {
                let t = n.wrapping_neg() % n;
                if lo < t {
                    continue;
                }
            }
            return (m >> 64) as u64;
        }
    }

    /// Standard exponential variate (inverse CDF).
    pub fn next_exp(&mut self) -> f64 {
        -(1.0 - self.next_f64()).ln()
    }

    /// Standard normal via Box–Muller (one of the pair, cheap enough here).
    pub fn next_normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Log-normal with the given mu/sigma of the underlying normal.
    pub fn next_lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.next_normal()).exp()
    }

    /// Poisson variate (Knuth for small lambda, normal approx for large).
    pub fn next_poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.next_f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = lambda + lambda.sqrt() * self.next_normal();
            x.max(0.0).round() as u64
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Current block counter (for slicing bookkeeping).
    pub fn counter(&self) -> u128 {
        self.counter
    }
}

/// SplitMix64 — tiny fast PRNG for *non-reproducibility-critical* utility
/// randomness (e.g. jitter in load generators when determinism is off).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn philox_is_deterministic() {
        let mut a = Philox::new(42);
        let mut b = Philox::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn philox_seeds_differ() {
        let mut a = Philox::new(1);
        let mut b = Philox::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams should be (almost surely) different");
    }

    #[test]
    fn philox_random_access_matches_sequential() {
        // Consuming blocks 0..8 sequentially == jumping to block 4 directly.
        let mut seq = Philox::new(7);
        let seq_vals: Vec<u32> = (0..32).map(|_| seq.next_u32()).collect();
        let mut jumped = Philox::at(7, 4);
        let jump_vals: Vec<u32> = (0..16).map(|_| jumped.next_u32()).collect();
        assert_eq!(&seq_vals[16..], &jump_vals[..]);
    }

    #[test]
    fn substreams_are_disjoint() {
        let mut s0 = Philox::substream(9, 0);
        let mut s1 = Philox::substream(9, 1);
        let v0: Vec<u32> = (0..32).map(|_| s0.next_u32()).collect();
        let v1: Vec<u32> = (0..32).map(|_| s1.next_u32()).collect();
        assert_ne!(v0, v1);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Philox::new(123);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.next_f64();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn next_below_is_in_range_and_roughly_uniform() {
        let mut rng = Philox::new(5);
        let n = 30_000;
        let k = 7u64;
        let mut counts = [0usize; 7];
        for _ in 0..n {
            let v = rng.next_below(k);
            assert!(v < k);
            counts[v as usize] += 1;
        }
        let expected = n as f64 / k as f64;
        for c in counts {
            assert!((c as f64 - expected).abs() < expected * 0.1, "counts {counts:?}");
        }
    }

    #[test]
    fn poisson_mean_matches_lambda() {
        let mut rng = Philox::new(11);
        for lambda in [0.5, 4.0, 80.0] {
            let n = 5_000;
            let mean: f64 =
                (0..n).map(|_| rng.next_poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.12,
                "lambda {lambda} mean {mean}"
            );
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Philox::new(17);
        let n = 40_000;
        let vals: Vec<f64> = (0..n).map(|_| rng.next_normal()).collect();
        let mean = vals.iter().sum::<f64>() / n as f64;
        let var = vals.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Philox::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn splitmix_advances() {
        let mut s = SplitMix64::new(0);
        let a = s.next_u64();
        let b = s.next_u64();
        assert_ne!(a, b);
    }

    #[test]
    fn philox_known_vector_nonzero_diffusion() {
        // Zero key + zero counter must still produce well-diffused output.
        let out = Philox::block([0, 0], 0);
        assert!(out.iter().all(|&w| w != 0));
        // And flipping one counter bit changes all words.
        let out2 = Philox::block([0, 0], 1);
        assert!(out.iter().zip(&out2).all(|(a, b)| a != b));
    }
}
