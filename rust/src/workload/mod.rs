//! Workload synthesis: ShareGPT-like request traces and arrival processes.
//!
//! The paper replays a fixed prompt set sampled from ShareGPT with early
//! stopping disabled (§7.1). ShareGPT is unavailable offline, so we
//! synthesize traces with the published shape of that dataset: log-normal
//! prompt lengths (median ≈ tens of tokens, long tail) and log-normal
//! output lengths (median ≈ 200).
//!
//! # Arrival processes
//!
//! Open-loop load is stamped onto a trace by a [`TrafficPattern`]:
//!
//! - [`TrafficPattern::Steady`] — homogeneous Poisson arrivals, the classic
//!   load–latency sweep (Figure 6).
//! - [`TrafficPattern::Burst`] — a two-state Markov-modulated Poisson
//!   process (MMPP): exponentially-distributed ON phases at
//!   `burst_factor ×` the base rate alternate with quiet OFF phases. The
//!   mean rate matches the steady pattern, but arrivals cluster — the
//!   batch-churn regime (admission floods, KV pressure, preemption) that
//!   steady traces never reach.
//! - [`TrafficPattern::Zipf`] — flash crowds: Poisson-spaced arrival
//!   *trains* whose sizes are Zipf-distributed, so most epochs bring one
//!   request but a heavy tail brings near-simultaneous floods.
//!
//! All three are deterministic in `(trace, rate, seed)` and preserve the
//! requested mean arrival rate, so P95/P99 latency under the three shapes
//! is directly comparable (the `burst` harness scenario does exactly that).
//!
//! # Example
//!
//! ```no_run
//! use simple_serve::workload::{self, TraceConfig, TrafficPattern};
//! let mut trace = workload::generate(&TraceConfig::tiny(64, 1000));
//! TrafficPattern::parse("burst").unwrap().stamp(&mut trace, 100.0, 7);
//! ```

use crate::decision::SamplingParams;
use crate::engine::Request;
use crate::rng::Philox;
use crate::rng::zipf::ZipfMandelbrot;

/// Trace generation parameters.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub num_requests: usize,
    /// ln-space mean/σ of prompt length.
    pub prompt_mu: f64,
    pub prompt_sigma: f64,
    /// ln-space mean/σ of output length.
    pub output_mu: f64,
    pub output_sigma: f64,
    pub min_prompt: usize,
    pub max_prompt: usize,
    pub min_output: usize,
    pub max_output: usize,
    pub vocab: usize,
    /// Zipf exponent of prompt-token frequencies.
    pub zipf_s: f64,
    pub seed: u64,
    /// Motif length for loopy prompts (0 = off): prompts cycle a small
    /// per-request token motif, so trailing n-grams recur and self-drafting
    /// (prompt-lookup) speculative decoding gets realistic hit rates —
    /// the shape of templated/agentic traffic.
    pub motif_len: usize,
}

impl TraceConfig {
    /// ShareGPT-shaped defaults scaled to a maximum sequence length.
    pub fn sharegpt_like(num_requests: usize, vocab: usize, max_seq: usize) -> TraceConfig {
        let cap = max_seq.saturating_sub(2);
        TraceConfig {
            num_requests,
            prompt_mu: 3.6, // median ~ 36 tokens
            prompt_sigma: 0.9,
            output_mu: 4.6, // median ~ 100 tokens
            output_sigma: 0.7,
            min_prompt: 4,
            max_prompt: (cap / 2).max(5),
            min_output: 8,
            max_output: (cap / 2).max(9),
            vocab,
            zipf_s: 1.05,
            seed: 0xC0FFEE,
            motif_len: 0,
        }
    }

    /// Tiny trace for tests.
    pub fn tiny(num_requests: usize, vocab: usize) -> TraceConfig {
        TraceConfig {
            num_requests,
            prompt_mu: 2.0,
            prompt_sigma: 0.4,
            output_mu: 2.0,
            output_sigma: 0.3,
            min_prompt: 2,
            max_prompt: 12,
            min_output: 2,
            max_output: 10,
            vocab,
            zipf_s: 1.1,
            seed: 7,
            motif_len: 0,
        }
    }

    /// Loopy (motif-cycled) prompts at ShareGPT-like lengths: the
    /// speculative-decoding-friendly workload (templated / agentic traffic
    /// repeats n-grams, which prompt-lookup drafting exploits). Used by
    /// `serve_e2e --loopy`.
    pub fn loopy(num_requests: usize, vocab: usize, max_seq: usize) -> TraceConfig {
        TraceConfig {
            motif_len: 4,
            ..Self::sharegpt_like(num_requests, vocab, max_seq)
        }
    }
}

/// A synthesized trace: requests plus their nominal output lengths.
#[derive(Debug, Clone)]
pub struct Trace {
    pub requests: Vec<Request>,
    /// Target output length per request (max_new_tokens mirrors it; kept
    /// separately for the simulator which doesn't run the engine).
    pub output_lens: Vec<usize>,
}

/// Generate a closed-loop trace (all arrivals at t = 0).
pub fn generate(cfg: &TraceConfig) -> Trace {
    let mut rng = Philox::new(cfg.seed);
    let zipf = crate::rng::zipf::ZipfMandelbrot::zipf(cfg.vocab, cfg.zipf_s);
    let mut requests = Vec::with_capacity(cfg.num_requests);
    let mut output_lens = Vec::with_capacity(cfg.num_requests);
    for id in 0..cfg.num_requests {
        let plen = (rng.next_lognormal(cfg.prompt_mu, cfg.prompt_sigma) as usize)
            .clamp(cfg.min_prompt, cfg.max_prompt);
        let olen = (rng.next_lognormal(cfg.output_mu, cfg.output_sigma) as usize)
            .clamp(cfg.min_output, cfg.max_output);
        let prompt: Vec<u32> = if cfg.motif_len > 0 {
            // loopy prompt: cycle a per-request motif with occasional fresh
            // tokens, so trailing n-grams repeat (templated-traffic shape)
            let motif: Vec<u32> = (0..cfg.motif_len)
                .map(|_| zipf.sample(&mut rng) as u32)
                .collect();
            (0..plen)
                .map(|i| {
                    if rng.next_f64() < 0.15 {
                        zipf.sample(&mut rng) as u32
                    } else {
                        motif[i % motif.len()]
                    }
                })
                .collect()
        } else {
            (0..plen).map(|_| zipf.sample(&mut rng) as u32).collect()
        };
        let mut req = Request::new(id as u64, prompt, olen);
        req.params = SamplingParams {
            seed: id as u64,
            ..SamplingParams::production_default()
        };
        requests.push(req);
        output_lens.push(olen);
    }
    Trace { requests, output_lens }
}

/// Stamp Poisson arrivals at `rate` req/s onto a trace (open loop).
/// `rate = f64::INFINITY` leaves everything at t = 0 (saturation).
pub fn poisson_arrivals(trace: &mut Trace, rate: f64, seed: u64) {
    TrafficPattern::Steady.stamp(trace, rate, seed);
}

/// Conversation-tree workload parameters (DESIGN.md §13): the prefix-
/// cache-friendly traffic shape. Each conversation opens with one of a
/// small, Zipf-popular set of *shared system prompts*; turn `n+1`'s
/// prompt extends turn `n`'s full history (its prompt plus a synthesized
/// assistant reply plus fresh user tokens), so the shared prefix between
/// consecutive turns — and across conversations with the same system
/// prompt — grows every turn. With `branch_p > 0` a turn occasionally
/// extends an *earlier* snapshot instead of the latest (a user edit /
/// retry), turning the chain into a genuine tree whose siblings share
/// their parent's prefix.
#[derive(Debug, Clone)]
pub struct ConvConfig {
    pub conversations: usize,
    /// Turns per conversation, uniform in `1..=max_turns` (a conversation
    /// also ends early when the next turn would overflow `max_context`).
    pub max_turns: usize,
    /// Distinct system prompts shared across conversations.
    pub system_prompts: usize,
    /// Tokens per system prompt.
    pub system_len: usize,
    /// User-turn length, uniform in `user_min..=user_max`.
    pub user_min: usize,
    pub user_max: usize,
    /// Assistant-reply length, uniform in `reply_min..=reply_max` — both
    /// the turn's `max_new_tokens` and the synthesized history the next
    /// turn extends.
    pub reply_min: usize,
    pub reply_max: usize,
    /// Hard cap on any turn's prompt length plus reply (fit `max_seq`).
    pub max_context: usize,
    pub vocab: usize,
    /// Zipf exponent of system-prompt popularity.
    pub zipf_s: f64,
    /// Probability a turn branches from an earlier history snapshot.
    pub branch_p: f64,
    pub seed: u64,
    /// Mean conversation-start rate in conversations/s (∞ = everything at
    /// t = 0, closed loop).
    pub start_rate: f64,
    /// Mean think time between consecutive turns, seconds (exponential;
    /// only meaningful with a finite `start_rate`).
    pub think_s: f64,
}

impl ConvConfig {
    /// Tiny conversations for tests (fits a 96-token max_seq).
    pub fn tiny(conversations: usize, vocab: usize) -> ConvConfig {
        ConvConfig {
            conversations,
            max_turns: 4,
            system_prompts: 3,
            system_len: 8,
            user_min: 2,
            user_max: 6,
            reply_min: 2,
            reply_max: 5,
            max_context: 88,
            vocab,
            zipf_s: 1.2,
            branch_p: 0.0,
            seed: 7,
            start_rate: f64::INFINITY,
            think_s: 0.0,
        }
    }

    /// ShareGPT-shaped multi-turn sessions scaled to `max_seq`.
    pub fn sharegpt_like(conversations: usize, vocab: usize, max_seq: usize) -> ConvConfig {
        let cap = max_seq.saturating_sub(2);
        ConvConfig {
            conversations,
            max_turns: 6,
            system_prompts: 8,
            system_len: (cap / 8).clamp(8, 64),
            user_min: 4,
            user_max: (cap / 8).max(5),
            reply_min: 8,
            reply_max: (cap / 6).max(9),
            max_context: cap,
            vocab,
            zipf_s: 1.1,
            branch_p: 0.1,
            seed: 0xC0FFEE,
            start_rate: f64::INFINITY,
            think_s: 0.0,
        }
    }
}

/// Generate a conversation-tree trace (see [`ConvConfig`]). Deterministic
/// in the config; request ids are sequential in emission order, which is
/// turn order within each conversation. Arrivals are stamped inline —
/// conversation starts are Poisson at `start_rate`, later turns follow
/// their predecessor by an exponential think time — because the arrival
/// process is coupled to the structure (a turn cannot precede its
/// parent), unlike the structure-blind [`TrafficPattern::stamp`].
pub fn conversations(cfg: &ConvConfig) -> Trace {
    assert!(cfg.system_prompts >= 1 && cfg.max_turns >= 1);
    assert!(cfg.user_min >= 1 && cfg.user_min <= cfg.user_max);
    assert!(cfg.reply_min >= 1 && cfg.reply_min <= cfg.reply_max);
    assert!(
        cfg.system_len + cfg.user_max + cfg.reply_max <= cfg.max_context,
        "max_context too small for even a single turn"
    );
    let mut rng = Philox::new(cfg.seed);
    let tokens = ZipfMandelbrot::zipf(cfg.vocab, 1.05);
    let popularity = ZipfMandelbrot::zipf(cfg.system_prompts, cfg.zipf_s);
    let systems: Vec<Vec<u32>> = (0..cfg.system_prompts)
        .map(|_| (0..cfg.system_len).map(|_| tokens.sample(&mut rng) as u32).collect())
        .collect();
    let mut requests = Vec::new();
    let mut output_lens = Vec::new();
    let mut id = 0u64;
    let mut t = 0.0f64;
    for _ in 0..cfg.conversations {
        if cfg.start_rate.is_finite() {
            t += rng.next_exp() / cfg.start_rate;
        }
        // History snapshots: [0] is the bare system prompt; each emitted
        // turn appends its full context + synthesized reply.
        let mut histories: Vec<Vec<u32>> =
            vec![systems[popularity.sample(&mut rng)].clone()];
        let turns = 1 + rng.next_below(cfg.max_turns as u64) as usize;
        let mut turn_t = t;
        for turn in 0..turns {
            let parent = if histories.len() > 1 && rng.next_f64() < cfg.branch_p {
                rng.next_below(histories.len() as u64) as usize
            } else {
                histories.len() - 1
            };
            let ulen = cfg.user_min
                + rng.next_below((cfg.user_max - cfg.user_min + 1) as u64) as usize;
            let olen = cfg.reply_min
                + rng.next_below((cfg.reply_max - cfg.reply_min + 1) as u64) as usize;
            if histories[parent].len() + ulen + olen > cfg.max_context {
                break; // context budget exhausted: the conversation ends
            }
            let mut prompt = histories[parent].clone();
            prompt.extend((0..ulen).map(|_| tokens.sample(&mut rng) as u32));
            if turn > 0 && cfg.start_rate.is_finite() {
                turn_t += rng.next_exp() * cfg.think_s;
            }
            let mut req = Request::new(id, prompt.clone(), olen);
            req.arrival = if cfg.start_rate.is_finite() { turn_t } else { 0.0 };
            req.params =
                SamplingParams { seed: id, ..SamplingParams::production_default() };
            requests.push(req);
            output_lens.push(olen);
            id += 1;
            // Synthesize the assistant reply into the next snapshot. (The
            // engine's real reply differs, so live prefix reuse comes from
            // the prompt-side prefix — which still grows every turn.)
            let mut next = prompt;
            next.extend((0..olen).map(|_| tokens.sample(&mut rng) as u32));
            histories.push(next);
        }
    }
    Trace { requests, output_lens }
}

/// Open-loop arrival process shape (see the module docs). All patterns
/// preserve the requested *mean* rate; they differ in clustering.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TrafficPattern {
    /// Homogeneous Poisson arrivals.
    Steady,
    /// Two-state MMPP: ON phases (mean `mean_on_s` seconds) arrive at
    /// `burst_factor ×` the base rate; OFF phases (mean `mean_off_s`) at a
    /// compensating low rate so the long-run mean equals `rate`. The
    /// factor is internally capped at `0.95 / duty-cycle` — beyond that no
    /// positive OFF rate can preserve the mean.
    Burst {
        burst_factor: f64,
        mean_on_s: f64,
        mean_off_s: f64,
    },
    /// Flash crowds: Poisson-spaced arrival trains with Zipf(`s`)-distributed
    /// sizes in `1..=max_train`; a train's requests arrive simultaneously.
    Zipf { s: f64, max_train: usize },
}

impl TrafficPattern {
    /// Parse a CLI name (`steady` | `burst` | `zipf`) with scenario defaults.
    pub fn parse(name: &str) -> Option<TrafficPattern> {
        Some(match name.to_ascii_lowercase().as_str() {
            "steady" | "poisson" => TrafficPattern::Steady,
            "burst" | "bursty" | "mmpp" => TrafficPattern::Burst {
                burst_factor: 4.0,
                mean_on_s: 0.5,
                mean_off_s: 2.0,
            },
            "zipf" | "flash" => TrafficPattern::Zipf { s: 1.5, max_train: 64 },
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            TrafficPattern::Steady => "steady",
            TrafficPattern::Burst { .. } => "burst",
            TrafficPattern::Zipf { .. } => "zipf",
        }
    }

    /// Stamp arrival times onto `trace` at mean `rate` req/s. Deterministic
    /// in `(self, rate, seed)`; `rate = ∞` puts everything at t = 0.
    pub fn stamp(self, trace: &mut Trace, rate: f64, seed: u64) {
        if !rate.is_finite() {
            for r in &mut trace.requests {
                r.arrival = 0.0;
            }
            return;
        }
        assert!(rate > 0.0, "arrival rate must be positive");
        let mut rng = Philox::new(seed);
        match self {
            TrafficPattern::Steady => {
                let mut t = 0.0;
                for r in &mut trace.requests {
                    t += rng.next_exp() / rate;
                    r.arrival = t;
                }
            }
            TrafficPattern::Burst { burst_factor, mean_on_s, mean_off_s } => {
                assert!(burst_factor >= 1.0 && mean_on_s > 0.0 && mean_off_s > 0.0);
                let p_on = mean_on_s / (mean_on_s + mean_off_s);
                // The mean-rate contract requires the ON phases alone to
                // carry less than the whole mean (p_on·f < 1): cap the
                // effective factor so the compensating OFF rate stays
                // positive and the long-run mean is preserved exactly.
                let f = burst_factor.min(0.95 / p_on);
                let rate_on = rate * f;
                let rate_off = (rate - p_on * rate_on) / (1.0 - p_on);
                debug_assert!(rate_off > 0.0);
                let mut t = 0.0f64;
                let mut on = true;
                let mut phase_end = rng.next_exp() * mean_on_s;
                for r in &mut trace.requests {
                    loop {
                        let cur = if on { rate_on } else { rate_off };
                        let dt = rng.next_exp() / cur;
                        if t + dt <= phase_end {
                            t += dt;
                            break;
                        }
                        // cross into the next phase; the exponential's
                        // memorylessness lets us redraw beyond the boundary
                        t = phase_end;
                        on = !on;
                        let mean = if on { mean_on_s } else { mean_off_s };
                        phase_end = t + rng.next_exp() * mean;
                    }
                    r.arrival = t;
                }
            }
            TrafficPattern::Zipf { s, max_train } => {
                assert!(max_train >= 1);
                let z = ZipfMandelbrot::zipf(max_train, s);
                // epoch rate preserves the mean request rate
                let mean_train: f64 =
                    (0..max_train).map(|r| (r + 1) as f64 * z.pmf(r)).sum();
                let epoch_rate = rate / mean_train.max(1.0);
                let mut t = 0.0f64;
                let mut left_in_train = 0usize;
                for r in &mut trace.requests {
                    if left_in_train == 0 {
                        t += rng.next_exp() / epoch_rate;
                        left_in_train = z.sample(&mut rng) + 1;
                    }
                    r.arrival = t;
                    left_in_train -= 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_respects_bounds() {
        let cfg = TraceConfig::sharegpt_like(200, 32_000, 256);
        let trace = generate(&cfg);
        assert_eq!(trace.requests.len(), 200);
        for (r, &olen) in trace.requests.iter().zip(&trace.output_lens) {
            assert!(r.prompt.len() >= cfg.min_prompt && r.prompt.len() <= cfg.max_prompt);
            assert!(olen >= cfg.min_output && olen <= cfg.max_output);
            assert_eq!(r.max_new_tokens, olen);
            assert!(r.prompt.iter().all(|&t| (t as usize) < cfg.vocab));
        }
    }

    #[test]
    fn trace_is_deterministic() {
        let cfg = TraceConfig::tiny(50, 1000);
        let a = generate(&cfg);
        let b = generate(&cfg);
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
        }
    }

    #[test]
    fn loopy_prompts_repeat_their_trailing_ngrams() {
        // The property speculative self-drafting relies on: in a loopy
        // trace, the trailing bigram of most prompts has an earlier
        // occurrence for prompt-lookup to match.
        let loopy = generate(&TraceConfig::loopy(200, 10_000, 256));
        let plain = generate(&TraceConfig::sharegpt_like(200, 10_000, 256));
        let hit_rate = |t: &Trace| {
            let mut hits = 0usize;
            let mut eligible = 0usize;
            for r in &t.requests {
                let p = &r.prompt;
                if p.len() < 4 {
                    continue;
                }
                eligible += 1;
                let tail = (p[p.len() - 2], p[p.len() - 1]);
                if (1..p.len() - 1).any(|i| (p[i - 1], p[i]) == tail) {
                    hits += 1;
                }
            }
            hits as f64 / eligible.max(1) as f64
        };
        let (l, p) = (hit_rate(&loopy), hit_rate(&plain));
        assert!(l > 0.6, "loopy bigram hit rate {l}");
        assert!(l > p, "loopy {l} must beat plain {p}");
        // still a valid trace: lengths, vocab bounds
        for r in &loopy.requests {
            assert!(r.prompt.iter().all(|&t| (t as usize) < 10_000));
        }
    }

    #[test]
    fn prompt_tokens_are_zipf_skewed() {
        let cfg = TraceConfig::sharegpt_like(500, 10_000, 256);
        let trace = generate(&cfg);
        let mut low = 0usize;
        let mut total = 0usize;
        for r in &trace.requests {
            for &t in &r.prompt {
                total += 1;
                if (t as usize) < 1000 {
                    low += 1;
                }
            }
        }
        // top 10% of ids should carry well over half the tokens
        assert!(low as f64 / total as f64 > 0.5, "{low}/{total}");
    }

    #[test]
    fn poisson_arrivals_monotone_with_mean_rate() {
        let cfg = TraceConfig::tiny(2000, 1000);
        let mut trace = generate(&cfg);
        poisson_arrivals(&mut trace, 50.0, 3);
        let times: Vec<f64> = trace.requests.iter().map(|r| r.arrival).collect();
        assert!(times.windows(2).all(|w| w[1] >= w[0]));
        let span = times.last().unwrap();
        let rate = times.len() as f64 / span;
        assert!((rate - 50.0).abs() < 5.0, "rate {rate}");
    }

    #[test]
    fn infinite_rate_means_saturation() {
        let cfg = TraceConfig::tiny(10, 1000);
        let mut trace = generate(&cfg);
        poisson_arrivals(&mut trace, f64::INFINITY, 3);
        assert!(trace.requests.iter().all(|r| r.arrival == 0.0));
    }

    /// Squared coefficient of variation of inter-arrival gaps: 1 for a
    /// Poisson process, > 1 for clustered (bursty) arrivals.
    fn cv2(times: &[f64]) -> f64 {
        let gaps: Vec<f64> = times.windows(2).map(|w| w[1] - w[0]).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var =
            gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
        var / (mean * mean)
    }

    fn stamped(pattern: TrafficPattern, n: usize, rate: f64, seed: u64) -> Vec<f64> {
        let cfg = TraceConfig::tiny(n, 1000);
        let mut trace = generate(&cfg);
        pattern.stamp(&mut trace, rate, seed);
        trace.requests.iter().map(|r| r.arrival).collect()
    }

    #[test]
    fn traffic_patterns_parse_roundtrip() {
        for name in ["steady", "burst", "zipf"] {
            let p = TrafficPattern::parse(name).unwrap();
            assert_eq!(p.name(), name);
        }
        assert_eq!(TrafficPattern::parse("mmpp").unwrap().name(), "burst");
        assert!(TrafficPattern::parse("nope").is_none());
    }

    #[test]
    fn all_patterns_preserve_mean_rate_and_monotonicity() {
        for name in ["steady", "burst", "zipf"] {
            let p = TrafficPattern::parse(name).unwrap();
            let times = stamped(p, 4000, 50.0, 11);
            assert!(times.windows(2).all(|w| w[1] >= w[0]), "{name} not sorted");
            let rate = times.len() as f64 / times.last().unwrap();
            assert!(
                (rate - 50.0).abs() < 50.0 * 0.3,
                "{name}: mean rate {rate} (want ≈50)"
            );
        }
    }

    #[test]
    fn burst_and_zipf_are_overdispersed() {
        let steady = cv2(&stamped(TrafficPattern::parse("steady").unwrap(), 4000, 50.0, 5));
        let burst = cv2(&stamped(TrafficPattern::parse("burst").unwrap(), 4000, 50.0, 5));
        let zipf = cv2(&stamped(TrafficPattern::parse("zipf").unwrap(), 4000, 50.0, 5));
        assert!((steady - 1.0).abs() < 0.25, "Poisson CV² ≈ 1, got {steady}");
        assert!(burst > 1.5, "burst CV² {burst} should exceed Poisson");
        assert!(zipf > 1.5, "zipf CV² {zipf} should exceed Poisson");
    }

    #[test]
    fn burst_mean_rate_holds_for_extreme_duty_cycles() {
        // p_on · factor ≥ 1 would need a negative OFF rate; the factor cap
        // must preserve the long-run mean instead of silently inflating it.
        let p = TrafficPattern::Burst { burst_factor: 8.0, mean_on_s: 1.0, mean_off_s: 1.0 };
        let times = stamped(p, 4000, 10.0, 21);
        let rate = times.len() as f64 / times.last().unwrap();
        assert!((rate - 10.0).abs() < 10.0 * 0.3, "mean rate {rate} (want ≈10)");
    }

    #[test]
    fn zipf_trains_arrive_simultaneously() {
        let times = stamped(TrafficPattern::parse("zipf").unwrap(), 2000, 50.0, 9);
        let ties = times.windows(2).filter(|w| w[1] == w[0]).count();
        assert!(
            ties > times.len() / 10,
            "flash crowds must share timestamps ({ties} ties)"
        );
    }

    /// Split a branch-free conversation trace back into conversations:
    /// within one conversation each turn's prompt strictly extends its
    /// predecessor's, so a prompt that does NOT start with the previous
    /// prompt opens a new conversation.
    fn conversation_spans(trace: &Trace) -> Vec<std::ops::Range<usize>> {
        let mut spans = Vec::new();
        let mut start = 0usize;
        for i in 1..trace.requests.len() {
            let prev = &trace.requests[i - 1].prompt;
            let cur = &trace.requests[i].prompt;
            if !(cur.len() > prev.len() && cur[..prev.len()] == prev[..]) {
                spans.push(start..i);
                start = i;
            }
        }
        spans.push(start..trace.requests.len());
        spans
    }

    #[test]
    fn conv_turns_extend_prior_history() {
        let cfg = ConvConfig::tiny(30, 1000);
        let trace = conversations(&cfg);
        assert!(trace.requests.len() >= 30, "every conversation has a turn");
        let spans = conversation_spans(&trace);
        assert_eq!(spans.len(), 30, "one span per conversation");
        for span in spans {
            for i in span.clone().skip(1) {
                let prev = &trace.requests[i - 1];
                let cur = &trace.requests[i];
                // the extension includes the synthesized reply: strictly
                // more than the previous prompt, by at least reply_min +
                // user_min tokens
                assert!(
                    cur.prompt.len() >= prev.prompt.len() + cfg.reply_min + cfg.user_min
                );
            }
            for i in span {
                let r = &trace.requests[i];
                assert!(r.prompt.len() + r.max_new_tokens <= cfg.max_context);
                assert!(r.prompt.iter().all(|&t| (t as usize) < cfg.vocab));
            }
        }
    }

    #[test]
    fn conv_system_prompts_are_zipf_shared() {
        let cfg = ConvConfig::tiny(100, 1000);
        let trace = conversations(&cfg);
        let spans = conversation_spans(&trace);
        let mut counts: std::collections::HashMap<Vec<u32>, usize> =
            std::collections::HashMap::new();
        for span in spans {
            let head = trace.requests[span.start].prompt[..cfg.system_len].to_vec();
            *counts.entry(head).or_insert(0) += 1;
        }
        assert!(
            counts.len() <= cfg.system_prompts,
            "at most {} distinct system prompts, got {}",
            cfg.system_prompts,
            counts.len()
        );
        // Zipf popularity: the head system prompt dominates a uniform share
        let max = counts.values().max().unwrap();
        assert!(
            *max as f64 > 100.0 / cfg.system_prompts as f64,
            "most popular system prompt used {max}×"
        );
    }

    #[test]
    fn conv_is_deterministic() {
        let cfg = ConvConfig::tiny(20, 1000);
        let (a, b) = (conversations(&cfg), conversations(&cfg));
        assert_eq!(a.requests.len(), b.requests.len());
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
            assert_eq!(x.arrival, y.arrival);
        }
    }

    #[test]
    fn conv_think_time_orders_turns_within_a_conversation() {
        let mut cfg = ConvConfig::tiny(25, 1000);
        cfg.start_rate = 10.0;
        cfg.think_s = 0.2;
        let trace = conversations(&cfg);
        for span in conversation_spans(&trace) {
            let arrivals: Vec<f64> =
                span.map(|i| trace.requests[i].arrival).collect();
            assert!(
                arrivals.windows(2).all(|w| w[1] >= w[0]),
                "turns arrive in order: {arrivals:?}"
            );
            assert!(arrivals[0] > 0.0, "open-loop starts are stamped");
        }
    }

    #[test]
    fn conv_branching_builds_trees_that_share_parent_prefixes() {
        let mut cfg = ConvConfig::tiny(40, 1000);
        cfg.branch_p = 0.5;
        cfg.max_turns = 6;
        let trace = conversations(&cfg);
        // every prompt still extends SOME earlier context: its system head
        // is one of the generated system prompts, and sibling branches
        // agree with their parent up to the branch point — weak but
        // structure-free check: each prompt shares its first system_len
        // tokens with at least one other request (Zipf sharing) while
        // branch points keep total requests above the chain-only count
        assert!(trace.requests.len() >= 40);
        for r in &trace.requests {
            assert!(r.prompt.len() >= cfg.system_len + cfg.user_min);
        }
    }

    #[test]
    fn patterns_are_deterministic_in_seed() {
        for name in ["steady", "burst", "zipf"] {
            let p = TrafficPattern::parse(name).unwrap();
            assert_eq!(stamped(p, 200, 30.0, 3), stamped(p, 200, 30.0, 3), "{name}");
            assert_ne!(stamped(p, 200, 30.0, 3), stamped(p, 200, 30.0, 4), "{name}");
        }
    }
}
