//! Workload synthesis: ShareGPT-like request traces and arrival processes.
//!
//! The paper replays a fixed prompt set sampled from ShareGPT with early
//! stopping disabled (§7.1). ShareGPT is unavailable offline, so we
//! synthesize traces with the published shape of that dataset: log-normal
//! prompt lengths (median ≈ tens of tokens, long tail) and log-normal
//! output lengths (median ≈ 200), plus Poisson arrivals for the open-loop
//! load–latency sweep (Figure 6).

use crate::decision::SamplingParams;
use crate::engine::Request;
use crate::rng::Philox;

/// Trace generation parameters.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub num_requests: usize,
    /// ln-space mean/σ of prompt length.
    pub prompt_mu: f64,
    pub prompt_sigma: f64,
    /// ln-space mean/σ of output length.
    pub output_mu: f64,
    pub output_sigma: f64,
    pub min_prompt: usize,
    pub max_prompt: usize,
    pub min_output: usize,
    pub max_output: usize,
    pub vocab: usize,
    /// Zipf exponent of prompt-token frequencies.
    pub zipf_s: f64,
    pub seed: u64,
}

impl TraceConfig {
    /// ShareGPT-shaped defaults scaled to a maximum sequence length.
    pub fn sharegpt_like(num_requests: usize, vocab: usize, max_seq: usize) -> TraceConfig {
        let cap = max_seq.saturating_sub(2);
        TraceConfig {
            num_requests,
            prompt_mu: 3.6, // median ~ 36 tokens
            prompt_sigma: 0.9,
            output_mu: 4.6, // median ~ 100 tokens
            output_sigma: 0.7,
            min_prompt: 4,
            max_prompt: (cap / 2).max(5),
            min_output: 8,
            max_output: (cap / 2).max(9),
            vocab,
            zipf_s: 1.05,
            seed: 0xC0FFEE,
        }
    }

    /// Tiny trace for tests.
    pub fn tiny(num_requests: usize, vocab: usize) -> TraceConfig {
        TraceConfig {
            num_requests,
            prompt_mu: 2.0,
            prompt_sigma: 0.4,
            output_mu: 2.0,
            output_sigma: 0.3,
            min_prompt: 2,
            max_prompt: 12,
            min_output: 2,
            max_output: 10,
            vocab,
            zipf_s: 1.1,
            seed: 7,
        }
    }
}

/// A synthesized trace: requests plus their nominal output lengths.
#[derive(Debug, Clone)]
pub struct Trace {
    pub requests: Vec<Request>,
    /// Target output length per request (max_new_tokens mirrors it; kept
    /// separately for the simulator which doesn't run the engine).
    pub output_lens: Vec<usize>,
}

/// Generate a closed-loop trace (all arrivals at t = 0).
pub fn generate(cfg: &TraceConfig) -> Trace {
    let mut rng = Philox::new(cfg.seed);
    let zipf = crate::rng::zipf::ZipfMandelbrot::zipf(cfg.vocab, cfg.zipf_s);
    let mut requests = Vec::with_capacity(cfg.num_requests);
    let mut output_lens = Vec::with_capacity(cfg.num_requests);
    for id in 0..cfg.num_requests {
        let plen = (rng.next_lognormal(cfg.prompt_mu, cfg.prompt_sigma) as usize)
            .clamp(cfg.min_prompt, cfg.max_prompt);
        let olen = (rng.next_lognormal(cfg.output_mu, cfg.output_sigma) as usize)
            .clamp(cfg.min_output, cfg.max_output);
        let prompt: Vec<u32> = (0..plen)
            .map(|_| zipf.sample(&mut rng) as u32)
            .collect();
        let mut req = Request::new(id as u64, prompt, olen);
        req.params = SamplingParams {
            seed: id as u64,
            ..SamplingParams::production_default()
        };
        requests.push(req);
        output_lens.push(olen);
    }
    Trace { requests, output_lens }
}

/// Stamp Poisson arrivals at `rate` req/s onto a trace (open loop).
/// `rate = f64::INFINITY` leaves everything at t = 0 (saturation).
pub fn poisson_arrivals(trace: &mut Trace, rate: f64, seed: u64) {
    if !rate.is_finite() {
        for r in &mut trace.requests {
            r.arrival = 0.0;
        }
        return;
    }
    assert!(rate > 0.0);
    let mut rng = Philox::new(seed);
    let mut t = 0.0;
    for r in &mut trace.requests {
        t += rng.next_exp() / rate;
        r.arrival = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_respects_bounds() {
        let cfg = TraceConfig::sharegpt_like(200, 32_000, 256);
        let trace = generate(&cfg);
        assert_eq!(trace.requests.len(), 200);
        for (r, &olen) in trace.requests.iter().zip(&trace.output_lens) {
            assert!(r.prompt.len() >= cfg.min_prompt && r.prompt.len() <= cfg.max_prompt);
            assert!(olen >= cfg.min_output && olen <= cfg.max_output);
            assert_eq!(r.max_new_tokens, olen);
            assert!(r.prompt.iter().all(|&t| (t as usize) < cfg.vocab));
        }
    }

    #[test]
    fn trace_is_deterministic() {
        let cfg = TraceConfig::tiny(50, 1000);
        let a = generate(&cfg);
        let b = generate(&cfg);
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
        }
    }

    #[test]
    fn prompt_tokens_are_zipf_skewed() {
        let cfg = TraceConfig::sharegpt_like(500, 10_000, 256);
        let trace = generate(&cfg);
        let mut low = 0usize;
        let mut total = 0usize;
        for r in &trace.requests {
            for &t in &r.prompt {
                total += 1;
                if (t as usize) < 1000 {
                    low += 1;
                }
            }
        }
        // top 10% of ids should carry well over half the tokens
        assert!(low as f64 / total as f64 > 0.5, "{low}/{total}");
    }

    #[test]
    fn poisson_arrivals_monotone_with_mean_rate() {
        let cfg = TraceConfig::tiny(2000, 1000);
        let mut trace = generate(&cfg);
        poisson_arrivals(&mut trace, 50.0, 3);
        let times: Vec<f64> = trace.requests.iter().map(|r| r.arrival).collect();
        assert!(times.windows(2).all(|w| w[1] >= w[0]));
        let span = times.last().unwrap();
        let rate = times.len() as f64 / span;
        assert!((rate - 50.0).abs() < 5.0, "rate {rate}");
    }

    #[test]
    fn infinite_rate_means_saturation() {
        let cfg = TraceConfig::tiny(10, 1000);
        let mut trace = generate(&cfg);
        poisson_arrivals(&mut trace, f64::INFINITY, 3);
        assert!(trace.requests.iter().all(|r| r.arrival == 0.0));
    }
}
