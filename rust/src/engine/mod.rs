//! Serving engine: requests, preemptive continuous-batching scheduler
//! (chunked prefill, recompute-on-resume, SLO-aware admission), paged KV
//! accounting, tokenizer, and the pipelined executor ([`Engine`]) that
//! runs end to end over any [`DataPlane`] — PJRT in production
//! ([`PjrtEngine`]), [`synthetic::SyntheticRuntime`] for artifact-free
//! tests and the overlap harness.

pub mod engine;
pub mod kvcache;
pub mod request;
pub mod scheduler;
pub mod synthetic;
pub mod tokenizer;

pub use engine::{DataPlane, Engine, PjrtEngine};
pub use kvcache::KvAllocator;
pub use synthetic::SyntheticRuntime;
pub use request::{Phase, Request, Sequence};
pub use scheduler::{
    CommitOutcome, MultiCommitOutcome, Scheduler, SchedulerConfig, SchedulingOutput, SlotPlan,
};
