//! Serving engine: requests, preemptive continuous-batching scheduler
//! (chunked prefill, recompute-on-resume, SLO-aware admission), paged KV
//! accounting, tokenizer, and the PJRT-backed end-to-end engine.

pub mod engine;
pub mod kvcache;
pub mod request;
pub mod scheduler;
pub mod tokenizer;

pub use engine::PjrtEngine;
pub use kvcache::KvAllocator;
pub use request::{Phase, Request, Sequence};
pub use scheduler::{
    CommitOutcome, MultiCommitOutcome, Scheduler, SchedulerConfig, SchedulingOutput, SlotPlan,
};
