//! Request and sequence state.

use crate::decision::grammar::GrammarConstraint;
use crate::decision::SamplingParams;
use std::sync::Arc;

/// An inference request as admitted by the engine.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub params: SamplingParams,
    pub max_new_tokens: usize,
    /// Stop token (engine-level EOS detection). None = run to max_new_tokens.
    pub eos_token: Option<u32>,
    /// Arrival time, seconds from engine start (0 for closed-loop).
    pub arrival: f64,
    /// Structured-decoding constraint (§9 extension iii): samplers restrict
    /// every decision to tokens that keep this grammar alive.
    pub grammar: Option<Arc<GrammarConstraint>>,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize) -> Request {
        Request {
            id,
            prompt,
            params: SamplingParams::production_default(),
            max_new_tokens,
            eos_token: None,
            arrival: 0.0,
            grammar: None,
        }
    }
}

/// Lifecycle phase of a running sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Feeding prompt tokens (no sampling needed yet). A preempted sequence
    /// re-enters this phase on resume: recompute-on-resume replays the
    /// prompt *and* the already-generated tokens through the forward pass.
    Prefill,
    /// Generating output tokens (each iteration samples one).
    Decode,
    Finished,
}

/// A scheduled sequence occupying a batch slot.
#[derive(Debug)]
pub struct Sequence {
    pub request: Request,
    /// Tokens generated so far. For a resumed sequence this starts non-empty
    /// (the tokens generated before preemption, replayed during recompute).
    pub output: Vec<u32>,
    /// Next position to feed (number of tokens already in the KV cache).
    pub position: usize,
    pub phase: Phase,
    /// Batch slot currently occupied.
    pub slot: usize,
    /// Times this sequence has been preempted (KV-pressure evictions).
    pub preemptions: u32,
}

impl Sequence {
    pub fn new(request: Request, slot: usize) -> Sequence {
        Self::resumed(request, Vec::new(), slot, 0)
    }

    /// Rebuild a preempted sequence for recompute-on-resume: the KV cache was
    /// released, so it restarts at position 0 and replays `prompt ⧺ output`
    /// before sampling its next (new) token. Token-stream determinism holds
    /// because decisions are keyed by (seed, seq, decode iteration), and the
    /// decode iteration continues from `output.len()`.
    pub fn resumed(request: Request, output: Vec<u32>, slot: usize, preemptions: u32) -> Sequence {
        Self::resumed_at(request, output, slot, preemptions, 0)
    }

    /// Like [`Self::resumed`], but with the first `start` known tokens
    /// already resident in the KV cache (a prefix-cache hit, DESIGN.md §13):
    /// prefill begins at the first uncached token. `start` must leave at
    /// least one known token to feed — the forward pass at the last known
    /// token produces the logits the next decision samples from, so a hit
    /// can skip *recompute* but never the decision-bearing step.
    pub fn resumed_at(
        request: Request,
        output: Vec<u32>,
        slot: usize,
        preemptions: u32,
        start: usize,
    ) -> Sequence {
        assert!(!request.prompt.is_empty(), "empty prompt");
        assert!(
            start < request.prompt.len() + output.len(),
            "cached prefix must leave at least one known token to feed"
        );
        Sequence { request, output, position: start, phase: Phase::Prefill, slot, preemptions }
    }

    /// The token to feed at the current position.
    pub fn input_token(&self) -> u32 {
        let p = &self.request.prompt;
        if self.position < p.len() {
            p[self.position]
        } else {
            self.output[self.position - p.len()]
        }
    }

    /// Whether this iteration's forward output needs a sampling decision:
    /// true once every *known* token is in (the logits at the last known
    /// token predict the next, unknown one). For a fresh sequence the known
    /// tokens are the prompt; for a resumed sequence they also include the
    /// replayed pre-preemption output, so recompute never re-samples tokens
    /// it already holds.
    pub fn needs_decision(&self) -> bool {
        self.phase != Phase::Finished && self.position + 1 >= self.total_len()
    }

    /// Tokens not yet fed to the forward pass, counting the one at the
    /// current position: `1` for a decoding sequence, up to the whole
    /// remaining prompt (plus replayed output) during prefill. The chunked-
    /// prefill scheduler spends its per-iteration token budget on this.
    pub fn remaining_known(&self) -> usize {
        self.total_len().saturating_sub(self.position).max(1)
    }

    /// Total tokens resident in the KV cache after feeding `position`.
    pub fn kv_len(&self) -> usize {
        self.position + 1
    }

    /// Record a sampled token; returns true if the sequence finished.
    pub fn commit_token(&mut self, token: u32) -> bool {
        debug_assert!(self.needs_decision());
        self.output.push(token);
        self.phase = Phase::Decode;
        let eos = self.request.eos_token == Some(token);
        if eos || self.output.len() >= self.request.max_new_tokens {
            self.phase = Phase::Finished;
            return true;
        }
        false
    }

    /// Advance to the next position (after the forward step).
    pub fn advance(&mut self) {
        self.position += 1;
    }

    /// Advance past a prefill chunk of `n` tokens fed in one iteration.
    pub fn advance_by(&mut self, n: usize) {
        debug_assert!(self.position + n <= self.total_len(), "advance past known tokens");
        self.position += n;
    }

    pub fn total_len(&self) -> usize {
        self.request.prompt.len() + self.output.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(prompt: usize, max_new: usize) -> Request {
        Request::new(1, (0..prompt as u32).collect(), max_new)
    }

    #[test]
    fn prefill_feeds_prompt_tokens() {
        let mut s = Sequence::new(req(3, 4), 0);
        assert_eq!(s.input_token(), 0);
        assert!(!s.needs_decision()); // position 0 of 3-token prompt
        s.advance();
        assert_eq!(s.input_token(), 1);
        assert!(!s.needs_decision());
        s.advance();
        assert_eq!(s.input_token(), 2);
        assert!(s.needs_decision()); // last prompt token -> sample now
    }

    #[test]
    fn decode_feeds_generated_tokens() {
        let mut s = Sequence::new(req(2, 4), 0);
        s.advance(); // fed token 0; now at last prompt token
        assert!(s.needs_decision());
        assert!(!s.commit_token(77));
        s.advance();
        assert_eq!(s.input_token(), 77);
        assert_eq!(s.phase, Phase::Decode);
        assert_eq!(s.kv_len(), 3);
    }

    #[test]
    fn finishes_on_max_tokens() {
        let mut s = Sequence::new(req(1, 2), 0);
        assert!(!s.commit_token(5));
        s.advance();
        assert!(s.commit_token(6));
        assert_eq!(s.phase, Phase::Finished);
        assert_eq!(s.output, vec![5, 6]);
    }

    #[test]
    fn finishes_on_eos() {
        let mut r = req(1, 100);
        r.eos_token = Some(9);
        let mut s = Sequence::new(r, 0);
        assert!(!s.commit_token(5));
        s.advance();
        assert!(s.commit_token(9));
        assert_eq!(s.phase, Phase::Finished);
    }

    #[test]
    fn single_token_prompt_samples_immediately() {
        let s = Sequence::new(req(1, 4), 0);
        assert!(s.needs_decision());
    }

    #[test]
    fn resumed_sequence_replays_output_without_sampling() {
        // 3-token prompt, 2 tokens generated before preemption. Recompute
        // feeds positions 0..4 (prompt + both outputs) with a decision only
        // at the last known token.
        let mut s = Sequence::resumed(req(3, 5), vec![40, 41], 0, 1);
        assert_eq!(s.preemptions, 1);
        let expected = [0u32, 1, 2, 40, 41];
        for (p, &tok) in expected.iter().enumerate() {
            assert_eq!(s.input_token(), tok, "position {p}");
            let last = p + 1 == expected.len();
            assert_eq!(s.needs_decision(), last, "position {p}");
            if !last {
                s.advance();
            }
        }
        // the decision at the last replayed token is a *new* third output
        assert!(!s.commit_token(42));
        assert_eq!(s.output, vec![40, 41, 42]);
        assert_eq!(s.phase, Phase::Decode);
    }

    #[test]
    fn resumed_at_starts_at_first_uncached_token() {
        // 6-token prompt, first 4 cached (prefix-cache hit): feed positions
        // 4 and 5 only, with the decision at the last known token as usual.
        let mut s = Sequence::resumed_at(req(6, 4), Vec::new(), 0, 0, 4);
        assert_eq!(s.position, 4);
        assert_eq!(s.input_token(), 4);
        assert_eq!(s.remaining_known(), 2);
        assert!(!s.needs_decision());
        s.advance();
        assert!(s.needs_decision());
        assert!(!s.commit_token(9));
        assert_eq!(s.output, vec![9]);
    }

    #[test]
    #[should_panic(expected = "at least one known token")]
    fn resumed_at_rejects_fully_cached_context() {
        let _ = Sequence::resumed_at(req(4, 4), Vec::new(), 0, 0, 4);
    }

    #[test]
    fn resumed_sequence_finish_counts_pre_preemption_tokens() {
        let mut s = Sequence::resumed(req(2, 3), vec![7, 8], 0, 2);
        s.advance(); // pos 1 (last prompt token)
        s.advance(); // pos 2 (output[0])
        s.advance(); // pos 3 (output[1] = last known)
        assert!(s.needs_decision());
        assert!(s.commit_token(9), "3rd token reaches max_new_tokens");
        assert_eq!(s.phase, Phase::Finished);
    }

    #[test]
    fn chunked_advance_matches_remaining() {
        let mut s = Sequence::new(req(8, 4), 0);
        assert_eq!(s.remaining_known(), 8);
        s.advance_by(5);
        assert_eq!(s.remaining_known(), 3);
        assert!(!s.needs_decision());
        s.advance_by(2);
        assert!(s.needs_decision(), "last prompt token reached");
        assert_eq!(s.remaining_known(), 1);
    }
}
