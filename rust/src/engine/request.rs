//! Request and sequence state.

use crate::decision::grammar::GrammarConstraint;
use crate::decision::SamplingParams;
use std::sync::Arc;

/// An inference request as admitted by the engine.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub params: SamplingParams,
    pub max_new_tokens: usize,
    /// Stop token (engine-level EOS detection). None = run to max_new_tokens.
    pub eos_token: Option<u32>,
    /// Arrival time, seconds from engine start (0 for closed-loop).
    pub arrival: f64,
    /// Structured-decoding constraint (§9 extension iii): samplers restrict
    /// every decision to tokens that keep this grammar alive.
    pub grammar: Option<Arc<GrammarConstraint>>,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize) -> Request {
        Request {
            id,
            prompt,
            params: SamplingParams::production_default(),
            max_new_tokens,
            eos_token: None,
            arrival: 0.0,
            grammar: None,
        }
    }
}

/// Lifecycle phase of a running sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Feeding prompt tokens (no sampling needed yet).
    Prefill,
    /// Generating output tokens (each iteration samples one).
    Decode,
    Finished,
}

/// A scheduled sequence occupying a batch slot.
#[derive(Debug)]
pub struct Sequence {
    pub request: Request,
    /// Tokens generated so far.
    pub output: Vec<u32>,
    /// Next position to feed (number of tokens already in the KV cache).
    pub position: usize,
    pub phase: Phase,
    /// Batch slot currently occupied.
    pub slot: usize,
}

impl Sequence {
    pub fn new(request: Request, slot: usize) -> Sequence {
        assert!(!request.prompt.is_empty(), "empty prompt");
        Sequence { request, output: Vec::new(), position: 0, phase: Phase::Prefill, slot }
    }

    /// The token to feed at the current position.
    pub fn input_token(&self) -> u32 {
        let p = &self.request.prompt;
        if self.position < p.len() {
            p[self.position]
        } else {
            self.output[self.position - p.len()]
        }
    }

    /// Whether this iteration's forward output needs a sampling decision
    /// (true once the whole prompt is in: the logits at the last prompt
    /// token predict the first output token).
    pub fn needs_decision(&self) -> bool {
        self.phase != Phase::Finished && self.position + 1 >= self.request.prompt.len()
    }

    /// Total tokens resident in the KV cache after feeding `position`.
    pub fn kv_len(&self) -> usize {
        self.position + 1
    }

    /// Record a sampled token; returns true if the sequence finished.
    pub fn commit_token(&mut self, token: u32) -> bool {
        debug_assert!(self.needs_decision());
        self.output.push(token);
        self.phase = Phase::Decode;
        let eos = self.request.eos_token == Some(token);
        if eos || self.output.len() >= self.request.max_new_tokens {
            self.phase = Phase::Finished;
            return true;
        }
        false
    }

    /// Advance to the next position (after the forward step).
    pub fn advance(&mut self) {
        self.position += 1;
    }

    pub fn total_len(&self) -> usize {
        self.request.prompt.len() + self.output.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(prompt: usize, max_new: usize) -> Request {
        Request::new(1, (0..prompt as u32).collect(), max_new)
    }

    #[test]
    fn prefill_feeds_prompt_tokens() {
        let mut s = Sequence::new(req(3, 4), 0);
        assert_eq!(s.input_token(), 0);
        assert!(!s.needs_decision()); // position 0 of 3-token prompt
        s.advance();
        assert_eq!(s.input_token(), 1);
        assert!(!s.needs_decision());
        s.advance();
        assert_eq!(s.input_token(), 2);
        assert!(s.needs_decision()); // last prompt token -> sample now
    }

    #[test]
    fn decode_feeds_generated_tokens() {
        let mut s = Sequence::new(req(2, 4), 0);
        s.advance(); // fed token 0; now at last prompt token
        assert!(s.needs_decision());
        assert!(!s.commit_token(77));
        s.advance();
        assert_eq!(s.input_token(), 77);
        assert_eq!(s.phase, Phase::Decode);
        assert_eq!(s.kv_len(), 3);
    }

    #[test]
    fn finishes_on_max_tokens() {
        let mut s = Sequence::new(req(1, 2), 0);
        assert!(!s.commit_token(5));
        s.advance();
        assert!(s.commit_token(6));
        assert_eq!(s.phase, Phase::Finished);
        assert_eq!(s.output, vec![5, 6]);
    }

    #[test]
    fn finishes_on_eos() {
        let mut r = req(1, 100);
        r.eos_token = Some(9);
        let mut s = Sequence::new(r, 0);
        assert!(!s.commit_token(5));
        s.advance();
        assert!(s.commit_token(9));
        assert_eq!(s.phase, Phase::Finished);
    }

    #[test]
    fn single_token_prompt_samples_immediately() {
        let s = Sequence::new(req(1, 4), 0);
        assert!(s.needs_decision());
    }
}
