//! The serving engine: data plane + disaggregated decision plane, run as a
//! **pipelined executor with in-flight microbatches**.
//!
//! Per microbatch iteration (paper §4.2 ⓪–⑥):
//! ⓪ the scheduler emits a microbatch-scoped scheduling output
//!   ([`Scheduler::plan_mb`]: admissions + slot plan);
//! ① the runtime executes the decode step (GPU compute);
//! ② ③ logits are transposed to vocabulary-major and "written" as
//!   TP-sharded slices into the shared view ([`crate::tensor::shard_row_major`]);
//! ④ ⑤ the sampler service reads its sequence partitions zero-copy and runs
//!   SHVS with the kernel-produced precompute;
//! ⑥ decisions are committed, finished sequences retired.
//!
//! **Overlap (DESIGN.md §8).** The slot space is split into
//! `cfg.n_microbatches` interleaved microbatches. With `cfg.overlap` on,
//! step ④⑤ is *asynchronous*: the engine submits microbatch A's
//! [`IterationTask`] and immediately launches microbatch B's forward;
//! A's decisions are reaped (non-blocking completion queue keyed by task
//! id) and land as **pending commits**, applied just before A's next plan —
//! a two-phase commit that preserves exact preemption/spec-verify
//! semantics. Decision latency is hidden whenever it is shorter than a
//! forward; the recorder's stage timeline measures exactly how much
//! ([`crate::metrics::OverlapReport`]). Committed token streams are
//! bit-identical to the synchronous engine for any `(n_microbatches,
//! overlap, m, spec_k)`: decisions are keyed by (seed, seq, decode
//! iteration) and logits depend only on the sequence's own slot context,
//! so interleaving changes timing, never tokens.
//!
//! The `GpuEpilogue` variant instead samples inline on the engine thread
//! right after the forward — the serial last-stage epilogue the paper's
//! baselines exhibit — so both architectures are measurable end to end on
//! the same host.
//!
//! **Speculative decoding** (`cfg.spec_k > 0`, DESIGN.md §7): each
//! iteration the engine drafts up to `k` tokens per decision-needing slot
//! (deterministic self-drafting), runs `k` extra chained decode steps
//! feeding the draft tokens, and ships all `k+1` logits views to the
//! decision plane in one [`IterationTask`]. Samplers verify the window
//! (accept-prefix + corrected bonus token, exact target distribution) and
//! the scheduler commits 1..=k+1 tokens via `commit_multi`. Rejected draft
//! positions leave stale KV rows that the next feed at the same position
//! deterministically overwrites — the same idempotence argument as
//! prefill-paused slots.

use crate::config::{DecisionVariant, EngineConfig};
use crate::decision::draft::DraftProposer;
use crate::decision::penalties::BatchHistory;
use crate::decision::service::{ColumnMeta, IterationTask, SamplerService};
use crate::decision::verify::{verify_window, GrammarSlot, Verdict};
use crate::decision::{DecisionPipeline, HotVocab, Precompute, SeqHandle};
use crate::engine::kvcache::KvAllocator;
use crate::engine::request::Request;
use crate::engine::scheduler::{Scheduler, SchedulerConfig};
use crate::fault::{FaultKind, FaultPlan};
use crate::metrics::{OverlapReport, Recorder};
use crate::runtime::{ModelRuntime, StepOutput};
use crate::tensor::{shard_row_major, ShardedLogits, Tensor2};
use crate::trace;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

/// The engine's view of a data plane: a static-batch decode-step model
/// with per-slot KV state. [`ModelRuntime`] (the PJRT/AOT path) is the
/// production implementation; [`super::synthetic::SyntheticRuntime`] is a
/// context-faithful in-process stand-in for tests, benches, and the
/// overlap harness, letting the *same executor code* run without
/// artifacts.
pub trait DataPlane {
    /// Static batch size B (slot count).
    fn batch(&self) -> usize;
    /// Vocabulary size V.
    fn vocab(&self) -> usize;
    /// Max sequence length (KV time dimension).
    fn max_seq(&self) -> usize;
    /// Execute one decode step for the whole batch: `ids[b]` is the token
    /// fed for slot b, `positions[b]` its 0-based position, `tau[b]` the
    /// temperature for SHVS precompute. The KV write at `(b, positions[b])`
    /// must be a deterministic function of the fed token (idempotent
    /// re-feeds), which recompute-on-resume and paused-slot feeding rely on.
    fn step(
        &mut self,
        ids: &[i32],
        positions: &[i32],
        tau: &[f32],
    ) -> crate::Result<StepOutput>;
    /// Zero one slot's KV rows (sequence retired or preempted).
    fn reset_kv_slot(&mut self, slot: usize);
    /// Install the hot-vocab mask for SHVS precompute (no-op where
    /// unsupported).
    fn install_hot_vocab(&mut self, _hot: &HotVocab) {}
    /// Whether [`Self::restore_prefix`] is implemented. The engine enables
    /// prefix-cache-aware admission (DESIGN.md §13) only when true: a
    /// cache hit skips re-feeding the cached tokens, so the data plane must
    /// be able to re-install their KV rows without a forward pass.
    fn supports_prefix_restore(&self) -> bool {
        false
    }
    /// Install a cached token prefix into a slot's KV (prefix-cache hit):
    /// afterwards the slot's rows `0..tokens.len()` must be exactly what
    /// feeding `tokens` through [`Self::step`] would have produced, so
    /// logits — and therefore token streams — are bit-identical with the
    /// cache on or off. Returns false where unsupported.
    fn restore_prefix(&mut self, _slot: usize, _tokens: &[u32]) -> bool {
        false
    }
}

impl DataPlane for ModelRuntime {
    fn batch(&self) -> usize {
        ModelRuntime::batch(self)
    }
    fn vocab(&self) -> usize {
        ModelRuntime::vocab(self)
    }
    fn max_seq(&self) -> usize {
        ModelRuntime::max_seq(self)
    }
    fn step(
        &mut self,
        ids: &[i32],
        positions: &[i32],
        tau: &[f32],
    ) -> crate::Result<StepOutput> {
        ModelRuntime::step(self, ids, positions, tau)
    }
    fn reset_kv_slot(&mut self, slot: usize) {
        ModelRuntime::reset_kv_slot(self, slot)
    }
    fn install_hot_vocab(&mut self, hot: &HotVocab) {
        self.set_hot_vocab(hot)
    }
}

/// A microbatch's submitted-but-unreaped decision task.
struct InFlight {
    task_id: u64,
}

/// End-to-end engine over a loaded data plane. `PjrtEngine` is the
/// PJRT-backed alias every production caller uses.
pub struct Engine<D: DataPlane> {
    runtime: D,
    scheduler: Scheduler,
    /// Decision-plane service. `Arc` so a cluster can share one sampler
    /// pool across data-parallel replicas (DESIGN.md §9); a standalone
    /// engine holds the only reference and tears it down at shutdown.
    service: Option<Arc<SamplerService>>,
    /// High bits OR-ed into every submitted task id so a shared pool's
    /// completion queue never aliases two replicas' iterations (0 for a
    /// standalone engine — the ids are then exactly the plan counter).
    task_base: u64,
    inline_pipe: Option<DecisionPipeline>,
    inline_hist: HashMap<u64, BatchHistory>,
    /// Live registrations with the decision plane, by sequence id. The
    /// handle IS the registration (lock-free replay record): every task
    /// that carries the sequence's column clones it in, and retiring means
    /// removing + flagging it — a later re-register mints a fresh record,
    /// which is the staleness guard for in-flight tasks.
    seq_handles: HashMap<u64, SeqHandle>,
    tp_shards: usize,
    pub recorder: Recorder,
    t0: Instant,
    variant: DecisionVariant,
    max_seq_len: usize,
    /// Speculative window size (0 = off) and its draft proposer.
    spec_k: usize,
    proposer: DraftProposer,
    /// Pipelined-executor state: microbatch count, overlap switch, idle
    /// poll quantum, the round-robin cursor, and per-microbatch in-flight
    /// tasks / pending (reaped, unapplied) commits.
    n_mb: usize,
    overlap: bool,
    idle_poll_us: u64,
    cursor: usize,
    inflight: Vec<Option<InFlight>>,
    pending: Vec<Vec<(usize, u64, Verdict)>>,
    /// Chaos-injection schedule (engine-level fault domains): sampler
    /// kills (including the legacy `poison@` syntax, now a clean kill of
    /// worker 0) fired as the plan counter passes each event's trigger
    /// (DESIGN.md §10).
    faults: FaultPlan,
    /// Speculation tallies over windows with at least one draft token:
    /// draft tokens accepted *and committed* / proposed, total committed
    /// tokens (accepted + bonus, after any EOS/max_new/preemption cut),
    /// and window count. Committed tokens per decision step =
    /// spec_committed / spec_windows.
    pub spec_accepted: u64,
    pub spec_proposed: u64,
    pub spec_committed: u64,
    pub spec_windows: u64,
    /// (fast_path_hits, decisions) tallies from the service at shutdown.
    pub sampler_stats: Vec<crate::decision::service::SamplerStats>,
}

/// The PJRT-backed production engine.
pub type PjrtEngine = Engine<ModelRuntime>;

impl<D: DataPlane> Engine<D> {
    /// Build from a loaded runtime. `cfg.sampler.variant` picks the decision
    /// plane; `cfg.parallel.tp` controls the simulated logits sharding;
    /// `cfg.n_microbatches`/`cfg.overlap` configure the pipelined executor.
    pub fn new(runtime: D, cfg: &EngineConfig, hot: Option<Arc<HotVocab>>) -> Self {
        // Clock against the shared trace epoch so recorder intervals, trace
        // spans, and log timestamps all live on one timeline (DESIGN.md §14).
        Self::build(runtime, cfg, hot, trace::epoch(), None, 0)
    }

    /// Like [`Self::new`] but timestamping against a caller-provided epoch,
    /// so several replicas' recorders (and their sampler services) share
    /// one timeline and [`Recorder::merge`] unions comparable intervals.
    pub fn with_epoch(
        runtime: D,
        cfg: &EngineConfig,
        hot: Option<Arc<HotVocab>>,
        epoch: Instant,
    ) -> Self {
        Self::build(runtime, cfg, hot, epoch, None, 0)
    }

    /// Build a replica over a *shared* sampler pool (DESIGN.md §9): the
    /// engine submits into `service` instead of spawning its own workers,
    /// namespacing every task id with `task_base` (callers use
    /// `(replica + 1) << 48`) so the pool's completion queue never aliases
    /// two replicas' iterations. The engine adopts the pool's epoch as its
    /// t0, putting the whole fleet's stage intervals on one timeline. The
    /// pool owner — not this engine — shuts the service down.
    pub fn with_shared_service(
        runtime: D,
        cfg: &EngineConfig,
        hot: Option<Arc<HotVocab>>,
        service: Arc<SamplerService>,
        task_base: u64,
    ) -> Self {
        assert!(
            !matches!(cfg.sampler.variant, DecisionVariant::GpuEpilogue),
            "the inline GPU-epilogue baseline has no service to share"
        );
        let epoch = service.epoch();
        Self::build(runtime, cfg, hot, epoch, Some(service), task_base)
    }

    fn build(
        mut runtime: D,
        cfg: &EngineConfig,
        hot: Option<Arc<HotVocab>>,
        t0: Instant,
        shared: Option<Arc<SamplerService>>,
        task_base: u64,
    ) -> Self {
        let b = runtime.batch();
        let max_seq_len = runtime.max_seq();
        // KV accounting: by default enough blocks for every slot to run to
        // max_seq (never preempts); `cfg.kv_blocks` over-commits the cache
        // production-style, engaging KV-pressure preemption. Floor at one
        // max-length sequence so a lone sequence can always run.
        let full = b * max_seq_len.div_ceil(cfg.kv_block_tokens);
        let blocks = if cfg.kv_blocks == 0 {
            full
        } else {
            cfg.kv_blocks.max(max_seq_len.div_ceil(cfg.kv_block_tokens) + 1)
        };
        let kv = KvAllocator::new(blocks, cfg.kv_block_tokens);
        let scheduler = Scheduler::with_config(
            b,
            kv,
            max_seq_len,
            SchedulerConfig {
                prefill_token_budget: cfg.prefill_token_budget,
                // the AOT decode-step data plane feeds one token per slot
                // per step, so chunks realize as budgeted prefill concurrency
                max_prefill_chunk: 1,
                // radix prefix reuse (§13) needs the data plane to restore
                // cached KV rows; planes that can't (the PJRT path today)
                // keep the exact pre-cache behavior
                prefix_cache: cfg.prefix_cache && runtime.supports_prefix_restore(),
                ..SchedulerConfig::default()
            },
        );
        if let Some(h) = &hot {
            runtime.install_hot_vocab(h);
        }
        let variant = cfg.sampler.variant;
        let inline_epilogue = matches!(variant, DecisionVariant::GpuEpilogue);
        // Samplers timestamp against the engine's t0 so decision and GPU
        // stage intervals share one timeline. With a shared pool the t0 IS
        // the pool's epoch (asserted by `with_shared_service`).
        let (service, inline_pipe) = if let Some(svc) = shared {
            (Some(svc), None)
        } else if inline_epilogue {
            (
                None,
                Some(DecisionPipeline::new(
                    DecisionVariant::NaiveCpu,
                    None,
                    cfg.sampler.seed,
                )),
            )
        } else {
            (
                Some(Arc::new(SamplerService::start_with_epoch(
                    &cfg.sampler,
                    hot,
                    max_seq_len,
                    t0,
                ))),
                None,
            )
        };
        let n_mb = cfg.n_microbatches.clamp(1, b.max(1));
        Engine {
            runtime,
            scheduler,
            service,
            task_base,
            inline_pipe,
            inline_hist: HashMap::new(),
            seq_handles: HashMap::new(),
            tp_shards: cfg.parallel.tp.max(1),
            recorder: Recorder::new(),
            t0,
            variant,
            max_seq_len,
            spec_k: cfg.spec_k,
            proposer: DraftProposer::new(),
            n_mb,
            overlap: cfg.overlap,
            idle_poll_us: cfg.idle_poll_us,
            cursor: 0,
            inflight: (0..n_mb).map(|_| None).collect(),
            pending: (0..n_mb).map(|_| Vec::new()).collect(),
            faults: cfg.faults.clone(),
            spec_accepted: 0,
            spec_proposed: 0,
            spec_committed: 0,
            spec_windows: 0,
            sampler_stats: Vec::new(),
        }
    }

    pub fn variant(&self) -> DecisionVariant {
        self.variant
    }

    /// Microbatch count the executor is running with.
    pub fn n_microbatches(&self) -> usize {
        self.n_mb
    }

    fn now(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    /// Submit a request (its `arrival` field gates open-loop admission).
    pub fn submit(&mut self, req: Request) {
        assert!(
            req.prompt.len() + 2 < self.max_seq_len,
            "prompt ({} tokens) too long for model (max_seq {})",
            req.prompt.len(),
            self.max_seq_len
        );
        self.recorder.on_arrival(req.id, req.arrival.max(0.0));
        self.scheduler.submit(req);
    }

    /// Submit a sequence that already generated `output` tokens elsewhere —
    /// a cluster's prefill→decode handoff (DESIGN.md §9). The scheduler
    /// replays `prompt ⧺ output` through the forward (recompute, exactly
    /// the preemption-resume path) and decisions continue from iteration
    /// `output.len()`, so the combined stream is bit-identical to one
    /// engine running the sequence end to end. `req.arrival` carries the
    /// handoff time plus the simulated KV-transfer cost.
    pub fn submit_resumed(&mut self, req: Request, output: Vec<u32>) {
        assert!(
            req.prompt.len() + output.len() + 2 < self.max_seq_len,
            "resumed context ({} tokens) too long for model (max_seq {})",
            req.prompt.len() + output.len(),
            self.max_seq_len
        );
        self.recorder.on_arrival(req.id, req.arrival.max(0.0));
        self.scheduler.submit_resumed(req, output);
    }

    /// Waiting + running sequences — the router's queue-depth heartbeat.
    pub fn queue_depth(&self) -> usize {
        self.scheduler.waiting_len() + self.scheduler.running_len()
    }

    /// Allocatable KV blocks right now — the router's KV-pressure
    /// heartbeat. Counts free blocks plus index-held blocks no live
    /// sequence references (reclaimable on demand), so a warm prefix cache
    /// doesn't read as pressure.
    pub fn kv_free_blocks(&self) -> usize {
        self.scheduler.kv.available_blocks()
    }

    /// Prefix-cache counters (lookups, hits, evictions, …; §13).
    pub fn prefix_stats(&self) -> crate::engine::kvcache::PrefixStats {
        self.scheduler.kv.stats
    }

    /// Prefill tokens fed through forward passes (decode steps excluded).
    pub fn prefill_computed_tokens(&self) -> u64 {
        self.scheduler.prefill_computed_tokens()
    }

    /// Known tokens skipped at admission via cached prefixes.
    pub fn prefill_skipped_tokens(&self) -> u64 {
        self.scheduler.prefill_skipped_tokens()
    }

    /// Run one executor turn: settle the cursor microbatch's previous
    /// iteration (reap → apply pending commits → advance), then launch its
    /// next forward. Without overlap the new iteration's decisions are
    /// reaped and applied in the same turn — exactly the synchronous
    /// engine. Returns false when fully drained.
    pub fn step_once(&mut self) -> crate::Result<bool> {
        if self.scheduler.is_idle()
            && self.inflight.iter().all(Option::is_none)
            && self.pending.iter().all(Vec::is_empty)
        {
            return Ok(false);
        }
        let mb = self.cursor;
        self.cursor = (self.cursor + 1) % self.n_mb;

        // Phase A (two-phase commit, phase 2): settle this microbatch's
        // previous iteration before planning its next one.
        self.reap_decisions(mb, true)?;
        self.apply_commits(mb);
        self.scheduler.advance_mb(mb, self.n_mb);

        // Phase B: plan + forward + submit the next iteration.
        let launched = self.launch_forward(mb)?;
        if !launched {
            self.idle_wait();
            return Ok(true);
        }
        if self.overlap {
            // Eagerly drain other microbatches' completed decisions
            // (non-blocking): their samplers likely finished under this
            // forward, and reaping now timestamps the hidden work and has
            // the pending commits ready before their turns.
            for other in 0..self.n_mb {
                if other != mb {
                    self.reap_decisions(other, false)?;
                }
            }
        } else {
            // Synchronous mode: block on this iteration's decisions now.
            self.reap_decisions(mb, true)?;
            self.apply_commits(mb);
            self.scheduler.advance_mb(mb, self.n_mb);
        }
        Ok(true)
    }

    /// ⓪–⑤ for one microbatch: plan, register admissions, draft, run the
    /// forward chain, and hand the logits to the decision plane. Returns
    /// false if the microbatch had nothing runnable.
    fn launch_forward(&mut self, mb: usize) -> crate::Result<bool> {
        if self.scheduler.is_idle() {
            return Ok(false);
        }
        let now = self.now();
        let plan = {
            let _sp = trace::span(trace::Kind::EnginePlan, mb as u64, 0);
            self.scheduler.plan_mb(now, mb, self.n_mb)
        };
        if plan.slots.is_empty() {
            // Nothing runnable in this microbatch right now (future
            // arrivals, or all slots owned by other microbatches).
            debug_assert!(plan.admitted.is_empty(), "admitted without a planned slot");
            return Ok(false);
        }

        // Register admissions with the decision plane. A resumed sequence
        // (recompute-on-resume after preemption) re-registers with its
        // pre-preemption output so sampler-local history stays exact. Look
        // the sequence up in the scheduler's slots, not the plan — a newly
        // admitted sequence may already be prefill-paused by the budget.
        for &seq_id in &plan.admitted {
            let seq = (0..self.scheduler.num_slots())
                .find_map(|s| {
                    self.scheduler_seq(s).filter(|q| q.request.id == seq_id)
                })
                .expect("admitted sequence in a slot");
            let prompt = seq.request.prompt.clone();
            let output = seq.output.clone();
            let params = seq.request.params.clone();
            let grammar = seq.request.grammar.clone();
            let (slot, start) = (seq.slot, seq.position);
            if start > 0 {
                // A prefix-cache hit admitted this sequence mid-context:
                // install the cached tokens into the slot's KV before any
                // forward (this microbatch's or a foreign re-feed) reads it.
                let mut ctx: Vec<u32> =
                    prompt.iter().chain(output.iter()).copied().collect();
                ctx.truncate(start);
                assert!(
                    self.runtime.restore_prefix(slot, &ctx),
                    "prefix-cache admission requires a restoring data plane"
                );
            }
            if let Some(svc) = &self.service {
                let handle = svc.register_full(seq_id, &prompt, &output, &params, grammar);
                self.seq_handles.insert(seq_id, handle);
            } else {
                self.inline_hist.insert(
                    seq_id,
                    BatchHistory::with_replay(prompt, &output, self.max_seq_len),
                );
            }
        }

        // Draft proposals for decision-needing slots (speculative windows,
        // indexed by slot; empty = plain single decision).
        let b = self.runtime.batch();
        let vocab = self.runtime.vocab();
        let mut drafts_by_slot: Vec<Vec<u32>> = vec![Vec::new(); b];
        if self.spec_k > 0 {
            for sp in &plan.slots {
                if !sp.needs_decision {
                    continue;
                }
                let seq = self.scheduler_seq(sp.slot).unwrap();
                let k = DraftProposer::clamp_window(
                    self.spec_k,
                    seq.request.max_new_tokens,
                    seq.output.len(),
                    self.max_seq_len,
                    sp.position,
                );
                if k == 0 {
                    continue;
                }
                drafts_by_slot[sp.slot] = self.proposer.propose(
                    seq.request.params.seed,
                    vocab,
                    &seq.request.prompt,
                    &seq.output,
                    k,
                );
            }
        }
        let kmax = drafts_by_slot.iter().map(Vec::len).max().unwrap_or(0);

        // ① GPU compute (decode steps: base + one per draft position).
        let mut ids = vec![0i32; b];
        let mut positions = vec![0i32; b];
        let mut tau = vec![1.0f32; b];
        let mut planned = vec![false; b];
        for sp in &plan.slots {
            debug_assert_eq!(sp.chunk_len, 1, "data plane feeds one token/slot/step");
            ids[sp.slot] = sp.input_token as i32;
            positions[sp.slot] = sp.position as i32;
            planned[sp.slot] = true;
            let seq = self.scheduler_seq(sp.slot).unwrap();
            let t = seq.request.params.temperature;
            tau[sp.slot] = if t > 0.0 { t } else { 1.0 };
        }
        // Occupied slots outside this plan — prefill-paused, or owned by
        // another microbatch (possibly with a decision in flight) — still
        // step through the forward (the static-B graph runs every slot);
        // feeding the *current* (token, position) again is idempotent on
        // the KV cache — the same deterministic write lands there when the
        // slot's own microbatch runs — and its logits are simply ignored
        // this iteration.
        for slot in 0..b {
            if planned[slot] {
                continue;
            }
            if let Some(seq) = self.scheduler_seq(slot) {
                ids[slot] = seq.input_token() as i32;
                positions[slot] = seq.position as i32;
            }
        }
        // ②③ vocabulary-major TP-sharded views (the "logits writes"), one
        // per chain position, with per-view SHVS precompute.
        let mut views: Vec<ShardedLogits> = Vec::with_capacity(kmax + 1);
        let mut pre_views: Vec<Vec<Precompute>> = Vec::with_capacity(kmax + 1);
        let fwd_start = self.now();
        for j in 0..=kmax {
            if j > 0 {
                // Chain step j: speculating slots feed draft token j−1 at
                // the next position; all other slots re-feed their current
                // (token, position) — KV-idempotent, logits ignored.
                for sp in &plan.slots {
                    let draft = &drafts_by_slot[sp.slot];
                    if draft.len() >= j {
                        ids[sp.slot] = draft[j - 1] as i32;
                        positions[sp.slot] = (sp.position + j) as i32;
                    }
                }
            }
            let out = self.runtime.step(&ids, &positions, &tau)?;
            let logits = Tensor2::from_vec(b, vocab, out.logits);
            views.push(shard_row_major(&logits, self.tp_shards));
            pre_views.push(
                out.stats
                    .iter()
                    .map(|s| Precompute {
                        z_max: s[0],
                        // composed from f32 partials (s_hot + s_tail) — an
                        // approximate S_V; the CPU reference path is exact.
                        total_sum: (s[1] + s[2]) as f64,
                        tail_sum: s[2] as f64,
                        tail_max_w: s[3] as f64,
                    })
                    .collect(),
            );
        }
        let fwd_end = self.now();
        self.recorder.on_stage_gpu(mb, fwd_start, fwd_end);
        // Same endpoints as the recorder call: the trace-derived overlap
        // report replays these X events through identical arithmetic.
        trace::complete_s(
            trace::Kind::EngineForward,
            fwd_start,
            fwd_end,
            mb as u64,
            (kmax + 1) as u64,
        );

        // ④⑤ decision plane: one task carries the whole chain. With the
        // service it is submitted asynchronously (reaped later); the
        // GpuEpilogue baseline decides inline, serially, on this thread.
        let mut decision_cols: Vec<ColumnMeta> = Vec::new();
        let mut col_drafts: Vec<Vec<u32>> = Vec::new();
        for sp in plan.slots.iter().filter(|sp| sp.needs_decision) {
            decision_cols.push(ColumnMeta {
                col: sp.slot,
                seq_id: sp.seq_id,
                iteration: sp.decode_iter,
            });
            col_drafts.push(std::mem::take(&mut drafts_by_slot[sp.slot]));
        }
        if decision_cols.is_empty() {
            return Ok(true); // pure prefill chunk: nothing to decide
        }
        if let Some(svc) = &self.service {
            // Chaos injection (DESIGN.md §10): fire engine-level fault
            // events whose trigger the plan counter has passed, strictly
            // BEFORE this iteration's task — so every injected kill is
            // followed by a collect that detects the corpse and recovers
            // it (respawn + registry replay + task resubmission), and no
            // corpse can linger undetected into shutdown. Streams stay
            // bit-identical; the inline GpuEpilogue baseline has no
            // service to kill, so its fault events never fire.
            if !self.faults.is_empty() {
                for kind in self.faults.take_due(plan.iter, |_| true) {
                    match kind {
                        FaultKind::KillSampler { sampler } => {
                            svc.inject_sampler_crash(sampler);
                        }
                        // The lock-free service has no poisonable hot-path
                        // mutex left; the legacy `poison@<iter>` syntax
                        // stays accepted and maps to a clean worker kill
                        // (same recovery machinery, same determinism bar).
                        FaultKind::PoisonLock => svc.inject_sampler_crash(0),
                        // replica kills are the router's fault domain
                        FaultKind::KillReplica { .. } => {}
                    }
                }
            }
            // Namespaced task id: unique fleet-wide under a shared pool
            // (replica id in the high bits), exactly the plan counter for
            // a standalone engine.
            let task_id = self.task_base | plan.iter;
            let recs: Vec<Option<SeqHandle>> = decision_cols
                .iter()
                .map(|meta| self.seq_handles.get(&meta.seq_id).cloned())
                .collect();
            svc.submit(IterationTask {
                iter: task_id,
                mb,
                views,
                columns: Arc::new(decision_cols),
                recs: Arc::new(recs),
                pre: Arc::new(pre_views),
                drafts: Arc::new(col_drafts),
            });
            debug_assert!(self.inflight[mb].is_none(), "one task per microbatch");
            self.inflight[mb] = Some(InFlight { task_id });
        } else {
            // Serial GPU-epilogue baseline: verify inline, single thread,
            // naive full-V kernels (no grammar support on this path,
            // matching the pre-speculation behavior). The epilogue extends
            // the GPU stage (the holdout!), and its decisions go straight
            // to the pending-commit queue.
            let ep_start = self.now();
            let mut decided = Vec::with_capacity(decision_cols.len());
            for (meta, draft) in decision_cols.iter().zip(&col_drafts) {
                let params = self
                    .scheduler
                    .slot(meta.col)
                    .unwrap()
                    .request
                    .params
                    .clone();
                let hist =
                    self.inline_hist.get_mut(&meta.seq_id).expect("registered");
                let pipe = self.inline_pipe.as_mut().unwrap();
                let mut grammar: GrammarSlot = None;
                let verdict = verify_window(
                    pipe,
                    &views,
                    meta.col,
                    draft,
                    hist,
                    &mut grammar,
                    &params,
                    &[],
                    meta.seq_id,
                    meta.iteration,
                );
                decided.push((meta.col, meta.seq_id, verdict));
            }
            let ep_end = self.now();
            self.recorder.on_stage_gpu(mb, ep_start, ep_end);
            trace::complete_s(trace::Kind::EngineForward, ep_start, ep_end, mb as u64, 0);
            self.pending[mb].extend(decided);
        }
        Ok(true)
    }

    /// Reap a microbatch's in-flight decisions into its pending-commit
    /// queue (two-phase commit, phase 1). Blocking reaps account the
    /// engine-thread stall as *exposed* decision time — zero whenever the
    /// decision plane finished under another microbatch's forward.
    fn reap_decisions(&mut self, mb: usize, block: bool) -> crate::Result<bool> {
        let Some(inflight) = self.inflight[mb].as_ref() else {
            return Ok(true);
        };
        let task_id = inflight.task_id;
        let svc = self.service.as_ref().expect("in-flight task implies service");
        let collected = if block {
            let wait_start = self.now();
            let done = svc.collect_checked(task_id)?;
            let wait_end = self.now();
            self.recorder.on_decision_exposed(wait_end - wait_start);
            trace::complete_s(
                trace::Kind::EngineCollectWait,
                wait_start,
                wait_end,
                mb as u64,
                0,
            );
            trace::metrics::COLLECT_WAIT
                .observe_ns(((wait_end - wait_start).max(0.0) * 1e9) as u64);
            Some(done)
        } else {
            svc.try_collect(task_id)?
        };
        let Some(done) = collected else {
            return Ok(false);
        };
        self.inflight[mb] = None;
        debug_assert_eq!(done.mb, mb, "completion queue returned a foreign task");
        for (start, end) in done.intervals {
            self.recorder.on_stage_decision(done.mb, start, end);
        }
        self.pending[mb].extend(done.decisions);
        Ok(true)
    }

    /// ⑥ apply a microbatch's pending commits (two-phase commit, phase 2):
    /// commit + retire (+ preempt under KV pressure). A verdict commits
    /// 1..=k+1 tokens; the scheduler cuts the window at EOS /
    /// max_new_tokens / KV pressure. Runs just before the microbatch's
    /// next plan, so a stale verdict can never alias a re-admitted
    /// sequence (admissions into this microbatch happen only after this).
    fn apply_commits(&mut self, mb: usize) {
        let decided = std::mem::take(&mut self.pending[mb]);
        if decided.is_empty() {
            return;
        }
        let _sp = trace::span(trace::Kind::EngineCommit, mb as u64, decided.len() as u64);
        let t_commit = self.now();
        for (slot, seq_id, verdict) in decided {
            // a commit earlier in this loop — or another microbatch's
            // commit while this one was in flight — may have preempted
            // this slot's sequence; its verdict is discarded and re-derived
            // (identically, by the deterministic RNG keying) after resume
            if self.scheduler.slot(slot).map(|s| s.request.id) != Some(seq_id) {
                continue;
            }
            let outcome =
                self.scheduler
                    .commit_multi_scoped(slot, &verdict.tokens, mb, self.n_mb);
            if verdict.proposed > 0 {
                // tally COMMITTED acceptances: a window cut by EOS / the KV
                // ceiling / self-preemption discards its accepted suffix
                // (re-verified identically after resume), which must not
                // inflate the reported tokens-per-step
                self.spec_windows += 1;
                self.spec_proposed += verdict.proposed as u64;
                self.spec_committed += outcome.committed as u64;
                // committed tokens are accepted drafts except the bonus, so
                // a window cut before its bonus committed exactly
                // `outcome.committed` accepted drafts
                self.spec_accepted += verdict.accepted.min(outcome.committed) as u64;
            }
            // committed tokens survive even a self-preemption (they are
            // carried into the waiting queue for replay), so record them
            for _ in 0..outcome.committed {
                self.recorder.on_token(seq_id, t_commit);
            }
            for (vslot, vid) in outcome.preempted {
                // evicted under KV pressure: drop decision-plane state and
                // clear the data-plane KV slot; the sequence re-enters via
                // `admitted` with recompute-on-resume
                if let Some(handle) = self.seq_handles.remove(&vid) {
                    if let Some(svc) = &self.service {
                        svc.retire(&handle);
                    }
                }
                self.inline_hist.remove(&vid);
                self.runtime.reset_kv_slot(vslot);
            }
            if let Some(finished) = outcome.finished {
                self.recorder.on_finish(finished, t_commit);
                if let Some(handle) = self.seq_handles.remove(&finished) {
                    if let Some(svc) = &self.service {
                        svc.retire(&handle);
                    }
                }
                self.inline_hist.remove(&finished);
                self.runtime.reset_kv_slot(slot);
            }
        }
    }

    /// Idle handling when a microbatch had nothing runnable: sleep only if
    /// *no* microbatch has work (no running slots, no in-flight tasks, no
    /// pending commits), bounded by `idle_poll_us` — and skip the sleep
    /// entirely when the next arrival is already due.
    fn idle_wait(&self) {
        if self.inflight.iter().any(Option::is_some)
            || self.pending.iter().any(|p| !p.is_empty())
            || self.scheduler.running_len() > 0
        {
            return; // another microbatch owns runnable or reapable work
        }
        if self.idle_poll_us == 0 {
            return; // busy-poll mode
        }
        let now = self.now();
        let poll_us = match self.scheduler.next_arrival() {
            Some(t) if t <= now => return, // due now: replan immediately
            Some(t) => {
                let until_us = ((t - now) * 1e6).ceil() as u64;
                self.idle_poll_us.min(until_us.max(1))
            }
            // no future arrivals either: the run is drained, nothing to
            // poll for
            None => return,
        };
        std::thread::sleep(std::time::Duration::from_micros(poll_us));
    }

    /// KV-pressure evictions so far (recompute-on-resume preemptions).
    pub fn preemption_count(&self) -> u64 {
        self.scheduler.preemption_count()
    }

    /// Measured decision/GPU overlap from the recorder's stage timeline.
    pub fn overlap_report(&self) -> OverlapReport {
        self.recorder.overlap_report()
    }

    fn scheduler_seq(&self, slot: usize) -> Option<&crate::engine::request::Sequence> {
        self.scheduler.slot(slot)
    }

    /// Run to completion (closed loop or fully-submitted open loop).
    pub fn run_until_idle(&mut self) -> crate::Result<crate::metrics::ServingSummary> {
        while self.step_once()? {}
        Ok(self.recorder.summary())
    }

    /// Drain finished sequences (outputs).
    pub fn take_finished(&mut self) -> Vec<crate::engine::request::Sequence> {
        self.scheduler.take_finished()
    }

    /// Shut the decision plane down, collecting sampler stats. An engine
    /// over a *shared* pool only drops its reference — the pool owner
    /// joins the workers (and gets the stats + recovery accounting) once
    /// every replica is gone.
    pub fn shutdown(mut self) -> (Recorder, Vec<crate::decision::service::SamplerStats>) {
        if let Some(svc) = self.service.take() {
            if let Ok(svc) = Arc::try_unwrap(svc) {
                let rec = svc.recovery_stats();
                self.recorder.on_recovery(rec.respawns, rec.recovery_s);
                self.sampler_stats = svc.shutdown();
            }
        }
        (self.recorder, self.sampler_stats)
    }
}
