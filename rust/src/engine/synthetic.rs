//! A context-faithful synthetic data plane implementing
//! [`DataPlane`](super::engine::DataPlane), so the *real* pipelined
//! executor — scheduler, two-phase commits, sampler service, overlap
//! accounting — can run end to end without the PJRT artifacts (tests,
//! property sweeps, the `overlap` harness, benches).
//!
//! Faithfulness matters more than realism here. Like the real runtime:
//!
//! - **KV state is per-slot and write-idempotent.** `step` records the fed
//!   token at `(slot, position)`; re-feeding the same (token, position) —
//!   what prefill-paused slots and other in-flight microbatches do — is a
//!   no-op, and recompute-on-resume rebuilds the identical state from
//!   position 0.
//! - **Logits are a function of the slot's fed-token prefix** (a hash of
//!   `kv[slot][0..=pos]` seeds a Zipf-shaped row). A draft chain fed a
//!   rejected token therefore sees *different* logits than the true
//!   continuation, so any bug that commits past the accept point — or
//!   interleaves microbatches incorrectly — breaks stream comparisons
//!   loudly, exactly like the `LogitsGen::ctx_view` churn tests.
//! - **Rows cost real compute** (V hashes per slot per step), so the
//!   forward has genuine wall time for the overlap machinery to hide
//!   decision work under.
//!
//! Stale rows past a rejection point stay in `kv` until overwritten by a
//! later feed at the same position — the same idempotent-overwrite
//! contract as the real KV cache.

use super::engine::DataPlane;
use crate::rng::SplitMix64;
use crate::runtime::StepOutput;

/// In-process synthetic decode-step runtime.
pub struct SyntheticRuntime {
    batch: usize,
    vocab: usize,
    max_seq: usize,
    seed: u64,
    /// Fed token per (slot, position) — the synthetic KV cache.
    kv: Vec<Vec<u32>>,
}

/// One SplitMix64 mix step as a pure keyed hash (the shared mixer from
/// [`crate::rng`], evaluated statelessly).
#[inline]
fn mix(x: u64) -> u64 {
    SplitMix64::new(x).next_u64()
}

impl SyntheticRuntime {
    pub fn new(batch: usize, vocab: usize, max_seq: usize, seed: u64) -> SyntheticRuntime {
        SyntheticRuntime {
            batch,
            vocab,
            max_seq,
            seed,
            kv: vec![Vec::new(); batch],
        }
    }

    /// One logits row for the context `kv[slot][0..=pos]`: a Zipf-shaped
    /// head (low ids likelier, like the AOT model's `lm_bias`) plus
    /// context-keyed noise. Pure function of (seed, context bytes).
    fn row(&self, slot: usize, pos: usize) -> Vec<f32> {
        let mut key = self.seed ^ 0xC0FF_EE00_D15E_A5E5;
        for &t in &self.kv[slot][..=pos] {
            key = mix(key ^ t as u64);
        }
        let mut out = Vec::with_capacity(self.vocab);
        for v in 0..self.vocab {
            let bias = -1.1 * ((1 + v) as f32).ln();
            let h = mix(key ^ (v as u64).wrapping_mul(0x9E37_79B9));
            // uniform in [-2, 2): enough spread for truncation filters to
            // bite without drowning the Zipf head
            let noise = ((h >> 11) as f32 / (1u64 << 53) as f32) * 4.0 - 2.0;
            out.push(bias + noise);
        }
        out
    }
}

impl DataPlane for SyntheticRuntime {
    fn batch(&self) -> usize {
        self.batch
    }
    fn vocab(&self) -> usize {
        self.vocab
    }
    fn max_seq(&self) -> usize {
        self.max_seq
    }

    fn step(
        &mut self,
        ids: &[i32],
        positions: &[i32],
        tau: &[f32],
    ) -> crate::Result<StepOutput> {
        assert_eq!(ids.len(), self.batch);
        assert_eq!(positions.len(), self.batch);
        let _ = tau; // no SHVS precompute on the synthetic plane
        let mut logits = Vec::with_capacity(self.batch * self.vocab);
        for slot in 0..self.batch {
            let pos = positions[slot] as usize;
            assert!(pos < self.max_seq, "position {pos} past max_seq");
            if self.kv[slot].len() <= pos {
                self.kv[slot].resize(pos + 1, 0);
            }
            // Idempotent KV write: same (token, position) → same state.
            self.kv[slot][pos] = ids[slot] as u32;
            logits.extend(self.row(slot, pos));
        }
        Ok(StepOutput { logits, stats: Vec::new() })
    }

    fn reset_kv_slot(&mut self, slot: usize) {
        self.kv[slot].clear();
    }

    fn supports_prefix_restore(&self) -> bool {
        true
    }

    /// Prefix-cache restore (DESIGN.md §13): the synthetic KV state *is*
    /// the fed-token stream, so installing the cached tokens at positions
    /// `0..tokens.len()` reproduces bit-exactly the state `step` would
    /// have built — every later logits row hashes the same prefix.
    fn restore_prefix(&mut self, slot: usize, tokens: &[u32]) -> bool {
        assert!(tokens.len() <= self.max_seq, "restored prefix past max_seq");
        if self.kv[slot].len() < tokens.len() {
            self.kv[slot].resize(tokens.len(), 0);
        }
        self.kv[slot][..tokens.len()].copy_from_slice(tokens);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn refeeding_same_position_is_idempotent() {
        let mut rt = SyntheticRuntime::new(2, 64, 32, 7);
        let a = rt.step(&[3, 5], &[0, 0], &[1.0, 1.0]).unwrap();
        let b = rt.step(&[3, 5], &[0, 0], &[1.0, 1.0]).unwrap();
        assert_eq!(a.logits, b.logits);
    }

    #[test]
    fn logits_depend_on_full_context_not_position_alone() {
        let mut rt = SyntheticRuntime::new(1, 64, 32, 7);
        rt.step(&[3], &[0], &[1.0]).unwrap();
        let after_a = rt.step(&[9], &[1], &[1.0]).unwrap();
        let mut rt2 = SyntheticRuntime::new(1, 64, 32, 7);
        rt2.step(&[4], &[0], &[1.0]).unwrap(); // different prefix
        let after_b = rt2.step(&[9], &[1], &[1.0]).unwrap();
        assert_ne!(after_a.logits, after_b.logits, "context must matter");
    }

    #[test]
    fn recompute_after_reset_rebuilds_identical_state() {
        let mut rt = SyntheticRuntime::new(1, 64, 32, 7);
        rt.step(&[3], &[0], &[1.0]).unwrap();
        let orig = rt.step(&[9], &[1], &[1.0]).unwrap();
        rt.reset_kv_slot(0);
        rt.step(&[3], &[0], &[1.0]).unwrap();
        let replay = rt.step(&[9], &[1], &[1.0]).unwrap();
        assert_eq!(orig.logits, replay.logits);
    }

    #[test]
    fn restore_prefix_matches_fed_state_bit_exactly() {
        // Feeding [3, 9] then stepping at position 2 must equal restoring
        // [3, 9] as a cached prefix and stepping at position 2 — the
        // determinism contract a prefix-cache hit relies on.
        let mut fed = SyntheticRuntime::new(1, 64, 32, 7);
        fed.step(&[3], &[0], &[1.0]).unwrap();
        fed.step(&[9], &[1], &[1.0]).unwrap();
        let want = fed.step(&[5], &[2], &[1.0]).unwrap();
        let mut restored = SyntheticRuntime::new(1, 64, 32, 7);
        assert!(restored.restore_prefix(0, &[3, 9]));
        let got = restored.step(&[5], &[2], &[1.0]).unwrap();
        assert_eq!(want.logits, got.logits);
    }

    #[test]
    fn stale_draft_rows_are_overwritten_by_later_feeds() {
        let mut rt = SyntheticRuntime::new(1, 64, 32, 7);
        rt.step(&[3], &[0], &[1.0]).unwrap();
        // draft chain wrote a (later rejected) token at position 1
        rt.step(&[50], &[1], &[1.0]).unwrap();
        // the committed path re-feeds position 1 with the real token
        let fixed = rt.step(&[9], &[1], &[1.0]).unwrap();
        let mut clean = SyntheticRuntime::new(1, 64, 32, 7);
        clean.step(&[3], &[0], &[1.0]).unwrap();
        let want = clean.step(&[9], &[1], &[1.0]).unwrap();
        assert_eq!(fixed.logits, want.logits, "overwrite must erase the draft");
    }
}
