//! Preemptive continuous-batching scheduler (DESIGN.md §6).
//!
//! Maintains a waiting queue and a fixed set of batch slots (the AOT model's
//! static B). Each iteration it: admits waiting requests into free slots
//! (KV-block admission control with an SLO-aware priority order), allocates
//! a chunked-prefill token budget across prefilling slots, emits the
//! *scheduling output* — the compact per-iteration plan broadcast to GPU
//! workers and samplers (§4.2 step ⓪) — and retires finished sequences.
//!
//! Three production-shaped mechanisms on top of FCFS slot-filling:
//!
//! - **Preemption with recompute-on-resume.** When a decoding sequence needs
//!   a KV block and none is free, the scheduler evicts the latest-arrived
//!   running sequence (vLLM-style LIFO victim), releases its blocks, and
//!   re-queues it at the front of the waiting queue carrying its generated
//!   tokens. On re-admission the sequence replays `prompt ⧺ output` through
//!   the forward pass (recompute) before sampling new tokens. Decisions are
//!   keyed by (seed, seq, decode iteration), so the token stream is
//!   identical with and without preemption, for any sampler count `m`.
//! - **Chunked prefill.** A per-iteration token budget bounds how much
//!   prompt work runs alongside decode, so admission bursts cannot inflate
//!   inter-token latency for already-decoding sequences. Decode slots are
//!   budget-exempt; prefilling slots consume the budget oldest-first and
//!   pause (zero chunk) once it is spent.
//! - **SLO-aware admission.** Waiting requests are scored by
//!   `age / slo_ttft` plus a resume bonus, so under bursty load the oldest
//!   (and previously preempted) requests are admitted first instead of
//!   whatever happens to sit at the queue head.

use super::kvcache::{KvAllocator, KvError};
use super::request::{Phase, Request, Sequence};
use std::collections::VecDeque;

/// Scheduling policy knobs. [`SchedulerConfig::default`] reproduces the
/// non-preemptive FCFS behavior of the original engine except that KV
/// exhaustion preempts instead of panicking.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Per-iteration prefill token budget shared by prefilling slots
    /// (0 = unlimited). Decoding slots are exempt: they always advance.
    pub prefill_token_budget: usize,
    /// Max known tokens one slot may feed per iteration. The PJRT engine's
    /// decode-step data plane feeds one token per slot per step, so it runs
    /// with 1 (the budget then caps *prefill concurrency*); the simulator
    /// models true multi-token chunks.
    pub max_prefill_chunk: usize,
    /// Preempt (recompute-on-resume) on KV exhaustion. When false, running
    /// out of KV blocks mid-decode panics, as allocators must never be
    /// over-committed without an eviction policy.
    pub preemption: bool,
    /// TTFT SLO target in seconds: a waiting request's admission priority
    /// grows by `age / slo_ttft_s`, boosting requests that have waited
    /// longest (starvation control under bursts).
    pub slo_ttft_s: f64,
    /// Additive admission-priority bonus for preempted entries, so resumed
    /// work (which already holds tokens) goes first.
    pub resume_boost: f64,
    /// Prefix-cache-aware admission (DESIGN.md §13): publish finished
    /// prompt blocks into the allocator's radix index, share the longest
    /// cached prefix on admission, and start chunked prefill at the first
    /// uncached token. Preemption-resume takes the same path (recompute
    /// only the tail). Requires a data plane that can restore cached
    /// prefixes into a slot, so the engine gates this on the runtime's
    /// capability; off by default.
    pub prefix_cache: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            prefill_token_budget: 0,
            max_prefill_chunk: 1,
            preemption: true,
            slo_ttft_s: 1.0,
            resume_boost: 1e9,
            prefix_cache: false,
        }
    }
}

/// Per-slot plan entry within a scheduling output.
#[derive(Debug, Clone)]
pub struct SlotPlan {
    pub slot: usize,
    pub seq_id: u64,
    /// First token of this iteration's chunk.
    pub input_token: u32,
    /// Position of `input_token`.
    pub position: usize,
    /// Known tokens fed this iteration (1 for decode; >1 only for prefill
    /// chunks, which the simulator models and the single-token PJRT data
    /// plane never requests).
    pub chunk_len: usize,
    /// Whether this iteration's logits column needs a sampling decision
    /// (true when the chunk reaches the last known token).
    pub needs_decision: bool,
    /// Iteration index local to the sequence (= #generated so far).
    pub decode_iter: u64,
}

/// The compact per-iteration scheduling output.
#[derive(Debug, Clone, Default)]
pub struct SchedulingOutput {
    pub iter: u64,
    /// Active slots this iteration (occupied slots missing from this list
    /// are prefill-paused by the token budget).
    pub slots: Vec<SlotPlan>,
    /// Requests newly admitted this iteration (register with samplers). A
    /// resumed sequence re-appears here; its registration must replay its
    /// pre-preemption output into the sampler-local history.
    pub admitted: Vec<u64>,
}

/// Result of committing one sampled token.
#[derive(Debug, Default)]
pub struct CommitOutcome {
    /// The sequence finished and was retired (caller drops sampler state
    /// and clears the data-plane KV slot).
    pub finished: Option<u64>,
    /// (slot, seq_id) pairs evicted by KV pressure while growing this
    /// sequence. Callers must drop their sampler state; the sequences
    /// re-enter via `admitted` later with recompute-on-resume.
    pub preempted: Vec<(usize, u64)>,
}

/// Result of committing a verified speculative window (1..=k+1 tokens).
#[derive(Debug, Default)]
pub struct MultiCommitOutcome {
    /// Tokens actually committed — the window is cut short by EOS /
    /// max_new_tokens / the KV ceiling mid-window, or by a self-preemption
    /// (the committed prefix survives in the re-queued entry for replay).
    pub committed: usize,
    pub finished: Option<u64>,
    pub preempted: Vec<(usize, u64)>,
}

/// A queued (or re-queued) request.
#[derive(Debug)]
struct WaitingEntry {
    req: Request,
    /// Tokens generated before preemption (empty for fresh requests);
    /// replayed through the forward pass on resume.
    resumed_output: Vec<u32>,
    preemptions: u32,
}

impl WaitingEntry {
    fn known_tokens(&self) -> usize {
        self.req.prompt.len() + self.resumed_output.len()
    }

    /// The full known context (`prompt ⧺ resumed_output`) — the token
    /// stream a prefix-cache lookup matches against on admission.
    fn known_ctx(&self) -> Vec<u32> {
        let mut ctx = self.req.prompt.clone();
        ctx.extend_from_slice(&self.resumed_output);
        ctx
    }
}

/// Scheduler state.
pub struct Scheduler {
    waiting: VecDeque<WaitingEntry>,
    slots: Vec<Option<Sequence>>,
    pub kv: KvAllocator,
    cfg: SchedulerConfig,
    iter: u64,
    max_seq_len: usize,
    finished: Vec<Sequence>,
    /// Chunk planned per slot by the last `plan()` (consumed by `advance`).
    last_chunks: Vec<usize>,
    preemption_count: u64,
    /// Prefill tokens actually planned for forward passes (chunk tokens of
    /// prefilling slots; decode steps excluded).
    prefill_computed: u64,
    /// Known tokens skipped at admission via cached prefixes (§13).
    prefill_skipped: u64,
}

impl Scheduler {
    /// FCFS-compatible scheduler (default policy, single-token chunks).
    pub fn new(num_slots: usize, kv: KvAllocator, max_seq_len: usize) -> Scheduler {
        Self::with_config(num_slots, kv, max_seq_len, SchedulerConfig::default())
    }

    pub fn with_config(
        num_slots: usize,
        kv: KvAllocator,
        max_seq_len: usize,
        cfg: SchedulerConfig,
    ) -> Scheduler {
        Scheduler {
            waiting: VecDeque::new(),
            slots: (0..num_slots).map(|_| None).collect(),
            kv,
            cfg,
            iter: 0,
            max_seq_len,
            finished: Vec::new(),
            last_chunks: vec![0; num_slots],
            preemption_count: 0,
            prefill_computed: 0,
            prefill_skipped: 0,
        }
    }

    pub fn submit(&mut self, req: Request) {
        self.submit_resumed(req, Vec::new());
    }

    /// Submit a sequence carrying tokens generated elsewhere (a cluster's
    /// prefill→decode handoff): admission replays `prompt ⧺ output` through
    /// the forward pass exactly like a preemption resume, and decisions
    /// continue from iteration `output.len()`. Unlike a preemption entry it
    /// gets no resume boost — it queues at its arrival-time priority.
    pub fn submit_resumed(&mut self, req: Request, output: Vec<u32>) {
        self.waiting.push_back(WaitingEntry {
            req,
            resumed_output: output,
            preemptions: 0,
        });
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn running_len(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.running_len() == 0
    }

    pub fn num_slots(&self) -> usize {
        self.slots.len()
    }

    /// Total KV-pressure evictions so far.
    pub fn preemption_count(&self) -> u64 {
        self.preemption_count
    }

    /// Prefill tokens planned for forward passes so far (decode excluded).
    pub fn prefill_computed_tokens(&self) -> u64 {
        self.prefill_computed
    }

    /// Known tokens skipped at admission via cached prefixes so far.
    pub fn prefill_skipped_tokens(&self) -> u64 {
        self.prefill_skipped
    }

    /// Admission priority: waiting-time boost against the TTFT SLO, plus a
    /// large bonus for resumed (previously preempted) entries.
    fn admission_score(&self, e: &WaitingEntry, now: f64) -> f64 {
        let slo = if self.cfg.slo_ttft_s > 0.0 { self.cfg.slo_ttft_s } else { 1.0 };
        let age = (now - e.req.arrival).max(0.0);
        let boost = if e.preemptions > 0 { self.cfg.resume_boost } else { 0.0 };
        age / slo + boost
    }

    /// Earliest arrival time among waiting requests, if any — lets the
    /// engine bound (or skip) its idle poll instead of sleeping a fixed
    /// quantum while an arrival is already due.
    pub fn next_arrival(&self) -> Option<f64> {
        self.waiting
            .iter()
            .map(|e| e.req.arrival)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Admit waiting requests into free slots (KV admission control in
    /// SLO-priority order), allocate the chunked-prefill budget, then emit
    /// this iteration's plan. `now` gates arrivals (open-loop traces).
    pub fn plan(&mut self, now: f64) -> SchedulingOutput {
        self.plan_mb(now, 0, 1)
    }

    /// Microbatch-scoped plan: the slot space is partitioned into `n_mb`
    /// interleaved microbatches (slot `s` belongs to microbatch `s % n_mb`)
    /// and this call admits into, budgets, and plans ONLY microbatch `mb`'s
    /// slots. Other microbatches' planned chunks (`last_chunks`) are left
    /// untouched, so in-flight microbatches advance independently via
    /// [`Self::advance_mb`]. `plan(now)` is the `n_mb = 1` special case.
    ///
    /// The chunked-prefill token budget is per *plan*, i.e. per microbatch
    /// iteration: each microbatch's prefill concurrency is bounded
    /// independently, matching its independent forward pass.
    pub fn plan_mb(&mut self, now: f64, mb: usize, n_mb: usize) -> SchedulingOutput {
        assert!(n_mb >= 1 && mb < n_mb, "microbatch {mb} of {n_mb}");
        let in_mb = |s: usize| s % n_mb == mb;
        let mut admitted = Vec::new();
        while let Some(slot) =
            (0..self.slots.len()).find(|&s| in_mb(s) && self.slots[s].is_none())
        {
            // highest-scoring arrived entry that fits; ties (e.g. the
            // closed-loop case where every score is 0) keep queue order.
            let mut best: Option<(usize, f64)> = None;
            for (i, e) in self.waiting.iter().enumerate() {
                if e.req.arrival > now {
                    continue;
                }
                let fits = if self.cfg.prefix_cache {
                    // Prefix-aware admission control: cached blocks are
                    // shared, not reallocated, so a hit needs fewer fresh
                    // blocks than `can_admit` would demand.
                    self.kv.probe(&e.known_ctx(), e.known_tokens() + 1).fits
                } else {
                    self.kv.can_admit(e.known_tokens() + 1)
                };
                if !fits {
                    continue;
                }
                let score = self.admission_score(e, now);
                if best.is_none_or(|(_, b)| score > b + 1e-12) {
                    best = Some((i, score));
                }
            }
            let Some((i, _)) = best else { break };
            let e = self.waiting.remove(i).unwrap();
            debug_assert!(e.known_tokens() < self.max_seq_len, "sequence exceeds max_seq");
            let start = if self.cfg.prefix_cache {
                let outcome = self
                    .kv
                    .admit_shared(e.req.id, &e.known_ctx(), e.known_tokens() + 1)
                    .expect("probe checked");
                self.prefill_skipped += outcome.cached_tokens as u64;
                outcome.cached_tokens
            } else {
                self.kv
                    .admit(e.req.id, e.known_tokens() + 1)
                    .expect("can_admit checked");
                0
            };
            admitted.push(e.req.id);
            let kind = if e.preemptions > 0 {
                crate::trace::Kind::SchedResume
            } else {
                crate::trace::Kind::SchedAdmit
            };
            crate::trace::instant(kind, e.req.id, slot as u64);
            self.slots[slot] =
                Some(Sequence::resumed_at(e.req, e.resumed_output, slot, e.preemptions, start));
        }

        // Chunk allocation: decode slots always advance one token; prefill
        // slots share the budget oldest-arrival-first. Only this
        // microbatch's slots participate.
        let mut chunks = vec![0usize; self.slots.len()];
        let mut prefill: Vec<usize> = Vec::new();
        for (s, slot) in self.slots.iter().enumerate() {
            if !in_mb(s) {
                continue;
            }
            let Some(seq) = slot else { continue };
            if seq.phase == Phase::Decode {
                chunks[s] = 1;
            } else {
                prefill.push(s);
            }
        }
        let key = |s: usize| {
            let r = &self.slots[s].as_ref().unwrap().request;
            (r.arrival, r.id)
        };
        prefill.sort_by(|&a, &b| key(a).partial_cmp(&key(b)).unwrap());
        let mut budget = if self.cfg.prefill_token_budget == 0 {
            usize::MAX
        } else {
            self.cfg.prefill_token_budget
        };
        for &s in &prefill {
            if budget == 0 {
                break; // remaining prefill slots pause this iteration
            }
            let seq = self.slots[s].as_ref().unwrap();
            let chunk = seq
                .remaining_known()
                .min(self.cfg.max_prefill_chunk.max(1))
                .min(budget);
            chunks[s] = chunk;
            budget -= chunk;
            self.prefill_computed += chunk as u64;
            crate::trace::instant(crate::trace::Kind::SchedChunk, s as u64, chunk as u64);
        }

        let mut plan = SchedulingOutput { iter: self.iter, slots: Vec::new(), admitted };
        for (s, seq) in self.slots.iter().enumerate() {
            if !in_mb(s) {
                continue; // another microbatch's slot
            }
            let Some(seq) = seq else { continue };
            if chunks[s] == 0 {
                continue; // prefill-paused
            }
            plan.slots.push(SlotPlan {
                slot: seq.slot,
                seq_id: seq.request.id,
                input_token: seq.input_token(),
                position: seq.position,
                chunk_len: chunks[s],
                // a decision is due when the chunk reaches the last known
                // token (always true for decode slots, where the chunk is 1)
                needs_decision: chunks[s] == seq.remaining_known(),
                decode_iter: seq.output.len() as u64,
            });
        }
        // Merge this microbatch's chunks; other microbatches' pending
        // chunks (not yet consumed by their advance_mb) must survive.
        for s in 0..self.slots.len() {
            if in_mb(s) {
                self.last_chunks[s] = chunks[s];
            }
        }
        self.iter += 1;
        plan
    }

    /// Commit one slot's sampled token. KV growth may evict other sequences
    /// under pressure (see [`CommitOutcome::preempted`]); if nothing else
    /// can be evicted the committing sequence preempts itself, keeping the
    /// just-committed token for replay.
    pub fn commit(&mut self, slot: usize, token: u32) -> CommitOutcome {
        let mut out = CommitOutcome::default();
        let pending = self.last_chunks[slot];
        let seq = self.slots[slot].as_mut().expect("commit to empty slot");
        // A decision implies the planned chunk was fed through the forward
        // pass: advance through its prefix now so the sequence sits at the
        // last known token, leaving the final step for `advance()`.
        if pending > 1 {
            seq.advance_by(pending - 1);
            self.last_chunks[slot] = 1;
        }
        // First decision of a residency: every known token (prompt plus any
        // replayed output) is now materialized in the KV cache — publish its
        // full blocks into the radix index before the phase flips to Decode,
        // so concurrent admissions of shared-prefix requests hit.
        if self.cfg.prefix_cache {
            let seq = self.slots[slot].as_ref().unwrap();
            if seq.phase == Phase::Prefill {
                let id = seq.request.id;
                let ctx = Self::ctx_prefix(seq, seq.kv_len());
                self.kv.publish(id, &ctx).expect("publish admitted seq");
            }
        }
        let seq = self.slots[slot].as_mut().unwrap();
        let finished = seq.commit_token(token);
        // the sequence also hits the cache ceiling when the next position
        // would overflow the static KV shape
        let overflow = seq.kv_len() + 1 >= self.max_seq_len;
        if finished || overflow {
            if overflow {
                seq.phase = Phase::Finished;
            }
            let id = seq.request.id;
            if self.cfg.prefix_cache {
                // Publish the full materialized history before releasing, so
                // the next conversation turn (whose prompt extends this one)
                // reuses the whole residency instead of just the prompt.
                let ctx = Self::ctx_prefix(seq, seq.kv_len());
                self.kv.publish(id, &ctx).expect("publish admitted seq");
            }
            self.kv.release(id).expect("release admitted seq");
            let seq = self.slots[slot].take().unwrap();
            self.finished.push(seq);
            out.finished = Some(id);
            return out;
        }
        let id = seq.request.id;
        let need = seq.kv_len() + 1;
        loop {
            match self.kv.grow(id, need) {
                Ok(()) => break,
                Err(KvError::OutOfBlocks { .. }) if self.cfg.preemption => {
                    match self.pick_victim(slot) {
                        Some(victim) => {
                            let vid = self.preempt(victim);
                            out.preempted.push((victim, vid));
                        }
                        None => {
                            // nothing else to evict: preempt self, keeping
                            // the token just committed for replay on resume
                            let sid = self.preempt(slot);
                            out.preempted.push((slot, sid));
                            return out;
                        }
                    }
                }
                Err(e) => panic!("grow admitted seq: {e}"),
            }
        }
        out
    }

    /// Commit a verified speculative window: `tokens` is the accepted draft
    /// prefix plus the corrected bonus token, oldest first (the engine's
    /// variable tokens-per-iteration path; a 1-token window is exactly
    /// [`Self::commit`]).
    ///
    /// KV accounting stays per-token exact: each commit after the first is
    /// preceded by one position advance (the draft token the data plane fed
    /// at that chain position), so `grow` sees the same sequence of needs
    /// as `k+1` ordinary iterations would. The window cuts short on EOS /
    /// max_new_tokens / the KV ceiling (the remaining verified tokens are
    /// discarded — the sequence is finished) and on self-preemption (the
    /// committed prefix rides the waiting-queue entry for replay; the rest
    /// is re-verified identically after resume, by uniform keying).
    ///
    /// The final position advance is left to [`Self::advance`], matching
    /// the single-token flow, so after `advance()` the slot sits exactly at
    /// its newest committed token.
    pub fn commit_multi(&mut self, slot: usize, tokens: &[u32]) -> MultiCommitOutcome {
        assert!(!tokens.is_empty(), "empty commit window");
        let mut out = MultiCommitOutcome::default();
        let id = self.slots[slot].as_ref().expect("commit to empty slot").request.id;
        for (j, &t) in tokens.iter().enumerate() {
            if j > 0 {
                // the draft token for this chain position went through the
                // forward pass; account its KV residency before committing
                match self.slots[slot].as_mut() {
                    Some(seq) if seq.request.id == id => seq.advance(),
                    _ => break, // self-preempted by the previous commit
                }
            }
            let o = self.commit(slot, t);
            out.committed += 1;
            let self_preempted = o.preempted.iter().any(|&(_, vid)| vid == id);
            out.preempted.extend(o.preempted);
            if let Some(f) = o.finished {
                out.finished = Some(f);
                break;
            }
            if self_preempted {
                break;
            }
        }
        out
    }

    /// Microbatch-scoped commit path for the pipelined executor's
    /// two-phase commit: decisions reaped from the asynchronous decision
    /// plane land as *pending commits* and are applied — through this
    /// method — just before the owning microbatch's next plan.
    ///
    /// The scope assertion is the contract that keeps preemption and
    /// spec-verify semantics exact: a pending commit may only ever be
    /// applied to a slot of its own microbatch, at a point where that
    /// microbatch has no forward in flight. Cross-microbatch effects are
    /// limited to KV-pressure evictions of *other* microbatches' slots,
    /// whose not-yet-reaped verdicts the engine discards by the
    /// `(slot, seq_id)` identity guard — and because admissions into a
    /// microbatch happen only in its own `plan_mb`, after its pending
    /// commits are applied, a stale verdict can never alias a re-admitted
    /// sequence in the same slot.
    pub fn commit_multi_scoped(
        &mut self,
        slot: usize,
        tokens: &[u32],
        mb: usize,
        n_mb: usize,
    ) -> MultiCommitOutcome {
        assert!(n_mb >= 1 && mb < n_mb, "microbatch {mb} of {n_mb}");
        assert_eq!(
            slot % n_mb,
            mb,
            "pending commit applied to a foreign microbatch's slot"
        );
        self.commit_multi(slot, tokens)
    }

    /// Victim policy: the latest-arrived running sequence other than
    /// `except` (LIFO preemption — youngest work is cheapest to redo).
    fn pick_victim(&self, except: usize) -> Option<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(s, seq)| *s != except && seq.is_some())
            .max_by(|(_, a), (_, b)| {
                let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
                a.request
                    .arrival
                    .partial_cmp(&b.request.arrival)
                    .unwrap()
                    .then(a.request.id.cmp(&b.request.id))
            })
            .map(|(s, _)| s)
    }

    /// The first `len` known tokens of a sequence (`prompt ⧺ output`
    /// prefix) — what prefix-cache publishes match against.
    fn ctx_prefix(seq: &Sequence, len: usize) -> Vec<u32> {
        let mut ctx = seq.request.prompt.clone();
        ctx.extend_from_slice(&seq.output);
        ctx.truncate(len);
        ctx
    }

    /// Evict a running sequence: release its KV blocks and re-queue it at
    /// the front of the waiting queue for recompute-on-resume.
    fn preempt(&mut self, slot: usize) -> u64 {
        let seq = self.slots[slot].take().expect("preempt empty slot");
        let id = seq.request.id;
        if self.cfg.prefix_cache {
            // Keep the victim's already-computed blocks discoverable: only
            // tokens at positions `0..position` are certainly materialized
            // (its planned chunk may still be in flight). On resume the
            // admission lookup finds them and recomputes only the tail.
            let ctx = Self::ctx_prefix(&seq, seq.position);
            self.kv.publish(id, &ctx).expect("publish admitted seq");
        }
        self.kv.release(id).expect("release admitted seq");
        self.preemption_count += 1;
        self.last_chunks[slot] = 0;
        crate::trace::instant(crate::trace::Kind::SchedPreempt, id, slot as u64);
        self.waiting.push_front(WaitingEntry {
            req: seq.request,
            resumed_output: seq.output,
            preemptions: seq.preemptions + 1,
        });
        id
    }

    /// Advance all slots planned by the last `plan()` past the forward step
    /// (after commits). Slots emptied since planning (finished, preempted)
    /// are skipped; calling twice without a new plan is a no-op.
    pub fn advance(&mut self) {
        self.advance_mb(0, 1);
    }

    /// Microbatch-scoped advance: consume only microbatch `mb`'s planned
    /// chunks, leaving other microbatches' pending chunks intact (they may
    /// still have forwards or decisions in flight).
    pub fn advance_mb(&mut self, mb: usize, n_mb: usize) {
        assert!(n_mb >= 1 && mb < n_mb, "microbatch {mb} of {n_mb}");
        for s in 0..self.last_chunks.len() {
            if s % n_mb != mb {
                continue;
            }
            let chunk = std::mem::take(&mut self.last_chunks[s]);
            if chunk == 0 {
                continue;
            }
            if let Some(seq) = self.slots[s].as_mut() {
                seq.advance_by(chunk);
            }
        }
    }

    /// The sequence occupying a slot, if any.
    pub fn slot(&self, slot: usize) -> Option<&Sequence> {
        self.slots.get(slot).and_then(|s| s.as_ref())
    }

    /// Finished sequences (drained by the caller).
    pub fn take_finished(&mut self) -> Vec<Sequence> {
        std::mem::take(&mut self.finished)
    }

    pub fn iter_count(&self) -> u64 {
        self.iter
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(slots: usize, blocks: usize) -> Scheduler {
        Scheduler::new(slots, KvAllocator::new(blocks, 16), 64)
    }

    fn req(id: u64, prompt_len: usize, max_new: usize) -> Request {
        Request::new(id, (0..prompt_len as u32).collect(), max_new)
    }

    /// Drive a scheduler to drain, committing `token` for every decision.
    /// Returns (#finished, #iterations).
    fn drain(s: &mut Scheduler, token: u32, guard: usize) -> (usize, usize) {
        let mut done = 0;
        let mut iters = 0;
        while !s.is_idle() {
            let plan = s.plan(0.0);
            let decisions: Vec<(usize, u64)> = plan
                .slots
                .iter()
                .filter(|p| p.needs_decision)
                .map(|p| (p.slot, p.seq_id))
                .collect();
            // commit decisions BEFORE advancing (matches engine flow);
            // skip slots whose sequence was preempted by an earlier commit
            for (slot, seq_id) in decisions {
                if s.slot(slot).map(|q| q.request.id) != Some(seq_id) {
                    continue;
                }
                if s.commit(slot, token).finished.is_some() {
                    done += 1;
                }
            }
            s.advance();
            iters += 1;
            assert!(iters < guard, "scheduler stuck after {guard} iterations");
        }
        (done, iters)
    }

    #[test]
    fn admits_up_to_slot_capacity() {
        let mut s = sched(2, 100);
        for i in 0..3 {
            s.submit(req(i, 4, 4));
        }
        let plan = s.plan(0.0);
        assert_eq!(plan.admitted, vec![0, 1]);
        assert_eq!(plan.slots.len(), 2);
        assert_eq!(s.waiting_len(), 1);
    }

    #[test]
    fn kv_admission_gates() {
        // 2 blocks of 16 tokens: a 40-token prompt can never be admitted;
        // two 10-token prompts each need 1 block.
        let mut s = sched(4, 2);
        s.submit(req(0, 40, 4));
        s.submit(req(1, 10, 4));
        s.submit(req(2, 10, 4));
        let plan = s.plan(0.0);
        assert_eq!(plan.admitted, vec![1, 2]); // 0 skipped (too large)
    }

    #[test]
    fn arrival_time_gates_admission() {
        let mut s = sched(2, 10);
        let mut r = req(0, 2, 2);
        r.arrival = 5.0;
        s.submit(r);
        assert!(s.plan(1.0).admitted.is_empty());
        assert_eq!(s.plan(6.0).admitted, vec![0]);
    }

    #[test]
    fn full_lifecycle_no_leaks() {
        let mut s = sched(2, 10);
        s.submit(req(0, 2, 2));
        s.submit(req(1, 3, 1));
        let (done, _) = drain(&mut s, 7, 50);
        assert_eq!(done, 2);
        assert_eq!(s.kv.used_blocks(), 0);
        s.kv.check_invariants().unwrap();
        let fin = s.take_finished();
        assert_eq!(fin.len(), 2);
        assert!(fin.iter().all(|f| f.phase == Phase::Finished));
    }

    #[test]
    fn slot_reuse_after_finish() {
        let mut s = sched(1, 10);
        s.submit(req(0, 1, 1));
        s.submit(req(1, 1, 1));
        let p1 = s.plan(0.0);
        assert_eq!(p1.admitted, vec![0]);
        assert!(s.commit(0, 3).finished.is_some());
        s.advance();
        let p2 = s.plan(0.0);
        assert_eq!(p2.admitted, vec![1]);
        assert_eq!(p2.slots[0].slot, 0); // same slot reused
    }

    #[test]
    fn max_seq_len_forces_retirement() {
        let mut s = Scheduler::new(1, KvAllocator::new(100, 16), 8);
        s.submit(req(0, 4, 100)); // wants 100 tokens but cache holds 8
        let mut done = false;
        for _ in 0..12 {
            let plan = s.plan(0.0);
            if plan.slots.is_empty() {
                break;
            }
            if plan.slots[0].needs_decision && s.commit(0, 9).finished.is_some() {
                done = true;
                break;
            }
            s.advance();
        }
        assert!(done, "sequence must retire at the KV ceiling");
    }

    // ---- preemption ----

    #[test]
    fn kv_pressure_preempts_latest_arrival() {
        // 4 blocks of 4 tokens. Two sequences each admitted with 1 block
        // (3-token prompt + 1); as they decode past 4 tokens each needs a
        // 2nd block; growth pressure must evict the later arrival, not
        // panic, and accounting must stay exact.
        let mut s = Scheduler::with_config(
            2,
            KvAllocator::new(4, 4),
            64,
            SchedulerConfig::default(),
        );
        let mut a = req(0, 3, 20);
        a.arrival = 0.0;
        let mut b = req(1, 3, 20);
        b.arrival = 0.5;
        s.submit(a);
        s.submit(b);
        let mut preempted_ids = Vec::new();
        let mut guard = 0;
        'outer: loop {
            let plan = s.plan(1.0);
            if plan.slots.is_empty() {
                break;
            }
            let decisions: Vec<(usize, u64)> = plan
                .slots
                .iter()
                .filter(|p| p.needs_decision)
                .map(|p| (p.slot, p.seq_id))
                .collect();
            for (slot, seq_id) in decisions {
                if s.slot(slot).map(|q| q.request.id) != Some(seq_id) {
                    continue;
                }
                let out = s.commit(slot, 7);
                for &(_, id) in &out.preempted {
                    preempted_ids.push(id);
                    break 'outer;
                }
            }
            s.advance();
            guard += 1;
            assert!(guard < 100, "no preemption triggered");
        }
        assert_eq!(preempted_ids, vec![1], "latest arrival is the victim");
        assert_eq!(s.preemption_count(), 1);
        s.kv.check_invariants().unwrap();
        // the victim is back in the waiting queue carrying its tokens
        assert_eq!(s.waiting_len(), 1);
        assert_eq!(s.running_len(), 1);
    }

    #[test]
    fn preempted_sequence_resumes_and_finishes() {
        // Tight cache forces repeated preemptions, but every sequence must
        // eventually drain with its full token count and no KV leak.
        let mut s = Scheduler::with_config(
            3,
            KvAllocator::new(6, 4),
            64,
            SchedulerConfig::default(),
        );
        for i in 0..3 {
            s.submit(req(i, 4, 12));
        }
        let (done, _) = drain(&mut s, 9, 2_000);
        assert_eq!(done, 3);
        assert!(s.preemption_count() > 0, "tight cache must preempt");
        assert_eq!(s.kv.used_blocks(), 0);
        s.kv.check_invariants().unwrap();
        let fin = s.take_finished();
        assert_eq!(fin.len(), 3);
        for f in fin {
            assert_eq!(f.output.len(), 12, "seq {}", f.request.id);
            assert!(f.output.iter().all(|&t| t == 9));
        }
    }

    #[test]
    fn self_preemption_when_alone() {
        // One sequence, cache of 2×4-token blocks: once decode outgrows the
        // cache there is no other victim, so the sequence preempts itself,
        // keeping every committed token. (A lone self-preempted sequence
        // can never be re-admitted — resume needs capacity+1 tokens — so
        // deployments size the cache for one max-length sequence; here we
        // assert the eviction accounting is exact.)
        let mut s = Scheduler::with_config(
            1,
            KvAllocator::new(2, 4),
            64,
            SchedulerConfig::default(),
        );
        s.submit(req(0, 2, 20));
        let mut preempt_out = None;
        for _ in 0..20 {
            let plan = s.plan(0.0);
            assert!(!plan.slots.is_empty());
            if plan.slots[0].needs_decision {
                let out = s.commit(0, 5);
                if !out.preempted.is_empty() {
                    preempt_out = Some(out);
                    break;
                }
            }
            s.advance();
        }
        let out = preempt_out.expect("self-preemption must trigger");
        assert_eq!(out.preempted, vec![(0, 0)]);
        assert!(out.finished.is_none());
        assert_eq!(s.preemption_count(), 1);
        assert_eq!(s.running_len(), 0);
        assert_eq!(s.waiting_len(), 1, "victim re-queued, not lost");
        assert_eq!(s.kv.used_blocks(), 0);
        s.kv.check_invariants().unwrap();
    }

    #[test]
    fn resumed_entries_admitted_before_fresh() {
        let mut s = sched(1, 100);
        // occupy the only slot, queue a fresh request, then preempt by hand:
        // the resumed entry must outrank the fresh one on re-admission.
        s.submit(req(0, 2, 10));
        s.submit(req(1, 2, 10));
        let _ = s.plan(0.0);
        s.advance();
        let _ = s.plan(0.0);
        let vid = s.preempt(0);
        assert_eq!(vid, 0);
        let plan = s.plan(0.0);
        assert_eq!(plan.admitted, vec![0], "resumed outranks fresh arrival");
    }

    // ---- speculative multi-token commits ----

    #[test]
    fn multi_commit_equals_single_token_iterations() {
        // Committing [a, b, c] in one window must leave the scheduler in
        // the same state as three plain iterations committing a, b, c.
        let run = |multi: bool| {
            let mut s = sched(1, 100);
            s.submit(req(0, 3, 10));
            // prefill to the decision point
            for _ in 0..2 {
                let p = s.plan(0.0);
                assert!(!p.slots[0].needs_decision);
                s.advance();
            }
            let p = s.plan(0.0);
            assert!(p.slots[0].needs_decision);
            if multi {
                let out = s.commit_multi(0, &[7, 8, 9]);
                assert_eq!(out.committed, 3);
                assert!(out.finished.is_none() && out.preempted.is_empty());
                s.advance();
            } else {
                s.commit(0, 7);
                s.advance();
                for &t in &[8u32, 9] {
                    let p = s.plan(0.0);
                    assert!(p.slots[0].needs_decision);
                    assert_eq!(p.slots[0].decode_iter, s.slot(0).unwrap().output.len() as u64);
                    s.commit(0, t);
                    s.advance();
                }
            }
            let seq = s.slot(0).unwrap();
            (seq.output.clone(), seq.position, s.kv.used_blocks())
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn multi_commit_cuts_window_at_max_new_tokens() {
        // max_new_tokens = 2: a 4-token verified window commits only 2 and
        // finishes; the rest of the window is discarded (EOS mid-window).
        let mut s = sched(1, 100);
        s.submit(req(0, 1, 2));
        let p = s.plan(0.0);
        assert!(p.slots[0].needs_decision);
        let out = s.commit_multi(0, &[5, 6, 7, 8]);
        assert_eq!(out.committed, 2);
        assert_eq!(out.finished, Some(0));
        let fin = s.take_finished();
        assert_eq!(fin[0].output, vec![5, 6]);
        assert_eq!(s.kv.used_blocks(), 0);
    }

    #[test]
    fn multi_commit_finishes_on_eos_mid_window() {
        let mut s = sched(1, 100);
        let mut r = req(0, 1, 50);
        r.eos_token = Some(6);
        s.submit(r);
        let _ = s.plan(0.0);
        let out = s.commit_multi(0, &[5, 6, 7]);
        assert_eq!(out.committed, 2, "EOS cuts the window");
        assert_eq!(out.finished, Some(0));
        assert_eq!(s.take_finished()[0].output, vec![5, 6]);
    }

    #[test]
    fn multi_commit_self_preemption_keeps_committed_prefix() {
        // One sequence, 2×4-token cache: a long verified window outgrows
        // the cache mid-commit; the committed prefix must survive in the
        // re-queued entry and nothing may leak.
        let mut s = Scheduler::with_config(
            1,
            KvAllocator::new(2, 4),
            64,
            SchedulerConfig::default(),
        );
        s.submit(req(0, 2, 30));
        let _ = s.plan(0.0);
        s.advance(); // feed first prompt token
        let _ = s.plan(0.0);
        // At commit time the slot sits at position 1; the j-th commit needs
        // j+3 KV tokens, so the 2×4-token cache dies at j = 6: 7 tokens
        // commit, the rest of the window is discarded, and the committed
        // prefix rides the waiting entry (a lone self-preempted sequence
        // can never resume — see `self_preemption_when_alone` — so only
        // accounting is asserted).
        let out = s.commit_multi(0, &[1, 2, 3, 4, 5, 6, 7, 8]);
        assert!(out.committed > 0 && out.committed < 8, "window cut: {out:?}");
        assert_eq!(out.preempted, vec![(0, 0)]);
        assert!(out.finished.is_none());
        assert_eq!(s.preemption_count(), 1);
        assert_eq!(s.running_len(), 0);
        assert_eq!(s.waiting_len(), 1, "victim re-queued with its tokens");
        assert_eq!(s.kv.used_blocks(), 0);
        s.kv.check_invariants().unwrap();
    }

    #[test]
    fn multi_commit_preempts_other_slot_and_continues() {
        // Two sequences; a multi-token window on slot 0 evicts the later
        // arrival under KV pressure but keeps committing its own tokens.
        // 3 blocks of 4: each seq admits with 1 block; slot 0's window
        // takes the free block at need 5 and must evict seq 1 at need 9.
        let mut s = Scheduler::with_config(
            2,
            KvAllocator::new(3, 4),
            64,
            SchedulerConfig::default(),
        );
        let mut a = req(0, 3, 20);
        a.arrival = 0.0;
        let mut b = req(1, 3, 20);
        b.arrival = 0.5;
        s.submit(a);
        s.submit(b);
        // prefill both to their decision points
        for _ in 0..2 {
            let _ = s.plan(1.0);
            s.advance();
        }
        let p = s.plan(1.0);
        assert!(p.slots.iter().all(|sp| sp.needs_decision));
        let out = s.commit_multi(0, &[7, 7, 7, 7, 7, 7]);
        assert_eq!(out.committed, 6, "own window commits fully");
        assert!(out.preempted.iter().any(|&(_, vid)| vid == 1), "{out:?}");
        assert!(s.slot(0).is_some());
        s.kv.check_invariants().unwrap();
    }

    // ---- SLO-aware admission ----

    #[test]
    fn oldest_request_admitted_first_under_backlog() {
        let mut s = sched(1, 100);
        // queue order 2, 1, 0 but arrival order 0 < 1 < 2: the aged request
        // must win the free slot.
        for (id, arrival) in [(2u64, 3.0), (1, 2.0), (0, 1.0)] {
            let mut r = req(id, 2, 2);
            r.arrival = arrival;
            s.submit(r);
        }
        let plan = s.plan(10.0);
        assert_eq!(plan.admitted, vec![0], "max waiting time wins");
    }

    // ---- chunked prefill ----

    #[test]
    fn prefill_chunks_bounded_by_budget() {
        let cfg = SchedulerConfig {
            prefill_token_budget: 8,
            max_prefill_chunk: 6,
            ..SchedulerConfig::default()
        };
        let mut s = Scheduler::with_config(4, KvAllocator::new(100, 16), 64, cfg);
        s.submit(req(0, 10, 2));
        s.submit(req(1, 10, 2));
        s.submit(req(2, 10, 2));
        let plan = s.plan(0.0);
        assert_eq!(plan.admitted, vec![0, 1, 2]);
        // budget 8, chunk cap 6: seq 0 gets 6, seq 1 gets 2, seq 2 pauses
        let total: usize = plan.slots.iter().map(|p| p.chunk_len).sum();
        assert_eq!(total, 8, "prefill tokens bounded by the budget");
        assert_eq!(plan.slots.len(), 2, "third prefill slot paused");
        assert_eq!(plan.slots[0].chunk_len, 6);
        assert_eq!(plan.slots[1].chunk_len, 2);
        assert!(plan.slots.iter().all(|p| !p.needs_decision));
        s.advance();
        let seq0 = s.slot(0).unwrap();
        assert_eq!(seq0.position, 6);
    }

    #[test]
    fn decode_slots_exempt_from_prefill_budget() {
        let cfg = SchedulerConfig {
            prefill_token_budget: 2,
            max_prefill_chunk: 4,
            ..SchedulerConfig::default()
        };
        let mut s = Scheduler::with_config(3, KvAllocator::new(100, 16), 64, cfg);
        s.submit(req(0, 1, 8)); // decodes immediately
        let plan = s.plan(0.0);
        assert!(plan.slots[0].needs_decision);
        assert!(s.commit(0, 3).finished.is_none());
        s.advance();
        // now in decode; admit two chunked prefills alongside
        s.submit(req(1, 9, 2));
        s.submit(req(2, 9, 2));
        let plan = s.plan(0.0);
        let by_id: std::collections::HashMap<u64, &SlotPlan> =
            plan.slots.iter().map(|p| (p.seq_id, p)).collect();
        assert_eq!(by_id[&0].chunk_len, 1, "decode advances regardless of budget");
        assert!(by_id[&0].needs_decision);
        assert_eq!(by_id[&1].chunk_len, 2, "prefill consumes the whole budget");
        assert!(!by_id.contains_key(&2), "second prefill paused");
    }

    #[test]
    fn chunked_prefill_reaches_decision_exactly_at_last_token() {
        let cfg = SchedulerConfig {
            prefill_token_budget: 4,
            max_prefill_chunk: 4,
            ..SchedulerConfig::default()
        };
        let mut s = Scheduler::with_config(1, KvAllocator::new(100, 16), 64, cfg);
        s.submit(req(0, 10, 1));
        // 10 prompt tokens in chunks of 4: 4, 4, 2(=last, decision)
        let p1 = s.plan(0.0);
        assert_eq!((p1.slots[0].chunk_len, p1.slots[0].needs_decision), (4, false));
        s.advance();
        let p2 = s.plan(0.0);
        assert_eq!((p2.slots[0].chunk_len, p2.slots[0].needs_decision), (4, false));
        s.advance();
        let p3 = s.plan(0.0);
        assert_eq!((p3.slots[0].chunk_len, p3.slots[0].needs_decision), (2, true));
        assert!(s.commit(0, 4).finished.is_some(), "max_new_tokens = 1");
        assert_eq!(s.kv.used_blocks(), 0);
    }

    // ---- microbatch-scoped planning (pipelined executor) ----

    #[test]
    fn plan_mb_partitions_slot_space() {
        let mut s = sched(4, 100);
        for i in 0..4 {
            s.submit(req(i, 2, 2));
        }
        let p0 = s.plan_mb(0.0, 0, 2);
        // microbatch 0 owns slots 0 and 2
        assert_eq!(p0.admitted, vec![0, 1]);
        assert!(p0.slots.iter().all(|sp| sp.slot % 2 == 0), "{p0:?}");
        let p1 = s.plan_mb(0.0, 1, 2);
        assert_eq!(p1.admitted, vec![2, 3]);
        assert!(p1.slots.iter().all(|sp| sp.slot % 2 == 1), "{p1:?}");
        // advancing microbatch 0 must not consume microbatch 1's chunks
        s.advance_mb(0, 2);
        let pos_mb1: Vec<usize> =
            [1, 3].iter().map(|&sl| s.slot(sl).unwrap().position).collect();
        assert_eq!(pos_mb1, vec![0, 0], "mb 1 not advanced by mb 0's advance");
        s.advance_mb(1, 2);
        assert_eq!(s.slot(1).unwrap().position, 1);
        assert_eq!(s.slot(0).unwrap().position, 1);
    }

    #[test]
    fn interleaved_microbatch_plans_match_single_plan_streams() {
        // Driving two interleaved microbatches to drain commits the same
        // per-request tokens as the monolithic plan/advance loop.
        let run = |n_mb: usize| {
            let mut s = sched(4, 100);
            for i in 0..6 {
                s.submit(req(i, 3, 4));
            }
            let mut guard = 0;
            while !s.is_idle() {
                for mb in 0..n_mb {
                    let plan = s.plan_mb(0.0, mb, n_mb);
                    let decisions: Vec<(usize, u64)> = plan
                        .slots
                        .iter()
                        .filter(|p| p.needs_decision)
                        .map(|p| (p.slot, p.seq_id))
                        .collect();
                    for (slot, seq_id) in decisions {
                        if s.slot(slot).map(|q| q.request.id) != Some(seq_id) {
                            continue;
                        }
                        let _ = s.commit_multi_scoped(slot, &[5], mb, n_mb);
                    }
                    s.advance_mb(mb, n_mb);
                }
                guard += 1;
                assert!(guard < 200, "stuck");
            }
            let mut fin: Vec<(u64, Vec<u32>)> = s
                .take_finished()
                .into_iter()
                .map(|f| (f.request.id, f.output))
                .collect();
            fin.sort();
            fin
        };
        assert_eq!(run(1), run(2));
        assert_eq!(run(1), run(4));
    }

    #[test]
    #[should_panic(expected = "foreign microbatch")]
    fn scoped_commit_rejects_foreign_slot() {
        let mut s = sched(2, 100);
        s.submit(req(0, 1, 2));
        s.submit(req(1, 1, 2));
        let _ = s.plan_mb(0.0, 0, 2);
        let _ = s.plan_mb(0.0, 1, 2);
        // slot 1 belongs to microbatch 1; committing it as mb 0 must panic
        let _ = s.commit_multi_scoped(1, &[3], 0, 2);
    }

    #[test]
    fn cross_microbatch_preemption_zeroes_victims_pending_chunk() {
        // A commit in microbatch 0 evicts microbatch 1's slot under KV
        // pressure while mb 1's chunk is still pending: the victim's chunk
        // must be cleared so mb 1's later advance doesn't touch a
        // re-admitted stranger.
        let mut s = Scheduler::with_config(
            2,
            KvAllocator::new(2, 4),
            64,
            SchedulerConfig::default(),
        );
        let mut a = req(0, 3, 20);
        a.arrival = 0.0;
        let mut b = req(1, 3, 20);
        b.arrival = 0.5;
        s.submit(a);
        s.submit(b);
        // prefill both microbatches to their decision points (position 2)
        for _ in 0..2 {
            let _ = s.plan_mb(1.0, 0, 2);
            let _ = s.plan_mb(1.0, 1, 2);
            s.advance_mb(0, 2);
            s.advance_mb(1, 2);
        }
        let p0 = s.plan_mb(1.0, 0, 2);
        let _p1 = s.plan_mb(1.0, 1, 2); // mb 1's chunk now pending
        assert!(p0.slots[0].needs_decision);
        // grow slot 0 until it needs a second block → evicts slot 1
        let out = s.commit_multi_scoped(0, &[7, 7, 7, 7], 0, 2);
        assert!(
            out.preempted.iter().any(|&(sl, vid)| sl == 1 && vid == 1),
            "{out:?}"
        );
        // the victim's pending chunk was cleared by preempt()
        s.advance_mb(1, 2); // must be a no-op, not a panic
        assert!(s.slot(1).is_none());
        s.kv.check_invariants().unwrap();
    }

    #[test]
    fn next_arrival_tracks_waiting_queue() {
        let mut s = sched(1, 100);
        assert_eq!(s.next_arrival(), None);
        let mut r = req(0, 2, 2);
        r.arrival = 4.0;
        s.submit(r);
        let mut r2 = req(1, 2, 2);
        r2.arrival = 2.5;
        s.submit(r2);
        assert_eq!(s.next_arrival(), Some(2.5));
        let _ = s.plan(3.0); // admits request 1
        assert_eq!(s.next_arrival(), Some(4.0));
    }

    // ---- prefix-cache-aware admission (§13) ----

    #[test]
    fn prefix_cache_shares_published_blocks_on_admission() {
        let cfg = SchedulerConfig { prefix_cache: true, ..SchedulerConfig::default() };
        let mut s = Scheduler::with_config(1, KvAllocator::new(100, 4), 64, cfg);
        s.submit(req(0, 8, 1));
        let (done, _) = drain(&mut s, 7, 50);
        assert_eq!(done, 1);
        assert!(s.kv.indexed_blocks() >= 2, "prompt blocks published");
        // A follow-up whose prompt extends the first one (the conversation
        // pattern) shares the cached head and prefills only the tail.
        s.submit(req(1, 12, 1));
        let plan = s.plan(0.0);
        assert_eq!(plan.admitted, vec![1]);
        assert_eq!(
            s.slot(0).unwrap().position,
            8,
            "prefill starts at the first uncached token"
        );
        assert_eq!(s.prefill_skipped_tokens(), 8);
        let (done, iters) = drain(&mut s, 7, 50);
        assert_eq!(done, 1);
        assert_eq!(iters, 4, "only the uncached tail is fed");
        s.kv.check_invariants().unwrap();
    }

    #[test]
    fn preempted_sequence_resumes_onto_cached_prefix() {
        // Same churn as `preempted_sequence_resumes_and_finishes`, but with
        // the prefix cache on: victims publish their materialized blocks on
        // eviction, so resumes recompute only the tail — and the token
        // streams must come out identical either way.
        let cfg = SchedulerConfig { prefix_cache: true, ..SchedulerConfig::default() };
        let mut s = Scheduler::with_config(3, KvAllocator::new(6, 4), 64, cfg);
        for i in 0..3 {
            s.submit(req(i, 4, 12));
        }
        let (done, _) = drain(&mut s, 9, 2_000);
        assert_eq!(done, 3);
        assert!(s.preemption_count() > 0, "tight cache must preempt");
        assert!(s.prefill_skipped_tokens() > 0, "resume must hit the cache");
        s.kv.check_invariants().unwrap();
        let fin = s.take_finished();
        assert_eq!(fin.len(), 3);
        for f in fin {
            assert_eq!(f.output.len(), 12, "seq {}", f.request.id);
            assert!(f.output.iter().all(|&t| t == 9));
        }
    }

    #[test]
    fn default_config_matches_single_token_prefill() {
        // SchedulerConfig::default() must reproduce the pre-chunking
        // behavior: every running slot feeds exactly one token per plan.
        let mut s = sched(2, 100);
        s.submit(req(0, 5, 2));
        s.submit(req(1, 3, 2));
        for _ in 0..3 {
            let plan = s.plan(0.0);
            assert!(plan.slots.iter().all(|p| p.chunk_len == 1));
            s.advance();
        }
    }
}
