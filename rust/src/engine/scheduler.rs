//! Continuous-batching scheduler.
//!
//! Maintains a waiting queue and a fixed set of batch slots (the AOT model's
//! static B). Each iteration it: admits waiting requests into free slots
//! (KV-block admission control), emits the *scheduling output* — the compact
//! per-iteration plan broadcast to GPU workers and samplers (§4.2 step ⓪) —
//! and retires finished sequences.

use super::kvcache::KvAllocator;
use super::request::{Phase, Request, Sequence};
use std::collections::VecDeque;

/// Per-slot plan entry within a scheduling output.
#[derive(Debug, Clone)]
pub struct SlotPlan {
    pub slot: usize,
    pub seq_id: u64,
    /// Token to feed this iteration.
    pub input_token: u32,
    /// Position being fed.
    pub position: usize,
    /// Whether this iteration's logits column needs a sampling decision.
    pub needs_decision: bool,
    /// Iteration index local to the sequence (= #generated so far).
    pub decode_iter: u64,
}

/// The compact per-iteration scheduling output.
#[derive(Debug, Clone, Default)]
pub struct SchedulingOutput {
    pub iter: u64,
    pub slots: Vec<SlotPlan>,
    /// Requests newly admitted this iteration (register with samplers).
    pub admitted: Vec<u64>,
}

/// Scheduler state.
pub struct Scheduler {
    waiting: VecDeque<Request>,
    slots: Vec<Option<Sequence>>,
    pub kv: KvAllocator,
    iter: u64,
    max_seq_len: usize,
    finished: Vec<Sequence>,
}

impl Scheduler {
    pub fn new(num_slots: usize, kv: KvAllocator, max_seq_len: usize) -> Scheduler {
        Scheduler {
            waiting: VecDeque::new(),
            slots: (0..num_slots).map(|_| None).collect(),
            kv,
            iter: 0,
            max_seq_len,
            finished: Vec::new(),
        }
    }

    pub fn submit(&mut self, req: Request) {
        self.waiting.push_back(req);
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn running_len(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.running_len() == 0
    }

    pub fn num_slots(&self) -> usize {
        self.slots.len()
    }

    /// Admit waiting requests into free slots (KV admission control), then
    /// emit this iteration's plan. `now` gates arrivals (open-loop traces).
    pub fn plan(&mut self, now: f64) -> SchedulingOutput {
        let mut admitted = Vec::new();
        for slot in 0..self.slots.len() {
            if self.slots[slot].is_some() {
                continue;
            }
            // find the first arrived request that fits
            let Some(pos) = self
                .waiting
                .iter()
                .position(|r| r.arrival <= now && self.kv.can_admit(r.prompt.len() + 1))
            else {
                continue;
            };
            let req = self.waiting.remove(pos).unwrap();
            let total = (req.prompt.len() + req.max_new_tokens).min(self.max_seq_len);
            debug_assert!(req.prompt.len() < self.max_seq_len, "prompt exceeds max_seq");
            self.kv
                .admit(req.id, req.prompt.len() + 1)
                .expect("can_admit checked");
            let _ = total;
            admitted.push(req.id);
            self.slots[slot] = Some(Sequence::new(req, slot));
        }

        let mut plan = SchedulingOutput { iter: self.iter, slots: Vec::new(), admitted };
        for seq in self.slots.iter().flatten() {
            plan.slots.push(SlotPlan {
                slot: seq.slot,
                seq_id: seq.request.id,
                input_token: seq.input_token(),
                position: seq.position,
                needs_decision: seq.needs_decision(),
                decode_iter: seq.output.len() as u64,
            });
        }
        self.iter += 1;
        plan
    }

    /// Commit one slot's sampled token. Returns `Some(seq_id)` if the
    /// sequence finished (caller retires it from samplers + KV).
    pub fn commit(&mut self, slot: usize, token: u32) -> Option<u64> {
        let seq = self.slots[slot].as_mut().expect("commit to empty slot");
        let finished = seq.commit_token(token);
        // the sequence also hits the cache ceiling when the next position
        // would overflow the static KV shape
        let overflow = seq.kv_len() + 1 >= self.max_seq_len;
        if finished || overflow {
            if overflow {
                seq.phase = Phase::Finished;
            }
            let id = seq.request.id;
            self.kv.release(id).expect("release admitted seq");
            let seq = self.slots[slot].take().unwrap();
            self.finished.push(seq);
            Some(id)
        } else {
            self.kv
                .grow(seq.request.id, seq.kv_len() + 1)
                .expect("grow admitted seq");
            None
        }
    }

    /// Advance all running sequences past the forward step (after commit).
    pub fn advance(&mut self) {
        for seq in self.slots.iter_mut().flatten() {
            seq.advance();
        }
    }

    /// The sequence occupying a slot, if any.
    pub fn slot(&self, slot: usize) -> Option<&Sequence> {
        self.slots.get(slot).and_then(|s| s.as_ref())
    }

    /// Finished sequences (drained by the caller).
    pub fn take_finished(&mut self) -> Vec<Sequence> {
        std::mem::take(&mut self.finished)
    }

    pub fn iter_count(&self) -> u64 {
        self.iter
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(slots: usize, blocks: usize) -> Scheduler {
        Scheduler::new(slots, KvAllocator::new(blocks, 16), 64)
    }

    fn req(id: u64, prompt_len: usize, max_new: usize) -> Request {
        Request::new(id, (0..prompt_len as u32).collect(), max_new)
    }

    #[test]
    fn admits_up_to_slot_capacity() {
        let mut s = sched(2, 100);
        for i in 0..3 {
            s.submit(req(i, 4, 4));
        }
        let plan = s.plan(0.0);
        assert_eq!(plan.admitted, vec![0, 1]);
        assert_eq!(plan.slots.len(), 2);
        assert_eq!(s.waiting_len(), 1);
    }

    #[test]
    fn kv_admission_gates() {
        // 2 blocks of 16 tokens: a 40-token prompt can never be admitted;
        // two 10-token prompts each need 1 block.
        let mut s = sched(4, 2);
        s.submit(req(0, 40, 4));
        s.submit(req(1, 10, 4));
        s.submit(req(2, 10, 4));
        let plan = s.plan(0.0);
        assert_eq!(plan.admitted, vec![1, 2]); // 0 skipped (too large)
    }

    #[test]
    fn arrival_time_gates_admission() {
        let mut s = sched(2, 10);
        let mut r = req(0, 2, 2);
        r.arrival = 5.0;
        s.submit(r);
        assert!(s.plan(1.0).admitted.is_empty());
        assert_eq!(s.plan(6.0).admitted, vec![0]);
    }

    #[test]
    fn full_lifecycle_no_leaks() {
        let mut s = sched(2, 10);
        s.submit(req(0, 2, 2));
        s.submit(req(1, 3, 1));
        let mut done = 0;
        let mut guard = 0;
        while !s.is_idle() {
            let plan = s.plan(0.0);
            let decisions: Vec<(usize, u64)> = plan
                .slots
                .iter()
                .filter(|p| p.needs_decision)
                .map(|p| (p.slot, p.seq_id))
                .collect();
            // commit decisions BEFORE advancing (matches engine flow)
            for (slot, _) in decisions {
                if s.commit(slot, 7).is_some() {
                    done += 1;
                }
            }
            s.advance();
            guard += 1;
            assert!(guard < 50, "scheduler stuck");
        }
        assert_eq!(done, 2);
        assert_eq!(s.kv.used_blocks(), 0);
        s.kv.check_invariants().unwrap();
        let fin = s.take_finished();
        assert_eq!(fin.len(), 2);
        assert!(fin.iter().all(|f| f.phase == Phase::Finished));
    }

    #[test]
    fn slot_reuse_after_finish() {
        let mut s = sched(1, 10);
        s.submit(req(0, 1, 1));
        s.submit(req(1, 1, 1));
        let p1 = s.plan(0.0);
        assert_eq!(p1.admitted, vec![0]);
        assert!(s.commit(0, 3).is_some());
        s.advance();
        let p2 = s.plan(0.0);
        assert_eq!(p2.admitted, vec![1]);
        assert_eq!(p2.slots[0].slot, 0); // same slot reused
    }

    #[test]
    fn max_seq_len_forces_retirement() {
        let mut s = Scheduler::new(1, KvAllocator::new(100, 16), 8);
        s.submit(req(0, 4, 100)); // wants 100 tokens but cache holds 8
        let mut done = false;
        for _ in 0..12 {
            let plan = s.plan(0.0);
            if plan.slots.is_empty() {
                break;
            }
            if plan.slots[0].needs_decision && s.commit(0, 9).is_some() {
                done = true;
                break;
            }
            s.advance();
        }
        assert!(done, "sequence must retire at the KV ceiling");
    }
}
