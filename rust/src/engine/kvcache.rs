//! Paged KV-cache block allocator (vLLM-style substrate).
//!
//! The engine admits sequences only when blocks are available, extends a
//! sequence's block list as it grows, and frees on retirement. This governs
//! admission/preemption exactly as in PagedAttention-based engines; the
//! tiny PJRT model uses dense per-slot caches underneath, so here the pages
//! are an *accounting* structure (host-memory figures in Table 3 come from
//! it), with the same invariants as a real allocator.

/// Allocator over `num_blocks` fixed-size blocks of `block_tokens` tokens.
#[derive(Debug)]
pub struct KvAllocator {
    block_tokens: usize,
    free: Vec<u32>,
    num_blocks: usize,
    /// blocks[seq] = allocated block ids, in append order.
    tables: std::collections::HashMap<u64, Vec<u32>>,
}

impl KvAllocator {
    pub fn new(num_blocks: usize, block_tokens: usize) -> Self {
        assert!(block_tokens > 0);
        KvAllocator {
            block_tokens,
            free: (0..num_blocks as u32).rev().collect(),
            num_blocks,
            tables: std::collections::HashMap::new(),
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }
    pub fn num_blocks(&self) -> usize {
        self.num_blocks
    }
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }
    pub fn used_blocks(&self) -> usize {
        self.num_blocks - self.free.len()
    }

    /// Blocks needed to hold `tokens` tokens.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Can a new sequence of `tokens` tokens be admitted?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.free.len()
    }

    /// Reserve blocks for a new sequence covering `tokens` tokens.
    pub fn admit(&mut self, seq: u64, tokens: usize) -> Result<(), KvError> {
        if self.tables.contains_key(&seq) {
            return Err(KvError::AlreadyAdmitted(seq));
        }
        let need = self.blocks_for(tokens).max(1);
        if need > self.free.len() {
            return Err(KvError::OutOfBlocks { need, free: self.free.len() });
        }
        let blocks = (0..need).map(|_| self.free.pop().unwrap()).collect();
        self.tables.insert(seq, blocks);
        Ok(())
    }

    /// Grow a sequence to cover `tokens` tokens (allocates on block-boundary
    /// crossings only).
    pub fn grow(&mut self, seq: u64, tokens: usize) -> Result<(), KvError> {
        let need = self.blocks_for(tokens).max(1);
        let table = self.tables.get_mut(&seq).ok_or(KvError::Unknown(seq))?;
        while table.len() < need {
            match self.free.pop() {
                Some(b) => table.push(b),
                None => {
                    return Err(KvError::OutOfBlocks { need, free: 0 });
                }
            }
        }
        Ok(())
    }

    /// Release all blocks of a retired sequence.
    pub fn release(&mut self, seq: u64) -> Result<(), KvError> {
        let blocks = self.tables.remove(&seq).ok_or(KvError::Unknown(seq))?;
        self.free.extend(blocks);
        Ok(())
    }

    /// Block table of a sequence (physical block ids).
    pub fn table(&self, seq: u64) -> Option<&[u32]> {
        self.tables.get(&seq).map(|v| v.as_slice())
    }

    /// Invariant check: every block is either free or owned by exactly one
    /// sequence. Used by property tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen = vec![false; self.num_blocks];
        for &b in &self.free {
            let i = b as usize;
            if seen[i] {
                return Err(format!("block {b} double-counted (free)"));
            }
            seen[i] = true;
        }
        for (seq, table) in &self.tables {
            for &b in table {
                let i = b as usize;
                if seen[i] {
                    return Err(format!("block {b} double-counted (seq {seq})"));
                }
                seen[i] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("leaked blocks".into());
        }
        Ok(())
    }
}

/// Allocator error.
#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum KvError {
    #[error("sequence {0} already admitted")]
    AlreadyAdmitted(u64),
    #[error("sequence {0} unknown")]
    Unknown(u64),
    #[error("out of KV blocks: need {need}, free {free}")]
    OutOfBlocks { need: usize, free: usize },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_grow_release_roundtrip() {
        let mut a = KvAllocator::new(10, 16);
        a.admit(1, 20).unwrap(); // 2 blocks
        assert_eq!(a.used_blocks(), 2);
        a.grow(1, 33).unwrap(); // 3 blocks
        assert_eq!(a.used_blocks(), 3);
        a.grow(1, 33).unwrap(); // no-op
        assert_eq!(a.used_blocks(), 3);
        a.release(1).unwrap();
        assert_eq!(a.used_blocks(), 0);
        a.check_invariants().unwrap();
    }

    #[test]
    fn admission_control() {
        let mut a = KvAllocator::new(4, 16);
        assert!(a.can_admit(64));
        assert!(!a.can_admit(65));
        a.admit(1, 48).unwrap(); // 3 blocks
        assert!(a.can_admit(16));
        assert!(!a.can_admit(17));
        assert_eq!(
            a.admit(2, 32).unwrap_err(),
            KvError::OutOfBlocks { need: 2, free: 1 }
        );
    }

    #[test]
    fn double_admit_and_unknown_release_error() {
        let mut a = KvAllocator::new(4, 16);
        a.admit(1, 1).unwrap();
        assert_eq!(a.admit(1, 1).unwrap_err(), KvError::AlreadyAdmitted(1));
        assert_eq!(a.release(9).unwrap_err(), KvError::Unknown(9));
    }

    #[test]
    fn grow_failure_keeps_partial_consistent() {
        let mut a = KvAllocator::new(2, 4);
        a.admit(1, 4).unwrap();
        // needs 3 blocks total, only 1 free -> error, but invariants hold
        assert!(matches!(a.grow(1, 12), Err(KvError::OutOfBlocks { .. })));
        a.check_invariants().unwrap();
        a.release(1).unwrap();
        assert_eq!(a.free_blocks(), 2);
    }

    #[test]
    fn block_tables_are_disjoint() {
        let mut a = KvAllocator::new(8, 4);
        a.admit(1, 8).unwrap();
        a.admit(2, 8).unwrap();
        let t1: Vec<u32> = a.table(1).unwrap().to_vec();
        let t2: Vec<u32> = a.table(2).unwrap().to_vec();
        assert!(t1.iter().all(|b| !t2.contains(b)));
        a.check_invariants().unwrap();
    }
}
