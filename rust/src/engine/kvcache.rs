//! Paged KV-cache block allocator with a radix prefix index (vLLM /
//! SGLang-style substrate).
//!
//! The engine admits sequences only when blocks are available, extends a
//! sequence's block list as it grows, and frees on retirement. This governs
//! admission/preemption exactly as in PagedAttention-based engines; the
//! tiny PJRT model uses dense per-slot caches underneath, so here the pages
//! are an *accounting* structure (host-memory figures in Table 3 come from
//! it), with the same invariants as a real allocator.
//!
//! On top of the flat allocator sits a **token-keyed radix index** over
//! full blocks (DESIGN.md §13): when a sequence's prompt (or its full
//! history at retirement) is published, each full block becomes a node
//! keyed by the chained digest of the tokens it covers. A later admission
//! walks the index, *shares* the matched blocks (refcount bump, zero
//! copies) and only allocates the uncached tail. Blocks are copy-on-write
//! at block granularity: shared blocks are never written (the share is
//! capped so at least one known token stays uncached), and when the cap
//! cuts inside a matched block the allocator *forks* it — a private block
//! is allocated for the partially-reused content instead of aliasing the
//! shared one. Unreferenced index leaves are reclaimed LRU-first when the
//! free list runs dry, so the prefix cache consumes only otherwise-idle
//! blocks and can never cause an admission failure that a cache-less
//! allocator would not also have.

use std::collections::HashMap;

/// FNV-1a offset/prime — the same chained digest is used by the router's
/// approximate per-replica index, so engine and router agree on what "the
/// first k blocks of this prompt" hashes to.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Chained block-aligned digests: entry `i` digests tokens
/// `[0, (i+1)·block_tokens)` — i.e. each entry extends the previous one,
/// so a shared prefix of `k` full blocks means the first `k` digests agree.
pub fn block_digests(tokens: &[u32], block_tokens: usize) -> Vec<u64> {
    assert!(block_tokens > 0);
    let mut out = Vec::with_capacity(tokens.len() / block_tokens);
    let mut h = FNV_OFFSET;
    for chunk in tokens.chunks_exact(block_tokens) {
        for &t in chunk {
            h ^= t as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        out.push(h);
    }
    out
}

/// One full block in the radix index. `key` is the chained digest of the
/// token prefix ending at this block (its slot in the parent's child map);
/// `tokens` is the block's own content, kept to resolve digest collisions
/// content-exactly.
#[derive(Debug)]
struct RadixNode {
    key: u64,
    tokens: Vec<u32>,
    block: u32,
    /// `None` = child of the (implicit) root.
    parent: Option<usize>,
    children: HashMap<u64, usize>,
    last_use: u64,
}

/// Counters for the prefix cache (reported by the `prefixcache` harness).
#[derive(Debug, Default, Clone, Copy)]
pub struct PrefixStats {
    /// Admissions that consulted the index.
    pub lookups: u64,
    /// Admissions that shared at least one block.
    pub hits: u64,
    /// Known tokens whose prefill was skipped via sharing.
    pub hit_tokens: u64,
    /// Partially-reused blocks that were forked copy-on-write.
    pub cow_forks: u64,
    /// Index leaves reclaimed under pressure.
    pub evictions: u64,
    /// Full blocks published into the index.
    pub published: u64,
}

/// Outcome of a prefix-aware admission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdmitOutcome {
    /// Known tokens covered by shared (or forked) cached blocks — the
    /// sequence's prefill may start at this position.
    pub cached_tokens: usize,
    /// Full blocks shared by refcount (no allocation, no copy).
    pub shared_blocks: usize,
    /// Whether the tail of the match was forked copy-on-write.
    pub cow_fork: bool,
}

/// Feasibility probe for a prefix-aware admission (no mutation).
#[derive(Debug, Clone, Copy)]
pub struct AdmitProbe {
    /// Known tokens a real admission would start prefill at.
    pub cached_tokens: usize,
    /// Blocks a real admission would newly allocate.
    pub new_blocks: usize,
    /// Whether those blocks are available (free + evictable, excluding the
    /// matched path itself).
    pub fits: bool,
}

/// Allocator over `num_blocks` fixed-size blocks of `block_tokens` tokens.
#[derive(Debug)]
pub struct KvAllocator {
    block_tokens: usize,
    free: Vec<u32>,
    num_blocks: usize,
    /// refs[b] = number of sequence tables containing block b, plus 1 if a
    /// radix node holds it. Free blocks have refs[b] == 0.
    refs: Vec<u32>,
    /// blocks[seq] = allocated block ids, in append order. A (possibly
    /// empty) strict prefix of the table is shared full blocks; everything
    /// after is private to the sequence.
    tables: HashMap<u64, Vec<u32>>,
    /// Radix-node slab (`None` = free slot) + its free list.
    nodes: Vec<Option<RadixNode>>,
    node_free: Vec<usize>,
    /// Children of the implicit root, keyed by first-block digest.
    roots: HashMap<u64, usize>,
    /// LRU clock, bumped on every index touch.
    clock: u64,
    pub stats: PrefixStats,
}

impl KvAllocator {
    pub fn new(num_blocks: usize, block_tokens: usize) -> Self {
        assert!(block_tokens > 0);
        KvAllocator {
            block_tokens,
            free: (0..num_blocks as u32).rev().collect(),
            num_blocks,
            refs: vec![0; num_blocks],
            tables: HashMap::new(),
            nodes: Vec::new(),
            node_free: Vec::new(),
            roots: HashMap::new(),
            clock: 0,
            stats: PrefixStats::default(),
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }
    pub fn num_blocks(&self) -> usize {
        self.num_blocks
    }
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }
    pub fn used_blocks(&self) -> usize {
        self.num_blocks - self.free.len()
    }
    /// Blocks resident in the radix index (shared or merely cached).
    pub fn indexed_blocks(&self) -> usize {
        self.nodes.iter().flatten().count()
    }

    /// Blocks needed to hold `tokens` tokens.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    fn node(&self, id: usize) -> &RadixNode {
        self.nodes[id].as_ref().expect("live radix node")
    }

    /// Walk the index along `ctx`'s full blocks; returns matched node ids
    /// in depth order (an ancestor chain from the root).
    fn walk(&self, ctx: &[u32]) -> Vec<usize> {
        let mut path = Vec::new();
        let mut children = &self.roots;
        let mut h = FNV_OFFSET;
        for chunk in ctx.chunks_exact(self.block_tokens) {
            for &t in chunk {
                h ^= t as u64;
                h = h.wrapping_mul(FNV_PRIME);
            }
            match children.get(&h) {
                Some(&id) if self.node(id).tokens == chunk => {
                    path.push(id);
                    children = &self.node(id).children;
                }
                _ => break,
            }
        }
        path
    }

    /// Longest indexed prefix of `ctx`, in tokens (full blocks only,
    /// uncapped). Read-only; does not stamp LRU recency.
    pub fn lookup_prefix(&self, ctx: &[u32]) -> usize {
        self.walk(ctx).len() * self.block_tokens
    }

    /// Node ids whose subtree is fully reclaimable (every block referenced
    /// only by the index), excluding `keep` and its ancestors.
    fn reclaimable(&self, keep: &[usize]) -> Vec<usize> {
        let live: Vec<usize> =
            (0..self.nodes.len()).filter(|&i| self.nodes[i].is_some()).collect();
        // Children-first order: sort by depth, deepest first.
        let mut depth: HashMap<usize, usize> = HashMap::new();
        for &id in &live {
            let mut d = 0;
            let mut cur = self.node(id).parent;
            while let Some(p) = cur {
                d += 1;
                cur = self.node(p).parent;
            }
            depth.insert(id, d);
        }
        let mut order = live.clone();
        order.sort_by_key(|id| std::cmp::Reverse(depth[id]));
        let mut ok: HashMap<usize, bool> = HashMap::new();
        for &id in &order {
            let n = self.node(id);
            let all_children = n.children.values().all(|c| ok[c]);
            ok.insert(
                id,
                all_children && self.refs[n.block as usize] == 1 && !keep.contains(&id),
            );
        }
        live.into_iter().filter(|id| ok[id]).collect()
    }

    /// Blocks that could be handed out right now: free + reclaimable.
    pub fn available_blocks(&self) -> usize {
        self.free.len() + self.reclaimable(&[]).len()
    }

    /// Evict the least-recently-used reclaimable leaf; returns its block
    /// (now ref 0, *not* pushed to the free list — callers either reuse it
    /// or push it themselves).
    fn evict_lru_leaf(&mut self) -> Option<u32> {
        let mut best: Option<(u64, usize)> = None;
        for (id, slot) in self.nodes.iter().enumerate() {
            if let Some(n) = slot {
                if n.children.is_empty() && self.refs[n.block as usize] == 1 {
                    match best {
                        Some((lu, _)) if lu <= n.last_use => {}
                        _ => best = Some((n.last_use, id)),
                    }
                }
            }
        }
        let (_, id) = best?;
        self.stats.evictions += 1;
        crate::trace::metrics::inc(&crate::trace::metrics::counters().lru_evictions);
        crate::trace::instant(crate::trace::Kind::KvEvict, id as u64, 0);
        Some(self.remove_node(id))
    }

    /// Unlink a node from the trie and the slab; returns its block with the
    /// index's reference dropped.
    fn remove_node(&mut self, id: usize) -> u32 {
        let n = self.nodes[id].take().expect("live radix node");
        match n.parent {
            Some(p) => {
                self.nodes[p].as_mut().expect("live parent").children.remove(&n.key);
            }
            None => {
                self.roots.remove(&n.key);
            }
        }
        self.node_free.push(id);
        let b = n.block as usize;
        debug_assert!(self.refs[b] >= 1);
        self.refs[b] -= 1;
        n.block
    }

    /// Evict up to `n` LRU leaves back to the free list; returns how many
    /// blocks were reclaimed. Test/chaos hook for cache-pressure scenarios.
    pub fn evict(&mut self, n: usize) -> usize {
        let mut got = 0;
        for _ in 0..n {
            match self.evict_lru_leaf() {
                Some(b) => {
                    self.free.push(b);
                    got += 1;
                }
                None => break,
            }
        }
        got
    }

    /// Drop the whole index (every reclaimable node). Unreclaimable nodes
    /// (blocks still shared with live sequences) stay.
    pub fn clear_index(&mut self) {
        while let Some(b) = self.evict_lru_leaf() {
            self.free.push(b);
        }
    }

    /// Pop a free block, falling back to LRU eviction. Returned block has
    /// ref 0; the caller installs it (and its refcount) or rolls back.
    fn alloc_block(&mut self) -> Option<u32> {
        self.free.pop().or_else(|| self.evict_lru_leaf())
    }

    /// Can a new sequence of `tokens` tokens be admitted (ignoring any
    /// prefix sharing)?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.blocks_for(tokens).max(1) <= self.available_blocks()
    }

    /// Feasibility + benefit of admitting `total` tokens whose known
    /// context is `ctx`, with prefix sharing. Read-only.
    pub fn probe(&self, ctx: &[u32], total: usize) -> AdmitProbe {
        debug_assert!(total >= ctx.len());
        let path = self.walk(ctx);
        let cap = ctx.len().saturating_sub(1);
        let cached = (path.len() * self.block_tokens).min(cap);
        let shared = cached / self.block_tokens;
        let new_blocks = self.blocks_for(total).max(1) - shared;
        let avail = self.free.len() + self.reclaimable(&path).len();
        AdmitProbe { cached_tokens: cached, new_blocks, fits: new_blocks <= avail }
    }

    /// Reserve blocks for a new sequence covering `tokens` tokens, without
    /// consulting the prefix index.
    pub fn admit(&mut self, seq: u64, tokens: usize) -> Result<(), KvError> {
        self.admit_shared(seq, &[], tokens).map(|_| ())
    }

    /// Reserve blocks for a new sequence of `total` tokens whose known
    /// context (prompt ⧺ replayed output) is `ctx`, sharing the longest
    /// indexed prefix instead of reallocating it.
    ///
    /// The share is capped at `ctx.len() - 1`: at least one known token is
    /// always left uncached so the forward still produces this sequence's
    /// decision logits. When that cap lands mid-block, the partially-reused
    /// block is **forked copy-on-write** — a private block is allocated for
    /// it rather than aliasing the shared one, since positions inside it
    /// will be written. On failure the call is a no-op.
    pub fn admit_shared(
        &mut self,
        seq: u64,
        ctx: &[u32],
        total: usize,
    ) -> Result<AdmitOutcome, KvError> {
        assert!(total >= ctx.len(), "admitted capacity below known context");
        if self.tables.contains_key(&seq) {
            return Err(KvError::AlreadyAdmitted(seq));
        }
        let path = self.walk(ctx);
        let cap = ctx.len().saturating_sub(1);
        let cached = (path.len() * self.block_tokens).min(cap);
        let shared = cached / self.block_tokens;
        let cow = cached > shared * self.block_tokens;
        let need = self.blocks_for(total).max(1);
        debug_assert!(need > shared, "shared prefix must leave a writable tail block");

        // Pin the shared prefix first so eviction inside alloc_block can
        // never reclaim the very nodes this admission depends on.
        for &id in &path[..shared] {
            let b = self.node(id).block as usize;
            self.refs[b] += 1;
        }
        let now = self.tick();
        for &id in &path {
            self.nodes[id].as_mut().expect("live radix node").last_use = now;
        }

        let mut fresh: Vec<u32> = Vec::with_capacity(need - shared);
        for _ in shared..need {
            match self.alloc_block() {
                Some(b) => fresh.push(b),
                None => {
                    // Roll back: this admission is a no-op.
                    for &id in &path[..shared] {
                        let b = self.node(id).block as usize;
                        self.refs[b] -= 1;
                    }
                    self.free.extend(fresh);
                    return Err(KvError::OutOfBlocks { need, free: self.free.len() });
                }
            }
        }

        let mut table: Vec<u32> =
            path[..shared].iter().map(|&id| self.node(id).block).collect();
        for &b in &fresh {
            self.refs[b as usize] += 1;
        }
        table.extend(fresh);
        self.tables.insert(seq, table);

        self.stats.lookups += !ctx.is_empty() as u64;
        if cached > 0 {
            self.stats.hits += 1;
            self.stats.hit_tokens += cached as u64;
            crate::trace::metrics::inc(&crate::trace::metrics::counters().prefix_hits);
            crate::trace::instant(crate::trace::Kind::KvHit, seq, cached as u64);
        } else if !ctx.is_empty() {
            crate::trace::metrics::inc(&crate::trace::metrics::counters().prefix_misses);
            crate::trace::instant(crate::trace::Kind::KvMiss, seq, 0);
        }
        self.stats.cow_forks += cow as u64;
        if cow {
            crate::trace::metrics::inc(&crate::trace::metrics::counters().cow_forks);
            crate::trace::instant(crate::trace::Kind::KvCowFork, seq, 0);
        }
        Ok(AdmitOutcome { cached_tokens: cached, shared_blocks: shared, cow_fork: cow })
    }

    /// Grow a sequence to cover `tokens` tokens (allocates on block-boundary
    /// crossings only). On `OutOfBlocks` the call is a **no-op**: blocks
    /// allocated within the failing call are rolled back, so callers never
    /// see a partially-grown table.
    pub fn grow(&mut self, seq: u64, tokens: usize) -> Result<(), KvError> {
        let need = self.blocks_for(tokens).max(1);
        let have = self.tables.get(&seq).ok_or(KvError::Unknown(seq))?.len();
        if need <= have {
            return Ok(());
        }
        let mut fresh: Vec<u32> = Vec::with_capacity(need - have);
        for _ in have..need {
            match self.alloc_block() {
                Some(b) => fresh.push(b),
                None => {
                    self.free.extend(fresh);
                    return Err(KvError::OutOfBlocks { need, free: self.free.len() });
                }
            }
        }
        for &b in &fresh {
            self.refs[b as usize] += 1;
        }
        self.tables.get_mut(&seq).expect("checked above").extend(fresh);
        Ok(())
    }

    /// Publish the full blocks of `seq` covering `ctx` (the sequence's
    /// materialized token content, table-aligned) into the radix index, so
    /// later admissions can share them. Idempotent: already-indexed prefixes
    /// are descended, only new depths insert nodes. Safe to call once the
    /// content is materialized (prefill committed past each block).
    pub fn publish(&mut self, seq: u64, ctx: &[u32]) -> Result<usize, KvError> {
        let table = self.tables.get(&seq).ok_or(KvError::Unknown(seq))?.clone();
        let full = (ctx.len() / self.block_tokens).min(table.len());
        let mut parent: Option<usize> = None;
        let mut h = FNV_OFFSET;
        let mut inserted = 0;
        let now = self.tick();
        for (d, chunk) in ctx.chunks_exact(self.block_tokens).take(full).enumerate() {
            for &t in chunk {
                h ^= t as u64;
                h = h.wrapping_mul(FNV_PRIME);
            }
            let children = match parent {
                Some(p) => &self.node(p).children,
                None => &self.roots,
            };
            if let Some(&id) = children.get(&h) {
                if self.node(id).tokens == chunk {
                    // Already indexed (possibly under another sequence's
                    // block with equal content) — descend, stamp recency.
                    self.nodes[id].as_mut().expect("live radix node").last_use = now;
                    parent = Some(id);
                    continue;
                }
                // Digest collision with different content: stop extending.
                break;
            }
            let block = table[d];
            self.refs[block as usize] += 1;
            let node = RadixNode {
                key: h,
                tokens: chunk.to_vec(),
                block,
                parent,
                children: HashMap::new(),
                last_use: now,
            };
            let id = match self.node_free.pop() {
                Some(i) => {
                    self.nodes[i] = Some(node);
                    i
                }
                None => {
                    self.nodes.push(Some(node));
                    self.nodes.len() - 1
                }
            };
            match parent {
                Some(p) => {
                    self.nodes[p].as_mut().expect("live parent").children.insert(h, id);
                }
                None => {
                    self.roots.insert(h, id);
                }
            }
            inserted += 1;
            parent = Some(id);
        }
        self.stats.published += inserted as u64;
        Ok(inserted)
    }

    /// Release all blocks of a retired sequence. Blocks still referenced by
    /// the radix index (or other sequences) stay allocated; the rest return
    /// to the free list.
    pub fn release(&mut self, seq: u64) -> Result<(), KvError> {
        let blocks = self.tables.remove(&seq).ok_or(KvError::Unknown(seq))?;
        for b in blocks {
            let i = b as usize;
            debug_assert!(self.refs[i] >= 1);
            self.refs[i] -= 1;
            if self.refs[i] == 0 {
                self.free.push(b);
            }
        }
        Ok(())
    }

    /// Block table of a sequence (physical block ids).
    pub fn table(&self, seq: u64) -> Option<&[u32]> {
        self.tables.get(&seq).map(|v| v.as_slice())
    }

    /// Invariant check: every block is either free (ref 0) or covered by
    /// exactly `refs[b]` owners — one per sequence table containing it plus
    /// one if a radix node holds it. No leaks, no double-frees, no aliasing
    /// inside a single table, trie structure consistent. Used by property
    /// tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut count = vec![0u32; self.num_blocks];
        for (seq, table) in &self.tables {
            let mut in_table = std::collections::HashSet::new();
            for &b in table {
                if !in_table.insert(b) {
                    return Err(format!("block {b} aliased within seq {seq}'s table"));
                }
                count[b as usize] += 1;
            }
        }
        let mut node_blocks = std::collections::HashSet::new();
        for (id, slot) in self.nodes.iter().enumerate() {
            let Some(n) = slot else { continue };
            if n.tokens.len() != self.block_tokens {
                return Err(format!("node {id} holds a partial block"));
            }
            if !node_blocks.insert(n.block) {
                return Err(format!("block {} indexed twice", n.block));
            }
            count[n.block as usize] += 1;
            let children = match n.parent {
                Some(p) => match self.nodes.get(p).and_then(|s| s.as_ref()) {
                    Some(pn) => &pn.children,
                    None => return Err(format!("node {id} has a dead parent")),
                },
                None => &self.roots,
            };
            if children.get(&n.key) != Some(&id) {
                return Err(format!("node {id} unlinked from its parent"));
            }
        }
        let mut in_free = std::collections::HashSet::new();
        for &b in &self.free {
            if !in_free.insert(b) {
                return Err(format!("block {b} double-counted (free)"));
            }
            if count[b as usize] != 0 {
                return Err(format!("block {b} both free and referenced"));
            }
        }
        for b in 0..self.num_blocks {
            if self.refs[b] != count[b] {
                return Err(format!(
                    "block {b} refcount {} != recount {}",
                    self.refs[b], count[b]
                ));
            }
            if count[b] == 0 && !in_free.contains(&(b as u32)) {
                return Err(format!("block {b} leaked (unreferenced, not free)"));
            }
        }
        Ok(())
    }
}

/// Allocator error.
#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum KvError {
    #[error("sequence {0} already admitted")]
    AlreadyAdmitted(u64),
    #[error("sequence {0} unknown")]
    Unknown(u64),
    #[error("out of KV blocks: need {need}, free {free}")]
    OutOfBlocks { need: usize, free: usize },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_grow_release_roundtrip() {
        let mut a = KvAllocator::new(10, 16);
        a.admit(1, 20).unwrap(); // 2 blocks
        assert_eq!(a.used_blocks(), 2);
        a.grow(1, 33).unwrap(); // 3 blocks
        assert_eq!(a.used_blocks(), 3);
        a.grow(1, 33).unwrap(); // no-op
        assert_eq!(a.used_blocks(), 3);
        a.release(1).unwrap();
        assert_eq!(a.used_blocks(), 0);
        a.check_invariants().unwrap();
    }

    #[test]
    fn admission_control() {
        let mut a = KvAllocator::new(4, 16);
        assert!(a.can_admit(64));
        assert!(!a.can_admit(65));
        a.admit(1, 48).unwrap(); // 3 blocks
        assert!(a.can_admit(16));
        assert!(!a.can_admit(17));
        assert_eq!(
            a.admit(2, 32).unwrap_err(),
            KvError::OutOfBlocks { need: 2, free: 1 }
        );
    }

    #[test]
    fn double_admit_and_unknown_release_error() {
        let mut a = KvAllocator::new(4, 16);
        a.admit(1, 1).unwrap();
        assert_eq!(a.admit(1, 1).unwrap_err(), KvError::AlreadyAdmitted(1));
        assert_eq!(a.release(9).unwrap_err(), KvError::Unknown(9));
    }

    #[test]
    fn grow_failure_is_a_no_op() {
        let mut a = KvAllocator::new(3, 4);
        a.admit(1, 4).unwrap();
        let before = a.table(1).unwrap().to_vec();
        // needs 4 blocks total, only 2 free -> error, and the table must be
        // exactly as before the call (satellite: no partial growth)
        assert!(matches!(a.grow(1, 16), Err(KvError::OutOfBlocks { .. })));
        assert_eq!(a.table(1).unwrap(), &before[..]);
        assert_eq!(a.free_blocks(), 2);
        a.check_invariants().unwrap();
        a.release(1).unwrap();
        assert_eq!(a.free_blocks(), 3);
    }

    #[test]
    fn block_tables_are_disjoint() {
        let mut a = KvAllocator::new(8, 4);
        a.admit(1, 8).unwrap();
        a.admit(2, 8).unwrap();
        let t1: Vec<u32> = a.table(1).unwrap().to_vec();
        let t2: Vec<u32> = a.table(2).unwrap().to_vec();
        assert!(t1.iter().all(|b| !t2.contains(b)));
        a.check_invariants().unwrap();
    }

    fn ctx(n: usize) -> Vec<u32> {
        (0..n as u32).map(|i| i * 7 + 3).collect()
    }

    #[test]
    fn publish_then_share_skips_prefill() {
        let mut a = KvAllocator::new(16, 4);
        let c = ctx(10); // 2 full blocks + 2 tokens
        a.admit_shared(1, &c, 11).unwrap();
        a.publish(1, &c).unwrap();
        assert_eq!(a.indexed_blocks(), 2);
        assert_eq!(a.lookup_prefix(&c), 8);
        // A second sequence with the same context shares both full blocks.
        let out = a.admit_shared(2, &c, 11).unwrap();
        assert_eq!(out, AdmitOutcome { cached_tokens: 8, shared_blocks: 2, cow_fork: false });
        assert_eq!(&a.table(2).unwrap()[..2], &a.table(1).unwrap()[..2]);
        a.check_invariants().unwrap();
        // Release both: published blocks stay resident in the index.
        a.release(1).unwrap();
        a.release(2).unwrap();
        assert_eq!(a.indexed_blocks(), 2);
        assert_eq!(a.lookup_prefix(&c), 8);
        a.check_invariants().unwrap();
    }

    #[test]
    fn block_aligned_match_forks_cow() {
        let mut a = KvAllocator::new(16, 4);
        let c = ctx(8); // exactly 2 blocks
        a.admit_shared(1, &c, 9).unwrap();
        a.publish(1, &c).unwrap();
        // Same 8-token context: the match covers the whole prompt, but one
        // token must stay uncached -> the cap cuts inside block 1 -> fork.
        let out = a.admit_shared(2, &c, 9).unwrap();
        assert_eq!(out, AdmitOutcome { cached_tokens: 7, shared_blocks: 1, cow_fork: true });
        // Block 0 shared, block 1 forked private.
        assert_eq!(a.table(2).unwrap()[0], a.table(1).unwrap()[0]);
        assert_ne!(a.table(2).unwrap()[1], a.table(1).unwrap()[1]);
        assert_eq!(a.stats.cow_forks, 1);
        a.check_invariants().unwrap();
    }

    #[test]
    fn eviction_reclaims_lru_leaves_under_pressure() {
        let mut a = KvAllocator::new(4, 4);
        a.admit_shared(1, &ctx(8), 8).unwrap(); // 2 blocks
        a.publish(1, &ctx(8)).unwrap();
        a.release(1).unwrap();
        assert_eq!(a.free_blocks(), 2);
        assert_eq!(a.indexed_blocks(), 2);
        assert_eq!(a.available_blocks(), 4);
        // Admitting an unrelated 4-block sequence must evict the cached
        // chain (leaf first, then its parent) rather than fail.
        let other: Vec<u32> = (100..116).collect();
        let out = a.admit_shared(2, &other, 16).unwrap();
        assert_eq!(out.cached_tokens, 0);
        assert_eq!(a.indexed_blocks(), 0);
        assert_eq!(a.stats.evictions, 2);
        a.check_invariants().unwrap();
    }

    #[test]
    fn shared_blocks_survive_pressure() {
        let mut a = KvAllocator::new(4, 4);
        a.admit_shared(1, &ctx(8), 8).unwrap();
        a.publish(1, &ctx(8)).unwrap();
        // Seq 1 still owns its blocks: nothing is evictable, so a 3-block
        // admission must fail cleanly (and leave refcounts untouched).
        let other: Vec<u32> = (100..112).collect();
        assert!(matches!(
            a.admit_shared(2, &other, 12),
            Err(KvError::OutOfBlocks { .. })
        ));
        a.check_invariants().unwrap();
        assert_eq!(a.lookup_prefix(&ctx(8)), 8, "shared prefix not evicted");
    }

    #[test]
    fn partial_eviction_shortens_the_hit() {
        let mut a = KvAllocator::new(8, 4);
        let c = ctx(16); // 4 full blocks
        a.admit_shared(1, &c, 16).unwrap();
        a.publish(1, &c).unwrap();
        a.release(1).unwrap();
        assert_eq!(a.lookup_prefix(&c), 16);
        // Evict two leaves: the chain shrinks from the tail, so the hit is
        // now 2 blocks — a resume onto this prefix recomputes only the rest.
        assert_eq!(a.evict(2), 2);
        assert_eq!(a.lookup_prefix(&c), 8);
        let out = a.admit_shared(2, &c, 17).unwrap();
        assert_eq!(out.cached_tokens, 8);
        a.check_invariants().unwrap();
    }

    #[test]
    fn probe_matches_admit() {
        let mut a = KvAllocator::new(6, 4);
        let c = ctx(12);
        a.admit_shared(1, &c, 13).unwrap();
        a.publish(1, &c).unwrap();
        let p = a.probe(&c, 13);
        assert!(p.fits);
        let out = a.admit_shared(2, &c, 13).unwrap();
        assert_eq!(p.cached_tokens, out.cached_tokens);
        // 6 blocks total: seq1 holds 4, seq2 shares 3 + allocates 1 -> 1
        // free; a 2-block stranger does not fit and probe must agree.
        let stranger: Vec<u32> = (900..908).collect();
        assert!(!a.probe(&stranger, 8).fits);
        a.check_invariants().unwrap();
    }

    #[test]
    fn digests_are_chained_and_block_aligned() {
        let c = ctx(12);
        let d4 = block_digests(&c, 4);
        assert_eq!(d4.len(), 3);
        // Shared prefix -> shared digest chain, divergence flips the rest.
        let mut c2 = c.clone();
        c2[9] ^= 1;
        let e4 = block_digests(&c2, 4);
        assert_eq!(d4[..2], e4[..2]);
        assert_ne!(d4[2], e4[2]);
        // Trailing partial blocks contribute nothing.
        assert_eq!(block_digests(&c[..11], 4).len(), 2);
    }
}
