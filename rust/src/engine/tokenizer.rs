//! Toy byte-level tokenizer for the runnable examples.
//!
//! Token ids: 0 = PAD, 1 = BOS, 2 = EOS, 3..258 = raw bytes. Any vocab
//! ≥ 259 can round-trip arbitrary UTF-8; the AOT models' vocabularies are
//! far larger, so ids above 258 only ever appear as *generated* tokens and
//! are rendered as `⟨id⟩` placeholders.

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;
const BYTE_BASE: u32 = 3;

/// Encode text as BOS + bytes.
pub fn encode(text: &str) -> Vec<u32> {
    let mut out = Vec::with_capacity(text.len() + 1);
    out.push(BOS);
    out.extend(text.bytes().map(|b| BYTE_BASE + b as u32));
    out
}

/// Decode ids back to text (non-byte ids become `⟨id⟩`).
pub fn decode(ids: &[u32]) -> String {
    let mut bytes = Vec::new();
    let mut out = String::new();
    let flush = |bytes: &mut Vec<u8>, out: &mut String| {
        if !bytes.is_empty() {
            out.push_str(&String::from_utf8_lossy(bytes));
            bytes.clear();
        }
    };
    for &id in ids {
        match id {
            PAD | BOS | EOS => flush(&mut bytes, &mut out),
            _ if id >= BYTE_BASE && id < BYTE_BASE + 256 => {
                bytes.push((id - BYTE_BASE) as u8)
            }
            other => {
                flush(&mut bytes, &mut out);
                out.push_str(&format!("⟨{other}⟩"));
            }
        }
    }
    flush(&mut bytes, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii_and_utf8() {
        for text in ["hello world", "héllo → 世界", ""] {
            let ids = encode(text);
            assert_eq!(ids[0], BOS);
            assert_eq!(decode(&ids), text);
        }
    }

    #[test]
    fn non_byte_ids_render_as_placeholders() {
        let out = decode(&[BOS, 3 + b'h' as u32, 999]);
        assert_eq!(out, "h⟨999⟩");
    }

    #[test]
    fn specials_are_silent() {
        assert_eq!(decode(&[PAD, EOS, BOS]), "");
    }
}
