//! # simple-serve
//!
//! Reproduction of **SIMPLE: Disaggregating Sampling from GPU Inference into a
//! Decision Plane for Faster Distributed LLM Serving** (CS.DC 2025).
//!
//! SIMPLE observes that in TP×PP-distributed LLM serving the *sampling* step —
//! the "decision plane" that turns logits into tokens — is a structural
//! holdout: it does not shard along tensor-parallel axes, it runs only on the
//! last pipeline stage, and its memory-bound `O(V)` scans do not shrink as
//! GEMMs get faster. SIMPLE disaggregates sampling into a CPU-side service
//! that is *parallelizable* (sequence-parallel across the batch axis),
//! *stage-agnostic* (off the PP critical path), and *overlappable* (hidden
//! under GPU compute), using three mechanisms:
//!
//! 1. **Sequence-parallel sampling** ([`decision::service`]) — shard the batch
//!    across `m` samplers reading TP-sharded, vocabulary-major logits blocks
//!    from shared-memory rings with zero copies.
//! 2. **Column-wise penalties + truncation-first filtering**
//!    ([`decision::penalties`], [`decision::filter`]) — single-pass,
//!    linear-time CPU kernels.
//! 3. **Speculative hot-vocab sampling** ([`decision::shvs`]) — sample on a
//!    small Zipf-head hot set, correct with rejection sampling (distribution-
//!    ally exact), and size the hot set with an analytic throughput model
//!    ([`decision::sizing`]).
//!
//! ## Architecture (three layers)
//!
//! - **L3 (this crate)** — the serving coordinator and the paper's decision
//!   plane, on the request path.
//! - **L2 (JAX, build time)** — a decode-step transformer producing logits,
//!   lowered once to HLO text (`python/compile/`).
//! - **L1 (Pallas, build time)** — the fused LM-head + SHVS-weight kernel
//!   inside the L2 graph.
//!
//! The [`runtime`] module loads the AOT artifacts through PJRT; the
//! [`simulator`] module provides the distributed-GPU timing substrate used to
//! regenerate the paper's figures on a CPU-only host (see `DESIGN.md` §2).
//! The [`cluster`] module scales the same decision plane across the fleet
//! axis: data-parallel engine replicas behind a decision-plane-aware router,
//! optionally sharing one sampler pool (`DESIGN.md` §9).

// Config structs (EngineConfig, SamplerConfig, SimConfig, …) are built by
// `let mut cfg = X::default();` followed by field assignments throughout
// the harness, examples, and tests — the idiomatic shape for sweep drivers
// that tweak one knob per run. Keep that style rather than fighting the
// lint; everything else in clippy's default set is enforced (`make ci`).
#![allow(clippy::field_reassign_with_default)]
// `--cfg loom` is injected via RUSTFLAGS by `make loom` (and declared by
// build.rs); tolerate toolchains that compile without the build script.
#![allow(unexpected_cfgs)]
// Concurrency hygiene for the lock-free decision plane (DESIGN.md §15):
// every unsafe operation needs its own block (and, by `make lint`, its
// own `// SAFETY:` argument).
#![deny(unsafe_op_in_unsafe_fn)]
#![deny(unused_unsafe)]

pub mod bench;
pub mod cluster;
pub mod config;
pub mod decision;
pub mod engine;
pub mod fault;
pub mod harness;
pub mod metrics;
pub mod ringbuf;
pub mod rng;
pub mod runtime;
pub mod simulator;
pub mod tensor;
pub mod trace;
pub mod util;
pub mod workload;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
