//! The `chaos` experiment (DESIGN.md §10): fault injection against the
//! decision plane and the cluster, proving the recovery hard bar on the
//! context-faithful synthetic plane — no artifacts needed.
//!
//! Two sections:
//! 1. **Measured chaos sweep** — a matrix of [`FaultPlan`]s (sampler
//!    kills, legacy `poison@` events — now clean worker kills, the
//!    lock-free service has no poisonable hot-path mutex — replica
//!    kills, and combinations) × engine shapes (replicas × samplers ×
//!    spec_k × microbatches × shared pool).
//!    Every run's fleet stream digest must equal the fault-free
//!    single-engine baseline: **recovery replays state, it never invents
//!    or loses tokens**. The run also reports what the recovery machinery
//!    did (sampler respawns, replica failovers, requeued sequences) and
//!    what it cost (`recovery_s`).
//! 2. **Simulated fault model** — `simulate_cluster` with a replica death
//!    at half the fault-free makespan, showing the throughput/latency
//!    cost of losing capacity + recomputing orphans on a paper-scale
//!    deployment, next to the healthy fleet.
//!
//! This experiment IS the chaos digest gate (`make chaos-smoke` in CI): a
//! fault plan that changes even one token fails the run loudly.

use super::{Effort, Report};
use crate::cluster::{Cluster, ClusterConfig, ClusterReport, RoutePolicy};
use crate::config::{DecisionVariant, EngineConfig, ModelSpec, ParallelConfig, PlatformSpec};
use crate::engine::{Engine, Request, SyntheticRuntime};
use crate::fault::FaultPlan;
use crate::simulator::{simulate_cluster, ClusterSimConfig, DecisionMode, GpuModel, SimConfig};
use crate::util::json::Json;
use crate::workload::{self, TraceConfig};
use std::fmt::Write;

const VOCAB: usize = 2_048;
const MAX_SEQ: usize = 96;
const BATCH: usize = 4;
const PLANE_SEED: u64 = 47;

fn engine_cfg(m: usize, spec_k: usize, n_mb: usize) -> EngineConfig {
    let mut cfg = EngineConfig::default();
    cfg.sampler.variant = DecisionVariant::Offloading;
    cfg.sampler.num_samplers = m;
    cfg.sampler.seed = 0xFA_17;
    cfg.spec_k = spec_k;
    cfg.n_microbatches = n_mb;
    cfg.overlap = n_mb > 1;
    cfg.idle_poll_us = 20;
    cfg
}

fn trace(n: usize) -> Vec<Request> {
    workload::generate(&TraceConfig::tiny(n, VOCAB)).requests
}

/// Fault-free ground truth: one engine serving the whole trace.
fn baseline_digest(n: usize) -> u64 {
    let cfg = engine_cfg(1, 0, 1);
    let runtime = SyntheticRuntime::new(BATCH, VOCAB, MAX_SEQ, PLANE_SEED);
    let mut engine = Engine::new(runtime, &cfg, None);
    for r in trace(n) {
        engine.submit(r);
    }
    engine.run_until_idle().expect("baseline engine run");
    let digest = crate::util::stream_digest(
        engine
            .take_finished()
            .into_iter()
            .map(|f| (f.request.id, f.output))
            .collect(),
    );
    engine.shutdown();
    digest
}

/// One chaos case in the measured sweep.
struct Case {
    name: &'static str,
    plan: &'static str,
    replicas: usize,
    m: usize,
    spec_k: usize,
    n_mb: usize,
    shared: bool,
}

fn run_case(n: usize, case: &Case) -> ClusterReport {
    let plan = FaultPlan::parse(case.plan).expect("case plan parses");
    let (engine_faults, router_faults) = plan.split();
    let mut cfg = engine_cfg(case.m, case.spec_k, case.n_mb);
    cfg.faults = engine_faults;
    let mut ccfg = ClusterConfig::default();
    ccfg.replicas = case.replicas;
    ccfg.policy = RoutePolicy::RoundRobin;
    ccfg.shared_samplers = case.shared;
    ccfg.idle_poll_us = 20;
    ccfg.faults = router_faults;
    let mut cluster = Cluster::start(&cfg, &ccfg, None, MAX_SEQ, |_id| {
        Ok(SyntheticRuntime::new(BATCH, VOCAB, MAX_SEQ, PLANE_SEED))
    });
    cluster.run(trace(n)).expect("chaos run must recover, not fail");
    cluster.shutdown().expect("chaos shutdown")
}

/// The `chaos` experiment driver.
pub fn chaos(effort: Effort) -> Report {
    let n_req = effort.scale(12, 48) as usize;
    let want = baseline_digest(n_req);

    // Snapshot the process-global decision-plane counters (DESIGN.md §14)
    // around the sweep: the fault plans must drive the instrumented
    // recovery paths — steals, sampler respawns, router requeues — not
    // just produce matching digests.
    let c0 = crate::trace::metrics::counters().snapshot();

    // The sweep: every engine-level and router-level fault domain, alone
    // and combined, across the executor shapes that complicate recovery
    // (speculation, microbatch overlap, shared pools, multiple replicas).
    #[rustfmt::skip]
    let cases = [
        Case { name: "sampler kill", plan: "sampler:0@4",
               replicas: 1, m: 2, spec_k: 0, n_mb: 1, shared: false },
        Case { name: "sampler kill ×2", plan: "sampler:1@3,sampler:0@9",
               replicas: 1, m: 2, spec_k: 0, n_mb: 1, shared: false },
        Case { name: "legacy poison (worker kill)", plan: "poison@2",
               replicas: 1, m: 2, spec_k: 0, n_mb: 1, shared: false },
        Case { name: "kill under spec", plan: "sampler:0@5",
               replicas: 1, m: 2, spec_k: 3, n_mb: 1, shared: false },
        Case { name: "kill under overlap", plan: "sampler:1@4",
               replicas: 1, m: 2, spec_k: 2, n_mb: 2, shared: false },
        Case { name: "replica kill", plan: "replica:1@4",
               replicas: 2, m: 2, spec_k: 0, n_mb: 1, shared: false },
        Case { name: "replica kill, shared pool", plan: "replica:1@4",
               replicas: 2, m: 2, spec_k: 0, n_mb: 1, shared: true },
        Case { name: "sampler + replica", plan: "sampler:0@3,replica:1@6",
               replicas: 2, m: 2, spec_k: 2, n_mb: 1, shared: false },
        Case { name: "everything at once", plan: "sampler:0@3,poison@5,replica:1@6",
               replicas: 2, m: 2, spec_k: 2, n_mb: 2, shared: true },
    ];

    let mut md = format!(
        "### chaos — injected faults vs the recovery hard bar (synthetic \
         plane, {n_req} requests, fault-free digest {want:016x})\n\n\
         | case | plan | fleet | respawn+failover | requeued | recovery | digest ok |\n\
         |---|---|---|---:|---:|---:|---|\n",
    );
    let mut rows = Vec::new();
    let mut identical = true;
    for case in &cases {
        let report = run_case(n_req, case);
        let digest = report.stream_digest();
        let ok = digest == want;
        identical &= ok;
        let recoveries = report.recorder.recoveries();
        let recovery_ms = report.recorder.recovery_s() * 1e3;
        let fleet = format!(
            "{}r × m{}{}{}{}",
            case.replicas,
            case.m,
            if case.spec_k > 0 { format!(" k{}", case.spec_k) } else { String::new() },
            if case.n_mb > 1 { format!(" mb{}", case.n_mb) } else { String::new() },
            if case.shared { " shared" } else { "" },
        );
        let _ = writeln!(
            md,
            "| {} | `{}` | {} | {} | {} | {:.2} ms | {} |",
            case.name, case.plan, fleet, recoveries, report.requeued, recovery_ms, ok,
        );
        rows.push(Json::obj(vec![
            ("case", Json::Str(case.name.into())),
            ("plan", Json::Str(case.plan.into())),
            ("replicas", Json::Num(case.replicas as f64)),
            ("samplers", Json::Num(case.m as f64)),
            ("spec_k", Json::Num(case.spec_k as f64)),
            ("n_microbatches", Json::Num(case.n_mb as f64)),
            ("shared_pool", Json::Bool(case.shared)),
            ("recoveries", Json::Num(recoveries as f64)),
            ("failovers", Json::Num(report.failovers as f64)),
            ("requeued", Json::Num(report.requeued as f64)),
            ("recovery_s", Json::Num(report.recorder.recovery_s())),
            ("digest_ok", Json::Bool(ok)),
        ]));
    }
    let c1 = crate::trace::metrics::counters().snapshot();
    let counter_deltas: Vec<(&'static str, u64)> = c0
        .iter()
        .zip(&c1)
        .map(|(&(name, before), &(_, after))| (name, after.saturating_sub(before)))
        .collect();
    let delta = |key: &str| {
        counter_deltas.iter().find(|(n, _)| *n == key).map(|(_, v)| *v).unwrap_or(0)
    };
    let _ = writeln!(
        md,
        "\nall digests equal the fault-free baseline: **{identical}** \
         (recovery replays state; it never invents or loses tokens)\n\n\
         recovery machinery counters across the sweep: {} steals, {} sampler \
         respawns, {} router requeues\n",
        delta("steals"),
        delta("sampler_respawns"),
        delta("router_requeues"),
    );

    // Simulated fault model on a paper deployment.
    md.push_str(
        "simulated replica death (H100, Qwen3-235B-A22B, 3 replicas, \
         roofline model):\n\n\
         | fleet | tok/s | makespan | requeued |\n|---|---:|---:|---:|\n",
    );
    let model = ModelSpec::qwen3_235b_a22b();
    let platform = PlatformSpec::h100();
    let parallel = ParallelConfig::paper_preset(&model, &platform).unwrap();
    let sim_n = effort.scale(120, 480) as usize;
    let sim_trace = {
        let t = workload::generate(&TraceConfig::sharegpt_like(sim_n, model.vocab, 4096));
        crate::simulator::serving::to_sim_requests(&t)
    };
    let gpu = GpuModel::new(model.clone(), platform.clone(), parallel);
    let sim_cfg = SimConfig::new(
        gpu,
        DecisionMode::SimpleOverlapped {
            per_seq_s: super::e2e::measured_shvs_per_seq(model.vocab, effort),
            samplers: 64,
        },
        32,
        platform.cpu_cores,
        64,
    );
    let mut healthy = ClusterSimConfig::default();
    healthy.replicas = 3;
    let base = simulate_cluster(&sim_cfg, &healthy, &sim_trace);
    let mut faulty = healthy.clone();
    faulty.fail_at_s = Some(base.recorder.summary().duration * 0.5);
    faulty.fail_replica = 1;
    let hit = simulate_cluster(&sim_cfg, &faulty, &sim_trace);
    let mut sim_rows = Vec::new();
    for (name, res) in [("healthy", &base), ("one death mid-run", &hit)] {
        let s = res.recorder.summary();
        let _ = writeln!(
            md,
            "| {name} | {:>8.0} | {:>7.2} s | {} |",
            s.throughput, s.duration, res.requeued
        );
        sim_rows.push(Json::obj(vec![
            ("fleet", Json::Str(name.into())),
            ("throughput", Json::Num(s.throughput)),
            ("duration_s", Json::Num(s.duration)),
            ("requeued", Json::Num(res.requeued as f64)),
        ]));
    }
    md.push_str(
        "\nthe measured rows prove recovery is exact (bit-identical \
         streams under any plan); the simulated rows price it (lost \
         capacity + recompute show up in makespan, never in tokens)\n",
    );

    // The experiment IS the chaos smoke gate (`make chaos-smoke` in CI).
    assert!(
        identical,
        "chaos digest mismatch: an injected fault changed the token \
         streams (recovery must replay, never improvise)"
    );
    // The counters are the observable face of recovery: a sweep that kills
    // samplers, workers, and replicas must steal orphaned work, respawn
    // the dead, and requeue the stranded — zero means the instrumentation
    // (or the recovery path) silently stopped firing.
    for key in ["steals", "sampler_respawns", "router_requeues"] {
        assert!(
            delta(key) > 0,
            "chaos sweep left the `{key}` counter at zero — the injected \
             faults did not exercise the instrumented recovery path"
        );
    }
    Report {
        id: "chaos",
        title: "Fault injection: sampler crash-recovery and replica failover".into(),
        markdown: md,
        json: Json::obj(vec![
            ("measured", Json::Arr(rows)),
            ("digests_identical", Json::Bool(identical)),
            (
                "counters",
                Json::Obj(
                    counter_deltas
                        .iter()
                        .map(|&(n, v)| (n.to_string(), Json::Num(v as f64)))
                        .collect(),
                ),
            ),
            ("simulated", Json::Arr(sim_rows)),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_experiment_streams_identical_across_every_fault_plan() {
        let r = chaos(Effort::Quick);
        assert!(
            r.json.get("digests_identical").as_bool().unwrap(),
            "faults must never change tokens"
        );
        let rows = r.json.get("measured").as_arr().unwrap();
        assert_eq!(rows.len(), 9);
        // every engine-level fault case actually exercised recovery, and
        // every replica-kill case actually failed over
        for row in rows {
            let plan = row.get("plan").as_str().unwrap();
            if plan.contains("sampler") {
                assert!(
                    row.get("recoveries").as_f64().unwrap() > 0.0,
                    "{plan}: no recovery happened"
                );
            }
            if plan.contains("replica") {
                assert!(
                    row.get("failovers").as_f64().unwrap() > 0.0,
                    "{plan}: no failover happened"
                );
            }
        }
        // the simulated fault row requeued work
        let sim = r.json.get("simulated").as_arr().unwrap();
        assert_eq!(sim.len(), 2);
        assert!(sim[1].get("requeued").as_f64().unwrap() > 0.0);
        // the decision-plane counters saw the recovery machinery fire
        let counters = r.json.get("counters");
        for key in ["steals", "sampler_respawns", "router_requeues"] {
            assert!(
                counters.get(key).as_f64().unwrap() > 0.0,
                "{key} counter stayed zero across the chaos sweep"
            );
        }
    }
}
