//! Figure/table regeneration harnesses — one driver per paper experiment
//! (DESIGN.md §4 maps each to its modules). Every driver returns a
//! [`Report`] (markdown + JSON series) and can write it under `results/`.

pub mod chaos;
pub mod cluster;
pub mod e2e;
pub mod exactness;
pub mod holdout;
pub mod measure;
pub mod micro;
pub mod overlap;
pub mod prefixcache;

use crate::util::json::Json;
use std::path::Path;

/// One regenerated experiment.
pub struct Report {
    /// Paper id, e.g. "fig3", "table3".
    pub id: &'static str,
    pub title: String,
    /// Markdown rendering (tables/series) for humans.
    pub markdown: String,
    /// Machine-readable series.
    pub json: Json,
}

impl Report {
    /// Write `results/<id>.md` and `results/<id>.json`.
    pub fn write(&self, results_dir: &Path) -> crate::Result<()> {
        std::fs::create_dir_all(results_dir)?;
        std::fs::write(results_dir.join(format!("{}.md", self.id)), &self.markdown)?;
        crate::util::json::write_json_file(
            &results_dir.join(format!("{}.json", self.id)),
            &self.json,
        )?;
        Ok(())
    }
}

/// Effort level: quick (CI) vs full (paper-scale sweeps).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Effort {
    Quick,
    Full,
}

impl Effort {
    pub fn scale(self, quick: u64, full: u64) -> u64 {
        match self {
            Effort::Quick => quick,
            Effort::Full => full,
        }
    }
}

/// All experiment ids, in paper order, plus repo-native scenarios beyond
/// the paper (`burst`: tail latency under bursty arrivals; `specdec`:
/// verified speculative decoding vs draft window size; `overlap`:
/// measured-vs-simulated decision-plane overlap under the pipelined
/// executor; `cluster`: data-parallel replicas × routing policy × traffic
/// behind the decision-plane-aware router; `chaos`: injected sampler /
/// replica / lock faults vs the recovery hard bar — bit-identical streams
/// under every fault plan; `prefixcache`: radix KV reuse over conversation
/// trees — prefill-token reduction and TTFT with reuse on vs off, digests
/// bit-identical throughout).
pub const ALL_EXPERIMENTS: &[&str] = &[
    "fig1a", "fig1b", "amdahl", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8",
    "fig9", "table3", "fig10", "fig11", "fig12", "fig13", "burst", "specdec",
    "overlap", "cluster", "chaos", "prefixcache",
];

/// Run one experiment by id.
pub fn run_experiment(id: &str, effort: Effort) -> crate::Result<Report> {
    Ok(match id {
        "fig1a" => holdout::fig1a(effort),
        "fig1b" => holdout::fig1b(effort),
        "amdahl" => holdout::amdahl(),
        "fig3" => e2e::fig3(effort),
        "fig4" => e2e::tpot_ecdf("fig4", "l40", effort),
        "fig5" => e2e::tpot_ecdf("fig5", "h100", effort),
        "fig7" => e2e::tpot_ecdf("fig7", "b200", effort),
        "fig6" => e2e::fig6(effort),
        "fig8" => e2e::utilization("fig8", "gpu", effort),
        "fig9" => e2e::utilization("fig9", "cpu", effort),
        "table3" => e2e::table3(effort),
        "burst" => e2e::burst(effort),
        "specdec" => e2e::specdec(effort),
        "fig10" => micro::fig10(effort),
        "fig11" => micro::fig11(effort),
        "fig12" => micro::fig12(effort),
        "fig13" => exactness::fig13(effort),
        "overlap" => overlap::overlap(effort),
        "cluster" => cluster::cluster(effort),
        "chaos" => chaos::chaos(effort),
        "prefixcache" => prefixcache::prefixcache(effort),
        other => anyhow::bail!("unknown experiment {other}"),
    })
}

/// Default results dir: `$SIMPLE_RESULTS` or `<repo>/results`.
pub fn default_results_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("SIMPLE_RESULTS") {
        return std::path::PathBuf::from(p);
    }
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results")
}
