//! End-to-end evaluation figures (§7.2–§7.3): throughput (Fig. 3), TPOT
//! ECDFs (Figs. 4/5/7), load–latency (Fig. 6), utilization (Figs. 8/9),
//! and host memory (Table 3).
//!
//! Engines compared:
//! - **vLLM** — baseline GPU epilogue (Eq. 4) with a synchronous host gap.
//! - **SGLang** — same epilogue on a leaner runtime (smaller host gap and
//!   fixed sampling overhead).
//! - **SIMPLE** — sequence-parallel CPU decision plane, overlapped; its
//!   per-sequence cost is *measured on this host* at the model's vocabulary
//!   with the hot size chosen by the §5.4 sizing model and then refined
//!   online by the runtime acceptance controller (§9 future-work i).

use super::measure;
use super::{Effort, Report};
use crate::config::{ModelSpec, ParallelConfig, PlatformSpec};
use crate::metrics::stats::ecdf;
use crate::simulator::{simulate, DecisionMode, GpuModel, SimConfig, SimRequest};
use crate::util::json::Json;
use crate::workload;
use std::collections::HashMap;
use std::fmt::Write;
use std::sync::Mutex;

/// Engine flavor for the comparison figures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    Vllm,
    Sglang,
    Simple,
}

impl EngineKind {
    pub fn name(self) -> &'static str {
        match self {
            EngineKind::Vllm => "vLLM",
            EngineKind::Sglang => "SGLang",
            EngineKind::Simple => "SIMPLE",
        }
    }
}

/// Cached measured SHVS cost per vocabulary size (measuring the naive
/// variants at V=152k is expensive; do it once per process).
static SHVS_COST_CACHE: Mutex<Option<HashMap<(usize, u64), f64>>> = Mutex::new(None);

/// Measured per-sequence SHVS decision cost at vocabulary `vocab` with a
/// hot set sized by the fitted sizing model.
pub fn measured_shvs_per_seq(vocab: usize, effort: Effort) -> f64 {
    let iters = effort.scale(8, 40);
    let key = (vocab, iters);
    {
        let cache = SHVS_COST_CACHE.lock().unwrap();
        if let Some(map) = cache.as_ref() {
            if let Some(&v) = map.get(&key) {
                return v;
            }
        }
    }
    let gen = measure::LogitsGen::new(vocab, 1.08, 42);
    // Deploy at the ONLINE-adapted H*: fit the offline §5.4 model, then let
    // the runtime controller refine H against the real decision plane (its
    // acceptance counters re-estimate ᾱ(H) and re-pick H* live) before
    // measuring at the converged size. The ranked hot vocab shares one
    // ranking across sizes, so the adaptive resizes never perturb streams.
    let adaptive = measure::adaptive_h_star(&gen, iters.min(20), 8);
    let h = adaptive.h.clamp(64, 32_768);
    let hot = gen.ranked_hot_vocab(h).into_arc();
    let params = crate::decision::SamplingParams::production_default();
    let (per_seq, _alpha) = measure::measure_variant(
        &gen,
        crate::config::DecisionVariant::Shvs,
        Some(hot),
        &params,
        iters,
    );
    let mut cache = SHVS_COST_CACHE.lock().unwrap();
    cache.get_or_insert_with(HashMap::new).insert(key, per_seq);
    per_seq
}

/// Build the (gpu model, decision mode, samplers) for an engine flavor.
fn engine_sim(
    kind: EngineKind,
    model: &ModelSpec,
    platform: &PlatformSpec,
    parallel: ParallelConfig,
    effort: Effort,
) -> SimConfig {
    let mut gpu = GpuModel::new(model.clone(), platform.clone(), parallel);
    // §7.1: 16 samplers × 4 threads each = 64 decision workers.
    let samplers = 64;
    let mode = match kind {
        EngineKind::Vllm => DecisionMode::GpuEpilogue,
        EngineKind::Sglang => {
            // leaner runtime: smaller host gap + lighter fixed sampling cost
            gpu.data.baseline_sync_s *= 0.6;
            gpu.sampling.fixed_s *= 0.75;
            DecisionMode::GpuEpilogue
        }
        EngineKind::Simple => DecisionMode::SimpleOverlapped {
            per_seq_s: measured_shvs_per_seq(model.vocab, effort),
            samplers,
        },
    };
    SimConfig::new(
        gpu,
        mode,
        32 * parallel.world_size(),
        platform.cpu_cores,
        samplers,
    )
}

/// ShareGPT-like closed-loop trace for a deployment.
fn closed_trace(n: usize, vocab: usize, seed_shift: u64) -> Vec<SimRequest> {
    let mut cfg = workload::TraceConfig::sharegpt_like(n, vocab, 4096);
    cfg.seed ^= seed_shift;
    let trace = workload::generate(&cfg);
    crate::simulator::serving::to_sim_requests(&trace)
}

/// Fig 3: end-to-end throughput across platforms and models.
pub fn fig3(effort: Effort) -> Report {
    let n_req = effort.scale(120, 600) as usize;
    let mut md = String::from(
        "### Fig 3 — end-to-end throughput (tokens/s)\n\n\
         | platform | model | TP×PP | vLLM | SGLang | SIMPLE | gain vs vLLM |\n\
         |---|---|---|---:|---:|---:|---:|\n",
    );
    let mut rows = Vec::new();
    for platform in PlatformSpec::all() {
        for (model, parallel) in ParallelConfig::paper_matrix(&platform) {
            let trace = closed_trace(n_req, model.vocab, 1);
            let mut tputs = Vec::new();
            for kind in [EngineKind::Vllm, EngineKind::Sglang, EngineKind::Simple] {
                let cfg = engine_sim(kind, &model, &platform, parallel, effort);
                let res = simulate(&cfg, &trace);
                tputs.push(res.throughput());
            }
            let gain = tputs[2] / tputs[0];
            let _ = writeln!(
                md,
                "| {} | {} | {}x{} | {:.0} | {:.0} | {:.0} | +{:.0}% |",
                platform.name,
                model.name,
                parallel.tp,
                parallel.pp,
                tputs[0],
                tputs[1],
                tputs[2],
                (gain - 1.0) * 100.0
            );
            rows.push(Json::obj(vec![
                ("platform", Json::Str(platform.name.into())),
                ("model", Json::Str(model.name.into())),
                ("tp", Json::Num(parallel.tp as f64)),
                ("pp", Json::Num(parallel.pp as f64)),
                ("vllm", Json::Num(tputs[0])),
                ("sglang", Json::Num(tputs[1])),
                ("simple", Json::Num(tputs[2])),
                ("gain", Json::Num(gain)),
            ]));
        }
    }
    md.push_str("\npaper: mean gains ≈ +50% (L40), +50% (H100), +28% (B200); max +96%\n");
    Report {
        id: "fig3",
        title: "End-to-end throughput across platforms and models".into(),
        markdown: md,
        json: Json::obj(vec![("rows", Json::Arr(rows))]),
    }
}

/// Figs 4/5/7: TPOT ECDF with P95 marked, per platform.
pub fn tpot_ecdf(id: &'static str, platform_name: &str, effort: Effort) -> Report {
    let platform = PlatformSpec::by_name(platform_name).expect("platform");
    let n_req = effort.scale(120, 600) as usize;
    let mut md = format!(
        "### {id} — TPOT ECDF on {} (P95 marked)\n\n\
         | model | engine | P50 | P95 | P95 reduction |\n|---|---|---:|---:|---:|\n",
        platform.name
    );
    let mut rows = Vec::new();
    for (model, parallel) in ParallelConfig::paper_matrix(&platform) {
        let trace = closed_trace(n_req, model.vocab, 2);
        let mut p95s = Vec::new();
        for kind in [EngineKind::Vllm, EngineKind::Simple] {
            let cfg = engine_sim(kind, &model, &platform, parallel, effort);
            let res = simulate(&cfg, &trace);
            let tpots = res.recorder.tpots();
            let summary = res.recorder.tpot_summary();
            let curve = ecdf(&tpots, 40);
            p95s.push(summary.p95);
            let reduction = if kind == EngineKind::Simple && p95s.len() == 2 {
                format!("-{:.0}%", (1.0 - p95s[1] / p95s[0]) * 100.0)
            } else {
                "—".into()
            };
            let _ = writeln!(
                md,
                "| {} | {} | {:.1} ms | {:.1} ms | {} |",
                model.name,
                kind.name(),
                summary.p50 * 1e3,
                summary.p95 * 1e3,
                reduction
            );
            rows.push(Json::obj(vec![
                ("model", Json::Str(model.name.into())),
                ("engine", Json::Str(kind.name().into())),
                ("p50", Json::Num(summary.p50)),
                ("p95", Json::Num(summary.p95)),
                (
                    "ecdf",
                    Json::Arr(
                        curve
                            .iter()
                            .map(|&(v, f)| Json::arr([Json::Num(v), Json::Num(f)]))
                            .collect(),
                    ),
                ),
            ]));
        }
    }
    md.push_str("\npaper P95 reductions: L40 mean 39%, H100 mean 55%, B200 mean 28%\n");
    Report {
        id,
        title: format!("TPOT ECDF on {}", platform.name),
        markdown: md,
        json: Json::obj(vec![("rows", Json::Arr(rows))]),
    }
}

/// Fig 6: load–latency tradeoff (H100, Qwen3-235B-A22B): throughput and
/// P99 TPOT vs request arrival rate.
pub fn fig6(effort: Effort) -> Report {
    let platform = PlatformSpec::h100();
    let model = ModelSpec::qwen3_235b_a22b();
    let parallel = ParallelConfig::paper_preset(&model, &platform).unwrap();
    let n_req = effort.scale(150, 800) as usize;

    // Capacity anchor: baseline saturation throughput (req/s).
    let sat_trace = closed_trace(n_req, model.vocab, 3);
    let base_cfg = engine_sim(EngineKind::Vllm, &model, &platform, parallel, effort);
    let sat = simulate(&base_cfg, &sat_trace);
    let mean_out: f64 = sat_trace.iter().map(|r| r.output_len as f64).sum::<f64>()
        / sat_trace.len() as f64;
    let capacity_req_s = sat.throughput() / mean_out;

    let fractions = [0.1, 0.3, 0.6, 0.9, f64::INFINITY];
    let mut md = String::from(
        "### Fig 6 — TPOT P99 / throughput vs request rate (H100, Qwen3-235B-A22B)\n\n\
         | rate (req/s) | vLLM tok/s | vLLM P99 | SIMPLE tok/s | SIMPLE P99 |\n\
         |---:|---:|---:|---:|---:|\n",
    );
    let mut rows = Vec::new();
    for &frac in &fractions {
        let rate = capacity_req_s * frac;
        let mut cells = Vec::new();
        for kind in [EngineKind::Vllm, EngineKind::Simple] {
            let mut trace_w = workload::generate(&{
                let mut c = workload::TraceConfig::sharegpt_like(n_req, model.vocab, 4096);
                c.seed ^= 4;
                c
            });
            workload::poisson_arrivals(&mut trace_w, rate, 11);
            let trace = crate::simulator::serving::to_sim_requests(&trace_w);
            let cfg = engine_sim(kind, &model, &platform, parallel, effort);
            let res = simulate(&cfg, &trace);
            cells.push((res.throughput(), res.recorder.tpot_summary().p99));
        }
        let rate_label = if rate.is_finite() {
            format!("{rate:.1}")
        } else {
            "inf".into()
        };
        let _ = writeln!(
            md,
            "| {} | {:.0} | {:.1} ms | {:.0} | {:.1} ms |",
            rate_label,
            cells[0].0,
            cells[0].1 * 1e3,
            cells[1].0,
            cells[1].1 * 1e3
        );
        rows.push(Json::obj(vec![
            ("rate_req_s", Json::Num(rate)),
            ("vllm_tput", Json::Num(cells[0].0)),
            ("vllm_p99", Json::Num(cells[0].1)),
            ("simple_tput", Json::Num(cells[1].0)),
            ("simple_p99", Json::Num(cells[1].1)),
        ]));
    }
    md.push_str(
        "\npaper at saturation: P99 105→63 ms (−40%), throughput 5326→9421 tok/s (+77%)\n",
    );
    Report {
        id: "fig6",
        title: "Load–latency tradeoff".into(),
        markdown: md,
        json: Json::obj(vec![
            ("capacity_req_s", Json::Num(capacity_req_s)),
            ("rows", Json::Arr(rows)),
        ]),
    }
}

/// Figs 8/9: runtime utilization (mid-50% band) comparison.
pub fn utilization(id: &'static str, resource: &'static str, effort: Effort) -> Report {
    let n_req = effort.scale(120, 500) as usize;
    // Fig 8: B200 across its models; Fig 9: Qwen3-235B across platforms.
    let cases: Vec<(PlatformSpec, ModelSpec)> = if resource == "gpu" {
        let b200 = PlatformSpec::b200();
        ParallelConfig::paper_matrix(&b200)
            .into_iter()
            .map(|(m, _)| (b200.clone(), m))
            .collect()
    } else {
        PlatformSpec::all()
            .into_iter()
            .filter(|p| {
                ParallelConfig::paper_preset(&ModelSpec::qwen3_235b_a22b(), p).is_some()
            })
            .map(|p| (p, ModelSpec::qwen3_235b_a22b()))
            .collect()
    };
    let mut md = format!(
        "### {id} — runtime {resource} utilization (mid-50%)\n\n\
         | platform | model | vLLM p25/p50/p75 | SIMPLE p25/p50/p75 |\n|---|---|---|---|\n"
    );
    let mut rows = Vec::new();
    for (platform, model) in cases {
        let parallel = ParallelConfig::paper_preset(&model, &platform).unwrap();
        let trace = closed_trace(n_req, model.vocab, 5);
        let mut bands = Vec::new();
        for kind in [EngineKind::Vllm, EngineKind::Simple] {
            let cfg = engine_sim(kind, &model, &platform, parallel, effort);
            let res = simulate(&cfg, &trace);
            let window = res.recorder.summary().duration / 50.0;
            bands.push(res.recorder.utilization_mid50(resource, window.max(1e-3)));
        }
        let fmt = |b: (f64, f64, f64)| {
            format!("{:.0}/{:.0}/{:.0}%", b.0 * 100.0, b.1 * 100.0, b.2 * 100.0)
        };
        let _ = writeln!(
            md,
            "| {} | {} | {} | {} |",
            platform.name,
            model.name,
            fmt(bands[0]),
            fmt(bands[1])
        );
        rows.push(Json::obj(vec![
            ("platform", Json::Str(platform.name.into())),
            ("model", Json::Str(model.name.into())),
            ("vllm_p50", Json::Num(bands[0].1)),
            ("simple_p50", Json::Num(bands[1].1)),
        ]));
    }
    if resource == "gpu" {
        md.push_str("\npaper (B200): mean GPU util 75% → 96% under SIMPLE\n");
    } else {
        md.push_str("\npaper: CPU util rises (B200 +17%, L40 +8%) but stays < 31%\n");
    }
    Report {
        id,
        title: format!("{resource} utilization"),
        markdown: md,
        json: Json::obj(vec![("rows", Json::Arr(rows))]),
    }
}

/// Burst scenario (beyond the paper's steady-state figures): tail latency
/// under steady vs bursty (MMPP) vs flash-crowd (Zipf-train) arrivals at
/// the same mean rate — 70% of baseline saturation capacity — with the
/// production scheduler features engaged (chunked prefill budget, bounded
/// KV with recompute-on-resume preemption). Reports throughput, P95
/// TTFT/TPOT, and preemption counts per engine × traffic shape.
pub fn burst(effort: Effort) -> Report {
    let platform = PlatformSpec::h100();
    let model = ModelSpec::qwen3_235b_a22b();
    let parallel = ParallelConfig::paper_preset(&model, &platform).unwrap();
    let n_req = effort.scale(150, 800) as usize;

    // Capacity anchor: baseline saturation throughput (req/s), as in Fig 6.
    let sat_trace = closed_trace(n_req, model.vocab, 7);
    let base_cfg = engine_sim(EngineKind::Vllm, &model, &platform, parallel, effort);
    let sat = simulate(&base_cfg, &sat_trace);
    let mean_out: f64 = sat_trace.iter().map(|r| r.output_len as f64).sum::<f64>()
        / sat_trace.len() as f64;
    let rate = sat.throughput() / mean_out * 0.7;

    let mut md = String::from(
        "### burst — P95 latency under bursty traffic (H100, Qwen3-235B-A22B, 70% load)\n\n\
         | traffic | engine | tok/s | TTFT P95 | TPOT P95 | preemptions |\n\
         |---|---|---:|---:|---:|---:|\n",
    );
    let mut rows = Vec::new();
    for pattern in ["steady", "burst", "zipf"] {
        let traffic = workload::TrafficPattern::parse(pattern).unwrap();
        for kind in [EngineKind::Vllm, EngineKind::Simple] {
            let mut trace_w = workload::generate(&{
                let mut c = workload::TraceConfig::sharegpt_like(n_req, model.vocab, 4096);
                c.seed ^= 8;
                c
            });
            traffic.stamp(&mut trace_w, rate, 13);
            let trace = crate::simulator::serving::to_sim_requests(&trace_w);
            let mut cfg = engine_sim(kind, &model, &platform, parallel, effort);
            // production scheduler: budgeted prefill + bounded KV
            cfg.prefill_chunk_tokens = 2048;
            cfg.kv_capacity_tokens = cfg.slots * 512;
            let res = simulate(&cfg, &trace);
            let (ttft, tpot) = (res.recorder.ttft_summary(), res.recorder.tpot_summary());
            let _ = writeln!(
                md,
                "| {} | {} | {:.0} | {:.0} ms | {:.1} ms | {} |",
                pattern,
                kind.name(),
                res.throughput(),
                ttft.p95 * 1e3,
                tpot.p95 * 1e3,
                res.preemptions
            );
            rows.push(Json::obj(vec![
                ("traffic", Json::Str(pattern.into())),
                ("engine", Json::Str(kind.name().into())),
                ("tput", Json::Num(res.throughput())),
                ("ttft_p95", Json::Num(ttft.p95)),
                ("tpot_p95", Json::Num(tpot.p95)),
                ("preemptions", Json::Num(res.preemptions as f64)),
            ]));
        }
    }
    md.push_str(
        "\nburstiness stresses the decision plane's admit/preempt/resume churn; \
         the same mean rate is offered in every row\n",
    );
    Report {
        id: "burst",
        title: "Tail latency under bursty traffic".into(),
        markdown: md,
        json: Json::obj(vec![("rate_req_s", Json::Num(rate)), ("rows", Json::Arr(rows))]),
    }
}

/// Speculative-decoding scenario (DESIGN.md §7, beyond the paper's
/// figures): throughput and accepted-tokens-per-step vs the draft window
/// size `k`, on the H100 Qwen3-235B-A22B deployment at a small per-GPU
/// batch (the weight-bound regime where draft chains hide under the weight
/// pass). The decision plane's per-position verify cost is *measured* on
/// this host (`measured_shvs_per_seq`, scaled by the k+1 chain positions
/// inside `DecisionMode::SpecVerify`) and the per-position acceptance rate
/// is *measured* by running the real proposer + verifier
/// (`measure::measure_spec_acceptance`) — nothing modelled.
pub fn specdec(effort: Effort) -> Report {
    let platform = PlatformSpec::h100();
    let model = ModelSpec::qwen3_235b_a22b();
    let parallel = ParallelConfig::paper_preset(&model, &platform).unwrap();
    let n_req = effort.scale(120, 600) as usize;
    let samplers = 64;
    let per_seq = measured_shvs_per_seq(model.vocab, effort);
    // acceptance of the self-drafting proposer, measured per window size
    // (continuation quality decays with depth, so deep windows must not
    // reuse a shallow-window rate); reduced vocab for CI speed at quick
    let accept_vocab = effort.scale(4_000, 32_000) as usize;
    let accept_steps = effort.scale(40, 200);

    let mut md = String::from(
        "### specdec — verified speculative decoding vs window size \
         (H100, Qwen3-235B-A22B, per-k measured acceptance)\n\n\
         | k | accept | tok/s | tokens/step | TPOT p95 | gain vs k=0 |\n\
         |---:|---:|---:|---:|---:|---:|\n",
    );
    let mut rows = Vec::new();
    let mut base_tput = 0.0f64;
    for k in [0usize, 1, 2, 4, 8] {
        let accept = measure::measure_spec_acceptance(accept_vocab, k, accept_steps);
        let trace = closed_trace(n_req, model.vocab, 9);
        let gpu = GpuModel::new(model.clone(), platform.clone(), parallel);
        let mode = if k == 0 {
            DecisionMode::SimpleOverlapped { per_seq_s: per_seq, samplers }
        } else {
            DecisionMode::SpecVerify { per_seq_s: per_seq, samplers, k, accept_rate: accept }
        };
        // 4 sequences per GPU: decode is weight-bound, the regime where the
        // chain's extra tokens ride along free
        let cfg = SimConfig::new(
            gpu,
            mode,
            4 * parallel.world_size(),
            platform.cpu_cores,
            samplers,
        );
        let res = simulate(&cfg, &trace);
        let tput = res.throughput();
        if k == 0 {
            base_tput = tput;
        }
        let per_step = if res.spec_windows > 0 {
            res.spec_tokens as f64 / res.spec_windows as f64
        } else {
            1.0
        };
        let _ = writeln!(
            md,
            "| {} | {:.2} | {:.0} | {:.2} | {:.1} ms | {:+.0}% |",
            k,
            accept,
            tput,
            per_step,
            res.recorder.tpot_summary().p95 * 1e3,
            (tput / base_tput - 1.0) * 100.0
        );
        rows.push(Json::obj(vec![
            ("k", Json::Num(k as f64)),
            ("accept_rate", Json::Num(accept)),
            ("tput", Json::Num(tput)),
            ("tokens_per_step", Json::Num(per_step)),
            ("tpot_p95", Json::Num(res.recorder.tpot_summary().p95)),
        ]));
    }
    md.push_str(
        "\naccepted-tokens/step grows with k but saturates as rejections cut \
         the window; throughput peaks where the chain still hides under the \
         weight pass\n",
    );
    Report {
        id: "specdec",
        title: "Speculative decoding in the decision plane".into(),
        markdown: md,
        json: Json::obj(vec![("rows", Json::Arr(rows))]),
    }
}

/// Table 3: host memory usage for Qwen3-235B-A22B.
pub fn table3(effort: Effort) -> Report {
    let model = ModelSpec::qwen3_235b_a22b();
    let n_req = effort.scale(60, 200) as usize;
    let mut md = String::from(
        "### Table 3 — host memory usage, Qwen3-235B-A22B (% of 2 TB host)\n\n\
         | platform | vLLM | SIMPLE | delta |\n|---|---:|---:|---:|\n",
    );
    let mut rows = Vec::new();
    for platform in PlatformSpec::all() {
        let Some(parallel) = ParallelConfig::paper_preset(&model, &platform) else {
            continue;
        };
        // Baseline host usage: weight staging + pinned IO for the host's
        // share of the model (more GPUs per host => larger resident share).
        let hosts = parallel.world_size().div_ceil(platform.gpus_per_node) as f64;
        let weights_gb = model.params_b * 2.0; // bf16
        let base_frac = (weights_gb / hosts * 0.15 + 30.0) / platform.host_mem_gb;
        let cfg = engine_sim(EngineKind::Simple, &model, &platform, parallel, effort);
        let trace = closed_trace(n_req, model.vocab, 6);
        let res = simulate(&cfg, &trace);
        let simple_frac = base_frac + res.host_mem_bytes / (platform.host_mem_gb * 1e9);
        let _ = writeln!(
            md,
            "| {} | {:.1}% | {:.1}% | +{:.1}pp |",
            platform.name,
            base_frac * 100.0,
            simple_frac * 100.0,
            (simple_frac - base_frac) * 100.0
        );
        rows.push(Json::obj(vec![
            ("platform", Json::Str(platform.name.into())),
            ("vllm_frac", Json::Num(base_frac)),
            ("simple_frac", Json::Num(simple_frac)),
        ]));
    }
    md.push_str("\npaper: at most +1.3pp (6.8% → 8.1% on B200), average +0.8pp\n");
    Report {
        id: "table3",
        title: "Host memory usage".into(),
        markdown: md,
        json: Json::obj(vec![("rows", Json::Arr(rows))]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_simple_wins_everywhere() {
        let r = fig3(Effort::Quick);
        let rows = r.json.get("rows").as_arr().unwrap();
        assert_eq!(rows.len(), 11, "Table 2 has 11 (platform, model) cells");
        for row in rows {
            let gain = row.get("gain").as_f64().unwrap();
            assert!(
                gain > 1.05 && gain < 3.0,
                "{} {}: gain {gain}",
                row.get("platform").as_str().unwrap(),
                row.get("model").as_str().unwrap()
            );
        }
        // Measured-cost-sensitive shape checks only hold in release builds
        // (debug builds inflate the measured SHVS per-seq cost ~20x, making
        // the simulated decision plane bind where it would be hidden).
        if cfg!(debug_assertions) {
            return;
        }
        // Shape checks (paper §7.2):
        // (1) the largest gain comes from a large-vocab MoE deployment;
        let best = rows
            .iter()
            .max_by(|a, b| {
                a.get("gain").as_f64().partial_cmp(&b.get("gain").as_f64()).unwrap()
            })
            .unwrap();
        assert!(
            best.get("model").as_str().unwrap().contains("qwen3"),
            "max gain on {}",
            best.get("model").as_str().unwrap()
        );
        // (2) for the same model, the shallower-pipeline B200 deployment
        // gains no more than the deeper H100 one.
        let gain_of = |plat: &str, model: &str| {
            rows.iter()
                .find(|r| {
                    r.get("platform").as_str() == Some(plat)
                        && r.get("model").as_str() == Some(model)
                })
                .map(|r| r.get("gain").as_f64().unwrap())
        };
        for model in ["qwen3-235b-a22b", "deepseek-v3"] {
            let (h, b) = (gain_of("h100", model).unwrap(), gain_of("b200", model).unwrap());
            assert!(b <= h * 1.05, "{model}: b200 {b} vs h100 {h}");
        }
    }

    #[test]
    fn tpot_p95_reduced() {
        let r = tpot_ecdf("fig5", "h100", Effort::Quick);
        let rows = r.json.get("rows").as_arr().unwrap();
        if cfg!(debug_assertions) {
            return; // see fig3 test: measurement-sensitive in debug builds
        }
        for pair in rows.chunks(2) {
            let base = pair[0].get("p95").as_f64().unwrap();
            let simple = pair[1].get("p95").as_f64().unwrap();
            assert!(
                simple < base,
                "{}: p95 {simple} !< {base}",
                pair[0].get("model").as_str().unwrap()
            );
        }
    }

    #[test]
    fn fig6_saturation_gain() {
        let r = fig6(Effort::Quick);
        let rows = r.json.get("rows").as_arr().unwrap();
        if cfg!(debug_assertions) {
            return; // see fig3 test: measurement-sensitive in debug builds
        }
        let last = rows.last().unwrap(); // rate = inf
        let v = last.get("vllm_tput").as_f64().unwrap();
        let s = last.get("simple_tput").as_f64().unwrap();
        assert!(s > v * 1.2, "saturation gain {s}/{v}");
        assert!(
            last.get("simple_p99").as_f64().unwrap()
                < last.get("vllm_p99").as_f64().unwrap()
        );
    }

    #[test]
    fn utilization_directions() {
        let g = utilization("fig8", "gpu", Effort::Quick);
        for row in g.json.get("rows").as_arr().unwrap() {
            let v = row.get("vllm_p50").as_f64().unwrap();
            let s = row.get("simple_p50").as_f64().unwrap();
            assert!(s > v, "gpu util should rise: {v} -> {s}");
        }
        let c = utilization("fig9", "cpu", Effort::Quick);
        for row in c.json.get("rows").as_arr().unwrap() {
            let v = row.get("vllm_p50").as_f64().unwrap();
            let s = row.get("simple_p50").as_f64().unwrap();
            assert!(s >= v, "cpu util should rise: {v} -> {s}");
            if !cfg!(debug_assertions) {
                assert!(s < 0.5, "cpu stays far from saturation: {s}");
            }
        }
    }

    #[test]
    fn burst_scenario_shapes() {
        let r = burst(Effort::Quick);
        let rows = r.json.get("rows").as_arr().unwrap();
        assert_eq!(rows.len(), 6, "3 traffic shapes × 2 engines");
        let get = |traffic: &str, engine: &str, key: &str| {
            rows.iter()
                .find(|row| {
                    row.get("traffic").as_str() == Some(traffic)
                        && row.get("engine").as_str() == Some(engine)
                })
                .and_then(|row| row.get(key).as_f64())
                .unwrap()
        };
        // queueing under clustered arrivals inflates the TTFT tail vs the
        // same mean rate offered steadily
        for engine in ["vLLM", "SIMPLE"] {
            let steady = get("steady", engine, "ttft_p95");
            let burst = get("burst", engine, "ttft_p95");
            assert!(
                burst > steady,
                "{engine}: burst TTFT p95 {burst} !> steady {steady}"
            );
        }
        if !cfg!(debug_assertions) {
            // the disaggregated decision plane keeps its TPOT advantage
            // under every traffic shape (measurement-sensitive in debug)
            for traffic in ["steady", "burst", "zipf"] {
                let v = get(traffic, "vLLM", "tpot_p95");
                let s = get(traffic, "SIMPLE", "tpot_p95");
                assert!(s < v, "{traffic}: SIMPLE p95 {s} !< vLLM {v}");
            }
        }
    }

    #[test]
    fn specdec_scenario_shapes() {
        let r = specdec(Effort::Quick);
        let rows = r.json.get("rows").as_arr().unwrap();
        assert_eq!(rows.len(), 5, "k ∈ {{0,1,2,4,8}}");
        let per_step = |i: usize| rows[i].get("tokens_per_step").as_f64().unwrap();
        let kval = |i: usize| rows[i].get("k").as_f64().unwrap() as usize;
        for i in 0..rows.len() {
            let accept = rows[i].get("accept_rate").as_f64().unwrap();
            assert!((0.0..=1.0).contains(&accept), "k={}: accept {accept}", kval(i));
            assert!(
                per_step(i) >= 1.0 - 1e-9 && per_step(i) <= kval(i) as f64 + 1.0,
                "k={}: tokens/step {}",
                kval(i),
                per_step(i)
            );
            // consistency with the leading-accept model at this row's own
            // measured rate: E[tokens/step] = 1 + Σ_{i≤k} p^i (end-of-
            // sequence caps only pull the empirical value down)
            let analytic: f64 =
                1.0 + (1..=kval(i)).map(|e| accept.powi(e as i32)).sum::<f64>();
            assert!(
                per_step(i) <= analytic + 0.05,
                "k={}: tokens/step {} vs analytic {analytic}",
                kval(i),
                per_step(i)
            );
        }
        // every variant still produces a positive-throughput schedule
        for row in rows {
            assert!(row.get("tput").as_f64().unwrap() > 0.0);
        }
    }

    #[test]
    fn table3_modest_delta() {
        let r = table3(Effort::Quick);
        for row in r.json.get("rows").as_arr().unwrap() {
            let v = row.get("vllm_frac").as_f64().unwrap();
            let s = row.get("simple_frac").as_f64().unwrap();
            assert!(s > v);
            assert!(s - v < 0.02, "delta {}", s - v);
            assert!(v > 0.005 && v < 0.15);
        }
    }
}
