//! §3 holdout figures: sampling fraction vs TP (Fig. 1a), per-iteration
//! breakdown with bubbles (Fig. 1b), and the Eq. 3 Amdahl drift.

use super::{Effort, Report};
use crate::config::{ModelSpec, ParallelConfig, PlatformSpec};
use crate::simulator::{amdahl_drift, decode_iteration, DecisionMode, GpuModel};
use crate::util::json::Json;
use std::fmt::Write;

/// Fig 1a: sampling ratio f vs TP degree on 8×H100 for large-vocab models.
pub fn fig1a(_effort: Effort) -> Report {
    let platform = PlatformSpec::h100();
    let models = [
        ModelSpec::qwq_32b(),
        ModelSpec::llama31_70b(),
        ModelSpec::qwen25_72b(),
    ];
    let mut md = String::from(
        "### Fig 1a — sampling ratio f vs TP degree (8×H100, baseline epilogue)\n\n\
         | model | t=2 | t=4 | t=8 |\n|---|---:|---:|---:|\n",
    );
    let mut rows = Vec::new();
    for model in &models {
        let mut cells = Vec::new();
        for tp in [2usize, 4, 8] {
            // fixed pipeline depth p=2; scaling out with t (batch follows
            // the paper's 32/GPU rule, so total batch grows with t)
            let pp = 2;
            let gpu = GpuModel::new(model.clone(), platform.clone(), ParallelConfig::new(tp, pp));
            let batch = 32 * gpu.parallel.world_size();
            let t = decode_iteration(&gpu, DecisionMode::GpuEpilogue, batch, 512.0);
            cells.push(t.sampling_fraction);
        }
        let _ = writeln!(
            md,
            "| {} | {:.1}% | {:.1}% | {:.1}% |",
            model.name,
            cells[0] * 100.0,
            cells[1] * 100.0,
            cells[2] * 100.0
        );
        rows.push(Json::obj(vec![
            ("model", Json::Str(model.name.into())),
            ("f_by_tp", Json::num_arr(&cells)),
        ]));
    }
    md.push_str("\npaper band: 20–38% for large vocabularies; +~10% from t=2→8\n");
    Report {
        id: "fig1a",
        title: "Sampling ratio vs TP degrees".into(),
        markdown: md,
        json: Json::obj(vec![("rows", Json::Arr(rows))]),
    }
}

/// Fig 1b: per-iteration breakdown, Qwen-2.5-72B (t=4, p=2) on H100.
pub fn fig1b(_effort: Effort) -> Report {
    let gpu = GpuModel::new(
        ModelSpec::qwen25_72b(),
        PlatformSpec::h100(),
        ParallelConfig::new(4, 2),
    );
    let batch = 32 * 8;
    let base = decode_iteration(&gpu, DecisionMode::GpuEpilogue, batch, 512.0);
    let simple = decode_iteration(
        &gpu,
        DecisionMode::SimpleOverlapped { per_seq_s: 50e-6, samplers: 16 },
        batch,
        512.0,
    );
    let md = format!(
        "### Fig 1b — per-iteration breakdown, Qwen-2.5-72B t=4 p=2 (H100)\n\n\
         | variant | cycle | stage compute | sampling | bubble |\n|---|---:|---:|---:|---:|\n\
         | baseline | {:.2} ms | {:.2} ms | {:.2} ms | {:.1}% |\n\
         | SIMPLE | {:.2} ms | {:.2} ms | hidden | {:.1}% |\n\n\
         paper: bubbles 22–40% attributable to the sampling epilogue\n",
        base.cycle_s * 1e3,
        base.stage_max_s * 1e3,
        base.gpu_sampling_s * 1e3,
        base.bubble_fraction * 100.0,
        simple.cycle_s * 1e3,
        simple.stage_max_s * 1e3,
        simple.bubble_fraction * 100.0,
    );
    let json = Json::obj(vec![
        (
            "baseline",
            Json::obj(vec![
                ("cycle_s", Json::Num(base.cycle_s)),
                ("stage_s", Json::Num(base.stage_max_s)),
                ("sampling_s", Json::Num(base.gpu_sampling_s)),
                ("bubble", Json::Num(base.bubble_fraction)),
            ]),
        ),
        (
            "simple",
            Json::obj(vec![
                ("cycle_s", Json::Num(simple.cycle_s)),
                ("stage_s", Json::Num(simple.stage_max_s)),
                ("bubble", Json::Num(simple.bubble_fraction)),
            ]),
        ),
    ]);
    Report { id: "fig1b", title: "Per-iteration breakdown".into(), markdown: md, json }
}

/// Eq. 3: the sampling fraction grows as the data plane accelerates.
pub fn amdahl() -> Report {
    let f0 = 0.2;
    let rhos = [1.0, 1.5, 2.0, 3.0, 5.0, 10.0];
    let mut md = String::from(
        "### Eq. 3 — Amdahl drift of the sampling fraction (f = 0.2 baseline)\n\n\
         | ρ (data-plane speedup) | f' |\n|---:|---:|\n",
    );
    let mut series = Vec::new();
    for &rho in &rhos {
        let f = amdahl_drift(f0, rho);
        let _ = writeln!(md, "| {rho} | {:.1}% |", f * 100.0);
        series.push(f);
    }
    Report {
        id: "amdahl",
        title: "Amdahl drift (Eq. 3)".into(),
        markdown: md,
        json: Json::obj(vec![
            ("f0", Json::Num(f0)),
            ("rho", Json::num_arr(&rhos)),
            ("f_prime", Json::num_arr(&series)),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1a_fractions_grow_with_tp() {
        let r = fig1a(Effort::Quick);
        for row in r.json.get("rows").as_arr().unwrap() {
            let f = row.get("f_by_tp").as_arr().unwrap();
            let f2 = f[0].as_f64().unwrap();
            let f8 = f[2].as_f64().unwrap();
            assert!(f8 > f2, "{}: {f2} -> {f8}", row.get("model").as_str().unwrap());
            assert!(f2 > 0.05 && f8 < 0.6);
        }
    }

    #[test]
    fn fig1b_simple_cuts_bubbles() {
        let r = fig1b(Effort::Quick);
        let base = r.json.get("baseline").get("bubble").as_f64().unwrap();
        let simple = r.json.get("simple").get("bubble").as_f64().unwrap();
        assert!(base > 0.1, "baseline bubble {base}");
        assert!(simple < base / 2.0, "simple bubble {simple}");
    }

    #[test]
    fn amdahl_series_monotone() {
        let r = amdahl();
        let f = r.json.get("f_prime").as_arr().unwrap();
        for w in f.windows(2) {
            assert!(w[1].as_f64().unwrap() > w[0].as_f64().unwrap());
        }
    }
}
