//! Fig 13 — exactness of SHVS: cumulative mean total-variation distance
//! between the SHVS-induced next-token distribution and the baseline
//! sampler's, per decode step (§7.6).
//!
//! Following the paper's theory (Eq. 9), the two distributions are equal;
//! residual TVD comes from finite precision (f32 GPU precompute of the
//! SHVS sums vs the oracle's f64) and stepwise truncation-support changes.
//! We therefore compute both *analytic* per-step distributions — the oracle
//! full-V filtered softmax in f64, and the SHVS-induced distribution using
//! the f32 precompute (α from kernel-grade sums, hot/tail proposals) — and
//! report TVD per step, cumulatively averaged over a decode run.

use super::measure::LogitsGen;
use super::{Effort, Report};
use crate::decision::filter::truncate;
use crate::decision::penalties::SeqHistory;
use crate::decision::{HotVocab, Precompute, SamplingParams};
use crate::metrics::stats::total_variation_distance;
use crate::rng::Philox;
use crate::util::json::Json;
use std::fmt::Write;

/// The SHVS-induced distribution for one step, using f32-precision hot/tail
/// sums (as the GPU kernel produces) for the acceptance probability.
fn shvs_induced_dist(
    view: &crate::tensor::ShardedLogits,
    hot: &HotVocab,
    hist: &SeqHistory,
    params: &SamplingParams,
) -> Vec<f64> {
    let vocab = view.vocab();
    let tau = params.temperature as f64;
    // f32 z_max + f32 tail sums: the kernel's arithmetic.
    let pre32 = {
        let mut z_max = f32::NEG_INFINITY;
        view.for_each_logit(0, |_, z| z_max = z_max.max(z));
        let mut tail_sum = 0.0f32;
        view.for_each_logit(0, |v, z| {
            if !hot.contains(v as u32) {
                tail_sum += (((z - z_max) as f64 / tau) as f32).exp();
            }
        });
        (z_max, tail_sum)
    };
    let _ = hist;

    // Hot weights in f64 (CPU side), α from the f32 tail sum.
    let mut hot_w = vec![0.0f64; vocab];
    let mut hot_sum = 0.0f64;
    let mut tail_w = vec![0.0f64; vocab];
    let mut tail_sum64 = 0.0f64;
    view.for_each_logit(0, |v, z| {
        let w = (((z - pre32.0) as f64) / tau).exp();
        if hot.contains(v as u32) {
            hot_w[v] = w;
            hot_sum += w;
        } else {
            tail_w[v] = w;
            tail_sum64 += w;
        }
    });
    let alpha = hot_sum / (hot_sum + pre32.1 as f64); // f32-contaminated α
    let mut dist = vec![0.0f64; vocab];
    for v in 0..vocab {
        dist[v] = alpha * hot_w[v] / hot_sum + (1.0 - alpha) * tail_w[v] / tail_sum64;
    }
    dist
}

/// Oracle full-V distribution in f64 (penalties off in this comparison, as
/// both sides share them identically).
fn oracle_dist(view: &crate::tensor::ShardedLogits, params: &SamplingParams) -> Vec<f64> {
    let pairs: Vec<(u32, f32)> = {
        let mut p = Vec::with_capacity(view.vocab());
        view.for_each_logit(0, |v, z| p.push((v as u32, z)));
        p
    };
    let t = truncate(pairs, params);
    let mut dist = vec![0.0f64; view.vocab()];
    for (i, &id) in t.ids.iter().enumerate() {
        dist[id as usize] = t.prob(i);
    }
    dist
}

/// Fig 13: cumulative mean TVD across decode steps for three models.
pub fn fig13(effort: Effort) -> Report {
    let steps = effort.scale(60, 1000);
    let models: Vec<(&str, usize, f64)> = match effort {
        Effort::Quick => vec![
            ("deepseek-v3", 12_928, 1.06),
            ("llama-3.1-70b", 12_826, 1.10),
            ("qwen3-235b-a22b", 15_194, 1.05),
        ],
        Effort::Full => vec![
            ("deepseek-v3", 129_280, 1.06),
            ("llama-3.1-70b", 128_256, 1.10),
            ("qwen3-235b-a22b", 151_936, 1.05),
        ],
    };
    let params = SamplingParams {
        temperature: 0.9,
        ..Default::default() // unfiltered: the rejection path (Eq. 9)
    };
    let mut md = String::from(
        "### Fig 13 — cumulative mean TVD of SHVS vs baseline sampler\n\n\
         | model | V | steps | cumulative mean TVD | max step TVD |\n\
         |---|---:|---:|---:|---:|\n",
    );
    let mut rows = Vec::new();
    for (name, vocab, zipf_s) in models {
        let gen = LogitsGen::new(vocab, zipf_s, 77);
        let hot = gen.hot_vocab((vocab / 5).min(32_768));
        let hist = SeqHistory::new(&[]);
        let mut rng = Philox::new(5);
        let mut cum = Vec::with_capacity(steps as usize);
        let mut sum = 0.0f64;
        let mut max_step = 0.0f64;
        for it in 0..steps {
            let view = gen.view(1, it, 1);
            let shvs = shvs_induced_dist(&view, &hot, &hist, &params);
            let oracle = oracle_dist(&view, &params);
            let tvd = total_variation_distance(&shvs, &oracle);
            sum += tvd;
            max_step = max_step.max(tvd);
            cum.push(sum / (it + 1) as f64);
            let _ = rng.next_u32();
        }
        let final_cum = *cum.last().unwrap();
        let _ = writeln!(
            md,
            "| {name} | {vocab} | {steps} | {:.4}% | {:.4}% |",
            final_cum * 100.0,
            max_step * 100.0
        );
        rows.push(Json::obj(vec![
            ("model", Json::Str(name.into())),
            ("vocab", Json::Num(vocab as f64)),
            ("cumulative_tvd", Json::Num(final_cum)),
            ("max_step_tvd", Json::Num(max_step)),
            (
                "curve",
                Json::num_arr(cum.iter().step_by((cum.len() / 40).max(1))),
            ),
        ]));
    }
    md.push_str("\npaper: flat cumulative curves well below 1% (e.g. 0.067% for Llama-3.1-70B)\n");
    Report {
        id: "fig13",
        title: "SHVS exactness (TVD)".into(),
        markdown: md,
        json: Json::obj(vec![("rows", Json::Arr(rows))]),
    }
}

/// Sanity helper also used by the property tests: exact SHVS-induced dist
/// must equal the oracle when everything is f64 (Eq. 9 identity).
pub fn exactness_identity_check(vocab: usize, seed: u64) -> f64 {
    let gen = LogitsGen::new(vocab, 1.1, seed);
    let hot = gen.hot_vocab(vocab / 8);
    let view = gen.view(1, 0, 1);
    // f64 α:
    let pre = Precompute::reference(&view, 0, &hot, 1.0);
    let mut hot_sum = 0.0f64;
    let mut dist = vec![0.0f64; vocab];
    let mut w_all = vec![0.0f64; vocab];
    view.for_each_logit(0, |v, z| {
        let w = ((z - pre.z_max) as f64).exp();
        w_all[v] = w;
        if hot.contains(v as u32) {
            hot_sum += w;
        }
    });
    let total = hot_sum + pre.tail_sum;
    let alpha = hot_sum / total;
    for v in 0..vocab {
        if hot.contains(v as u32) {
            dist[v] = alpha * w_all[v] / hot_sum;
        } else {
            dist[v] = (1.0 - alpha) * w_all[v] / pre.tail_sum;
        }
    }
    let oracle: Vec<f64> = w_all.iter().map(|w| w / total).collect();
    total_variation_distance(&dist, &oracle)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig13_tvd_below_one_percent() {
        let r = fig13(Effort::Quick);
        for row in r.json.get("rows").as_arr().unwrap() {
            let tvd = row.get("cumulative_tvd").as_f64().unwrap();
            assert!(
                tvd < 0.01,
                "{}: cumulative TVD {tvd}",
                row.get("model").as_str().unwrap()
            );
            // and the curve is flat-ish: max step not wildly above the mean
            let max = row.get("max_step_tvd").as_f64().unwrap();
            assert!(max < 0.05, "max step TVD {max}");
        }
    }

    #[test]
    fn identity_holds_in_f64() {
        // Eq. 9: with exact arithmetic the induced distribution IS the
        // softmax — TVD at machine-epsilon scale.
        for seed in [1u64, 2, 3] {
            let tvd = exactness_identity_check(2_000, seed);
            assert!(tvd < 1e-12, "seed {seed}: TVD {tvd}");
        }
    }
}
