//! Fig 13 — exactness of SHVS: cumulative mean total-variation distance
//! between the SHVS-induced next-token distribution and the baseline
//! sampler's, per decode step (§7.6).
//!
//! Following the paper's theory (Eq. 9), the two distributions are equal;
//! residual TVD comes from finite precision (f32 GPU precompute of the
//! SHVS sums vs the oracle's f64) and stepwise truncation-support changes.
//! We therefore compute both *analytic* per-step distributions — the oracle
//! full-V filtered softmax in f64, and the SHVS-induced distribution of the
//! coupled inverse-CDF rank walk (f64 weights, but the walk target scaled
//! by the kernel-grade f32-composed total, exactly as the engine ships
//! `s_hot + s_tail`) — and report TVD per step, cumulatively averaged over
//! a decode run. The same file carries the adaptive-SHVS exactness cases:
//! the controller's live resizes must leave token streams bit-identical
//! (nested rankings + an H-invariant walk), and on stationary traffic the
//! controller must converge within one sizing-grid bucket of the offline
//! H*.

use super::measure::LogitsGen;
use super::{Effort, Report};
use crate::config::DecisionVariant;
use crate::decision::filter::truncate;
use crate::decision::penalties::{apply_penalties_dense, BatchHistory, SeqHistory};
use crate::decision::verify::{verify_window, GrammarSlot};
use crate::decision::{DecisionPipeline, HotVocab, Precompute, SamplingParams};
use crate::metrics::stats::total_variation_distance;
use crate::rng::Philox;
use crate::util::json::Json;
use std::fmt::Write;

/// The SHVS-induced distribution for one step under the coupled inverse-CDF
/// rank walk, with the walk target scaled by f32-composed partial sums (as
/// the GPU kernel / engine stats path produces them).
///
/// The walk crosses the exact f64 cumulative weights in rank order, but the
/// target is `u · T₃₂` where `T₃₂ = (s_hot + s_tail)` in f32. So the
/// induced probability of each id is the overlap of its exact cumulative
/// interval with `[0, T₃₂)`, normalized by `T₃₂`; any target mass beyond
/// the exact total lands on the walk's guard (the last id in rank order).
fn shvs_induced_dist(
    view: &crate::tensor::ShardedLogits,
    hot: &HotVocab,
    hist: &SeqHistory,
    params: &SamplingParams,
) -> Vec<f64> {
    let vocab = view.vocab();
    let tau = params.temperature as f64;
    // f32 z_max + f32-composed total: the kernel's arithmetic.
    let (z_max, total32) = {
        let mut z_max = f32::NEG_INFINITY;
        view.for_each_logit(0, |_, z| z_max = z_max.max(z));
        let mut s_hot = 0.0f32;
        let mut s_tail = 0.0f32;
        view.for_each_logit(0, |v, z| {
            let w = (((z - z_max) as f64 / tau) as f32).exp();
            if hot.contains(v as u32) {
                s_hot += w;
            } else {
                s_tail += w;
            }
        });
        (z_max, (s_hot + s_tail) as f64)
    };
    let _ = hist;

    // Exact f64 weights, walked in rank order against the f32 total.
    let mut w = vec![0.0f64; vocab];
    view.for_each_logit(0, |v, z| w[v] = (((z - z_max) as f64) / tau).exp());
    let mut dist = vec![0.0f64; vocab];
    let mut cum = 0.0f64;
    for &id in hot.ranking() {
        let lo = cum.min(total32);
        cum += w[id as usize];
        dist[id as usize] = (cum.min(total32) - lo) / total32;
    }
    if cum < total32 {
        // Targets beyond the exact total hit the walk's last-id guard.
        dist[hot.ranking()[vocab - 1] as usize] += (total32 - cum) / total32;
    }
    dist
}

/// Oracle full-V distribution in f64 (penalties off in this comparison, as
/// both sides share them identically).
fn oracle_dist(view: &crate::tensor::ShardedLogits, params: &SamplingParams) -> Vec<f64> {
    let pairs: Vec<(u32, f32)> = {
        let mut p = Vec::with_capacity(view.vocab());
        view.for_each_logit(0, |v, z| p.push((v as u32, z)));
        p
    };
    let t = truncate(pairs, params);
    let mut dist = vec![0.0f64; view.vocab()];
    for (i, &id) in t.ids.iter().enumerate() {
        dist[id as usize] = t.prob(i);
    }
    dist
}

/// Fig 13: cumulative mean TVD across decode steps for three models.
pub fn fig13(effort: Effort) -> Report {
    let steps = effort.scale(60, 1000);
    let models: Vec<(&str, usize, f64)> = match effort {
        Effort::Quick => vec![
            ("deepseek-v3", 12_928, 1.06),
            ("llama-3.1-70b", 12_826, 1.10),
            ("qwen3-235b-a22b", 15_194, 1.05),
        ],
        Effort::Full => vec![
            ("deepseek-v3", 129_280, 1.06),
            ("llama-3.1-70b", 128_256, 1.10),
            ("qwen3-235b-a22b", 151_936, 1.05),
        ],
    };
    let params = SamplingParams {
        temperature: 0.9,
        ..Default::default() // unfiltered: the rejection path (Eq. 9)
    };
    let mut md = String::from(
        "### Fig 13 — cumulative mean TVD of SHVS vs baseline sampler\n\n\
         | model | V | steps | cumulative mean TVD | max step TVD |\n\
         |---|---:|---:|---:|---:|\n",
    );
    let mut rows = Vec::new();
    for (name, vocab, zipf_s) in models {
        let gen = LogitsGen::new(vocab, zipf_s, 77);
        let hot = gen.hot_vocab((vocab / 5).min(32_768));
        let hist = SeqHistory::new(&[]);
        let mut rng = Philox::new(5);
        let mut cum = Vec::with_capacity(steps as usize);
        let mut sum = 0.0f64;
        let mut max_step = 0.0f64;
        for it in 0..steps {
            let view = gen.view(1, it, 1);
            let shvs = shvs_induced_dist(&view, &hot, &hist, &params);
            let oracle = oracle_dist(&view, &params);
            let tvd = total_variation_distance(&shvs, &oracle);
            sum += tvd;
            max_step = max_step.max(tvd);
            cum.push(sum / (it + 1) as f64);
            let _ = rng.next_u32();
        }
        let final_cum = *cum.last().unwrap();
        let _ = writeln!(
            md,
            "| {name} | {vocab} | {steps} | {:.4}% | {:.4}% |",
            final_cum * 100.0,
            max_step * 100.0
        );
        rows.push(Json::obj(vec![
            ("model", Json::Str(name.into())),
            ("vocab", Json::Num(vocab as f64)),
            ("cumulative_tvd", Json::Num(final_cum)),
            ("max_step_tvd", Json::Num(max_step)),
            (
                "curve",
                Json::num_arr(cum.iter().step_by((cum.len() / 40).max(1))),
            ),
        ]));
    }
    md.push_str("\npaper: flat cumulative curves well below 1% (e.g. 0.067% for Llama-3.1-70B)\n");

    // Spec-decode verification exactness, reported alongside Fig 13: the
    // same per-position TVD methodology applied to rejection verification
    // (DESIGN.md §7), plus the acceptance identity |accept-rate − p(d)|.
    // Small vocabularies keep the Monte-Carlo noise floor low.
    let spec_trials = effort.scale(20_000, 120_000);
    md.push_str(
        "\n#### spec-decode verification (per-position induced distribution vs oracle)\n\n\
         | V | trials | TVD | accept-rate deviation |\n|---:|---:|---:|---:|\n",
    );
    let mut spec_rows = Vec::new();
    for vocab in [500usize, 2_000] {
        let (tvd, adev) = spec_verify_tvd(vocab, 31, spec_trials);
        let _ = writeln!(
            md,
            "| {vocab} | {spec_trials} | {:.4}% | {:.4} |",
            tvd * 100.0,
            adev
        );
        spec_rows.push(Json::obj(vec![
            ("vocab", Json::Num(vocab as f64)),
            ("trials", Json::Num(spec_trials as f64)),
            ("tvd", Json::Num(tvd)),
            ("accept_dev", Json::Num(adev)),
        ]));
    }
    md.push_str(
        "\nrejection verification is distribution-exact: residuals are pure \
         Monte-Carlo noise (they shrink with trials)\n",
    );
    Report {
        id: "fig13",
        title: "SHVS exactness (TVD)".into(),
        markdown: md,
        json: Json::obj(vec![
            ("rows", Json::Arr(rows)),
            ("spec_rows", Json::Arr(spec_rows)),
        ]),
    }
}

/// Spec-decode exactness: the per-position distribution induced by
/// rejection verification vs the oracle full-V filtered softmax.
///
/// Runs the *real* verifier on a one-draft window `trials` times with
/// fresh `(seed, seq, iteration)`-keyed uniforms, recording the committed
/// base-position token, and compares the empirical distribution against
/// the analytic penalized + truncated softmax. Also checks the acceptance
/// identity: with a point-mass draft `d`, acceptance must occur with
/// probability `p(d)` exactly. Returns `(tvd, |accept_rate − p(d)|)`.
pub fn spec_verify_tvd(vocab: usize, seed: u64, trials: u64) -> (f64, f64) {
    let gen = LogitsGen::new(vocab, 1.1, seed);
    let view = gen.view(1, 0, 2);
    let chain_view = gen.view(1, 1, 2); // position-1 logits (chain step)
    let params = SamplingParams {
        temperature: 0.9,
        top_k: 20,
        top_p: 0.95,
        min_p: 0.01,
        repetition_penalty: 1.2,
        presence_penalty: 0.1,
        frequency_penalty: 0.1,
        ..Default::default()
    };
    // A lived-in history so penalties are active at the verified position.
    let mut base_hist = BatchHistory::new(&[vec![1, 2, 3]], 64);
    base_hist.append_row(&[5 % vocab as u32]);
    base_hist.append_row(&[2]);

    // Oracle full-V filtered softmax under the same history (f64).
    let mut row = view.materialize_row(0);
    apply_penalties_dense(&mut row, base_hist.seq(0), &params);
    let pairs: Vec<(u32, f32)> =
        row.iter().enumerate().map(|(i, &z)| (i as u32, z)).collect();
    let t = truncate(pairs, &params);
    let mut oracle = vec![0.0f64; vocab];
    for (i, &id) in t.ids.iter().enumerate() {
        oracle[id as usize] = t.prob(i);
    }
    // Draft the most likely token so the accept branch is well exercised.
    let draft_tok = t
        .ids
        .iter()
        .enumerate()
        .max_by(|a, b| t.prob(a.0).partial_cmp(&t.prob(b.0)).unwrap())
        .map(|(_, &id)| id)
        .unwrap();

    let mut pipe = DecisionPipeline::new(DecisionVariant::Offloading, None, 9);
    let mut counts = vec![0.0f64; vocab];
    let mut accepts = 0u64;
    for trial in 0..trials {
        let mut hist = base_hist.clone();
        let mut grammar: GrammarSlot = None;
        // fresh uniforms per trial: each window keys a distinct base iter
        // (stride 2 keeps position 0 and 1 streams disjoint across trials)
        let v = verify_window(
            &mut pipe,
            &[view.clone(), chain_view.clone()],
            0,
            &[draft_tok],
            &mut hist,
            &mut grammar,
            &params,
            &[],
            0,
            trial * 2,
        );
        counts[v.tokens[0] as usize] += 1.0;
        if v.accepted > 0 {
            accepts += 1;
        }
    }
    let tvd = total_variation_distance(&counts, &oracle);
    let accept_dev =
        (accepts as f64 / trials as f64 - oracle[draft_tok as usize]).abs();
    (tvd, accept_dev)
}

/// Sanity helper also used by the property tests: exact SHVS-induced dist
/// must equal the oracle when everything is f64 (Eq. 9 identity).
pub fn exactness_identity_check(vocab: usize, seed: u64) -> f64 {
    let gen = LogitsGen::new(vocab, 1.1, seed);
    let hot = gen.hot_vocab(vocab / 8);
    let view = gen.view(1, 0, 1);
    // f64 α:
    let pre = Precompute::reference(&view, 0, &hot, 1.0);
    let mut hot_sum = 0.0f64;
    let mut dist = vec![0.0f64; vocab];
    let mut w_all = vec![0.0f64; vocab];
    view.for_each_logit(0, |v, z| {
        let w = ((z - pre.z_max) as f64).exp();
        w_all[v] = w;
        if hot.contains(v as u32) {
            hot_sum += w;
        }
    });
    let total = hot_sum + pre.tail_sum;
    let alpha = hot_sum / total;
    for v in 0..vocab {
        if hot.contains(v as u32) {
            dist[v] = alpha * w_all[v] / hot_sum;
        } else {
            dist[v] = (1.0 - alpha) * w_all[v] / pre.tail_sum;
        }
    }
    let oracle: Vec<f64> = w_all.iter().map(|w| w / total).collect();
    total_variation_distance(&dist, &oracle)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig13_tvd_below_one_percent() {
        let r = fig13(Effort::Quick);
        for row in r.json.get("rows").as_arr().unwrap() {
            let tvd = row.get("cumulative_tvd").as_f64().unwrap();
            assert!(
                tvd < 0.01,
                "{}: cumulative TVD {tvd}",
                row.get("model").as_str().unwrap()
            );
            // and the curve is flat-ish: max step not wildly above the mean
            let max = row.get("max_step_tvd").as_f64().unwrap();
            assert!(max < 0.05, "max step TVD {max}");
        }
    }

    #[test]
    fn spec_verify_induced_distribution_matches_oracle() {
        // The satellite check: rejection verification's per-position
        // distribution equals the oracle full-V filtered softmax, within
        // Monte-Carlo noise, and the accept branch fires with exactly the
        // draft token's target probability.
        let (tvd, accept_dev) = spec_verify_tvd(600, 7, 60_000);
        assert!(tvd < 0.03, "induced-vs-oracle TVD {tvd}");
        assert!(accept_dev < 0.02, "acceptance deviation {accept_dev}");
    }

    #[test]
    fn fig13_reports_spec_rows() {
        let r = fig13(Effort::Quick);
        let spec = r.json.get("spec_rows").as_arr().unwrap();
        assert_eq!(spec.len(), 2);
        for row in spec {
            // loose CI bound at quick-effort trial counts
            assert!(row.get("tvd").as_f64().unwrap() < 0.1);
        }
    }

    #[test]
    fn identity_holds_in_f64() {
        // Eq. 9: with exact arithmetic the induced distribution IS the
        // softmax — TVD at machine-epsilon scale.
        for seed in [1u64, 2, 3] {
            let tvd = exactness_identity_check(2_000, seed);
            assert!(tvd < 1e-12, "seed {seed}: TVD {tvd}");
        }
    }

    #[test]
    fn adaptive_shvs_stream_digest_equals_static() {
        // Satellite case, digest half: the controller resizing H live must
        // not perturb the sampled stream — with nested rankings and the
        // H-invariant coupled walk, the adaptive run's tokens are
        // bit-identical to a static-H run under the same seed.
        use crate::decision::sizing::{zipf_alpha_knots, SizingModel};
        use crate::decision::{ControllerConfig, HotVocabController};
        let vocab = 4_000;
        let gen = LogitsGen::new(vocab, 1.1, 21);
        let params = SamplingParams { temperature: 1.0, ..Default::default() };
        let hist = BatchHistory::new(&[vec![]], 4);
        let steps = 400u64;

        // Static reference stream at a fixed H over the SAME ranking.
        let static_hot = gen.ranked_hot_vocab(512).into_arc();
        let mut static_pipe =
            DecisionPipeline::new(DecisionVariant::Shvs, Some(static_hot.clone()), 3);
        let mut static_stream = Vec::with_capacity(steps as usize);
        for it in 0..steps {
            let view = gen.view(1, it, 1);
            let pre = Precompute::reference(&view, 0, &static_hot, 1.0);
            let d = static_pipe.decide(&view, 0, &hist, 0, &params, Some(&pre), 0, it);
            static_stream.push(d.token);
        }

        // Adaptive stream: the controller observes realized α and resizes.
        let knots = zipf_alpha_knots(vocab, 1.1, 12);
        let cost: Vec<(f64, f64)> =
            knots.iter().map(|&(h, _)| (h, 1.0e-8 * h + 8.0e-6)).collect();
        let sizing = SizingModel::fit(&cost, &knots, vocab);
        let mut ctl = HotVocabController::new(
            ControllerConfig { window: 40, ..Default::default() },
            sizing,
            96, // deliberately far from H* so resizes actually happen
        );
        let mut hot = gen.ranked_hot_vocab(ctl.h()).into_arc();
        let mut pipe = DecisionPipeline::new(DecisionVariant::Shvs, Some(hot.clone()), 3);
        let mut adaptive_stream = Vec::with_capacity(steps as usize);
        let mut resizes = 0usize;
        for it in 0..steps {
            let view = gen.view(1, it, 1);
            let pre = Precompute::reference(&view, 0, &hot, 1.0);
            let d = pipe.decide(&view, 0, &hist, 0, &params, Some(&pre), 0, it);
            adaptive_stream.push(d.token);
            if let Some(new_h) = ctl.observe(d.alpha, d.accepted) {
                resizes += 1;
                hot = hot.resize(new_h).into_arc();
                pipe.set_hot_vocab(hot.clone());
            }
        }
        assert!(resizes > 0, "controller never resized — test is vacuous");
        assert_eq!(
            adaptive_stream, static_stream,
            "adaptive resizing perturbed the token stream"
        );
    }

    #[test]
    fn adaptive_controller_converges_within_one_bucket() {
        // Satellite case, convergence half: on stationary traffic (runtime
        // acceptance matching the offline fit) the online controller stays
        // within one sizing-grid bucket of the offline H*.
        let gen = LogitsGen::new(8_000, 1.1, 5);
        let a = crate::harness::measure::adaptive_h_star(&gen, 10, 6);
        let (h, star) = (a.h as f64, a.offline_h_star as f64);
        let tol = a.bucket * 1.05;
        assert!(
            h <= star * tol && h >= star / tol,
            "adaptive H {h} vs offline H* {star} (bucket {})",
            a.bucket
        );
    }
}
