//! Measured decision-plane calibration.
//!
//! Everything the simulator needs about the decision plane is *measured*
//! here on this host, never modelled: per-sequence decision cost for each
//! ablation variant, the SHVS hit-ratio curve ᾱ(H), and the fitted sizing
//! model of §5.4.

use crate::config::DecisionVariant;
use crate::decision::penalties::BatchHistory;
use crate::decision::sizing::SizingModel;
use crate::decision::{
    ControllerConfig, DecisionPipeline, HotVocab, HotVocabController, Precompute,
    SamplingParams,
};
use crate::rng::Philox;
use crate::tensor::{shard_row_major, ShardedLogits, Tensor2};
use std::sync::Arc;
use std::time::Instant;

/// Synthetic Zipf-shaped logits generator: rank-based head + Gaussian noise,
/// under a seed-stable id permutation (so hot ids aren't trivially 0..H).
pub struct LogitsGen {
    pub vocab: usize,
    zipf_s: f64,
    rank_of_id: Vec<u32>,
    seed: u64,
}

impl LogitsGen {
    pub fn new(vocab: usize, zipf_s: f64, seed: u64) -> LogitsGen {
        let mut rng = Philox::new(seed ^ 0xFEED);
        let mut id_of_rank: Vec<u32> = (0..vocab as u32).collect();
        rng.shuffle(&mut id_of_rank);
        let mut rank_of_id = vec![0u32; vocab];
        for (rank, &id) in id_of_rank.iter().enumerate() {
            rank_of_id[id as usize] = rank as u32;
        }
        LogitsGen { vocab, zipf_s, rank_of_id, seed }
    }

    /// The top-`h` ids by rank — the matching hot vocabulary.
    pub fn hot_vocab(&self, h: usize) -> HotVocab {
        let ids: Vec<u32> = (0..self.vocab as u32)
            .filter(|&id| (self.rank_of_id[id as usize] as usize) < h)
            .collect();
        HotVocab::new(ids, self.vocab)
    }

    /// The top-`h` hot vocabulary built over the generator's FULL rank
    /// permutation ([`HotVocab::from_ranking`]), so every size derived from
    /// one generator shares a single ranking and the hot sets nest under
    /// [`HotVocab::resize`] — the property the adaptive-sizing
    /// bit-identical-streams contract relies on. The hot *set* equals
    /// [`Self::hot_vocab`]'s for the same `h`.
    pub fn ranked_hot_vocab(&self, h: usize) -> HotVocab {
        let mut ranking = vec![0u32; self.vocab];
        for (id, &rank) in self.rank_of_id.iter().enumerate() {
            ranking[rank as usize] = id as u32;
        }
        HotVocab::from_ranking(Arc::new(ranking), h, self.vocab)
    }

    /// Row-major [batch, V] logits for one iteration.
    pub fn batch_logits(&self, batch: usize, iter: u64) -> Tensor2 {
        let mut data = vec![0.0f32; batch * self.vocab];
        for b in 0..batch {
            let mut rng =
                Philox::at(self.seed, ((b as u128) << 64) | ((iter as u128) << 32));
            let row = &mut data[b * self.vocab..(b + 1) * self.vocab];
            for (id, z) in row.iter_mut().enumerate() {
                let rank = self.rank_of_id[id] as f64;
                *z = (-self.zipf_s * (rank + 2.0).ln()) as f32
                    + rng.next_normal() as f32 * 0.7;
            }
        }
        Tensor2::from_vec(batch, self.vocab, data)
    }

    /// Sharded view for one iteration.
    pub fn view(&self, batch: usize, iter: u64, shards: usize) -> ShardedLogits {
        shard_row_major(&self.batch_logits(batch, iter), shards)
    }

    /// Row-major [batch, V] logits where row `b` is keyed by that column's
    /// `(seq_id, decode_iter)` instead of its batch position. In a real
    /// model the logits are a function of the sequence's tokens, not of the
    /// slot it happens to occupy; tests of scheduler churn (preemption,
    /// slot migration) need the same invariance, else a resumed sequence
    /// would see different logits purely because it moved slots.
    pub fn seq_batch_logits(&self, cols: &[(u64, u64)]) -> Tensor2 {
        let mut data = vec![0.0f32; cols.len() * self.vocab];
        for (b, &(seq_id, decode_iter)) in cols.iter().enumerate() {
            let mut rng = Philox::at(
                self.seed ^ 0xD15C,
                ((seq_id as u128) << 64) | ((decode_iter as u128) << 32),
            );
            let row = &mut data[b * self.vocab..(b + 1) * self.vocab];
            for (id, z) in row.iter_mut().enumerate() {
                let rank = self.rank_of_id[id] as f64;
                *z = (-self.zipf_s * (rank + 2.0).ln()) as f32
                    + rng.next_normal() as f32 * 0.7;
            }
        }
        Tensor2::from_vec(cols.len(), self.vocab, data)
    }

    /// Sharded view of [`Self::seq_batch_logits`].
    pub fn seq_view(&self, cols: &[(u64, u64)], shards: usize) -> ShardedLogits {
        shard_row_major(&self.seq_batch_logits(cols), shards)
    }

    /// Row-major [batch, V] logits keyed by `(seq_id, decode_iter, fed
    /// token)` — the context-SENSITIVE synthetic data plane. Speculative
    /// decoding needs it: a draft chain position fed a *rejected* token
    /// must see different logits than the true continuation would, so any
    /// bug that commits past the accept point breaks stream determinism
    /// loudly instead of being masked by context-free logits.
    pub fn ctx_batch_logits(&self, cols: &[(u64, u64, u32)]) -> Tensor2 {
        let mut data = vec![0.0f32; cols.len() * self.vocab];
        for (b, &(seq_id, decode_iter, fed)) in cols.iter().enumerate() {
            let mut rng = Philox::at(
                self.seed ^ 0xC07E,
                ((seq_id as u128) << 72)
                    | ((decode_iter as u128) << 40)
                    | ((fed as u128) << 8),
            );
            let row = &mut data[b * self.vocab..(b + 1) * self.vocab];
            for (id, z) in row.iter_mut().enumerate() {
                let rank = self.rank_of_id[id] as f64;
                *z = (-self.zipf_s * (rank + 2.0).ln()) as f32
                    + rng.next_normal() as f32 * 0.7;
            }
        }
        Tensor2::from_vec(cols.len(), self.vocab, data)
    }

    /// Sharded view of [`Self::ctx_batch_logits`].
    pub fn ctx_view(&self, cols: &[(u64, u64, u32)], shards: usize) -> ShardedLogits {
        shard_row_major(&self.ctx_batch_logits(cols), shards)
    }
}

/// Build the `kmax+1` context-keyed chain views for one iteration's
/// decision columns — THE convention `verify_window` indexes by, held in
/// one place for every offline driver (churn tests, property tests,
/// acceptance measurement): `views[j]` holds, for each column, logits
/// keyed `(seq, base_iter + j, fed token)`, where `fed` is the column's
/// base input token at `j = 0` and its draft token `j−1` beyond (clamped
/// for columns with shorter windows, which never read those views).
///
/// `cols[ci] = (seq_id, base_decode_iter, base_input_token)`, aligned with
/// `drafts[ci]`.
pub fn chain_views(
    gen: &LogitsGen,
    cols: &[(u64, u64, u32)],
    drafts: &[Vec<u32>],
    shards: usize,
) -> Vec<ShardedLogits> {
    assert_eq!(cols.len(), drafts.len(), "one draft window per column");
    let kmax = drafts.iter().map(Vec::len).max().unwrap_or(0);
    (0..=kmax)
        .map(|j| {
            let keys: Vec<(u64, u64, u32)> = cols
                .iter()
                .zip(drafts)
                .map(|(&(seq, base, fed0), d)| {
                    let fed = if j == 0 || d.is_empty() {
                        fed0
                    } else {
                        d[(j - 1).min(d.len() - 1)]
                    };
                    (seq, base + j as u64, fed)
                })
                .collect();
            gen.ctx_view(&keys, shards)
        })
        .collect()
}

/// Measured per-position draft acceptance under verified speculative
/// decoding: runs the REAL proposer + verifier (never modelled) over a
/// self-drafted decode on the synthetic data plane, and reports
/// accepted/proposed. This is the `accept_rate` the simulator's
/// `DecisionMode::SpecVerify` is injected with.
pub fn measure_spec_acceptance(vocab: usize, k: usize, steps: u64) -> f64 {
    if k == 0 || steps == 0 {
        return 0.0;
    }
    let gen = LogitsGen::new(vocab, 1.2, 23);
    let proposer = crate::decision::draft::DraftProposer::new();
    let mut pipe = DecisionPipeline::new(DecisionVariant::Offloading, None, 3);
    let params = SamplingParams::production_default();
    let prompt = vec![1u32, 2, 3];
    let cap = (steps as usize) * (k + 2) + 8;
    let mut hist = BatchHistory::new(&[prompt.clone()], cap);
    let mut grammar: crate::decision::verify::GrammarSlot = None;
    let mut out: Vec<u32> = Vec::new();
    let (mut acc, mut prop) = (0u64, 0u64);
    for _ in 0..steps {
        let base = out.len() as u64;
        let draft = proposer.propose(params.seed, vocab, &prompt, &out, k);
        let fed0 = out.last().copied().unwrap_or(prompt[prompt.len() - 1]);
        let views = chain_views(
            &gen,
            &[(0, base, fed0)],
            std::slice::from_ref(&draft),
            1,
        );
        let v = crate::decision::verify::verify_window(
            &mut pipe, &views, 0, &draft, &mut hist, &mut grammar, &params, &[], 0,
            base,
        );
        acc += v.accepted as u64;
        prop += v.proposed as u64;
        out.extend(&v.tokens);
    }
    if prop == 0 {
        0.0
    } else {
        acc as f64 / prop as f64
    }
}

/// Measured per-variant decision costs (seconds per sequence).
#[derive(Debug, Clone)]
pub struct DecisionCalibration {
    pub vocab: usize,
    pub hot_size: usize,
    pub per_seq: Vec<(DecisionVariant, f64)>,
    /// Mean SHVS acceptance at the calibrated hot size.
    pub shvs_alpha: f64,
}

impl DecisionCalibration {
    pub fn per_seq_s(&self, v: DecisionVariant) -> f64 {
        self.per_seq
            .iter()
            .find(|(var, _)| *var == v)
            .map(|&(_, s)| s)
            .expect("variant measured")
    }
}

/// Measure per-sequence decision time for one variant.
///
/// GPU-side work (the SHVS precompute) is excluded from the timed region —
/// it ships with the logits in the real system.
pub fn measure_variant(
    gen: &LogitsGen,
    variant: DecisionVariant,
    hot: Option<Arc<HotVocab>>,
    params: &SamplingParams,
    iters: u64,
) -> (f64, f64) {
    let mut pipe = DecisionPipeline::new(variant, hot.clone(), 0xBEEF);
    let mut hist = BatchHistory::new(&[vec![1, 2, 3]], (iters + 8) as usize);
    let tau = params.temperature.max(1e-6);
    // Pre-generate views + precomputes outside the timed loop.
    let warm = 2u64.min(iters);
    let mut total = 0.0f64;
    let mut measured = 0u64;
    for it in 0..iters + warm {
        let view = gen.view(1, it, 1);
        let pre = hot
            .as_ref()
            .map(|h| Precompute::reference(&view, 0, h, tau));
        let t0 = Instant::now();
        let d = pipe.decide(&view, 0, &hist, 0, params, pre.as_ref(), 0, it);
        let dt = t0.elapsed().as_secs_f64();
        hist.append_row(&[d.token]);
        if it >= warm {
            total += dt;
            measured += 1;
        }
    }
    (total / measured as f64, pipe.mean_alpha())
}

/// Calibrate all CPU variants at a given vocabulary size.
pub fn calibrate(vocab: usize, hot_size: usize, iters: u64) -> DecisionCalibration {
    let gen = LogitsGen::new(vocab, 1.1, 42);
    let hot = gen.hot_vocab(hot_size).into_arc();
    let params = SamplingParams::production_default();
    let mut per_seq = Vec::new();
    let mut shvs_alpha = 0.0;
    for variant in [
        DecisionVariant::NaiveCpu,
        DecisionVariant::Parallel,
        DecisionVariant::Offloading,
        DecisionVariant::Shvs,
    ] {
        let h = matches!(variant, DecisionVariant::Shvs).then(|| hot.clone());
        let (t, alpha) = measure_variant(&gen, variant, h, &params, iters);
        if variant == DecisionVariant::Shvs {
            shvs_alpha = alpha;
        }
        per_seq.push((variant, t));
    }
    DecisionCalibration { vocab, hot_size, per_seq, shvs_alpha }
}

/// Measure the hit-ratio curve ᾱ(H): hot-set probability mass, averaged
/// over synthetic iterations (model/policy property, §5.4).
pub fn measure_alpha_curve(
    gen: &LogitsGen,
    h_points: &[usize],
    iters: u64,
) -> Vec<(f64, f64)> {
    let mut knots = Vec::with_capacity(h_points.len());
    for &h in h_points {
        let hot = gen.hot_vocab(h);
        let mut alpha_sum = 0.0;
        for it in 0..iters {
            let view = gen.view(1, it, 1);
            let pre = Precompute::reference(&view, 0, &hot, 1.0);
            // hot mass from the tail sum + total
            let mut total = 0.0f64;
            view.for_each_logit(0, |_, z| {
                total += ((z - pre.z_max) as f64).exp();
            });
            alpha_sum += (total - pre.tail_sum) / total;
        }
        knots.push((h as f64, alpha_sum / iters as f64));
    }
    knots
}

/// Measure SHVS *hot-path* time at several H values and fit the affine
/// cost model T_cpu(H) = cH + c0 (Figure 11a). Uses unfiltered sampling so
/// the fast path dominates, and reports only fast-path times.
pub fn measure_hot_path_costs(
    gen: &LogitsGen,
    h_points: &[usize],
    iters: u64,
) -> Vec<(f64, f64)> {
    let params = SamplingParams {
        temperature: 0.9,
        ..Default::default() // no filters: pure hot/tail rejection path
    };
    let n_views = iters.min(8) as usize;
    let views: Vec<_> = (0..n_views).map(|i| gen.view(1, i as u64, 1)).collect();
    let mut points = Vec::with_capacity(h_points.len());
    for &h in h_points {
        let hot = gen.hot_vocab(h).into_arc();
        let pres: Vec<_> = views
            .iter()
            .map(|v| Precompute::reference(v, 0, &hot, params.temperature))
            .collect();
        let mut pipe = DecisionPipeline::new(DecisionVariant::Shvs, Some(hot.clone()), 7);
        let hist = BatchHistory::new(&[vec![]], 4);
        let mut total = 0.0;
        let mut count = 0u64;
        for it in 0..iters {
            let i = it as usize % n_views;
            let t0 = Instant::now();
            let d = pipe.decide(&views[i], 0, &hist, 0, &params, Some(&pres[i]), 0, it);
            let dt = t0.elapsed().as_secs_f64();
            if d.fast_path {
                total += dt;
                count += 1;
            }
        }
        if count > 0 {
            points.push((h as f64, total / count as f64));
        }
    }
    points
}

/// Fit the full §5.4 sizing model from measurements.
pub fn fit_sizing_model(vocab: usize, zipf_s: f64, iters: u64) -> SizingModel {
    let gen = LogitsGen::new(vocab, zipf_s, 42);
    let h_points: Vec<usize> = geometric_points(vocab, 10);
    let costs = measure_hot_path_costs(&gen, &h_points, iters);
    let alphas = measure_alpha_curve(&gen, &h_points, iters.min(16));
    SizingModel::fit(&costs, &alphas, vocab)
}

/// Result of the online-adaptive §5.4 sizing run ([`adaptive_h_star`]).
#[derive(Debug, Clone, Copy)]
pub struct AdaptiveSizing {
    /// H the controller converged to.
    pub h: usize,
    /// The offline-fitted H* the controller started from.
    pub offline_h_star: usize,
    /// Multiplicative width of one sizing-grid bucket — adjacent H grid
    /// points differ by at most this factor; the natural convergence
    /// tolerance unit ("within one bucket of H*").
    pub bucket: f64,
}

/// Online-adaptive H* (§9 future-work item i, replacing the static §5.4
/// deployment rule): fit the offline sizing model from measurements on
/// `gen`, then run the [`HotVocabController`] against the REAL decision
/// plane — every decision's realized α feeds the acceptance counters, the
/// controller re-estimates ᾱ(H) from them, re-picks H* online, and the hot
/// vocabulary is resized live through the shared ranking
/// ([`LogitsGen::ranked_hot_vocab`] + [`HotVocab::resize`], so hot sets
/// nest and token streams stay bit-identical across sizes).
pub fn adaptive_h_star(gen: &LogitsGen, iters: u64, periods: u64) -> AdaptiveSizing {
    let h_points = geometric_points(gen.vocab, 10);
    let costs = measure_hot_path_costs(gen, &h_points, iters);
    let alphas = measure_alpha_curve(gen, &h_points, iters.min(16));
    let sizing = SizingModel::fit(&costs, &alphas, gen.vocab);
    let offline_h_star = sizing.h_star();
    let bucket = h_points
        .windows(2)
        .map(|w| w[1] as f64 / w[0] as f64)
        .fold(1.0f64, f64::max);

    let window = 256u64;
    let cfg = ControllerConfig { window, ..Default::default() };
    let mut ctl = HotVocabController::new(cfg, sizing, offline_h_star);
    // Unfiltered at τ = 1.0 so realized α matches the ᾱ(H) curve's unit.
    let params = SamplingParams { temperature: 1.0, ..Default::default() };
    let n_views = 8usize;
    let views: Vec<_> = (0..n_views).map(|i| gen.view(1, i as u64, 1)).collect();
    let mut hot = gen.ranked_hot_vocab(ctl.h()).into_arc();
    let mut pres: Vec<_> = views
        .iter()
        .map(|v| Precompute::reference(v, 0, &hot, params.temperature))
        .collect();
    let mut pipe = DecisionPipeline::new(DecisionVariant::Shvs, Some(hot.clone()), 0xADA7);
    let hist = BatchHistory::new(&[vec![]], 4);
    let mut it = 0u64;
    for _ in 0..periods {
        for _ in 0..window {
            let i = it as usize % n_views;
            let d = pipe.decide(&views[i], 0, &hist, 0, &params, Some(&pres[i]), 0, it);
            it += 1;
            if let Some(new_h) = ctl.observe(d.alpha, d.accepted) {
                hot = hot.resize(new_h).into_arc();
                pipe.set_hot_vocab(hot.clone());
                for (p, v) in pres.iter_mut().zip(&views) {
                    *p = Precompute::reference(v, 0, &hot, params.temperature);
                }
            }
        }
    }
    AdaptiveSizing { h: ctl.h(), offline_h_star, bucket }
}

/// Geometric grid of H values up to ~V/2.
pub fn geometric_points(vocab: usize, n: usize) -> Vec<usize> {
    let lo = 64.0f64.min(vocab as f64 / 4.0).max(2.0);
    let hi = vocab as f64 / 2.0;
    let mut pts: Vec<usize> = (0..n)
        .map(|i| {
            let f = i as f64 / (n - 1) as f64;
            (lo * (hi / lo).powf(f)).round() as usize
        })
        .collect();
    pts.dedup();
    pts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logits_gen_is_zipf_headed() {
        let gen = LogitsGen::new(2000, 1.1, 1);
        let hot = gen.hot_vocab(200);
        assert_eq!(hot.len(), 200);
        let view = gen.view(1, 0, 1);
        let pre = Precompute::reference(&view, 0, &hot, 1.0);
        let mut total = 0.0f64;
        view.for_each_logit(0, |_, z| total += ((z - pre.z_max) as f64).exp());
        let alpha = (total - pre.tail_sum) / total;
        assert!(alpha > 0.5, "head mass {alpha}");
    }

    #[test]
    fn logits_vary_across_iterations_and_sequences() {
        let gen = LogitsGen::new(500, 1.1, 2);
        let a = gen.batch_logits(2, 0);
        let b = gen.batch_logits(2, 1);
        assert_ne!(a.row(0), b.row(0), "iterations differ");
        assert_ne!(a.row(0), a.row(1), "sequences differ");
        // deterministic
        let a2 = gen.batch_logits(2, 0);
        assert_eq!(a.row(0), a2.row(0));
    }

    #[test]
    fn ctx_view_distinguishes_fed_tokens() {
        // Same (seq, iter) but a different fed token ⇒ different logits —
        // the property that makes spec-decode differential tests honest.
        let gen = LogitsGen::new(400, 1.1, 6);
        let a = gen.ctx_batch_logits(&[(3, 5, 10)]);
        let b = gen.ctx_batch_logits(&[(3, 5, 11)]);
        let c = gen.ctx_batch_logits(&[(3, 5, 10)]);
        assert_ne!(a.row(0), b.row(0), "fed token must perturb the logits");
        assert_eq!(a.row(0), c.row(0), "deterministic in the key");
    }

    #[test]
    fn spec_acceptance_is_a_probability() {
        let alpha = measure_spec_acceptance(512, 3, 60);
        assert!((0.0..=1.0).contains(&alpha), "alpha {alpha}");
        assert_eq!(measure_spec_acceptance(512, 0, 60), 0.0);
    }

    #[test]
    fn calibration_orders_the_ablation_ladder() {
        // Figure 10's qualitative claim at micro scale: each step of the
        // ladder is at least as fast as the previous.
        let cal = calibrate(32_000, 6_400, 20);
        let naive = cal.per_seq_s(DecisionVariant::NaiveCpu);
        let offload = cal.per_seq_s(DecisionVariant::Offloading);
        let shvs = cal.per_seq_s(DecisionVariant::Shvs);
        assert!(offload < naive, "offload {offload} vs naive {naive}");
        assert!(shvs < offload, "shvs {shvs} vs offload {offload}");
        assert!(cal.shvs_alpha > 0.0);
    }

    #[test]
    fn alpha_curve_monotone() {
        let gen = LogitsGen::new(4_000, 1.1, 3);
        let knots = measure_alpha_curve(&gen, &[64, 256, 1024, 2000], 6);
        for w in knots.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-9, "ᾱ must grow with H: {knots:?}");
        }
    }

    #[test]
    fn hot_path_cost_grows_with_h() {
        let gen = LogitsGen::new(16_000, 1.1, 4);
        let pts = measure_hot_path_costs(&gen, &[256, 8_000], 40);
        assert_eq!(pts.len(), 2);
        assert!(
            pts[1].1 > pts[0].1,
            "H=8000 must cost more than H=256: {pts:?}"
        );
    }

    #[test]
    fn geometric_points_span() {
        let pts = geometric_points(152_064, 10);
        assert!(pts.len() >= 8);
        assert!(pts[0] <= 100);
        assert!(*pts.last().unwrap() >= 70_000);
        assert!(pts.windows(2).all(|w| w[1] > w[0]));
    }
}
