//! The `cluster` experiment (DESIGN.md §9): data-parallel engine replicas
//! behind the decision-plane-aware router, measured end to end over the
//! context-faithful synthetic plane — no artifacts needed.
//!
//! Three sections:
//! 1. **Measured sweep** — replicas × routing policy × traffic pattern,
//!    reporting aggregate throughput and fleet-wide P95/P99 TPOT from the
//!    merged recorders, plus every run's stream digest. The digests must
//!    all equal the single-engine baseline: routing moves work, never
//!    decisions.
//! 2. **Sampler-pool comparison** — per-replica pools vs one shared pool
//!    at equal total sampler count (the paper's disaggregation taken
//!    across the fleet axis: pooled decision capacity instead of stranded
//!    per-replica samplers).
//! 3. **Simulated scaling** — `simulate_cluster` on a paper deployment,
//!    including a DistServe-style prefill/decode split row, so measured
//!    and simulated cluster behavior sit side by side.

use super::{Effort, Report};
use crate::cluster::{Cluster, ClusterConfig, ClusterReport, RoutePolicy};
use crate::config::{DecisionVariant, EngineConfig, ModelSpec, ParallelConfig, PlatformSpec};
use crate::engine::{Engine, Request, SyntheticRuntime};
use crate::simulator::{
    simulate_cluster, ClusterSimConfig, DecisionMode, GpuModel, SimConfig,
};
use crate::util::json::Json;
use crate::workload::{self, TraceConfig, TrafficPattern};
use std::fmt::Write;

const VOCAB: usize = 2_048;
const MAX_SEQ: usize = 96;
const BATCH: usize = 4;
const PLANE_SEED: u64 = 31;

fn engine_cfg(m: usize) -> EngineConfig {
    let mut cfg = EngineConfig::default();
    cfg.sampler.variant = DecisionVariant::Offloading;
    cfg.sampler.num_samplers = m;
    cfg.sampler.seed = 0xC1u64;
    cfg.idle_poll_us = 20;
    cfg
}

fn trace(n: usize, traffic: Option<(TrafficPattern, f64)>) -> Vec<Request> {
    let mut t = workload::generate(&TraceConfig::tiny(n, VOCAB));
    if let Some((pattern, rate)) = traffic {
        pattern.stamp(&mut t, rate, 5);
    }
    t.requests
}

/// Single-engine ground truth digest for the trace (arrivals don't change
/// tokens, so one digest anchors every traffic pattern).
fn baseline_digest(n: usize, m: usize) -> u64 {
    let cfg = engine_cfg(m);
    let runtime = SyntheticRuntime::new(BATCH, VOCAB, MAX_SEQ, PLANE_SEED);
    let mut engine = Engine::new(runtime, &cfg, None);
    for r in trace(n, None) {
        engine.submit(r);
    }
    engine.run_until_idle().expect("baseline engine run");
    let digest = crate::util::stream_digest(
        engine
            .take_finished()
            .into_iter()
            .map(|f| (f.request.id, f.output))
            .collect(),
    );
    engine.shutdown();
    digest
}

fn run_cluster(
    n: usize,
    m: usize,
    ccfg: &ClusterConfig,
    traffic: Option<(TrafficPattern, f64)>,
) -> (ClusterReport, f64) {
    let cfg = engine_cfg(m);
    let mut cluster = Cluster::start(
        &cfg,
        ccfg,
        None,
        MAX_SEQ,
        |_id| Ok(SyntheticRuntime::new(BATCH, VOCAB, MAX_SEQ, PLANE_SEED)),
    );
    let t0 = std::time::Instant::now();
    cluster.run(trace(n, traffic)).expect("cluster run");
    let wall_s = t0.elapsed().as_secs_f64();
    (cluster.shutdown().expect("cluster shutdown"), wall_s)
}

/// The `cluster` experiment driver.
pub fn cluster(effort: Effort) -> Report {
    let n_req = effort.scale(16, 64) as usize;
    let m = 2usize;
    let rate = 400.0;
    let want = baseline_digest(n_req, m);

    let mut md = format!(
        "### cluster — data-parallel replicas behind the decision-plane-aware \
         router (synthetic plane, {n_req} requests, m={m}/replica)\n\n\
         | replicas | policy | traffic | tok/s | TPOT p95 | TPOT p99 | preempt | digest |\n\
         |---:|---|---|---:|---:|---:|---:|---|\n",
    );
    let mut rows = Vec::new();
    let mut identical = true;
    let traffics: [(&str, Option<(TrafficPattern, f64)>); 2] = [
        ("closed", None),
        ("burst", Some((TrafficPattern::parse("burst").unwrap(), rate))),
    ];
    for replicas in [1usize, 2, 4] {
        for policy in RoutePolicy::ALL {
            for (tname, traffic) in traffics {
                let mut ccfg = ClusterConfig::default();
                ccfg.replicas = replicas;
                ccfg.policy = policy;
                let (report, _wall) = run_cluster(n_req, m, &ccfg, traffic);
                let digest = report.stream_digest();
                identical &= digest == want;
                let agg = report.recorder.summary();
                let tpot = report.recorder.tpot_summary();
                let _ = writeln!(
                    md,
                    "| {} | {} | {} | {:>7.0} | {:>6.2} ms | {:>6.2} ms | {} | {:016x} |",
                    replicas,
                    policy.name(),
                    tname,
                    agg.throughput,
                    tpot.p95 * 1e3,
                    tpot.p99 * 1e3,
                    report.preemptions,
                    digest,
                );
                rows.push(Json::obj(vec![
                    ("replicas", Json::Num(replicas as f64)),
                    ("policy", Json::Str(policy.name().into())),
                    ("traffic", Json::Str(tname.into())),
                    ("throughput", Json::Num(agg.throughput)),
                    ("tpot_p95", Json::Num(tpot.p95)),
                    ("tpot_p99", Json::Num(tpot.p99)),
                    ("preemptions", Json::Num(report.preemptions as f64)),
                    ("digest", Json::Str(format!("{digest:016x}"))),
                ]));
            }
        }
    }
    let _ = writeln!(
        md,
        "\nall digests equal the single-engine baseline: **{identical}** \
         (routing moves work, never decisions)\n"
    );

    // Pooled vs stranded decision capacity at equal total sampler count.
    md.push_str(
        "sampler pools, 2 replicas, 2 samplers total:\n\n\
         | pool | tok/s | digest ok |\n|---|---:|---|\n",
    );
    let mut pool_rows = Vec::new();
    let mut ccfg = ClusterConfig::default();
    ccfg.replicas = 2;
    ccfg.policy = RoutePolicy::LeastOutstanding;
    for shared in [false, true] {
        ccfg.shared_samplers = shared;
        let per_replica_m = if shared { 2 } else { 1 };
        let (report, _wall) = run_cluster(n_req, per_replica_m, &ccfg, None);
        // streams are invariant to the sampler count m, so the m=2
        // baseline digest anchors both pool modes
        let ok = report.stream_digest() == want;
        identical &= ok;
        let name = if shared { "shared (1×2)" } else { "per-replica (2×1)" };
        let tput = report.recorder.summary().throughput;
        let _ = writeln!(md, "| {name} | {tput:>7.0} | {ok} |");
        pool_rows.push(Json::obj(vec![
            ("shared", Json::Bool(shared)),
            ("throughput", Json::Num(tput)),
            ("digest_ok", Json::Bool(ok)),
        ]));
    }
    md.push_str(
        "\n`benches/decision_micro.rs cluster/` measures the same contrast \
         under deliberate load imbalance, where the stranded per-replica \
         sampler idles while the shared pool keeps both busy\n\n",
    );

    // Simulated fleet scaling on a paper deployment (+ a split row).
    md.push_str(
        "simulated (H100, Qwen3-235B-A22B, roofline model):\n\n\
         | fleet | tok/s | scaling |\n|---|---:|---:|\n",
    );
    let model = ModelSpec::qwen3_235b_a22b();
    let platform = PlatformSpec::h100();
    let parallel = ParallelConfig::paper_preset(&model, &platform).unwrap();
    let sim_n = effort.scale(120, 480) as usize;
    let sim_trace = {
        let t = workload::generate(&TraceConfig::sharegpt_like(sim_n, model.vocab, 4096));
        crate::simulator::serving::to_sim_requests(&t)
    };
    let gpu = GpuModel::new(model.clone(), platform.clone(), parallel);
    // 32 slots per replica (not 32 × world): the trace then saturates one
    // replica's slot capacity, so adding replicas adds visible throughput
    // at CI trace sizes.
    let sim_cfg = SimConfig::new(
        gpu,
        DecisionMode::SimpleOverlapped {
            per_seq_s: super::e2e::measured_shvs_per_seq(model.vocab, effort),
            samplers: 64,
        },
        32,
        platform.cpu_cores,
        64,
    );
    let mut sim_rows = Vec::new();
    let mut base_tput = 0.0f64;
    for replicas in [1usize, 2, 4] {
        let mut scfg = ClusterSimConfig::default();
        scfg.replicas = replicas;
        let res = simulate_cluster(&sim_cfg, &scfg, &sim_trace);
        let tput = res.throughput();
        if replicas == 1 {
            base_tput = tput;
        }
        let _ = writeln!(
            md,
            "| {replicas} unified | {tput:>8.0} | ×{:.2} |",
            tput / base_tput
        );
        sim_rows.push(Json::obj(vec![
            ("replicas", Json::Num(replicas as f64)),
            ("split", Json::Bool(false)),
            ("throughput", Json::Num(tput)),
        ]));
    }
    let mut split = ClusterSimConfig::default();
    split.replicas = 4;
    split.prefill_replicas = 1;
    let res = simulate_cluster(&sim_cfg, &split, &sim_trace);
    let _ = writeln!(
        md,
        "| 1 prefill + 3 decode | {:>8.0} | ×{:.2} |",
        res.throughput(),
        res.throughput() / base_tput
    );
    sim_rows.push(Json::obj(vec![
        ("replicas", Json::Num(4.0)),
        ("split", Json::Bool(true)),
        ("throughput", Json::Num(res.throughput())),
    ]));
    md.push_str(
        "\nthe measured rows and the simulated rows answer the same question \
         at two scales: decision-plane disaggregation holds across the fleet \
         axis — capacity pools, placement never touches tokens\n",
    );

    // The experiment IS the smoke gate (`make cluster-smoke` in CI): a
    // routing configuration that changed even one token is a hard bug, so
    // fail the run loudly rather than just reporting `false`.
    assert!(
        identical,
        "cluster digest mismatch: some routed run diverged from the \
         single-engine baseline (routing must never change tokens)"
    );
    Report {
        id: "cluster",
        title: "Data-parallel replicas behind a decision-plane-aware router".into(),
        markdown: md,
        json: Json::obj(vec![
            ("measured", Json::Arr(rows)),
            ("digests_identical", Json::Bool(identical)),
            ("pools", Json::Arr(pool_rows)),
            ("simulated", Json::Arr(sim_rows)),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_experiment_streams_identical_across_the_sweep() {
        let r = cluster(Effort::Quick);
        assert!(
            r.json.get("digests_identical").as_bool().unwrap(),
            "routing must never change tokens"
        );
        let rows = r.json.get("measured").as_arr().unwrap();
        // replicas {1,2,4} × 5 policies × 2 traffic shapes
        assert_eq!(rows.len(), 3 * 5 * 2);
        for row in rows {
            assert!(row.get("throughput").as_f64().unwrap() > 0.0);
            assert!(row.get("tpot_p99").as_f64().unwrap() >= 0.0);
        }
        assert_eq!(r.json.get("pools").as_arr().unwrap().len(), 2);
        // simulated fleet scales with replicas
        let sim = r.json.get("simulated").as_arr().unwrap();
        let t1 = sim[0].get("throughput").as_f64().unwrap();
        let t4 = sim[2].get("throughput").as_f64().unwrap();
        assert!(t4 > t1 * 1.5, "4 replicas {t4} vs 1 {t1}");
    }
}
