//! Measured-vs-simulated decision-plane overlap (DESIGN.md §8): does the
//! pipelined executor actually hide decision latency under forwards, and
//! does the hidden fraction match what the timing model predicts?
//!
//! The **measured** side runs the *real* executor — scheduler, two-phase
//! commits, sampler service threads, stage timeline — over the
//! context-faithful [`SyntheticRuntime`] data plane (no artifacts needed),
//! sweeping `n_microbatches` with overlap on/off. The decision plane is
//! real, measured code; only the forward is synthetic (and it costs real
//! wall time, so there is something to hide under). The **simulated** side
//! evaluates [`decode_iteration`]'s `overlap_fraction` for the paper's
//! deployments with the measured SHVS per-sequence cost.
//!
//! The report also prints each sweep row's stream digest: overlap and
//! microbatching must change timing, never tokens.

use super::e2e::measured_shvs_per_seq;
use super::{Effort, Report};
use crate::config::{DecisionVariant, EngineConfig, ParallelConfig, PlatformSpec};
use crate::engine::{Engine, SyntheticRuntime};
use crate::metrics::OverlapReport;
use crate::simulator::{decode_iteration, DecisionMode, GpuModel};
use crate::util::json::Json;
use crate::workload::{self, TraceConfig};
use std::fmt::Write;

/// One measured sweep row.
struct MiniRun {
    digest: u64,
    report: OverlapReport,
    wall_s: f64,
    tokens: usize,
}

/// Drive the real executor over the synthetic data plane.
fn run_mini(
    n_mb: usize,
    overlap: bool,
    spec_k: usize,
    n_req: usize,
    vocab: usize,
    samplers: usize,
) -> MiniRun {
    let mut cfg = EngineConfig::default();
    cfg.sampler.variant = DecisionVariant::Offloading;
    cfg.sampler.num_samplers = samplers;
    cfg.sampler.seed = 0x0EE7_1A9;
    cfg.n_microbatches = n_mb;
    cfg.overlap = overlap;
    cfg.spec_k = spec_k;
    cfg.idle_poll_us = 20;
    let runtime = SyntheticRuntime::new(8, vocab, 256, 11);
    let mut engine = Engine::new(runtime, &cfg, None);
    let trace = workload::generate(&TraceConfig::tiny(n_req, vocab));
    for r in trace.requests {
        engine.submit(r);
    }
    let t0 = std::time::Instant::now();
    let summary = engine.run_until_idle().expect("synthetic engine run");
    let wall_s = t0.elapsed().as_secs_f64();
    let finished: Vec<(u64, Vec<u32>)> = engine
        .take_finished()
        .into_iter()
        .map(|f| (f.request.id, f.output))
        .collect();
    let report = engine.overlap_report();
    engine.shutdown();
    MiniRun {
        digest: crate::util::stream_digest(finished),
        report,
        wall_s,
        tokens: summary.tokens,
    }
}

/// The `overlap` experiment: measured sweep + simulated deployments.
pub fn overlap(effort: Effort) -> Report {
    let n_req = effort.scale(16, 64) as usize;
    let vocab = effort.scale(4_096, 16_384) as usize;
    let samplers = 2;

    let mut md = String::from(
        "### overlap — decision latency hidden under forwards \
         (measured executor vs timing model)\n\n\
         measured: real sampler service + pipelined executor over the \
         synthetic data plane\n\n\
         | n_mb | overlap | hidden | exposed wait | bubble | ms/token | digest |\n\
         |---:|---|---:|---:|---:|---:|---|\n",
    );
    let mut rows = Vec::new();
    let mut digests = Vec::new();
    for (n_mb, ov) in [(1usize, false), (2, true), (4, true)] {
        let run = run_mini(n_mb, ov, 0, n_req, vocab, samplers);
        let r = &run.report;
        let _ = writeln!(
            md,
            "| {} | {} | {:>5.1}% | {:>7.2} ms | {:>5.1}% | {:>7.3} ms | {:016x} |",
            n_mb,
            if ov { "on" } else { "off" },
            r.overlap_fraction * 100.0,
            r.exposed_wait_s * 1e3,
            r.last_stage_bubble * 100.0,
            run.wall_s / (run.tokens.max(1) as f64) * 1e3,
            run.digest,
        );
        digests.push(run.digest);
        rows.push(Json::obj(vec![
            ("n_microbatches", Json::Num(n_mb as f64)),
            ("overlap", Json::Bool(ov)),
            ("overlap_fraction", Json::Num(r.overlap_fraction)),
            ("exposed_wait_s", Json::Num(r.exposed_wait_s)),
            ("last_stage_bubble", Json::Num(r.last_stage_bubble)),
            ("decision_busy_s", Json::Num(r.decision_busy_s)),
            ("gpu_busy_s", Json::Num(r.gpu_busy_s)),
            ("digest", Json::Str(format!("{:016x}", run.digest))),
        ]));
    }
    let identical = digests.windows(2).all(|w| w[0] == w[1]);
    let _ = writeln!(
        md,
        "\nstream digests identical across the sweep: **{identical}** \
         (overlap changes timing, never tokens)\n"
    );

    // Simulated column: the timing model's predicted hidden fraction for
    // the paper deployments, with the measured SHVS per-seq cost.
    md.push_str(
        "simulated (roofline model, measured SHVS cost):\n\n\
         | platform | model | TP×PP | predicted hidden |\n|---|---|---|---:|\n",
    );
    let mut sim_rows = Vec::new();
    for platform in [PlatformSpec::l40(), PlatformSpec::h100(), PlatformSpec::b200()] {
        let Some((model, parallel)) = ParallelConfig::paper_matrix(&platform).pop() else {
            continue;
        };
        let per_seq = measured_shvs_per_seq(model.vocab, effort);
        let gpu = GpuModel::new(model.clone(), platform.clone(), parallel);
        let batch = 32 * parallel.world_size();
        let t = decode_iteration(
            &gpu,
            DecisionMode::SimpleOverlapped { per_seq_s: per_seq, samplers: 64 },
            batch,
            512.0,
        );
        let _ = writeln!(
            md,
            "| {} | {} | {}x{} | {:.1}% |",
            platform.name,
            model.name,
            parallel.tp,
            parallel.pp,
            t.overlap_fraction * 100.0
        );
        sim_rows.push(Json::obj(vec![
            ("platform", Json::Str(platform.name.into())),
            ("model", Json::Str(model.name.into())),
            ("overlap_fraction", Json::Num(t.overlap_fraction)),
        ]));
    }
    md.push_str(
        "\nthe paper's claim is exactly this cell: the decision plane \
         overlaps (hidden ≈ 100%) whenever its wall time is shorter than a \
         forward; `serve_e2e --overlap --n_microbatches 2` reports the same \
         measured fraction on the real PJRT stack\n",
    );

    Report {
        id: "overlap",
        title: "Measured vs simulated decision-plane overlap".into(),
        markdown: md,
        json: Json::obj(vec![
            ("measured", Json::Arr(rows)),
            ("digests_identical", Json::Bool(identical)),
            ("simulated", Json::Arr(sim_rows)),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlap_experiment_streams_invariant_and_hidden_fraction_sane() {
        let r = overlap(Effort::Quick);
        assert!(r.json.get("digests_identical").as_bool().unwrap());
        let rows = r.json.get("measured").as_arr().unwrap();
        assert_eq!(rows.len(), 3);
        // synchronous engine hides nothing by construction
        let sync = &rows[0];
        assert_eq!(sync.get("n_microbatches").as_usize(), Some(1));
        assert!(sync.get("overlap_fraction").as_f64().unwrap() < 0.05);
        // Overlapped runs should hide a measurable share of decision work —
        // but actual concurrency is an OS-scheduling fact, so only assert
        // strict positivity where the host can genuinely run the sampler
        // threads beside the engine thread (skip on tiny/saturated runners).
        let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
        for row in &rows[1..] {
            let f = row.get("overlap_fraction").as_f64().unwrap();
            assert!((0.0..=1.0).contains(&f));
            if cores >= 4 {
                assert!(
                    f > 0.0,
                    "n_mb={:?}: overlap fraction {f} not positive on a {cores}-core host",
                    row.get("n_microbatches").as_usize()
                );
            }
        }
        // simulated rows are valid fractions
        for row in r.json.get("simulated").as_arr().unwrap() {
            let f = row.get("overlap_fraction").as_f64().unwrap();
            assert!((0.0..=1.0).contains(&f));
        }
    }
}
