//! The `prefixcache` experiment (DESIGN.md §13): global prefix-cache-aware
//! serving measured end to end over the context-faithful synthetic plane.
//!
//! Workload: a conversation-tree trace ([`crate::workload::conversations`])
//! — Zipf-shared system prompts spanning several KV blocks, with each
//! turn's prompt extending the conversation's prior history — the traffic
//! shape radix prefix caching exists for.
//!
//! Three sections:
//! 1. **Single engine, reuse on vs off** — prefill tokens computed vs
//!    skipped, prefill tokens/s, TTFT P95, and the stream digest. The
//!    digest must be identical across the two runs: a hit may change
//!    timing, never tokens.
//! 2. **Cluster sweep** — replicas × routing policy (placement-blind
//!    round-robin vs the prefix-cache scorer), all cache-on, all digests
//!    equal the cache-off single-engine baseline. At 2 replicas the
//!    prefix-cache policy must recover at least the reuse round-robin
//!    gets, since it steers a conversation's turns at the replica that
//!    already holds their prefix.
//! 3. **Tight-cache hard bar** — a KV pool small enough to force LRU
//!    eviction of cached leaves *and* preemption of live sequences, reuse
//!    on vs off: streams stay bit-identical while preemptions fire.
//!
//! The experiment asserts (not just reports) the acceptance bars: ≥30%
//! prefill-token reduction with reuse on, and digest equality everywhere
//! — it IS the `make cache-smoke` CI gate.

use super::{Effort, Report};
use crate::cluster::{Cluster, ClusterConfig, ClusterReport, RoutePolicy};
use crate::config::{DecisionVariant, EngineConfig};
use crate::engine::{Engine, Request, SyntheticRuntime};
use crate::util::json::Json;
use crate::workload::{self, ConvConfig};
use std::fmt::Write;

const VOCAB: usize = 2_048;
const MAX_SEQ: usize = 256;
const BATCH: usize = 4;
const PLANE_SEED: u64 = 37;

fn engine_cfg(prefix_cache: bool, kv_blocks: usize) -> EngineConfig {
    let mut cfg = EngineConfig::default();
    cfg.sampler.variant = DecisionVariant::Offloading;
    cfg.sampler.num_samplers = 2;
    cfg.sampler.seed = 0xC2;
    cfg.idle_poll_us = 20;
    cfg.prefix_cache = prefix_cache;
    cfg.kv_blocks = kv_blocks;
    cfg
}

/// One conversation-tree trace shared by every run in the experiment:
/// multi-block system prompts (3 full 16-token blocks, Zipf-shared across
/// conversations) and open-loop think-time arrivals, so turn `n+1`
/// usually arrives after turn `n` published its prefix.
fn conv_trace(conversations: usize) -> Vec<Request> {
    let mut cfg = ConvConfig::tiny(conversations, VOCAB);
    cfg.max_turns = 4;
    cfg.system_prompts = 4;
    cfg.system_len = 48; // 3 full KV blocks shared across conversations
    cfg.user_min = 8;
    cfg.user_max = 16;
    cfg.reply_min = 8;
    cfg.reply_max = 16;
    cfg.max_context = MAX_SEQ - 8;
    cfg.seed = 0xBEEF;
    cfg.start_rate = 40.0;
    cfg.think_s = 0.02;
    workload::conversations(&cfg).requests
}

struct EngineRun {
    digest: u64,
    ttft_p95: f64,
    prefill_computed: u64,
    prefill_skipped: u64,
    preemptions: u64,
    wall_s: f64,
    published: u64,
}

/// One single-engine run over the trace; the digest is the hard-bar key.
fn run_engine(trace: &[Request], prefix_cache: bool, kv_blocks: usize) -> EngineRun {
    let cfg = engine_cfg(prefix_cache, kv_blocks);
    let runtime = SyntheticRuntime::new(BATCH, VOCAB, MAX_SEQ, PLANE_SEED);
    let mut engine = Engine::new(runtime, &cfg, None);
    for r in trace {
        engine.submit(r.clone());
    }
    let t0 = std::time::Instant::now();
    engine.run_until_idle().expect("engine run");
    let wall_s = t0.elapsed().as_secs_f64();
    let digest = crate::util::stream_digest(
        engine
            .take_finished()
            .into_iter()
            .map(|f| (f.request.id, f.output))
            .collect(),
    );
    let (prefill_computed, prefill_skipped) =
        (engine.prefill_computed_tokens(), engine.prefill_skipped_tokens());
    let (preemptions, published) =
        (engine.preemption_count(), engine.prefix_stats().published);
    let (recorder, _stats) = engine.shutdown();
    EngineRun {
        digest,
        ttft_p95: recorder.ttft_summary().p95,
        prefill_computed,
        prefill_skipped,
        preemptions,
        wall_s,
        published,
    }
}

fn run_cluster(trace: &[Request], replicas: usize, policy: RoutePolicy) -> ClusterReport {
    let cfg = engine_cfg(true, 0);
    let mut ccfg = ClusterConfig::default();
    ccfg.replicas = replicas;
    ccfg.policy = policy;
    let mut cluster = Cluster::start(&cfg, &ccfg, None, MAX_SEQ, |_id| {
        Ok(SyntheticRuntime::new(BATCH, VOCAB, MAX_SEQ, PLANE_SEED))
    });
    cluster.run(trace.to_vec()).expect("cluster run");
    cluster.shutdown().expect("cluster shutdown")
}

fn reuse_fraction(computed: u64, skipped: u64) -> f64 {
    skipped as f64 / (computed + skipped).max(1) as f64
}

/// The `prefixcache` experiment driver.
pub fn prefixcache(effort: Effort) -> Report {
    let conversations = effort.scale(10, 40) as usize;
    let trace = conv_trace(conversations);
    let n_req = trace.len();

    // Snapshot the process-global decision-plane counters (DESIGN.md §14)
    // around the experiment: the conversation trace must drive the
    // instrumented cache paths — hits, misses, COW forks, LRU evictions.
    let c0 = crate::trace::metrics::counters().snapshot();

    // §1: single engine, reuse off (the ground-truth digest) vs on.
    let off = run_engine(&trace, false, 0);
    let on = run_engine(&trace, true, 0);
    let reduction = 1.0 - on.prefill_computed as f64 / off.prefill_computed.max(1) as f64;
    let mut md = format!(
        "### prefixcache — radix KV reuse over conversation trees \
         (synthetic plane, {conversations} conversations → {n_req} requests)\n\n\
         | reuse | prefill computed | skipped | reduction | prefill tok/s | TTFT P95 | digest |\n\
         |---|---:|---:|---:|---:|---:|---|\n",
    );
    for (name, r) in [("off", &off), ("on", &on)] {
        let red = 1.0 - r.prefill_computed as f64 / off.prefill_computed.max(1) as f64;
        let _ = writeln!(
            md,
            "| {} | {} | {} | {:.0}% | {:>7.0} | {:>6.2} ms | {:016x} |",
            name,
            r.prefill_computed,
            r.prefill_skipped,
            red * 100.0,
            r.prefill_computed as f64 / r.wall_s,
            r.ttft_p95 * 1e3,
            r.digest,
        );
    }
    let _ = writeln!(
        md,
        "\nreuse on skipped {:.0}% of prefill tokens ({} prefixes published) with a \
         bit-identical stream digest\n",
        reduction * 100.0,
        on.published,
    );

    // §2: cluster sweep — placement-blind vs prefix-aware routing, all
    // cache-on, every digest against the cache-off single-engine baseline.
    md.push_str(
        "cluster (reuse on everywhere):\n\n\
         | replicas | policy | reuse | TTFT P95 | digest ok |\n|---:|---|---:|---:|---|\n",
    );
    let mut rows = Vec::new();
    let mut identical = on.digest == off.digest;
    let mut reuse_by_policy = [0.0f64; 2];
    for replicas in [1usize, 2] {
        for (pi, policy) in [RoutePolicy::RoundRobin, RoutePolicy::PrefixCache]
            .into_iter()
            .enumerate()
        {
            let report = run_cluster(&trace, replicas, policy);
            let ok = report.stream_digest() == off.digest;
            identical &= ok;
            let reuse = reuse_fraction(report.prefill_computed, report.prefill_skipped);
            if replicas == 2 {
                reuse_by_policy[pi] = reuse;
            }
            let ttft = report.recorder.ttft_summary().p95;
            let _ = writeln!(
                md,
                "| {} | {} | {:.0}% | {:>6.2} ms | {ok} |",
                replicas,
                policy.name(),
                reuse * 100.0,
                ttft * 1e3,
            );
            rows.push(Json::obj(vec![
                ("replicas", Json::Num(replicas as f64)),
                ("policy", Json::Str(policy.name().into())),
                ("reuse", Json::Num(reuse)),
                ("ttft_p95", Json::Num(ttft)),
                ("digest_ok", Json::Bool(ok)),
            ]));
        }
    }
    let _ = writeln!(
        md,
        "\nat 2 replicas the prefix-cache policy reuses {:.0}% vs round-robin's \
         {:.0}% (longest-prefix routing keeps a conversation's turns with \
         their cached prefix)\n",
        reuse_by_policy[1] * 100.0,
        reuse_by_policy[0] * 100.0,
    );

    // §3: tight KV pool — eviction and preemption under reuse, on vs off.
    let tight_blocks = 24usize;
    let tight_off = run_engine(&trace, false, tight_blocks);
    let tight_on = run_engine(&trace, true, tight_blocks);
    let _ = writeln!(
        md,
        "tight cache ({tight_blocks} blocks): reuse off {} preemptions, reuse on \
         {} preemptions — digests identical: **{}** (eviction and preemption \
         may cost recompute, never tokens)\n",
        tight_off.preemptions,
        tight_on.preemptions,
        tight_on.digest == tight_off.digest && tight_off.digest == off.digest,
    );
    identical &= tight_on.digest == off.digest && tight_off.digest == off.digest;

    let c1 = crate::trace::metrics::counters().snapshot();
    let counter_deltas: Vec<(&'static str, u64)> = c0
        .iter()
        .zip(&c1)
        .map(|(&(name, before), &(_, after))| (name, after.saturating_sub(before)))
        .collect();
    let delta = |key: &str| {
        counter_deltas.iter().find(|(n, _)| *n == key).map(|(_, v)| *v).unwrap_or(0)
    };
    let _ = writeln!(
        md,
        "cache-path counters across the experiment: {} prefix hits, {} \
         misses, {} COW forks, {} LRU evictions\n",
        delta("prefix_hits"),
        delta("prefix_misses"),
        delta("cow_forks"),
        delta("lru_evictions"),
    );

    // The acceptance bars, asserted loudly (`make cache-smoke` runs this).
    assert!(
        identical,
        "prefix-cache digest mismatch: a cached run diverged from the \
         reuse-off baseline (a hit may change timing, never tokens)"
    );
    assert!(
        reduction >= 0.30,
        "prefill-token reduction {:.1}% below the 30% bar \
         (computed {} with reuse vs {} without)",
        reduction * 100.0,
        on.prefill_computed,
        off.prefill_computed,
    );
    assert!(
        tight_off.preemptions > 0,
        "the tight-cache section must actually preempt to exercise the bar"
    );
    assert!(
        reuse_by_policy[1] >= reuse_by_policy[0],
        "prefix-cache routing reuse {:.1}% fell below round-robin {:.1}%",
        reuse_by_policy[1] * 100.0,
        reuse_by_policy[0] * 100.0,
    );
    // The counters are the observable face of the cache: a conversation
    // trace with reuse on must hit, miss (first turns), fork shared
    // blocks on write, and — in the tight-pool section — evict.
    for key in ["prefix_hits", "prefix_misses", "cow_forks", "lru_evictions"] {
        assert!(
            delta(key) > 0,
            "prefixcache experiment left the `{key}` counter at zero — \
             the trace did not exercise the instrumented cache path"
        );
    }

    Report {
        id: "prefixcache",
        title: "Global prefix-cache-aware serving over conversation trees".into(),
        markdown: md,
        json: Json::obj(vec![
            ("requests", Json::Num(n_req as f64)),
            ("reduction", Json::Num(reduction)),
            ("ttft_p95_off", Json::Num(off.ttft_p95)),
            ("ttft_p95_on", Json::Num(on.ttft_p95)),
            ("published", Json::Num(on.published as f64)),
            ("digests_identical", Json::Bool(identical)),
            ("tight_preemptions_on", Json::Num(tight_on.preemptions as f64)),
            ("tight_preemptions_off", Json::Num(tight_off.preemptions as f64)),
            (
                "counters",
                Json::Obj(
                    counter_deltas
                        .iter()
                        .map(|&(n, v)| (n.to_string(), Json::Num(v as f64)))
                        .collect(),
                ),
            ),
            ("cluster", Json::Arr(rows)),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefixcache_experiment_meets_the_acceptance_bars() {
        // The driver asserts the bars itself (digest equality everywhere,
        // ≥30% prefill reduction, preemption coverage); the test adds the
        // reported-value sanity checks.
        let r = prefixcache(Effort::Quick);
        assert!(r.json.get("digests_identical").as_bool().unwrap());
        assert!(r.json.get("reduction").as_f64().unwrap() >= 0.30);
        assert_eq!(r.json.get("cluster").as_arr().unwrap().len(), 4);
        assert!(r.json.get("published").as_f64().unwrap() > 0.0);
        // the decision-plane counters saw the cache machinery fire
        let counters = r.json.get("counters");
        for key in ["prefix_hits", "prefix_misses", "cow_forks", "lru_evictions"] {
            assert!(
                counters.get(key).as_f64().unwrap() > 0.0,
                "{key} counter stayed zero across the prefixcache experiment"
            );
        }
    }
}
