//! Decision-plane microbenchmarks (§7.4–§7.5): the ablation ladder
//! (Fig. 10), the sizing-model ingredients (Fig. 11), and the predicted-vs-
//! measured optimal hot size (Fig. 12). All numbers here are **measured on
//! this host** with the real Rust decision plane; nothing is simulated.

use super::measure::{self, LogitsGen};
use super::{Effort, Report};
use crate::config::DecisionVariant;
use crate::decision::penalties::BatchHistory;
use crate::decision::{DecisionPipeline, Precompute, SamplingParams};
use crate::util::json::Json;
use std::fmt::Write;
use std::time::Instant;

/// QwQ-32B's vocabulary — the model Figure 10/11/12 profile.
const QWQ_VOCAB: usize = 152_064;

/// Fig 10: per-sampler throughput (tokens/s) of the ablated designs.
pub fn fig10(effort: Effort) -> Report {
    let vocab = match effort {
        Effort::Quick => 32_000, // keep CI fast; full uses QwQ's 152k
        Effort::Full => QWQ_VOCAB,
    };
    let iters = effort.scale(10, 60);
    let cal = measure::calibrate(vocab, (vocab / 5).min(32_768), iters);
    let mut md = format!(
        "### Fig 10 — per-sampler decision throughput, V = {vocab} (measured)\n\n\
         | variant | per-decision | tokens/s per sampler | step-up |\n|---|---:|---:|---:|\n"
    );
    let mut rows = Vec::new();
    let mut prev: Option<f64> = None;
    for (variant, per_seq) in &cal.per_seq {
        let tps = 1.0 / per_seq;
        let step = prev.map(|p| tps / p);
        let _ = writeln!(
            md,
            "| {} | {} | {:.1} | {} |",
            variant.name(),
            crate::util::fmt_duration(std::time::Duration::from_secs_f64(*per_seq)),
            tps,
            step.map(|s| format!("{s:.1}×")).unwrap_or_else(|| "—".into()),
        );
        rows.push(Json::obj(vec![
            ("variant", Json::Str(variant.name().into())),
            ("per_seq_s", Json::Num(*per_seq)),
            ("tokens_per_s", Json::Num(tps)),
        ]));
        prev = Some(tps);
    }
    let total = 1.0 / cal.per_seq_s(DecisionVariant::Shvs)
        / (1.0 / cal.per_seq_s(DecisionVariant::NaiveCpu));
    let _ = writeln!(
        md,
        "\ntotal SHVS vs naive-CPU speedup: {total:.0}× \
         (paper ladder: 4.8× → 8.4× → 5.6×, ≈225× total; SHVS α = {:.2})\n",
        cal.shvs_alpha
    );
    Report {
        id: "fig10",
        title: "Ablation ladder per-sampler throughput".into(),
        markdown: md,
        json: Json::obj(vec![
            ("vocab", Json::Num(vocab as f64)),
            ("rows", Json::Arr(rows)),
            ("total_speedup", Json::Num(total)),
            ("shvs_alpha", Json::Num(cal.shvs_alpha)),
        ]),
    }
}

/// Fig 11: (a) affine hot-path cost fit T_cpu(H) = cH + c0; (b) the
/// monotone-saturating hit-ratio curve ᾱ(H).
pub fn fig11(effort: Effort) -> Report {
    let vocab = match effort {
        Effort::Quick => 32_000,
        Effort::Full => QWQ_VOCAB,
    };
    let iters = effort.scale(15, 80);
    let gen = LogitsGen::new(vocab, 1.08, 42);
    let h_points = measure::geometric_points(vocab, 10);
    let costs = measure::measure_hot_path_costs(&gen, &h_points, iters);
    let alphas = measure::measure_alpha_curve(&gen, &h_points, iters.min(12));
    let xs: Vec<f64> = costs.iter().map(|p| p.0).collect();
    let ys: Vec<f64> = costs.iter().map(|p| p.1).collect();
    let (c, c0, r2) = crate::metrics::stats::affine_fit(&xs, &ys);

    let mut md = format!(
        "### Fig 11 — hot-vocab sizing ingredients, V = {vocab} (measured)\n\n\
         (a) hot-path cost fit: T_cpu(H) = {c:.3e}·H + {c0:.3e}  (R² = {r2:.4})\n\
         (paper on Xeon 8358: c = 1.06e-8, c0 = 8.55e-6)\n\n\
         | H | measured T_cpu | fitted | ᾱ(H) |\n|---:|---:|---:|---:|\n"
    );
    let mut rows = Vec::new();
    for ((h, t), (_, a)) in costs.iter().zip(&alphas) {
        let fitted = c * h + c0;
        let _ = writeln!(md, "| {h:.0} | {:.2e} s | {fitted:.2e} s | {a:.3} |", t);
        rows.push(Json::obj(vec![
            ("h", Json::Num(*h)),
            ("t_cpu_s", Json::Num(*t)),
            ("alpha", Json::Num(*a)),
        ]));
    }
    Report {
        id: "fig11",
        title: "Hot-vocab sizing model ingredients".into(),
        markdown: md,
        json: Json::obj(vec![
            ("vocab", Json::Num(vocab as f64)),
            ("c", Json::Num(c)),
            ("c0", Json::Num(c0)),
            ("r2", Json::Num(r2)),
            ("rows", Json::Arr(rows)),
        ]),
    }
}

/// Fig 12: expected cost F(H) and its minimizer vs the measured-throughput
/// optimum.
pub fn fig12(effort: Effort) -> Report {
    let vocab = match effort {
        Effort::Quick => 32_000,
        Effort::Full => QWQ_VOCAB,
    };
    let iters = effort.scale(12, 60);
    let model = measure::fit_sizing_model(vocab, 1.08, iters);
    let h_star = model.h_star();

    // Measured end-to-end decision throughput across H (full SHVS path,
    // production params — includes slow-path fallbacks).
    let gen = LogitsGen::new(vocab, 1.08, 42);
    let params = SamplingParams {
        temperature: 0.9,
        ..Default::default()
    };
    let h_points = measure::geometric_points(vocab, 8);
    // Pre-generate views once (logits generation and the GPU-side
    // precompute must not pollute the timed region).
    let n_views = iters.min(8) as usize;
    let views: Vec<_> = (0..n_views).map(|i| gen.view(1, i as u64, 1)).collect();
    let mut measured: Vec<(f64, f64)> = Vec::new();
    for &h in &h_points {
        let hot = gen.hot_vocab(h).into_arc();
        let pres: Vec<_> = views
            .iter()
            .map(|v| Precompute::reference(v, 0, &hot, params.temperature))
            .collect();
        let mut pipe = DecisionPipeline::new(DecisionVariant::Shvs, Some(hot.clone()), 3);
        let hist = BatchHistory::new(&[vec![]], 4);
        let t0 = Instant::now();
        for it in 0..iters {
            let i = it as usize % n_views;
            pipe.decide(&views[i], 0, &hist, 0, &params, Some(&pres[i]), 0, it);
        }
        let per = t0.elapsed().as_secs_f64() / iters as f64;
        measured.push((h as f64, 1.0 / per));
    }
    let measured_best = measured
        .iter()
        .cloned()
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();

    let mut md = format!(
        "### Fig 12 — optimizing the hot-vocab size, V = {vocab}\n\n\
         predicted H* = {h_star} (F(H*) = {:.2e} s); measured throughput peak \
         at H = {:.0}\n\n\
         | H | F(H) predicted | 1/F(H) | measured tokens/s |\n|---:|---:|---:|---:|\n",
        model.f(h_star as f64),
        measured_best.0
    );
    let mut rows = Vec::new();
    for &(h, tps) in &measured {
        let f = model.f(h);
        let _ = writeln!(md, "| {h:.0} | {f:.2e} | {:.0} | {tps:.0} |", 1.0 / f);
        rows.push(Json::obj(vec![
            ("h", Json::Num(h)),
            ("f_pred_s", Json::Num(f)),
            ("measured_tps", Json::Num(tps)),
        ]));
    }
    md.push_str("\npaper: predicted H* coincides with the empirical peak; broad valley\n");
    Report {
        id: "fig12",
        title: "Hot-vocab size optimization".into(),
        markdown: md,
        json: Json::obj(vec![
            ("vocab", Json::Num(vocab as f64)),
            ("h_star_pred", Json::Num(h_star as f64)),
            ("h_best_measured", Json::Num(measured_best.0)),
            ("rows", Json::Arr(rows)),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10_ladder_ascends() {
        let r = fig10(Effort::Quick);
        let rows = r.json.get("rows").as_arr().unwrap();
        assert_eq!(rows.len(), 4);
        let tps: Vec<f64> = rows
            .iter()
            .map(|row| row.get("tokens_per_s").as_f64().unwrap())
            .collect();
        // naive <= parallel <= offloading <= shvs (allow small noise on the
        // first step, which differs only by materialize+rebuild)
        assert!(tps[1] > tps[0] * 0.8, "parallel {:.0} vs naive {:.0}", tps[1], tps[0]);
        assert!(tps[2] > tps[1], "offload {:.0} vs parallel {:.0}", tps[2], tps[1]);
        assert!(tps[3] > tps[2] * 1.5, "shvs {:.0} vs offload {:.0}", tps[3], tps[2]);
        assert!(r.json.get("total_speedup").as_f64().unwrap() > 3.0);
    }

    #[test]
    fn fig11_fit_is_affine_and_alpha_saturates() {
        let r = fig11(Effort::Quick);
        assert!(r.json.get("c").as_f64().unwrap() > 0.0);
        assert!(r.json.get("r2").as_f64().unwrap() > 0.7);
        let rows = r.json.get("rows").as_arr().unwrap();
        let first_alpha = rows.first().unwrap().get("alpha").as_f64().unwrap();
        let last_alpha = rows.last().unwrap().get("alpha").as_f64().unwrap();
        assert!(last_alpha > first_alpha);
        assert!(last_alpha > 0.9, "ᾱ saturates: {last_alpha}");
    }

    #[test]
    fn fig12_prediction_near_measured_peak() {
        let r = fig12(Effort::Quick);
        let pred = r.json.get("h_star_pred").as_f64().unwrap();
        let vocab = r.json.get("vocab").as_f64().unwrap();
        assert!(pred > 8.0 && pred < vocab);
        // the valley is broad (paper's point): F at predicted H* is within
        // 2x of F at the measured best H
        let rows = r.json.get("rows").as_arr().unwrap();
        let best_measured = rows
            .iter()
            .max_by(|a, b| {
                a.get("measured_tps")
                    .as_f64()
                    .partial_cmp(&b.get("measured_tps").as_f64())
                    .unwrap()
            })
            .unwrap();
        let f_at_best = best_measured.get("f_pred_s").as_f64().unwrap();
        let f_star: f64 = rows
            .iter()
            .map(|row| row.get("f_pred_s").as_f64().unwrap())
            .fold(f64::INFINITY, f64::min);
        assert!(f_at_best < f_star * 2.5, "valley check: {f_at_best} vs {f_star}");
    }
}
