//! Distributed-GPU timing substrate (see DESIGN.md §2).
//!
//! We have no L40/H100/B200 testbed in this environment, so the *data
//! plane* is an analytic roofline model ([`gpu`]) composed into pipeline
//! cycles ([`pipeline`]) and driven by a discrete-event serving simulation
//! ([`serving`]). The *decision plane* — the paper's contribution — is
//! never simulated: its per-sequence costs are measured on this host by the
//! figure harnesses and injected as [`pipeline::DecisionMode`] parameters.

pub mod gpu;
pub mod pipeline;
pub mod serving;

pub use gpu::{DataPlaneModel, GpuModel, SamplingCostModel};
pub use pipeline::{amdahl_drift, decode_iteration, DecisionMode, IterationTiming};
pub use serving::{
    simulate, simulate_cluster, ClusterSimConfig, ClusterSimResult, SimConfig, SimRequest,
    SimResult,
};
