//! Pipeline-cycle composition: assemble per-iteration timings for the
//! baseline (sampling as a last-stage epilogue, Eq. 4) and for SIMPLE
//! (decision plane off-path and overlapped), with bubble accounting.

use super::gpu::GpuModel;

/// How the decision plane is realized, for timing purposes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DecisionMode {
    /// Baseline: on-GPU sampling appended to the last PP stage (Eq. 4).
    GpuEpilogue,
    /// Naive CPU offload without overlap-aware design: the (measured)
    /// CPU time is serial after the forward (§5.2's "naïve port").
    CpuSerial {
        /// Measured per-sequence decision seconds on this host.
        per_seq_s: f64,
        samplers: usize,
    },
    /// SIMPLE: sequence-parallel CPU sampling overlapped with the forward;
    /// it binds only when slower than the pipeline cycle.
    SimpleOverlapped { per_seq_s: f64, samplers: usize },
    /// SIMPLE + speculative decoding verified in the decision plane
    /// (DESIGN.md §7): each iteration feeds a `k`-token draft chain through
    /// the forward (one weight pass, k+1 tokens of GEMM/KV work) and the
    /// samplers verify all k+1 positions. `accept_rate` is the *measured*
    /// per-position draft acceptance probability (never modelled — see
    /// `harness::measure::measure_spec_acceptance`); a sequence commits
    /// `1 + LeadingAccepts(k, accept_rate)` tokens per iteration.
    SpecVerify {
        per_seq_s: f64,
        samplers: usize,
        k: usize,
        accept_rate: f64,
    },
}

impl DecisionMode {
    /// Wall time the decision plane needs for `batch` sequences.
    pub fn decision_wall_s(&self, batch: usize) -> f64 {
        match *self {
            DecisionMode::GpuEpilogue => 0.0, // folded into the GPU cycle
            DecisionMode::CpuSerial { per_seq_s, samplers }
            | DecisionMode::SimpleOverlapped { per_seq_s, samplers } => {
                let m = samplers.max(1) as f64;
                (batch as f64 / m).ceil() * per_seq_s
            }
            DecisionMode::SpecVerify { per_seq_s, samplers, k, .. } => {
                // batched verification decides every chain position
                let m = samplers.max(1) as f64;
                (batch as f64 / m).ceil() * per_seq_s * (k + 1) as f64
            }
        }
    }

    /// The speculative window shape, if any: (k, accept_rate).
    pub fn spec_shape(&self) -> Option<(usize, f64)> {
        match *self {
            DecisionMode::SpecVerify { k, accept_rate, .. } => Some((k, accept_rate)),
            _ => None,
        }
    }
}

/// Per-iteration timing decomposition.
#[derive(Debug, Clone)]
pub struct IterationTiming {
    /// Pipeline cycle time (inter-token time at steady state).
    pub cycle_s: f64,
    /// Max per-stage compute (without sampling).
    pub stage_max_s: f64,
    /// GPU-side sampling epilogue (baseline only).
    pub gpu_sampling_s: f64,
    /// CPU decision wall time (offloaded modes).
    pub cpu_decision_s: f64,
    /// Fraction of iteration spent sampling (Fig. 1's `f`).
    pub sampling_fraction: f64,
    /// Pipeline bubble fraction: idle stage-time / total stage-time.
    pub bubble_fraction: f64,
    /// GPU busy fraction within the cycle.
    pub gpu_busy_fraction: f64,
    /// Predicted fraction of decision-plane work hidden under GPU compute:
    /// `min(gpu-only cycle, decision wall) / decision wall` for the
    /// overlapped modes, 0 for the serial ones. The measured counterpart
    /// is [`crate::metrics::OverlapReport::overlap_fraction`].
    pub overlap_fraction: f64,
}

/// Compose one decode iteration's timing.
///
/// `batch` = total sequences in flight; `ctx` = mean context length.
pub fn decode_iteration(
    gpu: &GpuModel,
    mode: DecisionMode,
    batch: usize,
    ctx: f64,
) -> IterationTiming {
    let p = gpu.parallel.pp;
    let stage = gpu.stage_compute_s(batch, ctx);
    let comm = gpu.pp_comm_s(batch);
    let simple = matches!(
        mode,
        DecisionMode::SimpleOverlapped { .. } | DecisionMode::SpecVerify { .. }
    );
    let fanout = gpu.fanout_s(simple);

    let (cycle, gpu_sampling, cpu_decision, stage_eff, comm_eff, overlap_fraction) =
        match mode {
            DecisionMode::GpuEpilogue => {
                let samp = gpu.gpu_sampling_s(batch);
                // Eq. 4: the last stage carries compute + sampling; the cycle
                // is pinned at the stage maximum, plus the synchronous host
                // gap. Nothing overlaps.
                let last = stage + samp;
                (
                    last + comm + fanout + gpu.data.baseline_sync_s,
                    samp,
                    0.0,
                    stage,
                    comm,
                    0.0,
                )
            }
            DecisionMode::CpuSerial { .. } => {
                // Offloaded but NOT overlapped: decision wall time serializes
                // after the forward each iteration (still a synchronous
                // stack) — hidden fraction zero by construction.
                let d = mode.decision_wall_s(batch);
                (
                    stage + comm + fanout + gpu.data.baseline_sync_s + d,
                    0.0,
                    d,
                    stage,
                    comm,
                    0.0,
                )
            }
            DecisionMode::SimpleOverlapped { .. } => {
                // Overlapped: the decision plane runs under the next forward;
                // it binds only if slower than the GPU cycle. Async rings
                // shrink the host gap.
                let d = mode.decision_wall_s(batch);
                let gpu_cycle = stage + comm + fanout + gpu.data.simple_sync_s;
                let hidden = if d > 0.0 { gpu_cycle.min(d) / d } else { 0.0 };
                (gpu_cycle.max(d), 0.0, d, stage, comm, hidden)
            }
            DecisionMode::SpecVerify { k, .. } => {
                // Draft chain: one weight pass but k+1 tokens of GEMM / KV /
                // collective work per sequence — the roofline's weight-read
                // term is batch-independent, so the multi-token chain reuses
                // it while the per-token terms scale with the chain length.
                let chain_stage = gpu.stage_compute_s(batch * (k + 1), ctx);
                let chain_comm = gpu.pp_comm_s(batch * (k + 1));
                let d = mode.decision_wall_s(batch);
                let gpu_cycle = chain_stage + chain_comm + fanout + gpu.data.simple_sync_s;
                let hidden = if d > 0.0 { gpu_cycle.min(d) / d } else { 0.0 };
                (gpu_cycle.max(d), 0.0, d, chain_stage, chain_comm, hidden)
            }
        };

    let total_sampling = gpu_sampling + cpu_decision;
    let sampling_fraction = match mode {
        DecisionMode::GpuEpilogue => gpu_sampling / cycle,
        DecisionMode::CpuSerial { .. } => cpu_decision / cycle,
        DecisionMode::SimpleOverlapped { .. } | DecisionMode::SpecVerify { .. } => {
            // visible share: only the non-hidden part
            ((cpu_decision - (stage_eff + comm_eff)).max(0.0)) / cycle
        }
    };

    // Bubbles: every stage is busy `stage_eff` per cycle (the baseline's
    // last stage additionally runs the sampling epilogue while the others
    // idle).
    let total_busy = match mode {
        DecisionMode::GpuEpilogue => {
            (p - 1) as f64 * stage_eff + (stage_eff + gpu_sampling)
        }
        _ => p as f64 * stage_eff,
    };
    let bubble_fraction = 1.0 - total_busy / (cycle * p as f64);
    // Mean GPU utilization across stages (what nvidia-smi style Figures 8
    // report) is the complement of the bubble fraction.
    let gpu_busy_fraction = (1.0 - bubble_fraction).min(1.0);

    let _ = total_sampling;
    IterationTiming {
        cycle_s: cycle,
        stage_max_s: stage_eff,
        gpu_sampling_s: gpu_sampling,
        cpu_decision_s: cpu_decision,
        sampling_fraction,
        bubble_fraction: bubble_fraction.clamp(0.0, 1.0),
        gpu_busy_fraction,
        overlap_fraction: overlap_fraction.clamp(0.0, 1.0),
    }
}

/// Amdahl drift (Eq. 3): the sampling fraction after accelerating the
/// non-sampling work by ρ.
pub fn amdahl_drift(f: f64, rho: f64) -> f64 {
    f / (f + (1.0 - f) / rho)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelSpec, ParallelConfig, PlatformSpec};

    fn gpu(tp: usize, pp: usize) -> GpuModel {
        GpuModel::new(
            ModelSpec::qwen25_72b(),
            PlatformSpec::h100(),
            ParallelConfig::new(tp, pp),
        )
    }

    #[test]
    fn baseline_bubbles_in_paper_band() {
        // Fig 1b: bubbles of 22–40% for Qwen-2.5-72B (t=4, p=2).
        let g = gpu(4, 2);
        let t = decode_iteration(&g, DecisionMode::GpuEpilogue, 256, 512.0);
        assert!(
            (0.10..=0.45).contains(&t.bubble_fraction),
            "bubble {:.3}",
            t.bubble_fraction
        );
        assert!(t.sampling_fraction > 0.1);
    }

    #[test]
    fn simple_removes_bubbles_when_hidden() {
        let g = gpu(4, 2);
        let base = decode_iteration(&g, DecisionMode::GpuEpilogue, 256, 512.0);
        // decision plane fast enough to hide
        let simple = decode_iteration(
            &g,
            DecisionMode::SimpleOverlapped { per_seq_s: 10e-6, samplers: 16 },
            256,
            512.0,
        );
        assert!(simple.cycle_s < base.cycle_s);
        assert!(simple.bubble_fraction < base.bubble_fraction);
        assert_eq!(simple.sampling_fraction, 0.0, "fully hidden");
        assert!(simple.gpu_busy_fraction > base.gpu_busy_fraction - 1e-9);
        assert!(
            (simple.overlap_fraction - 1.0).abs() < 1e-12,
            "a hidden decision plane overlaps fully: {}",
            simple.overlap_fraction
        );
        assert_eq!(base.overlap_fraction, 0.0, "epilogue overlaps nothing");
    }

    #[test]
    fn slow_decision_plane_binds_the_cycle() {
        let g = gpu(4, 2);
        let slow = decode_iteration(
            &g,
            DecisionMode::SimpleOverlapped { per_seq_s: 5e-3, samplers: 1 },
            256,
            512.0,
        );
        assert!(slow.cycle_s >= slow.cpu_decision_s);
        assert!(slow.sampling_fraction > 0.0, "visible share when binding");
        assert!(
            slow.overlap_fraction < 1.0 && slow.overlap_fraction > 0.0,
            "a binding decision plane is only partly hidden: {}",
            slow.overlap_fraction
        );
    }

    #[test]
    fn naive_cpu_offload_is_worse_than_overlap() {
        let g = gpu(4, 2);
        let per_seq = 100e-6;
        let serial = decode_iteration(
            &g,
            DecisionMode::CpuSerial { per_seq_s: per_seq, samplers: 16 },
            256,
            512.0,
        );
        let overlapped = decode_iteration(
            &g,
            DecisionMode::SimpleOverlapped { per_seq_s: per_seq, samplers: 16 },
            256,
            512.0,
        );
        assert!(serial.cycle_s > overlapped.cycle_s);
    }

    #[test]
    fn spec_verify_cycle_sublinear_in_k() {
        // The draft chain reuses the weight pass: a k=3 iteration must cost
        // well under 4 plain iterations (that headroom, times acceptance,
        // is speculative decoding's whole win), yet more than one.
        let g = gpu(4, 2);
        let base = decode_iteration(
            &g,
            DecisionMode::SimpleOverlapped { per_seq_s: 10e-6, samplers: 64 },
            256,
            512.0,
        );
        let spec = decode_iteration(
            &g,
            DecisionMode::SpecVerify {
                per_seq_s: 10e-6,
                samplers: 64,
                k: 3,
                accept_rate: 0.6,
            },
            256,
            512.0,
        );
        assert!(spec.cycle_s > base.cycle_s, "chain work is not free");
        assert!(
            spec.cycle_s < 4.0 * base.cycle_s,
            "chain {} vs 4x plain {}",
            spec.cycle_s,
            4.0 * base.cycle_s
        );
    }

    #[test]
    fn spec_verify_decision_wall_scales_with_window() {
        let m = DecisionMode::SpecVerify {
            per_seq_s: 10e-6,
            samplers: 16,
            k: 3,
            accept_rate: 0.5,
        };
        let plain = DecisionMode::SimpleOverlapped { per_seq_s: 10e-6, samplers: 16 };
        assert!((m.decision_wall_s(64) - 4.0 * plain.decision_wall_s(64)).abs() < 1e-12);
        assert_eq!(m.spec_shape(), Some((3, 0.5)));
        assert_eq!(plain.spec_shape(), None);
    }

    #[test]
    fn amdahl_drift_monotone_to_one() {
        let f = 0.2;
        assert!((amdahl_drift(f, 1.0) - f).abs() < 1e-12);
        assert!(amdahl_drift(f, 2.0) > f);
        assert!(amdahl_drift(f, 1e9) > 0.999);
    }

    #[test]
    fn throughput_gain_band_matches_fig3_shape() {
        // SIMPLE vs baseline throughput gain should be material (tens of %)
        // for a large-vocab model on H100 and larger with deeper pipelines.
        let gain = |pp: usize| {
            let g = GpuModel::new(
                ModelSpec::qwen3_235b_a22b(),
                PlatformSpec::h100(),
                ParallelConfig::new(4, pp),
            );
            let batch = 32 * g.parallel.world_size();
            let base = decode_iteration(&g, DecisionMode::GpuEpilogue, batch, 512.0);
            let simple = decode_iteration(
                &g,
                DecisionMode::SimpleOverlapped { per_seq_s: 20e-6, samplers: 16 },
                batch,
                512.0,
            );
            base.cycle_s / simple.cycle_s
        };
        let g2 = gain(2);
        let g4 = gain(4);
        assert!(g2 > 1.1, "gain {g2}");
        assert!(g4 > g2, "deeper pipeline gains more: {g4} vs {g2}");
        assert!(g4 < 2.5, "gain {g4} implausibly large");
    }
}
