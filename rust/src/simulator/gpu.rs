//! Analytic GPU timing model.
//!
//! Produces per-iteration stage times for a (model, platform, TP×PP) triple
//! from first-principles roofline terms (weight/KV reads vs HBM bandwidth,
//! GEMM FLOPs vs tensor-core throughput, collective traffic vs interconnect)
//! plus a small set of named calibration constants for the baseline GPU
//! sampling epilogue. The *decision-plane* cost is never modelled here — it
//! is measured on this host and injected by the harness.
//!
//! Absolute numbers are estimates; the reproduced claims are the *ratios*
//! (sampling fraction `f`, bubble fraction, SIMPLE-vs-baseline speedups),
//! which depend on relative magnitudes the roofline terms capture.

use crate::config::{ModelSpec, ParallelConfig, PlatformSpec};

/// Calibration constants for the baseline on-GPU sampling epilogue
/// (§3: memory-bound O(V) scans + sort + vocab-axis collectives).
#[derive(Debug, Clone)]
pub struct SamplingCostModel {
    /// Equivalent full passes over the [B, V] f32 logits for penalties,
    /// temperature, masking, filtering, softmax, cumsum — the fused
    /// production control set (footnote 1 assumes sorting-free fused
    /// kernels, so no explicit sort term).
    pub scan_passes: f64,
    /// Per-sequence host-side work in the baseline sampler (penalty
    /// bookkeeping, per-request parameter dispatch) — scales with B.
    pub per_seq_s: f64,
    /// Fixed per-iteration overhead: kernel launches, host sync (seconds).
    pub fixed_s: f64,
    /// Extra fixed overhead per TP rank participating in the reconciliation
    /// (shard top-k lists / partial CDF reductions, §3).
    pub per_rank_s: f64,
}

impl Default for SamplingCostModel {
    fn default() -> Self {
        SamplingCostModel {
            scan_passes: 22.0,
            per_seq_s: 1e-6,
            fixed_s: 800e-6,
            per_rank_s: 60e-6,
        }
    }
}

/// Efficiency knobs for the data-plane roofline.
#[derive(Debug, Clone)]
pub struct DataPlaneModel {
    /// Achievable fraction of peak HBM bandwidth for streaming weights.
    pub hbm_efficiency: f64,
    /// Achievable fraction of peak bf16 FLOPs for decode GEMMs.
    pub flops_efficiency: f64,
    /// Achievable fraction of interconnect bandwidth for collectives.
    pub net_efficiency: f64,
    /// Fixed per-layer kernel overhead (seconds).
    pub per_layer_s: f64,
    /// Per-iteration host scheduling/sync gap in the baseline stack
    /// (python scheduler, synchronous epilogue handoff).
    pub baseline_sync_s: f64,
    /// Same gap under SIMPLE's asynchronous shared-memory rings.
    pub simple_sync_s: f64,
}

impl Default for DataPlaneModel {
    fn default() -> Self {
        DataPlaneModel {
            hbm_efficiency: 0.75,
            flops_efficiency: 0.6,
            net_efficiency: 0.7,
            per_layer_s: 8e-6,
            baseline_sync_s: 0.5e-3,
            simple_sync_s: 1.0e-4,
        }
    }
}

/// The assembled timing model.
#[derive(Debug, Clone)]
pub struct GpuModel {
    pub model: ModelSpec,
    pub platform: PlatformSpec,
    pub parallel: ParallelConfig,
    pub data: DataPlaneModel,
    pub sampling: SamplingCostModel,
}

impl GpuModel {
    pub fn new(model: ModelSpec, platform: PlatformSpec, parallel: ParallelConfig) -> GpuModel {
        GpuModel {
            model,
            platform,
            parallel,
            data: DataPlaneModel::default(),
            sampling: SamplingCostModel::default(),
        }
    }

    /// Per-stage decode compute time for a microbatch of `batch` sequences
    /// with mean context `ctx` tokens: max(weight-read, GEMM) + KV reads +
    /// per-layer overhead + TP collectives.
    pub fn stage_compute_s(&self, batch: usize, ctx: f64) -> f64 {
        let t = self.parallel.tp as f64;
        let p = self.parallel.pp as f64;
        let m = &self.model;
        let plat = &self.platform;

        // Weights resident per GPU (bf16): total active params / (t·p).
        let weight_bytes = m.active_params() * 2.0 / (t * p);
        let t_weights = weight_bytes / (plat.hbm_gbps * 1e9 * self.data.hbm_efficiency);

        // Decode GEMM flops per stage for the microbatch.
        let flops = m.decode_flops_per_token() * batch as f64 / (t * p);
        let t_flops = flops / (plat.tflops_bf16 * 1e12 * self.data.flops_efficiency);

        // KV reads: batch × ctx tokens × bytes/token, sharded over t·p.
        let kv_bytes = batch as f64 * ctx * m.kv_bytes_per_token() / (t * p);
        let t_kv = kv_bytes / (plat.hbm_gbps * 1e9 * self.data.hbm_efficiency);

        // TP collectives: 2 all-reduces per layer of [batch, hidden] bf16.
        let layers_per_stage = m.layers as f64 / p;
        let ar_bytes = 2.0 * (t - 1.0) / t * batch as f64 * m.hidden as f64 * 2.0;
        let t_tp = if self.parallel.tp > 1 {
            layers_per_stage
                * 2.0
                * (ar_bytes / (plat.intra_gbps * 1e9 * self.data.net_efficiency)
                    + plat.intra_lat_us * 1e-6)
        } else {
            0.0
        };

        t_weights.max(t_flops) + t_kv + t_tp + layers_per_stage * self.data.per_layer_s
    }

    /// Inter-stage activation transfer (PP edge).
    pub fn pp_comm_s(&self, batch: usize) -> f64 {
        if self.parallel.pp <= 1 {
            return 0.0;
        }
        let bytes = batch as f64 * self.model.hidden as f64 * 2.0;
        // Crossing hosts when the deployment spans nodes.
        let (bw, lat) = if self.parallel.is_multi_host(&self.platform) {
            (self.platform.inter_gbps, self.platform.inter_lat_us)
        } else {
            (self.platform.intra_gbps, self.platform.intra_lat_us)
        };
        bytes / (bw * 1e9 * self.data.net_efficiency) + lat * 1e-6
    }

    /// Baseline on-GPU sampling epilogue for `batch` total sequences:
    /// memory-bound scans + sort over [batch, V] + TP reconciliation.
    pub fn gpu_sampling_s(&self, batch: usize) -> f64 {
        let t = self.parallel.tp as f64;
        let plat = &self.platform;
        let logits_bytes = batch as f64 * self.model.vocab as f64 * 4.0;
        let scan = self.sampling.scan_passes * logits_bytes
            / (plat.hbm_gbps * 1e9 * self.data.hbm_efficiency);
        // All-gather of vocab-sharded logits to form a global decision.
        let gather = if self.parallel.tp > 1 {
            logits_bytes * (t - 1.0) / t
                / (plat.intra_gbps * 1e9 * self.data.net_efficiency)
                + plat.intra_lat_us * 1e-6
        } else {
            0.0
        };
        scan
            + gather
            + self.sampling.fixed_s
            + self.sampling.per_rank_s * t
            + self.sampling.per_seq_s * batch as f64
    }

    /// Prefill time for `tokens` prompt tokens across the whole pipeline
    /// (compute-bound GEMMs; batch=tokens on one microbatch).
    pub fn prefill_s(&self, tokens: usize) -> f64 {
        let flops = self.model.decode_flops_per_token() * tokens as f64;
        let cluster_flops = self.platform.tflops_bf16
            * 1e12
            * self.data.flops_efficiency
            * self.parallel.world_size() as f64;
        flops / cluster_flops + self.parallel.pp as f64 * self.pp_comm_s(tokens.min(512))
    }

    /// Scheduling-output fan-out per iteration (§4.2): the baseline
    /// broadcasts to every worker over the network in multi-host mode;
    /// SIMPLE sends once per host and fans out via shared memory.
    pub fn fanout_s(&self, simple: bool) -> f64 {
        if !self.parallel.is_multi_host(&self.platform) {
            return 0.0;
        }
        let hosts = self
            .parallel
            .world_size()
            .div_ceil(self.platform.gpus_per_node) as f64;
        let per_msg = self.platform.inter_lat_us * 1e-6;
        if simple {
            hosts * per_msg // one message per downstream host
        } else {
            self.parallel.world_size() as f64 * per_msg // one per worker
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn h100_qwen72(tp: usize, pp: usize) -> GpuModel {
        GpuModel::new(
            ModelSpec::qwen25_72b(),
            PlatformSpec::h100(),
            ParallelConfig::new(tp, pp),
        )
    }

    #[test]
    fn stage_time_decreases_with_more_gpus() {
        let t1 = h100_qwen72(2, 2).stage_compute_s(256, 512.0);
        let t2 = h100_qwen72(4, 2).stage_compute_s(256, 512.0);
        assert!(t2 < t1, "tp4 {t2} should beat tp2 {t1}");
    }

    #[test]
    fn decode_is_memory_bound_at_small_batch() {
        // At small batch decode is weight-read dominated, so halving FLOPs
        // efficiency changes little; at large batch it shifts compute-bound.
        let mut a = h100_qwen72(4, 2);
        let base = a.stage_compute_s(16, 256.0);
        a.data.flops_efficiency *= 0.5;
        let slower = a.stage_compute_s(16, 256.0);
        assert!((slower - base) / base < 0.1, "{base} -> {slower}");
        // compute-bound regime reacts strongly
        let mut b = h100_qwen72(4, 2);
        let base_big = b.stage_compute_s(512, 256.0);
        b.data.flops_efficiency *= 0.5;
        let slower_big = b.stage_compute_s(512, 256.0);
        assert!((slower_big - base_big) / base_big > 0.3);
    }

    #[test]
    fn sampling_fraction_in_paper_band_on_h100() {
        // Fig 1a: sampling share 20–38% on large-vocab models, 8×H100.
        for (tp, pp) in [(4usize, 2usize), (8, 1)] {
            let g = h100_qwen72(tp, pp);
            let batch = 32 * g.parallel.world_size();
            let stage = g.stage_compute_s(batch, 512.0);
            let samp = g.gpu_sampling_s(batch);
            let cycle = stage + samp;
            let f = samp / cycle;
            assert!(
                (0.15..=0.45).contains(&f),
                "tp{tp} pp{pp}: f = {f:.3} (stage {stage:.5}, samp {samp:.5})"
            );
        }
    }

    #[test]
    fn sampling_fraction_grows_with_tp() {
        // §3: "rises ~10% as tensor parallelism grows from 2 to 8".
        let f_of = |tp: usize| {
            let g = h100_qwen72(tp, 1);
            let batch = 32 * g.parallel.world_size();
            let samp = g.gpu_sampling_s(batch);
            samp / (g.stage_compute_s(batch, 512.0) + samp)
        };
        let f2 = f_of(2);
        let f8 = f_of(8);
        assert!(f8 > f2, "f(t=8)={f8} must exceed f(t=2)={f2}");
        assert!(f8 - f2 > 0.03, "growth {:.3} too small", f8 - f2);
    }

    #[test]
    fn sampling_fraction_grows_on_faster_gpus() {
        // Amdahl drift (Eq. 3): faster data plane ⇒ larger f.
        let f_on = |plat: PlatformSpec| {
            let g = GpuModel::new(
                ModelSpec::qwen3_235b_a22b(),
                plat,
                ParallelConfig::new(4, 2),
            );
            let batch = 32 * 8;
            let samp = g.gpu_sampling_s(batch);
            samp / (g.stage_compute_s(batch, 512.0) + samp)
        };
        let f_l40 = f_on(PlatformSpec::l40());
        let f_h100 = f_on(PlatformSpec::h100());
        let f_b200 = f_on(PlatformSpec::b200());
        assert!(f_l40 < f_h100 && f_h100 < f_b200, "{f_l40} {f_h100} {f_b200}");
    }

    #[test]
    fn multihost_fanout_favors_simple() {
        let g = GpuModel::new(
            ModelSpec::qwen3_235b_a22b(),
            PlatformSpec::l40(),
            ParallelConfig::new(4, 4), // 16 GPUs = 2 hosts
        );
        assert!(g.fanout_s(true) < g.fanout_s(false));
        // single host: no fan-out cost at all
        let g1 = h100_qwen72(4, 2);
        assert_eq!(g1.fanout_s(false), 0.0);
    }

    #[test]
    fn prefill_scales_with_tokens() {
        let g = h100_qwen72(4, 2);
        assert!(g.prefill_s(1000) > g.prefill_s(100));
    }

    #[test]
    fn kv_reads_grow_with_context() {
        let g = h100_qwen72(4, 2);
        assert!(g.stage_compute_s(256, 2048.0) > g.stage_compute_s(256, 64.0));
    }
}
