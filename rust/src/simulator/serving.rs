//! Discrete-event serving simulation over the analytic timing model.
//!
//! Simulates continuous batching at iteration granularity: requests arrive
//! (open loop) or are all present (closed loop), occupy batch slots, every
//! iteration advances all running sequences by one token at the composed
//! cycle time, and admissions pay a prefill cost. Produces the Recorder
//! streams behind Figures 3–9 and Table 3.

use super::gpu::GpuModel;
use super::pipeline::{decode_iteration, DecisionMode};
use crate::metrics::Recorder;
use std::collections::VecDeque;

/// One simulated request.
#[derive(Debug, Clone)]
pub struct SimRequest {
    pub id: u64,
    pub arrival: f64,
    pub prompt_len: usize,
    pub output_len: usize,
}

/// Simulation configuration.
pub struct SimConfig {
    pub gpu: GpuModel,
    pub mode: DecisionMode,
    /// Total batch slots (paper: 32 per GPU × world size).
    pub slots: usize,
    /// CPU cores available to samplers (utilization accounting).
    pub cpu_cores: usize,
    /// Samplers deployed (CPU utilization accounting).
    pub samplers: usize,
}

#[derive(Debug, Clone)]
struct RunningSeq {
    id: u64,
    ctx: usize,
    remaining: usize,
}

/// Result of a serving simulation.
pub struct SimResult {
    pub recorder: Recorder,
    pub iterations: u64,
    /// Mean sampling fraction across iterations.
    pub mean_sampling_fraction: f64,
    /// Mean bubble fraction.
    pub mean_bubble_fraction: f64,
    /// Host memory estimate in bytes for the decision plane + rings.
    pub host_mem_bytes: f64,
}

impl SimResult {
    pub fn throughput(&self) -> f64 {
        self.recorder.throughput()
    }
}

/// Run the simulation until all requests complete.
pub fn simulate(cfg: &SimConfig, requests: &[SimRequest]) -> SimResult {
    let mut queue: VecDeque<SimRequest> = {
        let mut rs = requests.to_vec();
        rs.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        rs.into()
    };
    let mut running: Vec<RunningSeq> = Vec::new();
    let mut recorder = Recorder::new();
    for r in requests {
        recorder.on_arrival(r.id, r.arrival);
    }
    let mut clock = 0.0f64;
    let mut iterations = 0u64;
    let mut f_sum = 0.0f64;
    let mut bubble_sum = 0.0f64;
    // Chunked-prefill budget: admissions in one iteration may add at most
    // about one decode cycle of prefill work, so admission bursts don't
    // create giant outlier iterations (vLLM-style chunked prefill).
    let mut last_cycle = 5e-3f64;

    while !queue.is_empty() || !running.is_empty() {
        let mut prefill = 0.0f64;
        while running.len() < cfg.slots
            && queue.front().is_some_and(|r| r.arrival <= clock)
        {
            let next_cost = cfg.gpu.prefill_s(queue.front().unwrap().prompt_len);
            if prefill > 0.0 && prefill + next_cost > last_cycle {
                break; // defer further admissions to the next iteration
            }
            let r = queue.pop_front().unwrap();
            prefill += next_cost;
            running.push(RunningSeq { id: r.id, ctx: r.prompt_len, remaining: r.output_len });
        }
        if running.is_empty() {
            // idle until the next arrival
            clock = queue.front().map(|r| r.arrival).unwrap_or(clock);
            continue;
        }

        let batch = running.len();
        let ctx = running.iter().map(|s| s.ctx as f64).sum::<f64>() / batch as f64;
        let t = decode_iteration(&cfg.gpu, cfg.mode, batch, ctx);
        let cycle = t.cycle_s + prefill;
        last_cycle = t.cycle_s;
        let start = clock;
        clock += cycle;
        iterations += 1;
        f_sum += t.sampling_fraction;
        bubble_sum += t.bubble_fraction;

        // Busy accounting for Figures 8/9.
        recorder.on_busy("gpu", start, start + cycle * t.gpu_busy_fraction);
        if t.cpu_decision_s > 0.0 {
            // decision-plane CPU busy: samplers × wall share of the cycle
            let cpu_busy = (t.cpu_decision_s * cfg.samplers.min(batch) as f64
                / cfg.cpu_cores as f64)
                .min(cycle);
            recorder.on_busy("cpu", start, start + cpu_busy);
        }

        // Every running sequence emits one token this iteration.
        let mut still_running = Vec::with_capacity(running.len());
        for mut s in running.drain(..) {
            recorder.on_token(s.id, clock);
            s.ctx += 1;
            s.remaining -= 1;
            if s.remaining == 0 {
                recorder.on_finish(s.id, clock);
            } else {
                still_running.push(s);
            }
        }
        running = still_running;
    }

    // Host-memory model (Table 3): per-TP-rank ring buffers of
    // vocabulary-major logits slabs (depth 8), pre-generated random-number
    // rings, and the paper's dense per-sequence histograms C_p/C_o + masks.
    let v = cfg.gpu.model.vocab as f64;
    let slots = cfg.slots as f64;
    let t = cfg.gpu.parallel.tp as f64;
    let ring_depth = 8.0;
    let ring_bytes = t * ring_depth * v * slots * 4.0; // [V/t × B] f32 slabs × t × depth
    let random_bytes = ring_depth * slots * 3.0 * 8.0;
    let hist_bytes = 2.0 * slots * v * 4.0 + 2.0 * slots * v / 8.0; // C_p,C_o + masks
    let host_mem_bytes = match cfg.mode {
        DecisionMode::GpuEpilogue => 0.0,
        _ => ring_bytes + random_bytes + hist_bytes,
    };

    SimResult {
        recorder,
        iterations,
        mean_sampling_fraction: if iterations > 0 { f_sum / iterations as f64 } else { 0.0 },
        mean_bubble_fraction: if iterations > 0 { bubble_sum / iterations as f64 } else { 0.0 },
        host_mem_bytes,
    }
}

/// Convenience: build SimRequests from the workload generator's trace.
pub fn to_sim_requests(trace: &crate::workload::Trace) -> Vec<SimRequest> {
    trace
        .requests
        .iter()
        .zip(&trace.output_lens)
        .map(|(r, &olen)| SimRequest {
            id: r.id,
            arrival: r.arrival,
            prompt_len: r.prompt.len(),
            output_len: olen,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelSpec, ParallelConfig, PlatformSpec};
    use crate::rng::Philox;

    fn gpu() -> GpuModel {
        GpuModel::new(
            ModelSpec::qwen25_72b(),
            PlatformSpec::h100(),
            ParallelConfig::new(4, 2),
        )
    }

    fn requests(n: usize, arrival_rate: Option<f64>) -> Vec<SimRequest> {
        let mut rng = Philox::new(1);
        let mut t = 0.0;
        (0..n)
            .map(|i| {
                if let Some(rate) = arrival_rate {
                    t += rng.next_exp() / rate;
                }
                SimRequest {
                    id: i as u64,
                    arrival: t,
                    prompt_len: 30 + (rng.next_below(100) as usize),
                    output_len: 50 + (rng.next_below(150) as usize),
                }
            })
            .collect()
    }

    fn cfg(mode: DecisionMode) -> SimConfig {
        SimConfig { gpu: gpu(), mode, slots: 256, cpu_cores: 192, samplers: 16 }
    }

    #[test]
    fn all_requests_complete_with_exact_token_counts() {
        let reqs = requests(100, None);
        let expected: usize = reqs.iter().map(|r| r.output_len).sum();
        let res = simulate(&cfg(DecisionMode::GpuEpilogue), &reqs);
        assert_eq!(res.recorder.total_tokens(), expected);
        assert_eq!(res.recorder.finished_requests(), 100);
    }

    #[test]
    fn simple_beats_baseline_throughput() {
        let reqs = requests(300, None);
        let base = simulate(&cfg(DecisionMode::GpuEpilogue), &reqs);
        let simple = simulate(
            &cfg(DecisionMode::SimpleOverlapped { per_seq_s: 20e-6, samplers: 16 }),
            &reqs,
        );
        let gain = simple.throughput() / base.throughput();
        assert!(gain > 1.15, "gain {gain}");
        // and P95 TPOT drops (Figures 4/5/7's headline)
        let p95_base = base.recorder.tpot_summary().p95;
        let p95_simple = simple.recorder.tpot_summary().p95;
        assert!(
            p95_simple < p95_base * 0.9,
            "P95 {p95_simple} vs {p95_base}"
        );
    }

    #[test]
    fn open_loop_latency_grows_with_rate() {
        let mode = DecisionMode::GpuEpilogue;
        let slow = simulate(&cfg(mode), &requests(150, Some(5.0)));
        let fast = simulate(&cfg(mode), &requests(150, Some(1e6)));
        // near-saturation arrival rate queues more: higher TTFT
        assert!(
            fast.recorder.ttft_summary().p50 > slow.recorder.ttft_summary().p50,
            "queueing should inflate TTFT"
        );
    }

    #[test]
    fn utilization_accounting_sane() {
        let reqs = requests(200, None);
        let base = simulate(&cfg(DecisionMode::GpuEpilogue), &reqs);
        let simple = simulate(
            &cfg(DecisionMode::SimpleOverlapped { per_seq_s: 20e-6, samplers: 16 }),
            &reqs,
        );
        let gpu_base = base.recorder.utilization("gpu");
        let gpu_simple = simple.recorder.utilization("gpu");
        assert!(gpu_simple > gpu_base, "{gpu_simple} vs {gpu_base}");
        assert!(gpu_simple <= 1.0);
        // CPU goes up for SIMPLE but stays far from saturation (§7.3)
        let cpu_simple = simple.recorder.utilization("cpu");
        assert!(cpu_simple > 0.0 && cpu_simple < 0.5, "cpu {cpu_simple}");
        assert_eq!(base.recorder.utilization("cpu"), 0.0);
    }

    #[test]
    fn host_memory_modest_for_simple() {
        let reqs = requests(50, None);
        let simple = simulate(
            &cfg(DecisionMode::SimpleOverlapped { per_seq_s: 20e-6, samplers: 16 }),
            &reqs,
        );
        // Table 3: ~1% of a 2 TB host
        let frac = simple.host_mem_bytes / (2048.0 * 1e9);
        assert!(frac < 0.02, "host mem frac {frac}");
        assert!(simple.host_mem_bytes > 0.0);
    }

    #[test]
    fn deterministic() {
        let reqs = requests(80, Some(50.0));
        let a = simulate(&cfg(DecisionMode::GpuEpilogue), &reqs);
        let b = simulate(&cfg(DecisionMode::GpuEpilogue), &reqs);
        assert_eq!(a.iterations, b.iterations);
        assert!((a.throughput() - b.throughput()).abs() < 1e-9);
    }
}
