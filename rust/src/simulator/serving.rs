//! Discrete-event serving simulation over the analytic timing model.
//!
//! Simulates continuous batching at iteration granularity: requests arrive
//! (open loop) or are all present (closed loop), occupy batch slots, every
//! iteration advances all running sequences by one token at the composed
//! cycle time, and admissions pay a prefill cost. Produces the Recorder
//! streams behind Figures 3–9 and Table 3.

use super::gpu::GpuModel;
use super::pipeline::{decode_iteration, DecisionMode};
use crate::metrics::Recorder;
use crate::rng::Philox;
use std::collections::VecDeque;

/// One simulated request.
#[derive(Debug, Clone)]
pub struct SimRequest {
    pub id: u64,
    pub arrival: f64,
    pub prompt_len: usize,
    pub output_len: usize,
}

/// Simulation configuration.
pub struct SimConfig {
    pub gpu: GpuModel,
    pub mode: DecisionMode,
    /// Total batch slots (paper: 32 per GPU × world size).
    pub slots: usize,
    /// CPU cores available to samplers (utilization accounting).
    pub cpu_cores: usize,
    /// Samplers deployed (CPU utilization accounting).
    pub samplers: usize,
    /// Chunked-prefill token budget per iteration (0 = legacy behavior:
    /// whole prompts prefill at admission, bounded by the one-cycle
    /// heuristic). With a budget, prompts are fed in chunks interleaved
    /// with decode iterations, oldest arrival first.
    pub prefill_chunk_tokens: usize,
    /// KV-cache capacity in tokens across all slots (0 = unlimited). Under
    /// pressure the latest-arrived running sequence is preempted and later
    /// resumed with recompute (its context re-prefills), mirroring the
    /// engine scheduler's eviction policy.
    pub kv_capacity_tokens: usize,
}

impl SimConfig {
    /// Legacy-shaped config: unlimited KV, admission-time prefill.
    pub fn new(
        gpu: GpuModel,
        mode: DecisionMode,
        slots: usize,
        cpu_cores: usize,
        samplers: usize,
    ) -> SimConfig {
        SimConfig {
            gpu,
            mode,
            slots,
            cpu_cores,
            samplers,
            prefill_chunk_tokens: 0,
            kv_capacity_tokens: 0,
        }
    }
}

#[derive(Debug, Clone)]
struct RunningSeq {
    id: u64,
    arrival: f64,
    /// Tokens resident in the (modeled) KV cache.
    ctx: usize,
    /// Prompt tokens not yet prefetched through the forward (chunked mode).
    prefill_left: usize,
    remaining: usize,
}

/// Result of a serving simulation.
pub struct SimResult {
    pub recorder: Recorder,
    pub iterations: u64,
    /// Mean sampling fraction across iterations.
    pub mean_sampling_fraction: f64,
    /// Mean bubble fraction.
    pub mean_bubble_fraction: f64,
    /// Host memory estimate in bytes for the decision plane + rings.
    pub host_mem_bytes: f64,
    /// KV-pressure evictions (recompute-on-resume).
    pub preemptions: u64,
    /// Speculative decoding: total tokens committed by spec windows and the
    /// number of windows (decode-sequence-iterations); their ratio is the
    /// accepted-tokens-per-step the `specdec` scenario reports.
    pub spec_tokens: u64,
    pub spec_windows: u64,
}

impl SimResult {
    pub fn throughput(&self) -> f64 {
        self.recorder.throughput()
    }
}

/// Run the simulation until all requests complete.
pub fn simulate(cfg: &SimConfig, requests: &[SimRequest]) -> SimResult {
    let chunked = cfg.prefill_chunk_tokens > 0;
    let mut queue: VecDeque<SimRequest> = {
        let mut rs = requests.to_vec();
        rs.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        rs.into()
    };
    let mut running: Vec<RunningSeq> = Vec::new();
    let mut recorder = Recorder::new();
    for r in requests {
        recorder.on_arrival(r.id, r.arrival);
    }
    let mut clock = 0.0f64;
    let mut iterations = 0u64;
    let mut spec_tokens = 0u64;
    let mut spec_windows = 0u64;
    // sampling/bubble fractions are decode-iteration means: pure-prefill
    // iterations (chunked mode, batch == 0) must not dilute them
    let mut decode_iters = 0u64;
    let mut preemptions = 0u64;
    let mut f_sum = 0.0f64;
    let mut bubble_sum = 0.0f64;
    // Legacy admission bound: admissions in one iteration may add at most
    // about one decode cycle of prefill work, so admission bursts don't
    // create giant outlier iterations. With `prefill_chunk_tokens` set, the
    // explicit token budget replaces this heuristic.
    let mut last_cycle = 5e-3f64;

    while !queue.is_empty() || !running.is_empty() {
        let mut prefill = 0.0f64;
        while running.len() < cfg.slots
            && queue.front().is_some_and(|r| r.arrival <= clock)
        {
            let head = queue.front().unwrap();
            // KV admission control (a sequence over capacity still runs
            // alone rather than deadlocking the queue)
            if cfg.kv_capacity_tokens > 0 && !running.is_empty() {
                let used: usize =
                    running.iter().map(|s| s.ctx + s.prefill_left + 1).sum();
                if used + head.prompt_len + 1 > cfg.kv_capacity_tokens {
                    break;
                }
            }
            if chunked {
                let r = queue.pop_front().unwrap();
                running.push(RunningSeq {
                    id: r.id,
                    arrival: r.arrival,
                    ctx: 0,
                    prefill_left: r.prompt_len,
                    remaining: r.output_len,
                });
            } else {
                let next_cost = cfg.gpu.prefill_s(head.prompt_len);
                if prefill > 0.0 && prefill + next_cost > last_cycle {
                    break; // defer further admissions to the next iteration
                }
                let r = queue.pop_front().unwrap();
                prefill += next_cost;
                running.push(RunningSeq {
                    id: r.id,
                    arrival: r.arrival,
                    ctx: r.prompt_len,
                    prefill_left: 0,
                    remaining: r.output_len,
                });
            }
        }
        if running.is_empty() {
            // idle until the next arrival
            clock = queue.front().map(|r| r.arrival).unwrap_or(clock);
            continue;
        }

        // Chunked prefill: spend the token budget on prefilling sequences,
        // oldest arrival first, interleaved with this decode iteration.
        if chunked {
            let mut budget = cfg.prefill_chunk_tokens;
            let mut idx: Vec<usize> =
                (0..running.len()).filter(|&i| running[i].prefill_left > 0).collect();
            idx.sort_by(|&a, &b| {
                (running[a].arrival, running[a].id)
                    .partial_cmp(&(running[b].arrival, running[b].id))
                    .unwrap()
            });
            let mut chunk_total = 0usize;
            for i in idx {
                if budget == 0 {
                    break;
                }
                let c = running[i].prefill_left.min(budget);
                running[i].prefill_left -= c;
                running[i].ctx += c;
                budget -= c;
                chunk_total += c;
            }
            if chunk_total > 0 {
                prefill = cfg.gpu.prefill_s(chunk_total);
            }
        }

        let batch = running.iter().filter(|s| s.prefill_left == 0).count();
        let (cycle, timing) = if batch > 0 {
            let ctx = running
                .iter()
                .filter(|s| s.prefill_left == 0)
                .map(|s| s.ctx as f64)
                .sum::<f64>()
                / batch as f64;
            let t = decode_iteration(&cfg.gpu, cfg.mode, batch, ctx);
            last_cycle = t.cycle_s;
            (t.cycle_s + prefill, Some(t))
        } else {
            // a pure-prefill iteration (everyone mid-chunk): the cycle is
            // the chunk's prefill time alone
            (prefill.max(1e-9), None)
        };
        let start = clock;
        clock += cycle;
        iterations += 1;

        // Busy accounting for Figures 8/9.
        if let Some(t) = &timing {
            decode_iters += 1;
            f_sum += t.sampling_fraction;
            bubble_sum += t.bubble_fraction;
            recorder.on_busy("gpu", start, start + cycle * t.gpu_busy_fraction);
            if t.cpu_decision_s > 0.0 {
                // decision-plane CPU busy: samplers × wall share of the cycle
                let cpu_busy = (t.cpu_decision_s * cfg.samplers.min(batch) as f64
                    / cfg.cpu_cores as f64)
                    .min(cycle);
                recorder.on_busy("cpu", start, start + cpu_busy);
            }
        } else {
            recorder.on_busy("gpu", start, start + cycle);
        }

        // Every fully-prefilled sequence commits this iteration: one token,
        // or 1 + LeadingAccepts(k, accept_rate) under speculative decoding
        // (deterministic per (seq, context) — the accept run mirrors the
        // verifier's prefix-accept semantics).
        let spec = cfg.mode.spec_shape();
        let mut still_running = Vec::with_capacity(running.len());
        for mut s in running.drain(..) {
            if s.prefill_left > 0 {
                still_running.push(s);
                continue;
            }
            let commit = match spec {
                Some((k, accept)) if k > 0 => {
                    let mut rng =
                        Philox::at(0x5bec ^ s.id, ((s.ctx as u128) << 32) | iterations as u128);
                    let mut acc = 0usize;
                    while acc < k && rng.next_f64() < accept {
                        acc += 1;
                    }
                    let c = (1 + acc).min(s.remaining);
                    spec_windows += 1;
                    spec_tokens += c as u64;
                    c
                }
                _ => 1,
            };
            for _ in 0..commit {
                recorder.on_token(s.id, clock);
            }
            s.ctx += commit;
            s.remaining -= commit;
            if s.remaining == 0 {
                recorder.on_finish(s.id, clock);
            } else {
                still_running.push(s);
            }
        }
        running = still_running;

        // KV pressure: evict latest arrivals (recompute-on-resume) until
        // the cache fits, always keeping at least one sequence running.
        if cfg.kv_capacity_tokens > 0 {
            loop {
                let used: usize =
                    running.iter().map(|s| s.ctx + s.prefill_left + 1).sum();
                if used <= cfg.kv_capacity_tokens || running.len() <= 1 {
                    break;
                }
                let vi = (0..running.len())
                    .max_by(|&a, &b| {
                        (running[a].arrival, running[a].id)
                            .partial_cmp(&(running[b].arrival, running[b].id))
                            .unwrap()
                    })
                    .unwrap();
                let v = running.swap_remove(vi);
                preemptions += 1;
                // resume replays everything fed so far (recompute)
                queue.push_front(SimRequest {
                    id: v.id,
                    arrival: v.arrival,
                    prompt_len: v.ctx + v.prefill_left,
                    output_len: v.remaining,
                });
            }
        }
    }

    // Host-memory model (Table 3): per-TP-rank ring buffers of
    // vocabulary-major logits slabs (depth 8), pre-generated random-number
    // rings, and the paper's dense per-sequence histograms C_p/C_o + masks.
    let v = cfg.gpu.model.vocab as f64;
    let slots = cfg.slots as f64;
    let t = cfg.gpu.parallel.tp as f64;
    let ring_depth = 8.0;
    let ring_bytes = t * ring_depth * v * slots * 4.0; // [V/t × B] f32 slabs × t × depth
    let random_bytes = ring_depth * slots * 3.0 * 8.0;
    let hist_bytes = 2.0 * slots * v * 4.0 + 2.0 * slots * v / 8.0; // C_p,C_o + masks
    let host_mem_bytes = match cfg.mode {
        DecisionMode::GpuEpilogue => 0.0,
        _ => ring_bytes + random_bytes + hist_bytes,
    };

    SimResult {
        recorder,
        iterations,
        mean_sampling_fraction: if decode_iters > 0 {
            f_sum / decode_iters as f64
        } else {
            0.0
        },
        mean_bubble_fraction: if decode_iters > 0 {
            bubble_sum / decode_iters as f64
        } else {
            0.0
        },
        host_mem_bytes,
        preemptions,
        spec_tokens,
        spec_windows,
    }
}

/// Cluster-layer mirror of `cluster::ClusterConfig` (DESIGN.md §9): how
/// many data-parallel replicas the simulated fleet runs, whether a
/// DistServe-style prefill/decode split is active, and the handoff-cost
/// model — so measured and simulated cluster throughput are comparable.
#[derive(Debug, Clone)]
pub struct ClusterSimConfig {
    pub replicas: usize,
    /// Replicas dedicated to prefill (0 = unified fleet). The rest decode.
    pub prefill_replicas: usize,
    /// Simulated KV-transfer cost per context token for the handoff,
    /// seconds (mirrors the router's `kv_transfer_us_per_token`).
    pub kv_transfer_s_per_token: f64,
    /// Fault/recovery timing model (DESIGN.md §10): replica
    /// `fail_replica` dies at this simulated time; its unfinished
    /// requests are requeued onto the survivors after `recovery_delay_s`
    /// and recompute from scratch — the timing mirror of the router's
    /// failover sweep. `None` = fault-free. Unified fleets only (the
    /// split-mode two-phase replay has no single death time per request);
    /// needs at least 2 replicas so a survivor exists.
    pub fail_at_s: Option<f64>,
    /// Which replica the fault kills.
    pub fail_replica: usize,
    /// Detection + requeue latency the orphaned requests pay before a
    /// survivor sees them (mirrors the sweep's failover pause).
    pub recovery_delay_s: f64,
}

impl Default for ClusterSimConfig {
    fn default() -> Self {
        ClusterSimConfig {
            replicas: 1,
            prefill_replicas: 0,
            kv_transfer_s_per_token: 2e-6,
            fail_at_s: None,
            fail_replica: 0,
            recovery_delay_s: 0.05,
        }
    }
}

/// Fleet-level simulation result.
pub struct ClusterSimResult {
    /// Merged fleet recorder (exact fleet-wide percentiles).
    pub recorder: Recorder,
    pub per_replica: Vec<SimResult>,
    pub preemptions: u64,
    /// Requests the fault model requeued onto survivors (0 = fault-free).
    pub requeued: usize,
}

impl ClusterSimResult {
    pub fn throughput(&self) -> f64 {
        self.recorder.throughput()
    }
}

/// Simulate a fleet of data-parallel replicas, each an independent
/// [`simulate`] run over its routed share of the trace (deterministic
/// round-robin — the placement-blind mirror of the measured router; every
/// routing policy commits the same tokens, so the simulator models the
/// placement-independent quantity).
///
/// With `prefill_replicas > 0` the fleet splits DistServe-style: the
/// prefill pool serves every request truncated to its first token, then
/// each sequence's decode phase is replayed on the decode pool with its
/// arrival delayed by the prefill finish time plus the simulated
/// KV-transfer cost — the same two-phase lifecycle the measured router
/// realizes, so fleet TPOT includes the handoff gap.
pub fn simulate_cluster(
    cfg: &SimConfig,
    ccfg: &ClusterSimConfig,
    requests: &[SimRequest],
) -> ClusterSimResult {
    assert!(ccfg.replicas >= 1);
    let mut per_replica = Vec::new();
    let mut recorder = Recorder::new();
    let mut preemptions = 0u64;
    if ccfg.prefill_replicas == 0 {
        let mut shares: Vec<Vec<SimRequest>> = (0..ccfg.replicas)
            .map(|rep| {
                requests
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| i % ccfg.replicas == rep)
                    .map(|(_, r)| r.clone())
                    .collect()
            })
            .collect();
        // Fault/recovery timing model: probe the doomed replica fault-free
        // to learn which of its requests outlive the death time; those are
        // requeued onto the survivors (full recompute — the router's
        // deterministic replay) arriving after the recovery delay, and the
        // dead replica keeps only the work it finished in time.
        let mut requeued = 0usize;
        if let Some(fail_t) = ccfg.fail_at_s {
            assert!(
                ccfg.replicas >= 2 && ccfg.fail_replica < ccfg.replicas,
                "the fault model needs a surviving replica"
            );
            let probe = simulate(cfg, &shares[ccfg.fail_replica]);
            let (kept, lost): (Vec<SimRequest>, Vec<SimRequest>) = shares
                [ccfg.fail_replica]
                .iter()
                .cloned()
                .partition(|r| {
                    probe.recorder.finish_time(r.id).is_some_and(|t| t <= fail_t)
                });
            requeued = lost.len();
            shares[ccfg.fail_replica] = kept;
            let survivors: Vec<usize> =
                (0..ccfg.replicas).filter(|&r| r != ccfg.fail_replica).collect();
            for (j, mut r) in lost.into_iter().enumerate() {
                // the request queues from its ORIGINAL arrival (merge takes
                // the min), but a survivor only serves it after the fault +
                // recovery delay — TTFT/TPOT absorb the pause, exactly like
                // the measured router's requeue accounting
                recorder.on_arrival(r.id, r.arrival);
                r.arrival = r.arrival.max(fail_t + ccfg.recovery_delay_s);
                shares[survivors[j % survivors.len()]].push(r);
            }
            recorder.on_recovery(1, ccfg.recovery_delay_s);
        }
        for share in &shares {
            let res = simulate(cfg, share);
            recorder.merge(&res.recorder);
            preemptions += res.preemptions;
            per_replica.push(res);
        }
        return ClusterSimResult { recorder, per_replica, preemptions, requeued };
    }
    assert!(
        ccfg.fail_at_s.is_none(),
        "the fault model composes with unified fleets only"
    );
    assert!(
        ccfg.prefill_replicas < ccfg.replicas,
        "the split needs at least one decode replica"
    );
    // Phase 1: the prefill pool produces every request's first token.
    let n_prefill = ccfg.prefill_replicas;
    let mut prefill_results = Vec::new();
    for rep in 0..n_prefill {
        let share: Vec<SimRequest> = requests
            .iter()
            .enumerate()
            .filter(|(i, _)| i % n_prefill == rep)
            .map(|(_, r)| SimRequest { output_len: 1, ..r.clone() })
            .collect();
        prefill_results.push(simulate(cfg, &share));
    }
    // Phase 2: decode resumes each multi-token request after its prefill
    // finish + the transfer of its (prompt + 1)-token context.
    let n_decode = ccfg.replicas - n_prefill;
    let mut decode_requests: Vec<SimRequest> = Vec::new();
    for r in requests {
        if r.output_len <= 1 {
            continue; // its whole lifecycle lived on the prefill pool
        }
        let done = prefill_results
            .iter()
            .find_map(|res| res.recorder.finish_time(r.id))
            .expect("prefill pool finished every request");
        let ctx = r.prompt_len + 1;
        decode_requests.push(SimRequest {
            id: r.id,
            arrival: done + ctx as f64 * ccfg.kv_transfer_s_per_token,
            prompt_len: ctx, // recompute replays prompt + the first token
            output_len: r.output_len - 1,
        });
    }
    let mut decode_results = Vec::new();
    for rep in 0..n_decode {
        let share: Vec<SimRequest> = decode_requests
            .iter()
            .enumerate()
            .filter(|(i, _)| i % n_decode == rep)
            .map(|(_, r)| r.clone())
            .collect();
        decode_results.push(simulate(cfg, &share));
    }
    for res in prefill_results.into_iter().chain(decode_results) {
        recorder.merge(&res.recorder);
        preemptions += res.preemptions;
        per_replica.push(res);
    }
    ClusterSimResult { recorder, per_replica, preemptions, requeued: 0 }
}

/// Convenience: build SimRequests from the workload generator's trace.
pub fn to_sim_requests(trace: &crate::workload::Trace) -> Vec<SimRequest> {
    trace
        .requests
        .iter()
        .zip(&trace.output_lens)
        .map(|(r, &olen)| SimRequest {
            id: r.id,
            arrival: r.arrival,
            prompt_len: r.prompt.len(),
            output_len: olen,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelSpec, ParallelConfig, PlatformSpec};
    use crate::rng::Philox;

    fn gpu() -> GpuModel {
        GpuModel::new(
            ModelSpec::qwen25_72b(),
            PlatformSpec::h100(),
            ParallelConfig::new(4, 2),
        )
    }

    fn requests(n: usize, arrival_rate: Option<f64>) -> Vec<SimRequest> {
        let mut rng = Philox::new(1);
        let mut t = 0.0;
        (0..n)
            .map(|i| {
                if let Some(rate) = arrival_rate {
                    t += rng.next_exp() / rate;
                }
                SimRequest {
                    id: i as u64,
                    arrival: t,
                    prompt_len: 30 + (rng.next_below(100) as usize),
                    output_len: 50 + (rng.next_below(150) as usize),
                }
            })
            .collect()
    }

    fn cfg(mode: DecisionMode) -> SimConfig {
        SimConfig::new(gpu(), mode, 256, 192, 16)
    }

    #[test]
    fn all_requests_complete_with_exact_token_counts() {
        let reqs = requests(100, None);
        let expected: usize = reqs.iter().map(|r| r.output_len).sum();
        let res = simulate(&cfg(DecisionMode::GpuEpilogue), &reqs);
        assert_eq!(res.recorder.total_tokens(), expected);
        assert_eq!(res.recorder.finished_requests(), 100);
    }

    #[test]
    fn simple_beats_baseline_throughput() {
        let reqs = requests(300, None);
        let base = simulate(&cfg(DecisionMode::GpuEpilogue), &reqs);
        let simple = simulate(
            &cfg(DecisionMode::SimpleOverlapped { per_seq_s: 20e-6, samplers: 16 }),
            &reqs,
        );
        let gain = simple.throughput() / base.throughput();
        assert!(gain > 1.15, "gain {gain}");
        // and P95 TPOT drops (Figures 4/5/7's headline)
        let p95_base = base.recorder.tpot_summary().p95;
        let p95_simple = simple.recorder.tpot_summary().p95;
        assert!(
            p95_simple < p95_base * 0.9,
            "P95 {p95_simple} vs {p95_base}"
        );
    }

    #[test]
    fn open_loop_latency_grows_with_rate() {
        let mode = DecisionMode::GpuEpilogue;
        let slow = simulate(&cfg(mode), &requests(150, Some(5.0)));
        let fast = simulate(&cfg(mode), &requests(150, Some(1e6)));
        // near-saturation arrival rate queues more: higher TTFT
        assert!(
            fast.recorder.ttft_summary().p50 > slow.recorder.ttft_summary().p50,
            "queueing should inflate TTFT"
        );
    }

    #[test]
    fn utilization_accounting_sane() {
        let reqs = requests(200, None);
        let base = simulate(&cfg(DecisionMode::GpuEpilogue), &reqs);
        let simple = simulate(
            &cfg(DecisionMode::SimpleOverlapped { per_seq_s: 20e-6, samplers: 16 }),
            &reqs,
        );
        let gpu_base = base.recorder.utilization("gpu");
        let gpu_simple = simple.recorder.utilization("gpu");
        assert!(gpu_simple > gpu_base, "{gpu_simple} vs {gpu_base}");
        assert!(gpu_simple <= 1.0);
        // CPU goes up for SIMPLE but stays far from saturation (§7.3)
        let cpu_simple = simple.recorder.utilization("cpu");
        assert!(cpu_simple > 0.0 && cpu_simple < 0.5, "cpu {cpu_simple}");
        assert_eq!(base.recorder.utilization("cpu"), 0.0);
    }

    #[test]
    fn host_memory_modest_for_simple() {
        let reqs = requests(50, None);
        let simple = simulate(
            &cfg(DecisionMode::SimpleOverlapped { per_seq_s: 20e-6, samplers: 16 }),
            &reqs,
        );
        // Table 3: ~1% of a 2 TB host
        let frac = simple.host_mem_bytes / (2048.0 * 1e9);
        assert!(frac < 0.02, "host mem frac {frac}");
        assert!(simple.host_mem_bytes > 0.0);
    }

    #[test]
    fn deterministic() {
        let reqs = requests(80, Some(50.0));
        let a = simulate(&cfg(DecisionMode::GpuEpilogue), &reqs);
        let b = simulate(&cfg(DecisionMode::GpuEpilogue), &reqs);
        assert_eq!(a.iterations, b.iterations);
        assert!((a.throughput() - b.throughput()).abs() < 1e-9);
    }

    #[test]
    fn chunked_prefill_completes_exactly_and_caps_admission_work() {
        let reqs = requests(120, Some(200.0));
        let expected: usize = reqs.iter().map(|r| r.output_len).sum();
        let mut c = cfg(DecisionMode::GpuEpilogue);
        c.prefill_chunk_tokens = 256;
        let res = simulate(&c, &reqs);
        assert_eq!(res.recorder.total_tokens(), expected);
        assert_eq!(res.recorder.finished_requests(), 120);
        assert_eq!(res.preemptions, 0);
    }

    #[test]
    fn chunked_prefill_tames_tail_latency_under_bursts() {
        // A flood of simultaneous arrivals: unbounded admission prefills
        // whole prompts alongside decode, inflating inter-token gaps for
        // running sequences; a chunk budget bounds the per-iteration
        // prefill work, so the decode-tail P95 improves.
        let mut rng = Philox::new(4);
        let reqs: Vec<SimRequest> = (0..200)
            .map(|i| SimRequest {
                id: i as u64,
                // bursts of 50 arriving together every 2s
                arrival: (i / 50) as f64 * 2.0,
                prompt_len: 400 + rng.next_below(400) as usize,
                output_len: 40 + rng.next_below(60) as usize,
            })
            .collect();
        let legacy = simulate(&cfg(DecisionMode::GpuEpilogue), &reqs);
        let mut c = cfg(DecisionMode::GpuEpilogue);
        c.prefill_chunk_tokens = 256;
        let chunked = simulate(&c, &reqs);
        let expected: usize = reqs.iter().map(|r| r.output_len).sum();
        assert_eq!(chunked.recorder.total_tokens(), expected);
        let (p95_legacy, p95_chunked) = (
            legacy.recorder.tpot_summary().p95,
            chunked.recorder.tpot_summary().p95,
        );
        assert!(
            p95_chunked <= p95_legacy,
            "chunked P95 {p95_chunked} vs legacy {p95_legacy}"
        );
    }

    #[test]
    fn spec_decode_completes_exactly_and_raises_throughput() {
        // Small batch: decode sits squarely in the weight-bound regime,
        // where the draft chain's extra per-token work hides under the
        // weight pass — speculative decoding's winning regime.
        let reqs = requests(150, None);
        let expected: usize = reqs.iter().map(|r| r.output_len).sum();
        let mut plain_cfg =
            cfg(DecisionMode::SimpleOverlapped { per_seq_s: 20e-6, samplers: 64 });
        plain_cfg.slots = 32;
        let plain = simulate(&plain_cfg, &reqs);
        let mut spec_cfg = cfg(DecisionMode::SpecVerify {
            per_seq_s: 20e-6,
            samplers: 64,
            k: 2,
            accept_rate: 0.8,
        });
        spec_cfg.slots = 32;
        let spec = simulate(&spec_cfg, &reqs);
        // exactness: speculation changes timing, never token counts
        assert_eq!(spec.recorder.total_tokens(), expected);
        assert_eq!(spec.recorder.finished_requests(), 150);
        assert_eq!(plain.recorder.total_tokens(), expected);
        // accepted-tokens-per-step ∈ (1, k+1]
        let per_step = spec.spec_tokens as f64 / spec.spec_windows as f64;
        assert!(per_step > 1.2 && per_step <= 3.0, "tokens/step {per_step}");
        assert_eq!(plain.spec_windows, 0);
        // at 80% per-position acceptance the chain pays for itself
        assert!(
            spec.throughput() > plain.throughput(),
            "spec {} !> plain {}",
            spec.throughput(),
            plain.throughput()
        );
        // fewer iterations: multi-token commits shrink the schedule
        assert!(spec.iterations < plain.iterations);
    }

    #[test]
    fn spec_decode_zero_accept_still_completes() {
        // accept_rate 0: every window commits exactly the bonus token; the
        // run degenerates to plain decode token-count-wise but pays the
        // chain cost, so it must not be faster.
        let reqs = requests(60, None);
        let expected: usize = reqs.iter().map(|r| r.output_len).sum();
        let plain = simulate(
            &cfg(DecisionMode::SimpleOverlapped { per_seq_s: 20e-6, samplers: 64 }),
            &reqs,
        );
        let spec = simulate(
            &cfg(DecisionMode::SpecVerify {
                per_seq_s: 20e-6,
                samplers: 64,
                k: 4,
                accept_rate: 0.0,
            }),
            &reqs,
        );
        assert_eq!(spec.recorder.total_tokens(), expected);
        let per_step = spec.spec_tokens as f64 / spec.spec_windows as f64;
        assert!((per_step - 1.0).abs() < 1e-9);
        assert!(spec.throughput() <= plain.throughput() * 1.001);
    }

    #[test]
    fn kv_pressure_preempts_and_still_completes() {
        let reqs = requests(60, None);
        let expected: usize = reqs.iter().map(|r| r.output_len).sum();
        let max_need: usize =
            reqs.iter().map(|r| r.prompt_len + r.output_len + 1).max().unwrap();
        let mut c = cfg(DecisionMode::GpuEpilogue);
        c.slots = 16;
        // capacity fits a handful of sequences but not 16 full ones
        c.kv_capacity_tokens = max_need * 4;
        let res = simulate(&c, &reqs);
        assert_eq!(res.recorder.total_tokens(), expected, "recompute loses no tokens");
        assert_eq!(res.recorder.finished_requests(), 60);
        assert!(res.preemptions > 0, "tight cache must preempt");
        // unlimited-capacity run of the same trace never preempts
        let free = simulate(&cfg(DecisionMode::GpuEpilogue), &reqs);
        assert_eq!(free.preemptions, 0);
    }

    // ---- cluster layer (data-parallel replicas, DESIGN.md §9) ----

    #[test]
    fn cluster_replicas_scale_throughput_and_lose_no_tokens() {
        let reqs = requests(200, None);
        let expected: usize = reqs.iter().map(|r| r.output_len).sum();
        // 32 slots per replica: the closed loop saturates one replica's
        // slot capacity, so the fleet's extra slots are visible throughput
        let mut scfg = cfg(DecisionMode::GpuEpilogue);
        scfg.slots = 32;
        let one = simulate_cluster(&scfg, &ClusterSimConfig::default(), &reqs);
        let mut c4 = ClusterSimConfig::default();
        c4.replicas = 4;
        let four = simulate_cluster(&scfg, &c4, &reqs);
        assert_eq!(one.recorder.total_tokens(), expected);
        assert_eq!(four.recorder.total_tokens(), expected);
        assert_eq!(four.recorder.finished_requests(), 200);
        assert_eq!(four.per_replica.len(), 4);
        // 4 replicas split a saturating closed loop: clearly faster,
        // sublinear-or-linear (each replica also runs smaller batches)
        let gain = four.throughput() / one.throughput();
        assert!(gain > 1.5, "4-replica gain {gain}");
    }

    #[test]
    fn cluster_prefill_decode_split_completes_with_transfer_gaps() {
        let reqs = requests(80, Some(100.0));
        let expected: usize = reqs.iter().map(|r| r.output_len).sum();
        let mut split = ClusterSimConfig::default();
        split.replicas = 3;
        split.prefill_replicas = 1;
        split.kv_transfer_s_per_token = 1e-3; // far above any queueing noise
        let res = simulate_cluster(&cfg(DecisionMode::GpuEpilogue), &split, &reqs);
        assert_eq!(res.recorder.total_tokens(), expected, "handoff loses no tokens");
        assert_eq!(res.recorder.finished_requests(), 80);
        assert_eq!(res.per_replica.len(), 3);
        // a cheap-transfer split finishes sooner per request than an
        // expensive one — the handoff cost model is visible in the tail
        let mut cheap = split.clone();
        cheap.kv_transfer_s_per_token = 0.0;
        let fast = simulate_cluster(&cfg(DecisionMode::GpuEpilogue), &cheap, &reqs);
        assert!(
            fast.recorder.tpot_summary().max <= res.recorder.tpot_summary().max,
            "transfer cost must widen the worst handoff gap"
        );
    }

    #[test]
    fn cluster_fault_model_requeues_without_losing_tokens() {
        // DESIGN.md §10: a replica death mid-run loses capacity and adds a
        // recovery pause, never tokens — the simulated mirror of the
        // router's failover sweep.
        let reqs = requests(200, None);
        let expected: usize = reqs.iter().map(|r| r.output_len).sum();
        let mut scfg = cfg(DecisionMode::GpuEpilogue);
        scfg.slots = 32;
        let mut healthy = ClusterSimConfig::default();
        healthy.replicas = 3;
        let base = simulate_cluster(&scfg, &healthy, &reqs);
        let mut faulty = healthy.clone();
        // kill replica 1 halfway through the fault-free fleet makespan
        faulty.fail_at_s = Some(base.recorder.summary().duration * 0.5);
        faulty.fail_replica = 1;
        faulty.recovery_delay_s = 0.05;
        let res = simulate_cluster(&scfg, &faulty, &reqs);
        assert_eq!(res.recorder.total_tokens(), expected, "failover loses no tokens");
        assert_eq!(res.recorder.finished_requests(), 200);
        assert!(res.requeued > 0, "a mid-run death must orphan some requests");
        assert_eq!(res.recorder.recoveries(), 1);
        assert!(res.recorder.recovery_s() > 0.0);
        // lost capacity + recompute: the faulty fleet cannot finish sooner
        assert!(
            res.recorder.summary().duration >= base.recorder.summary().duration,
            "a death cannot speed the fleet up"
        );
    }

    #[test]
    fn preemption_recompute_costs_iterations() {
        let reqs = requests(80, None);
        let max_need: usize =
            reqs.iter().map(|r| r.prompt_len + r.output_len + 1).max().unwrap();
        let mut base = cfg(DecisionMode::GpuEpilogue);
        base.slots = 16;
        let unconstrained = simulate(&base, &reqs);
        let mut c = cfg(DecisionMode::GpuEpilogue);
        c.slots = 16;
        c.kv_capacity_tokens = max_need * 3;
        let tight = simulate(&c, &reqs);
        assert!(tight.preemptions > 0);
        // same trace, same slots: evictions add recompute + smaller batches,
        // so the constrained run needs at least as many iterations
        assert!(
            tight.iterations >= unconstrained.iterations,
            "recompute cannot shrink work: {} vs {}",
            tight.iterations,
            unconstrained.iterations
        );
    }
}
