//! Configuration: model specs, platform specs (Table 1), parallelism
//! degrees (Table 2), sampler-service settings, and JSON config loading
//! with CLI overrides.

pub mod model;
pub mod parallel;
pub mod platform;

pub use model::ModelSpec;
pub use parallel::ParallelConfig;
pub use platform::PlatformSpec;

use crate::util::argparse::Args;
use crate::util::json::Json;

/// Which decision-plane implementation the engine uses — the ablation ladder
/// of Figure 10 plus the simulated GPU-epilogue baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecisionVariant {
    /// Baseline: sampling as a GPU epilogue on the last PP stage (vLLM-like);
    /// cost modelled by the simulator, logits path identical.
    GpuEpilogue,
    /// Naive CPU port: full-V row-major scans, rebuilt tensors (§7.4 "vLLM CPU").
    NaiveCpu,
    /// Sequence-parallel, but full-V per-sequence work ("Parallel Sampling").
    Parallel,
    /// + column-wise penalties and truncation-first filtering ("Offloading").
    Offloading,
    /// + speculative hot-vocab sampling (full SIMPLE).
    Shvs,
}

impl DecisionVariant {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "gpu" | "gpu-epilogue" | "baseline" => Self::GpuEpilogue,
            "naive" | "naive-cpu" | "vllm-cpu" => Self::NaiveCpu,
            "parallel" => Self::Parallel,
            "offloading" | "offload" => Self::Offloading,
            "shvs" | "simple" => Self::Shvs,
            _ => return None,
        })
    }
    pub fn name(self) -> &'static str {
        match self {
            Self::GpuEpilogue => "gpu-epilogue",
            Self::NaiveCpu => "naive-cpu",
            Self::Parallel => "parallel",
            Self::Offloading => "offloading",
            Self::Shvs => "shvs",
        }
    }
    pub const ALL: [DecisionVariant; 5] = [
        Self::GpuEpilogue,
        Self::NaiveCpu,
        Self::Parallel,
        Self::Offloading,
        Self::Shvs,
    ];
}

/// Decision-plane service settings (§7.1: 16 samplers × 4 threads default).
#[derive(Debug, Clone)]
pub struct SamplerConfig {
    /// Number of sampler workers `m`.
    pub num_samplers: usize,
    /// Hot-vocab size H (0 = auto via the sizing model).
    pub hot_vocab: usize,
    /// Ring capacity (iterations in flight).
    pub ring_depth: usize,
    /// Fixed RNG seed for deterministic decisions.
    pub seed: u64,
    pub variant: DecisionVariant,
    /// Respawn crashed sampler workers and replay their owned state
    /// instead of failing the collect (DESIGN.md §10). Token streams are
    /// bit-identical either way; recovery trades a pause for survival.
    pub recovery: bool,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        SamplerConfig {
            num_samplers: 4,
            hot_vocab: 0,
            ring_depth: 4,
            seed: 0x5111_7713,
            variant: DecisionVariant::Shvs,
            recovery: true,
        }
    }
}

/// Top-level engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub model: ModelSpec,
    pub platform: PlatformSpec,
    pub parallel: ParallelConfig,
    pub sampler: SamplerConfig,
    /// Per-GPU microbatch size (paper default B=32 per GPU).
    pub batch_per_gpu: usize,
    /// Max model length for the row-append output buffer (L_max).
    pub max_seq_len: usize,
    /// KV block size in tokens (paged KV cache).
    pub kv_block_tokens: usize,
    /// Chunked-prefill token budget per scheduler iteration (0 = unlimited).
    /// Bounds how much prompt work runs alongside decode so admission
    /// bursts cannot inflate inter-token latency.
    pub prefill_token_budget: usize,
    /// KV-cache blocks available to the engine (0 = auto: enough for every
    /// slot to run to max_seq_len, which can never preempt). Setting a
    /// smaller pool over-commits the cache — production-style — and
    /// engages KV-pressure preemption with recompute-on-resume.
    pub kv_blocks: usize,
    /// Speculative-decoding window: draft tokens proposed per sequence per
    /// iteration (0 = off). The decision plane verifies the window with
    /// exact-distribution rejection (DESIGN.md §7); token streams are
    /// bit-identical to `spec_k = 0` for any k and sampler count.
    pub spec_k: usize,
    /// Radix prefix-cache reuse (DESIGN.md §13): publish finished prompt
    /// blocks into a token-keyed index, share the longest cached prefix on
    /// admission, and prefill only the uncached tail. On by default, but
    /// only engaged when the data plane can restore cached KV rows
    /// (`DataPlane::supports_prefix_restore`; the synthetic plane can, the
    /// PJRT path cannot yet). Changes timing only, never tokens.
    pub prefix_cache: bool,
    /// In-flight microbatches for the pipelined executor (DESIGN.md §8):
    /// the slot space is split into `n` interleaved microbatches so one
    /// microbatch's decisions can be sampled while another's forward runs.
    /// 1 = the synchronous engine (clamped to the batch size).
    pub n_microbatches: usize,
    /// Overlap the decision plane with forwards (asynchronous submit +
    /// two-phase commit). Off = block on decisions every iteration, even
    /// with multiple microbatches. Changes timing only, never tokens.
    pub overlap: bool,
    /// Idle-poll quantum in microseconds when no microbatch has runnable
    /// work (open-loop gaps between arrivals). The engine skips the sleep
    /// entirely when the next arrival is already due, and bounds it by the
    /// time until that arrival otherwise. 0 = busy-poll.
    pub idle_poll_us: u64,
    /// Chaos-injection schedule for the engine-level fault domains
    /// (sampler kills, incl. the legacy `poison@` syntax — now a clean
    /// worker kill — keyed by plan iteration; see
    /// [`crate::fault::FaultPlan`]). Empty = no injected faults. Replica
    /// kills live in `ClusterConfig::faults` instead.
    pub faults: crate::fault::FaultPlan,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            model: ModelSpec::tiny_e2e(),
            platform: PlatformSpec::h100(),
            parallel: ParallelConfig::new(1, 1),
            sampler: SamplerConfig::default(),
            batch_per_gpu: 32,
            max_seq_len: 2048,
            kv_block_tokens: 16,
            prefill_token_budget: 0,
            kv_blocks: 0,
            spec_k: 0,
            prefix_cache: true,
            n_microbatches: 1,
            overlap: false,
            idle_poll_us: 200,
            faults: crate::fault::FaultPlan::default(),
        }
    }
}

impl EngineConfig {
    /// Total microbatch size B = batch_per_gpu × (t·p).
    pub fn total_batch(&self) -> usize {
        self.batch_per_gpu * self.parallel.world_size()
    }

    /// Load overrides from a JSON object (config file), then CLI args.
    pub fn apply_json(&mut self, j: &Json) -> crate::Result<()> {
        if let Some(name) = j.get("model").as_str() {
            self.model = ModelSpec::by_name(name)
                .ok_or_else(|| anyhow::anyhow!("unknown model {name}"))?;
        }
        if let Some(name) = j.get("platform").as_str() {
            self.platform = PlatformSpec::by_name(name)
                .ok_or_else(|| anyhow::anyhow!("unknown platform {name}"))?;
        }
        if let Some(t) = j.get("tp").as_usize() {
            self.parallel.tp = t;
        }
        if let Some(p) = j.get("pp").as_usize() {
            self.parallel.pp = p;
        }
        if let Some(b) = j.get("batch_per_gpu").as_usize() {
            self.batch_per_gpu = b;
        }
        if let Some(m) = j.get("samplers").as_usize() {
            self.sampler.num_samplers = m;
        }
        if let Some(h) = j.get("hot_vocab").as_usize() {
            self.sampler.hot_vocab = h;
        }
        if let Some(s) = j.get("seed").as_f64() {
            self.sampler.seed = s as u64;
        }
        if let Some(v) = j.get("variant").as_str() {
            self.sampler.variant = DecisionVariant::parse(v)
                .ok_or_else(|| anyhow::anyhow!("unknown variant {v}"))?;
        }
        if let Some(l) = j.get("max_seq_len").as_usize() {
            self.max_seq_len = l;
        }
        if let Some(p) = j.get("prefill_budget").as_usize() {
            self.prefill_token_budget = p;
        }
        if let Some(k) = j.get("kv_blocks").as_usize() {
            self.kv_blocks = k;
        }
        if let Some(k) = j.get("spec_k").as_usize() {
            self.spec_k = k;
        }
        // accept both a JSON bool and the CLI's numeric 0/1
        if let Some(p) = j.get("prefix_cache").as_bool() {
            self.prefix_cache = p;
        } else if let Some(p) = j.get("prefix_cache").as_f64() {
            self.prefix_cache = p != 0.0;
        }
        if let Some(n) = j.get("n_microbatches").as_usize() {
            self.n_microbatches = n.max(1);
        }
        // accept both a JSON bool and the CLI's numeric 0/1
        if let Some(o) = j.get("overlap").as_bool() {
            self.overlap = o;
        } else if let Some(o) = j.get("overlap").as_f64() {
            self.overlap = o != 0.0;
        }
        if let Some(u) = j.get("idle_poll_us").as_usize() {
            self.idle_poll_us = u as u64;
        }
        Ok(())
    }

    /// Apply CLI overrides (same keys as JSON).
    pub fn apply_args(&mut self, args: &Args) -> crate::Result<()> {
        let mut obj = std::collections::BTreeMap::new();
        for key in ["model", "platform", "variant"] {
            if let Some(v) = args.get(key) {
                obj.insert(key.to_string(), Json::Str(v.to_string()));
            }
        }
        for key in [
            "tp",
            "pp",
            "batch_per_gpu",
            "samplers",
            "hot_vocab",
            "seed",
            "max_seq_len",
            "prefill_budget",
            "kv_blocks",
            "spec_k",
            "prefix_cache",
            "n_microbatches",
            "idle_poll_us",
        ] {
            if let Some(v) = args.get(key) {
                let n: f64 = v
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--{key} expects a number, got {v}"))?;
                obj.insert(key.to_string(), Json::Num(n));
            }
        }
        self.apply_json(&Json::Obj(obj))?;
        // `--chaos <spec>` carries the whole fault plan; the engine keeps
        // its own fault domains (sampler kills, incl. legacy poisons) and
        // the router-side split is picked up by `ClusterConfig::apply_args`.
        if let Some(spec) = args.get("chaos") {
            let (engine_faults, _router) = crate::fault::FaultPlan::parse(spec)?.split();
            self.faults = engine_faults;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::argparse::{Args, OptSpec};

    #[test]
    fn variant_parse_roundtrip() {
        for v in DecisionVariant::ALL {
            assert_eq!(DecisionVariant::parse(v.name()), Some(v));
        }
        assert_eq!(DecisionVariant::parse("simple"), Some(DecisionVariant::Shvs));
        assert_eq!(DecisionVariant::parse("bogus"), None);
    }

    #[test]
    fn json_overrides_apply() {
        let mut cfg = EngineConfig::default();
        let j = Json::parse(
            r#"{"model": "qwen2.5-72b", "platform": "l40", "tp": 4, "pp": 2,
                "batch_per_gpu": 16, "samplers": 8, "variant": "offloading"}"#,
        )
        .unwrap();
        cfg.apply_json(&j).unwrap();
        assert_eq!(cfg.model.name, "qwen2.5-72b");
        assert_eq!(cfg.platform.name, "l40");
        assert_eq!(cfg.parallel.tp, 4);
        assert_eq!(cfg.parallel.pp, 2);
        assert_eq!(cfg.total_batch(), 16 * 8);
        assert_eq!(cfg.sampler.variant, DecisionVariant::Offloading);
    }

    #[test]
    fn spec_k_override_applies() {
        let mut cfg = EngineConfig::default();
        assert_eq!(cfg.spec_k, 0, "speculation is opt-in");
        cfg.apply_json(&Json::parse(r#"{"spec_k": 4}"#).unwrap()).unwrap();
        assert_eq!(cfg.spec_k, 4);
    }

    #[test]
    fn prefix_cache_override_applies() {
        let mut cfg = EngineConfig::default();
        assert!(cfg.prefix_cache, "prefix reuse is on by default");
        cfg.apply_json(&Json::parse(r#"{"prefix_cache": 0}"#).unwrap()).unwrap();
        assert!(!cfg.prefix_cache, "CLI numeric form disables it");
        cfg.apply_json(&Json::parse(r#"{"prefix_cache": true}"#).unwrap()).unwrap();
        assert!(cfg.prefix_cache);
    }

    #[test]
    fn pipelining_overrides_apply() {
        let mut cfg = EngineConfig::default();
        assert_eq!(cfg.n_microbatches, 1, "pipelining is opt-in");
        assert!(!cfg.overlap);
        assert_eq!(cfg.idle_poll_us, 200, "seed-compatible idle poll");
        let j = Json::parse(
            r#"{"n_microbatches": 2, "overlap": true, "idle_poll_us": 50}"#,
        )
        .unwrap();
        cfg.apply_json(&j).unwrap();
        assert_eq!(cfg.n_microbatches, 2);
        assert!(cfg.overlap);
        assert_eq!(cfg.idle_poll_us, 50);
        // the CLI's numeric form of the flag also works
        cfg.apply_json(&Json::parse(r#"{"overlap": 0}"#).unwrap()).unwrap();
        assert!(!cfg.overlap);
    }

    #[test]
    fn unknown_model_errors() {
        let mut cfg = EngineConfig::default();
        let j = Json::parse(r#"{"model": "nope"}"#).unwrap();
        assert!(cfg.apply_json(&j).is_err());
    }

    #[test]
    fn args_override() {
        let mut cfg = EngineConfig::default();
        let argv: Vec<String> = ["p", "--tp", "8", "--variant", "shvs"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let specs = [OptSpec::value("tp", ""), OptSpec::value("variant", "")];
        let args = Args::parse(&argv, &specs, false).unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.parallel.tp, 8);
    }
}
