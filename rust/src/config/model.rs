//! Model specifications.
//!
//! Two kinds:
//! - The paper's evaluation models (Table 2) — used by the distributed
//!   timing simulator with their real architecture numbers (layers, hidden,
//!   vocab, MoE activation) to produce per-stage compute times.
//! - `tiny_e2e` — the real ~30M-parameter transformer we AOT-compile and
//!   actually execute through PJRT for the end-to-end example.

/// Architecture description sufficient for FLOPs/bytes accounting and for
/// the AOT-compiled tiny model.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: &'static str,
    pub layers: usize,
    pub hidden: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub ffn_hidden: usize,
    /// Vocabulary size V — the axis the paper's analysis revolves around.
    pub vocab: usize,
    /// For MoE models: active parameter fraction per token (1.0 = dense).
    pub active_frac: f64,
    /// Total parameter count (billions) for memory/GEMM accounting.
    pub params_b: f64,
    /// Zipf exponent shaping this model's next-token distribution (traces);
    /// drives the synthetic-logits substrate and ᾱ(H) curves.
    pub zipf_s: f64,
}

impl ModelSpec {
    /// The small model actually served end-to-end via PJRT on this host.
    pub fn tiny_e2e() -> ModelSpec {
        ModelSpec {
            name: "tiny-30m",
            layers: 4,
            hidden: 256,
            heads: 8,
            kv_heads: 8,
            ffn_hidden: 1024,
            vocab: 32_000,
            active_frac: 1.0,
            params_b: 0.030,
            zipf_s: 1.05,
        }
    }

    /// An even smaller model for unit/integration tests (fast AOT + run).
    pub fn micro_test() -> ModelSpec {
        ModelSpec {
            name: "micro-test",
            layers: 2,
            hidden: 64,
            heads: 4,
            kv_heads: 4,
            ffn_hidden: 128,
            vocab: 1_000,
            active_frac: 1.0,
            params_b: 0.001,
            zipf_s: 1.1,
        }
    }

    // ---- Paper evaluation models (Table 2) ----

    pub fn qwq_32b() -> ModelSpec {
        ModelSpec {
            name: "qwq-32b",
            layers: 64,
            hidden: 5120,
            heads: 40,
            kv_heads: 8,
            ffn_hidden: 27648,
            vocab: 152_064,
            active_frac: 1.0,
            params_b: 32.5,
            zipf_s: 1.08,
        }
    }

    pub fn llama31_70b() -> ModelSpec {
        ModelSpec {
            name: "llama-3.1-70b",
            layers: 80,
            hidden: 8192,
            heads: 64,
            kv_heads: 8,
            ffn_hidden: 28672,
            vocab: 128_256,
            active_frac: 1.0,
            params_b: 70.6,
            zipf_s: 1.10,
        }
    }

    pub fn qwen25_72b() -> ModelSpec {
        ModelSpec {
            name: "qwen2.5-72b",
            layers: 80,
            hidden: 8192,
            heads: 64,
            kv_heads: 8,
            ffn_hidden: 29568,
            vocab: 152_064,
            active_frac: 1.0,
            params_b: 72.7,
            zipf_s: 1.07,
        }
    }

    pub fn qwen3_235b_a22b() -> ModelSpec {
        ModelSpec {
            name: "qwen3-235b-a22b",
            layers: 94,
            hidden: 4096,
            heads: 64,
            kv_heads: 4,
            ffn_hidden: 12288,
            vocab: 151_936,
            active_frac: 22.0 / 235.0,
            params_b: 235.0,
            zipf_s: 1.05,
        }
    }

    pub fn deepseek_v3() -> ModelSpec {
        ModelSpec {
            name: "deepseek-v3",
            layers: 61,
            hidden: 7168,
            heads: 128,
            kv_heads: 128,
            ffn_hidden: 18432,
            vocab: 129_280,
            active_frac: 37.0 / 671.0,
            params_b: 671.0,
            zipf_s: 1.06,
        }
    }

    pub fn qwen3_coder_480b() -> ModelSpec {
        ModelSpec {
            name: "qwen3-coder-480b-a35b",
            layers: 62,
            hidden: 6144,
            heads: 96,
            kv_heads: 8,
            ffn_hidden: 25600,
            vocab: 151_936,
            active_frac: 35.0 / 480.0,
            params_b: 480.0,
            zipf_s: 1.04,
        }
    }

    /// All paper evaluation models.
    pub fn paper_models() -> Vec<ModelSpec> {
        vec![
            Self::qwq_32b(),
            Self::llama31_70b(),
            Self::qwen25_72b(),
            Self::qwen3_235b_a22b(),
            Self::deepseek_v3(),
            Self::qwen3_coder_480b(),
        ]
    }

    pub fn by_name(name: &str) -> Option<ModelSpec> {
        let all = [
            Self::tiny_e2e(),
            Self::micro_test(),
            Self::qwq_32b(),
            Self::llama31_70b(),
            Self::qwen25_72b(),
            Self::qwen3_235b_a22b(),
            Self::deepseek_v3(),
            Self::qwen3_coder_480b(),
        ];
        all.into_iter()
            .find(|m| m.name.eq_ignore_ascii_case(name))
    }

    /// Active parameters per token (for decode GEMM flops), in units of
    /// parameters.
    pub fn active_params(&self) -> f64 {
        self.params_b * 1e9 * self.active_frac
    }

    /// Per-token decode FLOPs ≈ 2 × active params (multiply+add per weight).
    pub fn decode_flops_per_token(&self) -> f64 {
        2.0 * self.active_params()
    }

    /// Head dimension.
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    /// KV bytes per token (bf16): 2 bytes × 2 (K and V) × layers × kv_heads × head_dim.
    pub fn kv_bytes_per_token(&self) -> f64 {
        (2 * 2 * self.layers * self.kv_heads * self.head_dim()) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name_case_insensitive() {
        assert_eq!(ModelSpec::by_name("QwQ-32B").unwrap().name, "qwq-32b");
        assert!(ModelSpec::by_name("missing").is_none());
    }

    #[test]
    fn paper_models_have_large_vocabs() {
        // §2.3: the trend SIMPLE targets — every evaluated model has V ≥ 128k.
        for m in ModelSpec::paper_models() {
            assert!(m.vocab >= 128_000, "{} vocab {}", m.name, m.vocab);
        }
    }

    #[test]
    fn moe_activation_reduces_decode_flops() {
        let dense = ModelSpec::qwen25_72b();
        let moe = ModelSpec::qwen3_235b_a22b();
        // 235B MoE activates ~22B — fewer decode FLOPs than dense 72B.
        assert!(moe.decode_flops_per_token() < dense.decode_flops_per_token());
    }

    #[test]
    fn head_dim_divides() {
        for m in ModelSpec::paper_models() {
            assert_eq!(m.hidden % m.heads, 0, "{}", m.name);
        }
    }

    #[test]
    fn kv_bytes_positive_and_sane() {
        let m = ModelSpec::llama31_70b();
        // GQA: 8 kv heads × 128 head_dim × 80 layers × 4 bytes = 327,680 B/token
        assert_eq!(m.kv_bytes_per_token(), 327_680.0);
    }
}
