//! Platform specifications (paper Table 1) used by the distributed timing
//! simulator. Numbers are public datasheet values; the simulator cares about
//! *ratios* (compute vs bandwidth vs interconnect), which is what shapes the
//! paper's figures.

/// One GPU-node platform.
#[derive(Debug, Clone, PartialEq)]
pub struct PlatformSpec {
    pub name: &'static str,
    pub gpus_per_node: usize,
    /// Dense bf16 TFLOPs per GPU (no sparsity).
    pub tflops_bf16: f64,
    /// HBM bandwidth per GPU, GB/s.
    pub hbm_gbps: f64,
    /// GPU memory per device, GB.
    pub gpu_mem_gb: f64,
    /// Intra-node interconnect bandwidth per GPU, GB/s (NVLink or PCIe).
    pub intra_gbps: f64,
    /// Intra-node per-message latency, µs.
    pub intra_lat_us: f64,
    /// Inter-node network bandwidth per host, GB/s.
    pub inter_gbps: f64,
    /// Inter-node per-message latency, µs.
    pub inter_lat_us: f64,
    /// Host CPU cores (Table 1).
    pub cpu_cores: usize,
    /// Host memory, GB.
    pub host_mem_gb: f64,
    /// Host memory bandwidth, GB/s (per socket aggregate) — bounds the
    /// CPU decision plane's O(V) scans.
    pub host_bw_gbps: f64,
}

impl PlatformSpec {
    /// L40 node: PCIe 4.0 intra-node, 200 Gbps network, 128 Xeon 8358 cores.
    pub fn l40() -> PlatformSpec {
        PlatformSpec {
            name: "l40",
            gpus_per_node: 8,
            tflops_bf16: 90.5,
            hbm_gbps: 864.0,
            gpu_mem_gb: 48.0,
            intra_gbps: 32.0, // PCIe 4.0 x16
            intra_lat_us: 10.0,
            inter_gbps: 25.0, // 200 Gbps
            inter_lat_us: 15.0,
            cpu_cores: 128,
            host_mem_gb: 2048.0,
            host_bw_gbps: 400.0,
        }
    }

    /// H100 node: NVLink, 8×400 Gbps, 192 Xeon 8468 cores.
    pub fn h100() -> PlatformSpec {
        PlatformSpec {
            name: "h100",
            gpus_per_node: 8,
            tflops_bf16: 989.0,
            hbm_gbps: 3350.0,
            gpu_mem_gb: 80.0,
            intra_gbps: 450.0, // NVLink 4
            intra_lat_us: 3.0,
            inter_gbps: 400.0, // 8×400 Gbps aggregate
            inter_lat_us: 8.0,
            cpu_cores: 192,
            host_mem_gb: 2048.0,
            host_bw_gbps: 600.0,
        }
    }

    /// B200 node: NVLink 5, 8×400 Gbps, 256 Xeon 6767P cores.
    pub fn b200() -> PlatformSpec {
        PlatformSpec {
            name: "b200",
            gpus_per_node: 8,
            tflops_bf16: 2250.0,
            hbm_gbps: 8000.0,
            gpu_mem_gb: 180.0,
            intra_gbps: 900.0, // NVLink 5
            intra_lat_us: 2.0,
            inter_gbps: 400.0,
            inter_lat_us: 8.0,
            cpu_cores: 256,
            host_mem_gb: 2048.0,
            host_bw_gbps: 800.0,
        }
    }

    pub fn by_name(name: &str) -> Option<PlatformSpec> {
        match name.to_ascii_lowercase().as_str() {
            "l40" => Some(Self::l40()),
            "h100" => Some(Self::h100()),
            "b200" => Some(Self::b200()),
            _ => None,
        }
    }

    pub fn all() -> Vec<PlatformSpec> {
        vec![Self::l40(), Self::h100(), Self::b200()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        assert_eq!(PlatformSpec::by_name("H100").unwrap().name, "h100");
        assert!(PlatformSpec::by_name("a100").is_none());
    }

    #[test]
    fn generations_get_faster() {
        // The Amdahl-drift premise: each generation accelerates the data
        // plane (FLOPs and HBM), which *grows* the sampling fraction.
        let (l40, h100, b200) = (PlatformSpec::l40(), PlatformSpec::h100(), PlatformSpec::b200());
        assert!(l40.tflops_bf16 < h100.tflops_bf16);
        assert!(h100.tflops_bf16 < b200.tflops_bf16);
        assert!(l40.hbm_gbps < h100.hbm_gbps);
        assert!(h100.hbm_gbps < b200.hbm_gbps);
    }

    #[test]
    fn l40_is_pcie_era() {
        // §7.3 attributes L40's easier overlap to its slower data plane.
        let l40 = PlatformSpec::l40();
        let h100 = PlatformSpec::h100();
        assert!(l40.intra_gbps < h100.intra_gbps / 5.0);
    }

    #[test]
    fn table1_memory_sizes() {
        assert_eq!(PlatformSpec::l40().gpu_mem_gb, 48.0);
        assert_eq!(PlatformSpec::h100().gpu_mem_gb, 80.0);
        assert_eq!(PlatformSpec::b200().gpu_mem_gb, 180.0);
    }
}
