//! Tensor/pipeline parallelism configuration (paper Table 2 presets).

use super::{ModelSpec, PlatformSpec};

/// TP degree t × PP depth p.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParallelConfig {
    pub tp: usize,
    pub pp: usize,
}

impl ParallelConfig {
    pub fn new(tp: usize, pp: usize) -> Self {
        assert!(tp >= 1 && pp >= 1);
        ParallelConfig { tp, pp }
    }

    /// Total GPUs t·p.
    pub fn world_size(&self) -> usize {
        self.tp * self.pp
    }

    /// Whether this deployment spans hosts on the given platform.
    pub fn is_multi_host(&self, platform: &PlatformSpec) -> bool {
        self.world_size() > platform.gpus_per_node
    }

    /// Paper Table 2: the TP/PP degrees per (model, platform); `None` where
    /// the table shows "—" (too large, or fits a single GPU).
    pub fn paper_preset(model: &ModelSpec, platform: &PlatformSpec) -> Option<ParallelConfig> {
        let cfg = match (model.name, platform.name) {
            ("qwq-32b", "l40") => (4, 1),
            ("llama-3.1-70b", "l40") | ("llama-3.1-70b", "h100") => (4, 2),
            ("qwen2.5-72b", "l40") | ("qwen2.5-72b", "h100") => (4, 2),
            ("qwen3-235b-a22b", "l40") | ("qwen3-235b-a22b", "h100") => (4, 4),
            ("qwen3-235b-a22b", "b200") => (4, 2),
            ("deepseek-v3", "h100") => (4, 4),
            ("deepseek-v3", "b200") => (4, 2),
            ("qwen3-coder-480b-a35b", "b200") => (4, 2),
            _ => return None,
        };
        Some(ParallelConfig::new(cfg.0, cfg.1))
    }

    /// All (model, preset) pairs evaluated on a platform — the x-axis of
    /// Figure 3's per-platform panels.
    pub fn paper_matrix(platform: &PlatformSpec) -> Vec<(ModelSpec, ParallelConfig)> {
        ModelSpec::paper_models()
            .into_iter()
            .filter_map(|m| Self::paper_preset(&m, platform).map(|p| (m, p)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn world_size() {
        assert_eq!(ParallelConfig::new(4, 2).world_size(), 8);
    }

    #[test]
    fn paper_presets_match_table2() {
        let l40 = PlatformSpec::l40();
        let h100 = PlatformSpec::h100();
        let b200 = PlatformSpec::b200();
        assert_eq!(
            ParallelConfig::paper_preset(&ModelSpec::qwq_32b(), &l40),
            Some(ParallelConfig::new(4, 1))
        );
        // QwQ-32B not evaluated on H100/B200 (single-GPU there).
        assert_eq!(ParallelConfig::paper_preset(&ModelSpec::qwq_32b(), &h100), None);
        assert_eq!(
            ParallelConfig::paper_preset(&ModelSpec::qwen3_235b_a22b(), &l40),
            Some(ParallelConfig::new(4, 4))
        );
        assert_eq!(
            ParallelConfig::paper_preset(&ModelSpec::deepseek_v3(), &b200),
            Some(ParallelConfig::new(4, 2))
        );
        // DeepSeek V3 too large for L40 (>16 GPUs)
        assert_eq!(ParallelConfig::paper_preset(&ModelSpec::deepseek_v3(), &l40), None);
    }

    #[test]
    fn matrix_per_platform_counts() {
        // Table 2: L40 evaluates 4 models, H100 4 models, B200 3 models.
        assert_eq!(ParallelConfig::paper_matrix(&PlatformSpec::l40()).len(), 4);
        assert_eq!(ParallelConfig::paper_matrix(&PlatformSpec::h100()).len(), 4);
        assert_eq!(ParallelConfig::paper_matrix(&PlatformSpec::b200()).len(), 3);
    }

    #[test]
    fn multi_host_detection() {
        let h100 = PlatformSpec::h100();
        assert!(!ParallelConfig::new(4, 2).is_multi_host(&h100)); // 8 = one node
        assert!(ParallelConfig::new(4, 4).is_multi_host(&h100)); // 16 = two nodes
    }
}
