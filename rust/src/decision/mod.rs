//! The decision plane — SIMPLE's core contribution.
//!
//! Modules map one-to-one onto the paper's §5:
//! - [`service`] — sequence-parallel sampler service over shared-memory
//!   rings (§5.1, §4.2).
//! - [`penalties`] — column-wise, incrementally updated penalty state (§5.2).
//! - [`filter`] — truncation-first top-k/top-p/min-p with index maps (§5.2).
//! - [`kernels`] — lane-vectorized single-pass dense kernels with runtime
//!   scalar/SIMD dispatch and a bit-identical-streams contract (§5.2).
//! - [`shvs`] — speculative hot-vocab sampling with rejection-correctness
//!   (§5.3); [`hotvocab`] builds the hot set, [`sizing`] chooses H* (§5.4).
//! - [`pipeline`] — the per-sequence decision pipeline with the §7.4
//!   ablation ladder (naive CPU → parallel → offloading → SHVS).
//! - [`controller`] — online QoS-aware H adaptation (§9 future work i).
//! - [`grammar`] — grammar-constrained decoding masks (§9 future work iii).
//! - [`draft`], [`verify`] — speculative decoding in the decision plane
//!   (§9, DESIGN.md §7): a deterministic self-drafting proposer and batched
//!   rejection verification with exact-distribution commits and
//!   roll-forward/rollback of the per-sequence state.
//! - [`params`], [`softmax`], [`categorical`] — sampling controls, stable
//!   softmax, and deterministic pre-generated variates (§5.1).
//! - [`seqrec`], [`slots`] — the lock-free substrate of the shared sampler
//!   pool (DESIGN.md §11): per-sequence replay records and the in-flight
//!   task slot table with quiescent-state reclamation.

pub mod categorical;
pub mod controller;
pub mod draft;
pub mod filter;
pub mod grammar;
pub mod hotvocab;
pub mod kernels;
pub mod params;
pub mod penalties;
pub mod pipeline;
pub mod seqrec;
pub mod service;
pub mod shvs;
pub mod sizing;
pub mod slots;
pub mod softmax;
pub mod verify;

pub use controller::{ControllerConfig, HotVocabController};
pub use draft::DraftProposer;
pub use grammar::GrammarConstraint;
pub use hotvocab::HotVocab;
pub use kernels::{DenseKernel, KernelBackend};
pub use params::SamplingParams;
pub use pipeline::DecisionPipeline;
pub use seqrec::{SeqHandle, SeqRec};
pub use service::{ColumnMeta, DecisionBatch, IterationTask, SamplerService};
pub use shvs::{Decision, Precompute, ShvsSampler};
pub use sizing::SizingModel;
pub use verify::{verify_window, Verdict};
