//! Speculative hot-vocab sampling with rejection-correctness (§5.3).
//!
//! Split the support into the hot set `H` and tail `V\H`. Compute stable
//! weights `w_v = exp((z'_v − z_max)/τ)` (Eq. 6); the hot mass is
//! `α = S_H / (S_H + S_tail)` (Eq. 7). Draw a hot candidate `ŷ ∼ q ∝ w|_H`
//! and accept it iff `u ≤ α`; on rejection draw from the tail proposal
//! `r ∝ w|_{V\H}` (Eq. 8). Since `p̃_v/q_v = α` on `H`, the composite is
//! exact rejection sampling with envelope M = 1 (Eq. 9) — distributionally
//! identical to full-vocabulary sampling, at O(H) common-case cost.
//!
//! **GPU precompute.** `z_max`, `S_tail`, and the tail max weight are
//! produced where the logits are written (the L1 Pallas kernel outputs
//! them; [`Precompute::reference`] is the CPU oracle). The CPU sampler
//! adjusts them *incrementally* for the few penalty-touched ids, so no
//! O(V) pass happens on the fast path.
//!
//! **Filters.** When top-k/top-p/min-p are enabled, the fast path runs the
//! truncation-first chain on the hot candidates and proves, via a
//! *containment certificate* against the (adjusted) tail max weight, that
//! the globally filtered set lies entirely inside `H`; if the certificate
//! fails (rare: a tail token could enter the filtered set), it falls back
//! to the exact full-vocabulary slow path. Either way the output
//! distribution equals the full-vocabulary sampler's.

use super::categorical::{draw_index, draw_token};
use super::filter::{apply_allow_list, truncate, Truncated};
use super::hotvocab::HotVocab;
use super::params::SamplingParams;
use super::penalties::{penalized_logit_at, SeqHistory};
use crate::tensor::ShardedLogits;
use std::sync::Arc;

/// Per-sequence GPU-side precompute at temperature τ (pre-penalty).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Precompute {
    /// max_v z_v over the full vocabulary (stable-softmax shift).
    pub z_max: f32,
    /// Σ_{v∉H} exp((z_v − z_max)/τ).
    pub tail_sum: f64,
    /// max_{v∉H} exp((z_v − z_max)/τ) — the certificate bound.
    pub tail_max_w: f64,
}

impl Precompute {
    /// CPU reference implementation of the GPU precompute — one O(V) pass.
    /// The real system gets these numbers from the L1 kernel's outputs.
    pub fn reference(view: &ShardedLogits, b: usize, hot: &HotVocab, tau: f32) -> Precompute {
        let mut z_max = f32::NEG_INFINITY;
        view.for_each_logit(b, |_, z| z_max = z_max.max(z));
        let inv = 1.0 / tau.max(1e-6) as f64;
        let mut tail_sum = 0.0f64;
        let mut tail_max_w = 0.0f64;
        view.for_each_logit(b, |v, z| {
            if !hot.contains(v as u32) {
                let w = (((z - z_max) as f64) * inv).exp();
                tail_sum += w;
                if w > tail_max_w {
                    tail_max_w = w;
                }
            }
        });
        Precompute { z_max, tail_sum, tail_max_w }
    }
}

/// Outcome of one SHVS decision, with the observability the paper exposes
/// (acceptance α, fast/slow path) for tuning H.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Decision {
    pub token: u32,
    /// Hot-vocab mass α_b (or filtered-certificate pseudo-α = 1.0).
    pub alpha: f64,
    /// True if the decision completed without an O(V) pass.
    pub fast_path: bool,
    /// True if the rejection test accepted the hot candidate (unfiltered
    /// path) or the containment certificate held (filtered path).
    pub accepted: bool,
}

/// Reusable SHVS sampler (per sampler thread; owns scratch buffers).
pub struct ShvsSampler {
    hot: Arc<HotVocab>,
    // scratch, reused across sequences to avoid hot-loop allocation
    hot_logits: Vec<f32>,
    hot_pairs: Vec<(u32, f32)>,
}

impl ShvsSampler {
    pub fn new(hot: Arc<HotVocab>) -> Self {
        let h = hot.len();
        ShvsSampler {
            hot,
            hot_logits: Vec::with_capacity(h),
            hot_pairs: Vec::with_capacity(h),
        }
    }

    pub fn hot_vocab(&self) -> &Arc<HotVocab> {
        &self.hot
    }

    /// Decide the next token for sequence `b`.
    ///
    /// `uniforms = (u_select, u_accept, u_fallback)` — pre-generated per
    /// (sequence, iteration) so the outcome is sampler-assignment-invariant.
    pub fn decide(
        &mut self,
        view: &ShardedLogits,
        b: usize,
        hist: &SeqHistory,
        params: &SamplingParams,
        pre: &Precompute,
        uniforms: (f64, f64, f64),
    ) -> Decision {
        let (u_select, u_accept, u_fallback) = uniforms;

        // Greedy and allow-list requests skip speculation: greedy argmax
        // needs the global max (certificate rarely provable cheaply), and
        // allow-lists are usually tiny — both go straight to the exact path.
        if params.is_greedy() || params.allowed_tokens.is_some() {
            let token = slow_path_token(view, b, hist, params, u_fallback);
            return Decision { token, alpha: 1.0, fast_path: false, accepted: false };
        }

        let tau = params.temperature;
        let inv_tau = 1.0 / tau as f64;

        // ---- O(H) hot scan: gather raw hot logits (zero-copy view reads).
        view.gather(b, self.hot.ids(), &mut self.hot_logits);

        // Penalty-adjusted tail statistics, updated incrementally: only the
        // penalty-touched tail ids change (the column-wise trick of §5.2
        // applied to the SHVS sums).
        let mut tail_sum = pre.tail_sum;
        let mut tail_max_w = pre.tail_max_w;
        let penalties_active = params.has_penalties() || !params.logit_bias.is_empty();
        if penalties_active {
            for (id, _) in hist.penalized_ids() {
                if (id as usize) < view.vocab() && !self.hot.contains(id) {
                    let raw = view.get(id as usize, b);
                    let w_old = (((raw - pre.z_max) as f64) * inv_tau).exp();
                    let adj = penalized_logit_at(raw, id, hist, params);
                    let w_new = (((adj - pre.z_max) as f64) * inv_tau).exp();
                    tail_sum += w_new - w_old;
                    if w_new > tail_max_w {
                        tail_max_w = w_new; // may only grow stale-conservative
                    }
                }
            }
            // logit-bias-only ids (not in history) also shift tail weights
            for (&id, _) in &params.logit_bias {
                if !hist.seen(id) && (id as usize) < view.vocab() && !self.hot.contains(id) {
                    let raw = view.get(id as usize, b);
                    let w_old = (((raw - pre.z_max) as f64) * inv_tau).exp();
                    let adj = penalized_logit_at(raw, id, hist, params);
                    let w_new = (((adj - pre.z_max) as f64) * inv_tau).exp();
                    tail_sum += w_new - w_old;
                    if w_new > tail_max_w {
                        tail_max_w = w_new;
                    }
                }
            }
            tail_sum = tail_sum.max(0.0);
        }

        // Penalize hot candidates in place: patch only the touched ids by
        // binary search into the sorted hot id list — O(H + P·log H)
        // instead of O(H) hash probes. `hot_logits` is the working copy.
        let hot_ids = self.hot.ids();
        if penalties_active {
            for (id, _) in hist.penalized_ids() {
                if let Ok(i) = hot_ids.binary_search(&id) {
                    let raw = self.hot_logits[i];
                    self.hot_logits[i] = penalized_logit_at(raw, id, hist, params);
                }
            }
            for (&id, _) in &params.logit_bias {
                if !hist.seen(id) {
                    if let Ok(i) = hot_ids.binary_search(&id) {
                        let raw = self.hot_logits[i];
                        self.hot_logits[i] = penalized_logit_at(raw, id, hist, params);
                    }
                }
            }
        }

        if params.has_filter() {
            // Materialize (id, logit) pairs only for the filtered machinery.
            self.hot_pairs.clear();
            for (&id, &z) in hot_ids.iter().zip(self.hot_logits.iter()) {
                self.hot_pairs.push((id, z));
            }
            // ---- Filtered fast path with containment certificate.
            //
            // Case 1 — top-k enabled: if the k-th largest *hot* logit
            // outranks every tail token (bounded by tail_max_w), the global
            // top-k is exactly the hot top-k; the rest of the chain (top-p,
            // min-p) then operates on identical survivor sets globally and
            // hot-locally, so the hot-filtered draw is exact.
            if params.top_k > 0 && params.top_k < self.hot_pairs.len() {
                super::filter::select_top_k(&mut self.hot_pairs, params.top_k);
                let kth_logit = self.hot_pairs[..params.top_k]
                    .iter()
                    .map(|&(_, z)| z)
                    .fold(f32::INFINITY, f32::min);
                let kth_w = (((kth_logit - pre.z_max) as f64) * inv_tau).exp();
                if kth_w >= tail_max_w {
                    // select_top_k already partitioned the global top-k into
                    // the prefix; truncate just that (top-k disabled) instead
                    // of re-selecting over the whole hot set.
                    let survivors = self.hot_pairs[..params.top_k].to_vec();
                    let rest = SamplingParams { top_k: 0, ..params.clone() };
                    let truncated = truncate(survivors, &rest);
                    let token = draw_token(&truncated, u_select);
                    self.hot_pairs.clear();
                    return Decision { token, alpha: 1.0, fast_path: true, accepted: true };
                }
            } else {
                // Case 2 — no top-k: prove the nucleus/min-p set lies in H
                // against the global masses.
                let truncated = truncate(self.hot_pairs.clone(), params);
                let certificate = filtered_set_certificate(
                    &truncated,
                    pre.z_max,
                    inv_tau,
                    tail_max_w,
                    tail_sum,
                    params,
                );
                if certificate {
                    let token = draw_token(&truncated, u_select);
                    self.hot_pairs.clear();
                    return Decision { token, alpha: 1.0, fast_path: true, accepted: true };
                }
            }
            // Certificate failed: exact O(V) slow path.
            self.hot_pairs.clear();
            let token = slow_path_token(view, b, hist, params, u_fallback);
            return Decision { token, alpha: 0.0, fast_path: false, accepted: false };
        }

        // ---- Unfiltered path: classic SHVS rejection sampling (Eq. 8–9).
        // Hot weights + hot sum in one fused pass straight over the gathered
        // logits (no (id, logit) tuple materialization).
        let z_max = pre.z_max;
        let mut hot_w: Vec<f64> = Vec::with_capacity(self.hot_logits.len());
        let mut hot_sum = 0.0f64;
        for &z in &self.hot_logits {
            let w = (((z - z_max) as f64) * inv_tau).exp();
            hot_w.push(w);
            hot_sum += w;
        }
        let total = hot_sum + tail_sum;
        let alpha = if total > 0.0 { hot_sum / total } else { 0.0 };

        if u_accept <= alpha {
            // Accept: draw ŷ ∼ q over the hot set.
            let i = draw_index(&hot_w, hot_sum, u_select);
            let token = hot_ids[i];
            return Decision { token, alpha, fast_path: true, accepted: true };
        }

        // Reject: draw y′ ∼ r over the tail — one O(V−H) streaming pass.
        let token = tail_draw(
            view,
            b,
            &self.hot,
            hist,
            params,
            pre.z_max,
            inv_tau,
            tail_sum,
            u_fallback,
            penalties_active,
        );
        Decision { token, alpha, fast_path: false, accepted: false }
    }
}

/// Certificate that the filtered-on-hot set equals the filtered-on-V set.
///
/// Every member of the truncated hot set has weight ≥ the max tail weight
/// ⇒ in the global weight order, all members precede every tail token.
/// - top-k: the global top-k is then exactly these k members.
/// - top-p: the nucleus threshold must additionally be met against the
///   *global* sum (hot members' mass ≥ p·(S_kept + S_tail)); since all kept
///   members outrank all tail tokens, the global nucleus is the same prefix.
/// - min-p: no tail token may pass the min-p cut: tail_max_w < min_p·w_max.
fn filtered_set_certificate(
    truncated: &Truncated,
    _z_max: f32,
    _inv_tau: f64,
    tail_max_w: f64,
    tail_sum: f64,
    params: &SamplingParams,
) -> bool {
    if truncated.is_empty() {
        return false;
    }
    let min_kept_w = truncated.weights.iter().cloned().fold(f64::INFINITY, f64::min);
    // All kept hot tokens must dominate every tail token.
    if min_kept_w < tail_max_w {
        return false;
    }
    // top-p: the kept mass must satisfy the nucleus condition globally.
    if params.top_p < 1.0 {
        // Global candidate mass (pre-top-p, post-top-k) ≥ kept + tail; the
        // kept prefix must reach p of the *global* total to be the true
        // nucleus. (Conservative: uses kept+tail as the global total.)
        let global_total = truncated.sum + tail_sum;
        if truncated.sum < params.top_p as f64 * global_total {
            return false;
        }
    }
    // min-p: no tail token may survive the cut.
    if params.min_p > 0.0 {
        let w_max = truncated.weights.iter().cloned().fold(0.0f64, f64::max);
        if tail_max_w >= params.min_p as f64 * w_max {
            return false;
        }
    }
    true
}

/// Exact full-vocabulary decision: stream the row, patch the (few)
/// penalty-touched ids by direct index (no per-element history probes),
/// truncate, draw. Used for greedy/allow-list requests and certificate
/// failures — and as the TVD oracle (`pipeline::oracle_decide`).
pub fn slow_path_token(
    view: &ShardedLogits,
    b: usize,
    hist: &SeqHistory,
    params: &SamplingParams,
    u: f64,
) -> u32 {
    let mut pairs: Vec<(u32, f32)> = Vec::with_capacity(view.vocab());
    view.for_each_logit(b, |v, z| pairs.push((v as u32, z)));
    // Sparse penalty patch: pairs[id] holds id (vocab order), so the touch
    // set is patched in O(|penalized| + |bias|).
    if params.has_penalties() {
        for (id, out_count) in hist.penalized_ids() {
            if let Some(p) = pairs.get_mut(id as usize) {
                p.1 = super::penalties::penalize_logit(p.1, true, out_count, params);
            }
        }
    }
    for (&id, &bias) in &params.logit_bias {
        if let Some(p) = pairs.get_mut(id as usize) {
            p.1 += bias;
        }
    }
    if let Some(allow) = &params.allowed_tokens {
        pairs = apply_allow_list(pairs, allow);
    }
    let truncated = truncate(pairs, params);
    draw_token(&truncated, u)
}

/// One streaming pass over the tail: inverse-CDF draw from r ∝ w|_{V\H}.
/// Penalty-touched ids are merged in via a small sorted patch list, keeping
/// the scan a pure stream (no per-element hash probes).
#[allow(clippy::too_many_arguments)]
fn tail_draw(
    view: &ShardedLogits,
    b: usize,
    hot: &HotVocab,
    hist: &SeqHistory,
    params: &SamplingParams,
    z_max: f32,
    inv_tau: f64,
    tail_sum: f64,
    u: f64,
    penalties_active: bool,
) -> u32 {
    // Small sorted (id, adjusted logit) patch list.
    let mut patches: Vec<(u32, f32)> = Vec::new();
    if penalties_active {
        for (id, _) in hist.penalized_ids() {
            if (id as usize) < view.vocab() && !hot.contains(id) {
                let raw = view.get(id as usize, b);
                patches.push((id, penalized_logit_at(raw, id, hist, params)));
            }
        }
        for (&id, _) in &params.logit_bias {
            if !hist.seen(id) && (id as usize) < view.vocab() && !hot.contains(id) {
                let raw = view.get(id as usize, b);
                patches.push((id, penalized_logit_at(raw, id, hist, params)));
            }
        }
        patches.sort_unstable_by_key(|p| p.0);
        patches.dedup_by_key(|p| p.0);
    }
    let target = u * tail_sum;
    let mut acc = 0.0f64;
    let mut chosen: Option<u32> = None;
    let mut last_tail: u32 = 0;
    let mut patch_i = 0usize;
    view.for_each_logit(b, |v, z| {
        if chosen.is_some() {
            return;
        }
        let id = v as u32;
        if hot.contains(id) {
            return;
        }
        last_tail = id;
        // merge-join against the ascending patch list
        let mut z = z;
        while patch_i < patches.len() && patches[patch_i].0 < id {
            patch_i += 1;
        }
        if patch_i < patches.len() && patches[patch_i].0 == id {
            z = patches[patch_i].1;
        }
        let w = (((z - z_max) as f64) * inv_tau).exp();
        acc += w;
        if target < acc {
            chosen = Some(id);
        }
    });
    // fp-rounding guard: if the adjusted tail_sum slightly exceeds the
    // freshly accumulated sum, land on the last tail token.
    chosen.unwrap_or(last_tail)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decision::softmax::softmax_dense;
    use crate::metrics::stats::total_variation_distance;
    use crate::rng::Philox;
    use crate::tensor::{shard_row_major, Tensor2};

    fn make_view(logits: Vec<f32>, b: usize, v: usize, shards: usize) -> ShardedLogits {
        shard_row_major(&Tensor2::from_vec(b, v, logits), shards)
    }

    /// Full-vocabulary oracle distribution (penalties + filter + softmax).
    fn oracle_dist(
        view: &ShardedLogits,
        b: usize,
        hist: &SeqHistory,
        params: &SamplingParams,
    ) -> Vec<f64> {
        let mut row = view.materialize_row(b);
        super::super::penalties::apply_penalties_dense(&mut row, hist, params);
        let pairs: Vec<(u32, f32)> =
            row.iter().enumerate().map(|(i, &z)| (i as u32, z)).collect();
        let t = truncate(pairs, params);
        let mut dist = vec![0.0f64; view.vocab()];
        for (i, &id) in t.ids.iter().enumerate() {
            dist[id as usize] = t.prob(i);
        }
        dist
    }

    /// Empirical SHVS distribution over `n` independent uniform triples.
    fn shvs_empirical(
        view: &ShardedLogits,
        b: usize,
        hist: &SeqHistory,
        params: &SamplingParams,
        hot: Arc<HotVocab>,
        n: usize,
        seed: u64,
    ) -> (Vec<f64>, f64) {
        let pre = Precompute::reference(view, b, &hot, params.temperature);
        let mut sampler = ShvsSampler::new(hot);
        let mut rng = Philox::new(seed);
        let mut counts = vec![0.0f64; view.vocab()];
        let mut accepts = 0usize;
        for _ in 0..n {
            let u = (rng.next_f64(), rng.next_f64(), rng.next_f64());
            let d = sampler.decide(view, b, hist, params, &pre, u);
            counts[d.token as usize] += 1.0;
            if d.accepted {
                accepts += 1;
            }
        }
        (counts, accepts as f64 / n as f64)
    }

    #[test]
    fn precompute_reference_sums_tail() {
        let v = 16;
        let logits: Vec<f32> = (0..v).map(|i| i as f32 * 0.1).collect();
        let view = make_view(logits.clone(), 1, v, 2);
        let hot = HotVocab::new(vec![14, 15], v);
        let pre = Precompute::reference(&view, 0, &hot, 1.0);
        let z_max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert_eq!(pre.z_max, z_max);
        // recompute with the same f32-rounded logits the view holds
        let expect: f64 = (0..14).map(|i| ((logits[i] - z_max) as f64).exp()).sum();
        assert!((pre.tail_sum - expect).abs() < 1e-9, "tail_sum {} expect {expect}", pre.tail_sum);
        let expect_max = ((logits[13] - z_max) as f64).exp();
        assert!((pre.tail_max_w - expect_max).abs() < 1e-9);
    }

    #[test]
    fn shvs_unfiltered_matches_full_softmax() {
        // Zipf-ish logits: hot set covers most mass.
        let v = 64;
        let logits: Vec<f32> = (0..v).map(|i| 3.0 - (i as f32) * 0.2).collect();
        let view = make_view(logits.clone(), 1, v, 2);
        let hot = HotVocab::new((0..16).collect(), v).into_arc();
        let params = SamplingParams::default();
        let hist = SeqHistory::new(&[]);

        let (counts, accept_rate) =
            shvs_empirical(&view, 0, &hist, &params, hot, 150_000, 5);
        let mut oracle = Vec::new();
        softmax_dense(&logits, 1.0, &mut oracle);
        let tvd = total_variation_distance(&counts, &oracle);
        assert!(tvd < 0.01, "TVD {tvd}");
        // hot set covers the head -> high acceptance (paper: 80–95%)
        assert!(accept_rate > 0.8, "accept {accept_rate}");
    }

    #[test]
    fn shvs_with_penalties_matches_oracle() {
        let v = 48;
        let logits: Vec<f32> = (0..v).map(|i| ((i * 13 % 48) as f32) * 0.15).collect();
        let view = make_view(logits, 1, v, 3);
        let hot = HotVocab::new((0..12).collect(), v).into_arc();
        let params = SamplingParams {
            repetition_penalty: 1.4,
            presence_penalty: 0.3,
            frequency_penalty: 0.2,
            temperature: 0.9,
            ..Default::default()
        };
        let mut hist = SeqHistory::new(&[2, 30, 31]);
        hist.append(2);
        hist.append(45); // tail token penalized — exercises incremental sums

        let (counts, _) =
            shvs_empirical(&view, 0, &hist, &params, hot, 200_000, 6);
        let oracle = oracle_dist(&view, 0, &hist, &params);
        let tvd = total_variation_distance(&counts, &oracle);
        assert!(tvd < 0.012, "TVD {tvd}");
    }

    #[test]
    fn shvs_filtered_matches_oracle_certificate_holds() {
        // Steep head inside the hot set: top-k filtered set ⊆ H certainly.
        let v = 40;
        let mut logits: Vec<f32> = vec![0.0; v];
        for (i, l) in logits.iter_mut().enumerate().take(8) {
            *l = 10.0 - i as f32;
        }
        let view = make_view(logits, 1, v, 2);
        let hot = HotVocab::new((0..10).collect(), v).into_arc();
        let params = SamplingParams {
            top_k: 5,
            top_p: 0.99,
            min_p: 0.01,
            temperature: 0.8,
            ..Default::default()
        };
        let hist = SeqHistory::new(&[]);
        let pre = Precompute::reference(&view, 0, &hot, params.temperature);
        let mut sampler = ShvsSampler::new(hot.clone());
        // fast path must engage
        let d = sampler.decide(&view, 0, &hist, &params, &pre, (0.3, 0.5, 0.7));
        assert!(d.fast_path, "certificate should hold");

        let (counts, _) = shvs_empirical(&view, 0, &hist, &params, hot, 150_000, 7);
        let oracle = oracle_dist(&view, 0, &hist, &params);
        let tvd = total_variation_distance(&counts, &oracle);
        assert!(tvd < 0.01, "TVD {tvd}");
    }

    #[test]
    fn shvs_filtered_falls_back_when_tail_dominates() {
        // The strongest token lives in the TAIL: certificate must fail and
        // the slow path must still be exact.
        let v = 32;
        let mut logits: Vec<f32> = vec![0.0; v];
        logits[30] = 9.0; // tail spike
        logits[1] = 5.0;
        let view = make_view(logits, 1, v, 2);
        let hot = HotVocab::new((0..8).collect(), v).into_arc();
        let params = SamplingParams { top_k: 3, ..Default::default() };
        let hist = SeqHistory::new(&[]);
        let pre = Precompute::reference(&view, 0, &hot, params.temperature);
        let mut sampler = ShvsSampler::new(hot.clone());
        let d = sampler.decide(&view, 0, &hist, &params, &pre, (0.3, 0.5, 0.7));
        assert!(!d.fast_path, "certificate must fail — top token is in the tail");

        let (counts, _) = shvs_empirical(&view, 0, &hist, &params, hot, 100_000, 8);
        let oracle = oracle_dist(&view, 0, &hist, &params);
        let tvd = total_variation_distance(&counts, &oracle);
        assert!(tvd < 0.01, "TVD {tvd}");
        // the tail spike must dominate empirically
        assert!(counts[30] > counts[1]);
    }

    #[test]
    fn alpha_equals_hot_mass() {
        let v = 20;
        let logits: Vec<f32> = (0..v).map(|i| -(i as f32) * 0.5).collect();
        let view = make_view(logits.clone(), 1, v, 1);
        let hot = HotVocab::new((0..5).collect(), v).into_arc();
        let params = SamplingParams::default();
        let hist = SeqHistory::new(&[]);
        let pre = Precompute::reference(&view, 0, &hot, 1.0);
        let mut sampler = ShvsSampler::new(hot);
        let d = sampler.decide(&view, 0, &hist, &params, &pre, (0.1, 0.0, 0.1));
        // α must equal Σ_{v<5} p(v) of the full softmax
        let mut probs = Vec::new();
        softmax_dense(&logits, 1.0, &mut probs);
        let expect: f64 = probs[..5].iter().sum();
        assert!((d.alpha - expect).abs() < 1e-9, "alpha {} expect {expect}", d.alpha);
    }

    #[test]
    fn greedy_bypasses_speculation() {
        let v = 16;
        let mut logits = vec![0.0f32; v];
        logits[13] = 4.0; // argmax in tail
        let view = make_view(logits, 1, v, 2);
        let hot = HotVocab::new((0..4).collect(), v).into_arc();
        let params = SamplingParams::greedy();
        let hist = SeqHistory::new(&[]);
        let pre = Precompute::reference(&view, 0, &hot, 1.0);
        let mut sampler = ShvsSampler::new(hot);
        let d = sampler.decide(&view, 0, &hist, &params, &pre, (0.9, 0.9, 0.9));
        assert_eq!(d.token, 13);
        assert!(!d.fast_path);
    }

    #[test]
    fn decisions_deterministic_given_uniforms() {
        let v = 24;
        let logits: Vec<f32> = (0..v).map(|i| (i as f32 * 0.37).sin()).collect();
        let view = make_view(logits, 1, v, 2);
        let hot = HotVocab::new((0..6).collect(), v).into_arc();
        let params = SamplingParams::default();
        let hist = SeqHistory::new(&[]);
        let pre = Precompute::reference(&view, 0, &hot, 1.0);
        let mut s1 = ShvsSampler::new(hot.clone());
        let mut s2 = ShvsSampler::new(hot);
        for i in 0..50 {
            let u = (
                (i as f64 * 0.019) % 1.0,
                (i as f64 * 0.037) % 1.0,
                (i as f64 * 0.053) % 1.0,
            );
            assert_eq!(
                s1.decide(&view, 0, &hist, &params, &pre, u),
                s2.decide(&view, 0, &hist, &params, &pre, u)
            );
        }
    }
}
