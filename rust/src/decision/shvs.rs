//! Speculative hot-vocab sampling with rejection-correctness (§5.3).
//!
//! Split the support into the hot set `H` and tail `V\H`. Compute stable
//! weights `w_v = exp((z'_v − z_max)/τ)` (Eq. 6); the hot mass is
//! `α = S_H / S_V` (Eq. 7). The unfiltered draw is a **rank-order coupled
//! inverse-CDF**: one uniform `u_select` picks `target = u·S_V`, and the
//! sampler walks tokens in the hot ranking's rank order, accumulating
//! weights until the target is crossed. If the crossing happens within the
//! first H ranks the decision is O(H) (the fast path, probability exactly
//! α); otherwise the walk continues into the tail. Because the walk order
//! and the total `S_V` are independent of where the H cut sits, *the drawn
//! token is bit-identical for every H that is a prefix of the same
//! ranking* — this is what lets the adaptive sizing controller (§5.4) move
//! H online without perturbing token streams.
//!
//! **GPU precompute.** `z_max`, `S_V` (the full-vocab weight sum), `S_tail`,
//! and the tail max weight are produced where the logits are written (the
//! L1 Pallas kernel outputs them; [`Precompute::reference`] is the CPU
//! oracle, and the only path exercised in CI — the PJRT literal composes
//! `S_V` from f32 partials, which is approximate and documented as such).
//! The CPU sampler adjusts them *incrementally* for the few penalty-touched
//! ids — iterated in sorted id order so every f64 adjustment is
//! deterministic — and no O(V) pass happens on the fast path.
//!
//! **Filters.** When top-k/top-p/min-p are enabled, the fast path runs the
//! truncation-first chain on the hot candidates and proves, via a
//! *containment certificate* with conservative floating-point margins, that
//! the globally filtered set equals the hot-filtered set. When the
//! certificate holds, the hot draw (using `u_fallback`) is **bitwise
//! identical** to [`slow_path_token`]'s output — same kept ids, same
//! shift, same id-order weight sums — and when it fails the sampler runs
//! that very slow path. Either way the token equals the full-vocabulary
//! sampler's, so filtered decisions are also H-invariant.

use super::categorical::draw_token;
use super::filter::{apply_allow_list, truncate, Truncated};
use super::hotvocab::HotVocab;
use super::params::SamplingParams;
use super::penalties::{penalized_logit_at, touched_ids_sorted, SeqHistory};
use crate::tensor::ShardedLogits;
use std::sync::Arc;

/// Per-sequence GPU-side precompute at temperature τ (pre-penalty).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Precompute {
    /// max_v z_v over the full vocabulary (stable-softmax shift).
    pub z_max: f32,
    /// Σ_v exp((z_v − z_max)/τ) over the *full* vocabulary, accumulated in
    /// id order. H-invariant: the coupled draw scales its target by this.
    pub total_sum: f64,
    /// Σ_{v∉H} exp((z_v − z_max)/τ).
    pub tail_sum: f64,
    /// max_{v∉H} exp((z_v − z_max)/τ) — the certificate bound.
    pub tail_max_w: f64,
}

impl Precompute {
    /// CPU reference implementation of the GPU precompute — one O(V) pass.
    /// The real system gets these numbers from the L1 kernel's outputs.
    pub fn reference(view: &ShardedLogits, b: usize, hot: &HotVocab, tau: f32) -> Precompute {
        let mut z_max = f32::NEG_INFINITY;
        view.for_each_logit(b, |_, z| z_max = z_max.max(z));
        let inv = 1.0 / tau.max(1e-6) as f64;
        let mut total_sum = 0.0f64;
        let mut tail_sum = 0.0f64;
        let mut tail_max_w = 0.0f64;
        view.for_each_logit(b, |v, z| {
            let w = (((z - z_max) as f64) * inv).exp();
            total_sum += w;
            if !hot.contains(v as u32) {
                tail_sum += w;
                if w > tail_max_w {
                    tail_max_w = w;
                }
            }
        });
        Precompute { z_max, total_sum, tail_sum, tail_max_w }
    }
}

/// Outcome of one SHVS decision, with the observability the paper exposes
/// (acceptance α, fast/slow path) for tuning H.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Decision {
    pub token: u32,
    /// Hot-vocab mass α_b (or filtered-certificate pseudo-α = 1.0).
    pub alpha: f64,
    /// True if the decision completed without an O(V) pass.
    pub fast_path: bool,
    /// True if the coupled draw landed inside the hot prefix (unfiltered
    /// path) or the containment certificate held (filtered path).
    pub accepted: bool,
}

/// Reusable SHVS sampler (per sampler thread; owns scratch buffers).
pub struct ShvsSampler {
    hot: Arc<HotVocab>,
    // scratch, reused across sequences to avoid hot-loop allocation
    hot_logits: Vec<f32>,
    hot_pairs: Vec<(u32, f32)>,
    hot_w: Vec<f64>,
}

impl ShvsSampler {
    pub fn new(hot: Arc<HotVocab>) -> Self {
        let h = hot.len();
        ShvsSampler {
            hot,
            hot_logits: Vec::with_capacity(h),
            hot_pairs: Vec::with_capacity(h),
            hot_w: Vec::with_capacity(h),
        }
    }

    pub fn hot_vocab(&self) -> &Arc<HotVocab> {
        &self.hot
    }

    /// Swap the hot set (online adaptive resizing). Decisions made after
    /// the swap need `Precompute`s for the *new* H — the reference path
    /// recomputes per call, so pipeline users passing `pre: None` are safe.
    pub fn set_hot(&mut self, hot: Arc<HotVocab>) {
        self.hot = hot;
        self.hot_logits.clear();
        self.hot_pairs.clear();
        self.hot_w.clear();
    }

    /// Decide the next token for sequence `b`.
    ///
    /// `uniforms = (u_select, u_accept, u_fallback)` — pre-generated per
    /// (sequence, iteration) so the outcome is sampler-assignment-invariant.
    /// `u_accept` is reserved (the coupled draw folds the accept test into
    /// `u_select`); it stays in the tuple so variate streams are stable.
    pub fn decide(
        &mut self,
        view: &ShardedLogits,
        b: usize,
        hist: &SeqHistory,
        params: &SamplingParams,
        pre: &Precompute,
        uniforms: (f64, f64, f64),
    ) -> Decision {
        let (u_select, _u_accept, u_fallback) = uniforms;

        // Greedy and allow-list requests skip speculation: greedy argmax
        // needs the global max (certificate rarely provable cheaply), and
        // allow-lists are usually tiny — both go straight to the exact path.
        if params.is_greedy() || params.allowed_tokens.is_some() {
            let token = slow_path_token(view, b, hist, params, u_fallback);
            return Decision { token, alpha: 1.0, fast_path: false, accepted: false };
        }

        let tau = params.temperature;
        let inv_tau = 1.0 / tau as f64;

        // ---- O(H) hot scan: gather raw hot logits (zero-copy view reads).
        view.gather(b, self.hot.ids(), &mut self.hot_logits);

        // Unified sorted patch pass (§5.2 column-wise trick applied to the
        // SHVS sums): every penalty/bias-touched id is visited once, in
        // ascending id order, adjusting the total, the tail statistics, and
        // the gathered hot logits. The sorted order is load-bearing — f64
        // accumulation must not depend on HashMap iteration order.
        let penalties_active = params.has_penalties() || !params.logit_bias.is_empty();
        let mut total = pre.total_sum;
        let mut tail_sum = pre.tail_sum;
        let mut tail_max_w = pre.tail_max_w;
        let hot_ids = self.hot.ids();
        // tail patches retained for the (rare) tail continuation walk
        let mut tail_patches: Vec<(u32, f32)> = Vec::new();
        if penalties_active {
            for id in touched_ids_sorted(hist, params) {
                if (id as usize) >= view.vocab() {
                    continue;
                }
                let raw = view.get(id as usize, b);
                let adj = penalized_logit_at(raw, id, hist, params);
                let w_old = (((raw - pre.z_max) as f64) * inv_tau).exp();
                let w_new = (((adj - pre.z_max) as f64) * inv_tau).exp();
                total += w_new - w_old;
                if let Ok(i) = hot_ids.binary_search(&id) {
                    self.hot_logits[i] = adj;
                } else {
                    tail_sum += w_new - w_old;
                    if w_new > tail_max_w {
                        tail_max_w = w_new; // may only grow stale-conservative
                    }
                    tail_patches.push((id, adj));
                }
            }
            total = total.max(0.0);
            tail_sum = tail_sum.max(0.0);
        }

        if params.has_filter() {
            // Materialize (id, logit) pairs only for the filtered machinery.
            self.hot_pairs.clear();
            for (&id, &z) in hot_ids.iter().zip(self.hot_logits.iter()) {
                self.hot_pairs.push((id, z));
            }
            let hot_len = self.hot_pairs.len();
            // ---- Filtered fast path with containment certificate.
            //
            // Case 1 — top-k selects within H: if the k-th largest *hot*
            // weight strictly exceeds every tail weight, the global top-k
            // set is exactly the hot top-k set (both use the total order
            // logit desc / id asc, and no tail token can reach or tie the
            // boundary). Both weights come from the identical monotone
            // formula at the pre.z_max shift, so the strict f64 comparison
            // implies strict logit domination — no margin needed.
            if params.top_k > 0 && params.top_k < hot_len {
                super::filter::select_top_k(&mut self.hot_pairs, params.top_k);
                let kth_logit = self.hot_pairs[..params.top_k]
                    .iter()
                    .map(|&(_, z)| z)
                    .fold(f32::INFINITY, f32::min);
                let kth_w = (((kth_logit - pre.z_max) as f64) * inv_tau).exp();
                if kth_w > tail_max_w {
                    // The survivors are the global top-k; restore canonical
                    // id order and run the shared stage-2 continuation —
                    // bitwise identical to the slow path's truncate.
                    let mut survivors = self.hot_pairs[..params.top_k].to_vec();
                    survivors.sort_unstable_by_key(|&(id, _)| id);
                    let rest = SamplingParams { top_k: 0, ..params.clone() };
                    let truncated = truncate(survivors, &rest);
                    let token = draw_token(&truncated, u_fallback);
                    self.hot_pairs.clear();
                    return Decision { token, alpha: 1.0, fast_path: true, accepted: true };
                }
            } else if params.top_k == 0 || params.top_k >= view.vocab() {
                // Case 2 — top-k is globally inert: prove the nucleus /
                // min-p set lies in H against the global masses. (When
                // hot_len ≤ top_k < V the global top-k would admit tail
                // tokens that the hot-side chain never sees — no certificate
                // is possible there, so that shape always falls back.)
                let z_max_h = self
                    .hot_pairs
                    .iter()
                    .map(|&(_, z)| z)
                    .fold(f32::NEG_INFINITY, f32::max);
                // Pre-top-p hot sum exactly as truncate's stage 2 computes
                // it (same f32 formula, same id order) — the nucleus
                // certificate compares against the global sum bound.
                let inv_tau_f32 = 1.0 / tau;
                let mut hot_full_sum = 0.0f64;
                for &(_, z) in &self.hot_pairs {
                    hot_full_sum += (((z - z_max_h) * inv_tau_f32) as f64).exp();
                }
                let truncated = truncate(self.hot_pairs.clone(), params);
                let certificate = filtered_set_certificate(
                    &truncated,
                    pre.z_max,
                    z_max_h,
                    inv_tau,
                    hot_full_sum,
                    tail_max_w,
                    tail_sum,
                    params,
                );
                if certificate {
                    let token = draw_token(&truncated, u_fallback);
                    self.hot_pairs.clear();
                    return Decision { token, alpha: 1.0, fast_path: true, accepted: true };
                }
            }
            // Certificate failed: exact O(V) slow path.
            self.hot_pairs.clear();
            let token = slow_path_token(view, b, hist, params, u_fallback);
            return Decision { token, alpha: 0.0, fast_path: false, accepted: false };
        }

        // ---- Unfiltered path: rank-order coupled inverse-CDF draw.
        // target = u_select · S_V; walk ranks 0.. accumulating patched
        // weights. Neither the walk order nor S_V depends on H, so the
        // token is invariant under resizing H along the same ranking.
        let z_max = pre.z_max;
        self.hot_w.clear();
        for &z in &self.hot_logits {
            self.hot_w.push((((z - z_max) as f64) * inv_tau).exp());
        }
        let h = hot_ids.len();
        let target = u_select * total;
        let mut acc = 0.0f64;
        let mut token: Option<u32> = None;
        for r in 0..h {
            let i = self.hot.rank_index(r);
            acc += self.hot_w[i];
            if token.is_none() && target < acc {
                token = Some(hot_ids[i]);
            }
        }
        // The full O(H) prefix always accumulates, so α is observable on
        // every decision (the sizing controller feeds on it) and
        // P(fast path) = α exactly.
        let s_hot = acc;
        let alpha = if total > 0.0 { (s_hot / total).min(1.0) } else { 0.0 };
        if let Some(tok) = token {
            return Decision { token: tok, alpha, fast_path: true, accepted: true };
        }

        // Tail continuation: walk ranks h..V. Rank order is not id order,
        // so penalty patches are looked up by binary search (the patch
        // list is tiny and this path is the 1−α rare case).
        let ranking = self.hot.ranking();
        let vocab = view.vocab();
        for &id in &ranking[h..] {
            let mut z = view.get(id as usize, b);
            if !tail_patches.is_empty() {
                if let Ok(pi) = tail_patches.binary_search_by_key(&id, |p| p.0) {
                    z = tail_patches[pi].1;
                }
            }
            let w = (((z - z_max) as f64) * inv_tau).exp();
            acc += w;
            if target < acc {
                token = Some(id);
                break;
            }
        }
        // fp-rounding guard: if target ≥ the freshly accumulated total,
        // land on the last rank.
        let tok = token.unwrap_or(ranking[vocab - 1]);
        Decision { token: tok, alpha, fast_path: false, accepted: false }
    }
}

/// Certificate that the filtered-on-hot set equals the filtered-on-V set,
/// *as computed* — when it returns true, the hot-side `truncate` output is
/// bitwise identical to the slow path's, so drawing with the same uniform
/// yields the same token.
///
/// All cross-shift comparisons convert hot-shift weights into the
/// pre.z_max shift and apply a conservative relative `MARGIN` that absorbs
/// the f32-formula rounding (≈2⁻²⁴ relative) plus f64 summation noise:
/// - domination: every kept hot weight must *strictly* exceed the max tail
///   weight (so no tail token enters or ties the global filtered set, and
///   the global argmax — hence the stage-2 shift — lives in H);
/// - top-p: the kept mass must reach p of the *global* pre-top-p sum
///   (hot_full_sum + converted tail_sum), so the global nucleus walk stops
///   at exactly the hot prefix (the minimality half is automatic because
///   interleaving non-negative tail terms never decreases a rounded
///   left-to-right sum);
/// - min-p: no tail token may pass the cut: tail_max_w < min_p·w_max.
#[allow(clippy::too_many_arguments)]
fn filtered_set_certificate(
    truncated: &Truncated,
    z_max_pre: f32,
    z_max_hot: f32,
    inv_tau: f64,
    hot_full_sum: f64,
    tail_max_w: f64,
    tail_sum: f64,
    params: &SamplingParams,
) -> bool {
    const MARGIN: f64 = 1e-6;
    if truncated.is_empty() {
        return false;
    }
    // hot-shift → pre-shift weight conversion factor
    let shift = ((z_max_hot as f64 - z_max_pre as f64) * inv_tau).exp();
    if !shift.is_finite() || shift <= 0.0 {
        return false;
    }
    let min_kept_w = truncated.weights.iter().cloned().fold(f64::INFINITY, f64::min);
    // All kept hot tokens must strictly dominate every tail token.
    if min_kept_w * shift <= tail_max_w * (1.0 + MARGIN) {
        return false;
    }
    // top-p: the kept mass must satisfy the nucleus condition globally.
    if params.top_p < 1.0 {
        let tail_sum_hot_shift = tail_sum / shift;
        let global_total = (hot_full_sum + tail_sum_hot_shift) * (1.0 + MARGIN);
        if truncated.sum * (1.0 - MARGIN) < params.top_p as f64 * global_total {
            return false;
        }
    }
    // min-p: no tail token may survive the cut.
    if params.min_p > 0.0 {
        let w_max = truncated.weights.iter().cloned().fold(0.0f64, f64::max);
        let cut = params.min_p as f64 * w_max * shift * (1.0 - MARGIN);
        if tail_max_w * (1.0 + MARGIN) >= cut {
            return false;
        }
    }
    true
}

/// Exact full-vocabulary decision: stream the row, patch the (few)
/// penalty-touched ids by direct index (no per-element history probes),
/// truncate, draw. Used for greedy/allow-list requests and certificate
/// failures — and as the TVD oracle (`pipeline::oracle_decide`).
pub fn slow_path_token(
    view: &ShardedLogits,
    b: usize,
    hist: &SeqHistory,
    params: &SamplingParams,
    u: f64,
) -> u32 {
    let mut pairs: Vec<(u32, f32)> = Vec::with_capacity(view.vocab());
    view.for_each_logit(b, |v, z| pairs.push((v as u32, z)));
    // Sparse penalty patch: pairs[id] holds id (vocab order), so the touch
    // set is patched in O(|penalized| + |bias|).
    if params.has_penalties() {
        for (id, out_count) in hist.penalized_ids() {
            if let Some(p) = pairs.get_mut(id as usize) {
                p.1 = super::penalties::penalize_logit(p.1, true, out_count, params);
            }
        }
    }
    for (&id, &bias) in &params.logit_bias {
        if let Some(p) = pairs.get_mut(id as usize) {
            p.1 += bias;
        }
    }
    if let Some(allow) = &params.allowed_tokens {
        pairs = apply_allow_list(pairs, allow);
    }
    let truncated = truncate(pairs, params);
    draw_token(&truncated, u)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decision::softmax::softmax_dense;
    use crate::metrics::stats::total_variation_distance;
    use crate::rng::Philox;
    use crate::tensor::{shard_row_major, Tensor2};

    fn make_view(logits: Vec<f32>, b: usize, v: usize, shards: usize) -> ShardedLogits {
        shard_row_major(&Tensor2::from_vec(b, v, logits), shards)
    }

    /// Full-vocabulary oracle distribution (penalties + filter + softmax).
    fn oracle_dist(
        view: &ShardedLogits,
        b: usize,
        hist: &SeqHistory,
        params: &SamplingParams,
    ) -> Vec<f64> {
        let mut row = view.materialize_row(b);
        super::super::penalties::apply_penalties_dense(&mut row, hist, params);
        let pairs: Vec<(u32, f32)> =
            row.iter().enumerate().map(|(i, &z)| (i as u32, z)).collect();
        let t = truncate(pairs, params);
        let mut dist = vec![0.0f64; view.vocab()];
        for (i, &id) in t.ids.iter().enumerate() {
            dist[id as usize] = t.prob(i);
        }
        dist
    }

    /// Empirical SHVS distribution over `n` independent uniform triples.
    fn shvs_empirical(
        view: &ShardedLogits,
        b: usize,
        hist: &SeqHistory,
        params: &SamplingParams,
        hot: Arc<HotVocab>,
        n: usize,
        seed: u64,
    ) -> (Vec<f64>, f64) {
        let pre = Precompute::reference(view, b, &hot, params.temperature);
        let mut sampler = ShvsSampler::new(hot);
        let mut rng = Philox::new(seed);
        let mut counts = vec![0.0f64; view.vocab()];
        let mut accepts = 0usize;
        for _ in 0..n {
            let u = (rng.next_f64(), rng.next_f64(), rng.next_f64());
            let d = sampler.decide(view, b, hist, params, &pre, u);
            counts[d.token as usize] += 1.0;
            if d.accepted {
                accepts += 1;
            }
        }
        (counts, accepts as f64 / n as f64)
    }

    #[test]
    fn precompute_reference_sums_tail() {
        let v = 16;
        let logits: Vec<f32> = (0..v).map(|i| i as f32 * 0.1).collect();
        let view = make_view(logits.clone(), 1, v, 2);
        let hot = HotVocab::new(vec![14, 15], v);
        let pre = Precompute::reference(&view, 0, &hot, 1.0);
        let z_max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert_eq!(pre.z_max, z_max);
        // recompute with the same f32-rounded logits the view holds
        let expect: f64 = (0..14).map(|i| ((logits[i] - z_max) as f64).exp()).sum();
        assert!((pre.tail_sum - expect).abs() < 1e-9, "tail_sum {} expect {expect}", pre.tail_sum);
        let expect_max = ((logits[13] - z_max) as f64).exp();
        assert!((pre.tail_max_w - expect_max).abs() < 1e-9);
        let expect_total: f64 = (0..v).map(|i| ((logits[i] - z_max) as f64).exp()).sum();
        assert!(
            (pre.total_sum - expect_total).abs() < 1e-9,
            "total_sum {} expect {expect_total}",
            pre.total_sum
        );
    }

    #[test]
    fn shvs_unfiltered_matches_full_softmax() {
        // Zipf-ish logits: hot set covers most mass.
        let v = 64;
        let logits: Vec<f32> = (0..v).map(|i| 3.0 - (i as f32) * 0.2).collect();
        let view = make_view(logits.clone(), 1, v, 2);
        let hot = HotVocab::new((0..16).collect(), v).into_arc();
        let params = SamplingParams::default();
        let hist = SeqHistory::new(&[]);

        let (counts, accept_rate) =
            shvs_empirical(&view, 0, &hist, &params, hot, 150_000, 5);
        let mut oracle = Vec::new();
        softmax_dense(&logits, 1.0, &mut oracle);
        let tvd = total_variation_distance(&counts, &oracle);
        assert!(tvd < 0.01, "TVD {tvd}");
        // hot set covers the head -> high acceptance (paper: 80–95%)
        assert!(accept_rate > 0.8, "accept {accept_rate}");
    }

    #[test]
    fn shvs_with_penalties_matches_oracle() {
        let v = 48;
        let logits: Vec<f32> = (0..v).map(|i| ((i * 13 % 48) as f32) * 0.15).collect();
        let view = make_view(logits, 1, v, 3);
        let hot = HotVocab::new((0..12).collect(), v).into_arc();
        let params = SamplingParams {
            repetition_penalty: 1.4,
            presence_penalty: 0.3,
            frequency_penalty: 0.2,
            temperature: 0.9,
            ..Default::default()
        };
        let mut hist = SeqHistory::new(&[2, 30, 31]);
        hist.append(2);
        hist.append(45); // tail token penalized — exercises incremental sums

        let (counts, _) =
            shvs_empirical(&view, 0, &hist, &params, hot, 200_000, 6);
        let oracle = oracle_dist(&view, 0, &hist, &params);
        let tvd = total_variation_distance(&counts, &oracle);
        assert!(tvd < 0.012, "TVD {tvd}");
    }

    #[test]
    fn shvs_filtered_matches_oracle_certificate_holds() {
        // Steep head inside the hot set: top-k filtered set ⊆ H certainly.
        let v = 40;
        let mut logits: Vec<f32> = vec![0.0; v];
        for (i, l) in logits.iter_mut().enumerate().take(8) {
            *l = 10.0 - i as f32;
        }
        let view = make_view(logits, 1, v, 2);
        let hot = HotVocab::new((0..10).collect(), v).into_arc();
        let params = SamplingParams {
            top_k: 5,
            top_p: 0.99,
            min_p: 0.01,
            temperature: 0.8,
            ..Default::default()
        };
        let hist = SeqHistory::new(&[]);
        let pre = Precompute::reference(&view, 0, &hot, params.temperature);
        let mut sampler = ShvsSampler::new(hot.clone());
        // fast path must engage
        let d = sampler.decide(&view, 0, &hist, &params, &pre, (0.3, 0.5, 0.7));
        assert!(d.fast_path, "certificate should hold");

        let (counts, _) = shvs_empirical(&view, 0, &hist, &params, hot, 150_000, 7);
        let oracle = oracle_dist(&view, 0, &hist, &params);
        let tvd = total_variation_distance(&counts, &oracle);
        assert!(tvd < 0.01, "TVD {tvd}");
    }

    #[test]
    fn shvs_filtered_falls_back_when_tail_dominates() {
        // The strongest token lives in the TAIL: certificate must fail and
        // the slow path must still be exact.
        let v = 32;
        let mut logits: Vec<f32> = vec![0.0; v];
        logits[30] = 9.0; // tail spike
        logits[1] = 5.0;
        let view = make_view(logits, 1, v, 2);
        let hot = HotVocab::new((0..8).collect(), v).into_arc();
        let params = SamplingParams { top_k: 3, ..Default::default() };
        let hist = SeqHistory::new(&[]);
        let pre = Precompute::reference(&view, 0, &hot, params.temperature);
        let mut sampler = ShvsSampler::new(hot.clone());
        let d = sampler.decide(&view, 0, &hist, &params, &pre, (0.3, 0.5, 0.7));
        assert!(!d.fast_path, "certificate must fail — top token is in the tail");

        let (counts, _) = shvs_empirical(&view, 0, &hist, &params, hot, 100_000, 8);
        let oracle = oracle_dist(&view, 0, &hist, &params);
        let tvd = total_variation_distance(&counts, &oracle);
        assert!(tvd < 0.01, "TVD {tvd}");
        // the tail spike must dominate empirically
        assert!(counts[30] > counts[1]);
    }

    #[test]
    fn filtered_fast_path_token_equals_slow_path() {
        // When the certificate holds, the fast-path token must be BITWISE
        // the slow path's token for the same u_fallback — the property that
        // makes filtered decisions H-invariant.
        let v = 40;
        let mut logits: Vec<f32> = vec![0.0; v];
        for (i, l) in logits.iter_mut().enumerate().take(8) {
            *l = 10.0 - i as f32;
        }
        let view = make_view(logits, 1, v, 2);
        let hot = HotVocab::new((0..10).collect(), v).into_arc();
        let hist = SeqHistory::new(&[]);
        for params in [
            SamplingParams { top_k: 5, temperature: 0.8, ..Default::default() },
            SamplingParams { top_p: 0.9, temperature: 0.8, ..Default::default() },
            SamplingParams { min_p: 0.05, temperature: 0.8, ..Default::default() },
            SamplingParams {
                top_k: 5,
                top_p: 0.95,
                min_p: 0.02,
                temperature: 0.8,
                ..Default::default()
            },
        ] {
            let pre = Precompute::reference(&view, 0, &hot, params.temperature);
            let mut sampler = ShvsSampler::new(hot.clone());
            let mut rng = Philox::new(99);
            for _ in 0..200 {
                let u = (rng.next_f64(), rng.next_f64(), rng.next_f64());
                let d = sampler.decide(&view, 0, &hist, &params, &pre, u);
                assert!(d.fast_path, "certificate should hold ({params:?})");
                let slow = slow_path_token(&view, 0, &hist, &params, u.2);
                assert_eq!(d.token, slow, "fast/slow divergence ({params:?})");
            }
        }
    }

    #[test]
    fn top_k_between_hot_and_vocab_always_falls_back() {
        // hot_len ≤ top_k < V: the global top-k admits tail tokens the hot
        // chain never sees — no certificate may claim the fast path.
        let v = 32;
        let logits: Vec<f32> = (0..v).map(|i| 5.0 - i as f32 * 0.1).collect();
        let view = make_view(logits, 1, v, 2);
        let hot = HotVocab::new((0..8).collect(), v).into_arc();
        let params = SamplingParams { top_k: 12, ..Default::default() };
        let hist = SeqHistory::new(&[]);
        let pre = Precompute::reference(&view, 0, &hot, params.temperature);
        let mut sampler = ShvsSampler::new(hot);
        let d = sampler.decide(&view, 0, &hist, &params, &pre, (0.3, 0.5, 0.7));
        assert!(!d.fast_path);
        assert_eq!(d.token, slow_path_token(&view, 0, &hist, &params, 0.7));
    }

    #[test]
    fn unfiltered_tokens_invariant_under_hot_resize() {
        // The rank-order coupled draw: every H along the same ranking must
        // produce the same token for the same uniforms.
        let v = 64;
        let counts: Vec<u64> = (0..v as u64).map(|i| (i * 31 + 7) % 101).collect();
        let base = HotVocab::from_counts(&counts, 16);
        let logits: Vec<f32> = (0..v).map(|i| ((i * 29 % 64) as f32) * 0.2 - 3.0).collect();
        let view = make_view(logits, 1, v, 2);
        let params = SamplingParams { temperature: 0.9, ..Default::default() };
        let mut hist = SeqHistory::new(&[3, 40]);
        hist.append(9);
        let mut rng = Philox::new(1234);
        let us: Vec<(f64, f64, f64)> = (0..300)
            .map(|_| (rng.next_f64(), rng.next_f64(), rng.next_f64()))
            .collect();
        let mut streams: Vec<Vec<u32>> = Vec::new();
        for h in [2usize, 8, 16, 40] {
            let hot = base.resize(h).into_arc();
            let pre = Precompute::reference(&view, 0, &hot, params.temperature);
            let mut sampler = ShvsSampler::new(hot);
            streams.push(
                us.iter()
                    .map(|&u| sampler.decide(&view, 0, &hist, &params, &pre, u).token)
                    .collect(),
            );
        }
        for s in &streams[1..] {
            assert_eq!(s, &streams[0], "token stream must be H-invariant");
        }
    }

    #[test]
    fn alpha_equals_hot_mass() {
        let v = 20;
        let logits: Vec<f32> = (0..v).map(|i| -(i as f32) * 0.5).collect();
        let view = make_view(logits.clone(), 1, v, 1);
        let hot = HotVocab::new((0..5).collect(), v).into_arc();
        let params = SamplingParams::default();
        let hist = SeqHistory::new(&[]);
        let pre = Precompute::reference(&view, 0, &hot, 1.0);
        let mut sampler = ShvsSampler::new(hot);
        let d = sampler.decide(&view, 0, &hist, &params, &pre, (0.1, 0.0, 0.1));
        // α must equal Σ_{v<5} p(v) of the full softmax
        let mut probs = Vec::new();
        softmax_dense(&logits, 1.0, &mut probs);
        let expect: f64 = probs[..5].iter().sum();
        assert!((d.alpha - expect).abs() < 1e-9, "alpha {} expect {expect}", d.alpha);
    }

    #[test]
    fn greedy_bypasses_speculation() {
        let v = 16;
        let mut logits = vec![0.0f32; v];
        logits[13] = 4.0; // argmax in tail
        let view = make_view(logits, 1, v, 2);
        let hot = HotVocab::new((0..4).collect(), v).into_arc();
        let params = SamplingParams::greedy();
        let hist = SeqHistory::new(&[]);
        let pre = Precompute::reference(&view, 0, &hot, 1.0);
        let mut sampler = ShvsSampler::new(hot);
        let d = sampler.decide(&view, 0, &hist, &params, &pre, (0.9, 0.9, 0.9));
        assert_eq!(d.token, 13);
        assert!(!d.fast_path);
    }

    #[test]
    fn decisions_deterministic_given_uniforms() {
        let v = 24;
        let logits: Vec<f32> = (0..v).map(|i| (i as f32 * 0.37).sin()).collect();
        let view = make_view(logits, 1, v, 2);
        let hot = HotVocab::new((0..6).collect(), v).into_arc();
        let params = SamplingParams::default();
        let hist = SeqHistory::new(&[]);
        let pre = Precompute::reference(&view, 0, &hot, 1.0);
        let mut s1 = ShvsSampler::new(hot.clone());
        let mut s2 = ShvsSampler::new(hot);
        for i in 0..50 {
            let u = (
                (i as f64 * 0.019) % 1.0,
                (i as f64 * 0.037) % 1.0,
                (i as f64 * 0.053) % 1.0,
            );
            assert_eq!(
                s1.decide(&view, 0, &hist, &params, &pre, u),
                s2.decide(&view, 0, &hist, &params, &pre, u)
            );
        }
    }
}
