//! Online, QoS-aware hot-vocab controller — the paper's future-work item
//! (i) in §9: "online, QoS-aware controllers that adapt H using the sizing
//! model".
//!
//! The static `H*` of §5.4 is optimal for the *offline* trace; under domain
//! shift the realized acceptance ᾱ drops and SHVS degrades toward full-V
//! scans (§9 limitations). This controller closes the loop:
//!
//! 1. Observe the realized acceptance rate over a sliding window.
//! 2. Fold the observation into an [`OnlineAlphaEstimator`] — a
//!    multiplicative correction *curve* over the offline ᾱ(H) prior,
//!    learned locally at the H values actually visited (a single global
//!    scale would wrongly extrapolate a shift at one H to all of them).
//! 3. Re-solve for H* under the corrected curve and step toward it,
//!    rate-limited to avoid oscillation, bounded so the decision plane
//!    stays under the cycle budget F(H) ≤ T_cycle (the §5.4 deployment
//!    rule).

use super::sizing::{OnlineAlphaEstimator, SizingModel};

/// Controller configuration.
#[derive(Debug, Clone)]
pub struct ControllerConfig {
    /// Decisions per control period.
    pub window: u64,
    /// Max relative H change per period (rate limiting).
    pub max_step_frac: f64,
    /// Acceptance deadband: |observed − predicted| below this is noise.
    pub deadband: f64,
    /// Keep F(H) at or below this budget (seconds); 0 disables the check.
    pub cycle_budget_s: f64,
    /// Hard bounds on H.
    pub h_min: usize,
    pub h_max: usize,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            window: 2048,
            max_step_frac: 0.25,
            deadband: 0.02,
            cycle_budget_s: 0.0,
            h_min: 64,
            h_max: usize::MAX,
        }
    }
}

/// Observed decision outcomes within a window.
#[derive(Debug, Clone, Copy, Default)]
struct WindowStats {
    decisions: u64,
    accepted: u64,
    alpha_sum: f64,
}

/// The adaptive controller.
#[derive(Debug)]
pub struct HotVocabController {
    cfg: ControllerConfig,
    sizing: SizingModel,
    current_h: usize,
    window: WindowStats,
    /// Learned multiplicative correction curve over ᾱ(H) (1.0 = offline
    /// model everywhere until runtime evidence arrives).
    est: OnlineAlphaEstimator,
    /// Number of completed control periods.
    pub periods: u64,
    /// History of (period, H, observed ᾱ) for observability.
    pub history: Vec<(u64, usize, f64)>,
}

impl HotVocabController {
    pub fn new(cfg: ControllerConfig, sizing: SizingModel, initial_h: usize) -> Self {
        let h = initial_h.clamp(cfg.h_min, cfg.h_max.min(sizing.vocab - 1));
        let (lo, hi) = sizing.alpha.domain();
        let est = OnlineAlphaEstimator::new(
            lo.max(cfg.h_min as f64),
            hi.min((sizing.vocab - 1) as f64),
            16,
            0.5,
        );
        HotVocabController {
            cfg,
            sizing,
            current_h: h,
            window: WindowStats::default(),
            est,
            periods: 0,
            history: Vec::new(),
        }
    }

    /// Current hot-vocab size.
    pub fn h(&self) -> usize {
        self.current_h
    }

    /// The learned ᾱ correction at a given H (1.0 = still trusting the
    /// offline prior there).
    pub fn alpha_correction(&self, h: f64) -> f64 {
        self.est.correction(h)
    }

    /// The effective (re-anchored) hit-ratio estimate at a given H.
    pub fn alpha_estimate(&self, h: f64) -> f64 {
        (self.sizing.alpha.eval(h) * self.est.correction(h)).clamp(0.0, 1.0)
    }

    /// Expected decision cost with the re-anchored ᾱ.
    pub fn f_adapted(&self, h: f64) -> f64 {
        let a = self.alpha_estimate(h);
        let v = self.sizing.vocab as f64;
        self.sizing.c0 + self.sizing.c * (a * h + (1.0 - a) * (v - h))
    }

    /// Record one decision outcome (α from [`super::shvs::Decision`]).
    /// Returns `Some(new_h)` when a control period elapses and H changes.
    pub fn observe(&mut self, alpha: f64, accepted: bool) -> Option<usize> {
        self.window.decisions += 1;
        self.window.alpha_sum += alpha;
        if accepted {
            self.window.accepted += 1;
        }
        if self.window.decisions < self.cfg.window {
            return None;
        }
        let observed = self.window.alpha_sum / self.window.decisions as f64;
        self.window = WindowStats::default();
        self.periods += 1;
        self.history.push((self.periods, self.current_h, observed));

        // Re-anchor ᾱ locally at the current H: fold the observed/predicted
        // ratio into the correction curve (the estimator clamps the ratio
        // and splits the update across the bracketing knots).
        let predicted = self.sizing.alpha.eval(self.current_h as f64);
        if predicted > 1e-9
            && (observed - self.alpha_estimate(self.current_h as f64)).abs() > self.cfg.deadband
        {
            self.est.observe(self.current_h as f64, observed / predicted);
        }

        // Re-solve argmin F under the adapted curve (coarse grid — the
        // valley is broad, §7.5).
        let (lo, hi) = self.sizing.alpha.domain();
        let lo = lo.max(self.cfg.h_min as f64);
        let hi = hi.min(self.cfg.h_max as f64).min((self.sizing.vocab - 1) as f64);
        let steps = 128;
        let mut best_h = self.current_h as f64;
        let mut best_f = f64::INFINITY;
        let mut best_feasible: Option<(f64, f64)> = None;
        for i in 0..=steps {
            let h = lo + (hi - lo) * i as f64 / steps as f64;
            let f = self.f_adapted(h);
            if f < best_f {
                best_f = f;
                best_h = h;
            }
            if self.cfg.cycle_budget_s > 0.0 && f <= self.cfg.cycle_budget_s {
                if best_feasible.is_none_or(|(bf, _)| f < bf) {
                    best_feasible = Some((f, h));
                }
            }
        }
        // Prefer the cheapest H inside the overlap budget F(H) ≤ T_cycle;
        // if nothing is feasible, degrade gracefully to the global argmin.
        if self.cfg.cycle_budget_s > 0.0 {
            if let Some((_, h)) = best_feasible {
                best_h = h;
            }
        }

        // Rate-limited step toward the target.
        let max_step = (self.current_h as f64 * self.cfg.max_step_frac).max(1.0);
        let delta = (best_h - self.current_h as f64).clamp(-max_step, max_step);
        let new_h = ((self.current_h as f64 + delta).round() as usize)
            .clamp(self.cfg.h_min, self.cfg.h_max.min(self.sizing.vocab - 1));
        if new_h != self.current_h {
            self.current_h = new_h;
            Some(new_h)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decision::sizing::zipf_alpha_knots;

    fn sizing(vocab: usize) -> SizingModel {
        let knots = zipf_alpha_knots(vocab, 1.1, 20);
        let cost: Vec<(f64, f64)> = knots
            .iter()
            .map(|&(h, _)| (h, 1.0e-8 * h + 8.0e-6))
            .collect();
        SizingModel::fit(&cost, &knots, vocab)
    }

    fn run_periods(
        ctl: &mut HotVocabController,
        periods: usize,
        observed_alpha: impl Fn(usize) -> f64,
    ) {
        for _ in 0..periods {
            for _ in 0..ctl.cfg.window {
                let a = observed_alpha(ctl.h());
                ctl.observe(a, a > 0.5);
            }
        }
    }

    #[test]
    fn converges_near_h_star_when_model_is_right() {
        let s = sizing(100_000);
        let h_star = s.h_star();
        let alpha = s.alpha.clone();
        let mut ctl = HotVocabController::new(
            ControllerConfig { window: 64, ..Default::default() },
            s,
            512,
        );
        run_periods(&mut ctl, 40, |h| alpha.eval(h as f64));
        let h = ctl.h() as f64;
        // broad valley: F at converged H within 10% of F at H*
        let f_conv = ctl.f_adapted(h);
        let f_star = ctl.f_adapted(h_star as f64);
        assert!(
            f_conv < f_star * 1.1,
            "converged H={h} F={f_conv:.3e} vs H*={h_star} F={f_star:.3e}"
        );
    }

    #[test]
    fn domain_shift_grows_h() {
        // Observed acceptance is consistently LOWER than the offline model
        // (domain shift): the controller should re-anchor and increase H.
        let s = sizing(100_000);
        let h0 = s.h_star();
        let alpha = s.alpha.clone();
        let mut ctl = HotVocabController::new(
            ControllerConfig { window: 64, ..Default::default() },
            s,
            h0,
        );
        run_periods(&mut ctl, 30, |h| 0.6 * alpha.eval(h as f64));
        assert!(
            ctl.h() > h0,
            "H should grow under shift: {} -> {}",
            h0,
            ctl.h()
        );
        let corr = ctl.alpha_correction(ctl.h() as f64);
        assert!(corr < 0.9, "correction {corr}");
    }

    #[test]
    fn hot_distribution_shrinks_h() {
        // Observed acceptance HIGHER than modeled: smaller H suffices.
        let s = sizing(100_000);
        let alpha = s.alpha.clone();
        let h0 = (s.h_star() * 2).min(40_000);
        let mut ctl = HotVocabController::new(
            ControllerConfig { window: 64, ..Default::default() },
            s,
            h0,
        );
        run_periods(&mut ctl, 30, |h| (1.3 * alpha.eval(h as f64)).min(1.0));
        assert!(ctl.h() < h0, "H should shrink: {} -> {}", h0, ctl.h());
    }

    #[test]
    fn rate_limit_bounds_per_period_change() {
        let s = sizing(50_000);
        let mut ctl = HotVocabController::new(
            ControllerConfig { window: 8, max_step_frac: 0.1, ..Default::default() },
            s,
            1000,
        );
        let before = ctl.h();
        for _ in 0..8 {
            ctl.observe(0.05, false); // terrible acceptance
        }
        let after = ctl.h();
        assert!(after as f64 <= before as f64 * 1.1 + 1.0, "{before} -> {after}");
    }

    #[test]
    fn cycle_budget_caps_h() {
        let s = sizing(100_000);
        // budget slightly above the achievable minimum: a feasible band
        // exists around H*, and the controller must move into it.
        let min_f = (0..200)
            .map(|i| s.f(64.0 + i as f64 * 400.0))
            .fold(f64::INFINITY, f64::min);
        let budget = min_f * 1.2;
        let alpha = s.alpha.clone();
        let mut ctl = HotVocabController::new(
            ControllerConfig {
                window: 16,
                cycle_budget_s: budget,
                ..Default::default()
            },
            s,
            256, // far below the feasible band
        );
        run_periods(&mut ctl, 40, |h| alpha.eval(h as f64));
        assert!(
            ctl.f_adapted(ctl.h() as f64) <= budget * 1.05,
            "H={} F={:.3e} violates budget {budget:.3e}",
            ctl.h(),
            ctl.f_adapted(ctl.h() as f64)
        );
    }

    #[test]
    fn history_records_periods() {
        let s = sizing(10_000);
        let mut ctl =
            HotVocabController::new(ControllerConfig { window: 4, ..Default::default() }, s, 128);
        for _ in 0..12 {
            ctl.observe(0.8, true);
        }
        assert_eq!(ctl.periods, 3);
        assert_eq!(ctl.history.len(), 3);
    }
}
