//! Categorical token draws from pre-generated uniform variates.
//!
//! Determinism (§5.1): the engine pre-generates uniforms with the
//! counter-based [`crate::rng::Philox`] keyed on (engine seed, sequence id,
//! iteration), so the drawn token is independent of which sampler handles
//! the sequence and of batch composition — sequence-parallel outcomes match
//! the single-worker stream exactly.

use super::filter::Truncated;

/// Inverse-CDF draw over a truncated subset: returns the *subset index*.
/// `u ∈ [0,1)`. Single O(|K|) pass, no cumulative table materialized.
#[inline]
pub fn draw_index(weights: &[f64], sum: f64, u: f64) -> usize {
    debug_assert!(!weights.is_empty());
    let target = u * sum;
    let mut acc = 0.0f64;
    for (i, &w) in weights.iter().enumerate() {
        acc += w;
        if target < acc {
            return i;
        }
    }
    weights.len() - 1 // guard for u ~ 1 under fp rounding
}

/// Draw a token id from a truncated distribution, remapping the subset index
/// through the index map π_b back to the full vocabulary.
#[inline]
pub fn draw_token(t: &Truncated, u: f64) -> u32 {
    t.ids[draw_index(&t.weights, t.sum, u)]
}

/// The per-(sequence, iteration) uniform variate used for the final draw
/// plus the SHVS accept/reject test. Uses a dedicated Philox substream per
/// sequence; the iteration indexes within the stream.
pub struct VariateSource {
    engine_seed: u64,
}

impl VariateSource {
    pub fn new(engine_seed: u64) -> Self {
        VariateSource { engine_seed }
    }

    /// Uniforms for (sequence, iteration): (u_select, u_accept, u_fallback).
    /// All three are pinned so the fast/slow path choice never perturbs the
    /// stream of later iterations.
    pub fn uniforms(&self, request_seed: u64, seq_id: u64, iteration: u64) -> (f64, f64, f64) {
        let key = self
            .engine_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(request_seed);
        let mut rng = crate::rng::Philox::at(
            key,
            ((seq_id as u128) << 64) | ((iteration as u128) << 2),
        );
        (rng.next_f64(), rng.next_f64(), rng.next_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decision::filter::truncate;
    use crate::decision::params::SamplingParams;

    #[test]
    fn draw_index_respects_cdf() {
        let w = [0.25f64, 0.5, 0.25];
        let sum = 1.0;
        assert_eq!(draw_index(&w, sum, 0.0), 0);
        assert_eq!(draw_index(&w, sum, 0.24), 0);
        assert_eq!(draw_index(&w, sum, 0.25), 1);
        assert_eq!(draw_index(&w, sum, 0.74), 1);
        assert_eq!(draw_index(&w, sum, 0.75), 2);
        assert_eq!(draw_index(&w, sum, 0.999999), 2);
    }

    #[test]
    fn draw_index_handles_unnormalized() {
        let w = [2.0f64, 6.0];
        assert_eq!(draw_index(&w, 8.0, 0.2), 0);
        assert_eq!(draw_index(&w, 8.0, 0.3), 1);
    }

    #[test]
    fn empirical_frequencies_match_probs() {
        let logits = [0.0f32, 1.0, 2.0];
        let t = truncate(
            logits.iter().enumerate().map(|(i, &z)| (i as u32, z)).collect(),
            &SamplingParams::default(),
        );
        let mut rng = crate::rng::Philox::new(77);
        let n = 200_000;
        let mut counts = [0usize; 3];
        for _ in 0..n {
            counts[draw_token(&t, rng.next_f64()) as usize] += 1;
        }
        for i in 0..3 {
            let emp = counts[i] as f64 / n as f64;
            assert!((emp - t.prob(i)).abs() < 0.005, "i={i} emp={emp} p={}", t.prob(i));
        }
    }

    #[test]
    fn variates_are_deterministic_and_distinct() {
        let vs = VariateSource::new(42);
        let a = vs.uniforms(0, 3, 10);
        let b = vs.uniforms(0, 3, 10);
        assert_eq!(a, b);
        let c = vs.uniforms(0, 3, 11);
        assert_ne!(a, c);
        let d = vs.uniforms(0, 4, 10);
        assert_ne!(a, d);
        let e = vs.uniforms(1, 3, 10);
        assert_ne!(a, e);
        for u in [a.0, a.1, a.2] {
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn variates_independent_of_worker_order() {
        // The whole point of §5.1 determinism: any sampler computing the
        // variates for (seq, iter) gets the same values.
        let vs1 = VariateSource::new(7);
        let vs2 = VariateSource::new(7);
        for seq in 0..8u64 {
            for it in 0..8u64 {
                assert_eq!(vs1.uniforms(5, seq, it), vs2.uniforms(5, seq, it));
            }
        }
    }
}
