//! Vectorized single-pass dense sampling kernels (§5.2 hot path).
//!
//! The per-column decision work — sparse penalty patch, max reduction,
//! top-k boundary selection — is restructured here around explicit 8-wide
//! f32/u32 lane structs (`[f32; 8]` / `[u32; 8]` blocks that LLVM
//! autovectorizes to SSE/AVX/NEON without any non-portable intrinsics or
//! new dependencies). The backend is runtime-dispatched via
//! [`KernelBackend::detect`] (`SIMPLE_KERNELS=scalar|simd`), and `cargo
//! test` exercises both: `rust/tests/simd_kernels.rs` drives the two
//! backends against each other over adversarial vocabularies.
//!
//! **Bit-identical-streams invariant.** The vector path must produce the
//! same `Truncated` sets and the same sampled tokens as the scalar path,
//! bit for bit. Three design rules make that hold:
//!
//! 1. Lanes only touch *order* computations (max, compare, count), never
//!    the `exp`/f64 accumulation — weights and sums always flow through the
//!    one scalar formula in [`super::filter::truncate`].
//! 2. Comparisons run on a canonical order-preserving `u32` key
//!    ([`order_key`]): sign-flipped IEEE bits with `-0.0` canonicalized to
//!    `+0.0`, so key `>`/`==` agree exactly with f32 `partial_cmp` on every
//!    non-NaN input (±inf and subnormals included) and the tie classes
//!    match the scalar comparator's.
//! 3. Ties break **lowest index wins** everywhere — each lane keeps its
//!    earliest maximum via strict `>`, and the horizontal reduction picks
//!    the lowest absolute index among equal lane maxima, matching
//!    [`super::softmax::argmax`] and the top-k total order (logit desc,
//!    id asc) of [`super::filter::select_top_k`].
//!
//! The fused column pass is cache-resident: one sweep over the
//! materialized row builds the keys *and* tracks the running max; the
//! top-k boundary is then found by quickselect over the `u32` keys (far
//! cheaper than tuple-comparator quickselect on `(u32, f32)` pairs), the
//! strict-majority count `#{key > kth}` is a lane-parallel compare-count,
//! and survivors are emitted directly in ascending-id order — the canonical
//! `Truncated` layout — so the shared scalar continuation (temperature,
//! top-p, min-p, draw) is bitwise the slow path's.

use super::categorical::draw_token;
use super::filter::{truncate, Truncated};
use super::params::SamplingParams;
use super::penalties::{penalize_logit, SeqHistory};
use super::shvs::slow_path_token;
use crate::tensor::ShardedLogits;

/// Portable lane width: 8 × f32 = one AVX2 register, two NEON registers.
pub const LANES: usize = 8;

/// Which kernel implementation a sampler runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelBackend {
    /// The reference scalar path ([`slow_path_token`] verbatim).
    Scalar,
    /// The lane-vectorized fused path (default).
    Simd,
}

impl KernelBackend {
    /// Runtime dispatch: `SIMPLE_KERNELS=scalar` forces the reference
    /// path, `SIMPLE_KERNELS=simd` (or unset) the vector path. Exists so
    /// CI can run the whole suite under both backends.
    pub fn detect() -> KernelBackend {
        match std::env::var("SIMPLE_KERNELS").ok().as_deref() {
            Some("scalar") => KernelBackend::Scalar,
            _ => KernelBackend::Simd,
        }
    }
}

/// Order-preserving key transform: for all non-NaN `a, b`:
/// `order_key(a) > order_key(b) ⟺ a > b` and equality likewise, with
/// `-0.0` and `+0.0` mapping to one tie class (as f32 `==` does).
#[inline(always)]
fn order_key(z: f32) -> u32 {
    let bits = if z == 0.0 { 0 } else { z.to_bits() };
    if bits & 0x8000_0000 != 0 {
        !bits
    } else {
        bits ^ 0x8000_0000
    }
}

/// Backend-dispatched argmax. Tie rule: lowest index wins (the
/// [`super::softmax::argmax`] contract).
pub fn argmax(backend: KernelBackend, row: &[f32]) -> usize {
    match backend {
        KernelBackend::Scalar => super::softmax::argmax(row),
        KernelBackend::Simd => argmax_simd(row),
    }
}

fn argmax_simd(row: &[f32]) -> usize {
    let n = row.len();
    if n < LANES * 2 {
        return super::softmax::argmax(row);
    }
    // Per-lane running max with strict `>`: each lane keeps its EARLIEST
    // maximum, so the horizontal pass below sees one candidate per lane.
    let mut best = [0.0f32; LANES];
    let mut idx = [0u32; LANES];
    for l in 0..LANES {
        best[l] = row[l];
        idx[l] = l as u32;
    }
    let mut i = LANES;
    while i + LANES <= n {
        for l in 0..LANES {
            let z = row[i + l];
            if z > best[l] {
                best[l] = z;
                idx[l] = (i + l) as u32;
            }
        }
        i += LANES;
    }
    // Horizontal combine: strict `>` plus lowest-absolute-index tie-break,
    // which reproduces the scalar left-to-right strict-`>` scan exactly.
    let mut bz = best[0];
    let mut bi = idx[0];
    for l in 1..LANES {
        if best[l] > bz || (best[l] == bz && idx[l] < bi) {
            bz = best[l];
            bi = idx[l];
        }
    }
    // Remainder indices exceed every processed index, so strict `>` alone
    // preserves the tie rule.
    while i < n {
        if row[i] > bz {
            bz = row[i];
            bi = i as u32;
        }
        i += 1;
    }
    bi as usize
}

/// Fused column pass: write `order_key(row[i])` into `keys` and return the
/// argmax index (lowest-index tie rule) in the same cache-resident sweep.
fn build_keys_fused(row: &[f32], keys: &mut Vec<u32>) -> usize {
    let n = row.len();
    keys.clear();
    keys.resize(n, 0);
    if n < LANES * 2 {
        let mut bi = 0usize;
        for (i, &z) in row.iter().enumerate() {
            let k = order_key(z);
            keys[i] = k;
            if k > keys[bi] {
                bi = i;
            }
        }
        return bi;
    }
    let mut best = [0u32; LANES];
    let mut idx = [0u32; LANES];
    for l in 0..LANES {
        let k = order_key(row[l]);
        keys[l] = k;
        best[l] = k;
        idx[l] = l as u32;
    }
    let mut i = LANES;
    while i + LANES <= n {
        for l in 0..LANES {
            let k = order_key(row[i + l]);
            keys[i + l] = k;
            if k > best[l] {
                best[l] = k;
                idx[l] = (i + l) as u32;
            }
        }
        i += LANES;
    }
    let mut bk = best[0];
    let mut bi = idx[0];
    for l in 1..LANES {
        if best[l] > bk || (best[l] == bk && idx[l] < bi) {
            bk = best[l];
            bi = idx[l];
        }
    }
    while i < n {
        let k = order_key(row[i]);
        keys[i] = k;
        if k > bk {
            bk = k;
            bi = i as u32;
        }
        i += 1;
    }
    bi as usize
}

/// Lane-parallel `#{key > t}`.
fn count_gt(keys: &[u32], t: u32) -> usize {
    let mut acc = [0u32; LANES];
    let mut chunks = keys.chunks_exact(LANES);
    for ch in &mut chunks {
        for l in 0..LANES {
            acc[l] += (ch[l] > t) as u32;
        }
    }
    let mut n: usize = acc.iter().map(|&c| c as usize).sum();
    for &k in chunks.remainder() {
        n += (k > t) as usize;
    }
    n
}

/// A dense full-vocabulary decision kernel with reusable scratch buffers
/// (one per sampler thread; the vector path must not allocate per column).
pub struct DenseKernel {
    backend: KernelBackend,
    row: Vec<f32>,
    keys: Vec<u32>,
    sel: Vec<u32>,
}

impl DenseKernel {
    pub fn new(backend: KernelBackend) -> Self {
        DenseKernel { backend, row: Vec::new(), keys: Vec::new(), sel: Vec::new() }
    }

    pub fn backend(&self) -> KernelBackend {
        self.backend
    }

    /// Decide one column exactly: penalties → filter chain → draw. Output
    /// is bitwise [`slow_path_token`]'s for every input, on both backends.
    pub fn decide(
        &mut self,
        view: &ShardedLogits,
        b: usize,
        hist: &SeqHistory,
        params: &SamplingParams,
        u: f64,
    ) -> u32 {
        match self.backend {
            KernelBackend::Scalar => slow_path_token(view, b, hist, params, u),
            KernelBackend::Simd => self.decide_simd(view, b, hist, params, u),
        }
    }

    /// Materialize column `b` and apply the sparse penalty patch, identical
    /// in structure to `slow_path_token`: penalize each touched id first,
    /// then the separate bias-add loop (the order matters — bias applies
    /// after the sign-aware division). Pure per-element scalar arithmetic,
    /// so the patched row is bitwise the slow path's on both backends.
    fn load_column(
        &mut self,
        view: &ShardedLogits,
        b: usize,
        hist: &SeqHistory,
        params: &SamplingParams,
    ) {
        view.materialize_row_into(b, &mut self.row);
        if params.has_penalties() {
            for (id, out_count) in hist.penalized_ids() {
                if let Some(z) = self.row.get_mut(id as usize) {
                    *z = penalize_logit(*z, true, out_count, params);
                }
            }
        }
        for (&id, &bias) in &params.logit_bias {
            if let Some(z) = self.row.get_mut(id as usize) {
                *z += bias;
            }
        }
    }

    /// The vector top-k truncation over the loaded row. Fused pass builds
    /// canonical keys + running max in one sweep; quickselect finds the
    /// k-th largest KEY (u32 compares — no NaN branches, no tuple
    /// shuffles); survivors come out in one ascending-id scan: every key
    /// above the boundary, plus the first (k − #above) boundary ties —
    /// exactly the total-order (logit desc, id asc) top-k set, already in
    /// canonical order for the shared scalar continuation.
    fn truncate_loaded_topk(&mut self, params: &SamplingParams) -> Truncated {
        let _ = build_keys_fused(&self.row, &mut self.keys);
        let k = params.top_k;
        self.sel.clear();
        self.sel.extend_from_slice(&self.keys);
        self.sel.select_nth_unstable_by(k - 1, |a, b| b.cmp(a));
        let kth = self.sel[k - 1];
        let n_gt = count_gt(&self.keys, kth);
        debug_assert!(n_gt < k);
        let mut tie_take = k - n_gt;
        let mut survivors: Vec<(u32, f32)> = Vec::with_capacity(k);
        for (v, &key) in self.keys.iter().enumerate() {
            if key > kth {
                survivors.push((v as u32, self.row[v]));
            } else if key == kth && tie_take > 0 {
                tie_take -= 1;
                survivors.push((v as u32, self.row[v]));
            }
            if survivors.len() == k {
                break;
            }
        }
        let rest = SamplingParams { top_k: 0, ..params.clone() };
        truncate(survivors, &rest)
    }

    /// The column's canonical [`Truncated`] set under this backend — the
    /// differential-suite surface: kept ids, per-id stable weights, and the
    /// f64 weight sum must be bitwise equal across backends for every
    /// filter combination. (Greedy and allow-list columns never build a
    /// `Truncated` on the decide path; callers compare those via tokens.)
    pub fn truncated_column(
        &mut self,
        view: &ShardedLogits,
        b: usize,
        hist: &SeqHistory,
        params: &SamplingParams,
    ) -> Truncated {
        self.load_column(view, b, hist, params);
        let vocab = self.row.len();
        if self.backend == KernelBackend::Simd && params.top_k > 0 && params.top_k < vocab
        {
            return self.truncate_loaded_topk(params);
        }
        let pairs: Vec<(u32, f32)> = self
            .row
            .iter()
            .enumerate()
            .map(|(v, &z)| (v as u32, z))
            .collect();
        truncate(pairs, params)
    }

    fn decide_simd(
        &mut self,
        view: &ShardedLogits,
        b: usize,
        hist: &SeqHistory,
        params: &SamplingParams,
        u: f64,
    ) -> u32 {
        // Allow-lists shrink the candidate set to a handful of ids — the
        // scalar path is already optimal there and keeps grammar-masked
        // requests on one audited code path.
        if params.allowed_tokens.is_some() {
            return slow_path_token(view, b, hist, params, u);
        }
        self.load_column(view, b, hist, params);

        if params.is_greedy() {
            // truncate's greedy singleton is (max logit, lowest id) — the
            // lane argmax implements the identical total order.
            return argmax_simd(&self.row) as u32;
        }

        let vocab = self.row.len();
        if params.top_k > 0 && params.top_k < vocab {
            if params.top_k == 1 {
                // Total-order top-1 is the argmax; top-p/min-p keep a
                // singleton unchanged and the draw is forced.
                return argmax_simd(&self.row) as u32;
            }
            let truncated = self.truncate_loaded_topk(params);
            return draw_token(&truncated, u);
        }

        // No top-k: the chain starts at the temperature/top-p/min-p stage,
        // whose cost is the shared scalar continuation either way.
        let pairs: Vec<(u32, f32)> = self
            .row
            .iter()
            .enumerate()
            .map(|(v, &z)| (v as u32, z))
            .collect();
        let truncated = truncate(pairs, params);
        draw_token(&truncated, u)
    }
}

/// One-shot convenience wrapper (tests, oracles). Hot paths should hold a
/// [`DenseKernel`] to reuse its scratch.
pub fn decide_dense(
    backend: KernelBackend,
    view: &ShardedLogits,
    b: usize,
    hist: &SeqHistory,
    params: &SamplingParams,
    u: f64,
) -> u32 {
    DenseKernel::new(backend).decide(view, b, hist, params, u)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Philox;
    use crate::tensor::{shard_row_major, Tensor2};

    #[test]
    fn order_key_is_order_preserving() {
        let samples = [
            f32::NEG_INFINITY,
            -3.4e38,
            -1.0,
            -1e-40, // subnormal
            -0.0,
            0.0,
            1e-40, // subnormal
            f32::MIN_POSITIVE,
            0.5,
            1.0,
            3.4e38,
            f32::INFINITY,
        ];
        for (i, &a) in samples.iter().enumerate() {
            for &b in &samples[i..] {
                assert_eq!(order_key(a) > order_key(b), a > b, "{a} vs {b}");
                assert_eq!(order_key(a) == order_key(b), a == b, "{a} vs {b}");
            }
        }
        // ±0 is one tie class
        assert_eq!(order_key(-0.0), order_key(0.0));
    }

    #[test]
    fn lane_argmax_matches_scalar() {
        let mut rng = Philox::new(11);
        for n in [1usize, 7, 8, 9, 16, 17, 100, 1000] {
            for round in 0..8 {
                let row: Vec<f32> = (0..n)
                    .map(|_| {
                        if round % 2 == 0 {
                            rng.next_f32() * 10.0 - 5.0
                        } else {
                            // coarse quantization forces ties
                            (rng.next_f32() * 4.0).floor()
                        }
                    })
                    .collect();
                assert_eq!(
                    argmax_simd(&row),
                    super::super::softmax::argmax(&row),
                    "n={n} round={round}"
                );
            }
        }
        // all-equal rows: lowest index wins on both
        assert_eq!(argmax_simd(&vec![1.5f32; 37]), 0);
        // ±inf extremes
        let mut row = vec![f32::NEG_INFINITY; 40];
        row[23] = f32::INFINITY;
        row[31] = f32::INFINITY;
        assert_eq!(argmax_simd(&row), 23);
    }

    #[test]
    fn count_gt_matches_naive() {
        let mut rng = Philox::new(13);
        let keys: Vec<u32> = (0..301).map(|_| rng.next_u64() as u32 % 64).collect();
        for t in [0u32, 5, 31, 63, u32::MAX] {
            let naive = keys.iter().filter(|&&k| k > t).count();
            assert_eq!(count_gt(&keys, t), naive, "t={t}");
        }
    }

    #[test]
    fn fused_keys_agree_with_per_element_transform() {
        let mut rng = Philox::new(17);
        let row: Vec<f32> = (0..131).map(|_| rng.next_f32() * 6.0 - 3.0).collect();
        let mut keys = Vec::new();
        let amax = build_keys_fused(&row, &mut keys);
        for (i, &z) in row.iter().enumerate() {
            assert_eq!(keys[i], order_key(z));
        }
        assert_eq!(amax, super::super::softmax::argmax(&row));
    }

    #[test]
    fn simd_decide_matches_scalar_quick() {
        let v = 257; // off lane boundary
        let b = 2;
        let mut rng = Philox::new(23);
        let logits: Vec<f32> =
            (0..b * v).map(|_| (rng.next_f32() * 8.0).floor() * 0.5).collect();
        let view = shard_row_major(&Tensor2::from_vec(b, v, logits), 3);
        let mut hist = SeqHistory::new(&[3, 90]);
        hist.append(17);
        let mut params = SamplingParams {
            top_k: 24,
            top_p: 0.92,
            min_p: 0.01,
            temperature: 0.8,
            repetition_penalty: 1.2,
            presence_penalty: 0.1,
            frequency_penalty: 0.1,
            ..Default::default()
        };
        params.logit_bias.insert(200, 1.5);
        let mut scalar = DenseKernel::new(KernelBackend::Scalar);
        let mut simd = DenseKernel::new(KernelBackend::Simd);
        for col in 0..b {
            for i in 0..50 {
                let u = (i as f64 + 0.5) / 50.0;
                assert_eq!(
                    simd.decide(&view, col, &hist, &params, u),
                    scalar.decide(&view, col, &hist, &params, u),
                    "col={col} u={u}"
                );
            }
        }
    }

    #[test]
    fn detect_honors_env_contract() {
        // Can't mutate the process env safely in parallel tests; just pin
        // the default.
        if std::env::var("SIMPLE_KERNELS").is_err() {
            assert_eq!(KernelBackend::detect(), KernelBackend::Simd);
        }
    }
}
