//! Lock-free in-flight task table with quiescent-state reclamation —
//! the shared pool's completion queue without the completion-queue mutex
//! (DESIGN.md §11).
//!
//! Every submitted [`IterationTask`] occupies one slot holding the task
//! `Arc`, one **cell** per sampler shard for that shard's
//! [`DecisionBatch`], one packed **claim word** per cell, and a `reported`
//! bitmask. The life of a slot:
//!
//! ```text
//! FREE/RETIRED --alloc (CAS)--> RESERVED --init--> PUBLISHED
//!     PUBLISHED --all cells reported, collector CAS--> COLLECTING
//!     COLLECTING --cells moved out--> RETIRED  (contents reclaimed at
//!                                               next alloc, when no
//!                                               reader holds a pin)
//! ```
//!
//! **Claims.** A worker takes a cell by CAS-ing its claim word from 0 to
//! `(1<<63) | (worker << 32) | incarnation` — claim and claimant identity
//! are one atomic word, so crash recovery can release a *dead*
//! incarnation's claim with a single CAS and can never race a live
//! worker's (a live claim carries the live incarnation, which recovery
//! does not match). Duplicate task messages are therefore harmless: the
//! claim CAS admits exactly one decider per cell.
//!
//! **Pins (quiescent-state reclamation).** Readers guard short accesses to
//! a slot's contents by incrementing `readers` and *then* validating
//! `(state, task_id)`; allocation reuses a RETIRED slot only after
//! observing `readers == 0` from RESERVED, so contents are never dropped
//! while any validated reader exists. Pins are held only across the
//! atomic claim/write/read sections — never across a decision — so
//! reclamation never waits on user code.

use super::service::{DecisionBatch, IterationTask};
use crate::trace;
use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

const FREE: u64 = 0;
const RESERVED: u64 = 1;
const PUBLISHED: u64 = 2;
const COLLECTING: u64 = 3;
const RETIRED: u64 = 4;

/// Pack a cell claim: bit 63 = claimed, bits 62..32 = worker id,
/// bits 31..0 = that worker thread's incarnation.
pub fn claim_pack(worker: usize, incarnation: u32) -> u64 {
    (1u64 << 63) | ((worker as u64) << 32) | incarnation as u64
}

/// Worker id carried by a packed claim word.
pub fn claim_worker(packed: u64) -> usize {
    ((packed >> 32) & 0x7FFF_FFFF) as usize
}

struct Slot {
    state: AtomicU64,
    task_id: AtomicU64,
    /// Pin count — readers currently validated against this slot.
    readers: AtomicU32,
    /// Bit `v` set once cell `v`'s batch is written.
    reported: AtomicU64,
    claims: Box<[AtomicU64]>,
    cells: Box<[UnsafeCell<Option<DecisionBatch>>]>,
    task: UnsafeCell<Option<Arc<IterationTask>>>,
}

// Cell/task contents are only touched by the claim/pin/state protocol
// above; every access path is argued at its unsafe block.
unsafe impl Send for Slot {}
unsafe impl Sync for Slot {}

/// RAII pin on one slot (see module docs). Dropping it quiesces the read.
pub struct Pin<'a> {
    slot: &'a Slot,
}

impl Drop for Pin<'_> {
    fn drop(&mut self) {
        self.slot.readers.fetch_sub(1, Ordering::Release);
    }
}

/// A completed task moved out of its slot by the collector.
pub struct TakenTask {
    pub task: Arc<IterationTask>,
    /// One batch per cell, in cell (shard) order.
    pub batches: Vec<DecisionBatch>,
    /// The worker ids whose claims answered each cell — crash-loop
    /// breakers reset on these (proof of forward progress).
    pub claimants: Vec<usize>,
}

/// A cell crash recovery wants re-decided: the claim (if any) belonged to
/// a dead incarnation and was released, or the in-flight message may have
/// died with its consumer.
pub struct Resubmit {
    pub task_id: u64,
    pub slot: usize,
    pub shard: usize,
    pub task: Arc<IterationTask>,
}

/// Fixed-size lock-free table of in-flight tasks (see module docs).
pub struct TaskSlots {
    slots: Box<[Slot]>,
    m: usize,
    full_mask: u64,
    /// Rotating allocation cursor (load spread, not correctness).
    cursor: AtomicUsize,
}

impl TaskSlots {
    /// `capacity` in-flight tasks, `m` cells each. `m <= 63` (the reported
    /// bitmask plus the claim packing bound it).
    pub fn new(capacity: usize, m: usize) -> TaskSlots {
        assert!(m >= 1 && m <= 63, "sampler count {m} out of range 1..=63");
        let slots: Box<[Slot]> = (0..capacity.max(1))
            .map(|_| Slot {
                state: AtomicU64::new(FREE),
                task_id: AtomicU64::new(0),
                readers: AtomicU32::new(0),
                reported: AtomicU64::new(0),
                claims: (0..m).map(|_| AtomicU64::new(0)).collect(),
                cells: (0..m).map(|_| UnsafeCell::new(None)).collect(),
                task: UnsafeCell::new(None),
            })
            .collect();
        TaskSlots {
            slots,
            m,
            full_mask: (1u64 << m) - 1,
            cursor: AtomicUsize::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Try to place a task, reclaiming a RETIRED slot's contents if its
    /// readers have quiesced. Hands the task back when every slot is in
    /// flight.
    pub fn try_publish(
        &self,
        task: Arc<IterationTask>,
    ) -> Result<usize, Arc<IterationTask>> {
        let n = self.slots.len();
        let start = self.cursor.fetch_add(1, Ordering::Relaxed);
        for off in 0..n {
            let idx = (start + off) % n;
            let slot = &self.slots[idx];
            let st = slot.state.load(Ordering::Acquire);
            if st != FREE && st != RETIRED {
                continue;
            }
            if slot
                .state
                .compare_exchange(st, RESERVED, Ordering::AcqRel, Ordering::Acquire)
                .is_err()
            {
                continue;
            }
            // Reclamation gate: contents may only be dropped once no
            // pinned reader remains. A racing pin that lands after the
            // CAS sees RESERVED at validation and backs out, so a zero
            // here is stable for the duration of the init.
            if slot.readers.load(Ordering::Acquire) != 0 {
                slot.state.store(st, Ordering::Release);
                continue;
            }
            // Exclusive: state is RESERVED (no new pins validate) and
            // readers == 0 (no old pin outstanding).
            unsafe {
                *slot.task.get() = Some(task);
                for cell in slot.cells.iter() {
                    *cell.get() = None;
                }
            }
            let id = unsafe { (*slot.task.get()).as_ref().unwrap().iter };
            slot.task_id.store(id, Ordering::Relaxed);
            slot.reported.store(0, Ordering::Relaxed);
            for c in slot.claims.iter() {
                c.store(0, Ordering::Relaxed);
            }
            slot.state.store(PUBLISHED, Ordering::Release);
            return Ok(idx);
        }
        Err(task)
    }

    /// Place a task, spinning (yield) while the table is full — the
    /// submit-side backpressure, analogous to a full ring.
    pub fn publish(&self, mut task: Arc<IterationTask>) -> usize {
        loop {
            match self.try_publish(task) {
                Ok(idx) => return idx,
                Err(back) => {
                    task = back;
                    std::thread::yield_now();
                }
            }
        }
    }

    /// Pin slot `idx` if it still carries `task_id` in a readable state.
    pub fn pin(&self, idx: usize, task_id: u64) -> Option<Pin<'_>> {
        let slot = &self.slots[idx];
        slot.readers.fetch_add(1, Ordering::AcqRel);
        let st = slot.state.load(Ordering::Acquire);
        if st == PUBLISHED && slot.task_id.load(Ordering::Relaxed) == task_id {
            Some(Pin { slot })
        } else {
            slot.readers.fetch_sub(1, Ordering::Release);
            None
        }
    }

    /// CAS-claim cell `shard` of slot `idx` with a packed claim word.
    /// Exactly one caller wins per cell lifetime; duplicates bounce off.
    /// Caller must hold a pin on the slot.
    pub fn try_claim(&self, idx: usize, shard: usize, packed: u64) -> bool {
        self.slots[idx].claims[shard]
            .compare_exchange(0, packed, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }

    /// Write cell `shard`'s batch and mark it reported. Caller must hold a
    /// pin *and* the cell's claim — the claim makes this the cell's unique
    /// writer, the pin keeps the contents alive across the write.
    pub fn publish_cell(&self, idx: usize, shard: usize, batch: DecisionBatch) {
        let slot = &self.slots[idx];
        unsafe { *slot.cells[shard].get() = Some(batch) };
        slot.reported.fetch_or(1u64 << shard, Ordering::AcqRel);
    }

    /// Collect task `task_id` if every cell reported: moves the batches
    /// (and the task `Arc`, releasing its logits) out and retires the
    /// slot. `None` while incomplete or unknown.
    pub fn try_take(&self, task_id: u64) -> Option<TakenTask> {
        for slot in self.slots.iter() {
            if slot.state.load(Ordering::Acquire) != PUBLISHED
                || slot.task_id.load(Ordering::Relaxed) != task_id
            {
                continue;
            }
            if slot.reported.load(Ordering::Acquire) != self.full_mask {
                return None;
            }
            if slot
                .state
                .compare_exchange(PUBLISHED, COLLECTING, Ordering::AcqRel, Ordering::Acquire)
                .is_err()
            {
                return None; // another collector of the same id won
            }
            // Exclusive: COLLECTING blocks writers (pin validation) and
            // allocation (needs RETIRED); all cell writes happened-before
            // the reported mask read above.
            let claimants: Vec<usize> = slot
                .claims
                .iter()
                .map(|c| claim_worker(c.load(Ordering::Relaxed)))
                .collect();
            let batches: Vec<DecisionBatch> = slot
                .cells
                .iter()
                .filter_map(|c| unsafe { (*c.get()).take() })
                .collect();
            let task = unsafe { (*slot.task.get()).take() }.expect("published slot has task");
            slot.state.store(RETIRED, Ordering::Release);
            return Some(TakenTask { task, batches, claimants });
        }
        None
    }

    /// Retire every in-flight task of one task-id namespace (a dead
    /// replica's): they will never be collected, so their slots go
    /// straight to RETIRED and are reclaimed at the next allocation. Must
    /// not race submits *from that namespace* (the namespace owner is dead
    /// by contract); concurrent submits, decisions, and collects of other
    /// namespaces are fine.
    pub fn purge_namespace(&self, task_base: u64, ns_mask: u64) {
        for slot in self.slots.iter() {
            if slot.state.load(Ordering::Acquire) == PUBLISHED
                && slot.task_id.load(Ordering::Relaxed) & ns_mask == task_base
            {
                let _ = slot.state.compare_exchange(
                    PUBLISHED,
                    RETIRED,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                );
            }
        }
    }

    /// Crash recovery: release every claim held by a dead worker
    /// incarnation (`packed_dead`) and list every unreported, now-unclaimed
    /// cell for resubmission. Cells whose message may still sit in a live
    /// ring are listed too — duplicates are resolved by the claim CAS.
    pub fn sweep_dead_claims(&self, packed_dead: u64) -> Vec<Resubmit> {
        let mut out = Vec::new();
        for (idx, slot) in self.slots.iter().enumerate() {
            let task_id = slot.task_id.load(Ordering::Relaxed);
            let Some(pin) = self.pin(idx, task_id) else { continue };
            let reported = slot.reported.load(Ordering::Acquire);
            for shard in 0..self.m {
                if reported & (1u64 << shard) != 0 {
                    continue;
                }
                let claim = &slot.claims[shard];
                if claim.load(Ordering::Acquire) == packed_dead {
                    // Release the dead claim; a live claim never matches a
                    // dead incarnation, so this cannot steal a live cell.
                    if claim
                        .compare_exchange(packed_dead, 0, Ordering::AcqRel, Ordering::Acquire)
                        .is_ok()
                    {
                        trace::metrics::inc(&trace::metrics::counters().claim_releases);
                        trace::instant(trace::Kind::SvcClaimRelease, task_id, shard as u64);
                    }
                }
                if claim.load(Ordering::Acquire) == 0 {
                    // Pinned + PUBLISHED: the task field is stable.
                    let task = unsafe { (*slot.task.get()).as_ref().unwrap().clone() };
                    trace::instant(trace::Kind::SlotRecover, task_id, shard as u64);
                    out.push(Resubmit { task_id, slot: idx, shard, task });
                }
            }
            drop(pin);
        }
        out.sort_unstable_by_key(|r| (r.task_id, r.shard));
        out
    }

    /// How many slots are currently in flight (PUBLISHED or COLLECTING) —
    /// observability for tests and the chaos harness.
    pub fn in_flight(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| {
                let st = s.state.load(Ordering::Relaxed);
                st == PUBLISHED || st == COLLECTING
            })
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decision::verify::Verdict;

    fn mk_task(iter: u64) -> Arc<IterationTask> {
        Arc::new(IterationTask {
            iter,
            mb: 0,
            views: Vec::new(),
            columns: Arc::new(Vec::new()),
            recs: Arc::new(Vec::new()),
            pre: Arc::new(Vec::new()),
            drafts: Arc::new(Vec::new()),
        })
    }

    fn mk_batch(iter: u64, sampler: usize) -> DecisionBatch {
        DecisionBatch {
            iter,
            mb: 0,
            sampler_id: sampler,
            decisions: vec![(
                sampler,
                sampler as u64,
                Verdict { tokens: vec![iter as u32], accepted: 0, proposed: 0 },
            )],
            busy_s: 0.0,
            start_s: 0.0,
            end_s: 0.0,
        }
    }

    /// Full protocol walk: publish → claim/write per cell → take.
    #[test]
    fn publish_claim_report_collect_roundtrip() {
        let slots = TaskSlots::new(4, 2);
        let idx = slots.try_publish(mk_task(42)).ok().unwrap();
        assert!(slots.try_take(42).is_none(), "incomplete: only 0/2 cells");
        for shard in 0..2 {
            let pin = slots.pin(idx, 42).expect("published slot pins");
            assert!(slots.try_claim(idx, shard, claim_pack(shard, 1)));
            assert!(!slots.try_claim(idx, shard, claim_pack(1 - shard, 1)), "dup claim");
            slots.publish_cell(idx, shard, mk_batch(42, shard));
            drop(pin);
        }
        let taken = slots.try_take(42).expect("complete");
        assert_eq!(taken.batches.len(), 2);
        assert_eq!(taken.claimants, vec![0, 1]);
        assert!(slots.try_take(42).is_none(), "collected once");
    }

    #[test]
    fn table_full_backpressures_and_reuses_retired() {
        let slots = TaskSlots::new(2, 1);
        let a = slots.try_publish(mk_task(1)).ok().unwrap();
        let _b = slots.try_publish(mk_task(2)).ok().unwrap();
        assert!(slots.try_publish(mk_task(3)).is_err(), "table full");
        let pin = slots.pin(a, 1).unwrap();
        assert!(slots.try_claim(a, 0, claim_pack(0, 1)));
        slots.publish_cell(a, 0, mk_batch(1, 0));
        drop(pin);
        assert!(slots.try_take(1).is_some());
        let c = slots.try_publish(mk_task(3)).unwrap_or_else(|_| panic!("retired slot reused"));
        assert_eq!(c, a);
    }

    /// The reclamation invariant: a RETIRED slot is not reused while a
    /// reader still holds a pin taken before retirement.
    #[test]
    fn pinned_slot_is_not_reclaimed() {
        let slots = TaskSlots::new(1, 1);
        let idx = slots.try_publish(mk_task(5)).ok().unwrap();
        let pin = slots.pin(idx, 5).unwrap();
        {
            let p2 = slots.pin(idx, 5).unwrap();
            slots.try_claim(idx, 0, claim_pack(0, 1));
            slots.publish_cell(idx, 0, mk_batch(5, 0));
            drop(p2);
        }
        assert!(slots.try_take(5).is_some()); // slot now RETIRED
        assert!(
            slots.try_publish(mk_task(6)).is_err(),
            "pinned RETIRED slot must not be reclaimed"
        );
        drop(pin);
        assert!(slots.try_publish(mk_task(6)).is_ok(), "quiesced: reusable");
    }

    #[test]
    fn pin_validates_state_and_id() {
        let slots = TaskSlots::new(2, 1);
        let idx = slots.try_publish(mk_task(9)).ok().unwrap();
        assert!(slots.pin(idx, 8).is_none(), "wrong id");
        assert!(slots.pin(idx, 9).is_some());
        slots.purge_namespace(0, 0); // everything matches base 0, mask 0
        assert!(slots.pin(idx, 9).is_none(), "retired by purge");
    }

    #[test]
    fn purge_retires_only_matching_namespace() {
        use crate::decision::service::{TASK_NS_MASK, TASK_NS_SHIFT};
        let slots = TaskSlots::new(4, 1);
        let a = 1u64 << TASK_NS_SHIFT;
        let b = 2u64 << TASK_NS_SHIFT;
        slots.try_publish(mk_task(a | 1)).ok().unwrap();
        let bi = slots.try_publish(mk_task(b | 1)).ok().unwrap();
        slots.purge_namespace(a, TASK_NS_MASK);
        assert_eq!(slots.in_flight(), 1);
        assert!(slots.pin(bi, b | 1).is_some(), "other namespace untouched");
    }

    #[test]
    fn sweep_releases_dead_claims_and_lists_unreported_cells() {
        let slots = TaskSlots::new(2, 2);
        let idx = slots.try_publish(mk_task(7)).ok().unwrap();
        // Worker 0 (incarnation 1) claims cell 0 then "dies" pre-report;
        // cell 1 reports normally via worker 1.
        let pin = slots.pin(idx, 7).unwrap();
        assert!(slots.try_claim(idx, 0, claim_pack(0, 1)));
        assert!(slots.try_claim(idx, 1, claim_pack(1, 1)));
        slots.publish_cell(idx, 1, mk_batch(7, 1));
        drop(pin);
        let released_before =
            trace::metrics::counters().get("claim_releases").unwrap();
        let resub = slots.sweep_dead_claims(claim_pack(0, 1));
        assert!(
            trace::metrics::counters().get("claim_releases").unwrap() > released_before,
            "releasing a dead claim must bump the claim_releases counter"
        );
        assert_eq!(resub.len(), 1);
        assert_eq!((resub[0].slot, resub[0].shard, resub[0].task_id), (idx, 0, 7));
        // The claim is free again: the respawned incarnation can take it.
        let pin = slots.pin(idx, 7).unwrap();
        assert!(slots.try_claim(idx, 0, claim_pack(0, 2)));
        slots.publish_cell(idx, 0, mk_batch(7, 0));
        drop(pin);
        assert!(slots.try_take(7).is_some());
    }

    #[test]
    fn sweep_never_releases_live_claims() {
        let slots = TaskSlots::new(1, 1);
        let idx = slots.try_publish(mk_task(3)).ok().unwrap();
        let pin = slots.pin(idx, 3).unwrap();
        assert!(slots.try_claim(idx, 0, claim_pack(0, 2))); // live incarnation 2
        drop(pin);
        let resub = slots.sweep_dead_claims(claim_pack(0, 1)); // dead inc 1
        assert!(resub.is_empty(), "live claim must survive a dead sweep");
    }
}
