//! Lock-free in-flight task table with quiescent-state reclamation —
//! the shared pool's completion queue without the completion-queue mutex
//! (DESIGN.md §11).
//!
//! Every submitted [`IterationTask`] occupies one slot holding the task
//! `Arc`, one **cell** per sampler shard for that shard's
//! [`DecisionBatch`], one packed **claim word** per cell, and a `reported`
//! bitmask. The life of a slot:
//!
//! ```text
//! FREE/RETIRED --alloc (CAS)--> RESERVED --init--> PUBLISHED
//!     PUBLISHED --all cells reported, collector CAS--> COLLECTING
//!     COLLECTING --cells moved out--> RETIRED  (contents reclaimed at
//!                                               next alloc, when no
//!                                               reader holds a pin)
//! ```
//!
//! **Claims.** A worker takes a cell by CAS-ing its claim word from 0 to
//! `(1<<63) | (worker << 32) | incarnation` — claim and claimant identity
//! are one atomic word, so crash recovery can release a *dead*
//! incarnation's claim with a single CAS and can never race a live
//! worker's (a live claim carries the live incarnation, which recovery
//! does not match). Duplicate task messages are therefore harmless: the
//! claim CAS admits exactly one decider per cell.
//!
//! **Pins (quiescent-state reclamation).** Readers guard short accesses to
//! a slot's contents by incrementing `readers` and *then* validating
//! `(state, task_id)`; allocation reuses a RETIRED slot only after
//! observing `readers == 0` from RESERVED, so contents are never dropped
//! while any validated reader exists. Pins are held only across the
//! atomic claim/write/read sections — never across a decision — so
//! reclamation never waits on user code.
//!
//! The pin/reclaim pair is a store-buffering (Dekker) race: the reader
//! stores `readers += 1` then loads `state`; the reclaimer stores
//! `state = RESERVED` then loads `readers`. With only Acquire/Release
//! both sides may read their stale counterpart — the reader validates
//! against the *old* PUBLISHED while the reclaimer sees `readers == 0`
//! and starts dropping contents under the pin. The four racing
//! operations are therefore SeqCst (free on x86: the RMWs are already
//! locked instructions, SeqCst loads are plain `mov`s): in the single
//! total order, either the reclaimer's state CAS precedes the reader's
//! state load (the reader sees RESERVED and backs out) or the reader's
//! increment precedes the reclaimer's readers load (the reclaimer sees
//! the pin and backs off). `rust/tests/loom_models.rs` model-checks this
//! protocol — including the PR 6 regression (dead-claim release racing a
//! live re-claim across incarnations) — under `make loom`.

use super::service::{DecisionBatch, IterationTask};
use crate::trace;
use crate::util::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use crate::util::sync::cell::UnsafeCell;
use crate::util::sync::thread;
use std::sync::Arc;

const FREE: u64 = 0;
const RESERVED: u64 = 1;
const PUBLISHED: u64 = 2;
const COLLECTING: u64 = 3;
const RETIRED: u64 = 4;

/// Pack a cell claim: bit 63 = claimed, bits 62..32 = worker id,
/// bits 31..0 = that worker thread's incarnation.
pub fn claim_pack(worker: usize, incarnation: u32) -> u64 {
    (1u64 << 63) | ((worker as u64) << 32) | incarnation as u64
}

/// Worker id carried by a packed claim word.
pub fn claim_worker(packed: u64) -> usize {
    ((packed >> 32) & 0x7FFF_FFFF) as usize
}

struct Slot {
    state: AtomicU64,
    task_id: AtomicU64,
    /// Pin count — readers currently validated against this slot.
    readers: AtomicU32,
    /// Bit `v` set once cell `v`'s batch is written.
    reported: AtomicU64,
    claims: Box<[AtomicU64]>,
    cells: Box<[UnsafeCell<Option<DecisionBatch>>]>,
    /// The task `Arc`. Written only during init (RESERVED + quiesced);
    /// read-only for the rest of the slot's life — `try_take` *clones*
    /// it out rather than moving it, so a pinned reader (the dead-claim
    /// sweep) can never race a collector's write. The slot's reference
    /// drops at the next reclamation of this slot.
    task: UnsafeCell<Option<Arc<IterationTask>>>,
}

// SAFETY: cell/task contents are only touched under the claim/pin/state
// protocol above; every access path is argued at its unsafe block.
unsafe impl Send for Slot {}
// SAFETY: as above — the protocol serializes all cell/task access.
unsafe impl Sync for Slot {}

/// RAII pin on one slot (see module docs). Dropping it quiesces the read.
pub struct Pin<'a> {
    slot: &'a Slot,
}

impl Drop for Pin<'_> {
    fn drop(&mut self) {
        // Release orders this reader's content reads before the unpin, so
        // a reclaimer that observes the decrement cannot drop contents
        // under a read that is still in flight.
        self.slot.readers.fetch_sub(1, Ordering::Release);
    }
}

/// A completed task collected from its slot.
pub struct TakenTask {
    pub task: Arc<IterationTask>,
    /// One batch per cell, in cell (shard) order.
    pub batches: Vec<DecisionBatch>,
    /// The worker ids whose claims answered each cell — crash-loop
    /// breakers reset on these (proof of forward progress).
    pub claimants: Vec<usize>,
}

/// A cell crash recovery wants re-decided: the claim (if any) belonged to
/// a dead incarnation and was released, or the in-flight message may have
/// died with its consumer.
pub struct Resubmit {
    pub task_id: u64,
    pub slot: usize,
    pub shard: usize,
    pub task: Arc<IterationTask>,
}

/// Fixed-size lock-free table of in-flight tasks (see module docs).
pub struct TaskSlots {
    slots: Box<[Slot]>,
    m: usize,
    full_mask: u64,
    /// Rotating allocation cursor (load spread, not correctness).
    cursor: AtomicUsize,
}

impl TaskSlots {
    /// `capacity` in-flight tasks, `m` cells each. `m <= 63` (the reported
    /// bitmask plus the claim packing bound it).
    pub fn new(capacity: usize, m: usize) -> TaskSlots {
        assert!(m >= 1 && m <= 63, "sampler count {m} out of range 1..=63");
        let slots: Box<[Slot]> = (0..capacity.max(1))
            .map(|_| Slot {
                state: AtomicU64::new(FREE),
                task_id: AtomicU64::new(0),
                readers: AtomicU32::new(0),
                reported: AtomicU64::new(0),
                claims: (0..m).map(|_| AtomicU64::new(0)).collect(),
                cells: (0..m).map(|_| UnsafeCell::new(None)).collect(),
                task: UnsafeCell::new(None),
            })
            .collect();
        TaskSlots {
            slots,
            m,
            full_mask: (1u64 << m) - 1,
            cursor: AtomicUsize::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Try to place a task, reclaiming a RETIRED slot's contents if its
    /// readers have quiesced. Hands the task back when every slot is in
    /// flight.
    pub fn try_publish(
        &self,
        task: Arc<IterationTask>,
    ) -> Result<usize, Arc<IterationTask>> {
        let n = self.slots.len();
        // ordering: the cursor only spreads allocation scans across slots
        // for load balance; any value is correct.
        let start = self.cursor.fetch_add(1, Ordering::Relaxed);
        for off in 0..n {
            let idx = (start + off) % n;
            let slot = &self.slots[idx];
            let st = slot.state.load(Ordering::Acquire);
            if st != FREE && st != RETIRED {
                continue;
            }
            // ordering: SeqCst on success — one half of the Dekker pair
            // with `pin` (module docs): this store must be totally
            // ordered against the readers load below and the reader's
            // increment/validate pair. Acquire on failure only observes
            // the newer state.
            if slot
                .state
                .compare_exchange(st, RESERVED, Ordering::SeqCst, Ordering::Acquire)
                .is_err()
            {
                continue;
            }
            // Reclamation gate: contents may only be dropped once no
            // pinned reader remains. A racing pin either lands its
            // increment before this load (we see it and back off) or
            // validates after our CAS, sees RESERVED, and backs out —
            // the SeqCst total order rules out the both-stale outcome.
            if slot.readers.load(Ordering::SeqCst) != 0 {
                slot.state.store(st, Ordering::Release);
                continue;
            }
            let id = task.iter;
            // SAFETY: state is RESERVED (no new pin validates) and
            // readers == 0 was observed after the SeqCst CAS (no old pin
            // outstanding), so this thread has exclusive access to the
            // task and cell contents until the PUBLISHED store below.
            slot.task.with_mut(|t| unsafe { *t = Some(task) });
            for cell in slot.cells.iter() {
                // SAFETY: as above — RESERVED + quiesced readers.
                cell.with_mut(|c| unsafe { *c = None });
            }
            // ordering: Relaxed init stores are published by the Release
            // store of PUBLISHED below; no reader validates before it.
            slot.task_id.store(id, Ordering::Relaxed);
            // ordering: as above — published by the Release below.
            slot.reported.store(0, Ordering::Relaxed);
            for c in slot.claims.iter() {
                // ordering: as above — published by the Release below.
                c.store(0, Ordering::Relaxed);
            }
            slot.state.store(PUBLISHED, Ordering::Release);
            return Ok(idx);
        }
        Err(task)
    }

    /// Place a task, spinning (yield) while the table is full — the
    /// submit-side backpressure, analogous to a full ring.
    pub fn publish(&self, mut task: Arc<IterationTask>) -> usize {
        loop {
            match self.try_publish(task) {
                Ok(idx) => return idx,
                Err(back) => {
                    task = back;
                    thread::yield_now();
                }
            }
        }
    }

    /// Pin slot `idx` if it still carries `task_id` in a readable state.
    pub fn pin(&self, idx: usize, task_id: u64) -> Option<Pin<'_>> {
        let slot = &self.slots[idx];
        // ordering: SeqCst increment + SeqCst validate are the reader
        // half of the Dekker pair with `try_publish` (module docs).
        slot.readers.fetch_add(1, Ordering::SeqCst);
        let st = slot.state.load(Ordering::SeqCst);
        // ordering: task_id Relaxed is sound — it was stored before the
        // PUBLISHED Release store, and the validate above reads PUBLISHED
        // with at least Acquire strength, so the id is the fresh one.
        if st == PUBLISHED && slot.task_id.load(Ordering::Relaxed) == task_id {
            Some(Pin { slot })
        } else {
            slot.readers.fetch_sub(1, Ordering::Release);
            None
        }
    }

    /// CAS-claim cell `shard` of slot `idx` with a packed claim word.
    /// Exactly one caller wins per cell lifetime; duplicates bounce off.
    /// Caller must hold a pin on the slot.
    pub fn try_claim(&self, idx: usize, shard: usize, packed: u64) -> bool {
        self.slots[idx].claims[shard]
            .compare_exchange(0, packed, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }

    /// Write cell `shard`'s batch and mark it reported. Caller must hold a
    /// pin *and* the cell's claim — the claim makes this the cell's unique
    /// writer, the pin keeps the contents alive across the write.
    pub fn publish_cell(&self, idx: usize, shard: usize, batch: DecisionBatch) {
        let slot = &self.slots[idx];
        // SAFETY: the caller won cell `shard`'s claim CAS, making this
        // the cell's unique writer; the pin keeps reclamation away, and
        // the collector only reads the cell after the reported bit below.
        slot.cells[shard].with_mut(|c| unsafe { *c = Some(batch) });
        slot.reported.fetch_or(1u64 << shard, Ordering::AcqRel);
    }

    /// Collect task `task_id` if every cell reported: moves the batches
    /// out (cloning the task `Arc`; the slot's reference is reclaimed at
    /// the next allocation) and retires the slot. `None` while incomplete
    /// or unknown.
    pub fn try_take(&self, task_id: u64) -> Option<TakenTask> {
        for slot in self.slots.iter() {
            // ordering: task_id Relaxed after the Acquire state load —
            // fresh for the same reason as in `pin`.
            if slot.state.load(Ordering::Acquire) != PUBLISHED
                || slot.task_id.load(Ordering::Relaxed) != task_id
            {
                continue;
            }
            if slot.reported.load(Ordering::Acquire) != self.full_mask {
                return None;
            }
            if slot
                .state
                .compare_exchange(PUBLISHED, COLLECTING, Ordering::AcqRel, Ordering::Acquire)
                .is_err()
            {
                return None; // another collector of the same id won
            }
            // Cell access is exclusive: COLLECTING blocks writers (claim
            // holders re-validate their pin) and allocation (needs
            // RETIRED); all cell writes happened-before the reported mask
            // read above. The task cell is NOT exclusive — a pinned
            // sweep may be reading it — so it is cloned, never moved.
            let claimants: Vec<usize> = slot
                .claims
                .iter()
                .map(|c| claim_worker(c.load(Ordering::Relaxed)))
                .collect();
            let batches: Vec<DecisionBatch> = slot
                .cells
                .iter()
                // SAFETY: exclusive per the COLLECTING argument above.
                .filter_map(|c| c.with_mut(|p| unsafe { (*p).take() }))
                .collect();
            // SAFETY: shared read — the task cell is written only during
            // init (RESERVED + quiesced, happens-before PUBLISHED which
            // this thread observed); concurrent pinned readers also only
            // read it.
            let task = slot
                .task
                .with(|t| unsafe { (*t).clone() })
                .expect("published slot has task");
            slot.state.store(RETIRED, Ordering::Release);
            return Some(TakenTask { task, batches, claimants });
        }
        None
    }

    /// Retire every in-flight task of one task-id namespace (a dead
    /// replica's): they will never be collected, so their slots go
    /// straight to RETIRED and are reclaimed at the next allocation. Must
    /// not race submits *from that namespace* (the namespace owner is dead
    /// by contract); concurrent submits, decisions, and collects of other
    /// namespaces are fine.
    pub fn purge_namespace(&self, task_base: u64, ns_mask: u64) {
        for slot in self.slots.iter() {
            // ordering: task_id Relaxed after the Acquire state load —
            // fresh for the same reason as in `pin`.
            if slot.state.load(Ordering::Acquire) == PUBLISHED
                && slot.task_id.load(Ordering::Relaxed) & ns_mask == task_base
            {
                let _ = slot.state.compare_exchange(
                    PUBLISHED,
                    RETIRED,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                );
            }
        }
    }

    /// Crash recovery: release every claim held by a dead worker
    /// incarnation (`packed_dead`) and list every unreported, now-unclaimed
    /// cell for resubmission. Cells whose message may still sit in a live
    /// ring are listed too — duplicates are resolved by the claim CAS.
    pub fn sweep_dead_claims(&self, packed_dead: u64) -> Vec<Resubmit> {
        let mut out = Vec::new();
        for (idx, slot) in self.slots.iter().enumerate() {
            // ordering: an unvalidated probe — `pin` below re-validates
            // (state, task_id) with the full protocol before any use.
            let task_id = slot.task_id.load(Ordering::Relaxed);
            let Some(pin) = self.pin(idx, task_id) else { continue };
            let reported = slot.reported.load(Ordering::Acquire);
            for shard in 0..self.m {
                if reported & (1u64 << shard) != 0 {
                    continue;
                }
                let claim = &slot.claims[shard];
                if claim.load(Ordering::Acquire) == packed_dead {
                    // Release the dead claim; a live claim never matches a
                    // dead incarnation, so this cannot steal a live cell.
                    if claim
                        .compare_exchange(packed_dead, 0, Ordering::AcqRel, Ordering::Acquire)
                        .is_ok()
                    {
                        trace::metrics::inc(&trace::metrics::counters().claim_releases);
                        trace::instant(trace::Kind::SvcClaimRelease, task_id, shard as u64);
                    }
                }
                if claim.load(Ordering::Acquire) == 0 {
                    // SAFETY: shared read under the pin — the task cell is
                    // only written during init, which cannot start while
                    // this pin is held; `try_take` also only reads it.
                    let task = slot
                        .task
                        .with(|t| unsafe { (*t).clone() })
                        .expect("pinned slot has task");
                    trace::instant(trace::Kind::SlotRecover, task_id, shard as u64);
                    out.push(Resubmit { task_id, slot: idx, shard, task });
                }
            }
            drop(pin);
        }
        out.sort_unstable_by_key(|r| (r.task_id, r.shard));
        out
    }

    /// How many slots are currently in flight (PUBLISHED or COLLECTING) —
    /// observability for tests and the chaos harness.
    pub fn in_flight(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| {
                let st = s.state.load(Ordering::Relaxed);
                st == PUBLISHED || st == COLLECTING
            })
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decision::verify::Verdict;

    fn mk_task(iter: u64) -> Arc<IterationTask> {
        Arc::new(IterationTask {
            iter,
            mb: 0,
            views: Vec::new(),
            columns: Arc::new(Vec::new()),
            recs: Arc::new(Vec::new()),
            pre: Arc::new(Vec::new()),
            drafts: Arc::new(Vec::new()),
        })
    }

    fn mk_batch(iter: u64, sampler: usize) -> DecisionBatch {
        DecisionBatch {
            iter,
            mb: 0,
            sampler_id: sampler,
            decisions: vec![(
                sampler,
                sampler as u64,
                Verdict { tokens: vec![iter as u32], accepted: 0, proposed: 0 },
            )],
            busy_s: 0.0,
            start_s: 0.0,
            end_s: 0.0,
        }
    }

    /// Full protocol walk: publish → claim/write per cell → take.
    #[test]
    fn publish_claim_report_collect_roundtrip() {
        let slots = TaskSlots::new(4, 2);
        let idx = slots.try_publish(mk_task(42)).ok().unwrap();
        assert!(slots.try_take(42).is_none(), "incomplete: only 0/2 cells");
        for shard in 0..2 {
            let pin = slots.pin(idx, 42).expect("published slot pins");
            assert!(slots.try_claim(idx, shard, claim_pack(shard, 1)));
            assert!(!slots.try_claim(idx, shard, claim_pack(1 - shard, 1)), "dup claim");
            slots.publish_cell(idx, shard, mk_batch(42, shard));
            drop(pin);
        }
        let taken = slots.try_take(42).expect("complete");
        assert_eq!(taken.batches.len(), 2);
        assert_eq!(taken.claimants, vec![0, 1]);
        assert!(slots.try_take(42).is_none(), "collected once");
    }

    #[test]
    fn table_full_backpressures_and_reuses_retired() {
        let slots = TaskSlots::new(2, 1);
        let a = slots.try_publish(mk_task(1)).ok().unwrap();
        let _b = slots.try_publish(mk_task(2)).ok().unwrap();
        assert!(slots.try_publish(mk_task(3)).is_err(), "table full");
        let pin = slots.pin(a, 1).unwrap();
        assert!(slots.try_claim(a, 0, claim_pack(0, 1)));
        slots.publish_cell(a, 0, mk_batch(1, 0));
        drop(pin);
        assert!(slots.try_take(1).is_some());
        let c = slots.try_publish(mk_task(3)).unwrap_or_else(|_| panic!("retired slot reused"));
        assert_eq!(c, a);
    }

    /// The reclamation invariant: a RETIRED slot is not reused while a
    /// reader still holds a pin taken before retirement.
    #[test]
    fn pinned_slot_is_not_reclaimed() {
        let slots = TaskSlots::new(1, 1);
        let idx = slots.try_publish(mk_task(5)).ok().unwrap();
        let pin = slots.pin(idx, 5).unwrap();
        {
            let p2 = slots.pin(idx, 5).unwrap();
            slots.try_claim(idx, 0, claim_pack(0, 1));
            slots.publish_cell(idx, 0, mk_batch(5, 0));
            drop(p2);
        }
        assert!(slots.try_take(5).is_some()); // slot now RETIRED
        assert!(
            slots.try_publish(mk_task(6)).is_err(),
            "pinned RETIRED slot must not be reclaimed"
        );
        drop(pin);
        assert!(slots.try_publish(mk_task(6)).is_ok(), "quiesced: reusable");
    }

    /// `try_take` clones the task rather than moving it, so a collect
    /// racing a pinned sweep reader can never invalidate the sweep's
    /// reference — and the slot's own reference lives until reuse.
    #[test]
    fn collect_under_pin_keeps_sweep_reference_valid() {
        let slots = TaskSlots::new(1, 2);
        let idx = slots.try_publish(mk_task(11)).ok().unwrap();
        // Cell 0 reports; cell 1's claimant (worker 0, incarnation 1)
        // "dies" before reporting, so a sweep will list cell 1.
        let pin = slots.pin(idx, 11).unwrap();
        assert!(slots.try_claim(idx, 0, claim_pack(1, 1)));
        slots.publish_cell(idx, 0, mk_batch(11, 1));
        assert!(slots.try_claim(idx, 1, claim_pack(0, 1)));
        drop(pin);
        let resub = slots.sweep_dead_claims(claim_pack(0, 1));
        assert_eq!(resub.len(), 1);
        assert_eq!(resub[0].task.iter, 11, "sweep holds a live task clone");
        // Respawned incarnation finishes the cell; collect succeeds while
        // the sweep's clone is still alive.
        let pin = slots.pin(idx, 11).unwrap();
        assert!(slots.try_claim(idx, 1, claim_pack(0, 2)));
        slots.publish_cell(idx, 1, mk_batch(11, 0));
        drop(pin);
        let taken = slots.try_take(11).expect("complete");
        assert_eq!(taken.task.iter, resub[0].task.iter);
        assert!(Arc::ptr_eq(&taken.task, &resub[0].task), "same task, cloned");
    }

    #[test]
    fn pin_validates_state_and_id() {
        let slots = TaskSlots::new(2, 1);
        let idx = slots.try_publish(mk_task(9)).ok().unwrap();
        assert!(slots.pin(idx, 8).is_none(), "wrong id");
        assert!(slots.pin(idx, 9).is_some());
        slots.purge_namespace(0, 0); // everything matches base 0, mask 0
        assert!(slots.pin(idx, 9).is_none(), "retired by purge");
    }

    #[test]
    fn purge_retires_only_matching_namespace() {
        use crate::decision::service::{TASK_NS_MASK, TASK_NS_SHIFT};
        let slots = TaskSlots::new(4, 1);
        let a = 1u64 << TASK_NS_SHIFT;
        let b = 2u64 << TASK_NS_SHIFT;
        slots.try_publish(mk_task(a | 1)).ok().unwrap();
        let bi = slots.try_publish(mk_task(b | 1)).ok().unwrap();
        slots.purge_namespace(a, TASK_NS_MASK);
        assert_eq!(slots.in_flight(), 1);
        assert!(slots.pin(bi, b | 1).is_some(), "other namespace untouched");
    }

    #[test]
    fn sweep_releases_dead_claims_and_lists_unreported_cells() {
        let slots = TaskSlots::new(2, 2);
        let idx = slots.try_publish(mk_task(7)).ok().unwrap();
        // Worker 0 (incarnation 1) claims cell 0 then "dies" pre-report;
        // cell 1 reports normally via worker 1.
        let pin = slots.pin(idx, 7).unwrap();
        assert!(slots.try_claim(idx, 0, claim_pack(0, 1)));
        assert!(slots.try_claim(idx, 1, claim_pack(1, 1)));
        slots.publish_cell(idx, 1, mk_batch(7, 1));
        drop(pin);
        let released_before =
            trace::metrics::counters().get("claim_releases").unwrap();
        let resub = slots.sweep_dead_claims(claim_pack(0, 1));
        assert!(
            trace::metrics::counters().get("claim_releases").unwrap() > released_before,
            "releasing a dead claim must bump the claim_releases counter"
        );
        assert_eq!(resub.len(), 1);
        assert_eq!((resub[0].slot, resub[0].shard, resub[0].task_id), (idx, 0, 7));
        // The claim is free again: the respawned incarnation can take it.
        let pin = slots.pin(idx, 7).unwrap();
        assert!(slots.try_claim(idx, 0, claim_pack(0, 2)));
        slots.publish_cell(idx, 0, mk_batch(7, 0));
        drop(pin);
        assert!(slots.try_take(7).is_some());
    }

    #[test]
    fn sweep_never_releases_live_claims() {
        let slots = TaskSlots::new(1, 1);
        let idx = slots.try_publish(mk_task(3)).ok().unwrap();
        let pin = slots.pin(idx, 3).unwrap();
        assert!(slots.try_claim(idx, 0, claim_pack(0, 2))); // live incarnation 2
        drop(pin);
        let resub = slots.sweep_dead_claims(claim_pack(0, 1)); // dead inc 1
        assert!(resub.is_empty(), "live claim must survive a dead sweep");
    }
}
