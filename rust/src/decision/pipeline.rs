//! Per-sequence decision pipeline with the §7.4 ablation ladder.
//!
//! One entry point, four CPU implementations (plus the simulated GPU
//! epilogue handled by the engine/simulator):
//!
//! | variant      | logits access     | penalties            | filtering              | draw |
//! |--------------|-------------------|----------------------|------------------------|------|
//! | `NaiveCpu`   | materialized copy | histogram **rebuilt**| full **sort** O(V logV)| O(V) |
//! | `Parallel`   | zero-copy views   | rebuilt              | full sort              | O(V) |
//! | `Offloading` | zero-copy views   | **incremental** (§5.2)| truncation-first O(V), lane-vectorized ([`super::kernels`]) | O(k) |
//! | `Shvs`       | zero-copy views   | incremental           | hot-set + certificate  | O(H) |
//!
//! All variants produce the *same distribution*; they differ only in cost.
//! `Parallel` differs from `NaiveCpu` operationally (m workers instead of a
//! serial epilogue) — per-decision it drops the materialize+rebuild copies.

use super::categorical::{draw_token, VariateSource};
use super::filter::{apply_allow_list, truncate_sort_based};
use super::hotvocab::HotVocab;
use super::kernels::{DenseKernel, KernelBackend};
use super::params::SamplingParams;
use super::penalties::{apply_penalties_dense, BatchHistory, SeqHistory};
use super::shvs::{slow_path_token, Decision, Precompute, ShvsSampler};
use crate::config::DecisionVariant;
use crate::tensor::ShardedLogits;
use std::sync::Arc;

/// A reusable per-worker decision pipeline.
pub struct DecisionPipeline {
    variant: DecisionVariant,
    shvs: Option<ShvsSampler>,
    /// Vectorized dense kernel for the `Offloading` variant
    /// (backend from [`KernelBackend::detect`]: `SIMPLE_KERNELS=scalar|simd`).
    dense: DenseKernel,
    variates: VariateSource,
    // stats
    pub decisions: u64,
    pub fast_path_hits: u64,
    pub alpha_sum: f64,
}

impl DecisionPipeline {
    /// `hot` is required for the `Shvs` variant.
    pub fn new(variant: DecisionVariant, hot: Option<Arc<HotVocab>>, engine_seed: u64) -> Self {
        let shvs = match variant {
            DecisionVariant::Shvs => Some(ShvsSampler::new(
                hot.expect("SHVS variant requires a hot vocabulary"),
            )),
            _ => None,
        };
        DecisionPipeline {
            variant,
            shvs,
            dense: DenseKernel::new(KernelBackend::detect()),
            variates: VariateSource::new(engine_seed),
            decisions: 0,
            fast_path_hits: 0,
            alpha_sum: 0.0,
        }
    }

    /// Swap the SHVS hot set online (the adaptive sizing controller's
    /// actuation). No-op for non-SHVS variants. Subsequent decisions must
    /// see `Precompute`s for the new H; the `pre: None` reference path
    /// recomputes per call and is therefore always safe.
    pub fn set_hot_vocab(&mut self, hot: Arc<HotVocab>) {
        if let Some(s) = self.shvs.as_mut() {
            s.set_hot(hot);
        }
    }

    pub fn variant(&self) -> DecisionVariant {
        self.variant
    }

    /// Mean SHVS acceptance over the pipeline's lifetime (observability).
    pub fn mean_alpha(&self) -> f64 {
        if self.decisions == 0 {
            0.0
        } else {
            self.alpha_sum / self.decisions as f64
        }
    }

    /// Decide the next token for column `view_col` of `view`.
    ///
    /// `batch_hist` carries the sequence's history at column `hist_col`
    /// (the two indices differ when histories are stored per-sequence, as
    /// in the sampler service). The naive variant rebuilds its histogram
    /// from the raw rows, the others use the incremental one. `pre` is the
    /// SHVS GPU-side precompute for this column (ignored by other variants).
    #[allow(clippy::too_many_arguments)]
    pub fn decide(
        &mut self,
        view: &ShardedLogits,
        view_col: usize,
        batch_hist: &BatchHistory,
        hist_col: usize,
        params: &SamplingParams,
        pre: Option<&Precompute>,
        seq_id: u64,
        iteration: u64,
    ) -> Decision {
        let b = view_col;
        let uniforms = self.variates.uniforms(params.seed, seq_id, iteration);
        let hist = batch_hist.seq(hist_col);
        let d = match self.variant {
            DecisionVariant::GpuEpilogue | DecisionVariant::NaiveCpu => {
                // Naive port: full materialized copy + histogram rebuild +
                // sort-based filtering. (GpuEpilogue shares this exact code
                // for *numerics*; its cost is modelled by the simulator.)
                let rebuilt = hist.with_rebuilt_output(batch_hist.rebuild(hist_col));
                let mut row = view.materialize_row(b);
                apply_penalties_dense(&mut row, &rebuilt, params);
                let mut pairs: Vec<(u32, f32)> =
                    row.iter().enumerate().map(|(i, &z)| (i as u32, z)).collect();
                if let Some(allow) = &params.allowed_tokens {
                    pairs = apply_allow_list(pairs, allow);
                }
                let t = truncate_sort_based(pairs, params);
                Decision {
                    token: draw_token(&t, uniforms.2),
                    alpha: 1.0,
                    fast_path: false,
                    accepted: false,
                }
            }
            DecisionVariant::Parallel => {
                // Sequence-parallel but still full-V sort-based kernels:
                // zero-copy streaming reads, incremental histograms.
                let mut pairs: Vec<(u32, f32)> = Vec::with_capacity(view.vocab());
                view.for_each_logit(b, |v, z| pairs.push((v as u32, z)));
                if params.has_penalties() {
                    for (id, c) in hist.penalized_ids() {
                        if let Some(p) = pairs.get_mut(id as usize) {
                            p.1 = super::penalties::penalize_logit(p.1, true, c, params);
                        }
                    }
                }
                for (&id, &bias) in &params.logit_bias {
                    if let Some(p) = pairs.get_mut(id as usize) {
                        p.1 += bias;
                    }
                }
                if let Some(allow) = &params.allowed_tokens {
                    pairs = apply_allow_list(pairs, allow);
                }
                let t = truncate_sort_based(pairs, params);
                Decision {
                    token: draw_token(&t, uniforms.2),
                    alpha: 1.0,
                    fast_path: false,
                    accepted: false,
                }
            }
            DecisionVariant::Offloading => {
                // Column-wise incremental penalties + truncation-first
                // quickselect filtering — exact full-V, one fused
                // cache-resident pass through the lane-vectorized kernel
                // (bitwise identical to `slow_path_token` on both backends).
                let token = self.dense.decide(view, b, hist, params, uniforms.2);
                Decision { token, alpha: 1.0, fast_path: false, accepted: false }
            }
            DecisionVariant::Shvs => {
                let sampler = self.shvs.as_mut().expect("shvs sampler");
                let owned;
                let pre = match pre {
                    Some(p) => p,
                    None => {
                        // No GPU precompute available (pure-CPU harness):
                        // compute the reference one (counted as GPU work by
                        // the figure harnesses).
                        owned = Precompute::reference(
                            view,
                            b,
                            sampler.hot_vocab(),
                            params.temperature.max(1e-6),
                        );
                        &owned
                    }
                };
                sampler.decide(view, b, hist, params, pre, uniforms)
            }
        };
        self.decisions += 1;
        if d.fast_path {
            self.fast_path_hits += 1;
        }
        self.alpha_sum += d.alpha;
        d
    }
}

/// The exact full-vocabulary oracle decision (baseline sampler used for the
/// Figure 13 TVD comparison): identical distribution, no speculation.
pub fn oracle_decide(
    view: &ShardedLogits,
    b: usize,
    hist: &SeqHistory,
    params: &SamplingParams,
    u: f64,
) -> u32 {
    slow_path_token(view, b, hist, params, u)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::stats::total_variation_distance;
    use crate::tensor::{shard_row_major, Tensor2};

    fn setup(v: usize, b: usize, shards: usize) -> (ShardedLogits, BatchHistory) {
        let logits: Vec<f32> = (0..b * v)
            .map(|i| ((i * 2654435761usize % 1000) as f32) / 200.0 - 2.5)
            .collect();
        let view = shard_row_major(&Tensor2::from_vec(b, v, logits), shards);
        let prompts: Vec<Vec<u32>> = (0..b).map(|i| vec![i as u32, (i + 1) as u32]).collect();
        let mut hist = BatchHistory::new(&prompts, 64);
        hist.append_row(&(0..b).map(|i| (i % v) as u32).collect::<Vec<_>>());
        hist.append_row(&(0..b).map(|i| ((i + 3) % v) as u32).collect::<Vec<_>>());
        (view, hist)
    }

    /// All CPU variants must induce the same token distribution.
    #[test]
    fn all_variants_agree_in_distribution() {
        let v = 96;
        let (view, hist) = setup(v, 2, 2);
        let params = SamplingParams {
            temperature: 0.9,
            top_k: 40,
            top_p: 0.95,
            min_p: 0.01,
            repetition_penalty: 1.2,
            presence_penalty: 0.1,
            frequency_penalty: 0.1,
            ..Default::default()
        };
        let hot = HotVocab::new((0..24).collect(), v).into_arc();
        let n = 40_000;
        let mut dists: Vec<Vec<f64>> = Vec::new();
        for variant in [
            DecisionVariant::NaiveCpu,
            DecisionVariant::Parallel,
            DecisionVariant::Offloading,
            DecisionVariant::Shvs,
        ] {
            let mut pipe = DecisionPipeline::new(variant, Some(hot.clone()), 99);
            let mut counts = vec![0.0f64; v];
            for i in 0..n {
                // fresh uniforms per trial: vary iteration
                let d = pipe.decide(&view, 0, &hist, 0, &params, None, 0, i as u64);
                counts[d.token as usize] += 1.0;
            }
            dists.push(counts);
        }
        for i in 1..dists.len() {
            let tvd = total_variation_distance(&dists[0], &dists[i]);
            assert!(tvd < 0.02, "variant {i} TVD vs naive: {tvd}");
        }
    }

    /// Same (seq, iter, seed) ⇒ same token for the sort-based variants,
    /// which share the u_fallback draw.
    #[test]
    fn determinism_across_pipeline_instances() {
        let (view, hist) = setup(64, 2, 2);
        let params = SamplingParams::production_default();
        for variant in [DecisionVariant::NaiveCpu, DecisionVariant::Offloading] {
            let mut p1 = DecisionPipeline::new(variant, None, 7);
            let mut p2 = DecisionPipeline::new(variant, None, 7);
            for it in 0..10 {
                let a = p1.decide(&view, 1, &hist, 1, &params, None, 5, it);
                let b = p2.decide(&view, 1, &hist, 1, &params, None, 5, it);
                assert_eq!(a.token, b.token, "variant {variant:?} iter {it}");
            }
        }
    }

    /// NaiveCpu and Parallel use identical math (sort-based, same uniforms)
    /// so they must agree token-for-token, not just in distribution.
    #[test]
    fn naive_and_parallel_agree_exactly() {
        let (view, hist) = setup(80, 3, 2);
        let params = SamplingParams::production_default();
        let mut naive = DecisionPipeline::new(DecisionVariant::NaiveCpu, None, 3);
        let mut par = DecisionPipeline::new(DecisionVariant::Parallel, None, 3);
        for b in 0..3 {
            for it in 0..20 {
                let x = naive.decide(&view, b, &hist, b, &params, None, b as u64, it);
                let y = par.decide(&view, b, &hist, b, &params, None, b as u64, it);
                assert_eq!(x.token, y.token, "b={b} it={it}");
            }
        }
    }

    #[test]
    fn stats_accumulate() {
        let (view, hist) = setup(64, 1, 1);
        let hot = HotVocab::new((0..16).collect(), 64).into_arc();
        let mut pipe = DecisionPipeline::new(DecisionVariant::Shvs, Some(hot), 1);
        let params = SamplingParams::default();
        for it in 0..32 {
            pipe.decide(&view, 0, &hist, 0, &params, None, 0, it);
        }
        assert_eq!(pipe.decisions, 32);
        assert!(pipe.mean_alpha() > 0.0 && pipe.mean_alpha() <= 1.0);
        assert!(pipe.fast_path_hits <= 32);
    }

    #[test]
    fn oracle_matches_offloading_token_stream() {
        let (view, hist) = setup(48, 1, 3);
        let params = SamplingParams::production_default();
        let mut pipe = DecisionPipeline::new(DecisionVariant::Offloading, None, 11);
        let vs = VariateSource::new(11);
        for it in 0..16 {
            let d = pipe.decide(&view, 0, &hist, 0, &params, None, 9, it);
            let u = vs.uniforms(params.seed, 9, it);
            let o = oracle_decide(&view, 0, hist.seq(0), &params, u.2);
            assert_eq!(d.token, o);
        }
    }
}
