//! Stable softmax helpers.
//!
//! The truncation-first path normalizes only on the filtered subset (done in
//! [`super::filter`]); these dense helpers serve the baseline full-V
//! samplers, the SHVS weight computation (Eq. 6), and test oracles.

/// Stable softmax over a dense logits row at temperature τ, in place into
/// `out` (f64 for accumulation accuracy). Returns the max logit used as the
/// shift.
pub fn softmax_dense(logits: &[f32], tau: f32, out: &mut Vec<f64>) -> f32 {
    assert!(!logits.is_empty());
    assert!(tau > 0.0, "softmax needs τ > 0 (use argmax for greedy)");
    let z_max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    out.clear();
    out.reserve(logits.len());
    let inv = 1.0 / tau as f64;
    let mut sum = 0.0f64;
    for &z in logits {
        let w = (((z - z_max) as f64) * inv).exp();
        out.push(w);
        sum += w;
    }
    let norm = 1.0 / sum;
    for w in out.iter_mut() {
        *w *= norm;
    }
    z_max
}

/// Stable unnormalized weights w_v = exp((z_v − z_max)/τ) (Eq. 6) plus their
/// sum. The GPU-side SHVS precompute produces exactly these; the CPU reuses
/// the same function for oracle checks.
pub fn stable_weights(logits: &[f32], tau: f32, out: &mut Vec<f64>) -> (f32, f64) {
    assert!(!logits.is_empty());
    let z_max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    out.clear();
    out.reserve(logits.len());
    let inv = 1.0 / tau as f64;
    let mut sum = 0.0f64;
    for &z in logits {
        let w = (((z - z_max) as f64) * inv).exp();
        out.push(w);
        sum += w;
    }
    (z_max, sum)
}

/// Argmax for greedy decoding. Tie rule: **lowest index wins** — the strict
/// `>` comparison never replaces an earlier equal maximum. This is a
/// contract, not an accident: [`super::kernels`]' SIMD max-reduction and the
/// greedy singleton in [`super::filter::truncate`] implement the same rule,
/// and `rust/tests/simd_kernels.rs` pins all three against each other.
pub fn argmax(logits: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_z = f32::NEG_INFINITY;
    for (i, &z) in logits.iter().enumerate() {
        if z > best_z {
            best = i;
            best_z = z;
        }
    }
    best
}

/// Log-sum-exp of a logits row (for log-prob output).
pub fn log_sum_exp(logits: &[f32], tau: f32) -> f64 {
    let z_max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let inv = 1.0 / tau as f64;
    let s: f64 = logits
        .iter()
        .map(|&z| (((z - z_max) as f64) * inv).exp())
        .sum();
    (z_max as f64) * inv + s.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one() {
        let logits = [1.0f32, 2.0, 3.0, -5.0];
        let mut probs = Vec::new();
        softmax_dense(&logits, 1.0, &mut probs);
        let s: f64 = probs.iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
        // monotone in logits
        assert!(probs[2] > probs[1] && probs[1] > probs[0] && probs[0] > probs[3]);
    }

    #[test]
    fn softmax_is_shift_invariant() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [1001.0f32, 1002.0, 1003.0];
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        softmax_dense(&a, 1.0, &mut pa);
        softmax_dense(&b, 1.0, &mut pb);
        for (x, y) in pa.iter().zip(&pb) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn softmax_handles_extreme_logits() {
        let logits = [-1e30f32, 0.0, 1e4];
        let mut probs = Vec::new();
        softmax_dense(&logits, 1.0, &mut probs);
        assert!(probs.iter().all(|p| p.is_finite()));
        assert!((probs[2] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn temperature_sharpens_and_flattens() {
        let logits = [0.0f32, 1.0];
        let mut cold = Vec::new();
        let mut hot = Vec::new();
        softmax_dense(&logits, 0.5, &mut cold);
        softmax_dense(&logits, 2.0, &mut hot);
        assert!(cold[1] > hot[1]); // low τ concentrates on the max
    }

    #[test]
    fn stable_weights_match_softmax() {
        let logits = [0.3f32, -1.2, 2.2, 0.0];
        let tau = 0.8;
        let mut w = Vec::new();
        let (_, sum) = stable_weights(&logits, tau, &mut w);
        let mut probs = Vec::new();
        softmax_dense(&logits, tau, &mut probs);
        for (wi, pi) in w.iter().zip(&probs) {
            assert!((wi / sum - pi).abs() < 1e-12);
        }
        // max weight is exactly 1
        let wmax = w.iter().cloned().fold(0.0f64, f64::max);
        assert!((wmax - 1.0).abs() < 1e-12);
    }

    #[test]
    fn argmax_ties_break_low() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 0.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[2.0; 17]), 0);
    }

    #[test]
    fn argmax_tie_rule_matches_greedy_truncate() {
        use crate::decision::{filter, params::SamplingParams};
        let logits = [3.0f32, 7.0, 7.0, 1.0];
        let c: Vec<(u32, f32)> =
            logits.iter().enumerate().map(|(i, &z)| (i as u32, z)).collect();
        let t = filter::truncate(c, &SamplingParams::greedy());
        assert_eq!(t.ids, vec![argmax(&logits) as u32]);
    }

    #[test]
    fn lse_consistent_with_softmax() {
        let logits = [0.5f32, 1.5, -0.5];
        let tau = 1.0;
        let lse = log_sum_exp(&logits, tau);
        let mut probs = Vec::new();
        softmax_dense(&logits, tau, &mut probs);
        for (i, &z) in logits.iter().enumerate() {
            let logp = (z as f64) / tau as f64 - lse;
            assert!((logp.exp() - probs[i]).abs() < 1e-12);
        }
    }
}
