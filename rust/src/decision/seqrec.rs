//! Lock-free per-sequence replay records — the shared pool's replacement
//! for the mutex-guarded service registry (DESIGN.md §11).
//!
//! A [`SeqRec`] is the authoritative resume state of one live sequence:
//! its immutable prompt/params/grammar plus a fixed-capacity, positionally
//! written token log of decided output. Whichever worker decides a window
//! for the sequence writes the verdict's tokens at their absolute output
//! positions and publishes the new high-water length with a `fetch_max`;
//! a later rebuild (a respawned worker, or a sibling that *stole* the
//! sequence's shard) reads `tokens[..iteration]` and replays — exactly the
//! resume-`Register` path preemption uses, now without any lock.
//!
//! Positional writes make re-decides idempotent: decisions are keyed by
//! (sampler seed, request seed, sequence, iteration) — never by worker
//! identity — so a crash-recovery re-decision of an already-logged window
//! rewrites byte-identical tokens, and an engine-side cut (KV ceiling,
//! EOS) merely re-keys later tasks at a smaller `iteration`, which readers
//! truncate to. Stale in-flight verdicts from *before* a retire +
//! re-register can never corrupt the fresh incarnation because a
//! re-register mints a **new** `Arc<SeqRec>`: tasks carry the record they
//! were submitted with, so a stale verdict rolls only the orphaned old
//! record (the Arc-identity guard that replaces the registry's `gen`
//! stamps).

use super::grammar::{ConstraintState, GrammarConstraint};
use super::params::SamplingParams;
use crate::util::sync::atomic::{AtomicBool, AtomicU32, AtomicUsize, Ordering};
use crate::util::sync::fetch_max_usize;
use std::sync::Arc;

/// Shared handle to one sequence's replay record. `Arc` pointer identity
/// *is* the registration incarnation: comparing handles with
/// [`SeqHandle::same_rec`] distinguishes a live registration from a stale
/// one without any counter.
pub type SeqHandle = Arc<SeqRec>;

/// One live sequence's resume state. See the module docs for the write
/// protocol.
pub struct SeqRec {
    pub seq_id: u64,
    pub prompt: Vec<u32>,
    pub params: SamplingParams,
    pub grammar: Option<Arc<GrammarConstraint>>,
    /// Decided-output log, written positionally; entries `< len` are
    /// published.
    tokens: Box<[AtomicU32]>,
    /// High-water published length (monotone via `fetch_max`).
    len: AtomicUsize,
    /// Set by `retire`: workers skip columns whose record is retired, so a
    /// task in flight across a retire produces no decision for it.
    retired: AtomicBool,
}

impl SeqRec {
    /// Build a record with `capacity` output-token slots (the service's
    /// `max_seq_len`), seeded with `output` — the tokens generated before a
    /// preemption, replayed so penalties/constraints stay byte-identical.
    pub fn new(
        seq_id: u64,
        prompt: &[u32],
        output: &[u32],
        params: &SamplingParams,
        grammar: Option<Arc<GrammarConstraint>>,
        capacity: usize,
    ) -> SeqHandle {
        let capacity = capacity.max(output.len());
        let tokens: Box<[AtomicU32]> = (0..capacity).map(|_| AtomicU32::new(0)).collect();
        for (i, &t) in output.iter().enumerate() {
            // ordering: pre-publication init — the record is not shared
            // until the Arc::new below hands it out.
            tokens[i].store(t, Ordering::Relaxed);
        }
        Arc::new(SeqRec {
            seq_id,
            prompt: prompt.to_vec(),
            params: params.clone(),
            grammar,
            tokens,
            len: AtomicUsize::new(output.len()),
            retired: AtomicBool::new(false),
        })
    }

    /// Log a decided window: `toks` start at absolute output position
    /// `base`. Idempotent — determinism guarantees any overlapping rewrite
    /// carries identical values, so last-writer races are harmless.
    pub fn log_decided(&self, base: u64, toks: &[u32]) {
        let base = base as usize;
        let end = (base + toks.len()).min(self.tokens.len());
        for (i, &t) in toks.iter().take(end.saturating_sub(base)).enumerate() {
            // ordering: Relaxed positional stores are published by the
            // AcqRel fetch_max below; readers clamp to the acquired len,
            // and overlapping rewrites are value-identical by determinism.
            self.tokens[base + i].store(t, Ordering::Relaxed);
        }
        // AcqRel: later readers of this len must also observe every write
        // published under the smaller lens this max chains over.
        fetch_max_usize(&self.len, end, Ordering::AcqRel);
    }

    /// Published decided-output length.
    pub fn decided_len(&self) -> usize {
        self.len.load(Ordering::Acquire)
    }

    /// Copy the first `upto` decided tokens (clamped to the published
    /// length) — the replay prefix a rebuild truncates to.
    pub fn read_upto(&self, upto: u64) -> Vec<u32> {
        let n = (upto as usize).min(self.len.load(Ordering::Acquire));
        (0..n).map(|i| self.tokens[i].load(Ordering::Relaxed)).collect()
    }

    /// Mark retired. The record stays readable (stale in-flight tasks may
    /// still hold the handle) but workers decide nothing for it.
    pub fn retire(&self) {
        self.retired.store(true, Ordering::Release);
    }

    pub fn is_retired(&self) -> bool {
        self.retired.load(Ordering::Acquire)
    }

    /// Rebuild the grammar DFA state after `output` (the worker-side replay
    /// the `Register` message arm used to do).
    pub fn replay_grammar(
        &self,
        output: &[u32],
    ) -> Option<(Arc<GrammarConstraint>, ConstraintState)> {
        let g = self.grammar.clone()?;
        let mut state = g.start();
        for &t in output {
            if let Some(next) = g.advance(state, t) {
                state = next;
            }
        }
        Some((g, state))
    }
}

/// Arc-identity comparison: true iff both handles are the *same*
/// registration incarnation.
pub trait SameRec {
    fn same_rec(&self, other: &SeqHandle) -> bool;
}

impl SameRec for SeqHandle {
    fn same_rec(&self, other: &SeqHandle) -> bool {
        Arc::ptr_eq(self, other)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(cap: usize) -> SeqHandle {
        SeqRec::new(7, &[1, 2], &[], &SamplingParams::default(), None, cap)
    }

    #[test]
    fn positional_log_and_truncating_read() {
        let r = rec(16);
        r.log_decided(0, &[10, 11]);
        r.log_decided(2, &[12, 13, 14]);
        assert_eq!(r.decided_len(), 5);
        assert_eq!(r.read_upto(3), vec![10, 11, 12]);
        assert_eq!(r.read_upto(99), vec![10, 11, 12, 13, 14]);
    }

    #[test]
    fn rewrite_is_idempotent_and_len_monotone() {
        let r = rec(8);
        r.log_decided(0, &[5, 6, 7]);
        // A crash-recovery re-decide rewrites a prefix window: values are
        // identical by determinism, and len must not shrink.
        r.log_decided(0, &[5, 6]);
        assert_eq!(r.decided_len(), 3);
        assert_eq!(r.read_upto(3), vec![5, 6, 7]);
    }

    #[test]
    fn seeded_output_replays() {
        let r = SeqRec::new(1, &[9], &[3, 4], &SamplingParams::default(), None, 8);
        assert_eq!(r.decided_len(), 2);
        assert_eq!(r.read_upto(2), vec![3, 4]);
    }

    #[test]
    fn writes_never_overflow_capacity() {
        let r = rec(4);
        r.log_decided(2, &[1, 2, 3, 4]); // tail clamped
        assert_eq!(r.decided_len(), 4);
        assert_eq!(r.read_upto(9), vec![0, 0, 1, 2]);
    }

    #[test]
    fn retire_flag_and_arc_identity() {
        let a = rec(4);
        let b = rec(4);
        assert!(a.same_rec(&a.clone()));
        assert!(!a.same_rec(&b));
        assert!(!a.is_retired());
        a.retire();
        assert!(a.is_retired());
    }

    #[test]
    fn concurrent_writer_and_readers_agree() {
        const N: usize = if cfg!(miri) { 128 } else { 1024 };
        let r = rec(N);
        let w = r.clone();
        let writer = std::thread::spawn(move || {
            for i in 0..N as u64 {
                w.log_decided(i, &[i as u32 ^ 0xABCD]);
            }
        });
        let readers: Vec<_> = (0..3)
            .map(|_| {
                let r = r.clone();
                std::thread::spawn(move || {
                    loop {
                        let n = r.decided_len();
                        let snap = r.read_upto(n as u64);
                        for (i, &t) in snap.iter().enumerate() {
                            assert_eq!(t, i as u32 ^ 0xABCD);
                        }
                        if n == N {
                            break;
                        }
                        std::thread::yield_now();
                    }
                })
            })
            .collect();
        writer.join().unwrap();
        for h in readers {
            h.join().unwrap();
        }
    }
}
