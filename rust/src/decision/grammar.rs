//! Grammar-constrained decoding — the paper's future-work item (iii) in
//! §9: "extending SHVS to structured/grammar-constrained decoding".
//!
//! A constraint is a byte-level DFA compiled from a regex (the same
//! mechanism outlines/llguidance-style libraries use). At each decode step
//! the constraint yields the set of token ids whose byte expansions keep
//! the DFA alive; that set plugs into [`super::params::SamplingParams::allowed_tokens`]
//! and flows through the exact allow-list path of the decision pipeline —
//! composing with SHVS as §9 anticipates: with a constrained (often small)
//! candidate set the sampler skips speculation and stays exact.

use regex_automata::dfa::{dense, Automaton, StartKind};
use regex_automata::util::primitives::StateID;
use regex_automata::util::start::Config as StartConfig;
use regex_automata::Anchored;

/// A compiled token-level grammar constraint for a fixed vocabulary.
pub struct GrammarConstraint {
    /// Original pattern (for Debug/observability).
    pattern: String,
    dfa: dense::DFA<Vec<u32>>,
    /// Byte expansion of each token id (empty = never allowed, e.g. specials
    /// excluded from constrained output).
    token_bytes: Vec<Vec<u8>>,
    start: StateID,
}

/// Per-sequence constraint state (DFA state after the emitted bytes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConstraintState(StateID);

impl GrammarConstraint {
    /// Compile a regex pattern over a token vocabulary. The pattern is
    /// anchored: the whole generated text (so far) must stay a viable
    /// prefix of a match.
    pub fn new(pattern: &str, token_bytes: Vec<Vec<u8>>) -> crate::Result<GrammarConstraint> {
        // End-anchor with \z so that DFA dead states mean "no completion of
        // the grammar is reachable" (viable-prefix semantics); an un-anchored
        // search DFA instead saturates in a match sink after the longest
        // match and never dies.
        let anchored = format!(r"(?:{pattern})\z");
        let dfa = dense::Builder::new()
            .configure(dense::Config::new().start_kind(StartKind::Anchored))
            .build(&anchored)
            .map_err(|e| anyhow::anyhow!("compiling grammar {pattern:?}: {e}"))?;
        let start = dfa
            .start_state(&StartConfig::new().anchored(Anchored::Yes))
            .map_err(|e| anyhow::anyhow!("start state: {e}"))?;
        Ok(GrammarConstraint { pattern: pattern.to_string(), dfa, token_bytes, start })
    }

    /// Initial state.
    pub fn start(&self) -> ConstraintState {
        ConstraintState(self.start)
    }

    /// Advance a state by one byte; `None` = dead (byte not viable).
    fn step_byte(&self, state: StateID, byte: u8) -> Option<StateID> {
        let next = self.dfa.next_state(state, byte);
        if self.dfa.is_dead_state(next) {
            None
        } else {
            Some(next)
        }
    }

    /// Advance a state by a token; `None` if the token leaves the grammar.
    pub fn advance(&self, state: ConstraintState, token: u32) -> Option<ConstraintState> {
        let bytes = self.token_bytes.get(token as usize)?;
        if bytes.is_empty() {
            return None;
        }
        let mut s = state.0;
        for &b in bytes {
            s = self.step_byte(s, b)?;
        }
        Some(ConstraintState(s))
    }

    /// Whether the text accepted so far is a complete match (EOS legal).
    pub fn is_match(&self, state: ConstraintState) -> bool {
        // dense DFAs report matches from the *next* state on EOI.
        let eoi = self.dfa.next_eoi_state(state.0);
        self.dfa.is_match_state(eoi)
    }

    /// All token ids that keep the DFA alive from `state` — the allow-list
    /// for this decode step. O(Σ |token bytes|) worst case; practical
    /// grammars kill most tokens on their first byte, which short-circuits.
    pub fn allowed_tokens(&self, state: ConstraintState) -> Vec<u32> {
        // Precompute the 256 one-byte successors once per step.
        let mut first: [Option<StateID>; 256] = [None; 256];
        for b in 0..=255u8 {
            first[b as usize] = self.step_byte(state.0, b);
        }
        let mut out = Vec::new();
        'tok: for (id, bytes) in self.token_bytes.iter().enumerate() {
            let Some((&b0, rest)) = bytes.split_first() else {
                continue;
            };
            let Some(mut s) = first[b0 as usize] else {
                continue;
            };
            for &b in rest {
                match self.step_byte(s, b) {
                    Some(n) => s = n,
                    None => continue 'tok,
                }
            }
            out.push(id as u32);
        }
        out
    }

    pub fn vocab(&self) -> usize {
        self.token_bytes.len()
    }

    pub fn pattern(&self) -> &str {
        &self.pattern
    }
}

impl std::fmt::Debug for GrammarConstraint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("GrammarConstraint")
            .field("pattern", &self.pattern)
            .field("vocab", &self.token_bytes.len())
            .finish()
    }
}

/// Token byte table for the toy byte-level tokenizer
/// ([`crate::engine::tokenizer`]): ids 3..259 are raw bytes, specials and
/// out-of-range ids are unconstrained-illegal (empty expansion).
pub fn byte_tokenizer_table(vocab: usize) -> Vec<Vec<u8>> {
    (0..vocab)
        .map(|id| {
            if (3..259).contains(&id) {
                vec![(id - 3) as u8]
            } else {
                Vec::new()
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok(c: char) -> u32 {
        3 + c as u32
    }

    fn digits_grammar() -> GrammarConstraint {
        GrammarConstraint::new(r"[0-9]{1,3}(\.[0-9]{1,2})?", byte_tokenizer_table(300))
            .unwrap()
    }

    #[test]
    fn allowed_tokens_start_with_digits_only() {
        let g = digits_grammar();
        let allowed = g.allowed_tokens(g.start());
        let chars: Vec<char> = allowed
            .iter()
            .map(|&t| ((t - 3) as u8) as char)
            .collect();
        assert_eq!(chars.len(), 10);
        assert!(chars.iter().all(|c| c.is_ascii_digit()), "{chars:?}");
    }

    #[test]
    fn advance_follows_the_grammar() {
        let g = digits_grammar();
        let s0 = g.start();
        let s1 = g.advance(s0, tok('4')).expect("digit ok");
        assert!(g.is_match(s1), "'4' is a complete match");
        // after one digit: digits or '.' allowed
        let allowed: Vec<char> = g
            .allowed_tokens(s1)
            .iter()
            .map(|&t| ((t - 3) as u8) as char)
            .collect();
        assert!(allowed.contains(&'.'));
        assert!(allowed.contains(&'7'));
        assert!(!allowed.contains(&'x'));
        // letters die immediately
        assert!(g.advance(s0, tok('x')).is_none());
    }

    #[test]
    fn bounded_repetition_enforced() {
        let g = digits_grammar();
        let mut s = g.start();
        for c in ['1', '2', '3'] {
            s = g.advance(s, tok(c)).unwrap();
        }
        // a 4th integer digit is illegal; only '.' continues
        assert!(g.advance(s, tok('4')).is_none());
        let s = g.advance(s, tok('.')).unwrap();
        assert!(!g.is_match(s), "trailing dot incomplete");
        let s = g.advance(s, tok('0')).unwrap();
        assert!(g.is_match(s));
    }

    #[test]
    fn specials_never_allowed() {
        let g = digits_grammar();
        let allowed = g.allowed_tokens(g.start());
        assert!(allowed.iter().all(|&t| t >= 3));
        assert!(g.advance(g.start(), 0).is_none()); // PAD
        assert!(g.advance(g.start(), 299).is_none()); // beyond byte range
    }

    #[test]
    fn json_ish_grammar_walks() {
        let table = byte_tokenizer_table(300);
        let g = GrammarConstraint::new(r#"\{"a": [0-9]+\}"#, table).unwrap();
        let mut s = g.start();
        for c in ['{', '"', 'a', '"', ':', ' ', '1', '2'] {
            s = g.advance(s, tok(c)).unwrap_or_else(|| panic!("died at {c:?}"));
        }
        assert!(!g.is_match(s));
        let s2 = g.advance(s, tok('}')).unwrap();
        assert!(g.is_match(s2));
        // and the allow-list at the brace point is exactly digits or '}'
        let allowed: Vec<char> = g
            .allowed_tokens(s)
            .iter()
            .map(|&t| ((t - 3) as u8) as char)
            .collect();
        assert!(allowed.contains(&'}') && allowed.contains(&'5'));
        assert!(!allowed.contains(&'"'));
    }

    #[test]
    fn composes_with_decision_pipeline_allow_list() {
        use crate::decision::penalties::BatchHistory;
        use crate::decision::{DecisionPipeline, SamplingParams};
        use crate::tensor::{shard_row_major, Tensor2};

        let vocab = 300;
        let g = digits_grammar();
        let allowed = g.allowed_tokens(g.start());
        let logits: Vec<f32> = (0..vocab).map(|i| ((i * 31) % 97) as f32 * 0.05).collect();
        let view = shard_row_major(&Tensor2::from_vec(1, vocab, logits), 2);
        let params = SamplingParams {
            allowed_tokens: Some(allowed.clone()),
            temperature: 0.8,
            ..Default::default()
        };
        let hist = BatchHistory::new(&[vec![]], 8);
        let mut pipe =
            DecisionPipeline::new(crate::config::DecisionVariant::Offloading, None, 1);
        for it in 0..32 {
            let d = pipe.decide(&view, 0, &hist, 0, &params, None, 0, it);
            assert!(allowed.contains(&d.token), "token {} outside grammar", d.token);
            assert!(g.advance(g.start(), d.token).is_some());
        }
    }
}
