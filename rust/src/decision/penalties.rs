//! Column-wise, incremental penalty state (§5.2).
//!
//! The paper stores generated tokens in a preallocated row-append buffer
//! `Y ∈ N^{Lmax×B}` (step-s output written as row s, contiguous) and updates
//! the per-sequence output histogram incrementally:
//! `C_o^{s+1} = C_o^s + Hist(Y_s)` — only the newest row is touched, so the
//! update is O(B) per iteration instead of the naive O(B·s) rebuild.
//!
//! Penalty *application* is sparse: only tokens present in the history have
//! their logits adjusted, so the cost is O(#distinct seen) per sequence, not
//! O(V). Dense `C ∈ N^{B×V}` histograms (the paper's formulation) are
//! represented sparsely per sequence — identical semantics, and the
//! histogram-vs-rebuild ablation is preserved via [`BatchHistory::rebuild`].

use super::params::SamplingParams;
use std::collections::HashMap;

/// Sparse per-sequence history counts.
#[derive(Debug, Clone, Default)]
pub struct SeqHistory {
    /// C_p row: token -> count within the prompt (step-invariant).
    prompt_counts: HashMap<u32, u32>,
    /// C_o row: token -> count within generated output (incremental).
    out_counts: HashMap<u32, u32>,
    /// Number of generated tokens (s−1).
    out_len: usize,
}

impl SeqHistory {
    pub fn new(prompt: &[u32]) -> Self {
        let mut prompt_counts = HashMap::with_capacity(prompt.len());
        for &t in prompt {
            *prompt_counts.entry(t).or_insert(0) += 1;
        }
        SeqHistory { prompt_counts, out_counts: HashMap::new(), out_len: 0 }
    }

    /// Incremental update with the step-s output token (Eq. 5).
    pub fn append(&mut self, token: u32) {
        *self.out_counts.entry(token).or_insert(0) += 1;
        self.out_len += 1;
    }

    /// Undo one [`Self::append`] of `token` — the speculative-decoding
    /// rollback path: draft tokens are rolled forward through the histogram
    /// for batched verification and un-counted past the rejection point.
    /// Exact inverse: `append(t); unappend(t)` is the identity.
    pub fn unappend(&mut self, token: u32) {
        match self.out_counts.get_mut(&token) {
            Some(c) if *c > 1 => *c -= 1,
            Some(_) => {
                self.out_counts.remove(&token);
            }
            None => panic!("unappend of token {token} never appended"),
        }
        self.out_len -= 1;
    }

    pub fn out_len(&self) -> usize {
        self.out_len
    }

    pub fn prompt_count(&self, token: u32) -> u32 {
        self.prompt_counts.get(&token).copied().unwrap_or(0)
    }

    pub fn out_count(&self, token: u32) -> u32 {
        self.out_counts.get(&token).copied().unwrap_or(0)
    }

    /// Presence masks M_p ∨ M_o for a token.
    pub fn seen(&self, token: u32) -> bool {
        self.out_counts.contains_key(&token) || self.prompt_counts.contains_key(&token)
    }

    /// Iterate over every token id that any penalty could touch
    /// (M_p ∨ M_o support), with its output count.
    pub fn penalized_ids(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        self.out_counts
            .iter()
            .map(|(&t, &c)| (t, c))
            .chain(
                self.prompt_counts
                    .iter()
                    .filter(move |(t, _)| !self.out_counts.contains_key(t))
                    .map(|(&t, _)| (t, 0)),
            )
    }

    /// Clone with the output histogram replaced by an externally rebuilt
    /// one (the naive baseline recomputes Hist(Y_{<s}) every step; this
    /// lets the ablation exercise that path against identical state).
    pub fn with_rebuilt_output(&self, out_counts: HashMap<u32, u32>) -> SeqHistory {
        let out_len = out_counts.values().map(|&c| c as usize).sum();
        SeqHistory { prompt_counts: self.prompt_counts.clone(), out_counts, out_len }
    }

    /// Number of distinct penalizable ids (the sparse work bound).
    pub fn num_penalized(&self) -> usize {
        let overlap = self
            .prompt_counts
            .keys()
            .filter(|t| self.out_counts.contains_key(t))
            .count();
        self.prompt_counts.len() + self.out_counts.len() - overlap
    }
}

/// Adjust one logit according to the penalties (vLLM/OpenAI semantics):
/// sign-aware multiplicative repetition penalty on M_p ∨ M_o, then additive
/// presence/frequency penalties on the *output* counts.
#[inline]
pub fn penalize_logit(z: f32, seen_any: bool, out_count: u32, p: &SamplingParams) -> f32 {
    let mut z = z;
    if seen_any && p.repetition_penalty != 1.0 {
        // Paper Eq. §2.2 (Z' = Z / f) refined sign-aware as in HF/vLLM:
        // dividing a negative logit by λ>1 would *raise* its probability.
        if z > 0.0 {
            z /= p.repetition_penalty;
        } else {
            z *= p.repetition_penalty;
        }
    }
    if out_count > 0 {
        z -= p.presence_penalty;
        z -= p.frequency_penalty * out_count as f32;
    }
    z
}

/// Apply all penalties + logit bias to a dense logits row, in place.
/// Sparse: touches only penalized/biased ids.
pub fn apply_penalties_dense(logits: &mut [f32], hist: &SeqHistory, p: &SamplingParams) {
    if p.has_penalties() {
        for (t, out_count) in hist.penalized_ids() {
            let idx = t as usize;
            if idx < logits.len() {
                logits[idx] = penalize_logit(logits[idx], true, out_count, p);
            }
        }
    }
    for (&t, &b) in &p.logit_bias {
        let idx = t as usize;
        if idx < logits.len() {
            logits[idx] += b;
        }
    }
}

/// Compute the penalized logit for one id without materializing the row
/// (zero-copy path over [`crate::tensor::ShardedLogits`]).
#[inline]
pub fn penalized_logit_at(
    raw: f32,
    id: u32,
    hist: &SeqHistory,
    p: &SamplingParams,
) -> f32 {
    let mut z = penalize_logit(raw, hist.seen(id), hist.out_count(id), p);
    if let Some(&b) = p.logit_bias.get(&id) {
        z += b;
    }
    z
}

/// Every id whose logit the penalties or the bias can move, sorted and
/// deduplicated. The sorted order matters: incremental f64 sum adjustments
/// iterate this list, and a deterministic order keeps those sums bit-equal
/// across samplers (HashMap iteration order is not).
pub fn touched_ids_sorted(hist: &SeqHistory, p: &SamplingParams) -> Vec<u32> {
    let mut ids: Vec<u32> = Vec::with_capacity(hist.num_penalized() + p.logit_bias.len());
    if p.has_penalties() {
        ids.extend(hist.penalized_ids().map(|(id, _)| id));
    }
    ids.extend(p.logit_bias.keys().copied());
    ids.sort_unstable();
    ids.dedup();
    ids
}

/// Column-wise batch history: the preallocated row-append buffer
/// `Y ∈ N^{Lmax×B}` plus per-sequence sparse histograms.
#[derive(Debug, Clone)]
pub struct BatchHistory {
    /// Row-append storage: rows[s][b] = token generated for sequence b at
    /// step s. Rows are contiguous B-wide appends (cache-friendly, no
    /// reallocation of prior rows) — the paper's `Y^T` layout.
    rows: Vec<Vec<u32>>,
    /// Per-sequence incremental histograms.
    seqs: Vec<SeqHistory>,
    capacity_rows: usize,
}

impl BatchHistory {
    pub fn new(prompts: &[Vec<u32>], max_len: usize) -> Self {
        BatchHistory {
            rows: Vec::with_capacity(max_len),
            seqs: prompts.iter().map(|p| SeqHistory::new(p)).collect(),
            capacity_rows: max_len,
        }
    }

    /// Single-sequence history with a replayed output prefix — the
    /// recompute-on-resume path after a preemption: the prompt seeds the
    /// prompt histogram, then each pre-preemption token is appended exactly
    /// as if it had just been decided. Both the engine's inline path and
    /// the sampler service rebuild resumed state through this one helper
    /// so the two can never diverge.
    pub fn with_replay(prompt: Vec<u32>, output: &[u32], max_len: usize) -> Self {
        let mut h = BatchHistory::new(&[prompt], max_len);
        for &t in output {
            h.append_row(&[t]);
        }
        h
    }

    pub fn batch(&self) -> usize {
        self.seqs.len()
    }
    pub fn steps(&self) -> usize {
        self.rows.len()
    }

    /// Append the step-s output row and update histograms incrementally
    /// (only the new row is touched — Eq. 5).
    pub fn append_row(&mut self, tokens: &[u32]) {
        assert_eq!(tokens.len(), self.seqs.len(), "row width mismatch");
        assert!(self.rows.len() < self.capacity_rows, "exceeded L_max");
        for (b, &t) in tokens.iter().enumerate() {
            self.seqs[b].append(t);
        }
        self.rows.push(tokens.to_vec());
    }

    /// Remove the newest row (inverse of [`Self::append_row`]) — used by
    /// speculative-decoding verification to roll back draft tokens past the
    /// rejection point. Returns the removed row.
    pub fn pop_row(&mut self) -> Vec<u32> {
        let row = self.rows.pop().expect("pop_row on empty history");
        for (b, &t) in row.iter().enumerate() {
            self.seqs[b].unappend(t);
        }
        row
    }

    pub fn seq(&self, b: usize) -> &SeqHistory {
        &self.seqs[b]
    }

    pub fn seq_mut(&mut self, b: usize) -> &mut SeqHistory {
        &mut self.seqs[b]
    }

    /// Naive full rebuild of sequence b's output histogram from the rows —
    /// what the baseline "vLLM CPU" port does every step (O(s) per seq), and
    /// the oracle the incremental path is property-tested against.
    pub fn rebuild(&self, b: usize) -> HashMap<u32, u32> {
        let mut counts = HashMap::new();
        for row in &self.rows {
            *counts.entry(row[b]).or_insert(0) += 1;
        }
        counts
    }

    /// Generated tokens of sequence b, oldest first (column read of Y^T).
    pub fn column(&self, b: usize) -> Vec<u32> {
        self.rows.iter().map(|r| r[b]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params_all() -> SamplingParams {
        SamplingParams {
            repetition_penalty: 2.0,
            presence_penalty: 0.5,
            frequency_penalty: 0.25,
            ..Default::default()
        }
    }

    #[test]
    fn seq_history_counts() {
        let mut h = SeqHistory::new(&[1, 2, 2, 3]);
        assert_eq!(h.prompt_count(2), 2);
        assert_eq!(h.out_count(2), 0);
        assert!(h.seen(1));
        assert!(!h.seen(9));
        h.append(9);
        h.append(9);
        h.append(2);
        assert_eq!(h.out_count(9), 2);
        assert_eq!(h.out_count(2), 1);
        assert_eq!(h.out_len(), 3);
        assert_eq!(h.num_penalized(), 4); // {1,2,3,9}
    }

    #[test]
    fn penalized_ids_cover_prompt_and_output_once() {
        let mut h = SeqHistory::new(&[5, 6]);
        h.append(6);
        h.append(7);
        let mut ids: Vec<u32> = h.penalized_ids().map(|(t, _)| t).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![5, 6, 7]);
        // counts: 5 -> 0 out, 6 -> 1 out, 7 -> 1 out
        let counts: HashMap<u32, u32> = h.penalized_ids().collect();
        assert_eq!(counts[&5], 0);
        assert_eq!(counts[&6], 1);
        assert_eq!(counts[&7], 1);
    }

    #[test]
    fn repetition_penalty_is_sign_aware() {
        let p = SamplingParams { repetition_penalty: 2.0, ..Default::default() };
        assert_eq!(penalize_logit(4.0, true, 0, &p), 2.0);
        assert_eq!(penalize_logit(-4.0, true, 0, &p), -8.0);
        // unseen tokens untouched
        assert_eq!(penalize_logit(4.0, false, 0, &p), 4.0);
    }

    #[test]
    fn presence_and_frequency_penalties_scale_with_count() {
        let p = SamplingParams {
            presence_penalty: 0.5,
            frequency_penalty: 0.25,
            ..Default::default()
        };
        // out_count 3: z - 0.5 - 3*0.25
        assert_eq!(penalize_logit(1.0, true, 3, &p), 1.0 - 0.5 - 0.75);
        // prompt-only (out_count 0): additive penalties don't apply
        assert_eq!(penalize_logit(1.0, true, 0, &p), 1.0);
    }

    #[test]
    fn dense_apply_touches_only_history() {
        let mut h = SeqHistory::new(&[0]);
        h.append(2);
        let mut logits = vec![1.0f32; 5];
        apply_penalties_dense(&mut logits, &h, &params_all());
        assert!(logits[0] < 1.0); // prompt token: repetition only
        assert_eq!(logits[1], 1.0);
        assert!(logits[2] < logits[0]); // output token: rep + presence + freq
        assert_eq!(logits[3], 1.0);
    }

    #[test]
    fn logit_bias_applied() {
        let mut p = SamplingParams::default();
        p.logit_bias.insert(3, 5.0);
        let h = SeqHistory::new(&[]);
        let mut logits = vec![0.0f32; 5];
        apply_penalties_dense(&mut logits, &h, &p);
        assert_eq!(logits[3], 5.0);
        assert_eq!(penalized_logit_at(0.0, 3, &h, &p), 5.0);
    }

    #[test]
    fn sparse_view_matches_dense() {
        let mut h = SeqHistory::new(&[1, 4]);
        h.append(4);
        h.append(2);
        let p = params_all();
        let raw: Vec<f32> = (0..8).map(|i| (i as f32) - 4.0).collect();
        let mut dense = raw.clone();
        apply_penalties_dense(&mut dense, &h, &p);
        for (i, &r) in raw.iter().enumerate() {
            assert_eq!(
                penalized_logit_at(r, i as u32, &h, &p),
                dense[i],
                "id {i}"
            );
        }
    }

    #[test]
    fn batch_history_incremental_equals_rebuild() {
        let prompts = vec![vec![1, 2], vec![3], vec![]];
        let mut bh = BatchHistory::new(&prompts, 16);
        let rows = [[1u32, 1, 1], [2, 1, 7], [1, 3, 7]];
        for row in &rows {
            bh.append_row(row);
        }
        for b in 0..3 {
            let rebuilt = bh.rebuild(b);
            // incremental histogram must equal the naive rebuild
            for (&t, &c) in &rebuilt {
                assert_eq!(bh.seq(b).out_count(t), c, "b={b} t={t}");
            }
            let total: u32 = rebuilt.values().sum();
            assert_eq!(total as usize, bh.seq(b).out_len());
        }
        assert_eq!(bh.column(0), vec![1, 2, 1]);
        assert_eq!(bh.column(2), vec![1, 7, 7]);
    }

    #[test]
    fn unappend_is_exact_inverse_of_append() {
        let mut h = SeqHistory::new(&[1, 2]);
        h.append(9);
        h.append(9);
        h.append(2);
        let snapshot = (h.out_count(9), h.out_count(2), h.out_len());
        h.append(9);
        h.append(5);
        h.unappend(5);
        h.unappend(9);
        assert_eq!((h.out_count(9), h.out_count(2), h.out_len()), snapshot);
        assert!(!h.seen(5), "fully-rolled-back token leaves no trace");
        assert_eq!(h.num_penalized(), 3); // {1, 2, 9}
    }

    #[test]
    fn pop_row_rolls_back_batch_history() {
        let mut bh = BatchHistory::new(&[vec![1], vec![2]], 8);
        bh.append_row(&[3, 4]);
        bh.append_row(&[5, 4]);
        let cols = (bh.column(0), bh.column(1));
        bh.append_row(&[7, 8]); // speculative roll-forward
        bh.append_row(&[9, 4]);
        assert_eq!(bh.pop_row(), vec![9, 4]);
        assert_eq!(bh.pop_row(), vec![7, 8]);
        assert_eq!((bh.column(0), bh.column(1)), cols);
        assert_eq!(bh.seq(1).out_count(4), 2);
        assert!(!bh.seq(0).seen(7));
        // the rebuilt histogram agrees after rollback
        for b in 0..2 {
            for (&t, &c) in &bh.rebuild(b) {
                assert_eq!(bh.seq(b).out_count(t), c);
            }
        }
    }

    #[test]
    #[should_panic]
    fn unappend_never_appended_panics() {
        let mut h = SeqHistory::new(&[1]);
        h.append(2);
        h.unappend(3);
    }

    #[test]
    #[should_panic]
    fn append_beyond_lmax_panics() {
        let mut bh = BatchHistory::new(&[vec![]], 1);
        bh.append_row(&[0]);
        bh.append_row(&[1]);
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut bh = BatchHistory::new(&[vec![], vec![]], 4);
        bh.append_row(&[0]);
    }
}
