//! Hot-vocab sizing model (§5.4).
//!
//! Composes an affine CPU-cost model `T_cpu(H) = c·H + c0` (fit by least
//! squares from a few measured points — Figure 11a) with an empirical,
//! monotone-saturating hit-ratio curve `ᾱ(H)` (interpolated from traces —
//! Figure 11b) into the expected decision cost
//!
//! `F(H) = c0 + c·(ᾱ(H)·H + (1 − ᾱ(H))·(V − H))`   (Eq. 10)
//!
//! whose interior minimizer `H*` satisfies the first-order condition
//! `2ᾱ(H) + (2H − V)·ᾱ'(H) = 1` (Eq. 12). Because H is discrete, deployment
//! enumerates around the continuous stationary point and takes the argmin —
//! exactly the procedure the paper prescribes.

use crate::metrics::stats::{affine_fit, Interp1};

/// Fitted sizing model for one (model, platform) pair.
#[derive(Debug, Clone)]
pub struct SizingModel {
    /// Per-visited-token scan cost (seconds).
    pub c: f64,
    /// Fixed per-sequence overhead (seconds).
    pub c0: f64,
    /// Fit quality of the affine cost model.
    pub r2: f64,
    /// Hit-ratio curve ᾱ(H).
    pub alpha: Interp1,
    /// Full vocabulary size V.
    pub vocab: usize,
}

impl SizingModel {
    /// Fit from measurements: `(H, hot-path seconds)` pairs for the cost
    /// model and `(H, ᾱ)` knots for the hit-ratio curve.
    pub fn fit(
        cost_points: &[(f64, f64)],
        alpha_knots: &[(f64, f64)],
        vocab: usize,
    ) -> SizingModel {
        let xs: Vec<f64> = cost_points.iter().map(|p| p.0).collect();
        let ys: Vec<f64> = cost_points.iter().map(|p| p.1).collect();
        let (c, c0, r2) = affine_fit(&xs, &ys);
        let ax: Vec<f64> = alpha_knots.iter().map(|p| p.0).collect();
        let ay: Vec<f64> = alpha_knots.iter().map(|p| p.1).collect();
        SizingModel { c, c0, r2, alpha: Interp1::new(ax, ay), vocab }
    }

    /// Construct directly from known constants (tests, what-if analyses).
    pub fn from_parts(c: f64, c0: f64, alpha: Interp1, vocab: usize) -> SizingModel {
        SizingModel { c, c0, r2: 1.0, alpha, vocab }
    }

    /// Expected decision cost F(H) (Eq. 10), seconds per sequence.
    pub fn f(&self, h: f64) -> f64 {
        let a = self.alpha.eval(h).clamp(0.0, 1.0);
        let v = self.vocab as f64;
        self.c0 + self.c * (a * h + (1.0 - a) * (v - h))
    }

    /// Predicted per-sampler throughput 1/F(H) (Figure 12b's overlay).
    pub fn predicted_throughput(&self, h: f64) -> f64 {
        let f = self.f(h);
        if f > 0.0 {
            1.0 / f
        } else {
            0.0
        }
    }

    /// First-order-condition residual: `2ᾱ(H) + (2H − V)ᾱ'(H) − 1`
    /// (Eq. 12 LHS − RHS). Zero at the stationary point.
    pub fn foc_residual(&self, h: f64) -> f64 {
        let a = self.alpha.eval(h);
        let da = self.alpha.derivative(h);
        2.0 * a + (2.0 * h - self.vocab as f64) * da - 1.0
    }

    /// Continuous stationary point H* via dF/dH sign scan + bisection over
    /// the ᾱ knot domain. Falls back to the best scanned point if no sign
    /// change exists (boundary optimum).
    pub fn h_star_continuous(&self) -> f64 {
        let (lo, hi) = self.alpha.domain();
        let n = 512;
        let step = (hi - lo) / n as f64;
        let df = |h: f64| (self.f(h + step * 0.5) - self.f(h - step * 0.5)) / step;
        let mut best_h = lo;
        let mut best_f = f64::INFINITY;
        let mut bracket: Option<(f64, f64)> = None;
        let mut prev_h = lo + step;
        let mut prev_df = df(prev_h);
        for i in 2..n {
            let h = lo + step * i as f64;
            let d = df(h);
            if prev_df < 0.0 && d >= 0.0 && bracket.is_none() {
                bracket = Some((prev_h, h));
            }
            let fv = self.f(h);
            if fv < best_f {
                best_f = fv;
                best_h = h;
            }
            prev_h = h;
            prev_df = d;
        }
        if let Some((mut a, mut b)) = bracket {
            for _ in 0..60 {
                let m = 0.5 * (a + b);
                if df(m) < 0.0 {
                    a = m;
                } else {
                    b = m;
                }
            }
            0.5 * (a + b)
        } else {
            best_h
        }
    }

    /// Deployment choice: enumerate a candidate grid around the continuous
    /// optimum (±50%, plus the knots) and return `argmin_H F(H)` as an
    /// integer hot-vocab size.
    pub fn h_star(&self) -> usize {
        let hc = self.h_star_continuous();
        let (lo, hi) = self.alpha.domain();
        let mut candidates: Vec<f64> = Vec::new();
        let from = (hc * 0.5).max(lo);
        let to = (hc * 1.5).min(hi);
        let steps = 256;
        for i in 0..=steps {
            candidates.push(from + (to - from) * i as f64 / steps as f64);
        }
        candidates.push(lo);
        candidates.push(hi);
        let best = candidates
            .into_iter()
            .min_by(|&a, &b| self.f(a).partial_cmp(&self.f(b)).unwrap())
            .unwrap();
        (best.round() as usize).clamp(1, self.vocab - 1)
    }
}

/// Online ᾱ(H) re-estimator: a multiplicative correction *curve* over the
/// offline prior, learned from runtime acceptance counters (§5.4 made
/// adaptive, §9 future-work item i).
///
/// The offline `SizingModel` fixes ᾱ(H) from a trace; live traffic drifts.
/// Rather than one global scale factor — which wrongly extrapolates a
/// shift observed at the current H to every other H — this keeps an EWMA
/// of the observed/predicted ratio at geometric H knots and interpolates
/// (piecewise-linear in log H) between them. Regions the controller has
/// never visited retain the offline prior (correction 1.0), so re-solving
/// `argmin F` trusts the trace exactly where no evidence contradicts it.
#[derive(Debug, Clone)]
pub struct OnlineAlphaEstimator {
    /// Geometric knot positions in H (ascending).
    knots: Vec<f64>,
    /// EWMA of observed/predicted ᾱ ratio at each knot (1.0 = prior).
    corr: Vec<f64>,
    /// EWMA weight for one observation window.
    gain: f64,
}

impl OnlineAlphaEstimator {
    pub fn new(h_min: f64, h_max: f64, num_knots: usize, gain: f64) -> Self {
        let num_knots = num_knots.max(2);
        let lo = h_min.max(1.0);
        let hi = h_max.max(lo * 1.0001);
        let knots: Vec<f64> = (0..num_knots)
            .map(|i| {
                let t = i as f64 / (num_knots - 1) as f64;
                (lo.ln() + (hi.ln() - lo.ln()) * t).exp()
            })
            .collect();
        let corr = vec![1.0; knots.len()];
        OnlineAlphaEstimator { knots, corr, gain: gain.clamp(0.0, 1.0) }
    }

    /// Fold one control-window observation at hot size `h` into the curve:
    /// `ratio` = observed ᾱ / prior ᾱ(h). The update is split between the
    /// two bracketing knots by their interpolation weights, so repeated
    /// windows at a fixed H converge that neighborhood without touching
    /// the rest of the curve.
    pub fn observe(&mut self, h: f64, ratio: f64) {
        let ratio = ratio.clamp(0.25, 2.0);
        let (i, j, w) = self.bracket(h);
        self.corr[i] += (1.0 - w) * self.gain * (ratio - self.corr[i]);
        self.corr[j] += w * self.gain * (ratio - self.corr[j]);
    }

    /// Multiplicative correction to apply to the prior ᾱ at `h`.
    pub fn correction(&self, h: f64) -> f64 {
        let (i, j, w) = self.bracket(h);
        (self.corr[i] * (1.0 - w) + self.corr[j] * w).clamp(0.25, 2.0)
    }

    /// Bracketing knots and the log-space interpolation weight of the
    /// upper one. Clamps outside the knot domain.
    fn bracket(&self, h: f64) -> (usize, usize, f64) {
        let h = h.max(1.0);
        if h <= self.knots[0] {
            return (0, 0, 0.0);
        }
        let last = self.knots.len() - 1;
        if h >= self.knots[last] {
            return (last, last, 0.0);
        }
        let mut j = 1;
        while self.knots[j] < h {
            j += 1;
        }
        let i = j - 1;
        let w = (h.ln() - self.knots[i].ln()) / (self.knots[j].ln() - self.knots[i].ln());
        (i, j, w.clamp(0.0, 1.0))
    }
}

/// Build the ᾱ(H) knots analytically from a Zipf-shaped token distribution
/// (the offline-trace profiling substrate; model/policy-driven per §5.4).
pub fn zipf_alpha_knots(vocab: usize, zipf_s: f64, num_knots: usize) -> Vec<(f64, f64)> {
    let zipf = crate::rng::zipf::ZipfMandelbrot::zipf(vocab, zipf_s);
    let mut knots = Vec::with_capacity(num_knots);
    for i in 0..num_knots {
        // geometric spacing: hit-ratio curves saturate, so resolve the head
        let frac = (i + 1) as f64 / num_knots as f64;
        let h = ((vocab as f64).powf(frac)).round().max(1.0) as usize;
        knots.push((h as f64, zipf.head_mass(h)));
    }
    knots.dedup_by(|a, b| a.0 == b.0);
    knots
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(vocab: usize, s: f64) -> SizingModel {
        let knots = zipf_alpha_knots(vocab, s, 24);
        // paper's measured constants (Fig. 11a): c0 = 8.55e-6, c = 1.06e-8
        let cost: Vec<(f64, f64)> = (1..=8)
            .map(|i| {
                let h = i as f64 * vocab as f64 / 8.0;
                (h, 1.06e-8 * h + 8.55e-6)
            })
            .collect();
        SizingModel::fit(&cost, &knots, vocab)
    }

    #[test]
    fn fit_recovers_paper_constants() {
        let m = model(152_064, 1.1);
        assert!((m.c - 1.06e-8).abs() < 1e-12);
        assert!((m.c0 - 8.55e-6).abs() < 1e-9);
        assert!(m.r2 > 0.999999);
    }

    #[test]
    fn f_has_interior_minimum() {
        let m = model(152_064, 1.1);
        let f_small = m.f(16.0);
        let f_star = m.f(m.h_star() as f64);
        let f_full = m.f(150_000.0);
        assert!(f_star < f_small, "F(H*)={f_star} F(16)={f_small}");
        assert!(f_star < f_full, "F(H*)={f_star} F(V)={f_full}");
    }

    #[test]
    fn h_star_matches_brute_force() {
        let m = model(32_768, 1.2);
        let h_star = m.h_star();
        // brute force over the full domain
        let (lo, hi) = m.alpha.domain();
        let mut best = lo;
        let mut best_f = f64::INFINITY;
        let mut h = lo;
        while h <= hi {
            let fv = m.f(h);
            if fv < best_f {
                best_f = fv;
                best = h;
            }
            h += 1.0;
        }
        let rel = (m.f(h_star as f64) - best_f).abs() / best_f;
        assert!(rel < 0.01, "F(h*)={} brute={best_f} at {best}", m.f(h_star as f64));
    }

    #[test]
    fn foc_residual_changes_sign_around_h_star() {
        let m = model(100_000, 1.1);
        let hc = m.h_star_continuous();
        // dF/dH = c * foc_residual ⇒ residual < 0 left of H*, > 0 right.
        assert!(m.foc_residual(hc * 0.2) < 0.0);
        assert!(m.foc_residual((hc * 4.0).min(m.alpha.domain().1 * 0.9)) > 0.0);
    }

    #[test]
    fn steeper_zipf_gives_smaller_h_star() {
        // More concentrated distributions need smaller hot sets.
        let flat = model(100_000, 0.9).h_star();
        let steep = model(100_000, 1.4).h_star();
        assert!(
            steep < flat,
            "steep zipf H*={steep} should be < flat H*={flat}"
        );
    }

    #[test]
    fn throughput_is_inverse_cost() {
        let m = model(50_000, 1.1);
        let h = 1000.0;
        assert!((m.predicted_throughput(h) * m.f(h) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn online_estimator_learns_locally() {
        let mut est = OnlineAlphaEstimator::new(64.0, 32_768.0, 12, 0.5);
        // no observations: prior everywhere
        assert_eq!(est.correction(1000.0), 1.0);
        // repeated shift observations at H=1000 converge that neighborhood
        for _ in 0..32 {
            est.observe(1000.0, 0.6);
        }
        assert!(
            (est.correction(1000.0) - 0.6).abs() < 0.05,
            "corr {}",
            est.correction(1000.0)
        );
        // ...while far-away regions keep trusting the offline prior
        assert!((est.correction(30_000.0) - 1.0).abs() < 1e-9);
        assert!((est.correction(64.0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn online_estimator_clamps_and_brackets_edges() {
        let mut est = OnlineAlphaEstimator::new(64.0, 4096.0, 6, 1.0);
        est.observe(1.0, 100.0); // below domain, absurd ratio
        assert!(est.correction(1.0) <= 2.0);
        est.observe(1e9, 0.0); // above domain, ratio floor
        assert!(est.correction(1e9) >= 0.25);
        // interior query between knots interpolates smoothly
        let c = est.correction(500.0);
        assert!((0.25..=2.0).contains(&c));
    }

    #[test]
    fn alpha_knots_monotone_saturating() {
        let knots = zipf_alpha_knots(152_064, 1.1, 20);
        for w in knots.windows(2) {
            assert!(w[1].0 > w[0].0);
            assert!(w[1].1 >= w[0].1, "ᾱ must be monotone");
        }
        assert!(knots.last().unwrap().1 > 0.99);
        // diminishing marginal gains (concavity, coarse check)
        let first_gain = knots[1].1 - knots[0].1;
        let last_gain = knots[knots.len() - 1].1 - knots[knots.len() - 2].1;
        assert!(last_gain < first_gain);
    }
}
