//! Speculative-decoding verification in the decision plane (§5.3, §9).
//!
//! Given `k` draft tokens proposed for a sequence and the target-model
//! logits at the `k+1` chain positions (the base position plus one per
//! draft token), the verifier commits the **accepted draft prefix plus one
//! corrected bonus token**, exactly as classic rejection-based speculative
//! decoding does — specialized to a *deterministic* draft.
//!
//! # Exactness
//!
//! With a deterministic proposal `d_j` (a point-mass draft distribution),
//! rejection verification reduces to: draw `y_j` from the full filtered
//! target distribution `p_j` (the same inverse-CDF draw non-speculative
//! decode performs, with the same `(seed, seq, decode_iter)`-keyed
//! uniform), accept the draft iff `d_j == y_j`, and on rejection commit
//! `y_j` itself as the corrected token. Acceptance happens with probability
//! `p_j(d_j)` and the committed token is distributed as `p_j` *in every
//! case* — the general accept-with-`min(1, p/q)`-else-residual scheme
//! collapses to this when `q` is a point mass. Two consequences:
//!
//! 1. the per-position induced distribution equals the oracle full-V
//!    filtered softmax (checked by `harness/exactness.rs`), and
//! 2. the committed stream is **bit-identical** to non-speculative decode
//!    for any `k` and any sampler count `m`, because position `j` reuses
//!    decode iteration `base + j`'s uniforms against the same logits.
//!
//! # Batched verification with rollback
//!
//! All `k+1` positions are decided against the *draft* chain (their logits
//! were produced by feeding draft tokens, so penalties/grammar must see the
//! same prefix): the sequence's incremental history and grammar state are
//! rolled forward one draft token at a time, each position decided with the
//! truncation-first filtered pipeline, and then the state is **rolled
//! back** past the first rejection ([`BatchHistory::pop_row`] /
//! saved [`ConstraintState`]s) before the corrected token is applied.
//! Decisions beyond the rejection point are discarded — their logits were
//! conditioned on a prefix that never got committed.

use super::grammar::{ConstraintState, GrammarConstraint};
use super::penalties::BatchHistory;
use super::pipeline::DecisionPipeline;
use super::params::SamplingParams;
use super::shvs::Precompute;
use crate::tensor::ShardedLogits;
use std::sync::Arc;

/// The outcome of verifying one speculative window for one sequence.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Verdict {
    /// Tokens to commit, in order: the accepted draft prefix followed by
    /// one corrected/bonus token. `1 ..= proposed + 1` tokens.
    pub tokens: Vec<u32>,
    /// Number of draft tokens accepted (`tokens.len() - 1`).
    pub accepted: usize,
    /// Number of draft tokens proposed (the window size `k`; 0 for a plain
    /// non-speculative decision).
    pub proposed: usize,
}

impl Verdict {
    /// Convenience for the non-speculative single-token case.
    pub fn single(token: u32) -> Verdict {
        Verdict { tokens: vec![token], accepted: 0, proposed: 0 }
    }
}

/// Sampler-local grammar state, as owned by a sampler worker per sequence.
pub type GrammarSlot = Option<(Arc<GrammarConstraint>, ConstraintState)>;

/// Verify one speculative window for the sequence owning column `col`.
///
/// `views[j]` holds the target logits for chain position `j` (`views[0]`
/// is the base decode step; `views[j>0]` was produced by feeding
/// `draft[j-1]`). `pre[j]` carries the per-column SHVS precompute for view
/// `j` (may be empty). `hist` is the owner's single-column history;
/// `grammar` its constraint state. Both are left advanced by exactly the
/// committed tokens — roll-forward along the draft chain is undone past the
/// rejection point. With an empty `draft` this degenerates to one plain
/// decision (and is the code path every non-speculative iteration takes).
#[allow(clippy::too_many_arguments)]
pub fn verify_window(
    pipeline: &mut DecisionPipeline,
    views: &[ShardedLogits],
    col: usize,
    draft: &[u32],
    hist: &mut BatchHistory,
    grammar: &mut GrammarSlot,
    params: &SamplingParams,
    pre: &[Vec<Precompute>],
    seq_id: u64,
    base_iter: u64,
) -> Verdict {
    assert!(!views.is_empty(), "verify_window needs at least the base view");
    let k = draft.len().min(views.len() - 1);
    let mut decided: Vec<u32> = Vec::with_capacity(k + 1);
    // Grammar states saved before each draft roll-forward, for rollback.
    let mut grammar_stack: Vec<ConstraintState> = Vec::with_capacity(k);

    for (j, view) in views.iter().enumerate().take(k + 1) {
        // Structured decoding: restrict to grammar-viable tokens at the
        // rolled-forward state (exact allow-list path).
        let owned;
        let params_j = match grammar.as_ref() {
            Some((g, state)) => {
                let allowed = g.allowed_tokens(*state);
                if allowed.is_empty() {
                    params
                } else {
                    owned = SamplingParams {
                        allowed_tokens: Some(allowed),
                        ..params.clone()
                    };
                    &owned
                }
            }
            None => params,
        };
        let pre_j = pre.get(j).and_then(|p| p.get(col));
        let d = pipeline.decide(
            view,
            col,
            hist,
            0, // single-column owner history
            params_j,
            pre_j,
            seq_id,
            base_iter + j as u64,
        );
        decided.push(d.token);
        if j < k {
            // Roll local metadata forward along the DRAFT chain: position
            // j+1's logits are conditioned on draft[..=j], so its penalties
            // and grammar mask must be too.
            if let Some((g, state)) = grammar.as_mut() {
                grammar_stack.push(*state);
                if let Some(next) = g.advance(*state, draft[j]) {
                    *state = next;
                }
            }
            hist.append_row(&[draft[j]]);
        }
    }

    // Accepted prefix: the longest run where the target draw reproduced the
    // draft. Everything after it was conditioned on a rejected prefix.
    let mut accepted = 0usize;
    while accepted < k && decided[accepted] == draft[accepted] {
        accepted += 1;
    }

    // Rollback: un-count the rejected draft roll-forward.
    for _ in accepted..k {
        hist.pop_row();
    }
    if accepted < k {
        if let Some((_, state)) = grammar.as_mut() {
            *state = grammar_stack[accepted];
        }
    }

    // Commit the corrected/bonus token into the local state. (The accepted
    // prefix is already applied: its rows equal the committed tokens.)
    let bonus = decided[accepted];
    hist.append_row(&[bonus]);
    if let Some((g, state)) = grammar.as_mut() {
        if let Some(next) = g.advance(*state, bonus) {
            *state = next;
        }
    }

    decided.truncate(accepted + 1);
    Verdict { tokens: decided, accepted, proposed: k }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::draft::DraftProposer;
    use crate::config::DecisionVariant;
    use crate::harness::measure::LogitsGen;

    const VOCAB: usize = 128;

    /// Context-free synthetic data plane: logits keyed by decode_iter only,
    /// so the spec chain's views are exactly what non-speculative decode
    /// would see — the committed streams must then match bit-for-bit.
    fn iter_views(gen: &LogitsGen, base: u64, n: usize) -> Vec<ShardedLogits> {
        (0..n as u64).map(|j| gen.view(1, base + j, 2)).collect()
    }

    fn decode_plain(gen: &LogitsGen, params: &SamplingParams, steps: usize) -> Vec<u32> {
        let mut pipe = DecisionPipeline::new(DecisionVariant::Offloading, None, 7);
        let mut hist = BatchHistory::new(&[vec![1, 2, 3]], 256);
        let mut out = Vec::new();
        for it in 0..steps as u64 {
            let view = gen.view(1, it, 2);
            let d = pipe.decide(&view, 0, &hist, 0, params, None, 5, it);
            hist.append_row(&[d.token]);
            out.push(d.token);
        }
        out
    }

    fn decode_spec(
        gen: &LogitsGen,
        params: &SamplingParams,
        steps: usize,
        k: usize,
    ) -> (Vec<u32>, usize, usize) {
        let proposer = DraftProposer::new();
        let mut pipe = DecisionPipeline::new(DecisionVariant::Offloading, None, 7);
        let mut hist = BatchHistory::new(&[vec![1, 2, 3]], 256);
        let mut grammar: GrammarSlot = None;
        let mut out: Vec<u32> = Vec::new();
        let (mut acc, mut prop) = (0usize, 0usize);
        while out.len() < steps {
            let base = out.len() as u64;
            let draft = proposer.propose(params.seed, VOCAB, &[1, 2, 3], &out, k);
            let views = iter_views(gen, base, draft.len() + 1);
            let v = verify_window(
                &mut pipe, &views, 0, &draft, &mut hist, &mut grammar, params, &[], 5,
                base,
            );
            assert_eq!(v.tokens.len(), v.accepted + 1);
            assert_eq!(v.tokens[..v.accepted], draft[..v.accepted]);
            acc += v.accepted;
            prop += v.proposed;
            out.extend(&v.tokens);
        }
        out.truncate(steps);
        (out, acc, prop)
    }

    #[test]
    fn spec_streams_bit_identical_to_plain_decode() {
        let gen = LogitsGen::new(VOCAB, 1.1, 21);
        let params = SamplingParams::production_default();
        let plain = decode_plain(&gen, &params, 40);
        for k in [1usize, 2, 4, 7] {
            let (spec, acc, prop) = decode_spec(&gen, &params, 40, k);
            assert_eq!(spec, plain, "k={k}");
            assert!(acc <= prop, "k={k}: accepted {acc} of {prop}");
        }
    }

    #[test]
    fn empty_draft_is_a_plain_decision() {
        let gen = LogitsGen::new(VOCAB, 1.1, 3);
        let params = SamplingParams::production_default();
        let plain = decode_plain(&gen, &params, 12);
        let (spec, acc, prop) = decode_spec(&gen, &params, 12, 0);
        assert_eq!(spec, plain);
        assert_eq!((acc, prop), (0, 0));
    }

    #[test]
    fn history_matches_committed_tokens_after_rollback() {
        // After every window the owner history must hold exactly the
        // committed tokens — no residue from rejected draft roll-forward.
        let gen = LogitsGen::new(VOCAB, 1.1, 9);
        let params = SamplingParams::production_default();
        let proposer = DraftProposer::new();
        let mut pipe = DecisionPipeline::new(DecisionVariant::Offloading, None, 11);
        let mut hist = BatchHistory::new(&[vec![4, 5]], 256);
        let mut grammar: GrammarSlot = None;
        let mut out: Vec<u32> = Vec::new();
        for _ in 0..8 {
            let base = out.len() as u64;
            let draft = proposer.propose(0, VOCAB, &[4, 5], &out, 3);
            let views = iter_views(&gen, base, draft.len() + 1);
            let v = verify_window(
                &mut pipe, &views, 0, &draft, &mut hist, &mut grammar, &params, &[], 2,
                base,
            );
            out.extend(&v.tokens);
            assert_eq!(hist.column(0), out, "history == committed stream");
            assert_eq!(hist.seq(0).out_len(), out.len());
        }
    }

    #[test]
    fn grammar_state_rolls_back_past_rejection() {
        use super::super::grammar::byte_tokenizer_table;
        // Grammar [0-9]+ over the byte tokenizer; draft a token the grammar
        // forbids — the verifier must reject it (the allow-list excludes
        // it), commit a legal corrected token, and keep the grammar state
        // consistent with the committed text only.
        let vocab = 300;
        let g = Arc::new(
            GrammarConstraint::new(r"[0-9]+", byte_tokenizer_table(vocab)).unwrap(),
        );
        let start = g.start();
        let mut grammar: GrammarSlot = Some((g.clone(), start));
        let gen = LogitsGen::new(vocab, 1.1, 13);
        let params = SamplingParams { temperature: 0.9, ..Default::default() };
        let mut pipe = DecisionPipeline::new(DecisionVariant::Offloading, None, 5);
        let mut hist = BatchHistory::new(&[vec![1]], 64);
        let tok_x = 3 + 'x' as u32; // illegal under the grammar
        let views = iter_views(&gen, 0, 3);
        let v = verify_window(
            &mut pipe,
            &views,
            0,
            &[tok_x, tok_x],
            &mut hist,
            &mut grammar,
            &params,
            &[],
            1,
            0,
        );
        assert_eq!(v.accepted, 0, "grammar-illegal draft cannot be accepted");
        assert_eq!(v.tokens.len(), 1);
        let digit = v.tokens[0];
        assert!((3 + '0' as u32..=3 + '9' as u32).contains(&digit), "token {digit}");
        // state must equal start advanced by exactly the committed token
        let expect = g.advance(start, digit).unwrap();
        assert_eq!(grammar.unwrap().1, expect);
        assert_eq!(hist.column(0), vec![digit]);
    }

    #[test]
    fn acceptance_is_nonzero_for_self_repeating_streams() {
        // Zipf-headed logits + greedy-ish temperature repeat tokens often;
        // the n-gram proposer must then win a useful share of acceptances.
        let gen = LogitsGen::new(VOCAB, 1.4, 2);
        let params = SamplingParams {
            temperature: 0.3,
            top_k: 8,
            ..SamplingParams::default()
        };
        let (_, acc, prop) = decode_spec(&gen, &params, 120, 3);
        assert!(prop > 0);
        assert!(acc > 0, "no draft token ever accepted over {prop} proposals");
    }
}
