//! Hot-vocabulary construction (§5.3).
//!
//! The hot set `H ⊂ V` is model-dependent and built from traces: rank
//! tokens by observed frequency and keep the top H. A `HotVocab` carries the
//! *full* frequency ranking (rank → id permutation over V, shared via `Arc`),
//! not just the member list: the adaptive sizing controller (§5.4) resizes H
//! online with [`HotVocab::resize`], and the SHVS coupled draw walks tokens
//! in rank order so that nested prefixes of one ranking produce bit-identical
//! token streams for every H. Membership tests are O(1) via the inverse
//! rank table; the sorted id list drives the O(H) hot-path gather.

use crate::rng::zipf::ZipfMandelbrot;
use crate::rng::Philox;
use std::sync::Arc;

/// An immutable hot set, shared across samplers.
#[derive(Debug, Clone)]
pub struct HotVocab {
    /// Hot token ids, ascending.
    ids: Vec<u32>,
    /// rank → id permutation over the full vocabulary (rank 0 = hottest).
    /// Shared across resized instances so all H share one rank order.
    ranking: Arc<Vec<u32>>,
    /// id → rank inverse of `ranking`.
    rank_of: Arc<Vec<u32>>,
    /// rank r (r < h) → index into `ids`, so the id-order hot gather can be
    /// walked in rank order without re-sorting.
    rank_pos: Vec<u32>,
    vocab: usize,
}

impl HotVocab {
    /// Build from an explicit id list. The synthesized ranking is the hot
    /// ids ascending followed by the tail ascending — i.e. rank order within
    /// H equals id order, which keeps pre-ranking callers bit-compatible.
    pub fn new(mut ids: Vec<u32>, vocab: usize) -> Self {
        ids.sort_unstable();
        ids.dedup();
        assert!(
            ids.last().is_none_or(|&v| (v as usize) < vocab),
            "hot id out of vocab"
        );
        assert!(ids.len() < vocab, "hot set must be a strict subset");
        let h = ids.len();
        let mut ranking = Vec::with_capacity(vocab);
        ranking.extend_from_slice(&ids);
        let mut member = vec![false; vocab];
        for &v in &ids {
            member[v as usize] = true;
        }
        ranking.extend((0..vocab as u32).filter(|&v| !member[v as usize]));
        let rank_of = invert(&ranking);
        HotVocab {
            ids,
            ranking: Arc::new(ranking),
            rank_of: Arc::new(rank_of),
            rank_pos: (0..h as u32).collect(),
            vocab,
        }
    }

    /// Build from a full frequency ranking (rank → id permutation over V),
    /// keeping the first `h` ranks hot.
    pub fn from_ranking(ranking: Arc<Vec<u32>>, h: usize, vocab: usize) -> Self {
        assert_eq!(ranking.len(), vocab, "ranking must cover the vocab");
        assert!(h < vocab, "hot set must be a strict subset");
        let rank_of = Arc::new(invert(&ranking));
        Self::from_shared(ranking, rank_of, h, vocab)
    }

    fn from_shared(
        ranking: Arc<Vec<u32>>,
        rank_of: Arc<Vec<u32>>,
        h: usize,
        vocab: usize,
    ) -> Self {
        let mut ids: Vec<u32> = ranking[..h].to_vec();
        ids.sort_unstable();
        let rank_pos = ranking[..h]
            .iter()
            .map(|&id| ids.binary_search(&id).unwrap() as u32)
            .collect();
        HotVocab { ids, ranking, rank_of, rank_pos, vocab }
    }

    /// A hot set over the same ranking with a different H. O(h log h); the
    /// rank tables are shared, so adaptive resizing allocates only the id
    /// list. Nested prefixes of one ranking are what make adaptive-vs-static
    /// SHVS streams bit-identical.
    pub fn resize(&self, new_h: usize) -> Self {
        let new_h = new_h.clamp(1, self.vocab - 1);
        Self::from_shared(self.ranking.clone(), self.rank_of.clone(), new_h, self.vocab)
    }

    /// Build from trace token counts: the `h` most frequent ids (ties by
    /// id), with the full count ranking retained for online resizing.
    pub fn from_counts(counts: &[u64], h: usize) -> Self {
        let vocab = counts.len();
        let h = h.min(vocab.saturating_sub(1)).max(1);
        let mut idx: Vec<u32> = (0..vocab as u32).collect();
        idx.sort_unstable_by(|&a, &b| {
            counts[b as usize]
                .cmp(&counts[a as usize])
                .then(a.cmp(&b))
        });
        Self::from_ranking(Arc::new(idx), h, vocab)
    }

    /// Synthetic trace: draw `samples` tokens from a Zipf-shaped unigram
    /// distribution over `vocab` (rank == id under `perm_seed`-driven
    /// shuffling of ranks), then keep the top `h`. Models the paper's
    /// offline trace profiling.
    pub fn from_synthetic_trace(
        vocab: usize,
        h: usize,
        zipf_s: f64,
        samples: usize,
        seed: u64,
    ) -> Self {
        let zipf = ZipfMandelbrot::zipf(vocab, zipf_s);
        let mut rng = Philox::new(seed);
        // rank -> id permutation (so hot ids are NOT simply 0..h)
        let mut rank_to_id: Vec<u32> = (0..vocab as u32).collect();
        rng.shuffle(&mut rank_to_id);
        let mut counts = vec![0u64; vocab];
        for _ in 0..samples {
            let r = zipf.sample(&mut rng);
            counts[rank_to_id[r] as usize] += 1;
        }
        Self::from_counts(&counts, h)
    }

    #[inline]
    pub fn contains(&self, v: u32) -> bool {
        let v = v as usize;
        debug_assert!(v < self.vocab);
        (self.rank_of[v] as usize) < self.ids.len()
    }

    /// Sorted hot ids.
    pub fn ids(&self) -> &[u32] {
        &self.ids
    }
    /// The full rank → id permutation (rank 0 = hottest).
    pub fn ranking(&self) -> &[u32] {
        &self.ranking
    }
    /// For rank r < h: the index of `ranking[r]` within the ascending `ids`
    /// list, so id-order gathers can be consumed in rank order.
    #[inline]
    pub fn rank_index(&self, r: usize) -> usize {
        self.rank_pos[r] as usize
    }
    pub fn len(&self) -> usize {
        self.ids.len()
    }
    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }
    pub fn vocab(&self) -> usize {
        self.vocab
    }
    pub fn tail_len(&self) -> usize {
        self.vocab - self.ids.len()
    }

    pub fn into_arc(self) -> Arc<HotVocab> {
        Arc::new(self)
    }
}

fn invert(ranking: &[u32]) -> Vec<u32> {
    let mut rank_of = vec![u32::MAX; ranking.len()];
    for (r, &id) in ranking.iter().enumerate() {
        assert_eq!(rank_of[id as usize], u32::MAX, "ranking must be a permutation");
        rank_of[id as usize] = r as u32;
    }
    rank_of
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn membership_and_sizes() {
        let h = HotVocab::new(vec![5, 1, 3, 3], 10);
        assert_eq!(h.ids(), &[1, 3, 5]);
        assert_eq!(h.len(), 3);
        assert_eq!(h.tail_len(), 7);
        for v in 0..10u32 {
            assert_eq!(h.contains(v), [1, 3, 5].contains(&v), "v={v}");
        }
    }

    #[test]
    fn from_counts_takes_most_frequent() {
        let counts = vec![5u64, 100, 2, 50, 50, 0];
        let h = HotVocab::from_counts(&counts, 3);
        // top-3 by count: 1(100), 3(50), 4(50)
        assert_eq!(h.ids(), &[1, 3, 4]);
        // full ranking continues past H in count order
        assert_eq!(h.ranking(), &[1, 3, 4, 0, 2, 5]);
    }

    #[test]
    fn from_counts_tie_break_by_id() {
        let counts = vec![7u64, 7, 7, 7];
        let h = HotVocab::from_counts(&counts, 2);
        assert_eq!(h.ids(), &[0, 1]);
    }

    #[test]
    fn synthetic_trace_hot_set_covers_zipf_head() {
        let vocab = 2000;
        let h = HotVocab::from_synthetic_trace(vocab, 200, 1.2, 50_000, 42);
        assert_eq!(h.len(), 200);
        // The hot set should capture most of the distribution's mass:
        // re-draw from the same distribution and measure the hit rate.
        let zipf = ZipfMandelbrot::zipf(vocab, 1.2);
        let mut rng = Philox::new(42);
        let mut rank_to_id: Vec<u32> = (0..vocab as u32).collect();
        rng.shuffle(&mut rank_to_id);
        let mut hits = 0;
        let n = 20_000;
        for _ in 0..n {
            let id = rank_to_id[zipf.sample(&mut rng)];
            if h.contains(id) {
                hits += 1;
            }
        }
        let rate = hits as f64 / n as f64;
        assert!(rate > 0.75, "hot hit rate {rate}");
    }

    #[test]
    fn membership_spans_word_boundaries() {
        let h = HotVocab::new(vec![63, 64, 127, 128], 200);
        assert!(h.contains(63) && h.contains(64) && h.contains(127) && h.contains(128));
        assert!(!h.contains(62) && !h.contains(65) && !h.contains(199));
    }

    #[test]
    fn resize_shares_ranking_and_nests() {
        let counts = vec![9u64, 1, 8, 7, 2, 6, 3, 5, 4, 0];
        let big = HotVocab::from_counts(&counts, 6);
        let small = big.resize(3);
        assert_eq!(small.ranking(), big.ranking());
        // nested prefix: every small member is a big member
        for &id in small.ids() {
            assert!(big.contains(id));
        }
        assert_eq!(small.len(), 3);
        // rank_index maps rank order onto the ascending id list
        for r in 0..small.len() {
            assert_eq!(small.ids()[small.rank_index(r)], small.ranking()[r]);
        }
        let grown = small.resize(8);
        assert_eq!(grown.len(), 8);
        assert_eq!(grown.ranking(), big.ranking());
    }

    #[test]
    fn new_synthesizes_id_order_ranking() {
        let h = HotVocab::new(vec![4, 2, 7], 9);
        // hot ids ascending first, then the tail ascending
        assert_eq!(h.ranking(), &[2, 4, 7, 0, 1, 3, 5, 6, 8]);
        for r in 0..h.len() {
            assert_eq!(h.ids()[h.rank_index(r)], h.ranking()[r]);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_out_of_vocab_ids() {
        HotVocab::new(vec![10], 10);
    }

    #[test]
    #[should_panic]
    fn rejects_full_vocab_hot_set() {
        HotVocab::new((0..10).collect(), 10);
    }
}
