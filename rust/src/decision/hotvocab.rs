//! Hot-vocabulary construction (§5.3).
//!
//! The hot set `H ⊂ V` is model-dependent and built offline from traces:
//! rank tokens by observed frequency and keep the top H. Membership tests
//! are O(1) via a bitset; the sorted id list drives the O(H) hot-path scan.

use crate::rng::zipf::ZipfMandelbrot;
use crate::rng::Philox;
use std::sync::Arc;

/// An immutable hot set, shared across samplers.
#[derive(Debug, Clone)]
pub struct HotVocab {
    /// Hot token ids, ascending.
    ids: Vec<u32>,
    /// Bitset over the vocabulary: bit v set ⇔ v ∈ H.
    mask: Vec<u64>,
    vocab: usize,
}

impl HotVocab {
    /// Build from an explicit id list.
    pub fn new(mut ids: Vec<u32>, vocab: usize) -> Self {
        ids.sort_unstable();
        ids.dedup();
        assert!(
            ids.last().is_none_or(|&v| (v as usize) < vocab),
            "hot id out of vocab"
        );
        assert!(ids.len() < vocab, "hot set must be a strict subset");
        let mut mask = vec![0u64; vocab.div_ceil(64)];
        for &v in &ids {
            mask[(v / 64) as usize] |= 1u64 << (v % 64);
        }
        HotVocab { ids, mask, vocab }
    }

    /// Build from trace token counts: the `h` most frequent ids (ties by id).
    pub fn from_counts(counts: &[u64], h: usize) -> Self {
        let vocab = counts.len();
        let h = h.min(vocab.saturating_sub(1)).max(1);
        let mut idx: Vec<u32> = (0..vocab as u32).collect();
        idx.select_nth_unstable_by(h - 1, |&a, &b| {
            counts[b as usize]
                .cmp(&counts[a as usize])
                .then(a.cmp(&b))
        });
        idx.truncate(h);
        Self::new(idx, vocab)
    }

    /// Synthetic trace: draw `samples` tokens from a Zipf-shaped unigram
    /// distribution over `vocab` (rank == id under `perm_seed`-driven
    /// shuffling of ranks), then keep the top `h`. Models the paper's
    /// offline trace profiling.
    pub fn from_synthetic_trace(
        vocab: usize,
        h: usize,
        zipf_s: f64,
        samples: usize,
        seed: u64,
    ) -> Self {
        let zipf = ZipfMandelbrot::zipf(vocab, zipf_s);
        let mut rng = Philox::new(seed);
        // rank -> id permutation (so hot ids are NOT simply 0..h)
        let mut rank_to_id: Vec<u32> = (0..vocab as u32).collect();
        rng.shuffle(&mut rank_to_id);
        let mut counts = vec![0u64; vocab];
        for _ in 0..samples {
            let r = zipf.sample(&mut rng);
            counts[rank_to_id[r] as usize] += 1;
        }
        Self::from_counts(&counts, h)
    }

    #[inline]
    pub fn contains(&self, v: u32) -> bool {
        let v = v as usize;
        debug_assert!(v < self.vocab);
        (self.mask[v / 64] >> (v % 64)) & 1 == 1
    }

    /// Sorted hot ids.
    pub fn ids(&self) -> &[u32] {
        &self.ids
    }
    pub fn len(&self) -> usize {
        self.ids.len()
    }
    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }
    pub fn vocab(&self) -> usize {
        self.vocab
    }
    pub fn tail_len(&self) -> usize {
        self.vocab - self.ids.len()
    }

    pub fn into_arc(self) -> Arc<HotVocab> {
        Arc::new(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn membership_and_sizes() {
        let h = HotVocab::new(vec![5, 1, 3, 3], 10);
        assert_eq!(h.ids(), &[1, 3, 5]);
        assert_eq!(h.len(), 3);
        assert_eq!(h.tail_len(), 7);
        for v in 0..10u32 {
            assert_eq!(h.contains(v), [1, 3, 5].contains(&v), "v={v}");
        }
    }

    #[test]
    fn from_counts_takes_most_frequent() {
        let counts = vec![5u64, 100, 2, 50, 50, 0];
        let h = HotVocab::from_counts(&counts, 3);
        // top-3 by count: 1(100), 3(50), 4(50)
        assert_eq!(h.ids(), &[1, 3, 4]);
    }

    #[test]
    fn from_counts_tie_break_by_id() {
        let counts = vec![7u64, 7, 7, 7];
        let h = HotVocab::from_counts(&counts, 2);
        assert_eq!(h.ids(), &[0, 1]);
    }

    #[test]
    fn synthetic_trace_hot_set_covers_zipf_head() {
        let vocab = 2000;
        let h = HotVocab::from_synthetic_trace(vocab, 200, 1.2, 50_000, 42);
        assert_eq!(h.len(), 200);
        // The hot set should capture most of the distribution's mass:
        // re-draw from the same distribution and measure the hit rate.
        let zipf = ZipfMandelbrot::zipf(vocab, 1.2);
        let mut rng = Philox::new(42);
        let mut rank_to_id: Vec<u32> = (0..vocab as u32).collect();
        rng.shuffle(&mut rank_to_id);
        let mut hits = 0;
        let n = 20_000;
        for _ in 0..n {
            let id = rank_to_id[zipf.sample(&mut rng)];
            if h.contains(id) {
                hits += 1;
            }
        }
        let rate = hits as f64 / n as f64;
        assert!(rate > 0.75, "hot hit rate {rate}");
    }

    #[test]
    fn bitset_spans_word_boundaries() {
        let h = HotVocab::new(vec![63, 64, 127, 128], 200);
        assert!(h.contains(63) && h.contains(64) && h.contains(127) && h.contains(128));
        assert!(!h.contains(62) && !h.contains(65) && !h.contains(199));
    }

    #[test]
    #[should_panic]
    fn rejects_out_of_vocab_ids() {
        HotVocab::new(vec![10], 10);
    }

    #[test]
    #[should_panic]
    fn rejects_full_vocab_hot_set() {
        HotVocab::new((0..10).collect(), 10);
    }
}
