//! Draft proposer for speculative decoding in the decision plane.
//!
//! The paper's §9 future-work item: the sampler's accept/reject machinery
//! (built for SHVS) verifies *multiple* proposed tokens per iteration. This
//! module supplies the proposals. There is no draft model in this offline
//! environment, so the proposer is a deterministic **self-drafting n-gram
//! stub** (prompt-lookup decoding): it finds the most recent earlier
//! occurrence of the sequence's trailing n-gram and proposes the tokens
//! that followed it, falling back to a Philox-keyed pseudo-draft when no
//! match exists.
//!
//! Two properties matter more than draft quality:
//!
//! 1. **Determinism.** A proposal is a pure function of
//!    `(request seed, prompt, output, k)` — independent of the sampler
//!    count `m`, batch composition, slot assignment, and preemption — so
//!    every component (engine, churn tests, property tests) recomputes the
//!    identical draft and verified token streams stay bit-identical to
//!    non-speculative decode.
//! 2. **Exactness is the verifier's job.** A bad draft only lowers the
//!    acceptance rate; [`super::verify`] guarantees the committed tokens
//!    follow the exact target distribution regardless.

use crate::rng::Philox;

/// Deterministic self-drafting n-gram proposer (prompt-lookup decoding).
#[derive(Debug, Clone)]
pub struct DraftProposer {
    /// Trailing n-gram length to match (2 = bigram lookup).
    pub ngram: usize,
    /// How far back the newest-first match scan looks. Bounds the per-call
    /// cost at O(lookback + k) — without it a match-free context costs
    /// O(len) per proposal, O(L²) per generation, in the engine's serial
    /// section between plan and forward. Recent context also drafts better.
    pub lookback: usize,
}

impl Default for DraftProposer {
    fn default() -> Self {
        DraftProposer { ngram: 2, lookback: 128 }
    }
}

impl DraftProposer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Clamp a configured window size for a sequence about to decode: the
    /// bonus token is the last that can commit (never draft past
    /// `max_new_tokens − 1` remaining), and the chain feeds positions
    /// `position+1 ..= position+k`, which must stay inside the static KV
    /// shape with room for the next feed. One definition shared by the
    /// engine and the offline churn harness so the two cannot drift.
    pub fn clamp_window(
        spec_k: usize,
        max_new_tokens: usize,
        output_len: usize,
        max_seq_len: usize,
        position: usize,
    ) -> usize {
        let remaining = max_new_tokens.saturating_sub(output_len);
        spec_k
            .min(remaining.saturating_sub(1))
            .min(max_seq_len.saturating_sub(position + 2))
    }

    /// Propose up to `k` draft tokens to follow `prompt ⧺ output`.
    ///
    /// `seed` is the request seed (the same one keying the decision
    /// uniforms); `vocab` bounds the fallback pseudo-tokens. Returns exactly
    /// `k` tokens (the window the verifier checks).
    pub fn propose(
        &self,
        seed: u64,
        vocab: usize,
        prompt: &[u32],
        output: &[u32],
        k: usize,
    ) -> Vec<u32> {
        let mut draft = Vec::with_capacity(k);
        if k == 0 {
            return draft;
        }
        let len = prompt.len() + output.len();
        let tok = |i: usize| -> u32 {
            if i < prompt.len() {
                prompt[i]
            } else {
                output[i - prompt.len()]
            }
        };

        // --- n-gram lookup: latest earlier match of the trailing n-gram.
        let n = self.ngram.max(1);
        if len > n {
            let is_match = |end: usize| (0..n).all(|j| tok(end - j) == tok(len - 1 - j));
            // `end` is the last index of a candidate match, strictly before
            // the trailing n-gram itself; scan newest-first, bounded by the
            // lookback window.
            let mut src = None;
            for end in (n - 1..len - 1).rev().take(self.lookback.max(1)) {
                if is_match(end) {
                    src = Some(end + 1);
                    break;
                }
            }
            if let Some(start) = src {
                for i in start..(start + k).min(len) {
                    draft.push(tok(i));
                }
            }
        }

        // --- fallback: Philox-keyed pseudo-draft for the remaining slots,
        // keyed by (seed, previous token, absolute position) so it is
        // stable under replay and independent of the batch.
        while draft.len() < k {
            let pos = (len + draft.len()) as u64;
            let prev = draft
                .last()
                .copied()
                .unwrap_or_else(|| if len > 0 { tok(len - 1) } else { 0 });
            let mut rng = Philox::at(
                seed ^ 0xD12A_F7ED,
                ((prev as u128) << 64) | (pos as u128),
            );
            draft.push(rng.next_below(vocab as u64) as u32);
        }
        draft
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clamp_window_respects_budget_and_ceiling() {
        // plenty of room: the configured k survives
        assert_eq!(DraftProposer::clamp_window(4, 100, 0, 1024, 10), 4);
        // one token left to generate: no point drafting (bonus covers it)
        assert_eq!(DraftProposer::clamp_window(4, 10, 9, 1024, 10), 0);
        // two left: one draft + bonus
        assert_eq!(DraftProposer::clamp_window(4, 10, 8, 1024, 10), 1);
        // KV ceiling: chain positions p+1..=p+k must stay < max_seq - 1
        assert_eq!(DraftProposer::clamp_window(8, 100, 0, 16, 12), 2);
        assert_eq!(DraftProposer::clamp_window(8, 100, 0, 16, 15), 0);
    }

    #[test]
    fn proposes_exactly_k_tokens_in_vocab() {
        let p = DraftProposer::new();
        for k in [0usize, 1, 3, 8] {
            let d = p.propose(7, 100, &[1, 2, 3], &[4, 5], k);
            assert_eq!(d.len(), k);
            assert!(d.iter().all(|&t| (t as usize) < 100));
        }
    }

    #[test]
    fn ngram_lookup_copies_the_continuation() {
        // context: 1 2 3 9 9 1 2 — trailing bigram (1,2) matched at the
        // front, so the draft copies what followed it: 3 9 9 ...
        let p = DraftProposer::new();
        let d = p.propose(0, 50, &[1, 2, 3, 9, 9], &[1, 2], 3);
        assert_eq!(d, vec![3, 9, 9]);
    }

    #[test]
    fn latest_match_wins() {
        // (1,2) occurs twice; the most recent earlier occurrence (followed
        // by 8) must be chosen, mirroring prompt-lookup decoding.
        let p = DraftProposer::new();
        let d = p.propose(0, 50, &[1, 2, 7, 1, 2, 8], &[1, 2], 1);
        assert_eq!(d, vec![8]);
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let p = DraftProposer::new();
        // no n-gram match -> pure fallback path
        let a = p.propose(3, 1000, &[5, 6, 7], &[], 4);
        let b = p.propose(3, 1000, &[5, 6, 7], &[], 4);
        assert_eq!(a, b);
        let c = p.propose(4, 1000, &[5, 6, 7], &[], 4);
        assert_ne!(a, c, "fallback drafts must vary with the request seed");
    }

    #[test]
    fn split_invariant_across_prompt_output_boundary() {
        // The proposer sees prompt ⧺ output as one context: moving the
        // boundary must not change the proposal (preemption replay moves
        // tokens between the two).
        let p = DraftProposer::new();
        let a = p.propose(9, 64, &[1, 2, 3, 1], &[2, 3, 1, 2], 3);
        let b = p.propose(9, 64, &[1, 2], &[3, 1, 2, 3, 1, 2], 3);
        assert_eq!(a, b);
    }

    #[test]
    fn continuation_stops_at_context_end_then_falls_back() {
        // match near the end: fewer than k copied tokens, rest from fallback
        let p = DraftProposer::new();
        let d = p.propose(11, 32, &[4, 4, 9], &[4, 4], 4);
        assert_eq!(d.len(), 4);
        assert_eq!(d[0], 9, "copied continuation comes first");
    }
}
