//! Sampling parameters — the full production control set the paper assumes
//! enabled (§7.1): temperature, top-k, nucleus top-p, min-p, and the
//! repetition/presence/frequency penalties, plus optional logit bias.

use std::collections::BTreeMap;

/// Per-request sampling controls (OpenAI-API-compatible semantics).
#[derive(Debug, Clone, PartialEq)]
pub struct SamplingParams {
    /// Softmax temperature τ > 0 (0 is treated as greedy argmax).
    pub temperature: f32,
    /// Keep the k most likely tokens (0 = disabled).
    pub top_k: usize,
    /// Nucleus: keep the smallest prefix with cumulative mass ≥ p (1.0 = off).
    pub top_p: f32,
    /// Drop tokens with p < min_p · p_max (0.0 = off).
    pub min_p: f32,
    /// Multiplicative repetition penalty λ_rep ≥ 1 (1.0 = off); divides the
    /// logit of seen tokens when positive, multiplies when negative (HF/vLLM
    /// convention).
    pub repetition_penalty: f32,
    /// Additive presence penalty (subtracted once if the token appeared).
    pub presence_penalty: f32,
    /// Additive frequency penalty (subtracted × occurrence count).
    pub frequency_penalty: f32,
    /// Explicit per-token logit bias.
    pub logit_bias: BTreeMap<u32, f32>,
    /// Restrict sampling to this allow-list (constrained decoding), if set.
    pub allowed_tokens: Option<Vec<u32>>,
    /// Request RNG seed (combined with the engine seed + sequence id).
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            temperature: 1.0,
            top_k: 0,
            top_p: 1.0,
            min_p: 0.0,
            repetition_penalty: 1.0,
            presence_penalty: 0.0,
            frequency_penalty: 0.0,
            logit_bias: BTreeMap::new(),
            allowed_tokens: None,
            seed: 0,
        }
    }
}

impl SamplingParams {
    /// The paper's evaluation setting (§7.1): all production knobs on.
    pub fn production_default() -> Self {
        SamplingParams {
            temperature: 0.8,
            top_k: 50,
            top_p: 0.95,
            min_p: 0.02,
            repetition_penalty: 1.1,
            presence_penalty: 0.1,
            frequency_penalty: 0.1,
            ..Default::default()
        }
    }

    /// Greedy decoding (argmax).
    pub fn greedy() -> Self {
        SamplingParams { temperature: 0.0, ..Default::default() }
    }

    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0
    }

    /// Whether any history-dependent penalty is enabled.
    pub fn has_penalties(&self) -> bool {
        self.repetition_penalty != 1.0
            || self.presence_penalty != 0.0
            || self.frequency_penalty != 0.0
    }

    /// Whether any candidate filtering is enabled.
    pub fn has_filter(&self) -> bool {
        self.top_k > 0 || self.top_p < 1.0 || self.min_p > 0.0 || self.allowed_tokens.is_some()
    }

    /// Validate ranges; returns a description of the first problem.
    pub fn validate(&self, vocab: usize) -> Result<(), String> {
        if self.temperature < 0.0 || !self.temperature.is_finite() {
            return Err(format!("temperature {} out of range", self.temperature));
        }
        if !(0.0..=1.0).contains(&self.top_p) {
            return Err(format!("top_p {} out of range", self.top_p));
        }
        if !(0.0..=1.0).contains(&self.min_p) {
            return Err(format!("min_p {} out of range", self.min_p));
        }
        if self.repetition_penalty <= 0.0 {
            return Err(format!(
                "repetition_penalty {} must be positive",
                self.repetition_penalty
            ));
        }
        if self.top_k > vocab {
            return Err(format!("top_k {} exceeds vocab {vocab}", self.top_k));
        }
        if let Some(allow) = &self.allowed_tokens {
            if allow.is_empty() {
                return Err("allowed_tokens is empty".into());
            }
            if let Some(&bad) = allow.iter().find(|&&t| t as usize >= vocab) {
                return Err(format!("allowed token {bad} exceeds vocab {vocab}"));
            }
        }
        for (&t, _) in &self.logit_bias {
            if t as usize >= vocab {
                return Err(format!("logit_bias token {t} exceeds vocab {vocab}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_neutral() {
        let p = SamplingParams::default();
        assert!(!p.has_penalties());
        assert!(!p.has_filter());
        assert!(!p.is_greedy());
        assert!(p.validate(100).is_ok());
    }

    #[test]
    fn production_default_enables_everything() {
        let p = SamplingParams::production_default();
        assert!(p.has_penalties());
        assert!(p.has_filter());
        assert!(p.validate(152_064).is_ok());
    }

    #[test]
    fn greedy_detected() {
        assert!(SamplingParams::greedy().is_greedy());
    }

    #[test]
    fn validation_catches_bad_values() {
        let vocab = 100;
        let mut p = SamplingParams { temperature: -1.0, ..Default::default() };
        assert!(p.validate(vocab).is_err());
        p = SamplingParams { top_p: 1.5, ..Default::default() };
        assert!(p.validate(vocab).is_err());
        p = SamplingParams { top_k: 101, ..Default::default() };
        assert!(p.validate(vocab).is_err());
        p = SamplingParams { repetition_penalty: 0.0, ..Default::default() };
        assert!(p.validate(vocab).is_err());
        p = SamplingParams { allowed_tokens: Some(vec![]), ..Default::default() };
        assert!(p.validate(vocab).is_err());
        p = SamplingParams { allowed_tokens: Some(vec![100]), ..Default::default() };
        assert!(p.validate(vocab).is_err());
        let mut bias = BTreeMap::new();
        bias.insert(200u32, 1.0f32);
        p = SamplingParams { logit_bias: bias, ..Default::default() };
        assert!(p.validate(vocab).is_err());
    }
}
