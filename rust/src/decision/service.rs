//! The disaggregated decision-plane service (§4.2, §5.1) — lock-free
//! shared-pool edition (DESIGN.md §11).
//!
//! `m` sampler workers run on dedicated threads. Each iteration, a
//! submitter publishes one [`IterationTask`] into the in-flight slot table
//! and pushes one *shard message* per worker onto that worker's MPMC ring
//! ([`crate::ringbuf::mpmc::Ring`]): shard `v` covers the columns of the
//! sequences owned by sampler `v` (`seq_id % m`). Workers decide their
//! shard's columns independently — **sequence-parallel**, no
//! vocabulary-axis reconciliation — and write their [`DecisionBatch`] into
//! the task's per-shard cell; a collect assembles the cells once all `m`
//! reported. There is **no mutex anywhere on the submit, decide, or
//! collect hot path**: several engine replicas sharing one pool submit and
//! collect concurrently through CAS-only rings, claims, and slot states.
//!
//! **Work stealing.** An idle worker pops a backlogged sibling's ring and
//! decides that shard in its place. Safe because decisions are keyed by
//! (sampler seed, request seed, sequence, iteration) — never by worker
//! identity — and per-sequence state is rebuilt on demand from the
//! sequence's lock-free [`SeqRec`] replay log; the per-cell claim CAS
//! guarantees exactly one decider per shard per task no matter who pops
//! the message.
//!
//! **Ownership.** A sequence's *shard* is `seq_id % m` for its whole life,
//! so its columns always travel in the same cell and the same ring —
//! stealing moves the compute, never the keying. Ownership-by-id replaces
//! the paper's per-iteration contiguous ranges — the balance is the same
//! in expectation.
//!
//! **Determinism.** Decisions use pre-generated Philox uniforms keyed by
//! (engine seed, request seed, sequence, iteration), so the token stream
//! is identical for any `m`, any replica count, any steal schedule, and
//! any fault plan (asserted in tests).
//!
//! **Crash recovery (DESIGN.md §10, §11).** A dead worker is detected by a
//! lock-free death flag (set by a drop guard during unwind), joined, and
//! respawned on the *same* ring — rings and per-sequence records survive
//! the worker, so recovery releases the dead incarnation's cell claims
//! with single CASes, re-pushes the unanswered shard messages, and starts
//! a fresh thread; the respawn replays nothing eagerly because workers
//! rebuild sequence state lazily from the [`SeqRec`] log. A worker that
//! dies repeatedly without the pool completing a collect trips a
//! crash-loop breaker and the failure surfaces as an error.

use super::grammar::GrammarConstraint;
use super::hotvocab::HotVocab;
use super::params::SamplingParams;
use super::penalties::BatchHistory;
use super::pipeline::DecisionPipeline;
use super::seqrec::{SeqHandle, SeqRec};
use super::shvs::Precompute;
use super::slots::{claim_pack, TakenTask, TaskSlots};
use super::verify::{self, Verdict};
#[cfg(test)]
use crate::config::DecisionVariant;
use crate::config::SamplerConfig;
use crate::ringbuf::mpmc;
use crate::tensor::ShardedLogits;
use crate::trace;
use crate::util::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use crate::util::sync::Mutex;
use std::collections::HashMap;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Bit position of the task-id namespace: a shared pool's submitters put
/// their replica id in the bits at and above this shift (`(id+1) << 48`),
/// leaving the low bits for the per-engine plan counter.
pub const TASK_NS_SHIFT: u32 = 48;
/// Mask selecting the namespace bits of a task id.
pub const TASK_NS_MASK: u64 = !((1u64 << TASK_NS_SHIFT) - 1);

/// Consecutive respawns of the same worker (without any collect completing
/// a cell it claimed in between) before recovery gives up and surfaces the
/// panic — the crash-loop breaker for deterministically-poisonous tasks.
const MAX_CONSECUTIVE_RESPAWNS: u32 = 3;

/// Per-column metadata within an iteration's microbatch.
#[derive(Debug, Clone)]
pub struct ColumnMeta {
    pub col: usize,
    pub seq_id: u64,
    /// Decode iteration of the *base* chain position for this sequence
    /// (speculative positions key their uniforms at `iteration + j`). This
    /// equals the sequence's committed-output length at submit time — the
    /// replay prefix a rebuilding worker truncates its [`SeqRec`] to.
    pub iteration: u64,
}

/// One iteration's work for the decision plane. Shared (Arc'd) pieces are
/// written once by the engine and read zero-copy by every sampler.
///
/// Speculative decoding ships the whole draft chain in one task:
/// `views[0]` is the base decode step's logits; `views[j > 0]` were
/// produced by feeding draft token `j-1`, and `drafts[ci]` carries column
/// `ci`'s proposed window. The batch-axis sharding is untouched — each
/// sampler still reads only its shard's columns, in every view, with no
/// vocab-axis collectives.
pub struct IterationTask {
    /// Task id — the scheduler's global plan counter. Unique across
    /// microbatches (and, in a shared pool, namespaced per replica); the
    /// slot table is keyed by it.
    pub iter: u64,
    /// Microbatch this task belongs to (0 for the synchronous engine).
    pub mb: usize,
    /// Per-chain-position logits views (len 1 = plain decode).
    pub views: Vec<ShardedLogits>,
    pub columns: Arc<Vec<ColumnMeta>>,
    /// Per-column sequence records, aligned with `columns`. `None` (or a
    /// retired record) = decide nothing for that column — the task-in-
    /// flight-across-retire contract. Carrying the record *in the task*
    /// is the Arc-identity staleness guard: a retire + re-register mints a
    /// new record, so a stale task can only touch its orphaned old one.
    pub recs: Arc<Vec<Option<SeqHandle>>>,
    /// Per-view, per-column SHVS precompute: `pre[j][col]` (empty when the
    /// variant doesn't use it).
    pub pre: Arc<Vec<Vec<Precompute>>>,
    /// Draft windows aligned with `columns` (an empty window = plain
    /// decision; an empty outer vec = no speculation this iteration).
    pub drafts: Arc<Vec<Vec<u32>>>,
}

impl IterationTask {
    /// A plain non-speculative iteration: one view, no drafts. `pre` is the
    /// per-column SHVS precompute for that view (may be empty).
    pub fn single(
        iter: u64,
        view: ShardedLogits,
        columns: Vec<ColumnMeta>,
        recs: Vec<Option<SeqHandle>>,
        pre: Vec<Precompute>,
    ) -> IterationTask {
        let pre = if pre.is_empty() { Vec::new() } else { vec![pre] };
        IterationTask {
            iter,
            mb: 0,
            views: vec![view],
            columns: Arc::new(columns),
            recs: Arc::new(recs),
            pre: Arc::new(pre),
            drafts: Arc::new(Vec::new()),
        }
    }
}

/// One shard's unit of work: decide task `task`'s columns whose sequences
/// hash to `shard`, and write the result into `slot`'s cell `shard`. The
/// whole submit/steal/recovery protocol moves only this message.
pub struct ShardMsg {
    pub task: Arc<IterationTask>,
    pub slot: usize,
    pub shard: usize,
}

/// One shard's decisions for one iteration.
#[derive(Debug)]
pub struct DecisionBatch {
    pub iter: u64,
    /// Microbatch tag copied from the task (stage-timeline attribution).
    pub mb: usize,
    /// The worker thread that actually decided this shard (the owner, a
    /// stealer, or a respawned incarnation) — stats/breaker attribution;
    /// never part of the decision keying.
    pub sampler_id: usize,
    /// (column, seq_id, verdict) — a verdict commits 1..=k+1 tokens
    /// (accepted draft prefix + corrected bonus; exactly 1 without
    /// speculation).
    pub decisions: Vec<(usize, u64, Verdict)>,
    /// Wall seconds this sampler spent deciding (busy time).
    pub busy_s: f64,
    /// Busy interval endpoints, seconds since the service epoch (the
    /// engine's t0) — the stage timeline's raw material.
    pub start_s: f64,
    pub end_s: f64,
}

/// All `m` shards' decisions for one task, assembled from the slot cells.
#[derive(Debug, Default)]
pub struct Collected {
    /// Microbatch the task belonged to (as tagged by the submitter).
    pub mb: usize,
    /// Column-sorted (column, seq_id, verdict) triples.
    pub decisions: Vec<(usize, u64, Verdict)>,
    /// Max per-shard busy seconds — the decision-plane latency that must
    /// hide under GPU compute.
    pub busy_s: f64,
    /// Per-shard busy intervals (epoch seconds), for overlap accounting.
    pub intervals: Vec<(f64, f64)>,
}

/// Lifetime fault-recovery statistics of a service.
#[derive(Debug, Clone, Copy, Default)]
pub struct RecoveryStats {
    /// Sampler workers respawned after a crash.
    pub respawns: u64,
    /// Wall seconds spent respawning + resubmitting (the recovery pauses a
    /// fault-free run would not have paid).
    pub recovery_s: f64,
}

/// Per-sampler lifetime statistics. (Speculative-decoding acceptance is
/// tallied engine-side from *committed* windows — see
/// `PjrtEngine::spec_accepted` — not here, where discarded-after-preemption
/// verdicts would skew the counts.)
#[derive(Debug, Clone, Default)]
pub struct SamplerStats {
    pub decisions: u64,
    pub fast_path_hits: u64,
    pub alpha_sum: f64,
    pub busy_s: f64,
}

/// Running service handle. Submit/decide/collect touch only the lock-free
/// rings, records, and slot table; the two mutexes below guard *cold*
/// paths exclusively (respawn bookkeeping and recovery stats), proven by
/// `submit_collect_hot_path_holds_no_service_lock` below.
pub struct SamplerService {
    /// Per-worker task rings. Immutable for the life of the service: a
    /// respawned worker pops the *same* ring its predecessor did, so no
    /// message is ever stranded by a death and no lock guards the set.
    rings: Arc<Vec<mpmc::Ring<ShardMsg>>>,
    /// In-flight task table (slots, cells, claims — see `slots`).
    slots: Arc<TaskSlots>,
    /// Set by a worker's drop guard the moment its thread unwinds or
    /// returns — the lock-free death signal every collect polls.
    dead_flags: Arc<Vec<AtomicBool>>,
    /// Chaos injection: worker `id` panics at the top of its next loop
    /// turn when its flag is set (replaces the old in-band Crash message,
    /// which a stealer could have accidentally absorbed).
    crash_flags: Arc<Vec<AtomicBool>>,
    /// Current thread incarnation per worker; claims pack it so recovery
    /// can release a dead incarnation's claims without racing live ones.
    incarnations: Vec<AtomicU32>,
    /// Consecutive respawns per worker since a collect last completed a
    /// cell that worker claimed — the per-worker crash-loop breaker.
    respawns: Vec<AtomicU32>,
    /// Respawns since *any* collect completed — the pool-wide breaker
    /// (stealing can spread a poisonous task's kills across workers, so
    /// per-worker counters alone could loop forever).
    stuck_respawns: AtomicU32,
    /// Cold: worker join handles (taken by recovery joins and shutdown).
    workers: Mutex<Vec<Option<JoinHandle<SamplerStats>>>>,
    /// Cold: lifetime recovery stats.
    recovery_log: Mutex<RecoveryStats>,
    /// Spawn ingredients for respawns.
    cfg: SamplerConfig,
    hot: Option<Arc<HotVocab>>,
    max_seq_len: usize,
    m: usize,
    /// Shared time origin the workers timestamp against (the engine's t0;
    /// a cluster's replicas all adopt it so fleet stage timelines merge).
    epoch: Instant,
}

/// Sets the worker's death flag on *any* thread exit — panic unwind or
/// clean return — giving collects a lock-free corpse signal.
struct DeathGuard {
    flags: Arc<Vec<AtomicBool>>,
    id: usize,
}

impl Drop for DeathGuard {
    fn drop(&mut self) {
        self.flags[self.id].store(true, Ordering::Release);
    }
}

/// Cached per-sequence decide state. Valid only while `rec` is the same
/// registration incarnation (Arc identity) *and* `decided` equals the
/// incoming task's `iteration` — any mismatch (steal hand-back, respawn,
/// engine cut, re-register) rebuilds from the record's replay log.
struct CachedSeq {
    rec: SeqHandle,
    hist: BatchHistory,
    grammar: Option<(Arc<GrammarConstraint>, super::grammar::ConstraintState)>,
    decided: u64,
}

/// A sampler's worker loop state.
struct SamplerWorker {
    id: usize,
    m: usize,
    /// This thread's incarnation (packed into every claim it takes).
    incarnation: u32,
    pipeline: DecisionPipeline,
    epoch: Instant,
    rings: Arc<Vec<mpmc::Ring<ShardMsg>>>,
    slots: Arc<TaskSlots>,
    crash_flags: Arc<Vec<AtomicBool>>,
    /// Sequence-state cache, keyed by seq_id (see [`CachedSeq`]). Grows
    /// with stolen shards; retired entries are swept periodically.
    owned: HashMap<u64, CachedSeq>,
    max_seq_len: usize,
    processed: u64,
}

/// Steal only from siblings with a backlog at least this deep — below it,
/// the owner is already on the message and stealing would just burn a
/// claim bounce.
const STEAL_BACKLOG: usize = 2;
/// After this many empty polls, steal even a single queued message — the
/// owner is probably dead or wedged (this is what lets survivors absorb a
/// corpse's shard before recovery even runs).
const STEAL_DESPERATION: u32 = 4096;

impl SamplerWorker {
    fn run(mut self) -> SamplerStats {
        // Sampler workers live on the pool lane (pid 0) — a shared pool's
        // threads serve every replica, so they are not any replica's.
        trace::register_thread(0, trace::tid_sampler(self.id));
        let mut stats = SamplerStats::default();
        let mut idle = 0u32;
        loop {
            if self.crash_flags[self.id].swap(false, Ordering::AcqRel) {
                panic!("chaos: injected sampler crash (worker {})", self.id);
            }
            match self.rings[self.id].try_pop() {
                Ok(msg) => {
                    idle = 0;
                    self.process(msg, &mut stats);
                    continue;
                }
                Err(mpmc::PopError::Closed) => break,
                Err(mpmc::PopError::Empty) => {}
            }
            let threshold = if idle > STEAL_DESPERATION { 1 } else { STEAL_BACKLOG };
            let mut stole = false;
            for off in 1..self.m {
                let v = (self.id + off) % self.m;
                if self.rings[v].len() >= threshold {
                    if let Ok(msg) = self.rings[v].try_pop() {
                        idle = 0;
                        stole = true;
                        trace::metrics::inc(&trace::metrics::counters().steals);
                        trace::instant(trace::Kind::SvcSteal, self.id as u64, v as u64);
                        self.process(msg, &mut stats);
                        break;
                    }
                }
            }
            if !stole {
                idle = idle.saturating_add(1);
                if idle < 64 {
                    std::hint::spin_loop();
                } else {
                    std::thread::yield_now();
                }
            }
        }
        stats.decisions = self.pipeline.decisions;
        stats.fast_path_hits = self.pipeline.fast_path_hits;
        stats.alpha_sum = self.pipeline.alpha_sum;
        stats
    }

    /// Claim → decide → publish for one shard message. Pins bracket only
    /// the atomic claim and the cell write; the decision itself runs
    /// unpinned so a panic inside it can never wedge reclamation.
    fn process(&mut self, msg: ShardMsg, stats: &mut SamplerStats) {
        let ShardMsg { task, slot, shard } = msg;
        {
            let Some(_pin) = self.slots.pin(slot, task.iter) else {
                return; // task collected, purged, or slot already recycled
            };
            if !self.slots.try_claim(slot, shard, claim_pack(self.id, self.incarnation)) {
                return; // duplicate message — someone else owns this cell
            }
        }
        let batch = self.decide(&task, shard, stats);
        if let Some(_pin) = self.slots.pin(slot, task.iter) {
            self.slots.publish_cell(slot, shard, batch);
        }
        self.processed += 1;
        if self.processed % 256 == 0 {
            self.owned.retain(|_, c| !c.rec.is_retired());
        }
    }

    /// Decide shard `shard`'s columns of `task`. Works identically for the
    /// shard's owner, a stealer, and a respawned incarnation — state comes
    /// from the cache when fresh, else from a [`SeqRec`] replay.
    fn decide(
        &mut self,
        task: &IterationTask,
        shard: usize,
        stats: &mut SamplerStats,
    ) -> DecisionBatch {
        let start_s = self.epoch.elapsed().as_secs_f64();
        let mut decisions = Vec::new();
        for (ci, meta) in task.columns.iter().enumerate() {
            if (meta.seq_id as usize) % self.m != shard {
                continue;
            }
            let Some(rec) = task.recs.get(ci).and_then(|r| r.as_ref()) else {
                continue; // unregistered column decides nothing
            };
            if rec.is_retired() {
                continue; // retired mid-flight; engine resends if needed
            }
            let seq =
                Self::seq_state(&mut self.owned, rec, meta.iteration, self.max_seq_len);
            let draft: &[u32] = task.drafts.get(ci).map(Vec::as_slice).unwrap_or(&[]);
            // One code path for both modes: with an empty draft this is
            // exactly one grammar-masked decision plus the local metadata
            // append (§5.1); with a draft it is batched rejection
            // verification with roll-forward/rollback of the owned state.
            let verdict = verify::verify_window(
                &mut self.pipeline,
                &task.views,
                meta.col,
                draft,
                &mut seq.hist,
                &mut seq.grammar,
                &rec.params,
                &task.pre,
                meta.seq_id,
                meta.iteration,
            );
            // Log to the shared record so any later decider (respawn,
            // steal hand-back) can rebuild this prefix; positional +
            // deterministic = idempotent under recovery re-decides.
            rec.log_decided(meta.iteration, &verdict.tokens);
            seq.decided = meta.iteration + verdict.tokens.len() as u64;
            decisions.push((meta.col, meta.seq_id, verdict));
        }
        let end_s = self.epoch.elapsed().as_secs_f64();
        let busy = end_s - start_s;
        stats.busy_s += busy;
        trace::metrics::DECIDE_LATENCY.observe_ns((busy.max(0.0) * 1e9) as u64);
        // a = microbatch: the trace-derived OverlapReport replays these X
        // events through the same Recorder arithmetic the engine uses live.
        trace::complete_s(
            trace::Kind::SvcDecide,
            start_s,
            end_s,
            task.mb as u64,
            decisions.len() as u64,
        );
        DecisionBatch {
            iter: task.iter,
            mb: task.mb,
            sampler_id: self.id,
            decisions,
            busy_s: busy,
            start_s,
            end_s,
        }
    }

    /// Fetch the cached decide state for `rec`, rebuilding it from the
    /// record's replay log when the cache is stale (different registration
    /// incarnation, or decided length ≠ the task's iteration).
    fn seq_state<'a>(
        owned: &'a mut HashMap<u64, CachedSeq>,
        rec: &SeqHandle,
        iteration: u64,
        max_seq_len: usize,
    ) -> &'a mut CachedSeq {
        let fresh = owned
            .get(&rec.seq_id)
            .is_some_and(|c| Arc::ptr_eq(&c.rec, rec) && c.decided == iteration);
        if !fresh {
            let replay = rec.read_upto(iteration);
            let hist = BatchHistory::with_replay(rec.prompt.clone(), &replay, max_seq_len);
            let grammar = rec.replay_grammar(&replay);
            owned.insert(
                rec.seq_id,
                CachedSeq { rec: rec.clone(), hist, grammar, decided: iteration },
            );
        }
        owned.get_mut(&rec.seq_id).unwrap()
    }
}

/// Render a worker panic payload for error surfacing.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

impl SamplerService {
    /// Spawn `cfg.num_samplers` workers clocked against the shared trace
    /// epoch ([`crate::trace::epoch`]), so busy intervals, trace spans, and
    /// engine stage timestamps are directly comparable. `hot` is required
    /// for the SHVS variant.
    pub fn start(cfg: &SamplerConfig, hot: Option<Arc<HotVocab>>, max_seq_len: usize) -> Self {
        Self::start_with_epoch(cfg, hot, max_seq_len, trace::epoch())
    }

    /// Spawn workers that timestamp their busy intervals relative to
    /// `epoch` (the engine's t0), so decision intervals land on the same
    /// timeline as the engine's GPU stage intervals.
    pub fn start_with_epoch(
        cfg: &SamplerConfig,
        hot: Option<Arc<HotVocab>>,
        max_seq_len: usize,
        epoch: Instant,
    ) -> Self {
        let m = cfg.num_samplers.max(1);
        // Slot table sized off the ring-depth knob; rings get 2x slack so
        // recovery duplicates never wedge a resubmit.
        let slot_cap = (cfg.ring_depth.max(1) * 64).max(64);
        let svc = SamplerService {
            rings: Arc::new((0..m).map(|_| mpmc::Ring::new(slot_cap * 2)).collect()),
            slots: Arc::new(TaskSlots::new(slot_cap, m)),
            dead_flags: Arc::new((0..m).map(|_| AtomicBool::new(false)).collect()),
            crash_flags: Arc::new((0..m).map(|_| AtomicBool::new(false)).collect()),
            incarnations: (0..m).map(|_| AtomicU32::new(1)).collect(),
            respawns: (0..m).map(|_| AtomicU32::new(0)).collect(),
            stuck_respawns: AtomicU32::new(0),
            // cold: join-handle bookkeeping — touched by recovery/shutdown only
            workers: Mutex::new((0..m).map(|_| None).collect()),
            // cold: recovery stats — written on the respawn path only
            recovery_log: Mutex::new(RecoveryStats::default()),
            cfg: cfg.clone(),
            hot,
            max_seq_len,
            m,
            epoch,
        };
        {
            let mut workers = svc.workers.lock().unwrap();
            for (id, slot) in workers.iter_mut().enumerate() {
                *slot = Some(svc.spawn_worker(id));
            }
        }
        svc
    }

    fn spawn_worker(&self, id: usize) -> JoinHandle<SamplerStats> {
        let worker = SamplerWorker {
            id,
            m: self.m,
            incarnation: self.incarnations[id].load(Ordering::Acquire),
            pipeline: DecisionPipeline::new(self.cfg.variant, self.hot.clone(), self.cfg.seed),
            epoch: self.epoch,
            rings: self.rings.clone(),
            slots: self.slots.clone(),
            crash_flags: self.crash_flags.clone(),
            owned: HashMap::new(),
            max_seq_len: self.max_seq_len,
            processed: 0,
        };
        let guard = DeathGuard { flags: self.dead_flags.clone(), id };
        std::thread::Builder::new()
            .name(format!("sampler-{id}"))
            .spawn(move || {
                let _guard = guard;
                worker.run()
            })
            .expect("spawn sampler")
    }

    pub fn num_samplers(&self) -> usize {
        self.m
    }

    /// The time origin workers timestamp busy intervals against. Engines
    /// sharing this service adopt it as their t0 so GPU and decision stage
    /// intervals live on one fleet-wide timeline.
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Register a new sequence: mint its replay record. The caller keeps
    /// the handle and passes it (cloned) in every task that carries the
    /// sequence's column — registration touches no service state at all.
    pub fn register(&self, seq_id: u64, prompt: &[u32], params: &SamplingParams) -> SeqHandle {
        self.register_full(seq_id, prompt, &[], params, None)
    }

    /// Register with an optional structured-decoding constraint.
    pub fn register_with_grammar(
        &self,
        seq_id: u64,
        prompt: &[u32],
        params: &SamplingParams,
        grammar: Option<Arc<GrammarConstraint>>,
    ) -> SeqHandle {
        self.register_full(seq_id, prompt, &[], params, grammar)
    }

    /// Register a (possibly resumed) sequence: `output` carries tokens
    /// generated before a preemption, replayed by whichever worker next
    /// decides for it. Always mints a **new** record — the Arc-identity
    /// incarnation guard that keeps stale in-flight verdicts away from the
    /// fresh registration.
    pub fn register_full(
        &self,
        seq_id: u64,
        prompt: &[u32],
        output: &[u32],
        params: &SamplingParams,
        grammar: Option<Arc<GrammarConstraint>>,
    ) -> SeqHandle {
        SeqRec::new(seq_id, prompt, output, params, grammar, self.max_seq_len)
    }

    /// Retire a finished sequence: flips the record's flag, so any task
    /// still in flight decides nothing for it.
    pub fn retire(&self, rec: &SeqHandle) {
        rec.retire();
    }

    /// Publish one iteration's logits + metadata to all shards. Shared
    /// pools rely on the caller namespacing `task.iter` (unique
    /// fleet-wide). Lock-free: one slot-table CAS walk plus `m` ring
    /// pushes; backpressure (full table / full ring) spins.
    pub fn submit(&self, task: IterationTask) {
        debug_assert_eq!(
            task.recs.len(),
            task.columns.len(),
            "task {}: recs must align with columns",
            task.iter
        );
        trace::instant(trace::Kind::SvcSubmit, task.iter, task.columns.len() as u64);
        let task = Arc::new(task);
        let slot = self.slots.publish(task.clone());
        for shard in 0..self.m {
            self.rings[shard].push(ShardMsg { task: task.clone(), slot, shard });
        }
    }

    /// Assemble a completed task's cells and reset the crash-loop
    /// breakers (a completed collect is the pool's forward progress).
    fn assemble(&self, taken: TakenTask) -> Collected {
        // ordering: Relaxed — the breakers are advisory counters compared
        // against a threshold under the workers mutex; a stale read only
        // delays a reset by one collect, never corrupts the protocol.
        self.stuck_respawns.store(0, Ordering::Relaxed);
        for &w in &taken.claimants {
            if let Some(r) = self.respawns.get(w) {
                // ordering: Relaxed — same advisory breaker-reset as above.
                r.store(0, Ordering::Relaxed);
            }
        }
        let mb = taken.task.mb;
        let mut decisions = Vec::new();
        let mut intervals = Vec::new();
        let mut max_busy = 0.0f64;
        for b in taken.batches {
            max_busy = max_busy.max(b.busy_s);
            if b.end_s > b.start_s {
                intervals.push((b.start_s, b.end_s));
            }
            decisions.extend(b.decisions);
        }
        decisions.sort_unstable_by_key(|&(col, _, _)| col);
        Collected { mb, decisions, busy_s: max_busy, intervals }
    }

    /// Lock-free liveness check: a handful of atomic loads while every
    /// worker is healthy; only an actual corpse takes the cold path.
    fn check_workers(&self) -> crate::Result<()> {
        if !self.dead_flags.iter().any(|f| f.load(Ordering::Acquire)) {
            return Ok(());
        }
        self.handle_dead_workers()
    }

    /// Cold path: join corpses, run the breakers, respawn on the same
    /// rings, release dead claims, resubmit unanswered cells. Serialized
    /// on the workers mutex; concurrent submits/collects proceed — rings
    /// and the slot table carry all the shared state.
    #[cold]
    fn handle_dead_workers(&self) -> crate::Result<()> {
        let t0 = Instant::now();
        let mut workers = self.workers.lock().unwrap();
        let mut dead: Vec<(usize, String)> = Vec::new();
        for id in 0..self.m {
            if !self.dead_flags[id].load(Ordering::Acquire) {
                continue;
            }
            let Some(handle) = workers[id].take() else { continue };
            let msg = match handle.join() {
                Err(payload) => {
                    format!("sampler {id} panicked: {}", panic_message(payload.as_ref()))
                }
                Ok(_) => format!("sampler {id} exited mid-service"),
            };
            dead.push((id, msg));
        }
        if dead.is_empty() {
            return Ok(()); // another collector already recovered this corpse
        }
        if !self.cfg.recovery {
            anyhow::bail!("{}", dead[0].1);
        }
        for (id, msg) in &dead {
            // ordering: Relaxed — incremented under the workers mutex (the
            // only writer path); the lock serializes breaker arithmetic.
            let n = self.respawns[*id].fetch_add(1, Ordering::Relaxed) + 1;
            if n > MAX_CONSECUTIVE_RESPAWNS {
                anyhow::bail!("sampler {id} crash-looping ({n} consecutive respawns): {msg}");
            }
            // ordering: Relaxed — mutex-serialized like the per-worker
            // counter; concurrent collect resets racing it are benign.
            let pool_wide = self.stuck_respawns.fetch_add(1, Ordering::Relaxed) + 1;
            if pool_wide > self.m as u32 * (MAX_CONSECUTIVE_RESPAWNS + 1) {
                anyhow::bail!(
                    "sampler pool crash-looping ({pool_wide} respawns without a completed \
                     collect; last: {msg})"
                );
            }
        }
        for (id, msg) in &dead {
            eprintln!("[sampler-service] {msg}; respawning worker {id}");
            // The dead thread's incarnation retires here; its claims are
            // released by exact CAS (a live claim can never match it).
            trace::metrics::inc(&trace::metrics::counters().sampler_respawns);
            trace::instant(trace::Kind::SvcRespawn, *id as u64, 0);
            let old_inc = self.incarnations[*id].fetch_add(1, Ordering::AcqRel);
            for r in self.slots.sweep_dead_claims(claim_pack(*id, old_inc)) {
                self.rings[r.shard].push(ShardMsg {
                    task: r.task,
                    slot: r.slot,
                    shard: r.shard,
                });
            }
            self.dead_flags[*id].store(false, Ordering::Release);
            workers[*id] = Some(self.spawn_worker(*id));
        }
        let mut log = self.recovery_log.lock().unwrap();
        log.respawns += dead.len() as u64;
        log.recovery_s += t0.elapsed().as_secs_f64();
        Ok(())
    }

    /// Lifetime recovery statistics (respawn count + recovery seconds).
    pub fn recovery_stats(&self) -> RecoveryStats {
        *self.recovery_log.lock().unwrap()
    }

    /// Chaos injection: crash sampler `id` (its thread panics at the top
    /// of its next loop turn). Recovery — if enabled — repairs it on the
    /// next collect; otherwise the death surfaces as an error. Also the
    /// engine-level mapping target for the legacy `poison@<iter>` fault
    /// syntax, now that no poisonable hot-path mutex exists.
    pub fn inject_sampler_crash(&self, id: usize) {
        match self.crash_flags.get(id) {
            Some(flag) => flag.store(true, Ordering::Release),
            // callers validate ids up front (FaultPlan::validate); never
            // let a typo'd id pass as a silently fault-free chaos run
            None => eprintln!(
                "[sampler-service] chaos: no sampler {id} to crash ({} exist)",
                self.m
            ),
        }
    }

    /// Drop all in-flight tasks of one task-id namespace (a dead engine
    /// replica's in a shared pool): their slots retire without collection.
    /// Registered sequences are untouched — the router re-registers them
    /// (minting fresh records) when it requeues onto survivors, and the
    /// old records absorb any stale in-flight decisions harmlessly.
    /// Replica ids are never reused, so purging is permanent.
    pub fn purge_namespace(&self, task_base: u64) {
        self.slots.purge_namespace(task_base, TASK_NS_MASK);
    }

    /// Non-blocking collect: return task `iter`'s assembled result if all
    /// `m` shard cells reported. Errors if a sampler thread died and could
    /// not be recovered.
    pub fn try_collect(&self, iter: u64) -> crate::Result<Option<Collected>> {
        self.check_workers()?;
        Ok(self.slots.try_take(iter).map(|t| self.assemble(t)))
    }

    /// Blocking collect for task `iter`: waits until all `m` shard cells
    /// arrived, recovering crashed workers along the way (or surfacing
    /// their panics as errors instead of deadlocking when recovery is off
    /// or crash-looping).
    pub fn collect_checked(&self, iter: u64) -> crate::Result<Collected> {
        let _span = trace::span(trace::Kind::SvcCollect, iter, 0);
        let mut spins = 0u32;
        loop {
            self.check_workers()?;
            if let Some(taken) = self.slots.try_take(iter) {
                return Ok(self.assemble(taken));
            }
            spins = spins.saturating_add(1);
            if spins < 64 {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
        }
    }

    /// Collect decisions for iteration `iter` (blocks until all `m` shard
    /// cells for that iteration arrived). Returns (col → (seq, verdict))
    /// plus the max per-shard busy time (the decision-plane latency that
    /// must hide under GPU compute). `expected_cols` is the caller's
    /// submitted column count, asserted against what came back — a
    /// mismatch means a sequence was decided by zero or two shards. Panics
    /// if a sampler died unrecoverably — callers on the fallible path (the
    /// engine loop) use [`Self::collect_checked`]; this wrapper exists for
    /// tests and benches.
    pub fn collect(&self, iter: u64, expected_cols: usize) -> (Vec<(usize, u64, Verdict)>, f64) {
        let done = self.collect_checked(iter).expect("decision plane failed");
        debug_assert_eq!(
            done.decisions.len(),
            expected_cols,
            "task {iter}: decided columns != submitted columns"
        );
        (done.decisions, done.busy_s)
    }

    /// Close the rings and join every worker. Returns the stats of workers
    /// that exited cleanly; panicked workers are surfaced per `propagate`
    /// (true = re-panic, false = log and continue — the drop path).
    fn join_all(&mut self, propagate: bool) -> Vec<SamplerStats> {
        for ring in self.rings.iter() {
            ring.close();
        }
        let mut handles: Vec<Option<JoinHandle<SamplerStats>>> =
            std::mem::take(&mut *self.workers.lock().unwrap());
        let mut stats = Vec::new();
        for (id, slot) in handles.iter_mut().enumerate() {
            let Some(handle) = slot.take() else { continue };
            match handle.join() {
                Ok(s) => stats.push(s),
                Err(payload) => {
                    let msg =
                        format!("sampler {id} panicked: {}", panic_message(payload.as_ref()));
                    if propagate && !std::thread::panicking() {
                        panic!("{msg}");
                    }
                    eprintln!("[sampler-service] {msg}");
                }
            }
        }
        stats
    }

    /// Shut down and return per-sampler stats. Panics if a worker panicked
    /// (explicit shutdown wants the failure loud).
    pub fn shutdown(mut self) -> Vec<SamplerStats> {
        self.join_all(true)
    }
}

impl Drop for SamplerService {
    /// Join-on-drop: an engine that errors out (or a panicking test) still
    /// tears the workers down instead of leaking threads; worker panics are
    /// surfaced to stderr rather than silently swallowed.
    fn drop(&mut self) {
        self.join_all(false);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decision::draft::DraftProposer;
    use crate::harness::measure::LogitsGen;
    use crate::tensor::{shard_row_major, Tensor2};

    fn logits_view(b: usize, v: usize, iter: u64, shards: usize) -> ShardedLogits {
        let data: Vec<f32> = (0..b * v)
            .map(|i| {
                let x = (i as u64).wrapping_mul(2654435761).wrapping_add(iter * 97);
                ((x % 1000) as f32) / 150.0 - 3.0
            })
            .collect();
        shard_row_major(&Tensor2::from_vec(b, v, data), shards)
    }

    fn run_service(m: usize, variant: DecisionVariant, iters: u64) -> Vec<Vec<u32>> {
        run_service_with_faults(m, variant, iters, &[])
    }

    /// Drive the service for `iters` plain iterations; `crash_at` lists
    /// (iteration, sampler) chaos injections fired just before that
    /// iteration's submit.
    fn run_service_with_faults(
        m: usize,
        variant: DecisionVariant,
        iters: u64,
        crash_at: &[(u64, usize)],
    ) -> Vec<Vec<u32>> {
        let v = 64;
        let b = 6;
        let cfg = SamplerConfig {
            num_samplers: m,
            variant,
            seed: 42,
            ..Default::default()
        };
        let hot = HotVocab::new((0..16).collect(), v).into_arc();
        let svc = SamplerService::start(&cfg, Some(hot), 128);
        let params = SamplingParams::production_default();
        let handles: Vec<SeqHandle> = (0..b as u64)
            .map(|s| svc.register(s, &[1, 2, 3], &params))
            .collect();
        let mut streams: Vec<Vec<u32>> = vec![Vec::new(); b];
        for iter in 0..iters {
            for &(at, sampler) in crash_at {
                if at == iter {
                    svc.inject_sampler_crash(sampler);
                }
            }
            let view = logits_view(b, v, iter, 2);
            let columns: Vec<ColumnMeta> = (0..b)
                .map(|col| ColumnMeta { col, seq_id: col as u64, iteration: iter })
                .collect();
            let recs: Vec<Option<SeqHandle>> =
                columns.iter().map(|c| Some(handles[c.seq_id as usize].clone())).collect();
            svc.submit(IterationTask::single(iter, view, columns, recs, Vec::new()));
            let (decisions, _busy) = svc.collect(iter, b);
            assert_eq!(decisions.len(), b, "every column decided");
            for (col, seq, verdict) in decisions {
                assert_eq!(col as u64, seq);
                assert_eq!(verdict.tokens.len(), 1, "non-speculative: one token");
                streams[col].push(verdict.tokens[0]);
            }
        }
        for h in &handles {
            svc.retire(h);
        }
        if crash_at.is_empty() {
            let stats = svc.shutdown();
            assert_eq!(stats.len(), m);
            let total: u64 = stats.iter().map(|s| s.decisions).sum();
            assert_eq!(total, iters * b as u64);
        } else {
            assert!(svc.recovery_stats().respawns > 0, "faults must respawn");
            svc.shutdown();
        }
        streams
    }

    /// Drive the service with speculative windows of size `k` until every
    /// sequence committed ≥ `total` tokens. Logits are keyed by
    /// (seq, decode_iter) — the context-free synthetic data plane — so the
    /// streams must be bit-identical across `k` and `m`.
    fn run_service_spec(m: usize, k: usize, total: usize) -> Vec<Vec<u32>> {
        let vocab = 256;
        let b = 4usize;
        let gen = LogitsGen::new(vocab, 1.1, 5);
        let proposer = DraftProposer::new();
        let cfg = SamplerConfig {
            num_samplers: m,
            variant: DecisionVariant::Offloading,
            seed: 17,
            ..Default::default()
        };
        let svc = SamplerService::start(&cfg, None, 512);
        let prompts: Vec<Vec<u32>> = (0..b).map(|s| vec![s as u32 + 1, 9]).collect();
        let params: Vec<SamplingParams> = (0..b)
            .map(|s| SamplingParams { seed: s as u64, ..SamplingParams::production_default() })
            .collect();
        let handles: Vec<SeqHandle> = (0..b)
            .map(|s| svc.register(s as u64, &prompts[s], &params[s]))
            .collect();
        let mut streams: Vec<Vec<u32>> = vec![Vec::new(); b];
        let mut iter = 0u64;
        while streams.iter().any(|s| s.len() < total) {
            let live: Vec<usize> =
                (0..b).filter(|&s| streams[s].len() < total).collect();
            let drafts: Vec<Vec<u32>> = live
                .iter()
                .map(|&s| {
                    proposer.propose(params[s].seed, vocab, &prompts[s], &streams[s], k)
                })
                .collect();
            let kmax = drafts.iter().map(Vec::len).max().unwrap_or(0);
            let columns: Vec<ColumnMeta> = live
                .iter()
                .enumerate()
                .map(|(col, &s)| ColumnMeta {
                    col,
                    seq_id: s as u64,
                    iteration: streams[s].len() as u64,
                })
                .collect();
            let recs: Vec<Option<SeqHandle>> =
                live.iter().map(|&s| Some(handles[s].clone())).collect();
            // view j: per-column logits at that column's decode_iter + j
            let views: Vec<ShardedLogits> = (0..=kmax as u64)
                .map(|j| {
                    let keys: Vec<(u64, u64)> = live
                        .iter()
                        .map(|&s| (s as u64, streams[s].len() as u64 + j))
                        .collect();
                    gen.seq_view(&keys, 2)
                })
                .collect();
            svc.submit(IterationTask {
                iter,
                mb: 0,
                views,
                columns: Arc::new(columns),
                recs: Arc::new(recs),
                pre: Arc::new(Vec::new()),
                drafts: Arc::new(drafts),
            });
            let (decisions, _busy) = svc.collect(iter, live.len());
            assert_eq!(decisions.len(), live.len());
            for (col, seq, verdict) in decisions {
                let _ = col;
                streams[seq as usize].extend(&verdict.tokens);
            }
            iter += 1;
        }
        for h in &handles {
            svc.retire(h);
        }
        svc.shutdown();
        for s in streams.iter_mut() {
            s.truncate(total);
        }
        streams
    }

    #[test]
    fn speculative_streams_bit_identical_across_k_and_m() {
        // The tentpole's end-to-end service contract: verified speculative
        // decode commits the same stream as plain decode for any window
        // size k and any sampler count m.
        let baseline = run_service_spec(1, 0, 24);
        for (m, k) in [(1usize, 2usize), (2, 2), (4, 4), (2, 3)] {
            let spec = run_service_spec(m, k, 24);
            assert_eq!(spec, baseline, "m={m} k={k}");
        }
    }

    #[test]
    fn service_decides_all_columns() {
        let streams = run_service(3, DecisionVariant::Offloading, 8);
        assert!(streams.iter().all(|s| s.len() == 8));
    }

    #[test]
    fn token_streams_invariant_to_sampler_count() {
        // §5.1 determinism: m=1 and m=4 must produce identical tokens.
        let a = run_service(1, DecisionVariant::Offloading, 10);
        let b = run_service(4, DecisionVariant::Offloading, 10);
        assert_eq!(a, b);
        let c = run_service(2, DecisionVariant::Shvs, 10);
        let d = run_service(5, DecisionVariant::Shvs, 10);
        assert_eq!(c, d);
    }

    #[test]
    fn shvs_service_matches_offloading_distributionally() {
        // Not token-exact (different uniform usage) but same distribution —
        // light smoke here; the heavy TVD check lives in shvs::tests.
        let a = run_service(2, DecisionVariant::Shvs, 30);
        let b = run_service(2, DecisionVariant::Offloading, 30);
        // same length streams, tokens within vocab
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.len(), y.len());
            assert!(x.iter().all(|&t| (t as usize) < 64));
            assert!(y.iter().all(|&t| (t as usize) < 64));
        }
    }

    #[test]
    fn crashed_sampler_respawns_and_streams_stay_identical() {
        // The recovery contract survives the lock-free rebuild: a sampler
        // killed mid-run is respawned on the same ring, its dead claims
        // released, unanswered cells resubmitted — the caller sees at most
        // a hiccup and the committed streams are bit-identical to the
        // fault-free run.
        let want = run_service(2, DecisionVariant::Offloading, 12);
        for faults in [vec![(4u64, 0usize)], vec![(2, 1), (7, 0)], vec![(0, 0)]] {
            let got =
                run_service_with_faults(2, DecisionVariant::Offloading, 12, &faults);
            assert_eq!(got, want, "faults {faults:?}");
        }
    }

    #[test]
    fn submit_collect_hot_path_holds_no_service_lock() {
        // The lock-freedom canary: a background thread grabs every mutex
        // the service still owns (all cold-path) and sits on them while
        // the main thread registers, submits, and collects a full
        // iteration. If any hot-path operation took either lock, this
        // test would deadlock instead of finishing.
        let cfg = SamplerConfig {
            num_samplers: 2,
            variant: DecisionVariant::Offloading,
            seed: 11,
            ..Default::default()
        };
        let svc = SamplerService::start(&cfg, None, 64);
        let params = SamplingParams::production_default();
        let locks_held = AtomicBool::new(false);
        let release = AtomicBool::new(false);
        std::thread::scope(|s| {
            s.spawn(|| {
                let _workers = svc.workers.lock().unwrap();
                let _log = svc.recovery_log.lock().unwrap();
                locks_held.store(true, Ordering::Release);
                while !release.load(Ordering::Acquire) {
                    std::thread::yield_now();
                }
            });
            while !locks_held.load(Ordering::Acquire) {
                std::thread::yield_now();
            }
            let handles: Vec<SeqHandle> =
                (0..4u64).map(|q| svc.register(q, &[1, 2], &params)).collect();
            for iter in 0..4u64 {
                let view = logits_view(4, 64, iter, 1);
                let columns: Vec<ColumnMeta> = (0..4)
                    .map(|col| ColumnMeta { col, seq_id: col as u64, iteration: iter })
                    .collect();
                let recs: Vec<Option<SeqHandle>> =
                    columns.iter().map(|c| Some(handles[c.seq_id as usize].clone())).collect();
                svc.submit(IterationTask::single(iter, view, columns, recs, Vec::new()));
                // Poll with the lock-free non-blocking collect only.
                let done = loop {
                    if let Some(d) = svc.try_collect(iter).expect("healthy pool") {
                        break d;
                    }
                    std::thread::yield_now();
                };
                assert_eq!(done.decisions.len(), 4);
            }
            for h in &handles {
                svc.retire(h);
            }
            release.store(true, Ordering::Release);
        });
        svc.shutdown();
    }

    #[test]
    fn submit_path_types_are_send() {
        // Compile-time guard: everything the lock-free submit path moves
        // across threads is Send (and the shared handles Sync) — the
        // static half of the no-mutex-on-the-hot-path acceptance bar.
        fn assert_send<T: Send>() {}
        fn assert_sync<T: Sync>() {}
        assert_send::<ShardMsg>();
        assert_send::<Arc<IterationTask>>();
        assert_send::<SeqHandle>();
        assert_send::<mpmc::Ring<ShardMsg>>();
        assert_sync::<TaskSlots>();
        assert_sync::<SamplerService>();
    }

    #[test]
    fn crash_loop_trips_breaker_when_recovery_enabled() {
        // A deterministically-poisonous task (out-of-range column) kills
        // every respawn: recovery must give up after the breaker limit and
        // surface the real panic instead of looping forever. With work
        // stealing the kills may spread across workers — the pool-wide
        // breaker bounds that case.
        let cfg = SamplerConfig {
            num_samplers: 2,
            variant: DecisionVariant::Offloading,
            ..Default::default()
        };
        let svc = SamplerService::start(&cfg, None, 64);
        let params = SamplingParams::default();
        let h = svc.register(0, &[1], &params);
        let view = logits_view(1, 32, 0, 1);
        svc.submit(IterationTask::single(
            0,
            view,
            vec![ColumnMeta { col: 7, seq_id: 0, iteration: 0 }],
            vec![Some(h)],
            Vec::new(),
        ));
        let err = svc
            .collect_checked(0)
            .expect_err("crash loop must surface, not spin");
        let msg = format!("{err:#}");
        assert!(
            msg.contains("sampler") && msg.contains("panicked"),
            "unhelpful error: {msg}"
        );
        drop(svc); // join-on-drop must not re-panic the test thread
    }

    #[test]
    fn worker_panic_surfaces_instead_of_deadlocking_without_recovery() {
        // With recovery disabled, the pre-hardening contract still holds:
        // a dead worker is joined and its panic surfaces as an error on
        // the first collect (never a deadlock).
        let cfg = SamplerConfig {
            num_samplers: 2,
            variant: DecisionVariant::Offloading,
            recovery: false,
            ..Default::default()
        };
        let svc = SamplerService::start(&cfg, None, 64);
        let params = SamplingParams::default();
        let h = svc.register(0, &[1], &params);
        let view = logits_view(1, 32, 0, 1);
        svc.submit(IterationTask::single(
            0,
            view,
            vec![ColumnMeta { col: 7, seq_id: 0, iteration: 0 }],
            vec![Some(h)],
            Vec::new(),
        ));
        let res = svc.collect_checked(0);
        let err = res.expect_err("dead sampler must surface, not deadlock");
        let msg = format!("{err:#}");
        assert!(
            msg.contains("sampler") && msg.contains("panicked"),
            "unhelpful error: {msg}"
        );
        // drop (join-on-drop) must not re-panic the test thread
        drop(svc);
    }

    #[test]
    fn completion_queue_reaps_tasks_out_of_order() {
        // Two tasks in flight at once (the pipelined executor's shape):
        // reaping the later one first must work, and the earlier one's
        // cells stay parked in their slot.
        let cfg = SamplerConfig {
            num_samplers: 2,
            variant: DecisionVariant::Offloading,
            seed: 9,
            ..Default::default()
        };
        let svc = SamplerService::start(&cfg, None, 128);
        let params = SamplingParams::production_default();
        let handles: Vec<SeqHandle> =
            (0..2u64).map(|s| svc.register(s, &[1, 2], &params)).collect();
        for iter in 0..2u64 {
            let view = logits_view(2, 64, iter, 1);
            let columns: Vec<ColumnMeta> = (0..2)
                .map(|col| ColumnMeta { col, seq_id: col as u64, iteration: iter })
                .collect();
            let recs: Vec<Option<SeqHandle>> =
                columns.iter().map(|c| Some(handles[c.seq_id as usize].clone())).collect();
            svc.submit(IterationTask::single(iter, view, columns, recs, Vec::new()));
        }
        let later = svc.collect_checked(1).expect("task 1");
        assert_eq!(later.decisions.len(), 2);
        assert!(later.busy_s >= 0.0);
        let earlier = loop {
            if let Some(done) = svc.try_collect(0).expect("no dead workers") {
                break done;
            }
            std::thread::yield_now();
        };
        assert_eq!(earlier.decisions.len(), 2);
        for (start, end) in earlier.intervals.iter().chain(&later.intervals) {
            assert!(end >= start, "interval {start}..{end}");
        }
        for h in &handles {
            svc.retire(h);
        }
        svc.shutdown();
    }

    #[test]
    fn purge_namespace_drops_only_that_namespace() {
        let cfg = SamplerConfig {
            num_samplers: 1,
            variant: DecisionVariant::Offloading,
            seed: 3,
            ..Default::default()
        };
        let svc = SamplerService::start(&cfg, None, 64);
        let params = SamplingParams::production_default();
        let handles: Vec<SeqHandle> =
            (0..2u64).map(|s| svc.register(s, &[1, 2], &params)).collect();
        let (base_a, base_b) = (1u64 << TASK_NS_SHIFT, 2u64 << TASK_NS_SHIFT);
        for (base, seq) in [(base_a, 0u64), (base_b, 1u64)] {
            let view = logits_view(1, 64, seq, 1);
            svc.submit(IterationTask::single(
                base,
                view,
                vec![ColumnMeta { col: 0, seq_id: seq, iteration: 0 }],
                vec![Some(handles[seq as usize].clone())],
                Vec::new(),
            ));
        }
        // both tasks complete; purge A's namespace before collecting it
        let b = svc.collect_checked(base_b).expect("task b");
        assert_eq!(b.decisions.len(), 1);
        svc.purge_namespace(base_a);
        assert!(
            svc.try_collect(base_a).expect("no dead workers").is_none(),
            "purged namespace must not complete"
        );
        for h in &handles {
            svc.retire(h);
        }
        svc.shutdown();
    }

    #[test]
    fn retire_frees_ownership() {
        let cfg = SamplerConfig {
            num_samplers: 2,
            variant: DecisionVariant::Offloading,
            ..Default::default()
        };
        let svc = SamplerService::start(&cfg, None, 64);
        let params = SamplingParams::default();
        let h = svc.register(7, &[1], &params);
        svc.retire(&h);
        // Iterating a retired sequence: no decision is produced for it,
        // even though the stale task still carries the retired record.
        let view = logits_view(1, 32, 0, 1);
        svc.submit(IterationTask::single(
            0,
            view,
            vec![ColumnMeta { col: 0, seq_id: 7, iteration: 0 }],
            vec![Some(h)],
            Vec::new(),
        ));
        let (decisions, _) = svc.collect(0, 0);
        assert!(decisions.is_empty());
        svc.shutdown();
    }

    #[test]
    fn reregister_mints_a_fresh_record_and_orphans_the_old() {
        // The Arc-identity incarnation guard: retire + re-register while a
        // task is in flight must leave the new record exactly as seeded —
        // the stale task's decisions land on the orphaned old record.
        let cfg = SamplerConfig {
            num_samplers: 1,
            variant: DecisionVariant::Offloading,
            seed: 5,
            ..Default::default()
        };
        let svc = SamplerService::start(&cfg, None, 64);
        let params = SamplingParams::production_default();
        let old = svc.register(3, &[1, 2], &params);
        let view = logits_view(1, 64, 0, 1);
        svc.submit(IterationTask::single(
            0,
            view,
            vec![ColumnMeta { col: 0, seq_id: 3, iteration: 0 }],
            vec![Some(old.clone())],
            Vec::new(),
        ));
        let (decisions, _) = svc.collect(0, 1);
        assert_eq!(decisions.len(), 1);
        assert_eq!(old.decided_len(), 1, "decision logged on the old record");
        svc.retire(&old);
        let fresh = svc.register_full(3, &[1, 2], &[], &params, None);
        assert!(!Arc::ptr_eq(&old, &fresh), "re-register mints a new record");
        assert_eq!(fresh.decided_len(), 0, "fresh record untouched by the stale task");
        svc.retire(&fresh);
        svc.shutdown();
    }
}
