//! The disaggregated decision-plane service (§4.2, §5.1).
//!
//! `m` sampler workers run on dedicated threads. Each iteration, the engine
//! publishes one [`IterationTask`] per sampler over that sampler's SPSC ring
//! (the shared-memory ring analog); the task carries a zero-copy
//! [`ShardedLogits`] view plus per-column metadata. Samplers decide their
//! columns independently — **sequence-parallel**, no vocabulary-axis
//! reconciliation — and push [`DecisionBatch`]es to the shared return
//! channel (the paper's lightweight ZMQ path back to the scheduler).
//!
//! **Ownership.** A sequence is owned by sampler `seq_id % m` for its whole
//! life, so its history metadata is created, updated, and retired *locally*
//! (the paper's "per-sequence metadata follow the same batch partition and
//! are updated locally"), independent of batch composition. Ownership-by-id
//! replaces the paper's per-iteration contiguous ranges — the balance is the
//! same in expectation and history never migrates.
//!
//! **Determinism.** Decisions use pre-generated Philox uniforms keyed by
//! (engine seed, request seed, sequence, iteration), so the token stream is
//! identical for any `m` (asserted in tests).
//!
//! **Shared pools (DESIGN.md §9).** One service may serve a whole fleet of
//! data-parallel engine replicas: submitters namespace their task ids
//! (`replica id` in the high bits of [`IterationTask::iter`]) so the
//! completion queue never aliases two replicas' iterations, and sequence
//! ownership stays `seq_id % m` — globally unique request ids spread the
//! fleet's sequences over one sampler pool instead of stranding capacity
//! per replica. The submit paths serialize on an internal lock (the SPSC
//! rings still have exactly one logical producer); collects are already
//! concurrent-safe through the shared completion queue.
//!
//! **Crash recovery (DESIGN.md §10).** A sampler thread can die mid-
//! iteration (a panic — real or chaos-injected) while the GPU side keeps
//! producing logits. With `cfg.recovery` on (the default), the service
//! self-heals instead of failing the collect: the collect paths detect the
//! corpse, join it, respawn a fresh worker on a fresh ring, replay its
//! owned sequences from the service-side **registry** (the same
//! resume-replay `Register` path preemption uses — prompt ⧺ decided
//! output), and resubmit any in-flight [`IterationTask`] the dead worker
//! had not answered. The registry mirrors worker-local state exactly: it
//! is written on `register_full`, dropped on `retire`, and rolled forward
//! by each absorbed verdict — precisely the worker's own roll-forward
//! discipline, so the respawned worker recomputes bit-identical decisions
//! (uniforms are keyed by (seed, seq, iteration), not by worker identity).
//! A worker that dies repeatedly without producing work trips a
//! crash-loop breaker and the failure surfaces as an error. Every service
//! mutex is accessed through poison-tolerant locking (`into_inner`), so a
//! panic that poisons a lock is surfaced once with its real payload rather
//! than cascading `PoisonError`s through every later submit.

use super::grammar::GrammarConstraint;
use super::hotvocab::HotVocab;
use super::params::SamplingParams;
use super::penalties::BatchHistory;
use super::pipeline::DecisionPipeline;
use super::shvs::Precompute;
use super::verify::{self, Verdict};
#[cfg(test)]
use crate::config::DecisionVariant;
use crate::config::SamplerConfig;
use crate::ringbuf::{mpmc, spsc};
use crate::tensor::ShardedLogits;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Bit position of the task-id namespace: a shared pool's submitters put
/// their replica id in the bits at and above this shift (`(id+1) << 48`),
/// leaving the low bits for the per-engine plan counter.
pub const TASK_NS_SHIFT: u32 = 48;
/// Mask selecting the namespace bits of a task id.
pub const TASK_NS_MASK: u64 = !((1u64 << TASK_NS_SHIFT) - 1);

/// Consecutive respawns of the same worker (without it producing a single
/// batch in between) before recovery gives up and surfaces the panic — the
/// crash-loop breaker for deterministically-poisonous tasks.
const MAX_CONSECUTIVE_RESPAWNS: u32 = 3;

/// Poison-tolerant lock: a panic while holding a service mutex must be
/// surfaced once (by the collect that joins the corpse) with its real
/// payload — not turned into an opaque `PoisonError` panic in every
/// subsequent submit/collect. The inner data is still consistent for every
/// poison source we have: the injected chaos poison panics before touching
/// the map, and worker panics never run while holding service locks.
fn plock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Per-column metadata within an iteration's microbatch.
#[derive(Debug, Clone)]
pub struct ColumnMeta {
    pub col: usize,
    pub seq_id: u64,
    /// Decode iteration of the *base* chain position for this sequence
    /// (speculative positions key their uniforms at `iteration + j`).
    pub iteration: u64,
}

/// One iteration's work for the decision plane. Shared (Arc'd) pieces are
/// written once by the engine and read zero-copy by every sampler.
///
/// Speculative decoding ships the whole draft chain in one task:
/// `views[0]` is the base decode step's logits; `views[j > 0]` were
/// produced by feeding draft token `j-1`, and `drafts[ci]` carries column
/// `ci`'s proposed window. The batch-axis sharding is untouched — each
/// sampler still reads only its owned columns, in every view, with no
/// vocab-axis collectives.
pub struct IterationTask {
    /// Task id — the scheduler's global plan counter. Unique across
    /// microbatches; the completion queue is keyed by it.
    pub iter: u64,
    /// Microbatch this task belongs to (0 for the synchronous engine).
    /// Samplers copy it into their [`DecisionBatch`]es so the assembled
    /// [`Collected`] can attribute decision intervals to the right
    /// microbatch in the stage timeline.
    pub mb: usize,
    /// Per-chain-position logits views (len 1 = plain decode).
    pub views: Vec<ShardedLogits>,
    pub columns: Arc<Vec<ColumnMeta>>,
    /// Per-view, per-column SHVS precompute: `pre[j][col]` (empty when the
    /// variant doesn't use it).
    pub pre: Arc<Vec<Vec<Precompute>>>,
    /// Draft windows aligned with `columns` (an empty window = plain
    /// decision; an empty outer vec = no speculation this iteration).
    pub drafts: Arc<Vec<Vec<u32>>>,
}

impl IterationTask {
    /// A plain non-speculative iteration: one view, no drafts. `pre` is the
    /// per-column SHVS precompute for that view (may be empty).
    pub fn single(
        iter: u64,
        view: ShardedLogits,
        columns: Vec<ColumnMeta>,
        pre: Vec<Precompute>,
    ) -> IterationTask {
        let pre = if pre.is_empty() { Vec::new() } else { vec![pre] };
        IterationTask {
            iter,
            mb: 0,
            views: vec![view],
            columns: Arc::new(columns),
            pre: Arc::new(pre),
            drafts: Arc::new(Vec::new()),
        }
    }
}

/// Control + data messages flowing engine → sampler.
pub enum SamplerMsg {
    /// A sequence enters the system: register its prompt + params with its
    /// owner sampler. `output` is non-empty when a preempted sequence
    /// resumes (recompute-on-resume): the owner replays those tokens into
    /// its local history/grammar state so penalties and constraints are
    /// byte-identical to an uninterrupted run.
    Register {
        seq_id: u64,
        prompt: Vec<u32>,
        output: Vec<u32>,
        params: SamplingParams,
        grammar: Option<Arc<GrammarConstraint>>,
    },
    /// Decide this iteration's owned columns.
    Iterate(Arc<IterationTask>),
    /// A sequence finished: drop its metadata.
    Retire { seq_id: u64 },
    /// Chaos injection: panic inside the worker thread (a simulated
    /// sampler crash, exercised by the recovery path and `--chaos`).
    Crash,
}

/// One sampler's decisions for one iteration.
#[derive(Debug)]
pub struct DecisionBatch {
    pub iter: u64,
    /// Microbatch tag copied from the task (stage-timeline attribution).
    pub mb: usize,
    pub sampler_id: usize,
    /// (column, seq_id, verdict) — a verdict commits 1..=k+1 tokens
    /// (accepted draft prefix + corrected bonus; exactly 1 without
    /// speculation).
    pub decisions: Vec<(usize, u64, Verdict)>,
    /// Wall seconds this sampler spent deciding (busy time).
    pub busy_s: f64,
    /// Busy interval endpoints, seconds since the service epoch (the
    /// engine's t0) — the stage timeline's raw material.
    pub start_s: f64,
    pub end_s: f64,
}

/// All `m` samplers' decisions for one task, assembled by the completion
/// queue (see [`SamplerService::try_collect`]).
#[derive(Debug, Default)]
pub struct Collected {
    /// Microbatch the task belonged to (as tagged by the submitter).
    pub mb: usize,
    /// Column-sorted (column, seq_id, verdict) triples.
    pub decisions: Vec<(usize, u64, Verdict)>,
    /// Max per-sampler busy seconds — the decision-plane latency that must
    /// hide under GPU compute.
    pub busy_s: f64,
    /// Per-sampler busy intervals (epoch seconds), for overlap accounting.
    pub intervals: Vec<(f64, f64)>,
}

/// Partially-assembled task result in the completion queue.
#[derive(Default)]
struct PendingCollect {
    mb: usize,
    decisions: Vec<(usize, u64, Verdict)>,
    intervals: Vec<(f64, f64)>,
    batches: usize,
    max_busy: f64,
    /// Which samplers reported for this task (lazily sized to `m`): makes
    /// crash-recovery resubmission idempotent — a respawned worker's
    /// re-decision of a task its predecessor already answered is dropped.
    reported: Vec<bool>,
}

/// Service-side replay state for one live sequence — the authoritative
/// mirror of the owner worker's local state, used to rebuild a respawned
/// worker. `output` is rolled forward verdict-by-verdict at absorb time
/// (exactly the worker's own roll-forward); every divergence between
/// verdicts and committed tokens (EOS / max_new / KV-ceiling cuts,
/// preemption) ends in a `retire` or a fresh `register_full`, which resets
/// this entry the same way it resets the worker.
///
/// `gen` is the entry's registration incarnation (globally unique): a
/// submitted task stamps each column with its sequence's gen at submit
/// time, and absorb only rolls a verdict forward when the stamp still
/// matches — so a stale in-flight verdict from *before* a retire +
/// re-register (a preempted sequence whose task was mid-flight) can never
/// double-apply against the fresh incarnation. The workers need no such
/// guard: their SPSC rings deliver Register/Retire/Iterate in exact push
/// order.
struct RegEntry {
    gen: u64,
    prompt: Vec<u32>,
    output: Vec<u32>,
    params: SamplingParams,
    grammar: Option<Arc<GrammarConstraint>>,
}

/// A submitted-but-uncollected task plus the registry incarnations its
/// columns were stamped with (col → gen, computed once at submit — the
/// absorb hot path only looks entries up).
struct LiveTask {
    task: Arc<IterationTask>,
    col_gens: HashMap<usize, u64>,
}

/// Lifetime fault-recovery statistics of a service.
#[derive(Debug, Clone, Copy, Default)]
pub struct RecoveryStats {
    /// Sampler workers respawned after a crash.
    pub respawns: u64,
    /// Wall seconds spent respawning + replaying state (the recovery
    /// pauses a fault-free run would not have paid).
    pub recovery_s: f64,
}

/// Running service handle.
pub struct SamplerService {
    /// Per-sampler control/data rings. Locked because a *shared* pool has
    /// several engine replicas submitting concurrently; each ring still
    /// sees a serialized producer stream (register-before-iterate order is
    /// preserved per replica by the lock). Recovery holds this lock across
    /// its whole respawn-replay-resubmit critical section so no submit can
    /// interleave with a half-rebuilt worker.
    senders: Mutex<Vec<spsc::Producer<SamplerMsg>>>,
    results: mpmc::Receiver<DecisionBatch>,
    /// Kept so crash-recovery can hand a respawned worker the return
    /// channel; dropped at shutdown so channel disconnect still means
    /// "every worker exited".
    result_tx: Option<mpmc::Sender<DecisionBatch>>,
    /// Worker handles; slots are taken when a dead worker is joined
    /// (respawn or panic propagation), and drained at shutdown/drop.
    workers: Mutex<Vec<Option<JoinHandle<SamplerStats>>>>,
    /// Completion queue: batches drained off the return channel, bucketed
    /// by task id `(iter)` until all `m` samplers reported. Lets multiple
    /// microbatches' tasks be in flight and reaped out of order.
    pending: Mutex<HashMap<u64, PendingCollect>>,
    /// Submitted-but-uncollected tasks (+ column gen stamps), retained so
    /// recovery can resubmit them to a respawned worker. Removed when the
    /// task completes.
    live_tasks: Mutex<HashMap<u64, LiveTask>>,
    /// Task-id namespaces whose owner is gone (a failed-over replica):
    /// their stale batches are dropped on arrival so they can neither
    /// recreate purged pending entries nor roll the registry forward past
    /// the state the failover requeue replays from. Replica ids are never
    /// reused, so purging is permanent.
    purged: Mutex<std::collections::HashSet<u64>>,
    /// Replay registry: live sequences' resume state (see [`RegEntry`]).
    registry: Mutex<HashMap<u64, RegEntry>>,
    /// Consecutive respawns per worker since it last produced a batch —
    /// the crash-loop breaker's state.
    respawns: Vec<AtomicU32>,
    /// Registration-incarnation counter (see [`RegEntry::gen`]).
    reg_gen: AtomicU64,
    recovery_log: Mutex<RecoveryStats>,
    /// Spawn ingredients for respawns.
    cfg: SamplerConfig,
    hot: Option<Arc<HotVocab>>,
    max_seq_len: usize,
    m: usize,
    /// Shared time origin the workers timestamp against (the engine's t0;
    /// a cluster's replicas all adopt it so fleet stage timelines merge).
    epoch: Instant,
}

/// Per-sampler lifetime statistics. (Speculative-decoding acceptance is
/// tallied engine-side from *committed* windows — see
/// `PjrtEngine::spec_accepted` — not here, where discarded-after-preemption
/// verdicts would skew the counts.)
#[derive(Debug, Clone, Default)]
pub struct SamplerStats {
    pub decisions: u64,
    pub fast_path_hits: u64,
    pub alpha_sum: f64,
    pub busy_s: f64,
}

/// A sampler's worker loop state.
struct SamplerWorker {
    id: usize,
    m: usize,
    pipeline: DecisionPipeline,
    /// Shared time origin (the engine's t0) so busy intervals are directly
    /// comparable with the engine's GPU stage timestamps.
    epoch: Instant,
    /// Histories of owned sequences, keyed by seq_id. Each history is a
    /// single-column BatchHistory (the column-wise machinery per sequence).
    owned: HashMap<u64, OwnedSeq>,
}

/// Per-sequence sampler-local state.
struct OwnedSeq {
    hist: BatchHistory,
    params: SamplingParams,
    grammar: Option<(Arc<GrammarConstraint>, super::grammar::ConstraintState)>,
}

impl SamplerWorker {
    fn owns(&self, seq_id: u64) -> bool {
        (seq_id as usize) % self.m == self.id
    }

    fn run(
        mut self,
        rx: spsc::Consumer<SamplerMsg>,
        tx: mpmc::Sender<DecisionBatch>,
        max_seq_len: usize,
    ) -> SamplerStats {
        let mut stats = SamplerStats::default();
        while let Some(msg) = rx.pop() {
            match msg {
                SamplerMsg::Register { seq_id, prompt, output, params, grammar } => {
                    if self.owns(seq_id) {
                        // resumed sequence: replay pre-preemption decisions
                        // into the history and the grammar state
                        let hist = BatchHistory::with_replay(prompt, &output, max_seq_len);
                        let mut grammar = grammar.map(|g| {
                            let s = g.start();
                            (g, s)
                        });
                        for &t in &output {
                            if let Some((g, state)) = &mut grammar {
                                if let Some(next) = g.advance(*state, t) {
                                    *state = next;
                                }
                            }
                        }
                        self.owned.insert(seq_id, OwnedSeq { hist, params, grammar });
                    }
                }
                SamplerMsg::Retire { seq_id } => {
                    if self.owns(seq_id) {
                        self.owned.remove(&seq_id);
                    }
                }
                SamplerMsg::Crash => {
                    panic!("chaos: injected sampler crash (worker {})", self.id);
                }
                SamplerMsg::Iterate(task) => {
                    let start_s = self.epoch.elapsed().as_secs_f64();
                    let mut decisions = Vec::new();
                    for (ci, meta) in task.columns.iter().enumerate() {
                        if !self.owns(meta.seq_id) {
                            continue;
                        }
                        let Some(seq) = self.owned.get_mut(&meta.seq_id) else {
                            continue; // retired concurrently; engine resends
                        };
                        let draft: &[u32] =
                            task.drafts.get(ci).map(Vec::as_slice).unwrap_or(&[]);
                        // One code path for both modes: with an empty draft
                        // this is exactly one grammar-masked decision plus
                        // the local metadata append (§5.1); with a draft it
                        // is batched rejection verification with
                        // roll-forward/rollback of the owned state.
                        let verdict = verify::verify_window(
                            &mut self.pipeline,
                            &task.views,
                            meta.col,
                            draft,
                            &mut seq.hist,
                            &mut seq.grammar,
                            &seq.params,
                            &task.pre,
                            meta.seq_id,
                            meta.iteration,
                        );
                        decisions.push((meta.col, meta.seq_id, verdict));
                    }
                    let end_s = self.epoch.elapsed().as_secs_f64();
                    let busy = end_s - start_s;
                    stats.busy_s += busy;
                    let batch = DecisionBatch {
                        iter: task.iter,
                        mb: task.mb,
                        sampler_id: self.id,
                        decisions,
                        busy_s: busy,
                        start_s,
                        end_s,
                    };
                    if tx.send(batch).is_err() {
                        break; // engine gone
                    }
                }
            }
        }
        stats.decisions = self.pipeline.decisions;
        stats.fast_path_hits = self.pipeline.fast_path_hits;
        stats.alpha_sum = self.pipeline.alpha_sum;
        stats
    }
}

/// Render a worker panic payload for error surfacing.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

impl SamplerService {
    /// Spawn `cfg.num_samplers` workers with a fresh time epoch. `hot` is
    /// required for the SHVS variant.
    pub fn start(cfg: &SamplerConfig, hot: Option<Arc<HotVocab>>, max_seq_len: usize) -> Self {
        Self::start_with_epoch(cfg, hot, max_seq_len, Instant::now())
    }

    /// Spawn workers that timestamp their busy intervals relative to
    /// `epoch` (the engine's t0), so decision intervals land on the same
    /// timeline as the engine's GPU stage intervals.
    pub fn start_with_epoch(
        cfg: &SamplerConfig,
        hot: Option<Arc<HotVocab>>,
        max_seq_len: usize,
        epoch: Instant,
    ) -> Self {
        let m = cfg.num_samplers.max(1);
        let (result_tx, results) = mpmc::channel::<DecisionBatch>(m * cfg.ring_depth.max(1) * 2);
        let mut senders = Vec::with_capacity(m);
        let mut workers = Vec::with_capacity(m);
        for id in 0..m {
            let (tx, handle) =
                spawn_worker(id, m, cfg, hot.clone(), max_seq_len, epoch, result_tx.clone());
            senders.push(tx);
            workers.push(Some(handle));
        }
        SamplerService {
            senders: Mutex::new(senders),
            results,
            result_tx: Some(result_tx),
            workers: Mutex::new(workers),
            pending: Mutex::new(HashMap::new()),
            live_tasks: Mutex::new(HashMap::new()),
            purged: Mutex::new(std::collections::HashSet::new()),
            registry: Mutex::new(HashMap::new()),
            respawns: (0..m).map(|_| AtomicU32::new(0)).collect(),
            reg_gen: AtomicU64::new(0),
            recovery_log: Mutex::new(RecoveryStats::default()),
            cfg: cfg.clone(),
            hot,
            max_seq_len,
            m,
            epoch,
        }
    }

    pub fn num_samplers(&self) -> usize {
        self.m
    }

    /// The time origin workers timestamp busy intervals against. Engines
    /// sharing this service adopt it as their t0 so GPU and decision stage
    /// intervals live on one fleet-wide timeline.
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Register a new sequence (routed to its owner sampler).
    pub fn register(&self, seq_id: u64, prompt: &[u32], params: &SamplingParams) {
        self.register_full(seq_id, prompt, &[], params, None);
    }

    /// Register with an optional structured-decoding constraint.
    pub fn register_with_grammar(
        &self,
        seq_id: u64,
        prompt: &[u32],
        params: &SamplingParams,
        grammar: Option<Arc<GrammarConstraint>>,
    ) {
        self.register_full(seq_id, prompt, &[], params, grammar);
    }

    /// Register a (possibly resumed) sequence: `output` carries tokens
    /// generated before a preemption, replayed into the owner's local state.
    pub fn register_full(
        &self,
        seq_id: u64,
        prompt: &[u32],
        output: &[u32],
        params: &SamplingParams,
        grammar: Option<Arc<GrammarConstraint>>,
    ) {
        let owner = (seq_id as usize) % self.m;
        let senders = plock(&self.senders);
        // Registry entry BEFORE the ring push, both under the senders lock:
        // recovery (which also holds that lock) therefore either sees the
        // entry and replays it, or runs before this registration entirely —
        // never in between, where the push could vanish into a dead ring
        // without a registry record to replay from.
        plock(&self.registry).insert(
            seq_id,
            RegEntry {
                gen: self.reg_gen.fetch_add(1, Ordering::Relaxed),
                prompt: prompt.to_vec(),
                output: output.to_vec(),
                params: params.clone(),
                grammar: grammar.clone(),
            },
        );
        senders[owner].push(SamplerMsg::Register {
            seq_id,
            prompt: prompt.to_vec(),
            output: output.to_vec(),
            params: params.clone(),
            grammar,
        });
    }

    /// Retire a finished sequence.
    pub fn retire(&self, seq_id: u64) {
        let owner = (seq_id as usize) % self.m;
        let senders = plock(&self.senders);
        plock(&self.registry).remove(&seq_id);
        senders[owner].push(SamplerMsg::Retire { seq_id });
    }

    /// Publish one iteration's logits + metadata to all samplers. Shared
    /// pools rely on the caller namespacing `task.iter` (unique fleet-wide).
    /// The task is retained until collected so crash-recovery can resubmit
    /// it to a respawned worker.
    pub fn submit(&self, task: IterationTask) {
        let task = Arc::new(task);
        let senders = plock(&self.senders);
        // Stamp each column with its sequence's current registration
        // incarnation — the absorb-time freshness guard for the registry
        // roll-forward (see [`RegEntry::gen`]). Unregistered columns get
        // no stamp, so their verdicts never roll the registry.
        let col_gens: HashMap<usize, u64> = {
            let reg = plock(&self.registry);
            task.columns
                .iter()
                .filter_map(|c| reg.get(&c.seq_id).map(|e| (c.col, e.gen)))
                .collect()
        };
        plock(&self.live_tasks)
            .insert(task.iter, LiveTask { task: task.clone(), col_gens });
        for tx in senders.iter() {
            tx.push(SamplerMsg::Iterate(task.clone()));
        }
    }

    /// Bucket one returned batch into the completion queue, rolling its
    /// verdicts into the replay registry (the service-side mirror of the
    /// owner worker's roll-forward).
    fn absorb(&self, batch: DecisionBatch) {
        if plock(&self.purged).contains(&(batch.iter & TASK_NS_MASK)) {
            return; // stale answer to a failed-over replica's task
        }
        let mut pending = plock(&self.pending);
        let entry = pending.entry(batch.iter).or_default();
        if entry.reported.is_empty() {
            entry.reported = vec![false; self.m];
        }
        if entry.reported[batch.sampler_id] {
            // a respawned worker re-decided a task its crashed predecessor
            // had already answered — identical by determinism; drop it
            return;
        }
        entry.reported[batch.sampler_id] = true;
        self.respawns[batch.sampler_id].store(0, Ordering::Relaxed);
        entry.mb = batch.mb;
        entry.batches += 1;
        entry.max_busy = entry.max_busy.max(batch.busy_s);
        if batch.end_s > batch.start_s {
            entry.intervals.push((batch.start_s, batch.end_s));
        }
        // Roll the verdicts into the replay registry — but only where the
        // column's submit-time gen stamp still matches the entry (a stale
        // verdict from before a retire + re-register must not double-apply
        // against the fresh incarnation; the engine discards the same
        // verdict through its (slot, seq_id) identity guard).
        {
            let live = plock(&self.live_tasks);
            let col_gens = live.get(&batch.iter).map(|lt| &lt.col_gens);
            let mut reg = plock(&self.registry);
            for (col, seq_id, verdict) in &batch.decisions {
                if let Some(e) = reg.get_mut(seq_id) {
                    if col_gens.and_then(|g| g.get(col)) == Some(&e.gen) {
                        e.output.extend_from_slice(&verdict.tokens);
                    }
                }
            }
        }
        entry.decisions.extend(batch.decisions);
    }

    /// Remove task `iter` from the completion queue if all `m` sampler
    /// batches for it arrived.
    fn take_if_complete(&self, iter: u64) -> Option<Collected> {
        let done = {
            let mut pending = plock(&self.pending);
            if !pending.get(&iter).is_some_and(|e| e.batches >= self.m) {
                return None;
            }
            pending.remove(&iter).unwrap()
        };
        plock(&self.live_tasks).remove(&iter);
        let mut decisions = done.decisions;
        decisions.sort_unstable_by_key(|&(col, _, _)| col);
        Some(Collected {
            mb: done.mb,
            decisions,
            busy_s: done.max_busy,
            intervals: done.intervals,
        })
    }

    /// Reap dead workers: take + join every finished handle while the
    /// service is live. Returns their (id, failure message) pairs.
    fn reap_dead(&self) -> Vec<(usize, String)> {
        let mut workers = plock(&self.workers);
        let mut dead = Vec::new();
        for (id, slot) in workers.iter_mut().enumerate() {
            if slot.as_ref().is_some_and(|h| h.is_finished()) {
                let handle = slot.take().unwrap();
                let msg = match handle.join() {
                    Err(payload) => format!(
                        "sampler {id} panicked: {}",
                        panic_message(payload.as_ref())
                    ),
                    Ok(_) => format!("sampler {id} exited mid-service"),
                };
                dead.push((id, msg));
            }
        }
        dead
    }

    /// Propagate or repair sampler-thread death. A worker whose handle is
    /// finished while the service is live either panicked or exited early;
    /// without this check a dead worker deadlocks `collect` forever,
    /// because the surviving workers keep the return channel alive while
    /// the batch count can never reach `m`. With `cfg.recovery` the corpse
    /// is respawned and its state replayed (see [`Self::recover`]);
    /// otherwise — or when the crash-loop breaker trips — the death
    /// surfaces as an error carrying the panic payload.
    fn check_workers(&self) -> crate::Result<()> {
        let dead = self.reap_dead();
        if dead.is_empty() {
            return Ok(());
        }
        if !self.cfg.recovery {
            anyhow::bail!("{}", dead[0].1);
        }
        for (id, msg) in &dead {
            let n = self.respawns[*id].fetch_add(1, Ordering::Relaxed) + 1;
            if n > MAX_CONSECUTIVE_RESPAWNS {
                anyhow::bail!(
                    "sampler {id} crash-looping ({n} consecutive respawns): {msg}"
                );
            }
        }
        self.recover(&dead)
    }

    /// Respawn dead workers and rebuild their state: fresh ring + thread,
    /// drain the return channel (so `reported` and the registry are
    /// current), replay owned sequences through the resume-`Register`
    /// path, and resubmit every live task the corpse had not answered.
    /// Holds the senders lock throughout so no submit interleaves with a
    /// half-rebuilt worker.
    fn recover(&self, dead: &[(usize, String)]) -> crate::Result<()> {
        let t0 = Instant::now();
        let mut senders = plock(&self.senders);
        let Some(result_tx) = &self.result_tx else {
            anyhow::bail!("{} (service shutting down)", dead[0].1);
        };
        for (id, msg) in dead {
            eprintln!("[sampler-service] {msg}; respawning worker {id}");
            let (tx, handle) = spawn_worker(
                *id,
                self.m,
                &self.cfg,
                self.hot.clone(),
                self.max_seq_len,
                self.epoch,
                result_tx.clone(),
            );
            senders[*id] = tx; // old producer drops; the dead ring closes
            plock(&self.workers)[*id] = Some(handle);
        }
        // Everything the corpses sent before dying is already in the
        // return channel: drain it so the registry holds their final
        // roll-forward and `reported` knows which tasks they answered.
        while let Some(batch) = self.results.try_recv() {
            self.absorb(batch);
        }
        // Replay owned sequences (deterministic order for reproducibility).
        {
            let reg = plock(&self.registry);
            let mut ids: Vec<u64> = reg
                .keys()
                .copied()
                .filter(|s| dead.iter().any(|(id, _)| (*s as usize) % self.m == *id))
                .collect();
            ids.sort_unstable();
            for seq_id in ids {
                let e = &reg[&seq_id];
                senders[(seq_id as usize) % self.m].push(SamplerMsg::Register {
                    seq_id,
                    prompt: e.prompt.clone(),
                    output: e.output.clone(),
                    params: e.params.clone(),
                    grammar: e.grammar.clone(),
                });
            }
        }
        // Resubmit unanswered live tasks to the respawned workers only
        // (idempotent: `absorb` drops a duplicate answer anyway).
        {
            let mut tasks: Vec<(u64, Arc<IterationTask>)> = plock(&self.live_tasks)
                .iter()
                .map(|(&id, lt)| (id, lt.task.clone()))
                .collect();
            tasks.sort_unstable_by_key(|&(id, _)| id);
            for (tid, task) in tasks {
                let answered = plock(&self.pending)
                    .get(&tid)
                    .map(|e| e.reported.clone())
                    .unwrap_or_default();
                for (id, _) in dead {
                    if !answered.get(*id).copied().unwrap_or(false) {
                        senders[*id].push(SamplerMsg::Iterate(task.clone()));
                    }
                }
            }
        }
        let mut log = plock(&self.recovery_log);
        log.respawns += dead.len() as u64;
        log.recovery_s += t0.elapsed().as_secs_f64();
        Ok(())
    }

    /// Lifetime recovery statistics (respawn count + recovery seconds).
    pub fn recovery_stats(&self) -> RecoveryStats {
        *plock(&self.recovery_log)
    }

    /// Chaos injection: crash sampler `id` (its thread panics on the next
    /// message it processes). Recovery — if enabled — repairs it on the
    /// next collect; otherwise the death surfaces as an error.
    pub fn inject_sampler_crash(&self, id: usize) {
        let senders = plock(&self.senders);
        match senders.get(id) {
            Some(tx) => {
                tx.push(SamplerMsg::Crash);
            }
            // callers validate ids up front (FaultPlan::validate); never
            // let a typo'd id pass as a silently fault-free chaos run
            None => eprintln!(
                "[sampler-service] chaos: no sampler {id} to crash ({} exist)",
                senders.len()
            ),
        }
    }

    /// Chaos injection: poison the completion-queue mutex (a thread panics
    /// while holding it, before touching the data). Every later access
    /// goes through poison-tolerant locking, so the service keeps
    /// operating — the injected panic stays contained in its thread.
    pub fn inject_lock_poison(&self) {
        std::thread::scope(|s| {
            let h = s.spawn(|| {
                let _guard = plock(&self.pending);
                panic!("chaos: injected lock poison");
            });
            let _ = h.join(); // the panic is the point; swallow it
        });
    }

    /// Drop all queue state owned by one task-id namespace (a dead engine
    /// replica's in-flight tasks in a shared pool): its pending partial
    /// collects and retained live tasks. Its registered sequences are NOT
    /// dropped here — the router re-registers them (with replay) when it
    /// requeues the replica's sequences onto survivors.
    pub fn purge_namespace(&self, task_base: u64) {
        plock(&self.purged).insert(task_base);
        plock(&self.pending).retain(|&id, _| id & TASK_NS_MASK != task_base);
        plock(&self.live_tasks).retain(|&id, _| id & TASK_NS_MASK != task_base);
    }

    /// Non-blocking collect: drain whatever the samplers have pushed so
    /// far and return task `iter`'s assembled result if complete. Errors
    /// if a sampler thread died and could not be recovered.
    pub fn try_collect(&self, iter: u64) -> crate::Result<Option<Collected>> {
        loop {
            if let Some(done) = self.take_if_complete(iter) {
                return Ok(Some(done));
            }
            match self.results.try_recv() {
                Some(batch) => self.absorb(batch),
                None => {
                    self.check_workers()?;
                    return Ok(None);
                }
            }
        }
    }

    /// Blocking collect for task `iter`: waits until all `m` sampler
    /// batches arrived, recovering crashed workers along the way (or
    /// surfacing their panics as errors instead of deadlocking when
    /// recovery is off or crash-looping).
    pub fn collect_checked(&self, iter: u64) -> crate::Result<Collected> {
        loop {
            if let Some(done) = self.take_if_complete(iter) {
                return Ok(done);
            }
            match self.results.recv_timeout(Duration::from_millis(20)) {
                Ok(Some(batch)) => self.absorb(batch),
                Ok(None) => anyhow::bail!("decision plane disconnected"),
                Err(()) => self.check_workers()?, // starved: look for corpses
            }
        }
    }

    /// Collect decisions for iteration `iter` (blocks until all `m` sampler
    /// batches for that iteration arrived). Returns (col → (seq, verdict))
    /// plus the max per-sampler busy time (the decision-plane latency that
    /// must hide under GPU compute). `expected_cols` is the caller's
    /// submitted column count, asserted against what came back — a mismatch
    /// means a sequence was decided by zero or two owners. Panics if a
    /// sampler died unrecoverably — callers on the fallible path (the
    /// engine loop) use [`Self::collect_checked`]; this wrapper exists for
    /// tests and benches.
    pub fn collect(&self, iter: u64, expected_cols: usize) -> (Vec<(usize, u64, Verdict)>, f64) {
        let done = self.collect_checked(iter).expect("decision plane failed");
        debug_assert_eq!(
            done.decisions.len(),
            expected_cols,
            "task {iter}: decided columns != submitted columns"
        );
        (done.decisions, done.busy_s)
    }

    /// Close the rings and join every worker. Returns the stats of workers
    /// that exited cleanly; panicked workers are surfaced per `propagate`
    /// (true = re-panic, false = log and continue — the drop path).
    fn join_all(&mut self, propagate: bool) -> Vec<SamplerStats> {
        self.result_tx = None; // recovery is over; let the channel disconnect
        let mut senders = plock(&self.senders);
        for tx in senders.iter() {
            tx.close();
        }
        senders.clear(); // Producer::drop closes the rings
        drop(senders);
        let mut handles: Vec<Option<JoinHandle<SamplerStats>>> =
            std::mem::take(&mut *plock(&self.workers));
        // Drain stray result batches while workers wind down so none blocks
        // forever on a full return channel (timed waits, not a spin: each
        // worker drops its sender on exit, so `Ok(None)` means all done).
        loop {
            match self.results.recv_timeout(Duration::from_millis(5)) {
                Ok(Some(_)) => {}  // discard a stray batch
                Ok(None) => break, // every worker dropped its sender
                Err(()) => {
                    let all_done = handles
                        .iter()
                        .all(|h| h.as_ref().is_none_or(|h| h.is_finished()));
                    if all_done {
                        break;
                    }
                }
            }
        }
        while self.results.try_recv().is_some() {}
        let mut stats = Vec::new();
        for (id, slot) in handles.iter_mut().enumerate() {
            let Some(handle) = slot.take() else { continue };
            match handle.join() {
                Ok(s) => stats.push(s),
                Err(payload) => {
                    let msg =
                        format!("sampler {id} panicked: {}", panic_message(payload.as_ref()));
                    if propagate && !std::thread::panicking() {
                        panic!("{msg}");
                    }
                    eprintln!("[sampler-service] {msg}");
                }
            }
        }
        stats
    }

    /// Shut down and return per-sampler stats. Panics if a worker panicked
    /// (explicit shutdown wants the failure loud).
    pub fn shutdown(mut self) -> Vec<SamplerStats> {
        self.join_all(true)
    }
}

/// Spawn one sampler worker on a fresh ring (initial start and respawns).
fn spawn_worker(
    id: usize,
    m: usize,
    cfg: &SamplerConfig,
    hot: Option<Arc<HotVocab>>,
    max_seq_len: usize,
    epoch: Instant,
    result_tx: mpmc::Sender<DecisionBatch>,
) -> (spsc::Producer<SamplerMsg>, JoinHandle<SamplerStats>) {
    let (tx, rx) = spsc::ring::<SamplerMsg>(cfg.ring_depth.max(1) * 64);
    let worker = SamplerWorker {
        id,
        m,
        pipeline: DecisionPipeline::new(cfg.variant, hot, cfg.seed),
        epoch,
        owned: HashMap::new(),
    };
    let handle = std::thread::Builder::new()
        .name(format!("sampler-{id}"))
        .spawn(move || worker.run(rx, result_tx, max_seq_len))
        .expect("spawn sampler");
    (tx, handle)
}

impl Drop for SamplerService {
    /// Join-on-drop: an engine that errors out (or a panicking test) still
    /// tears the workers down instead of leaking threads; worker panics are
    /// surfaced to stderr rather than silently swallowed.
    fn drop(&mut self) {
        self.join_all(false);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decision::draft::DraftProposer;
    use crate::harness::measure::LogitsGen;
    use crate::tensor::{shard_row_major, Tensor2};

    fn logits_view(b: usize, v: usize, iter: u64, shards: usize) -> ShardedLogits {
        let data: Vec<f32> = (0..b * v)
            .map(|i| {
                let x = (i as u64).wrapping_mul(2654435761).wrapping_add(iter * 97);
                ((x % 1000) as f32) / 150.0 - 3.0
            })
            .collect();
        shard_row_major(&Tensor2::from_vec(b, v, data), shards)
    }

    fn run_service(m: usize, variant: DecisionVariant, iters: u64) -> Vec<Vec<u32>> {
        run_service_with_faults(m, variant, iters, &[])
    }

    /// Drive the service for `iters` plain iterations; `crash_at` lists
    /// (iteration, sampler) chaos injections fired just before that
    /// iteration's submit.
    fn run_service_with_faults(
        m: usize,
        variant: DecisionVariant,
        iters: u64,
        crash_at: &[(u64, usize)],
    ) -> Vec<Vec<u32>> {
        let v = 64;
        let b = 6;
        let cfg = SamplerConfig {
            num_samplers: m,
            variant,
            seed: 42,
            ..Default::default()
        };
        let hot = HotVocab::new((0..16).collect(), v).into_arc();
        let svc = SamplerService::start(&cfg, Some(hot), 128);
        let params = SamplingParams::production_default();
        for s in 0..b as u64 {
            svc.register(s, &[1, 2, 3], &params);
        }
        let mut streams: Vec<Vec<u32>> = vec![Vec::new(); b];
        for iter in 0..iters {
            for &(at, sampler) in crash_at {
                if at == iter {
                    svc.inject_sampler_crash(sampler);
                }
            }
            let view = logits_view(b, v, iter, 2);
            let columns: Vec<ColumnMeta> = (0..b)
                .map(|col| ColumnMeta { col, seq_id: col as u64, iteration: iter })
                .collect();
            svc.submit(IterationTask::single(iter, view, columns, Vec::new()));
            let (decisions, _busy) = svc.collect(iter, b);
            assert_eq!(decisions.len(), b, "every column decided");
            for (col, seq, verdict) in decisions {
                assert_eq!(col as u64, seq);
                assert_eq!(verdict.tokens.len(), 1, "non-speculative: one token");
                streams[col].push(verdict.tokens[0]);
            }
        }
        for s in 0..b as u64 {
            svc.retire(s);
        }
        if crash_at.is_empty() {
            let stats = svc.shutdown();
            assert_eq!(stats.len(), m);
            let total: u64 = stats.iter().map(|s| s.decisions).sum();
            assert_eq!(total, iters * b as u64);
        } else {
            assert!(svc.recovery_stats().respawns > 0, "faults must respawn");
            svc.shutdown();
        }
        streams
    }

    /// Drive the service with speculative windows of size `k` until every
    /// sequence committed ≥ `total` tokens. Logits are keyed by
    /// (seq, decode_iter) — the context-free synthetic data plane — so the
    /// streams must be bit-identical across `k` and `m`.
    fn run_service_spec(m: usize, k: usize, total: usize) -> Vec<Vec<u32>> {
        let vocab = 256;
        let b = 4usize;
        let gen = LogitsGen::new(vocab, 1.1, 5);
        let proposer = DraftProposer::new();
        let cfg = SamplerConfig {
            num_samplers: m,
            variant: DecisionVariant::Offloading,
            seed: 17,
            ..Default::default()
        };
        let svc = SamplerService::start(&cfg, None, 512);
        let prompts: Vec<Vec<u32>> = (0..b).map(|s| vec![s as u32 + 1, 9]).collect();
        let params: Vec<SamplingParams> = (0..b)
            .map(|s| SamplingParams { seed: s as u64, ..SamplingParams::production_default() })
            .collect();
        for s in 0..b {
            svc.register(s as u64, &prompts[s], &params[s]);
        }
        let mut streams: Vec<Vec<u32>> = vec![Vec::new(); b];
        let mut iter = 0u64;
        while streams.iter().any(|s| s.len() < total) {
            let live: Vec<usize> =
                (0..b).filter(|&s| streams[s].len() < total).collect();
            let drafts: Vec<Vec<u32>> = live
                .iter()
                .map(|&s| {
                    proposer.propose(params[s].seed, vocab, &prompts[s], &streams[s], k)
                })
                .collect();
            let kmax = drafts.iter().map(Vec::len).max().unwrap_or(0);
            let columns: Vec<ColumnMeta> = live
                .iter()
                .enumerate()
                .map(|(col, &s)| ColumnMeta {
                    col,
                    seq_id: s as u64,
                    iteration: streams[s].len() as u64,
                })
                .collect();
            // view j: per-column logits at that column's decode_iter + j
            let views: Vec<ShardedLogits> = (0..=kmax as u64)
                .map(|j| {
                    let keys: Vec<(u64, u64)> = live
                        .iter()
                        .map(|&s| (s as u64, streams[s].len() as u64 + j))
                        .collect();
                    gen.seq_view(&keys, 2)
                })
                .collect();
            svc.submit(IterationTask {
                iter,
                mb: 0,
                views,
                columns: Arc::new(columns),
                pre: Arc::new(Vec::new()),
                drafts: Arc::new(drafts),
            });
            let (decisions, _busy) = svc.collect(iter, live.len());
            assert_eq!(decisions.len(), live.len());
            for (col, seq, verdict) in decisions {
                let _ = col;
                streams[seq as usize].extend(&verdict.tokens);
            }
            iter += 1;
        }
        for s in 0..b as u64 {
            svc.retire(s);
        }
        svc.shutdown();
        for s in streams.iter_mut() {
            s.truncate(total);
        }
        streams
    }

    #[test]
    fn speculative_streams_bit_identical_across_k_and_m() {
        // The tentpole's end-to-end service contract: verified speculative
        // decode commits the same stream as plain decode for any window
        // size k and any sampler count m.
        let baseline = run_service_spec(1, 0, 24);
        for (m, k) in [(1usize, 2usize), (2, 2), (4, 4), (2, 3)] {
            let spec = run_service_spec(m, k, 24);
            assert_eq!(spec, baseline, "m={m} k={k}");
        }
    }

    #[test]
    fn service_decides_all_columns() {
        let streams = run_service(3, DecisionVariant::Offloading, 8);
        assert!(streams.iter().all(|s| s.len() == 8));
    }

    #[test]
    fn token_streams_invariant_to_sampler_count() {
        // §5.1 determinism: m=1 and m=4 must produce identical tokens.
        let a = run_service(1, DecisionVariant::Offloading, 10);
        let b = run_service(4, DecisionVariant::Offloading, 10);
        assert_eq!(a, b);
        let c = run_service(2, DecisionVariant::Shvs, 10);
        let d = run_service(5, DecisionVariant::Shvs, 10);
        assert_eq!(c, d);
    }

    #[test]
    fn shvs_service_matches_offloading_distributionally() {
        // Not token-exact (different uniform usage) but same distribution —
        // light smoke here; the heavy TVD check lives in shvs::tests.
        let a = run_service(2, DecisionVariant::Shvs, 30);
        let b = run_service(2, DecisionVariant::Offloading, 30);
        // same length streams, tokens within vocab
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.len(), y.len());
            assert!(x.iter().all(|&t| (t as usize) < 64));
            assert!(y.iter().all(|&t| (t as usize) < 64));
        }
    }

    #[test]
    fn crashed_sampler_respawns_and_streams_stay_identical() {
        // The tentpole: a sampler killed mid-run is respawned, its owned
        // sequences replayed from the registry, and the in-flight task
        // resubmitted — the caller sees at most a hiccup and the committed
        // streams are bit-identical to the fault-free run.
        let want = run_service(2, DecisionVariant::Offloading, 12);
        for faults in [vec![(4u64, 0usize)], vec![(2, 1), (7, 0)], vec![(0, 0)]] {
            let got =
                run_service_with_faults(2, DecisionVariant::Offloading, 12, &faults);
            assert_eq!(got, want, "faults {faults:?}");
        }
    }

    #[test]
    fn poisoned_lock_does_not_cascade() {
        // A panic while holding the completion-queue mutex must be
        // contained: subsequent submits/collects keep working (the
        // poisoned-mutex satellite), and the streams stay identical.
        let want = run_service(2, DecisionVariant::Offloading, 6);
        let cfg = SamplerConfig {
            num_samplers: 2,
            variant: DecisionVariant::Offloading,
            seed: 42,
            ..Default::default()
        };
        let hot = HotVocab::new((0..16).collect(), 64).into_arc();
        let svc = SamplerService::start(&cfg, Some(hot), 128);
        let params = SamplingParams::production_default();
        for s in 0..6u64 {
            svc.register(s, &[1, 2, 3], &params);
        }
        let mut streams: Vec<Vec<u32>> = vec![Vec::new(); 6];
        for iter in 0..6u64 {
            if iter == 2 {
                svc.inject_lock_poison();
            }
            let view = logits_view(6, 64, iter, 2);
            let columns: Vec<ColumnMeta> = (0..6)
                .map(|col| ColumnMeta { col, seq_id: col as u64, iteration: iter })
                .collect();
            svc.submit(IterationTask::single(iter, view, columns, Vec::new()));
            let done = svc.collect_checked(iter).expect("poison must not cascade");
            for (col, _, verdict) in done.decisions {
                streams[col].push(verdict.tokens[0]);
            }
        }
        for s in 0..6u64 {
            svc.retire(s);
        }
        svc.shutdown();
        assert_eq!(streams, want);
    }

    #[test]
    fn crash_loop_trips_breaker_when_recovery_enabled() {
        // A deterministically-poisonous task (out-of-range column) kills
        // every respawn: recovery must give up after the breaker limit and
        // surface the real panic instead of looping forever.
        let cfg = SamplerConfig {
            num_samplers: 2,
            variant: DecisionVariant::Offloading,
            ..Default::default()
        };
        let svc = SamplerService::start(&cfg, None, 64);
        let params = SamplingParams::default();
        svc.register(0, &[1], &params);
        let view = logits_view(1, 32, 0, 1);
        svc.submit(IterationTask::single(
            0,
            view,
            vec![ColumnMeta { col: 7, seq_id: 0, iteration: 0 }],
            Vec::new(),
        ));
        let err = svc
            .collect_checked(0)
            .expect_err("crash loop must surface, not spin");
        let msg = format!("{err:#}");
        assert!(
            msg.contains("sampler") && msg.contains("panicked"),
            "unhelpful error: {msg}"
        );
        drop(svc); // join-on-drop must not re-panic the test thread
    }

    #[test]
    fn worker_panic_surfaces_instead_of_deadlocking_without_recovery() {
        // With recovery disabled, the pre-hardening contract still holds:
        // a dead worker is joined and its panic surfaces as an error on
        // the first collect (never a deadlock, never a PoisonError).
        let cfg = SamplerConfig {
            num_samplers: 2,
            variant: DecisionVariant::Offloading,
            recovery: false,
            ..Default::default()
        };
        let svc = SamplerService::start(&cfg, None, 64);
        let params = SamplingParams::default();
        svc.register(0, &[1], &params);
        let view = logits_view(1, 32, 0, 1);
        svc.submit(IterationTask::single(
            0,
            view,
            vec![ColumnMeta { col: 7, seq_id: 0, iteration: 0 }],
            Vec::new(),
        ));
        let res = svc.collect_checked(0);
        let err = res.expect_err("dead sampler must surface, not deadlock");
        let msg = format!("{err:#}");
        assert!(
            msg.contains("sampler") && msg.contains("panicked"),
            "unhelpful error: {msg}"
        );
        // drop (join-on-drop) must not re-panic the test thread
        drop(svc);
    }

    #[test]
    fn completion_queue_reaps_tasks_out_of_order() {
        // Two tasks in flight at once (the pipelined executor's shape):
        // reaping the later one first must work, and the earlier one's
        // batches stay buffered in the completion queue.
        let cfg = SamplerConfig {
            num_samplers: 2,
            variant: DecisionVariant::Offloading,
            seed: 9,
            ..Default::default()
        };
        let svc = SamplerService::start(&cfg, None, 128);
        let params = SamplingParams::production_default();
        for s in 0..2u64 {
            svc.register(s, &[1, 2], &params);
        }
        for iter in 0..2u64 {
            let view = logits_view(2, 64, iter, 1);
            let columns: Vec<ColumnMeta> = (0..2)
                .map(|col| ColumnMeta { col, seq_id: col as u64, iteration: iter })
                .collect();
            svc.submit(IterationTask::single(iter, view, columns, Vec::new()));
        }
        let later = svc.collect_checked(1).expect("task 1");
        assert_eq!(later.decisions.len(), 2);
        assert!(later.busy_s >= 0.0);
        // task 0 completes too (possibly already buffered by the first
        // collect's draining; otherwise try_collect drains it here)
        let earlier = loop {
            if let Some(done) = svc.try_collect(0).expect("no dead workers") {
                break done;
            }
            std::thread::yield_now();
        };
        assert_eq!(earlier.decisions.len(), 2);
        for (start, end) in earlier.intervals.iter().chain(&later.intervals) {
            assert!(end >= start, "interval {start}..{end}");
        }
        for s in 0..2u64 {
            svc.retire(s);
        }
        svc.shutdown();
    }

    #[test]
    fn purge_namespace_drops_only_that_namespace() {
        let cfg = SamplerConfig {
            num_samplers: 1,
            variant: DecisionVariant::Offloading,
            seed: 3,
            ..Default::default()
        };
        let svc = SamplerService::start(&cfg, None, 64);
        let params = SamplingParams::production_default();
        for s in 0..2u64 {
            svc.register(s, &[1, 2], &params);
        }
        let (base_a, base_b) = (1u64 << TASK_NS_SHIFT, 2u64 << TASK_NS_SHIFT);
        for (base, seq) in [(base_a, 0u64), (base_b, 1u64)] {
            let view = logits_view(1, 64, seq, 1);
            svc.submit(IterationTask::single(
                base,
                view,
                vec![ColumnMeta { col: 0, seq_id: seq, iteration: 0 }],
                Vec::new(),
            ));
        }
        // both tasks complete; purge A's namespace before collecting it
        let b = svc.collect_checked(base_b).expect("task b");
        assert_eq!(b.decisions.len(), 1);
        svc.purge_namespace(base_a);
        assert!(
            svc.try_collect(base_a).expect("no dead workers").is_none(),
            "purged namespace must not complete"
        );
        for s in 0..2u64 {
            svc.retire(s);
        }
        svc.shutdown();
    }

    #[test]
    fn retire_frees_ownership() {
        let cfg = SamplerConfig {
            num_samplers: 2,
            variant: DecisionVariant::Offloading,
            ..Default::default()
        };
        let svc = SamplerService::start(&cfg, None, 64);
        let params = SamplingParams::default();
        svc.register(7, &[1], &params);
        svc.retire(7);
        // Iterating a retired sequence: no decision is produced for it.
        let view = logits_view(1, 32, 0, 1);
        svc.submit(IterationTask::single(
            0,
            view,
            vec![ColumnMeta { col: 0, seq_id: 7, iteration: 0 }],
            Vec::new(),
        ));
        let (decisions, _) = svc.collect(0, 0);
        assert!(decisions.is_empty());
        svc.shutdown();
    }
}
