//! The disaggregated decision-plane service (§4.2, §5.1).
//!
//! `m` sampler workers run on dedicated threads. Each iteration, the engine
//! publishes one [`IterationTask`] per sampler over that sampler's SPSC ring
//! (the shared-memory ring analog); the task carries a zero-copy
//! [`ShardedLogits`] view plus per-column metadata. Samplers decide their
//! columns independently — **sequence-parallel**, no vocabulary-axis
//! reconciliation — and push [`DecisionBatch`]es to the shared return
//! channel (the paper's lightweight ZMQ path back to the scheduler).
//!
//! **Ownership.** A sequence is owned by sampler `seq_id % m` for its whole
//! life, so its history metadata is created, updated, and retired *locally*
//! (the paper's "per-sequence metadata follow the same batch partition and
//! are updated locally"), independent of batch composition. Ownership-by-id
//! replaces the paper's per-iteration contiguous ranges — the balance is the
//! same in expectation and history never migrates.
//!
//! **Determinism.** Decisions use pre-generated Philox uniforms keyed by
//! (engine seed, request seed, sequence, iteration), so the token stream is
//! identical for any `m` (asserted in tests).
//!
//! **Shared pools (DESIGN.md §9).** One service may serve a whole fleet of
//! data-parallel engine replicas: submitters namespace their task ids
//! (`replica id` in the high bits of [`IterationTask::iter`]) so the
//! completion queue never aliases two replicas' iterations, and sequence
//! ownership stays `seq_id % m` — globally unique request ids spread the
//! fleet's sequences over one sampler pool instead of stranding capacity
//! per replica. The submit paths serialize on an internal lock (the SPSC
//! rings still have exactly one logical producer); collects are already
//! concurrent-safe through the shared completion queue.

use super::grammar::{ConstraintState, GrammarConstraint};
use super::hotvocab::HotVocab;
use super::params::SamplingParams;
use super::penalties::BatchHistory;
use super::pipeline::DecisionPipeline;
use super::shvs::Precompute;
use super::verify::{self, Verdict};
use crate::config::SamplerConfig;
#[cfg(test)]
use crate::config::DecisionVariant;
use crate::ringbuf::{mpmc, spsc};
use crate::tensor::ShardedLogits;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Per-column metadata within an iteration's microbatch.
#[derive(Debug, Clone)]
pub struct ColumnMeta {
    pub col: usize,
    pub seq_id: u64,
    /// Decode iteration of the *base* chain position for this sequence
    /// (speculative positions key their uniforms at `iteration + j`).
    pub iteration: u64,
}

/// One iteration's work for the decision plane. Shared (Arc'd) pieces are
/// written once by the engine and read zero-copy by every sampler.
///
/// Speculative decoding ships the whole draft chain in one task:
/// `views[0]` is the base decode step's logits; `views[j > 0]` were
/// produced by feeding draft token `j-1`, and `drafts[ci]` carries column
/// `ci`'s proposed window. The batch-axis sharding is untouched — each
/// sampler still reads only its owned columns, in every view, with no
/// vocab-axis collectives.
pub struct IterationTask {
    /// Task id — the scheduler's global plan counter. Unique across
    /// microbatches; the completion queue is keyed by it.
    pub iter: u64,
    /// Microbatch this task belongs to (0 for the synchronous engine).
    /// Samplers copy it into their [`DecisionBatch`]es so the assembled
    /// [`Collected`] can attribute decision intervals to the right
    /// microbatch in the stage timeline.
    pub mb: usize,
    /// Per-chain-position logits views (len 1 = plain decode).
    pub views: Vec<ShardedLogits>,
    pub columns: Arc<Vec<ColumnMeta>>,
    /// Per-view, per-column SHVS precompute: `pre[j][col]` (empty when the
    /// variant doesn't use it).
    pub pre: Arc<Vec<Vec<Precompute>>>,
    /// Draft windows aligned with `columns` (an empty window = plain
    /// decision; an empty outer vec = no speculation this iteration).
    pub drafts: Arc<Vec<Vec<u32>>>,
}

impl IterationTask {
    /// A plain non-speculative iteration: one view, no drafts. `pre` is the
    /// per-column SHVS precompute for that view (may be empty).
    pub fn single(
        iter: u64,
        view: ShardedLogits,
        columns: Vec<ColumnMeta>,
        pre: Vec<Precompute>,
    ) -> IterationTask {
        let pre = if pre.is_empty() { Vec::new() } else { vec![pre] };
        IterationTask {
            iter,
            mb: 0,
            views: vec![view],
            columns: Arc::new(columns),
            pre: Arc::new(pre),
            drafts: Arc::new(Vec::new()),
        }
    }
}

/// Control + data messages flowing engine → sampler.
pub enum SamplerMsg {
    /// A sequence enters the system: register its prompt + params with its
    /// owner sampler. `output` is non-empty when a preempted sequence
    /// resumes (recompute-on-resume): the owner replays those tokens into
    /// its local history/grammar state so penalties and constraints are
    /// byte-identical to an uninterrupted run.
    Register {
        seq_id: u64,
        prompt: Vec<u32>,
        output: Vec<u32>,
        params: SamplingParams,
        grammar: Option<Arc<GrammarConstraint>>,
    },
    /// Decide this iteration's owned columns.
    Iterate(Arc<IterationTask>),
    /// A sequence finished: drop its metadata.
    Retire { seq_id: u64 },
}

/// One sampler's decisions for one iteration.
#[derive(Debug)]
pub struct DecisionBatch {
    pub iter: u64,
    /// Microbatch tag copied from the task (stage-timeline attribution).
    pub mb: usize,
    pub sampler_id: usize,
    /// (column, seq_id, verdict) — a verdict commits 1..=k+1 tokens
    /// (accepted draft prefix + corrected bonus; exactly 1 without
    /// speculation).
    pub decisions: Vec<(usize, u64, Verdict)>,
    /// Wall seconds this sampler spent deciding (busy time).
    pub busy_s: f64,
    /// Busy interval endpoints, seconds since the service epoch (the
    /// engine's t0) — the stage timeline's raw material.
    pub start_s: f64,
    pub end_s: f64,
}

/// All `m` samplers' decisions for one task, assembled by the completion
/// queue (see [`SamplerService::try_collect`]).
#[derive(Debug, Default)]
pub struct Collected {
    /// Microbatch the task belonged to (as tagged by the submitter).
    pub mb: usize,
    /// Column-sorted (column, seq_id, verdict) triples.
    pub decisions: Vec<(usize, u64, Verdict)>,
    /// Max per-sampler busy seconds — the decision-plane latency that must
    /// hide under GPU compute.
    pub busy_s: f64,
    /// Per-sampler busy intervals (epoch seconds), for overlap accounting.
    pub intervals: Vec<(f64, f64)>,
}

/// Partially-assembled task result in the completion queue.
#[derive(Default)]
struct PendingCollect {
    mb: usize,
    decisions: Vec<(usize, u64, Verdict)>,
    intervals: Vec<(f64, f64)>,
    batches: usize,
    max_busy: f64,
}

/// Running service handle.
pub struct SamplerService {
    /// Per-sampler control/data rings. Locked because a *shared* pool has
    /// several engine replicas submitting concurrently; each ring still
    /// sees a serialized producer stream (register-before-iterate order is
    /// preserved per replica by the lock).
    senders: Mutex<Vec<spsc::Producer<SamplerMsg>>>,
    results: mpmc::Receiver<DecisionBatch>,
    /// Worker handles; slots are taken when a dead worker is joined for
    /// panic propagation, and drained at shutdown/drop.
    workers: Mutex<Vec<Option<JoinHandle<SamplerStats>>>>,
    /// Completion queue: batches drained off the return channel, bucketed
    /// by task id `(iter)` until all `m` samplers reported. Lets multiple
    /// microbatches' tasks be in flight and reaped out of order.
    pending: Mutex<HashMap<u64, PendingCollect>>,
    m: usize,
    /// Shared time origin the workers timestamp against (the engine's t0;
    /// a cluster's replicas all adopt it so fleet stage timelines merge).
    epoch: Instant,
}

/// Per-sampler lifetime statistics. (Speculative-decoding acceptance is
/// tallied engine-side from *committed* windows — see
/// `PjrtEngine::spec_accepted` — not here, where discarded-after-preemption
/// verdicts would skew the counts.)
#[derive(Debug, Clone, Default)]
pub struct SamplerStats {
    pub decisions: u64,
    pub fast_path_hits: u64,
    pub alpha_sum: f64,
    pub busy_s: f64,
}

/// A sampler's worker loop state.
struct SamplerWorker {
    id: usize,
    m: usize,
    pipeline: DecisionPipeline,
    /// Shared time origin (the engine's t0) so busy intervals are directly
    /// comparable with the engine's GPU stage timestamps.
    epoch: Instant,
    /// Histories of owned sequences, keyed by seq_id. Each history is a
    /// single-column BatchHistory (the column-wise machinery per sequence).
    owned: HashMap<u64, OwnedSeq>,
}

/// Per-sequence sampler-local state.
struct OwnedSeq {
    hist: BatchHistory,
    params: SamplingParams,
    grammar: Option<(Arc<GrammarConstraint>, ConstraintState)>,
}

impl SamplerWorker {
    fn owns(&self, seq_id: u64) -> bool {
        (seq_id as usize) % self.m == self.id
    }

    fn run(
        mut self,
        rx: spsc::Consumer<SamplerMsg>,
        tx: mpmc::Sender<DecisionBatch>,
        max_seq_len: usize,
    ) -> SamplerStats {
        let mut stats = SamplerStats::default();
        while let Some(msg) = rx.pop() {
            match msg {
                SamplerMsg::Register { seq_id, prompt, output, params, grammar } => {
                    if self.owns(seq_id) {
                        // resumed sequence: replay pre-preemption decisions
                        // into the history and the grammar state
                        let hist = BatchHistory::with_replay(prompt, &output, max_seq_len);
                        let mut grammar = grammar.map(|g| {
                            let s = g.start();
                            (g, s)
                        });
                        for &t in &output {
                            if let Some((g, state)) = &mut grammar {
                                if let Some(next) = g.advance(*state, t) {
                                    *state = next;
                                }
                            }
                        }
                        self.owned.insert(seq_id, OwnedSeq { hist, params, grammar });
                    }
                }
                SamplerMsg::Retire { seq_id } => {
                    if self.owns(seq_id) {
                        self.owned.remove(&seq_id);
                    }
                }
                SamplerMsg::Iterate(task) => {
                    let start_s = self.epoch.elapsed().as_secs_f64();
                    let mut decisions = Vec::new();
                    for (ci, meta) in task.columns.iter().enumerate() {
                        if !self.owns(meta.seq_id) {
                            continue;
                        }
                        let Some(seq) = self.owned.get_mut(&meta.seq_id) else {
                            continue; // retired concurrently; engine resends
                        };
                        let draft: &[u32] =
                            task.drafts.get(ci).map(Vec::as_slice).unwrap_or(&[]);
                        // One code path for both modes: with an empty draft
                        // this is exactly one grammar-masked decision plus
                        // the local metadata append (§5.1); with a draft it
                        // is batched rejection verification with
                        // roll-forward/rollback of the owned state.
                        let verdict = verify::verify_window(
                            &mut self.pipeline,
                            &task.views,
                            meta.col,
                            draft,
                            &mut seq.hist,
                            &mut seq.grammar,
                            &seq.params,
                            &task.pre,
                            meta.seq_id,
                            meta.iteration,
                        );
                        decisions.push((meta.col, meta.seq_id, verdict));
                    }
                    let end_s = self.epoch.elapsed().as_secs_f64();
                    let busy = end_s - start_s;
                    stats.busy_s += busy;
                    let batch = DecisionBatch {
                        iter: task.iter,
                        mb: task.mb,
                        sampler_id: self.id,
                        decisions,
                        busy_s: busy,
                        start_s,
                        end_s,
                    };
                    if tx.send(batch).is_err() {
                        break; // engine gone
                    }
                }
            }
        }
        stats.decisions = self.pipeline.decisions;
        stats.fast_path_hits = self.pipeline.fast_path_hits;
        stats.alpha_sum = self.pipeline.alpha_sum;
        stats
    }
}

/// Render a worker panic payload for error surfacing.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

impl SamplerService {
    /// Spawn `cfg.num_samplers` workers with a fresh time epoch. `hot` is
    /// required for the SHVS variant.
    pub fn start(cfg: &SamplerConfig, hot: Option<Arc<HotVocab>>, max_seq_len: usize) -> Self {
        Self::start_with_epoch(cfg, hot, max_seq_len, Instant::now())
    }

    /// Spawn workers that timestamp their busy intervals relative to
    /// `epoch` (the engine's t0), so decision intervals land on the same
    /// timeline as the engine's GPU stage intervals.
    pub fn start_with_epoch(
        cfg: &SamplerConfig,
        hot: Option<Arc<HotVocab>>,
        max_seq_len: usize,
        epoch: Instant,
    ) -> Self {
        let m = cfg.num_samplers.max(1);
        let (result_tx, results) = mpmc::channel::<DecisionBatch>(m * cfg.ring_depth.max(1) * 2);
        let mut senders = Vec::with_capacity(m);
        let mut workers = Vec::with_capacity(m);
        for id in 0..m {
            let (tx, rx) = spsc::ring::<SamplerMsg>(cfg.ring_depth.max(1) * 64);
            let worker = SamplerWorker {
                id,
                m,
                pipeline: DecisionPipeline::new(cfg.variant, hot.clone(), cfg.seed),
                epoch,
                owned: HashMap::new(),
            };
            let result_tx = result_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("sampler-{id}"))
                .spawn(move || worker.run(rx, result_tx, max_seq_len))
                .expect("spawn sampler");
            senders.push(tx);
            workers.push(Some(handle));
        }
        drop(result_tx);
        SamplerService {
            senders: Mutex::new(senders),
            results,
            workers: Mutex::new(workers),
            pending: Mutex::new(HashMap::new()),
            m,
            epoch,
        }
    }

    pub fn num_samplers(&self) -> usize {
        self.m
    }

    /// The time origin workers timestamp busy intervals against. Engines
    /// sharing this service adopt it as their t0 so GPU and decision stage
    /// intervals live on one fleet-wide timeline.
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Register a new sequence (broadcast; only the owner keeps it).
    pub fn register(&self, seq_id: u64, prompt: &[u32], params: &SamplingParams) {
        self.register_full(seq_id, prompt, &[], params, None);
    }

    /// Register with an optional structured-decoding constraint.
    pub fn register_with_grammar(
        &self,
        seq_id: u64,
        prompt: &[u32],
        params: &SamplingParams,
        grammar: Option<Arc<GrammarConstraint>>,
    ) {
        self.register_full(seq_id, prompt, &[], params, grammar);
    }

    /// Register a (possibly resumed) sequence: `output` carries tokens
    /// generated before a preemption, replayed into the owner's local state.
    pub fn register_full(
        &self,
        seq_id: u64,
        prompt: &[u32],
        output: &[u32],
        params: &SamplingParams,
        grammar: Option<Arc<GrammarConstraint>>,
    ) {
        let owner = (seq_id as usize) % self.m;
        self.senders.lock().unwrap()[owner].push(SamplerMsg::Register {
            seq_id,
            prompt: prompt.to_vec(),
            output: output.to_vec(),
            params: params.clone(),
            grammar,
        });
    }

    /// Retire a finished sequence.
    pub fn retire(&self, seq_id: u64) {
        let owner = (seq_id as usize) % self.m;
        self.senders.lock().unwrap()[owner].push(SamplerMsg::Retire { seq_id });
    }

    /// Publish one iteration's logits + metadata to all samplers. Shared
    /// pools rely on the caller namespacing `task.iter` (unique fleet-wide).
    pub fn submit(&self, task: IterationTask) {
        let task = Arc::new(task);
        for tx in self.senders.lock().unwrap().iter() {
            tx.push(SamplerMsg::Iterate(task.clone()));
        }
    }

    /// Bucket one returned batch into the completion queue.
    fn absorb(&self, batch: DecisionBatch) {
        let mut pending = self.pending.lock().unwrap();
        let entry = pending.entry(batch.iter).or_default();
        entry.mb = batch.mb;
        entry.batches += 1;
        entry.max_busy = entry.max_busy.max(batch.busy_s);
        if batch.end_s > batch.start_s {
            entry.intervals.push((batch.start_s, batch.end_s));
        }
        entry.decisions.extend(batch.decisions);
    }

    /// Remove task `iter` from the completion queue if all `m` sampler
    /// batches for it arrived.
    fn take_if_complete(&self, iter: u64) -> Option<Collected> {
        let mut pending = self.pending.lock().unwrap();
        if pending.get(&iter).is_some_and(|e| e.batches >= self.m) {
            let entry = pending.remove(&iter).unwrap();
            let mut decisions = entry.decisions;
            decisions.sort_unstable_by_key(|&(col, _, _)| col);
            Some(Collected {
                mb: entry.mb,
                decisions,
                busy_s: entry.max_busy,
                intervals: entry.intervals,
            })
        } else {
            None
        }
    }

    /// Propagate sampler-thread death: a worker whose handle is finished
    /// while the service is live either panicked (its payload is surfaced)
    /// or exited early — both are fatal to the iteration protocol. Without
    /// this check a dead worker deadlocks `collect` forever, because the
    /// surviving workers keep the return channel alive while the batch
    /// count can never reach `m`.
    fn check_workers(&self) -> crate::Result<()> {
        let mut workers = self.workers.lock().unwrap();
        for (id, slot) in workers.iter_mut().enumerate() {
            if slot.as_ref().is_some_and(|h| h.is_finished()) {
                let handle = slot.take().unwrap();
                return match handle.join() {
                    Err(payload) => Err(anyhow::anyhow!(
                        "sampler {id} panicked: {}",
                        panic_message(payload.as_ref())
                    )),
                    Ok(_) => Err(anyhow::anyhow!("sampler {id} exited mid-service")),
                };
            }
        }
        Ok(())
    }

    /// Non-blocking collect: drain whatever the samplers have pushed so
    /// far and return task `iter`'s assembled result if complete. Errors
    /// if a sampler thread died.
    pub fn try_collect(&self, iter: u64) -> crate::Result<Option<Collected>> {
        loop {
            if let Some(done) = self.take_if_complete(iter) {
                return Ok(Some(done));
            }
            match self.results.try_recv() {
                Some(batch) => self.absorb(batch),
                None => {
                    self.check_workers()?;
                    return Ok(None);
                }
            }
        }
    }

    /// Blocking collect for task `iter`: waits until all `m` sampler
    /// batches arrived, surfacing worker panics as errors instead of
    /// deadlocking (the satellite fix: join-on-death with error surfacing).
    pub fn collect_checked(&self, iter: u64) -> crate::Result<Collected> {
        loop {
            if let Some(done) = self.take_if_complete(iter) {
                return Ok(done);
            }
            match self.results.recv_timeout(Duration::from_millis(20)) {
                Ok(Some(batch)) => self.absorb(batch),
                Ok(None) => anyhow::bail!("decision plane disconnected"),
                Err(()) => self.check_workers()?, // starved: look for corpses
            }
        }
    }

    /// Collect decisions for iteration `iter` (blocks until all `m` sampler
    /// batches for that iteration arrived). Returns (col → (seq, verdict))
    /// plus the max per-sampler busy time (the decision-plane latency that
    /// must hide under GPU compute). `expected_cols` is the caller's
    /// submitted column count, asserted against what came back — a mismatch
    /// means a sequence was decided by zero or two owners. Panics if a
    /// sampler died — callers on the fallible path use
    /// [`Self::collect_checked`].
    pub fn collect(&self, iter: u64, expected_cols: usize) -> (Vec<(usize, u64, Verdict)>, f64) {
        let done = self.collect_checked(iter).expect("decision plane failed");
        debug_assert_eq!(
            done.decisions.len(),
            expected_cols,
            "task {iter}: decided columns != submitted columns"
        );
        (done.decisions, done.busy_s)
    }

    /// Close the rings and join every worker. Returns the stats of workers
    /// that exited cleanly; panicked workers are surfaced per `propagate`
    /// (true = re-panic, false = log and continue — the drop path).
    fn join_all(&mut self, propagate: bool) -> Vec<SamplerStats> {
        let mut senders = self.senders.lock().unwrap();
        for tx in senders.iter() {
            tx.close();
        }
        senders.clear(); // Producer::drop closes the rings
        drop(senders);
        let mut handles: Vec<Option<JoinHandle<SamplerStats>>> =
            std::mem::take(&mut *self.workers.lock().unwrap());
        // Drain stray result batches while workers wind down so none blocks
        // forever on a full return channel (timed waits, not a spin: each
        // worker drops its sender on exit, so `Ok(None)` means all done).
        loop {
            match self.results.recv_timeout(Duration::from_millis(5)) {
                Ok(Some(_)) => {}  // discard a stray batch
                Ok(None) => break, // every worker dropped its sender
                Err(()) => {
                    let all_done = handles
                        .iter()
                        .all(|h| h.as_ref().is_none_or(|h| h.is_finished()));
                    if all_done {
                        break;
                    }
                }
            }
        }
        while self.results.try_recv().is_some() {}
        let mut stats = Vec::new();
        for (id, slot) in handles.iter_mut().enumerate() {
            let Some(handle) = slot.take() else { continue };
            match handle.join() {
                Ok(s) => stats.push(s),
                Err(payload) => {
                    let msg =
                        format!("sampler {id} panicked: {}", panic_message(payload.as_ref()));
                    if propagate && !std::thread::panicking() {
                        panic!("{msg}");
                    }
                    eprintln!("[sampler-service] {msg}");
                }
            }
        }
        stats
    }

    /// Shut down and return per-sampler stats. Panics if a worker panicked
    /// (explicit shutdown wants the failure loud).
    pub fn shutdown(mut self) -> Vec<SamplerStats> {
        self.join_all(true)
    }
}

impl Drop for SamplerService {
    /// Join-on-drop: an engine that errors out (or a panicking test) still
    /// tears the workers down instead of leaking threads; worker panics are
    /// surfaced to stderr rather than silently swallowed.
    fn drop(&mut self) {
        self.join_all(false);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decision::draft::DraftProposer;
    use crate::harness::measure::LogitsGen;
    use crate::tensor::{shard_row_major, Tensor2};

    fn logits_view(b: usize, v: usize, iter: u64, shards: usize) -> ShardedLogits {
        let data: Vec<f32> = (0..b * v)
            .map(|i| {
                let x = (i as u64).wrapping_mul(2654435761).wrapping_add(iter * 97);
                ((x % 1000) as f32) / 150.0 - 3.0
            })
            .collect();
        shard_row_major(&Tensor2::from_vec(b, v, data), shards)
    }

    fn run_service(m: usize, variant: DecisionVariant, iters: u64) -> Vec<Vec<u32>> {
        let v = 64;
        let b = 6;
        let cfg = SamplerConfig {
            num_samplers: m,
            variant,
            seed: 42,
            ..Default::default()
        };
        let hot = HotVocab::new((0..16).collect(), v).into_arc();
        let svc = SamplerService::start(&cfg, Some(hot), 128);
        let params = SamplingParams::production_default();
        for s in 0..b as u64 {
            svc.register(s, &[1, 2, 3], &params);
        }
        let mut streams: Vec<Vec<u32>> = vec![Vec::new(); b];
        for iter in 0..iters {
            let view = logits_view(b, v, iter, 2);
            let columns: Vec<ColumnMeta> = (0..b)
                .map(|col| ColumnMeta { col, seq_id: col as u64, iteration: iter })
                .collect();
            svc.submit(IterationTask::single(iter, view, columns, Vec::new()));
            let (decisions, _busy) = svc.collect(iter, b);
            assert_eq!(decisions.len(), b, "every column decided");
            for (col, seq, verdict) in decisions {
                assert_eq!(col as u64, seq);
                assert_eq!(verdict.tokens.len(), 1, "non-speculative: one token");
                streams[col].push(verdict.tokens[0]);
            }
        }
        for s in 0..b as u64 {
            svc.retire(s);
        }
        let stats = svc.shutdown();
        assert_eq!(stats.len(), m);
        let total: u64 = stats.iter().map(|s| s.decisions).sum();
        assert_eq!(total, iters * b as u64);
        streams
    }

    /// Drive the service with speculative windows of size `k` until every
    /// sequence committed ≥ `total` tokens. Logits are keyed by
    /// (seq, decode_iter) — the context-free synthetic data plane — so the
    /// streams must be bit-identical across `k` and `m`.
    fn run_service_spec(m: usize, k: usize, total: usize) -> Vec<Vec<u32>> {
        let vocab = 256;
        let b = 4usize;
        let gen = LogitsGen::new(vocab, 1.1, 5);
        let proposer = DraftProposer::new();
        let cfg = SamplerConfig {
            num_samplers: m,
            variant: DecisionVariant::Offloading,
            seed: 17,
            ..Default::default()
        };
        let svc = SamplerService::start(&cfg, None, 512);
        let prompts: Vec<Vec<u32>> = (0..b).map(|s| vec![s as u32 + 1, 9]).collect();
        let params: Vec<SamplingParams> = (0..b)
            .map(|s| SamplingParams { seed: s as u64, ..SamplingParams::production_default() })
            .collect();
        for s in 0..b {
            svc.register(s as u64, &prompts[s], &params[s]);
        }
        let mut streams: Vec<Vec<u32>> = vec![Vec::new(); b];
        let mut iter = 0u64;
        while streams.iter().any(|s| s.len() < total) {
            let live: Vec<usize> =
                (0..b).filter(|&s| streams[s].len() < total).collect();
            let drafts: Vec<Vec<u32>> = live
                .iter()
                .map(|&s| {
                    proposer.propose(params[s].seed, vocab, &prompts[s], &streams[s], k)
                })
                .collect();
            let kmax = drafts.iter().map(Vec::len).max().unwrap_or(0);
            let columns: Vec<ColumnMeta> = live
                .iter()
                .enumerate()
                .map(|(col, &s)| ColumnMeta {
                    col,
                    seq_id: s as u64,
                    iteration: streams[s].len() as u64,
                })
                .collect();
            // view j: per-column logits at that column's decode_iter + j
            let views: Vec<ShardedLogits> = (0..=kmax as u64)
                .map(|j| {
                    let keys: Vec<(u64, u64)> = live
                        .iter()
                        .map(|&s| (s as u64, streams[s].len() as u64 + j))
                        .collect();
                    gen.seq_view(&keys, 2)
                })
                .collect();
            svc.submit(IterationTask {
                iter,
                mb: 0,
                views,
                columns: Arc::new(columns),
                pre: Arc::new(Vec::new()),
                drafts: Arc::new(drafts),
            });
            let (decisions, _busy) = svc.collect(iter, live.len());
            assert_eq!(decisions.len(), live.len());
            for (col, seq, verdict) in decisions {
                let _ = col;
                streams[seq as usize].extend(&verdict.tokens);
            }
            iter += 1;
        }
        for s in 0..b as u64 {
            svc.retire(s);
        }
        svc.shutdown();
        for s in streams.iter_mut() {
            s.truncate(total);
        }
        streams
    }

    #[test]
    fn speculative_streams_bit_identical_across_k_and_m() {
        // The tentpole's end-to-end service contract: verified speculative
        // decode commits the same stream as plain decode for any window
        // size k and any sampler count m.
        let baseline = run_service_spec(1, 0, 24);
        for (m, k) in [(1usize, 2usize), (2, 2), (4, 4), (2, 3)] {
            let spec = run_service_spec(m, k, 24);
            assert_eq!(spec, baseline, "m={m} k={k}");
        }
    }

    #[test]
    fn service_decides_all_columns() {
        let streams = run_service(3, DecisionVariant::Offloading, 8);
        assert!(streams.iter().all(|s| s.len() == 8));
    }

    #[test]
    fn token_streams_invariant_to_sampler_count() {
        // §5.1 determinism: m=1 and m=4 must produce identical tokens.
        let a = run_service(1, DecisionVariant::Offloading, 10);
        let b = run_service(4, DecisionVariant::Offloading, 10);
        assert_eq!(a, b);
        let c = run_service(2, DecisionVariant::Shvs, 10);
        let d = run_service(5, DecisionVariant::Shvs, 10);
        assert_eq!(c, d);
    }

    #[test]
    fn shvs_service_matches_offloading_distributionally() {
        // Not token-exact (different uniform usage) but same distribution —
        // light smoke here; the heavy TVD check lives in shvs::tests.
        let a = run_service(2, DecisionVariant::Shvs, 30);
        let b = run_service(2, DecisionVariant::Offloading, 30);
        // same length streams, tokens within vocab
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.len(), y.len());
            assert!(x.iter().all(|&t| (t as usize) < 64));
            assert!(y.iter().all(|&t| (t as usize) < 64));
        }
    }

    #[test]
    fn worker_panic_surfaces_instead_of_deadlocking() {
        // A column index past the view's batch makes the owning sampler
        // panic mid-iteration. Before the completion-queue rework this
        // deadlocked `collect` forever (the surviving workers keep the
        // return channel open while the batch count can never reach m);
        // now the dead worker is joined and its panic surfaces as an error.
        let cfg = SamplerConfig {
            num_samplers: 2,
            variant: DecisionVariant::Offloading,
            ..Default::default()
        };
        let svc = SamplerService::start(&cfg, None, 64);
        let params = SamplingParams::default();
        svc.register(0, &[1], &params);
        let view = logits_view(1, 32, 0, 1);
        svc.submit(IterationTask::single(
            0,
            view,
            vec![ColumnMeta { col: 7, seq_id: 0, iteration: 0 }],
            Vec::new(),
        ));
        let res = svc.collect_checked(0);
        let err = res.expect_err("dead sampler must surface, not deadlock");
        let msg = format!("{err:#}");
        assert!(
            msg.contains("sampler") && msg.contains("panicked"),
            "unhelpful error: {msg}"
        );
        // drop (join-on-drop) must not re-panic the test thread
        drop(svc);
    }

    #[test]
    fn completion_queue_reaps_tasks_out_of_order() {
        // Two tasks in flight at once (the pipelined executor's shape):
        // reaping the later one first must work, and the earlier one's
        // batches stay buffered in the completion queue.
        let cfg = SamplerConfig {
            num_samplers: 2,
            variant: DecisionVariant::Offloading,
            seed: 9,
            ..Default::default()
        };
        let svc = SamplerService::start(&cfg, None, 128);
        let params = SamplingParams::production_default();
        for s in 0..2u64 {
            svc.register(s, &[1, 2], &params);
        }
        for iter in 0..2u64 {
            let view = logits_view(2, 64, iter, 1);
            let columns: Vec<ColumnMeta> = (0..2)
                .map(|col| ColumnMeta { col, seq_id: col as u64, iteration: iter })
                .collect();
            svc.submit(IterationTask::single(iter, view, columns, Vec::new()));
        }
        let later = svc.collect_checked(1).expect("task 1");
        assert_eq!(later.decisions.len(), 2);
        assert!(later.busy_s >= 0.0);
        // task 0 completes too (possibly already buffered by the first
        // collect's draining; otherwise try_collect drains it here)
        let earlier = loop {
            if let Some(done) = svc.try_collect(0).expect("no dead workers") {
                break done;
            }
            std::thread::yield_now();
        };
        assert_eq!(earlier.decisions.len(), 2);
        for (start, end) in earlier.intervals.iter().chain(&later.intervals) {
            assert!(end >= start, "interval {start}..{end}");
        }
        for s in 0..2u64 {
            svc.retire(s);
        }
        svc.shutdown();
    }

    #[test]
    fn retire_frees_ownership() {
        let cfg = SamplerConfig {
            num_samplers: 2,
            variant: DecisionVariant::Offloading,
            ..Default::default()
        };
        let svc = SamplerService::start(&cfg, None, 64);
        let params = SamplingParams::default();
        svc.register(7, &[1], &params);
        svc.retire(7);
        // Iterating a retired sequence: no decision is produced for it.
        let view = logits_view(1, 32, 0, 1);
        svc.submit(IterationTask::single(
            0,
            view,
            vec![ColumnMeta { col: 0, seq_id: 7, iteration: 0 }],
            Vec::new(),
        ));
        let (decisions, _) = svc.collect(0, 0);
        assert!(decisions.is_empty());
        svc.shutdown();
    }
}
